//! Spectral convolution on real signals: matched-filter a real chirp
//! out of noise with BOTH transforms on the half-precision R2C/C2R
//! path — the workload the real-input engine exists for (seismic
//! filtering, image correlation, Toeplitz solvers all feed real data).
//!
//!     cargo run --release --example spectral_conv
//!
//! Pipeline: template -> rfft (device, once at build) ; strain ->
//! rfft (device) -> pointwise cross-spectrum (host f32, 1/n folded in)
//! -> irfft (device) -> correlation peak = injection time.

use tcfft::runtime::Runtime;
use tcfft::util::rng::SplitMix64;
use tcfft::workload::{chirp, SpectralConv};

const N: usize = 8192;
const TEMPLATE_LEN: usize = 1024;

fn main() -> tcfft::error::Result<()> {
    let rt = Runtime::load_default()?;

    // a real chirp template (the real part of the complex chirp the
    // pyCBC example uses)
    let template: Vec<f32> = chirp(TEMPLATE_LEN, 6.0, 80.0, 0.8)
        .iter()
        .map(|c| c.re)
        .collect();

    // strain: the template injected at a known lag into real noise
    let inject_at = 2953usize;
    let mut rng = SplitMix64::new(41);
    let mut strain: Vec<f32> = (0..N).map(|_| 0.15 * rng.normal() as f32).collect();
    for (i, &t) in template.iter().enumerate() {
        strain[(inject_at + i) % N] += 0.4 * t;
    }

    // build once (one R2C over the reversed template), then filter:
    // R2C -> pointwise multiply -> C2R, ~2x cheaper than the C2C pair
    let mf = SpectralConv::matched_filter(&rt, N, &template)?;
    let snr = mf.convolve(&rt, &strain)?;

    let (best_lag, best) = snr
        .iter()
        .map(|v| v.abs())
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    let mean = snr.iter().map(|v| v.abs()).sum::<f32>() / N as f32;

    println!("injected template at lag {inject_at}");
    println!(
        "matched filter peak at lag {best_lag} (peak/mean ratio {:.1})",
        best / mean
    );
    tcfft::ensure!(best_lag == inject_at, "matched filter missed the injection");
    tcfft::ensure!(best / mean > 5.0, "detection not significant");
    println!("spectral_conv: OK — detection at the injected time via R2C/C2R");
    Ok(())
}
