//! Batched 2D FFT image pipeline (the paper's medical-imaging
//! motivation, Sec 1): low-pass filter a batch of synthetic CT-phantom
//! slices in the frequency domain and report reconstruction PSNR.
//!
//! Images are REAL, so both directions ride the packed R2C/C2R 2D
//! path (`Plan::rfft2d` / `Plan::irfft2d`): the spectrum holds only
//! the `ny/2 + 1` non-redundant Hermitian bins per row, and each
//! transform costs roughly half its promote-to-complex counterpart.
//!
//!     cargo run --release --example image_pipeline_2d

use tcfft::plan::Plan;
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::workload::phantom_image;

const NX: usize = 256;
const NY: usize = 256;
/// packed Hermitian bins per image row
const BINS: usize = NY / 2 + 1;
const BATCH: usize = 2;

fn psnr(a: &[f32], b: &[f32]) -> f64 {
    let mse: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64;
    10.0 * (1.0f64 / mse.max(1e-12)).log10()
}

fn main() -> tcfft::error::Result<()> {
    let rt = Runtime::load_default()?;
    let fwd = Plan::rfft2d(&rt.registry, NX, NY, BATCH)?;
    let inv = Plan::irfft2d(&rt.registry, NX, NY, BATCH)?;

    // batch of phantoms (real images — the R2C path reads only `re`)
    let mut input = PlanarBatch::new(vec![BATCH, NX, NY]);
    let mut originals = Vec::new();
    for b in 0..BATCH {
        let img = phantom_image(NX, NY, 11 + b as u64);
        input.re[b * NX * NY..(b + 1) * NX * NY].copy_from_slice(&img);
        originals.push(img);
    }

    // forward R2C 2D FFT on device: [b, nx, ny] -> [b, nx, ny/2 + 1]
    let mut spec = fwd.execute(&rt, input.clone())?;
    tcfft::ensure!(spec.shape == vec![BATCH, NX, BINS], "packed shape {:?}", spec.shape);

    // low-pass: zero all bins with radial frequency > cutoff. Packed
    // columns c run 0..=ny/2 only — the mirror half never exists, so
    // the filter touches half the data a complex pipeline would.
    let cutoff = 0.25 * NX as f64;
    let mut kept = 0usize;
    for b in 0..BATCH {
        for r in 0..NX {
            for c in 0..BINS {
                let fr = r.min(NX - r) as f64;
                let fc = c as f64; // c <= ny/2 already
                let idx = (b * NX + r) * BINS + c;
                if (fr * fr + fc * fc).sqrt() > cutoff {
                    spec.re[idx] = 0.0;
                    spec.im[idx] = 0.0;
                } else if b == 0 {
                    kept += 1;
                }
            }
        }
    }

    // normalize the spectrum into fp16 range for the inverse transform
    // (DC bin of a [0,1] image is ~N^2/2 >> fp16 max)
    let scale = (NX * NY) as f32;
    for v in spec.re.iter_mut().chain(spec.im.iter_mut()) {
        *v /= scale;
    }

    // inverse C2R on device (unnormalized, so /scale above is exactly
    // 1/(nx*ny)): packed bins back to [b, nx, ny] real samples
    let recon = inv.execute(&rt, spec)?;
    tcfft::ensure!(recon.shape == vec![BATCH, NX, NY], "real shape {:?}", recon.shape);

    for b in 0..BATCH {
        let rec: Vec<f32> = recon.re[b * NX * NY..(b + 1) * NX * NY].to_vec();
        let p = psnr(&originals[b], &rec);
        println!(
            "image {b}: kept {:.1}% of the packed spectrum, reconstruction PSNR {p:.1} dB",
            100.0 * kept as f64 / (NX * BINS) as f64
        );
        tcfft::ensure!(p > 20.0, "low-pass reconstruction too lossy: {p:.1} dB");
    }
    println!("image_pipeline_2d: OK");
    Ok(())
}
