//! Large-FFT composition (paper Sec 3.1: "larger size FFTs can be
//! realized by combining these basic kernels"): compute a 2^20-point
//! FFT with the four-step algorithm over 1024-point device artifacts,
//! and verify against the host f64 radix-2 FFT.
//!
//!     cargo run --release --example fourstep_large [-- --log2n 20]

use tcfft::error::relative_error;
use tcfft::fft::radix2;
use tcfft::hp::C64;
use tcfft::large::FourStepPlan;
use tcfft::runtime::Runtime;
use tcfft::util::cli::Args;
use tcfft::workload::random_signal;

fn main() -> tcfft::error::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let log2n = args.get_usize("log2n", 20);
    let n = 1usize << log2n;

    let rt = Runtime::load_default()?;
    let plan = FourStepPlan::new(&rt, n, false)?;
    println!(
        "four-step: N = 2^{log2n} = {} x {} over batched 1024-pt artifacts",
        plan.n1, plan.n2
    );

    let x = random_signal(n, 777);
    let t0 = std::time::Instant::now();
    let y = plan.execute(&rt, &x)?;
    let dt = t0.elapsed().as_secs_f64();

    // oracle on the fp16-quantized input
    let q: Vec<C64> = x
        .iter()
        .map(|c| {
            C64::new(
                tcfft::hp::F16::from_f32(c.re).to_f64(),
                tcfft::hp::F16::from_f32(c.im).to_f64(),
            )
        })
        .collect();
    let want = radix2::fft_vec(&q, false);
    let got: Vec<C64> = y.iter().map(|c| C64::new(c.re as f64, c.im as f64)).collect();
    let err = relative_error(&want, &got);
    println!("computed 2^{log2n}-point FFT in {:.1} ms, mean relative error {err:.3e}", dt * 1e3);
    tcfft::ensure!(err < 0.02, "four-step error too high");
    println!("fourstep_large: OK");
    Ok(())
}
