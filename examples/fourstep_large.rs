//! Large-FFT composition (paper Sec 3.1: "larger size FFTs can be
//! realized by combining these basic kernels"): transform a whole
//! batch of 2^20-point sequences through the batched four-step engine
//! and verify row 0 against the host f64 radix-2 FFT.
//!
//!     cargo run --release --example fourstep_large \
//!         [-- --log2n 20 --batch 4 --algo tc]
//!
//! `--algo` selects the leaf algorithm (`tc`, `tc_split`, `r2`);
//! factors without artifacts for it fall back to `tc`. Host-side
//! transpose/twiddle steps parallelize per `TCFFT_THREADS`.

use tcfft::error::relative_error;
use tcfft::fft::radix2;
use tcfft::hp::{C32, C64};
use tcfft::large::FourStepPlan;
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::util::cli::Args;
use tcfft::workload::random_signal;

fn main() -> tcfft::error::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let log2n = args.get_usize("log2n", 20);
    let batch = args.get_usize("batch", 4);
    let algo = args.get_str("algo", "tc");
    let n = 1usize << log2n;

    let rt = Runtime::load_default()?;
    let plan = FourStepPlan::with_algo(&rt, n, algo, false)?;
    println!(
        "four-step: N = 2^{log2n}, batch {batch}, decomposition {} ({} levels, {} host threads)",
        plan.describe(),
        plan.depth(),
        plan.threads()
    );

    let x: Vec<C32> = (0..batch as u64)
        .flat_map(|b| random_signal(n, 777 + b))
        .collect();
    let input = PlanarBatch::from_complex(&x, vec![batch, n]);
    let t0 = std::time::Instant::now();
    let y = plan.execute_batch(&rt, input.clone())?;
    let dt = t0.elapsed().as_secs_f64();

    // oracle on the fp16-quantized row 0
    let q = input.slice_rows(0, 1).quantize_f16();
    let want = radix2::fft_vec(
        &q.to_complex()
            .iter()
            .map(|c| C64::new(c.re as f64, c.im as f64))
            .collect::<Vec<_>>(),
        false,
    );
    let got: Vec<C64> = y
        .slice_rows(0, 1)
        .to_complex()
        .iter()
        .map(|c| C64::new(c.re as f64, c.im as f64))
        .collect();
    let err = relative_error(&want, &got);
    println!(
        "computed {batch} x 2^{log2n}-point FFTs in {:.1} ms ({:.1} ms/seq), \
         mean relative error {err:.3e}",
        dt * 1e3,
        dt * 1e3 / batch as f64
    );
    tcfft::ensure!(err < 0.02, "four-step error too high");
    println!("fourstep_large: OK");
    Ok(())
}
