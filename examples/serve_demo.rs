//! End-to-end serving driver (DESIGN.md "E2E serving driver"): start
//! the FFT service, fire a Poisson stream of mixed 1D/2D requests from
//! concurrent clients, and report latency/throughput + batching
//! metrics.  This is the run recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example serve_demo [-- --seconds 10 --rate 120]
//!
//! `--chaos` runs the same offered load against a deterministically
//! faulty service (scheduled exec panics, worker kills, forced plan
//! evictions, injected delays) and reports the failure metrics — a
//! smoke-level version of `tests/chaos_service.rs` you can watch.

use std::sync::Arc;
use std::time::{Duration, Instant};

use tcfft::coordinator::faults::install_quiet_panic_hook;
use tcfft::coordinator::{FaultInjector, FaultPlan, FftRequest, FftService, Op, ServiceConfig};
use tcfft::plan::Direction;
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::util::cli::Args;
use tcfft::util::rng::SplitMix64;
use tcfft::util::stats::Summary;
use tcfft::workload::random_signal;

fn main() -> tcfft::error::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let horizon = args.get_f64("seconds", 10.0);
    let rate = args.get_f64("rate", 120.0);
    let n_clients = args.get_f64("clients", 4.0).max(1.0) as usize;
    let chaos = args.has_flag("chaos");

    let rt = Arc::new(Runtime::load_default()?);
    // warm the artifacts the workload uses (compile once, off the clock)
    for key in [
        "fft1d_tc_n1024_b4_fwd",
        "fft1d_tc_n4096_b4_fwd",
        "fft2d_tc_nx256x256_b2_fwd",
        "rfft2d_tc_nx128x128_b4_fwd",
    ] {
        rt.warm(key)?;
    }
    let faults = if chaos {
        install_quiet_panic_hook();
        // a mixed schedule: frequent-enough panics and kills to watch
        // the recovery paths, rare-enough delays to keep the offered
        // load realistic
        Arc::new(FaultInjector::new(FaultPlan {
            panic_every: 7,
            panic_limit: 25,
            kill_worker_every: 20,
            kill_worker_limit: 4,
            exec_delay: Duration::from_millis(2),
            exec_delay_prob: 0.05,
            evict_every: 11,
            ..FaultPlan::default()
        }))
    } else {
        Arc::new(FaultInjector::disabled())
    };
    let svc = Arc::new(FftService::start(
        Arc::clone(&rt),
        ServiceConfig {
            max_wait: Duration::from_millis(5),
            faults: Arc::clone(&faults),
            ..ServiceConfig::default()
        },
    ));
    // a 3-filter bank for the convolve route: smoother, differencer,
    // and a short low-pass FIR over 1024-sample signals
    let fir: Vec<f32> = (0..16).map(|i| 0.4 / (1.0 + i as f32)).collect();
    svc.register_filter_bank(
        "demo",
        1024,
        &[vec![0.25f32, 0.5, 0.25], vec![1.0, -1.0], fir],
        "tc",
    )?;

    // request mix: 40% 1D/1024, 20% 1D/4096, 10% R2C/4096,
    // 10% R2C-2D/128x128, 15% 2D/256x256, 5% filter-bank convolve
    println!(
        "offered load: Poisson {rate:.0} req/s for {horizon:.0}s \
         (mix: 40% 1D/1024, 20% 1D/4096, 10% R2C/4096, \
          10% rfft2d/128x128, 15% 2D/256x256, 5% convolve/1024x3)"
    );
    let t0 = Instant::now();
    let mut rng = SplitMix64::new(2026);
    let mut lat = Summary::new();
    let mut issued = 0u64;
    let mut failed = 0u64;
    let mut workers: Vec<std::thread::JoinHandle<(Summary, u64)>> = Vec::new();
    for c in 0..n_clients {
        let svc = Arc::clone(&svc);
        let mut crng = rng.fork();
        let horizon = horizon;
        let rate = rate / n_clients as f64;
        workers.push(std::thread::spawn(move || {
            let mut lat = Summary::new();
            let mut failed = 0u64;
            let t0 = Instant::now();
            loop {
                let wait = crng.exp(rate);
                std::thread::sleep(Duration::from_secs_f64(wait));
                if t0.elapsed().as_secs_f64() >= horizon {
                    break;
                }
                let pick = crng.next_f64();
                if pick >= 0.95 {
                    // filter-bank convolve: one real signal, all three
                    // registered filters back in one reply
                    let sig: Vec<f32> = random_signal(1024, crng.next_u64())
                        .iter()
                        .map(|v| v.re)
                        .collect();
                    let t_req = Instant::now();
                    let input = PlanarBatch::from_real(&sig, vec![1024]);
                    // bounded wait: under --chaos a reply may be an
                    // injected failure, but it must never be a hang
                    match svc
                        .submit_convolve_as(c as u64, "demo", input)
                        .and_then(|t| t.wait_timeout(Duration::from_secs(30)))
                    {
                        Ok(_) => lat.add(t_req.elapsed().as_secs_f64()),
                        Err(e) => {
                            failed += 1;
                            if failed <= 3 {
                                eprintln!("client {c}: {e}");
                            }
                        }
                    }
                    continue;
                }
                let (op, data_len) = if pick < 0.4 {
                    (Op::Fft1d { n: 1024 }, 1024)
                } else if pick < 0.6 {
                    (Op::Fft1d { n: 4096 }, 4096)
                } else if pick < 0.7 {
                    // real-signal clients ride the packed R2C route
                    (Op::Rfft1d { n: 4096 }, 4096)
                } else if pick < 0.8 {
                    // real image fields ride the packed 2D route
                    (Op::Rfft2d { nx: 128, ny: 128 }, 128 * 128)
                } else {
                    (Op::Fft2d { nx: 256, ny: 256 }, 65536)
                };
                let sig = random_signal(data_len, crng.next_u64());
                let shape = match op {
                    Op::Fft1d { n } | Op::Rfft1d { n } => vec![n],
                    Op::Fft2d { nx, ny } | Op::Rfft2d { nx, ny } => vec![nx, ny],
                };
                let req = FftRequest {
                    op,
                    algo: "tc".into(),
                    direction: Direction::Forward,
                    input: PlanarBatch::from_complex(&sig, shape),
                };
                let t_req = Instant::now();
                match svc
                    .submit_as(c as u64, req)
                    .and_then(|t| t.wait_timeout(Duration::from_secs(30)))
                {
                    Ok(_) => lat.add(t_req.elapsed().as_secs_f64()),
                    Err(e) => {
                        failed += 1;
                        if failed <= 3 {
                            eprintln!("client {c}: {e}");
                        }
                    }
                }
            }
            (lat, failed)
        }));
    }
    for w in workers {
        let (l, f) = w.join().unwrap();
        issued += l.len() as u64 + f;
        failed += f;
        lat = merge(lat, l);
    }
    let wall = t0.elapsed().as_secs_f64();
    svc.shutdown();

    let m = svc.metrics();
    println!("\n== serve_demo results ==");
    println!("wall time             : {wall:.2} s");
    println!("requests issued       : {issued} ({failed} failed)");
    println!("completed throughput  : {:.1} req/s", lat.len() as f64 / wall);
    println!("latency p50 / p99     : {:.2} / {:.2} ms", lat.median() * 1e3, lat.p99() * 1e3);
    println!("service metrics       : {}", m.snapshot().to_string());
    if chaos {
        use std::sync::atomic::Ordering;
        let snap = m.snapshot();
        println!("\n== chaos report ==");
        println!(
            "injected              : {} exec panics, {} worker kills, \
             {} forced evictions, {} delays",
            faults.panics_injected(),
            faults.kills_injected(),
            faults.evicts_forced(),
            faults.delays_injected()
        );
        println!(
            "recovered             : exec_panics={} worker_restarts={} deadline_shed={}",
            m.exec_panics.load(Ordering::Relaxed),
            m.worker_restarts.load(Ordering::Relaxed),
            m.deadline_shed.load(Ordering::Relaxed)
        );
        if let Some(codes) = snap.get("errors_by_code") {
            println!("errors by code        : {}", codes.to_string());
        }
        // the books must balance even under chaos: every injected
        // panic was caught and counted, nothing hung, work completed
        tcfft::ensure!(
            m.exec_panics.load(Ordering::Relaxed) == faults.panics_injected(),
            "exec_panics metric diverged from the injection plan"
        );
        tcfft::ensure!(lat.len() > 0, "no requests completed under chaos");
        println!("serve_demo (chaos): OK — {failed} injected failures, all isolated");
    } else {
        tcfft::ensure!(failed == 0, "requests failed");
        tcfft::ensure!(lat.len() > 0, "no requests completed");
        println!("serve_demo: OK");
    }
    Ok(())
}

fn merge(mut a: Summary, b: Summary) -> Summary {
    for q in b.raw() {
        a.add(*q);
    }
    a
}
