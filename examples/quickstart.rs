//! Quickstart: plan and execute 1D and 2D half-precision FFTs through
//! the AOT artifacts, verifying against the host f64 oracle.
//!
//!     cargo run --release --example quickstart

use tcfft::error::relative_error;
use tcfft::fft::mixed::fft_mixed_batch;
use tcfft::hp::{C32, C64};
use tcfft::plan::{Direction, Plan};
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::workload::random_signal;

fn widen(x: &[C32]) -> Vec<C64> {
    x.iter().map(|c| C64::new(c.re as f64, c.im as f64)).collect()
}

fn main() -> tcfft::error::Result<()> {
    let rt = Runtime::load_default()?;

    // --- sanity: impulse input -> flat spectrum -------------------------
    let n = 256;
    let plan = Plan::fft1d(&rt.registry, n, 1)?;
    let mut x = vec![C32::new(0.0, 0.0); n];
    x[0] = C32::new(1.0, 0.0);
    let out = plan.execute(&rt, PlanarBatch::from_complex(&x, vec![1, n]))?;
    let y = out.to_complex();
    println!("impulse -> X[0]={:?} X[1]={:?} X[{}]={:?}", y[0], y[1], n - 1, y[n - 1]);
    for (k, v) in y.iter().enumerate() {
        tcfft::ensure!(
            (v.re - 1.0).abs() < 0.05 && v.im.abs() < 0.05,
            "impulse FFT wrong at bin {k}: {v:?}"
        );
    }
    println!("impulse OK");

    // --- batched random 1D, checked against the f64 oracle -------------
    let n = 4096;
    let batch = 4;
    let plan = Plan::fft1d(&rt.registry, n, batch)?;
    println!("1D plan: {} radices {:?}", plan.meta.key, plan.radices_1d);
    let x: Vec<C32> = (0..batch).flat_map(|b| random_signal(n, b as u64)).collect();
    let input = PlanarBatch::from_complex(&x, vec![batch, n]);
    let out = plan.execute(&rt, input.clone())?;
    let want = fft_mixed_batch(&widen(&input.quantize_f16().to_complex()), batch, n, false);
    let err = relative_error(&want, &widen(&out.to_complex()));
    println!("1D n={n} batch={batch}: mean relative error {err:.3e}");
    tcfft::ensure!(err < 0.02, "1D error too high");

    // --- inverse round trip ---------------------------------------------
    let fwd = Plan::fft1d(&rt.registry, 1024, 4)?;
    let inv = Plan::fft1d_algo(&rt.registry, 1024, 4, "tc", Direction::Inverse)?;
    let x: Vec<C32> = (0..4).flat_map(|b| random_signal(1024, 50 + b as u64)).collect();
    let input = PlanarBatch::from_complex(&x, vec![4, 1024]);
    let spec = fwd.execute(&rt, input.clone())?;
    let mut back = inv.execute(&rt, spec)?;
    // inverse is unnormalized (cuFFT convention): scale by 1/N on host
    for v in back.re.iter_mut().chain(back.im.iter_mut()) {
        *v /= 1024.0;
    }
    let err = relative_error(
        &widen(&input.quantize_f16().to_complex()),
        &widen(&back.to_complex()),
    );
    println!("1D 1024-pt forward+inverse round trip: error {err:.3e}");
    tcfft::ensure!(err < 0.05, "round-trip error too high");

    // --- 2D -------------------------------------------------------------
    let (nx, ny) = (256, 256);
    let plan2 = Plan::fft2d(&rt.registry, nx, ny, 2)?;
    let x: Vec<C32> = (0..2).flat_map(|b| random_signal(nx * ny, 90 + b as u64)).collect();
    let input = PlanarBatch::from_complex(&x, vec![2, nx, ny]);
    let out = plan2.execute(&rt, input.clone())?;
    // oracle: rows then columns on the quantized input
    let q = input.quantize_f16().to_complex();
    let mut want = Vec::new();
    for b in 0..2 {
        let mut m = widen(&q[b * nx * ny..(b + 1) * nx * ny]);
        tcfft::fft::radix2::fft2(&mut m, nx, ny, false);
        want.extend(m);
    }
    let err = relative_error(&want, &widen(&out.to_complex()));
    println!("2D {nx}x{ny} batch=2: mean relative error {err:.3e}");
    tcfft::ensure!(err < 0.02, "2D error too high");

    println!("\nquickstart: ALL OK");
    Ok(())
}
