//! Gravitational-wave-style matched filtering (the paper's pyCBC
//! motivation, Sec 1): find a chirp template buried in noise via
//! frequency-domain correlation, with the forward/inverse FFTs running
//! through the half-precision tcFFT artifacts.
//!
//!     cargo run --release --example pycbc_matched_filter
//!
//! Pipeline: template & strain -> fp16 FFT (device) -> cross-spectrum
//! (host f32) -> fp16 inverse FFT (device) -> SNR peak = merger time.

use tcfft::hp::C32;
use tcfft::plan::{Direction, Plan};
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::workload::{add_noise, chirp};

const N: usize = 4096;

fn main() -> tcfft::error::Result<()> {
    let rt = Runtime::load_default()?;
    let fwd = Plan::fft1d(&rt.registry, N, 4)?;
    let inv = Plan::fft1d_algo(&rt.registry, N, 4, "tc", Direction::Inverse)?;

    // template: a clean chirp; strain: the same chirp injected at a
    // known shift into noise, at a modest SNR
    let template = chirp(N, 8.0, 96.0, 0.75);
    let inject_at = 1234usize;
    let mut strain = vec![C32::new(0.0, 0.0); N];
    for (i, t) in template.iter().enumerate() {
        let j = (i + inject_at) % N;
        strain[j].re += 0.35 * t.re;
        strain[j].im += 0.35 * t.im;
    }
    add_noise(&mut strain, 0.12, 99);

    // device FFTs (batch the two signals together — one artifact call)
    let mut batch = PlanarBatch::new(vec![2, N]);
    for i in 0..N {
        batch.re[i] = template[i].re;
        batch.im[i] = template[i].im;
        batch.re[N + i] = strain[i].re;
        batch.im[N + i] = strain[i].im;
    }
    let spec = fwd.execute(&rt, batch)?;

    // cross-spectrum: S(f) * conj(T(f)) (host f32, like pyCBC's weave)
    let mut cross = PlanarBatch::new(vec![1, N]);
    for i in 0..N {
        let (tr, ti) = (spec.re[i], spec.im[i]);
        let (sr, si) = (spec.re[N + i], spec.im[N + i]);
        // s * conj(t)
        cross.re[i] = sr * tr + si * ti;
        cross.im[i] = si * tr - sr * ti;
    }
    // normalize so the fp16 inverse stays in range
    let peak = cross
        .re
        .iter()
        .chain(cross.im.iter())
        .fold(0.0f32, |a, &b| a.max(b.abs()))
        .max(1e-9);
    for v in cross.re.iter_mut().chain(cross.im.iter_mut()) {
        *v /= peak;
    }

    // inverse FFT -> time-domain correlation (SNR time series)
    let corr = inv.execute(&rt, cross)?;
    let snr: Vec<f32> = (0..N)
        .map(|i| (corr.re[i] * corr.re[i] + corr.im[i] * corr.im[i]).sqrt())
        .collect();
    let (best_lag, best) = snr
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, &v)| (i, v))
        .unwrap();
    let mean = snr.iter().sum::<f32>() / N as f32;

    println!("injected template at lag {inject_at}");
    println!("matched filter peak at lag {best_lag} (SNR ratio {:.1})", best / mean);
    tcfft::ensure!(best_lag == inject_at, "matched filter missed the injection");
    tcfft::ensure!(best / mean > 5.0, "detection not significant");
    println!("pycbc_matched_filter: OK — detection at the injected time");
    Ok(())
}
