#!/usr/bin/env bash
# CI entry point: build + test + lint on the default (offline) feature
# set. Everything here must pass with no network and no artifacts on
# disk — the interpreter backend serves the synthesized catalog.
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy -- -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "ci: OK"
