#!/usr/bin/env bash
# CI entry point: build + test + lint on the default (offline) feature
# set, plus a short smoke-bench that regenerates and validates
# BENCH_interp.json. Everything here must pass with no network and no
# artifacts on disk — the interpreter backend serves the synthesized
# catalog.
#
#   ./ci.sh              # everything (core + bench-smoke)
#   ./ci.sh core         # build + test + fmt + clippy only
#   ./ci.sh bench-smoke  # capped-iteration benches + JSON validation
set -euo pipefail
cd "$(dirname "$0")"

# Cross-reference check over the anchor documents: every relative
# markdown link target in ARCHITECTURE/BENCHMARKS/README/ROADMAP must
# exist on disk (http/mailto links and pure #anchors are skipped).
# Pure grep/sed so the gate needs no extra tooling.
md_link_check() {
  local failed=0
  for f in README.md ARCHITECTURE.md BENCHMARKS.md ROADMAP.md; do
    [ -f "$f" ] || { echo "dead-link check: $f itself is missing"; failed=1; continue; }
    while IFS= read -r link; do
      case "$link" in
        http://*|https://*|mailto:*) continue ;;
      esac
      local target="${link%%#*}"
      [ -n "$target" ] || continue # same-file #anchor
      if [ ! -e "$target" ]; then
        echo "dead link in $f: ($link) -> $target does not exist"
        failed=1
      fi
    done < <(grep -oE '\]\([^)]+\)' "$f" | sed -E 's/^\]\(//; s/\)$//')
  done
  [ "$failed" -eq 0 ] || { echo "markdown dead-link check FAILED"; return 1; }
  echo "markdown cross-references OK"
}

# Static gate: every mutex in the coordinator must be taken through the
# poison-recovering helpers (coordinator::lock::LockExt), so one
# panicking holder can never wedge the serving layer behind a
# PoisonError. Bare `.lock()` is allowed only inside lock.rs itself
# (the helper's implementation and its poison tests need it).
lock_gate() {
  local hits
  hits=$(grep -rn '\.lock()' rust/src/coordinator/ --include='*.rs' | grep -v 'coordinator/lock\.rs' || true)
  if [ -n "$hits" ]; then
    echo "bare Mutex::lock() in coordinator/ — use .plock()/.try_plock() from coordinator::lock:"
    echo "$hits"
    return 1
  fi
  echo "no bare .lock() outside coordinator/lock.rs"
}

# Static gate: raw CPU intrinsics stay inside runtime/simd.rs. That
# module owns the `unsafe` vector bodies, the target_feature gates and
# the runtime dispatch; `std::arch`/`core::arch` anywhere else would
# bypass the feature-detection contract (and the bitwise-vs-scalar
# equivalence suite that polices it).
simd_gate() {
  local hits
  hits=$(grep -rnE 'std::arch|core::arch' rust/src/ rust/tests/ rust/benches/ --include='*.rs' \
    | grep -v 'runtime/simd\.rs' || true)
  if [ -n "$hits" ]; then
    echo "raw std::arch/core::arch outside rust/src/runtime/simd.rs — route through runtime::simd:"
    echo "$hits"
    return 1
  fi
  echo "no raw std::arch/core::arch outside runtime/simd.rs"
}

core() {
  echo "== cargo build --release =="
  cargo build --release

  # the whole suite runs twice: once with the SIMD stage kernels on the
  # best path this CPU offers (auto), once pinned to the scalar
  # fallback. The bitwise contract (tests/simd_equivalence.rs) says
  # both runs must be indistinguishable — a divergence fails here even
  # on tests that never heard of SIMD.
  echo "== cargo test -q (TCFFT_SIMD=auto) =="
  TCFFT_SIMD=auto cargo test -q

  echo "== cargo test -q (TCFFT_SIMD=scalar) =="
  TCFFT_SIMD=scalar cargo test -q

  echo "== chaos suite (fault injection) =="
  cargo test -q --test chaos_service

  echo "== precision ladder (tc_split >= tc >> tc_ec) =="
  cargo test -q --test precision_ladder

  echo "== poison-safe lock gate (rust/src/coordinator) =="
  lock_gate

  echo "== SIMD intrinsics containment gate (rust/) =="
  simd_gate

  echo "== cargo doc --no-deps (warnings are errors) =="
  RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

  echo "== cargo test --doc -q =="
  cargo test --doc -q

  echo "== markdown dead-link check =="
  md_link_check

  echo "== cargo fmt --check =="
  cargo fmt --check

  echo "== cargo clippy -- -D warnings =="
  cargo clippy --all-targets -- -D warnings
}

bench_smoke() {
  echo "== smoke bench: fig4_1d + fig7_batch + large_fourstep + rfft_1d + rfft_2d + rfft2d_large + e2e_serve + table4_precision (TCFFT_BENCH_SMOKE=1) =="
  # start from a clean slate so bench-validate proves the benches
  # emitted fresh entries (update_bench_json merges into existing files)
  rm -f BENCH_interp.json
  TCFFT_BENCH_SMOKE=1 cargo bench --bench fig4_1d
  TCFFT_BENCH_SMOKE=1 cargo bench --bench fig7_batch
  TCFFT_BENCH_SMOKE=1 cargo bench --bench large_fourstep
  TCFFT_BENCH_SMOKE=1 cargo bench --bench rfft_1d
  TCFFT_BENCH_SMOKE=1 cargo bench --bench rfft_2d
  TCFFT_BENCH_SMOKE=1 cargo bench --bench rfft2d_large
  TCFFT_BENCH_SMOKE=1 cargo bench --bench e2e_serve
  TCFFT_BENCH_SMOKE=1 cargo bench --bench table4_precision

  echo "== bench-validate BENCH_interp.json =="
  # no --file: benches and validator share the cwd-independent default
  # (<workspace-root>/BENCH_interp.json, from CARGO_MANIFEST_DIR);
  # bench-validate requires the 2D entries rfft2d_tc_nx256x256_b8_fwd
  # and rfft2d_tc_nx2048x2048_b4_fwd, the serving entry
  # e2e_serve_tc_n4096_c64, the accuracy-gain entry
  # precision_tc_ec_n4096_b32 (table4_precision), and the tc_ec
  # time-cost entry fft1d_tc_ec_n4096_b32_fwd (fig4_1d part 4)
  cargo run --release -- bench-validate
}

case "${1:-all}" in
  core) core ;;
  bench-smoke) bench_smoke ;;
  all)
    core
    bench_smoke
    ;;
  *)
    echo "usage: $0 [core|bench-smoke|all]" >&2
    exit 2
    ;;
esac

echo "ci: OK"
