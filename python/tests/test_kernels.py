"""L1 kernel tests: each Pallas merging kernel against the pure-numpy
merge formula X_out = F_r (T (.) X_in), plus hypothesis shape sweeps."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import plans
from compile.kernels import fused256, radix16, small_radix, split

RNG = np.random.default_rng(42)


def planar(x):
    return (
        jnp.asarray(x.real.astype(np.float16)),
        jnp.asarray(x.imag.astype(np.float16)),
    )


def to_c(yr, yi):
    return np.asarray(yr, np.float32) + 1j * np.asarray(yi, np.float32)


def merge_ref(x, r, n2, inverse=False):
    """Numpy reference merge over blocks: (G, r, n2) -> F (T (.) x)."""
    f = plans.dft_matrix(r, inverse)
    t = plans.twiddle_matrix(r, n2, inverse)
    xq = x.real.astype(np.float16).astype(np.float64) + 1j * x.imag.astype(
        np.float16
    ).astype(np.float64)
    return np.einsum("mj,gjk->gmk", f, t[None] * xq)


def rand(shape, scale=1.0):
    return scale * (RNG.uniform(-1, 1, shape) + 1j * RNG.uniform(-1, 1, shape))


def assert_close(got, want, rtol=0.01):
    scale = np.abs(want).max() + 1e-9
    err = np.abs(got - want).max() / scale
    assert err < rtol, f"max scaled err {err:.4f}"


class TestR16First:
    @pytest.mark.parametrize("g,lane", [(4, 1), (64, 1), (128, 1), (4, 8)])
    def test_matches_blockwise_dft(self, g, lane):
        x = rand((g, 16, lane))
        yr, yi = radix16.r16_first(*planar(x), lane=lane)
        f = plans.dft_matrix(16)
        xq = x.real.astype(np.float16) + 1j * x.imag.astype(np.float16)
        want = np.einsum("mj,gjl->gml", f, xq.astype(np.complex128))
        assert_close(to_c(yr, yi), want)

    def test_inverse_uses_conjugate(self):
        x = rand((8, 16, 1))
        yr, yi = radix16.r16_first(*planar(x), inverse=True)
        f = plans.dft_matrix(16, inverse=True)
        want = np.einsum("mj,gjl->gml", f, x)
        assert_close(to_c(yr, yi), want, rtol=0.02)


class TestR16:
    @pytest.mark.parametrize("g,n2,lane", [(2, 16, 1), (4, 256, 1), (1, 1024, 1), (2, 16, 4)])
    def test_matches_merge_formula(self, g, n2, lane):
        x = rand((g, 16, n2 * lane))
        yr, yi = radix16.r16(*planar(x), n2=n2, lane=lane)
        # lane-expanded reference: twiddle repeats along lane
        xx = x.reshape(g, 16, n2, lane)
        f = plans.dft_matrix(16)
        t = plans.twiddle_matrix(16, n2)
        xq = xx.real.astype(np.float16) + 1j * xx.imag.astype(np.float16)
        want = np.einsum("mj,gjkl->gmkl", f, t[None, :, :, None] * xq.astype(np.complex128))
        assert_close(to_c(yr, yi), want.reshape(g, 16, n2 * lane), rtol=0.02)


class TestFused256:
    def test_first_stage_equals_256_point_dft(self):
        # one group = one 256-point FFT when input is digit-reversed
        n = 256
        x = rand((1, n))
        perm = plans.digit_reverse_indices(n)
        xp = x[:, perm].reshape(1, 16, 16, 1)
        yr, yi = fused256.fused256_first(*planar(xp), lane=1)
        got = to_c(yr, yi).reshape(n)
        xq = x[0].real.astype(np.float16) + 1j * x[0].imag.astype(np.float16)
        want = np.fft.fft(xq)
        assert_close(got, want, rtol=0.02)

    def test_merge256_equals_two_r16_merges(self):
        g, n2 = 2, 16
        x = rand((g, 256 * n2))
        x5 = x.reshape(g, 16, 16, n2, 1)
        yr, yi = fused256.merge256(*planar(x5), n2=n2, lane=1)
        got = to_c(yr, yi).reshape(g, 256 * n2)
        # reference: r16 at n2 over 16 sub-blocks, then r16 at 16*n2
        a = merge_ref(x.reshape(g * 16, 16, n2), 16, n2)
        b = merge_ref(a.reshape(g, 16, 16 * n2), 16, 16 * n2)
        assert_close(got, b.reshape(g, 256 * n2), rtol=0.02)


class TestSmallRadix:
    @pytest.mark.parametrize("r", [2, 4, 8])
    @pytest.mark.parametrize("inverse", [False, True])
    def test_matches_merge_formula(self, r, inverse):
        g, n2 = 3, 64
        x = rand((g, r, n2))
        yr, yi = small_radix.small(*planar(x), radix=r, n2=n2, inverse=inverse)
        want = merge_ref(x, r, n2, inverse)
        assert_close(to_c(yr, yi), want, rtol=0.02)

    @given(st.sampled_from([2, 4, 8]), st.integers(min_value=4, max_value=9))
    @settings(max_examples=12, deadline=None)
    def test_hypothesis_shapes(self, r, logn2):
        n2 = 1 << logn2
        x = rand((1, r, n2))
        yr, yi = small_radix.small(*planar(x), radix=r, n2=n2)
        want = merge_ref(x, r, n2)
        assert_close(to_c(yr, yi), want, rtol=0.02)


class TestSplitAblation:
    def test_split_matches_fused_r16(self):
        g, n2 = 2, 256
        x = rand((g, 16, n2))
        a = to_c(*radix16.r16(*planar(x), n2=n2))
        b = to_c(*split.r16_split(*planar(x), n2=n2))
        # identical arithmetic, only kernel structure differs
        assert_close(a, b, rtol=0.005)


class TestDtypes:
    def test_outputs_are_fp16(self):
        x = rand((2, 16, 16))
        yr, yi = radix16.r16(*planar(x), n2=16)
        assert yr.dtype == jnp.float16
        assert yi.dtype == jnp.float16

    def test_fp16_quantization_bounds_error(self):
        # feeding larger-magnitude data still yields bounded scaled error
        x = rand((2, 16, 64), scale=8.0)
        yr, yi = radix16.r16(*planar(x), n2=64)
        want = merge_ref(x, 16, 64)
        assert_close(to_c(yr, yi), want, rtol=0.02)
