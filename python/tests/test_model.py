"""L2 pipeline tests: full staged FFTs against numpy oracles, all
algorithm variants, directions, 1D/2D, plus hypothesis sweeps."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(7)


def rand(shape):
    return RNG.uniform(-1, 1, shape) + 1j * RNG.uniform(-1, 1, shape)


def q16(x):
    return x.real.astype(np.float16).astype(np.float64) + 1j * x.imag.astype(
        np.float16
    ).astype(np.float64)


def rel(got, want):
    return np.abs(got - want).max() / (np.abs(want).max() + 1e-30)


class TestFft1d:
    @pytest.mark.parametrize("n", [2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 4096])
    def test_tc_matches_numpy(self, n):
        x = rand((2, n))
        got = model.run_fft1d(x, "tc")
        assert rel(got, np.fft.fft(q16(x), axis=-1)) < 0.01

    @pytest.mark.parametrize("n", [65536, 131072])
    def test_tc_large_sizes(self, n):
        x = rand((1, n))
        got = model.run_fft1d(x, "tc")
        assert rel(got, np.fft.fft(q16(x), axis=-1)) < 0.01

    @pytest.mark.parametrize("algo", ["tc_split", "r2"])
    def test_other_algos(self, algo):
        x = rand((2, 1024))
        got = model.run_fft1d(x, algo)
        assert rel(got, np.fft.fft(q16(x), axis=-1)) < 0.02

    def test_inverse_unnormalized(self):
        x = rand((2, 256))
        spec = np.fft.fft(q16(x), axis=-1)
        got = model.run_fft1d(spec / 256, "tc", inverse=True)
        # inverse(fft(x)/N) == x when inverse is unnormalized
        assert rel(got, q16(x)) < 0.02

    @given(st.integers(min_value=1, max_value=13), st.integers(min_value=1, max_value=3))
    @settings(max_examples=10, deadline=None)
    def test_hypothesis_sizes_and_batches(self, t, b):
        n = 1 << t
        x = rand((b, n))
        got = model.run_fft1d(x, "tc")
        assert rel(got, np.fft.fft(q16(x), axis=-1)) < 0.02

    def test_impulse_and_constant(self):
        n = 256
        x = np.zeros((1, n), dtype=complex)
        x[0, 0] = 1.0
        assert rel(model.run_fft1d(x, "tc"), np.ones((1, n))) < 0.01
        c = np.ones((1, n), dtype=complex)
        want = np.zeros((1, n), dtype=complex)
        want[0, 0] = n
        assert rel(model.run_fft1d(c, "tc"), want) < 0.01

    def test_linearity(self):
        n = 512
        a, b = rand((1, n)) * 0.5, rand((1, n)) * 0.5
        fa = model.run_fft1d(a, "tc")
        fb = model.run_fft1d(b, "tc")
        fs = model.run_fft1d(a + b, "tc")
        assert rel(fs, fa + fb) < 0.02


class TestFft2d:
    @pytest.mark.parametrize("shape", [(1, 16, 16), (2, 64, 32), (1, 128, 128), (1, 512, 256)])
    def test_tc_matches_numpy(self, shape):
        x = rand(shape)
        got = model.run_fft2d(x, "tc")
        want = np.fft.fft2(q16(x))
        assert rel(got, want) < 0.015

    def test_r2_baseline_2d(self):
        x = rand((1, 64, 64))
        got = model.run_fft2d(x, "r2")
        assert rel(got, np.fft.fft2(q16(x))) < 0.02

    def test_inverse_round_trip(self):
        x = rand((1, 64, 64))
        spec = np.fft.fft2(q16(x)) / (64 * 64)
        got = model.run_fft2d(spec, "tc", inverse=True)
        assert rel(got, q16(x)) < 0.02

    def test_row_only_content(self):
        # an image constant along rows transforms to content in column 0
        x = np.broadcast_to(rand((1, 64, 1)), (1, 64, 64)).copy()
        got = model.run_fft2d(x, "tc")
        energy_col0 = np.abs(got[0, :, 0]).sum()
        energy_rest = np.abs(got[0, :, 1:]).sum()
        assert energy_col0 > 50 * energy_rest


class TestStockhamBaseline:
    @pytest.mark.parametrize("n", [2, 8, 64, 1024])
    def test_forward(self, n):
        x = rand((2, n))
        xr, xi = ref.fft_fp16_radix2(
            np.float16(x.real), np.float16(x.imag)
        )
        got = np.asarray(xr, np.float32) + 1j * np.asarray(xi, np.float32)
        assert rel(got, np.fft.fft(q16(x), axis=-1)) < 0.02

    def test_axis_argument(self):
        x = rand((2, 16, 32))
        xr, xi = ref.fft_fp16_radix2(np.float16(x.real), np.float16(x.imag), axis=-2)
        got = np.asarray(xr, np.float32) + 1j * np.asarray(xi, np.float32)
        want = np.fft.fft(q16(x), axis=-2)
        assert rel(got, want) < 0.02


class TestErrorCharacter:
    def test_tc_error_not_worse_than_r2(self):
        # paper Table 4: both at the same level; matmul formulation with
        # fp32 accumulation should be at least as accurate
        n = 4096
        x = rand((4, n))
        want = np.fft.fft(q16(x), axis=-1)
        e_tc = rel(model.run_fft1d(x, "tc"), want)
        e_r2 = rel(model.run_fft1d(x, "r2"), want)
        assert e_tc < e_r2 * 1.5
