"""Unit tests for the plan composer (schedules, permutations, twiddles)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import plans


class TestRadixSchedule:
    def test_paper_radix512_kernel(self):
        # paper Sec 3.2: radix-512 = 16 x 16 x 2
        assert plans.radix_schedule(512) == [16, 16, 2]

    @pytest.mark.parametrize(
        "n,want",
        [
            (2, [2]),
            (8, [8]),
            (16, [16]),
            (32, [16, 2]),
            (256, [16, 16]),
            (4096, [16, 16, 16]),
            (131072, [16, 16, 16, 16, 2]),
        ],
    )
    def test_known(self, n, want):
        assert plans.radix_schedule(n) == want

    @pytest.mark.parametrize("bad", [0, 1, 3, 100, -8])
    def test_rejects_non_pow2(self, bad):
        with pytest.raises(ValueError):
            plans.radix_schedule(bad)

    @given(st.integers(min_value=1, max_value=24))
    def test_product_reconstructs(self, t):
        n = 1 << t
        assert int(np.prod(plans.radix_schedule(n))) == n


class TestDigitReverse:
    def test_radix2_is_bit_reversal(self):
        p = plans.digit_reverse_indices(8, [2, 2, 2])
        assert list(p) == [0, 4, 2, 6, 1, 5, 3, 7]

    @given(st.integers(min_value=1, max_value=16))
    @settings(max_examples=20)
    def test_is_permutation(self, t):
        n = 1 << t
        p = plans.digit_reverse_indices(n)
        assert sorted(p) == list(range(n))

    def test_uniform_radix_involution(self):
        p = plans.digit_reverse_indices(256, [16, 16])
        assert all(p[p[i]] == i for i in range(256))


class TestMatrices:
    def test_dft_matrix_unitary(self):
        f = plans.dft_matrix(16)
        eye = f @ f.conj().T / 16
        assert np.allclose(eye, np.eye(16), atol=1e-12)

    def test_inverse_is_conjugate(self):
        assert np.allclose(plans.dft_matrix(16, True), plans.dft_matrix(16).conj())
        assert np.allclose(
            plans.twiddle_matrix(16, 64, True), plans.twiddle_matrix(16, 64).conj()
        )

    def test_twiddle_unit_magnitude(self):
        t = plans.twiddle_matrix(16, 256)
        assert np.allclose(np.abs(t), 1.0)

    def test_twiddle_first_row_col_ones(self):
        t = plans.twiddle_matrix(16, 8)
        assert np.allclose(t[0], 1.0)
        assert np.allclose(t[:, 0], 1.0)


class TestKernelSchedule:
    @pytest.mark.parametrize(
        "n,kernels",
        [
            (16, ["r16_first"]),
            (32, ["r16_first", "small"]),
            (256, ["fused256_first"]),
            (512, ["fused256_first", "small"]),
            (4096, ["fused256_first", "r16"]),
            (65536, ["fused256_first", "merge256"]),
            (131072, ["fused256_first", "merge256", "small"]),
        ],
    )
    def test_kernel_selection(self, n, kernels):
        assert [s.kernel for s in plans.kernel_schedule(n)] == kernels

    @given(st.integers(min_value=1, max_value=22))
    @settings(max_examples=22)
    def test_radix_product(self, t):
        n = 1 << t
        sts = plans.kernel_schedule(n)
        assert int(np.prod([s.radix for s in sts])) == n

    @given(st.integers(min_value=1, max_value=22))
    @settings(max_examples=22)
    def test_vmem_budget(self, t):
        n = 1 << t
        for s in plans.kernel_schedule(n):
            if s.kernel == "merge256":
                assert s.vmem_bytes() <= plans.VMEM_FUSE_BUDGET

    def test_large_lane_disables_fusion(self):
        sts = plans.kernel_schedule(1 << 16, lane=512)
        assert all(s.kernel != "merge256" for s in sts)

    def test_totals_structure(self):
        tot = plans.schedule_totals(65536)
        assert tot["stages"] == 2
        assert tot["flops"] > 0
        # 2 stages x read+write x 4 bytes x N
        assert tot["hbm_bytes"] == 2 * 2 * 4 * 65536

    def test_radix2_equivalent_metric(self):
        # paper eq. 4 numerator for N=1024, batch 1: 6*2*10*1024
        assert plans.radix2_equivalent_flops(1024) == 6 * 2 * 10 * 1024
