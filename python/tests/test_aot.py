"""AOT pipeline tests: HLO text emission, manifest integrity, and the
round-trip property the Rust loader depends on (no elided constants)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, plans


class TestHloEmission:
    def test_small_variant_lowers_to_parseable_hlo(self):
        var = aot.Variant("fft1d", "tc", 2, False, n=256)
        text = aot.lower_variant(var)
        assert text.startswith("HloModule")
        assert "f16[2,256]" in text

    def test_no_elided_constants(self):
        # the Rust text parser needs every constant printed: an elided
        # "constant({...})" would silently zero the twiddles
        var = aot.Variant("fft1d", "tc", 2, False, n=4096)
        text = aot.lower_variant(var)
        assert "constant({...}" not in text

    def test_r2_variant_lowers(self):
        var = aot.Variant("fft1d", "r2", 2, False, n=256)
        text = aot.lower_variant(var)
        assert text.startswith("HloModule")


class TestVariantMatrix:
    def test_keys_are_unique(self):
        keys = [v.key for v in aot.variant_matrix()]
        assert len(keys) == len(set(keys))

    def test_covers_paper_experiments(self):
        keys = set(v.key for v in aot.variant_matrix())
        # Fig 4 / Table 4 ladder
        for n in (256, 1024, 4096, 16384, 65536):
            assert f"fft1d_tc_n{n}_b4_fwd" in keys
            assert f"fft1d_r2_n{n}_b4_fwd" in keys
        # Fig 7a batch sweep
        for b in (1, 2, 4, 8, 16):
            assert f"fft1d_tc_n131072_b{b}_fwd" in keys
        # Fig 5 2D shapes
        assert "fft2d_tc_nx512x256_b2_fwd" in keys
        # Sec 5.4 ablation
        assert "fft1d_tc_split_n4096_b4_fwd" in keys

    def test_manifest_entry_schema(self):
        var = aot.Variant("fft2d", "tc", 2, False, nx=512, ny=256)
        e = var.manifest_entry("f.hlo.txt")
        for field in (
            "key",
            "file",
            "op",
            "algo",
            "batch",
            "input_shape",
            "stages",
            "flops_per_seq",
            "hbm_bytes_per_seq",
            "radix2_equiv_flops",
        ):
            assert field in e, field
        assert e["input_shape"] == [2, 512, 256]
        # 2D stages = ny schedule + strided nx schedule
        kinds = [s["kernel"] for s in e["stages"]]
        assert kinds.count("fused256_first") == 2
        lanes = [s["lane"] for s in e["stages"]]
        assert max(lanes) == 256  # strided pass carries lane = ny

    def test_stage_flops_positive(self):
        for var in aot.variant_matrix()[:6]:
            for s in var.stages():
                assert s["flops"] > 0
                assert s["hbm_bytes"] > 0


class TestBuiltManifest:
    """Checks against the actually-built artifacts/ when present."""

    @pytest.fixture()
    def manifest(self):
        path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        with open(path) as f:
            return json.load(f)

    def test_files_exist_and_nonempty(self, manifest):
        base = os.path.join(os.path.dirname(__file__), "../../artifacts")
        for v in manifest["variants"]:
            p = os.path.join(base, v["file"])
            assert os.path.exists(p), v["key"]
            assert os.path.getsize(p) > 1000, v["key"]

    def test_schedule_products(self, manifest):
        for v in manifest["variants"]:
            if v["algo"] == "r2":
                continue
            prod = int(np.prod([s["radix"] for s in v["stages"]]))
            want = v["n"] if v["op"] == "fft1d" else v["nx"] * v["ny"]
            assert prod == want, v["key"]

    def test_inverse_norm_documented(self, manifest):
        assert manifest["inverse_norm"] == "none"
