"""Plan composer for tcFFT: radix schedules, digit-reversal permutations,
twiddle factors, and per-stage cost accounting.

This module is the single source of truth for *what* kernels run for a
given FFT size.  The Rust planner (``rust/src/plan``) recomputes the same
schedule and is cross-checked against the manifest emitted from here.

Math (paper Sec 2.1): a merge of radix ``r`` with sub-FFT length ``n2``
maps ``X_out = F_r . (T_{r,n2} (.) X_in)`` over blocks of ``r*n2``
elements, where ``T[m, k] = W_{r*n2}^{m*k}`` and ``F_r`` is the r-point
DFT matrix.  Stages are applied smallest-span first; the input must be
pre-permuted by the mixed-radix digit reversal matching the schedule.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

import numpy as np

# VMEM budget (bytes) a single fused merge block may occupy.  A fused
# radix-256 merge holds a (256, n2*lane) complex-fp16 block twice (in +
# out) plus twiddles; keep well under the ~16 MB/core of a real TPU so
# the schedule would be valid on hardware, not just in interpret mode.
VMEM_FUSE_BUDGET = 4 * 1024 * 1024

# Tile (lane) width used by the unfused radix-16 merge kernel.
# Perf iteration 1 (EXPERIMENTS.md SPerf): 256 -> 2048. Fewer grid steps
# amortize per-step overhead (interpret mode) / DMA descriptors (TPU);
# VMEM stays at 16*2048*4*3 = 384 KiB per block.
R16_TILE = 2048
# Rows per grid step for the first-stage kernels (divided by the lane
# width for strided 2D passes to hold the VMEM block ~constant).
# Perf iteration 1: 64 -> 512 (1 MiB blocks).
FIRST_STAGE_ROWS = 512
# Column tile for the small-radix (2/4/8) kernels. Perf iteration 1:
# 1024 -> 32768; capped by VMEM_FUSE_BUDGET in the kernel.
SMALL_TILE = 32768


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def radix_schedule(n: int) -> List[int]:
    """Radix factors of ``n`` in merge order (smallest span first).

    n = 16**a * r with r in {2, 4, 8}; the small radix merges last,
    mirroring the paper's radix-512 kernel (= 16*16*2).
    """
    if not _is_pow2(n) or n < 2:
        raise ValueError(f"FFT size must be a power of two >= 2, got {n}")
    t = n.bit_length() - 1
    a, b = divmod(t, 4)
    radices = [16] * a
    if b:
        radices.append(2 ** b)
    return radices


def digit_reverse_indices(n: int, radices: Optional[List[int]] = None) -> np.ndarray:
    """Mixed-radix digit-reversal permutation for the given merge order.

    ``x[perm]`` is the input ordering the staged merges expect.  Defined
    recursively: the *last*-merged radix corresponds to the outermost
    decimation split (n mod r), matching decimation-in-time.
    """
    if radices is None:
        radices = radix_schedule(n)
    assert math.prod(radices) == n, (n, radices)

    def rec(idx: np.ndarray, rads: List[int]) -> np.ndarray:
        if not rads:
            return idx
        r = rads[-1]
        return np.concatenate([rec(idx[m::r], rads[:-1]) for m in range(r)])

    return rec(np.arange(n, dtype=np.int64), list(radices))


def dft_matrix(r: int, inverse: bool = False) -> np.ndarray:
    """The r-point DFT matrix F_r (complex128). Inverse uses conj."""
    k = np.arange(r)
    sign = 2j if inverse else -2j
    return np.exp(sign * np.pi * np.outer(k, k) / r)


def twiddle_matrix(r: int, n2: int, inverse: bool = False) -> np.ndarray:
    """T_{r,n2}[m, k] = W_{r*n2}^{m*k} (complex128)."""
    n = r * n2
    m = np.arange(r).reshape(-1, 1)
    k = np.arange(n2).reshape(1, -1)
    sign = 2j if inverse else -2j
    return np.exp(sign * np.pi * (m * k % n) / n)


@dataclasses.dataclass
class Stage:
    """One Pallas kernel invocation in the staged pipeline.

    kernel: 'r16_first' | 'fused256_first' | 'r16' | 'merge256' | 'small'
    radix:  total radix merged by this invocation (16, 256, 2, 4, 8)
    n2:     sub-FFT length entering the invocation
    lane:   trailing broadcast dimension (1 for contiguous 1D FFT,
            = row length for the strided first-axis pass of a 2D FFT)
    """

    kernel: str
    radix: int
    n2: int
    lane: int = 1

    # -- cost accounting (per batch element of the full length-n FFT) --
    def out_len(self) -> int:
        return self.radix * self.n2

    def flops(self, n: int) -> int:
        """Real FLOPs for this stage over one length-n sequence
        (complex mul = 6, complex add = 2)."""
        groups = n // self.out_len()
        if self.kernel in ("r16_first", "r16"):
            per_block = 16 * 16 * self.n2 * 6 + 16 * 15 * self.n2 * 2
            if self.kernel == "r16":
                per_block += 16 * self.n2 * 6  # twiddle
            return groups * per_block
        if self.kernel == "fused256_first":
            # two radix-16 sub-merges over a 256 block
            per_block = 2 * 16 * (16 * 16 * 6 + 16 * 15 * 2) + 16 * 16 * 6
            return groups * per_block
        if self.kernel == "merge256":
            # sub-merge 1: 16 blocks of (16 x n2); sub-merge 2: (16 x 16n2)
            s1 = 16 * (16 * 16 * self.n2 * 6 + 16 * 15 * self.n2 * 2 + 16 * self.n2 * 6)
            s2 = 16 * 16 * (16 * self.n2) * 6 + 16 * 15 * (16 * self.n2) * 2 + 16 * (16 * self.n2) * 6
            return groups * (s1 + s2)
        if self.kernel == "small":
            r = self.radix
            # butterflies: r*n2 twiddle cmuls + r*r*n2 cmul-adds (explicit
            # forms for r=2/4 are cheaper; count the generic bound)
            return groups * (r * self.n2 * 6 + r * r * self.n2 * 6 + r * (r - 1) * self.n2 * 2)
        raise ValueError(self.kernel)

    def hbm_bytes(self, n: int, bytes_per_cplx: int = 4) -> int:
        """Global-memory traffic: read + write the full sequence once."""
        return 2 * n * bytes_per_cplx

    def vmem_bytes(self, bytes_per_cplx: int = 4) -> int:
        """Per-block VMEM footprint (in + out + twiddles)."""
        if self.kernel in ("r16_first",):
            rows = max(1, FIRST_STAGE_ROWS // self.lane)
            return rows * 16 * self.lane * bytes_per_cplx * 2
        if self.kernel == "fused256_first":
            rows = max(1, FIRST_STAGE_ROWS // self.lane)
            blk = rows * 256 * self.lane
            return blk * bytes_per_cplx * 2 + 256 * bytes_per_cplx
        if self.kernel == "r16":
            cols = min(self.n2 * self.lane, R16_TILE)
            return 16 * cols * bytes_per_cplx * 3
        if self.kernel == "merge256":
            blk = 256 * self.n2 * self.lane
            tw = (16 * self.n2 + 16 * 16 * self.n2) * bytes_per_cplx
            return blk * bytes_per_cplx * 2 + tw
        if self.kernel == "small":
            cols = min(self.n2 * self.lane, SMALL_TILE)
            return self.radix * cols * bytes_per_cplx * 3
        raise ValueError(self.kernel)


def kernel_schedule(n: int, lane: int = 1) -> List[Stage]:
    """Group the radix schedule into fused kernel invocations.

    Mirrors the paper's merging-kernel selection: the first two radix-16
    stages fuse into a radix-256 first-stage kernel; later radix-16
    pairs fuse into radix-256 merge kernels while the block fits the
    VMEM budget; a trailing radix-2/4/8 stage runs on the VPU.
    """
    radices = radix_schedule(n)
    a = sum(1 for r in radices if r == 16)
    small = [r for r in radices if r != 16]
    stages: List[Stage] = []
    n2 = 1
    i = 0
    # first stage(s)
    if a >= 2:
        stages.append(Stage("fused256_first", 256, 1, lane))
        n2 = 256
        i = 2
    elif a == 1:
        stages.append(Stage("r16_first", 16, 1, lane))
        n2 = 16
        i = 1
    # middle radix-16 stages, fused pairwise when VMEM allows
    while i < a:
        remaining = a - i
        fused = Stage("merge256", 256, n2, lane)
        if remaining >= 2 and fused.vmem_bytes() <= VMEM_FUSE_BUDGET:
            stages.append(fused)
            n2 *= 256
            i += 2
        else:
            stages.append(Stage("r16", 16, n2, lane))
            n2 *= 16
            i += 1
    # trailing small radix
    for r in small:
        stages.append(Stage("small", r, n2, lane))
        n2 *= r
    assert n2 == n, (n, [dataclasses.asdict(s) for s in stages])
    return stages


def schedule_totals(n: int, lane: int = 1) -> dict:
    stages = kernel_schedule(n, lane)
    return {
        "stages": len(stages),
        "flops": sum(s.flops(n) for s in stages),
        "hbm_bytes": sum(s.hbm_bytes(n) for s in stages),
        "max_vmem_bytes": max(s.vmem_bytes() for s in stages),
    }


def radix2_equivalent_flops(n: int, batch: int = 1) -> float:
    """The paper's performance metric numerator (eq. 4): 6*2*log2(N)*N."""
    return 6.0 * 2.0 * math.log2(n) * n * batch


def stage_dicts(n: int, lane: int = 1) -> List[dict]:
    """JSON-friendly stage descriptions for the artifact manifest."""
    out = []
    for s in kernel_schedule(n, lane):
        out.append(
            {
                "kernel": s.kernel,
                "radix": s.radix,
                "n2": s.n2,
                "lane": s.lane,
                "flops": s.flops(n),
                "hbm_bytes": s.hbm_bytes(n),
                "vmem_bytes": s.vmem_bytes(),
            }
        )
    return out
