"""L1: Pallas merging kernels for tcFFT (interpret mode on CPU PJRT).

Modules:
* ``radix16``     — r16_first / r16: the core radix-16 MXU merges.
* ``fused256``    — fused256_first / merge256: VMEM-fused stage pairs.
* ``small_radix`` — radix-2/4/8 VPU butterflies (last merge).
* ``split``       — unfused twiddle+matmul pair (Sec 5.4 ablation).
* ``ref``         — f64 oracle + fp16 radix-2 Stockham baseline.
* ``common``      — planar-complex helpers (cmul, complex einsum).
"""

from . import common, fused256, radix16, ref, small_radix, split  # noqa: F401
