"""Fused radix-256 kernels: two radix-16 sub-merges per HBM round trip.

The paper's large merging kernels (radix-256/512/8192) chain several
sub-merges through shared memory to raise arithmetic intensity (Sec
3.2, "Combine multiple mergings").  The TPU analogue keeps the block
resident in VMEM between the two MXU dots:

* ``fused256_first`` — stages 1+2 (n2 = 1 then 16) over 256-point
  blocks; the workhorse first stage for every N >= 256.
* ``merge256``       — a mid-pipeline pair (n2 then 16*n2); used while
  the (256, n2*lane) block fits the VMEM fuse budget.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import plans
from .common import DTYPE, INTERPRET, cdot, cmul, pick_tile, planar_const


def _fused256_first_kernel(fr_ref, fi_ref, t2r_ref, t2i_ref, xr_ref, xi_ref, or_ref, oi_ref):
    # x: (Tg, 16b, 16j, L).  Stage 1 (n2=1, no twiddle):
    #   X1[g,b,m,l] = sum_j F[m,j] x[g,b,j,l]
    fr, fi = fr_ref[...], fi_ref[...]
    xr, xi = xr_ref[...], xi_ref[...]
    x1r, x1i = cdot("mj,gbjl->gbml", fr, fi, xr, xi)
    # Stage 2 (n2=16): the stage-1 output block (b, m) *is* the stage-2
    # input matrix (j, k) — data never leaves VMEM (paper: shared mem).
    t2r, t2i = t2r_ref[...], t2i_ref[...]  # (16, 16) twiddles W_256^{jk}
    zr, zi = cmul(x1r, x1i, t2r[None, :, :, None], t2i[None, :, :, None])
    orr, oii = cdot("mj,gjkl->gmkl", fr, fi, zr, zi)
    or_ref[...] = orr
    oi_ref[...] = oii


def fused256_first(xr, xi, *, lane: int = 1, inverse: bool = False):
    """Fused first stage for N >= 256. Input planar (G, 16, 16, lane)."""
    g = xr.shape[0]
    assert xr.shape == (g, 16, 16, lane), xr.shape
    fr, fi = planar_const(plans.dft_matrix(16, inverse))
    t2r, t2i = planar_const(plans.twiddle_matrix(16, 16, inverse))
    # keep the VMEM block ~constant for strided (lane > 1) passes
    tg = pick_tile(g, max(1, plans.FIRST_STAGE_ROWS // lane))
    grid = (g // tg,)
    bs_x = pl.BlockSpec((tg, 16, 16, lane), lambda i: (i, 0, 0, 0))
    bs_f = pl.BlockSpec((16, 16), lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((g, 16, 16, lane), DTYPE),
        jax.ShapeDtypeStruct((g, 16, 16, lane), DTYPE),
    ]
    return pl.pallas_call(
        _fused256_first_kernel,
        grid=grid,
        in_specs=[bs_f, bs_f, bs_f, bs_f, bs_x, bs_x],
        out_specs=[bs_x, bs_x],
        out_shape=out_shape,
        interpret=INTERPRET,
    )(fr, fi, t2r, t2i, xr, xi)


def _merge256_kernel(fr_ref, fi_ref, t1r_ref, t1i_ref, t2r_ref, t2i_ref,
                     xr_ref, xi_ref, or_ref, oi_ref):
    # x: (1, 16b, 16j, n2, L) — one full stage-(s+1) block in VMEM.
    fr, fi = fr_ref[...], fi_ref[...]
    xr, xi = xr_ref[0], xi_ref[0]
    # Sub-merge 1: 16 independent (16, n2) blocks, twiddle T1 (16, n2).
    t1r, t1i = t1r_ref[...], t1i_ref[...]
    zr, zi = cmul(xr, xi, t1r[None, :, :, None], t1i[None, :, :, None])
    ar, ai = cdot("mj,bjkl->bmkl", fr, fi, zr, zi)
    # Sub-merge 2: view (b, m, k) as (j, k2 = m*n2+k): merge axes 1-2.
    b, m, n2, lane = ar.shape
    ar = ar.reshape(b, m * n2, lane)
    ai = ai.reshape(b, m * n2, lane)
    t2r, t2i = t2r_ref[...], t2i_ref[...]  # (16, 16*n2) twiddles
    zr, zi = cmul(ar, ai, t2r[:, :, None], t2i[:, :, None])
    orr, oii = cdot("mj,jkl->mkl", fr, fi, zr, zi)
    or_ref[0] = orr.reshape(16, 16, n2, lane)
    oi_ref[0] = oii.reshape(16, 16, n2, lane)


def merge256(xr, xi, *, n2: int, lane: int = 1, inverse: bool = False):
    """Fused pair of radix-16 merges (n2 then 16*n2), VMEM-resident.

    Input planar (G, 16, 16, n2, lane): group g holds one 256*n2-element
    stage-(s+1) block; leading 16 = stage-s blocks, middle 16 = rows.
    """
    g = xr.shape[0]
    assert xr.shape == (g, 16, 16, n2, lane), (xr.shape, n2, lane)
    fr, fi = planar_const(plans.dft_matrix(16, inverse))
    t1r, t1i = planar_const(plans.twiddle_matrix(16, n2, inverse))
    t2r, t2i = planar_const(plans.twiddle_matrix(16, 16 * n2, inverse))
    grid = (g,)
    bs_x = pl.BlockSpec((1, 16, 16, n2, lane), lambda i: (i, 0, 0, 0, 0))
    bs_f = pl.BlockSpec((16, 16), lambda i: (0, 0))
    bs_t1 = pl.BlockSpec((16, n2), lambda i: (0, 0))
    bs_t2 = pl.BlockSpec((16, 16 * n2), lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct(xr.shape, DTYPE),
        jax.ShapeDtypeStruct(xr.shape, DTYPE),
    ]
    return pl.pallas_call(
        _merge256_kernel,
        grid=grid,
        in_specs=[bs_f, bs_f, bs_t1, bs_t1, bs_t2, bs_t2, bs_x, bs_x],
        out_specs=[bs_x, bs_x],
        out_shape=out_shape,
        interpret=INTERPRET,
    )(fr, fi, t1r, t1i, t2r, t2i, xr, xi)
