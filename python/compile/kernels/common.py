"""Shared helpers for the tcFFT Pallas merging kernels.

All kernels operate on *planar* complex data: separate fp16 real and
imaginary arrays.  This mirrors the paper's Sec 4.1 fragment split of a
complex matrix into a real fragment and an imaginary fragment — on TPU
the split is free because we fuse it into the kernel body instead of
bouncing through shared memory.

Matmuls accumulate in fp32 (``preferred_element_type``), matching the
Tensor-Core FP32 accumulate path, and results are stored back as fp16 —
the paper notes fp16 storage of intermediates is the dominant error
source, and we reproduce that behaviour.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

# Pallas must run in interpret mode on CPU PJRT: real-TPU lowering emits
# a Mosaic custom-call the CPU plugin cannot execute.
INTERPRET = True

DTYPE = jnp.float16
ACC_DTYPE = jnp.float32


def planar_const(mat: np.ndarray, dtype=DTYPE):
    """Split a complex numpy matrix into planar fp16 jnp constants."""
    return (
        jnp.asarray(mat.real.astype(np.float16), dtype=dtype),
        jnp.asarray(mat.imag.astype(np.float16), dtype=dtype),
    )


def cmul(ar, ai, br, bi):
    """Element-wise complex multiply in fp16 on the VPU.

    (paper: twiddle (.) performed on FP16 CUDA cores inside the
    fragment registers; here: fused into the kernel body.)
    """
    return ar * br - ai * bi, ar * bi + ai * br


def cdot(spec: str, fr, fi, xr, xi):
    """Complex einsum F . X with fp32 accumulation, fp16 result.

    Four real einsums — the classic complex GEMM decomposition the paper
    runs on Tensor Cores; on TPU each lowers to an MXU dot.
    """
    kw = dict(preferred_element_type=ACC_DTYPE)
    rr = jnp.einsum(spec, fr, xr, **kw) - jnp.einsum(spec, fi, xi, **kw)
    ri = jnp.einsum(spec, fr, xi, **kw) + jnp.einsum(spec, fi, xr, **kw)
    return rr.astype(DTYPE), ri.astype(DTYPE)


def pick_tile(c: int, max_tile: int) -> int:
    """Largest power-of-two tile <= max_tile that divides c."""
    t = min(c, max_tile)
    while c % t:
        t //= 2
    return t
