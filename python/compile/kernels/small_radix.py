"""Small-radix (2/4/8) merging kernels on the VPU.

The paper computes radix-2/4 merges on FP16 CUDA cores because their
DFT matrices contain only {0, +-1, +-i} — no point burning Tensor-Core
cycles.  The TPU analogue: element-wise butterflies on the VPU, written
explicitly for r=2 and r=4 (adds/swaps only) and as a tiny einsum for
r=8 (W_8 introduces sqrt(2)/2 factors).

These always run as the *last* merge (largest span), mirroring the
paper's radix-512 kernel layout = 16 x 16 x 2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import plans
from .common import DTYPE, INTERPRET, cdot, cmul, pick_tile, planar_const


def _small2_kernel(twr_ref, twi_ref, xr_ref, xi_ref, or_ref, oi_ref):
    # x: (1, 2, T). y0 = x0 + w (.) x1 ; y1 = x0 - w (.) x1
    x0r, x0i = xr_ref[0, 0], xi_ref[0, 0]
    x1r, x1i = xr_ref[0, 1], xi_ref[0, 1]
    wr, wi = twr_ref[0], twi_ref[0]
    zr, zi = cmul(x1r, x1i, wr, wi)
    or_ref[0, 0] = x0r + zr
    oi_ref[0, 0] = x0i + zi
    or_ref[0, 1] = x0r - zr
    oi_ref[0, 1] = x0i - zi


def _make_small4_kernel(sign: float):
    """Radix-4 butterfly kernel; ``sign`` = +1 forward, -1 inverse (static).

    F4 rows (forward): [1,1,1,1], [1,-i,-1,i], [1,-1,1,-1], [1,i,-1,-i];
    implemented as two layers of radix-2 butterflies plus one +-i swap —
    no multiplies beyond the twiddles, exactly the paper's rationale for
    keeping radix-2/4 off the Tensor Cores.
    """

    def kernel(twr_ref, twi_ref, xr_ref, xi_ref, or_ref, oi_ref):
        zr = [None] * 4
        zi = [None] * 4
        zr[0], zi[0] = xr_ref[0, 0], xi_ref[0, 0]
        for j in (1, 2, 3):
            zr[j], zi[j] = cmul(xr_ref[0, j], xi_ref[0, j], twr_ref[j - 1], twi_ref[j - 1])
        ar, ai = zr[0] + zr[2], zi[0] + zi[2]
        br, bi = zr[0] - zr[2], zi[0] - zi[2]
        cr, ci = zr[1] + zr[3], zi[1] + zi[3]
        dr, di = zr[1] - zr[3], zi[1] - zi[3]
        or_ref[0, 0] = ar + cr
        oi_ref[0, 0] = ai + ci
        or_ref[0, 2] = ar - cr
        oi_ref[0, 2] = ai - ci
        # forward: y1 = b - i*d, y3 = b + i*d; -i*(dr + i*di) = di - i*dr
        s = jnp.asarray(sign, DTYPE)
        or_ref[0, 1] = br + s * di
        oi_ref[0, 1] = bi - s * dr
        or_ref[0, 3] = br - s * di
        oi_ref[0, 3] = bi + s * dr

    return kernel


def _small8_kernel(fr_ref, fi_ref, twr_ref, twi_ref, xr_ref, xi_ref, or_ref, oi_ref):
    # x: (1, 8, T); generic tiny complex matmul on the VPU/MXU.
    fr, fi = fr_ref[...], fi_ref[...]
    twr, twi = twr_ref[...], twi_ref[...]
    xr, xi = xr_ref[0], xi_ref[0]
    zr, zi = cmul(xr, xi, twr, twi)
    orr, oii = cdot("mj,jk->mk", fr, fi, zr, zi)
    or_ref[0] = orr
    oi_ref[0] = oii


def small(xr, xi, *, radix: int, n2: int, lane: int = 1, inverse: bool = False):
    """Radix-2/4/8 merge. Input planar (G, r, n2*lane)."""
    g, r, c = xr.shape
    assert r == radix and c == n2 * lane, (xr.shape, radix, n2, lane)
    tw = plans.twiddle_matrix(radix, n2, inverse)
    if lane > 1:
        tw = tw.repeat(lane, axis=1)
    # tile bounded by both SMALL_TILE and the per-block VMEM budget
    vmem_cap = plans.VMEM_FUSE_BUDGET // (radix * 4 * 3)
    t = pick_tile(c, min(plans.SMALL_TILE, vmem_cap))
    grid = (g, c // t)
    bs_x = pl.BlockSpec((1, radix, t), lambda i, j: (i, 0, j))
    out_shape = [
        jax.ShapeDtypeStruct((g, radix, c), DTYPE),
        jax.ShapeDtypeStruct((g, radix, c), DTYPE),
    ]
    if radix == 2:
        # only row 1 of T is non-trivial
        twr, twi = planar_const(tw[1:2])
        bs_tw = pl.BlockSpec((1, t), lambda i, j: (0, j))
        return pl.pallas_call(
            _small2_kernel,
            grid=grid,
            in_specs=[bs_tw, bs_tw, bs_x, bs_x],
            out_specs=[bs_x, bs_x],
            out_shape=out_shape,
            interpret=INTERPRET,
        )(twr, twi, xr, xi)
    if radix == 4:
        twr, twi = planar_const(tw[1:4])  # rows 1..3
        bs_tw = pl.BlockSpec((3, t), lambda i, j: (0, j))
        return pl.pallas_call(
            _make_small4_kernel(-1.0 if inverse else 1.0),
            grid=grid,
            in_specs=[bs_tw, bs_tw, bs_x, bs_x],
            out_specs=[bs_x, bs_x],
            out_shape=out_shape,
            interpret=INTERPRET,
        )(twr, twi, xr, xi)
    if radix == 8:
        fr, fi = planar_const(plans.dft_matrix(8, inverse))
        twr, twi = planar_const(tw)
        bs_f = pl.BlockSpec((8, 8), lambda i, j: (0, 0))
        bs_tw = pl.BlockSpec((8, t), lambda i, j: (0, j))
        return pl.pallas_call(
            _small8_kernel,
            grid=grid,
            in_specs=[bs_f, bs_f, bs_tw, bs_tw, bs_x, bs_x],
            out_specs=[bs_x, bs_x],
            out_shape=out_shape,
            interpret=INTERPRET,
        )(fr, fi, twr, twi, xr, xi)
    raise ValueError(f"unsupported small radix {radix}")
