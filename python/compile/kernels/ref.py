"""Reference implementations used as correctness oracles and baselines.

* ``fft_ref``       — float64 oracle via numpy fft (the "FFTW double"
                      stand-in at build time; the Rust side has its own
                      from-scratch f64 FFT for runtime checks).
* ``fft_fp16_radix2`` — pure-jnp fp16 radix-2 Stockham FFT: the
                      "half-precision kernels on CUDA cores" (cuFFT-like)
                      baseline the paper compares against.  No matmul
                      formulation, scalar butterflies, fp16 storage per
                      stage.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def fft_ref(x: np.ndarray, axis: int = -1, inverse: bool = False) -> np.ndarray:
    """float64 FFT oracle (numpy), complex128 in/out, backward norm."""
    x = np.asarray(x, dtype=np.complex128)
    return np.fft.ifft(x, axis=axis, norm="backward") if inverse else np.fft.fft(x, axis=axis)


def fft2_ref(x: np.ndarray, inverse: bool = False) -> np.ndarray:
    x = np.asarray(x, dtype=np.complex128)
    return np.fft.ifft2(x, norm="backward") if inverse else np.fft.fft2(x)


def fft_fp16_radix2(xr, xi, *, inverse: bool = False, axis: int = -1):
    """Batched fp16 radix-2 Stockham autosort FFT along ``axis``.

    Stockham needs no bit-reversal; each stage is a reshape + butterfly,
    the access pattern cuFFT-style half-precision CUDA-core kernels use.
    Intermediates are stored fp16 (same error behaviour as the paper's
    cuFFT-half baseline).
    """
    moved = axis not in (-1, xr.ndim - 1)
    if moved:
        xr = jnp.moveaxis(xr, axis, -1)
        xi = jnp.moveaxis(xi, axis, -1)
    n = xr.shape[-1]
    t = n.bit_length() - 1
    assert 1 << t == n, n
    sign = 1.0 if inverse else -1.0
    shape = xr.shape[:-1]

    # Stockham autosort: at step s, L = 2^s sub-results of the *output*
    # ordering are already in place.
    for s in range(t):
        l = 1 << s
        m = n // (2 * l)
        ar = xr.reshape(shape + (2, m, l))
        ai = xi.reshape(shape + (2, m, l))
        a_r, b_r = ar[..., 0, :, :], ar[..., 1, :, :]
        a_i, b_i = ai[..., 0, :, :], ai[..., 1, :, :]
        ang = sign * 2.0 * np.pi * np.arange(l) / (2 * l)
        wr = jnp.asarray(np.cos(ang).astype(np.float16))
        wi = jnp.asarray(np.sin(ang).astype(np.float16))
        tbr = b_r * wr - b_i * wi
        tbi = b_r * wi + b_i * wr
        # interleave: y viewed (m, 2, l): [a + tb, a - tb]
        yr = jnp.stack([a_r + tbr, a_r - tbr], axis=-2)
        yi = jnp.stack([a_i + tbi, a_i - tbi], axis=-2)
        xr = yr.reshape(shape + (n,)).astype(jnp.float16)
        xi = yi.reshape(shape + (n,)).astype(jnp.float16)
    if inverse:
        inv = jnp.asarray(1.0 / n, jnp.float16)
        xr = xr * inv
        xi = xi * inv
    if moved:
        xr = jnp.moveaxis(xr, -1, axis)
        xi = jnp.moveaxis(xi, -1, axis)
    return xr, xi
