"""Unoptimized "split" kernels for the Sec 5.4 "Optimized TC" ablation.

The paper's baseline-before-optimization performs the twiddle multiply
and the complex matrix (de)interleave through shared memory, separately
from the Tensor-Core matmul.  The faithful TPU analogue: run the merge
as TWO pallas_calls — an element-wise twiddle kernel that writes the
intermediate back to HBM, then a matmul-only kernel that reads it again.
One extra HBM round trip per merge, identical arithmetic.

Used by the ``tc_split`` artifact variants; comparing them against the
fused ``tc`` variants reproduces the paper's 1.15x-1.32x ablation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import plans
from .common import DTYPE, INTERPRET, cdot, cmul, pick_tile, planar_const


def _twiddle_kernel(twr_ref, twi_ref, xr_ref, xi_ref, or_ref, oi_ref):
    zr, zi = cmul(xr_ref[0], xi_ref[0], twr_ref[...], twi_ref[...])
    or_ref[0] = zr
    oi_ref[0] = zi


def _matmul_kernel(fr_ref, fi_ref, xr_ref, xi_ref, or_ref, oi_ref):
    orr, oii = cdot("mj,jk->mk", fr_ref[...], fi_ref[...], xr_ref[0], xi_ref[0])
    or_ref[0] = orr
    oi_ref[0] = oii


def r16_split(xr, xi, *, n2: int, lane: int = 1, inverse: bool = False):
    """Radix-16 merge as twiddle-kernel + matmul-kernel (2 HBM trips)."""
    g, r, c = xr.shape
    assert r == 16 and c == n2 * lane, (xr.shape, n2, lane)
    tw = plans.twiddle_matrix(16, n2, inverse)
    if lane > 1:
        tw = tw.repeat(lane, axis=1)
    twr, twi = planar_const(tw)
    fr, fi = planar_const(plans.dft_matrix(16, inverse))
    t = pick_tile(c, plans.R16_TILE)
    grid = (g, c // t)
    bs_x = pl.BlockSpec((1, 16, t), lambda i, j: (i, 0, j))
    bs_tw = pl.BlockSpec((16, t), lambda i, j: (0, j))
    bs_f = pl.BlockSpec((16, 16), lambda i, j: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((g, 16, c), DTYPE),
        jax.ShapeDtypeStruct((g, 16, c), DTYPE),
    ]
    # pass 1: twiddle only — intermediate goes back to HBM
    zr, zi = pl.pallas_call(
        _twiddle_kernel,
        grid=grid,
        in_specs=[bs_tw, bs_tw, bs_x, bs_x],
        out_specs=[bs_x, bs_x],
        out_shape=out_shape,
        interpret=INTERPRET,
    )(twr, twi, xr, xi)
    # pass 2: matmul only
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[bs_f, bs_f, bs_x, bs_x],
        out_specs=[bs_x, bs_x],
        out_shape=out_shape,
        interpret=INTERPRET,
    )(fr, fi, zr, zi)
