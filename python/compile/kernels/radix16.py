"""Radix-16 merging kernels — the core of tcFFT (paper Sec 3.2).

A radix-16 merge computes ``X_out = F_16 . (T_{16,n2} (.) X_in)`` over
(16, n2) blocks.  The 16x16 complex DFT matrix exactly fills one MXU
tile (the paper's Tensor-Core fragment), and the twiddle multiply is
fused into the kernel body before the dot — the Pallas analogue of the
paper's single-element fragment manipulation (Sec 4.1).

Two kernels live here:

* ``r16_first``  — the first stage (n2 = 1, no twiddles): 16 length-1
  sub-FFTs per block; formulated as a (rows, 16) x (16, 16) matmul.
* ``r16``        — a generic mid-pipeline radix-16 merge for n2 >= 16,
  gridded over (group, column-tile).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import plans
from .common import ACC_DTYPE, DTYPE, INTERPRET, cdot, cmul, pick_tile, planar_const


def _r16_first_kernel(fr_ref, fi_ref, xr_ref, xi_ref, or_ref, oi_ref):
    # x: (Tg, 16, L); F: (16, 16). out[g,m,l] = sum_j F[m,j] x[g,j,l]
    fr, fi = fr_ref[...], fi_ref[...]
    xr, xi = xr_ref[...], xi_ref[...]
    orr, oii = cdot("mj,gjl->gml", fr, fi, xr, xi)
    or_ref[...] = orr
    oi_ref[...] = oii


def r16_first(xr, xi, *, lane: int = 1, inverse: bool = False):
    """First-stage radix-16 merge. Input planar (G, 16, lane)."""
    g = xr.shape[0]
    assert xr.shape == (g, 16, lane), xr.shape
    fr, fi = planar_const(plans.dft_matrix(16, inverse))
    # keep the VMEM block ~constant for strided (lane > 1) passes
    tg = pick_tile(g, max(1, plans.FIRST_STAGE_ROWS // lane))
    grid = (g // tg,)
    bs_x = pl.BlockSpec((tg, 16, lane), lambda i: (i, 0, 0))
    bs_f = pl.BlockSpec((16, 16), lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((g, 16, lane), DTYPE),
        jax.ShapeDtypeStruct((g, 16, lane), DTYPE),
    ]
    return pl.pallas_call(
        _r16_first_kernel,
        grid=grid,
        in_specs=[bs_f, bs_f, bs_x, bs_x],
        out_specs=[bs_x, bs_x],
        out_shape=out_shape,
        interpret=INTERPRET,
    )(fr, fi, xr, xi)


def _r16_kernel(fr_ref, fi_ref, twr_ref, twi_ref, xr_ref, xi_ref, or_ref, oi_ref):
    # x: (1, 16, T) one group's column tile; tw: (16, T); F: (16, 16)
    fr, fi = fr_ref[...], fi_ref[...]
    twr, twi = twr_ref[...], twi_ref[...]
    xr, xi = xr_ref[0], xi_ref[0]
    zr, zi = cmul(xr, xi, twr, twi)  # twiddle on the VPU, in-register
    orr, oii = cdot("mj,jk->mk", fr, fi, zr, zi)  # 16x16 @ 16xT on the MXU
    or_ref[0] = orr
    oi_ref[0] = oii


def r16(xr, xi, *, n2: int, lane: int = 1, inverse: bool = False):
    """Mid-pipeline radix-16 merge. Input planar (G, 16, n2*lane).

    The twiddle matrix T_{16,n2} is lane-expanded (each column repeated
    ``lane`` times) so the strided first-axis pass of a 2D FFT reuses
    this kernel unchanged — the paper's "strided batched FFT".
    """
    g, r, c = xr.shape
    assert r == 16 and c == n2 * lane, (xr.shape, n2, lane)
    fr, fi = planar_const(plans.dft_matrix(16, inverse))
    tw = plans.twiddle_matrix(16, n2, inverse)
    if lane > 1:
        tw = tw.repeat(lane, axis=1)
    twr, twi = planar_const(tw)
    t = pick_tile(c, plans.R16_TILE)
    grid = (g, c // t)
    bs_x = pl.BlockSpec((1, 16, t), lambda i, j: (i, 0, j))
    bs_tw = pl.BlockSpec((16, t), lambda i, j: (0, j))
    bs_f = pl.BlockSpec((16, 16), lambda i, j: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((g, 16, c), DTYPE),
        jax.ShapeDtypeStruct((g, 16, c), DTYPE),
    ]
    return pl.pallas_call(
        _r16_kernel,
        grid=grid,
        in_specs=[bs_f, bs_f, bs_tw, bs_tw, bs_x, bs_x],
        out_specs=[bs_x, bs_x],
        out_shape=out_shape,
        interpret=INTERPRET,
    )(fr, fi, twr, twi, xr, xi)
