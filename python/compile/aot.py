"""AOT pipeline: lower every artifact variant to HLO text + manifest.

Interchange format is HLO TEXT (not serialized HloModuleProto): jax>=0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Usage (from ``python/``):
    python -m compile.aot --out-dir ../artifacts          # build all
    python -m compile.aot --report                        # L1 perf report
    python -m compile.aot --only fft1d_tc_n256_b4_fwd ... # subset
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import time
from typing import List, Optional

import jax
import jax.numpy as jnp

from . import model, plans


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: twiddle/DFT-matrix constants must
    # round-trip through the text parser or the rust side gets zeros.
    return comp.as_hlo_text(print_large_constants=True)


@dataclasses.dataclass
class Variant:
    op: str                      # 'fft1d' | 'fft2d'
    algo: str                    # 'tc' | 'tc_split' | 'r2'
    batch: int
    inverse: bool
    n: int = 0                   # 1D length
    nx: int = 0                  # 2D first dim (strided)
    ny: int = 0                  # 2D second dim (contiguous)

    @property
    def key(self) -> str:
        d = "inv" if self.inverse else "fwd"
        if self.op == "fft1d":
            return f"fft1d_{self.algo}_n{self.n}_b{self.batch}_{d}"
        return f"fft2d_{self.algo}_nx{self.nx}x{self.ny}_b{self.batch}_{d}"

    def build_fn(self):
        if self.op == "fft1d":
            return model.fft1d_fn(self.n, self.batch, self.algo, self.inverse)
        return model.fft2d_fn(self.nx, self.ny, self.batch, self.algo, self.inverse)

    def input_shape(self) -> List[int]:
        if self.op == "fft1d":
            return [self.batch, self.n]
        return [self.batch, self.nx, self.ny]

    def stages(self) -> List[dict]:
        if self.algo == "r2":
            total = self.n if self.op == "fft1d" else self.nx * self.ny
            log2 = total.bit_length() - 1
            return [{"kernel": "stockham2", "radix": 2, "n2": 1 << s,
                     "lane": 1, "flops": 10 * total, "hbm_bytes": 8 * total,
                     "vmem_bytes": 0}
                    for s in range(log2)]
        mk = model.split_schedule if self.algo == "tc_split" else plans.kernel_schedule
        if self.op == "fft1d":
            return [_stage_dict(s, self.n) for s in mk(self.n)]
        out = [_stage_dict(s, self.ny) for s in mk(self.ny, 1)]
        out += [_stage_dict(s, self.nx) for s in mk(self.nx, self.ny)]
        return out

    def manifest_entry(self, fname: str) -> dict:
        stages = self.stages()
        n_total = self.n if self.op == "fft1d" else self.nx * self.ny
        return {
            "key": self.key,
            "file": fname,
            "op": self.op,
            "algo": self.algo,
            "n": self.n,
            "nx": self.nx,
            "ny": self.ny,
            "batch": self.batch,
            "inverse": self.inverse,
            "dtype": "f16",
            "input_shape": self.input_shape(),
            "stages": stages,
            "flops_per_seq": sum(s["flops"] for s in stages),
            "hbm_bytes_per_seq": sum(s["hbm_bytes"] for s in stages),
            "radix2_equiv_flops": plans.radix2_equivalent_flops(n_total, self.batch),
        }


def _stage_dict(s: plans.Stage, n_axis: int) -> dict:
    return {
        "kernel": s.kernel,
        "radix": s.radix,
        "n2": s.n2,
        "lane": s.lane,
        "flops": s.flops(n_axis) * s.lane,
        "hbm_bytes": s.hbm_bytes(n_axis) * s.lane,
        "vmem_bytes": s.vmem_bytes(),
    }


def variant_matrix() -> List[Variant]:
    """The full artifact set (see DESIGN.md 'Artifact variant matrix')."""
    v: List[Variant] = []
    # -- 1D perf/precision ladder (Fig 4, Table 4) --
    for n in (256, 1024, 4096, 16384, 65536):
        v.append(Variant("fft1d", "tc", 4, False, n=n))
        v.append(Variant("fft1d", "r2", 4, False, n=n))
    # ablation variants (Sec 5.4 'Optimized TC')
    for n in (4096, 65536):
        v.append(Variant("fft1d", "tc_split", 4, False, n=n))
    # inverse round-trip support
    for n in (1024, 4096):
        v.append(Variant("fft1d", "tc", 4, True, n=n))
    # -- batch sweep at 131072 points (Fig 7a) --
    for b in (1, 2, 4, 8, 16):
        v.append(Variant("fft1d", "tc", b, False, n=131072))
    v.append(Variant("fft1d", "r2", 4, False, n=131072))
    # four-step large-FFT building block: 1024-point with batch 32
    v.append(Variant("fft1d", "tc", 32, False, n=1024))
    v.append(Variant("fft1d", "tc", 32, True, n=1024))
    # -- 2D shapes (Fig 5, Table 4) --
    for nx, ny in ((128, 128), (256, 256), (256, 512), (512, 256), (512, 512)):
        v.append(Variant("fft2d", "tc", 2, False, nx=nx, ny=ny))
    v.append(Variant("fft2d", "tc", 2, True, nx=256, ny=256))
    v.append(Variant("fft2d", "r2", 2, False, nx=256, ny=256))
    v.append(Variant("fft2d", "r2", 2, False, nx=512, ny=256))
    v.append(Variant("fft2d", "tc_split", 2, False, nx=512, ny=256))
    # batch sweep 2D 512x256 (Fig 7b)
    for b in (1, 4, 8):
        v.append(Variant("fft2d", "tc", b, False, nx=512, ny=256))
    return v


def lower_variant(var: Variant) -> str:
    spec = jax.ShapeDtypeStruct(tuple(var.input_shape()), jnp.float16)
    lowered = jax.jit(var.build_fn()).lower(spec, spec)
    return to_hlo_text(lowered)


def build(out_dir: str, only: Optional[List[str]] = None, verbose: bool = True) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "dtype": "f16", "inverse_norm": "none", "variants": []}
    t0 = time.time()
    for var in variant_matrix():
        if only and var.key not in only:
            continue
        fname = var.key + ".hlo.txt"
        text = lower_variant(var)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry = var.manifest_entry(fname)
        entry["hlo_sha256"] = hashlib.sha256(text.encode()).hexdigest()
        entry["hlo_bytes"] = len(text)
        manifest["variants"].append(entry)
        if verbose:
            print(f"  {var.key:<42} {len(text)//1024:>6} KiB  "
                  f"[{time.time()-t0:6.1f}s]", flush=True)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"wrote {len(manifest['variants'])} artifacts + manifest.json "
              f"in {time.time()-t0:.1f}s")


def report() -> None:
    """L1 perf report: per-plan VMEM footprint and MXU utilization estimate.

    MXU utilization is estimated structurally (interpret=True gives no
    hardware timings): the fraction of FLOPs issued as 16x16xK dots
    (MXU-eligible) scaled by tile-fill efficiency — 16x16 operand tiles
    occupy 1/8 of a 128x128 MXU pass in each dimension, but the fused
    kernels batch >= 8 tiles per block which pipelines passes back to
    back; 0.72 is the resulting steady-state estimate used in DESIGN.md.
    """
    print(f"{'plan':>10} {'stages':>6} {'VMEM max':>10} {'AI (fl/B)':>10} "
          f"{'MXU-elig':>9} {'est MXU util':>12}")
    for n in (256, 1024, 4096, 16384, 65536, 131072, 1 << 20, 1 << 24):
        sts = plans.kernel_schedule(n)
        tot = plans.schedule_totals(n)
        mxu_flops = sum(
            s.flops(n) for s in sts
            if s.kernel in ("r16", "r16_first", "fused256_first", "merge256")
        )
        frac = mxu_flops / tot["flops"]
        ai = tot["flops"] / tot["hbm_bytes"]
        est = frac * 0.72
        print(f"{n:>10} {tot['stages']:>6} {tot['max_vmem_bytes']//1024:>9}K "
              f"{ai:>10.1f} {frac:>8.1%} {est:>11.1%}")


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--only", nargs="*", default=None)
    p.add_argument("--report", action="store_true")
    args = p.parse_args(argv)
    if args.report:
        report()
        return
    build(args.out_dir, args.only)


if __name__ == "__main__":
    main()
