"""L2: JAX compute graphs for tcFFT — the plan executor.

Builds, per (op, algo, size, batch, direction) variant, a jit-able
function over *planar* fp16 complex arrays.  The staged pipeline mirrors
the paper's execution function: a digit-reverse gather, then the
selected merging kernels in order.  Inverse transforms are UNNORMALIZED
(cuFFT convention) — callers scale by 1/N.

Algorithms:
* ``tc``       — the tcFFT pipeline: fused Pallas merging kernels
                 (fused256_first / merge256 / r16 / small) with in-kernel
                 twiddle fusion (Sec 4.1) and VMEM stage fusion (Sec 3.2).
* ``tc_split`` — ablation: same merges, but every radix-16 merge is an
                 unfused twiddle-kernel + matmul-kernel pair (extra HBM
                 round trips) and no stage fusion; the paper's
                 pre-optimization Tensor-Core baseline.
* ``r2``       — fp16 radix-2 Stockham on the VPU only: the cuFFT-half
                 "CUDA core" comparator.

2D FFTs do the contiguous last axis first, then the strided first axis
via the same kernels with a lane dimension (paper: strided batched FFT);
no transposes are materialized.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from . import plans
from .kernels import fused256, radix16, ref, small_radix, split

DTYPE = jnp.float16


def _apply_stage(st: plans.Stage, xr, xi, b, n_axis, lane, inverse, algo):
    """Dispatch one kernel invocation; arrays arrive flattened as
    (rows, n_axis*lane) where rows = batch x leading dims."""
    n2 = st.n2
    if st.kernel == "fused256_first":
        g = b * n_axis // 256
        xr = xr.reshape(g, 16, 16, lane)
        xi = xi.reshape(g, 16, 16, lane)
        yr, yi = fused256.fused256_first(xr, xi, lane=lane, inverse=inverse)
    elif st.kernel == "r16_first":
        g = b * n_axis // 16
        xr = xr.reshape(g, 16, lane)
        xi = xi.reshape(g, 16, lane)
        yr, yi = radix16.r16_first(xr, xi, lane=lane, inverse=inverse)
    elif st.kernel == "r16":
        g = b * n_axis // (16 * n2)
        xr = xr.reshape(g, 16, n2 * lane)
        xi = xi.reshape(g, 16, n2 * lane)
        fn = split.r16_split if algo == "tc_split" else radix16.r16
        yr, yi = fn(xr, xi, n2=n2, lane=lane, inverse=inverse)
    elif st.kernel == "merge256":
        g = b * n_axis // (256 * n2)
        xr = xr.reshape(g, 16, 16, n2, lane)
        xi = xi.reshape(g, 16, 16, n2, lane)
        yr, yi = fused256.merge256(xr, xi, n2=n2, lane=lane, inverse=inverse)
    elif st.kernel == "small":
        g = b * n_axis // (st.radix * n2)
        xr = xr.reshape(g, st.radix, n2 * lane)
        xi = xi.reshape(g, st.radix, n2 * lane)
        yr, yi = small_radix.small(
            xr, xi, radix=st.radix, n2=n2, lane=lane, inverse=inverse
        )
    else:
        raise ValueError(st.kernel)
    return yr.reshape(b, n_axis * lane), yi.reshape(b, n_axis * lane)


def split_schedule(n_axis: int, lane: int = 1):
    """The tc_split ablation schedule: no stage fusion, unfused merges."""
    radices = plans.radix_schedule(n_axis)
    a = sum(1 for r in radices if r == 16)
    stages = []
    n2 = 1
    if a >= 1:
        stages.append(plans.Stage("r16_first", 16, 1, lane))
        n2 = 16
    for _ in range(1, a):
        stages.append(plans.Stage("r16", 16, n2, lane))
        n2 *= 16
    for r in [r for r in radices if r != 16]:
        stages.append(plans.Stage("small", r, n2, lane))
        n2 *= r
    return stages


def _staged_fft(xr, xi, n_axis: int, lane: int, inverse: bool, algo: str):
    """Run the staged pipeline along an axis of length ``n_axis`` with a
    trailing contiguous ``lane`` dim.  Input shape (rows, n_axis*lane)."""
    b = xr.shape[0]
    if algo == "tc_split":
        stages = split_schedule(n_axis, lane)
    else:
        stages = plans.kernel_schedule(n_axis, lane)
    for st in stages:
        xr, xi = _apply_stage(st, xr, xi, b, n_axis, lane, inverse, algo)
    return xr, xi


def _permute(xr, xi, n_axis: int, lane: int):
    """Digit-reverse gather along the staged axis (paper Fig 3b: the
    changing-order, in-place-friendly layout, applied once up front)."""
    perm = plans.digit_reverse_indices(n_axis)
    idx = jnp.asarray(perm, jnp.int32)
    b = xr.shape[0]
    xr = xr.reshape(b, n_axis, lane)
    xi = xi.reshape(b, n_axis, lane)
    xr = jnp.take(xr, idx, axis=1).reshape(b, n_axis * lane)
    xi = jnp.take(xi, idx, axis=1).reshape(b, n_axis * lane)
    return xr, xi


def fft1d_fn(n: int, batch: int, algo: str = "tc", inverse: bool = False):
    """Build f(xr, xi) -> (yr, yi) over (batch, n) planar fp16 arrays."""

    def f(xr, xi):
        xr = xr.astype(DTYPE)
        xi = xi.astype(DTYPE)
        if algo == "r2":
            yr, yi = ref.fft_fp16_radix2(xr, xi, inverse=inverse)
            if inverse:  # undo ref's normalization: cuFFT convention
                scale = jnp.asarray(float(n), jnp.float32)
                yr = (yr.astype(jnp.float32) * scale).astype(DTYPE)
                yi = (yi.astype(jnp.float32) * scale).astype(DTYPE)
            return yr, yi
        xr, xi = _permute(xr, xi, n, 1)
        return _staged_fft(xr, xi, n, 1, inverse, algo)

    return f


def fft2d_fn(nx: int, ny: int, batch: int, algo: str = "tc", inverse: bool = False):
    """Build f(xr, xi) -> (yr, yi) over (batch, nx, ny) planar fp16.

    Row-major storage: ny (second dim) is contiguous — transformed
    first; the nx axis is transformed via strided (lane=ny) kernels.
    """

    def f(xr, xi):
        xr = xr.astype(DTYPE)
        xi = xi.astype(DTYPE)
        b = xr.shape[0]
        if algo == "r2":
            yr, yi = ref.fft_fp16_radix2(xr, xi, inverse=inverse, axis=-1)
            yr, yi = ref.fft_fp16_radix2(yr, yi, inverse=inverse, axis=-2)
            if inverse:
                scale = jnp.asarray(float(nx * ny), jnp.float32)
                yr = (yr.astype(jnp.float32) * scale).astype(DTYPE)
                yi = (yi.astype(jnp.float32) * scale).astype(DTYPE)
            return yr, yi
        # pass 1: contiguous rows (batch*nx independent ny-point FFTs)
        xr = xr.reshape(b * nx, ny)
        xi = xi.reshape(b * nx, ny)
        xr, xi = _permute(xr, xi, ny, 1)
        xr, xi = _staged_fft(xr, xi, ny, 1, inverse, algo)
        # pass 2: strided first axis (lane = ny), no transpose
        xr = xr.reshape(b, nx * ny)
        xi = xi.reshape(b, nx * ny)
        xr, xi = _permute(xr, xi, nx, ny)
        xr, xi = _staged_fft(xr, xi, nx, ny, inverse, algo)
        return xr.reshape(b, nx, ny), xi.reshape(b, nx, ny)

    return f


# ---------------------------------------------------------------------------
# numpy convenience wrappers (used by tests)
# ---------------------------------------------------------------------------

def run_fft1d(x: np.ndarray, algo: str = "tc", inverse: bool = False) -> np.ndarray:
    """x: complex (batch, n) -> complex64 result via the fp16 pipeline."""
    b, n = x.shape
    f = jax.jit(fft1d_fn(n, b, algo, inverse))
    yr, yi = f(
        jnp.asarray(x.real.astype(np.float16)), jnp.asarray(x.imag.astype(np.float16))
    )
    return np.asarray(yr, np.float32) + 1j * np.asarray(yi, np.float32)


def run_fft2d(x: np.ndarray, algo: str = "tc", inverse: bool = False) -> np.ndarray:
    b, nx, ny = x.shape
    f = jax.jit(fft2d_fn(nx, ny, b, algo, inverse))
    yr, yi = f(
        jnp.asarray(x.real.astype(np.float16)), jnp.asarray(x.imag.astype(np.float16))
    )
    return np.asarray(yr, np.float32) + 1j * np.asarray(yi, np.float32)
