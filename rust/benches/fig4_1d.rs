//! Bench: regenerate paper Fig 4 — 1D FFT performance across sizes on
//! V100 and A100.
//!
//! Two parts:
//!  1. MODEL: radix-2-equivalent TFLOPS for tcFFT / unoptimized-TC /
//!     cuFFT-half over 2^8..2^27 on both GPUs (the figure's series).
//!  2. MEASURED (CPU interpret substrate): wall-clock of the real AOT
//!     artifacts, tc vs r2 baseline, which validates the *relative*
//!     algorithm structure this testbed can observe.
//!
//!     cargo bench --bench fig4_1d

use tcfft::bench_harness::{bench, header};
use tcfft::perfmodel::{figures as f, GpuSpec};
use tcfft::plan::{Direction, Plan};
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::util::table::Table;
use tcfft::workload::random_signal;

fn main() -> tcfft::error::Result<()> {
    header("Fig 4: 1D FFT performance of different sizes");

    // ---- part 1: modelled series (the paper's figure) ----
    let v100 = GpuSpec::v100();
    let a100 = GpuSpec::a100();
    println!("{}", f::render_series("Fig 4(a) model: V100", "TFLOPS", &f::fig4_series(&v100)));
    println!("{}", f::render_series("Fig 4(b) model: A100", "TFLOPS", &f::fig4_series(&a100)));
    let s_v: Vec<f64> = f::fig4_series(&v100).iter().skip(6).map(|p| p.speedup()).collect();
    let avg_v = s_v.iter().sum::<f64>() / s_v.len() as f64;
    let s_a: Vec<f64> = f::fig4_series(&a100).iter().skip(6).map(|p| p.speedup()).collect();
    let avg_a = s_a.iter().sum::<f64>() / s_a.len() as f64;
    println!("model avg speedup (non-bw-bound): V100 {avg_v:.2}x (paper 1.90x) | A100 {avg_a:.2}x (paper 1.24x)\n");

    // ---- part 2: measured artifacts on the CPU substrate ----
    let rt = Runtime::load_default()?;
    let mut t = Table::new(&["n", "tc median ms", "r2 median ms", "tc/r2 (CPU)"]);
    for n in [256usize, 1024, 4096, 16384, 65536] {
        let mut med = Vec::new();
        for algo in ["tc", "r2"] {
            let plan = Plan::fft1d_algo(&rt.registry, n, 4, algo, Direction::Forward)?;
            let x: Vec<_> = (0..4).flat_map(|b| random_signal(n, b as u64)).collect();
            let input = PlanarBatch::from_complex(&x, vec![4, n]);
            plan.execute(&rt, input.clone())?; // warm/compile
            let r = bench(
                &format!("n={n} {algo}"),
                || {
                    plan.execute(&rt, input.clone()).unwrap();
                },
                12,
            );
            med.push(r.summary.median());
        }
        t.row(vec![
            n.to_string(),
            format!("{:.2}", med[0] * 1e3),
            format!("{:.2}", med[1] * 1e3),
            format!("{:.2}x", med[1] / med[0]),
        ]);
    }
    println!("measured on CPU-PJRT (interpret substrate; relative only):\n{}", t.render());
    println!("fig4_1d: OK");
    Ok(())
}
