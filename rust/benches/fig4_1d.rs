//! Bench: regenerate paper Fig 4 — 1D FFT performance across sizes on
//! V100 and A100.
//!
//! Three parts:
//!  1. MODEL: radix-2-equivalent TFLOPS for tcFFT / unoptimized-TC /
//!     cuFFT-half over 2^8..2^27 on both GPUs (the figure's series).
//!  2. MEASURED (CPU interpret substrate): wall-clock of the real AOT
//!     artifacts, tc vs r2 baseline, which validates the *relative*
//!     algorithm structure this testbed can observe.
//!  3. ENGINE: the batch-major fused parallel engine vs the pre-PR
//!     row-major reference interpreter; medians land in
//!     `BENCH_interp.json` (headline: n=4096 batch=32, 4 threads).
//!  4. EC COST: the error-corrected `tc_ec` tier at the headline
//!     shape, referenced against the plain `tc` engine median — the
//!     time side of the accuracy-vs-speed tradeoff that
//!     `precision_tc_ec_n4096_b32` records the accuracy side of
//!     (entry `fft1d_tc_ec_n4096_b32_fwd`; its `speedup` reads as
//!     tc/tc_ec and is expected **below 1** — a measured cost).
//!
//!     cargo bench --bench fig4_1d
//!     TCFFT_BENCH_SMOKE=1 cargo bench --bench fig4_1d   # CI smoke
//!
//! Parts 2 and 3 honor TCFFT_BENCH_SMOKE (reduced matrix, capped
//! iterations) while still emitting the JSON entries CI validates.

use tcfft::bench_harness::{bench, bench_entry, header, smoke, update_bench_json};
use tcfft::perfmodel::{figures as f, GpuSpec};
use tcfft::plan::{Direction, Plan};
use tcfft::runtime::{
    Backend, CpuInterpreter, PlanarBatch, ReferenceInterpreter, Runtime, VariantMeta,
};
use tcfft::util::json::Json;
use tcfft::util::table::Table;
use tcfft::workload::random_signal;

/// Headline thread count recorded in BENCH_interp.json.
const ENGINE_THREADS: usize = 4;

/// Bench-local 1D forward descriptor. The synthesized catalog
/// deliberately has no b=32 tier at n=4096 (adding one would flip
/// `find_fft1d` from split-over-b4 to pad-to-32 for serving requests
/// with batch 5..=31), so the engine comparisons build their variant
/// metadata here instead of polluting the registry.
fn bench_meta_1d(key: &str, algo: &str, n: usize, batch: usize) -> VariantMeta {
    VariantMeta {
        key: key.to_string(),
        file: std::path::PathBuf::new(),
        op: "fft1d".to_string(),
        algo: algo.to_string(),
        n,
        nx: 0,
        ny: 0,
        batch,
        inverse: false,
        input_shape: vec![batch, n],
        stages: Vec::new(),
        flops_per_seq: 0.0,
        hbm_bytes_per_seq: 0.0,
        radix2_equiv_flops: 0.0,
    }
}

fn main() -> tcfft::error::Result<()> {
    header("Fig 4: 1D FFT performance of different sizes");
    let iters = if smoke() { 3 } else { 12 };

    // ---- part 1: modelled series (the paper's figure) ----
    let v100 = GpuSpec::v100();
    let a100 = GpuSpec::a100();
    println!("{}", f::render_series("Fig 4(a) model: V100", "TFLOPS", &f::fig4_series(&v100)));
    println!("{}", f::render_series("Fig 4(b) model: A100", "TFLOPS", &f::fig4_series(&a100)));
    let s_v: Vec<f64> = f::fig4_series(&v100).iter().skip(6).map(|p| p.speedup()).collect();
    let avg_v = s_v.iter().sum::<f64>() / s_v.len() as f64;
    let s_a: Vec<f64> = f::fig4_series(&a100).iter().skip(6).map(|p| p.speedup()).collect();
    let avg_a = s_a.iter().sum::<f64>() / s_a.len() as f64;
    println!("model avg speedup (non-bw-bound): V100 {avg_v:.2}x (paper 1.90x) | A100 {avg_a:.2}x (paper 1.24x)\n");

    // ---- part 2: measured artifacts on the CPU substrate ----
    let rt = Runtime::load_default()?;
    let sizes: &[usize] = if smoke() { &[256, 4096] } else { &[256, 1024, 4096, 16384, 65536] };
    let mut t = Table::new(&["n", "tc median ms", "r2 median ms", "tc/r2 (CPU)"]);
    for &n in sizes {
        let mut med = Vec::new();
        for algo in ["tc", "r2"] {
            let plan = Plan::fft1d_algo(&rt.registry, n, 4, algo, Direction::Forward)?;
            let x: Vec<_> = (0..4).flat_map(|b| random_signal(n, b as u64)).collect();
            let input = PlanarBatch::from_complex(&x, vec![4, n]);
            plan.execute(&rt, input.clone())?; // warm/compile
            let r = bench(
                &format!("n={n} {algo}"),
                || {
                    plan.execute(&rt, input.clone()).unwrap();
                },
                iters,
            );
            med.push(r.summary.median());
        }
        t.row(vec![
            n.to_string(),
            format!("{:.2}", med[0] * 1e3),
            format!("{:.2}", med[1] * 1e3),
            format!("{:.2}x", med[1] / med[0]),
        ]);
    }
    println!("measured on CPU-PJRT (interpret substrate; relative only):\n{}", t.render());

    // ---- part 3: batch-major engine vs the pre-PR reference ----
    // (n, batch) shapes; the first is the acceptance headline
    let shapes: &[(usize, usize)] =
        if smoke() { &[(4096, 32)] } else { &[(4096, 32), (1024, 32), (16384, 4)] };
    let mut entries: Vec<(String, Json)> = Vec::new();
    let mut te = Table::new(&["key", "reference ms", "engine 1t ms", "engine 4t ms", "speedup"]);
    let mut headline_tc_par = None;
    for &(n, b) in shapes {
        let key = format!("fft1d_tc_n{n}_b{b}_fwd");
        let meta = bench_meta_1d(&key, "tc", n, b);
        let x: Vec<_> = (0..b).flat_map(|i| random_signal(n, i as u64)).collect();
        let input = PlanarBatch::from_complex(&x, vec![b, n]);

        let reference = ReferenceInterpreter::new();
        let serial = CpuInterpreter::with_threads(1);
        let parallel = CpuInterpreter::with_threads(ENGINE_THREADS);
        reference.execute(&meta, input.clone())?; // warm all three
        serial.execute(&meta, input.clone())?;
        parallel.execute(&meta, input.clone())?;

        let r_ref = bench(
            &format!("{key} reference"),
            || {
                reference.execute(&meta, input.clone()).unwrap();
            },
            iters,
        );
        let r_ser = bench(
            &format!("{key} engine 1t"),
            || {
                serial.execute(&meta, input.clone()).unwrap();
            },
            iters,
        );
        let r_par = bench(
            &format!("{key} engine {ENGINE_THREADS}t"),
            || {
                parallel.execute(&meta, input.clone()).unwrap();
            },
            iters,
        );
        let (m_ref, m_ser, m_par) =
            (r_ref.summary.median(), r_ser.summary.median(), r_par.summary.median());
        if (n, b) == (4096, 32) {
            headline_tc_par = Some(m_par);
        }
        te.row(vec![
            key.clone(),
            format!("{:.2}", m_ref * 1e3),
            format!("{:.2}", m_ser * 1e3),
            format!("{:.2}", m_par * 1e3),
            format!("{:.2}x", m_ref / m_par),
        ]);
        entries.push((
            key,
            bench_entry("fig4_1d", ENGINE_THREADS, r_par.summary.len(), m_ref, m_ser, m_par),
        ));
    }

    // ---- part 4: the tc_ec tier's multiply cost at the headline ----
    // never fused, 3x the stage multiplies: the "reference" series is
    // the plain tc engine median just measured, so the entry's speedup
    // reads directly as tc/tc_ec (a cost factor below 1)
    {
        let (n, b) = (4096usize, 32usize);
        let key = format!("fft1d_tc_ec_n{n}_b{b}_fwd");
        let meta = bench_meta_1d(&key, "tc_ec", n, b);
        let m_tc = headline_tc_par.expect("headline shape runs in every mode");
        let x: Vec<_> = (0..b).flat_map(|i| random_signal(n, i as u64)).collect();
        let input = PlanarBatch::from_complex(&x, vec![b, n]);
        let serial = CpuInterpreter::with_threads(1);
        let parallel = CpuInterpreter::with_threads(ENGINE_THREADS);
        serial.execute(&meta, input.clone())?; // warm both
        parallel.execute(&meta, input.clone())?;
        let r_ser = bench(
            &format!("{key} engine 1t"),
            || {
                serial.execute(&meta, input.clone()).unwrap();
            },
            iters,
        );
        let r_par = bench(
            &format!("{key} engine {ENGINE_THREADS}t"),
            || {
                parallel.execute(&meta, input.clone()).unwrap();
            },
            iters,
        );
        let (m_ser, m_par) = (r_ser.summary.median(), r_par.summary.median());
        te.row(vec![
            key.clone(),
            format!("{:.2}", m_tc * 1e3),
            format!("{:.2}", m_ser * 1e3),
            format!("{:.2}", m_par * 1e3),
            format!("{:.2}x", m_tc / m_par),
        ]);
        entries.push((
            key,
            bench_entry("fig4_1d", ENGINE_THREADS, r_par.summary.len(), m_tc, m_ser, m_par),
        ));
    }
    let path = update_bench_json(&entries)?;
    println!(
        "engine vs pre-PR reference (before/after recorded in {}):\n{}",
        path.display(),
        te.render()
    );
    println!("fig4_1d: OK");
    Ok(())
}
