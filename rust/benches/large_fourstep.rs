//! Bench: the batched four-step large-FFT engine vs the kept
//! per-sequence baseline, at the acceptance shape n = 2^20, batch 8.
//!
//! The baseline ([`tcfft::large::BaselineFourStep`]) is the pre-PR
//! path: one sequence per call, element-wise gather/scatter transposes
//! and a full N1 x N2 `C64` twiddle table recomputed every call. The
//! engine ([`tcfft::large::FourStepPlan`]) batches the whole request,
//! runs tiled transposes with a cached flat twiddle table, and chunks
//! host-side steps over the worker pool. Before/after medians merge
//! into `BENCH_interp.json` (entry `fourstep_tc_n1048576_b8_fwd`) and
//! `tcfft bench-validate` checks them in CI.
//!
//!     cargo bench --bench large_fourstep
//!     TCFFT_BENCH_SMOKE=1 cargo bench --bench large_fourstep   # CI smoke

use tcfft::bench_harness::{bench, bench_entry, header, smoke, update_bench_json};
use tcfft::error::relative_rmse;
use tcfft::fft::radix2;
use tcfft::hp::complex::widen;
use tcfft::hp::C32;
use tcfft::large::{BaselineFourStep, FourStepConfig, FourStepPlan};
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::util::table::Table;
use tcfft::workload::random_signal;

const LOG2N: usize = 20;
const BATCH: usize = 8;
/// Headline host-side thread count recorded in BENCH_interp.json
/// (matches the fig4_1d/fig7_batch engine entries).
const ENGINE_THREADS: usize = 4;

fn main() -> tcfft::error::Result<()> {
    header("Four-step large FFT: batched engine vs per-sequence baseline");
    let n = 1usize << LOG2N;
    // the shape IS the acceptance headline, so smoke mode caps
    // iterations but never shrinks it
    let iters = if smoke() { 2 } else { 5 };
    let rt = Runtime::load_default()?;

    let baseline = BaselineFourStep::new(&rt, n, "tc", false)?;
    let serial = FourStepPlan::with_config(
        &rt,
        n,
        false,
        FourStepConfig { threads: 1, ..FourStepConfig::default() },
    )?;
    let parallel = FourStepPlan::with_config(
        &rt,
        n,
        false,
        FourStepConfig { threads: ENGINE_THREADS, ..FourStepConfig::default() },
    )?;
    println!(
        "n = 2^{LOG2N}, batch {BATCH}: baseline {} x {}, engine {}",
        baseline.n1,
        baseline.n2,
        parallel.describe()
    );

    let x: Vec<C32> = (0..BATCH)
        .flat_map(|i| random_signal(n, 0x4A + i as u64))
        .collect();
    let seqs: Vec<Vec<C32>> = (0..BATCH).map(|i| x[i * n..(i + 1) * n].to_vec()).collect();
    let input = PlanarBatch::from_complex(&x, vec![BATCH, n]);

    // correctness gate before timing: engine row 0 vs the f64 oracle
    let out = parallel.execute_batch(&rt, input.clone())?;
    let q = PlanarBatch::from_complex(&seqs[0], vec![1, n]).quantize_f16();
    let want = radix2::fft_vec(&widen(&q.to_complex()), false);
    let got = widen(&out.slice_rows(0, 1).to_complex());
    let err = relative_rmse(&want, &got);
    tcfft::ensure!(err < 5e-3, "four-step engine rel-RMSE {err:.3e} over 5e-3");
    println!("engine vs radix2 oracle (row 0): rel-RMSE {err:.3e}\n");

    let r_ref = bench(
        &format!("baseline per-seq x{BATCH}"),
        || {
            for s in &seqs {
                baseline.execute(&rt, s).unwrap();
            }
        },
        iters,
    );
    let r_ser = bench(
        "engine batched 1t",
        || {
            serial.execute_batch(&rt, input.clone()).unwrap();
        },
        iters,
    );
    let r_par = bench(
        &format!("engine batched {ENGINE_THREADS}t"),
        || {
            parallel.execute_batch(&rt, input.clone()).unwrap();
        },
        iters,
    );
    let (m_ref, m_ser, m_par) =
        (r_ref.summary.median(), r_ser.summary.median(), r_par.summary.median());

    let key = format!("fourstep_tc_n{n}_b{BATCH}_fwd");
    let mut t = Table::new(&["key", "baseline ms", "engine 1t ms", "engine 4t ms", "speedup"]);
    t.row(vec![
        key.clone(),
        format!("{:.1}", m_ref * 1e3),
        format!("{:.1}", m_ser * 1e3),
        format!("{:.1}", m_par * 1e3),
        format!("{:.2}x", m_ref / m_par),
    ]);
    let entries = vec![(
        key,
        bench_entry("large_fourstep", ENGINE_THREADS, r_par.summary.len(), m_ref, m_ser, m_par),
    )];
    let path = update_bench_json(&entries)?;
    println!(
        "batched engine vs per-sequence baseline (recorded in {}):\n{}",
        path.display(),
        t.render()
    );
    println!("large_fourstep: OK");
    Ok(())
}
