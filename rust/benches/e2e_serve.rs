//! Bench: end-to-end service overhead — the L3 coordinator must not be
//! the bottleneck (DESIGN.md Perf L3 target: <= 10% overhead over raw
//! executable wall-clock at matched batch size) — plus the sustained
//! 64-concurrent-client run through the sharded router, recorded into
//! `BENCH_interp.json` as `e2e_serve_tc_n4096_c64` (required by
//! `tcfft bench-validate`).
//!
//!     cargo bench --bench e2e_serve

use std::sync::Arc;
use std::time::{Duration, Instant};

use tcfft::bench_harness::{bench_entry, header, smoke, update_bench_json};
use tcfft::coordinator::{FftRequest, FftService, Op, ServiceConfig};
use tcfft::plan::Direction;
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::util::stats::Summary;
use tcfft::workload::random_signal;

// 4096-point transforms: realistic per-batch device time (~0.7 ms on
// this substrate) against which fixed per-batch coordination costs
// (~100-140 us: two thread hand-offs + reply channels) must amortize.
const N: usize = 4096;
const REQS: usize = 64;

fn main() -> tcfft::error::Result<()> {
    header("E2E serving: coordinator overhead + batched throughput");
    let rt = Arc::new(Runtime::load_default()?);
    let key = "fft1d_tc_n4096_b4_fwd";
    rt.warm(key)?;

    // raw path: batch-4 executions, batches to cover REQS sequences
    let x: Vec<_> = (0..4).flat_map(|b| random_signal(N, b as u64)).collect();
    let input = PlanarBatch::from_complex(&x, vec![4, N]);
    rt.execute(key, input.clone())?;
    let t0 = Instant::now();
    for _ in 0..REQS / 4 {
        rt.execute(key, input.clone())?;
    }
    let raw = t0.elapsed().as_secs_f64();
    println!("raw runtime path : {REQS} seqs in {:.1} ms", raw * 1e3);

    // service path: same sequences as individual requests, batched by
    // the coordinator (saturating submit -> batches fill to 4)
    // long deadline: this bench measures pure coordination overhead at
    // full batches; short deadlines trade efficiency for latency SLOs
    // (that trade-off is exercised by examples/serve_demo instead)
    let svc = Arc::new(FftService::start(
        Arc::clone(&rt),
        ServiceConfig {
            max_wait: Duration::from_millis(500),
            ..ServiceConfig::default()
        },
    ));
    // pre-generate all request payloads OUTSIDE the timed region
    let payloads: Vec<PlanarBatch> = (0..REQS)
        .map(|i| PlanarBatch::from_complex(&random_signal(N, 100 + i as u64), vec![N]))
        .collect();
    // warm the service path once (first-touch page faults, lazy inits)
    for input in payloads.iter().take(8).cloned() {
        svc.submit(FftRequest {
            op: Op::Fft1d { n: N },
            algo: "tc".into(),
            direction: Direction::Forward,
            input,
        })?
        .wait()?;
    }
    let mut lat = Summary::new();
    let t0 = Instant::now();
    let mut tickets = Vec::new();
    for input in payloads {
        tickets.push((
            Instant::now(),
            svc.submit(FftRequest {
                op: Op::Fft1d { n: N },
                algo: "tc".into(),
                direction: Direction::Forward,
                input,
            })?,
        ));
    }
    for (t_sub, ticket) in tickets {
        ticket.wait()?;
        lat.add(t_sub.elapsed().as_secs_f64());
    }
    let served = t0.elapsed().as_secs_f64();
    let m = svc.metrics();
    println!(
        "service path     : {REQS} seqs in {:.1} ms (p50 latency {:.2} ms, padding {:.0}%)",
        served * 1e3,
        lat.median() * 1e3,
        m.padding_ratio() * 100.0
    );
    let overhead = served / raw - 1.0;
    println!(
        "coordinator overhead vs raw (4096-pt, fixed costs visible): {:+.1}%",
        overhead * 100.0
    );
    println!("metrics: {}", m.snapshot().to_string());
    svc.shutdown();

    // --- sustained concurrency: 64 closed-loop clients through the
    // sharded router, every request tagged with its client id (the
    // admission-quota key). This is the recorded serving entry:
    // reference = raw batch-4 executions per sequence, serial = the
    // one-thread saturating service path above, engine = the
    // 64-client run.
    let clients = 64usize;
    let per_client = if smoke() { 4 } else { 16 };
    let svc64 = Arc::new(FftService::start(
        Arc::clone(&rt),
        ServiceConfig {
            max_wait: Duration::from_millis(2),
            ..ServiceConfig::default()
        },
    ));
    // warm the service path (plan cache + first batches)
    for i in 0..8 {
        svc64
            .submit(FftRequest {
                op: Op::Fft1d { n: N },
                algo: "tc".into(),
                direction: Direction::Forward,
                input: PlanarBatch::from_complex(&random_signal(N, 900 + i), vec![N]),
            })?
            .wait()?;
    }
    let t0 = Instant::now();
    let workers: Vec<_> = (0..clients as u64)
        .map(|c| {
            let svc = Arc::clone(&svc64);
            std::thread::spawn(move || {
                for i in 0..per_client {
                    let sig = random_signal(N, c * 1000 + i as u64);
                    svc.submit_as(
                        c,
                        FftRequest {
                            op: Op::Fft1d { n: N },
                            algo: "tc".into(),
                            direction: Direction::Forward,
                            input: PlanarBatch::from_complex(&sig, vec![N]),
                        },
                    )
                    .unwrap()
                    .wait()
                    .unwrap();
                }
            })
        })
        .collect();
    for w in workers {
        w.join().expect("client thread panicked");
    }
    let wall64 = t0.elapsed().as_secs_f64();
    let total = (clients * per_client) as f64;
    let m64 = svc64.metrics();
    let snap = m64.snapshot();
    assert_eq!(
        snap.get("completed").and_then(|v| v.as_f64()),
        Some(total + 8.0),
        "every request must complete"
    );
    assert_eq!(snap.get("failed").and_then(|v| v.as_f64()), Some(0.0));
    println!(
        "64-client path   : {:.0} seqs in {:.1} ms ({:.0} seq/s, {} stolen batches)",
        total,
        wall64 * 1e3,
        total / wall64,
        snap.get("stolen_batches").and_then(|v| v.as_f64()).unwrap_or(0.0)
    );
    svc64.shutdown();

    // recorded entry: per-sequence medians so the speedup column reads
    // as raw-vs-served throughput at 64 clients
    let raw_per_seq = raw / REQS as f64;
    let serial_per_seq = served / REQS as f64;
    let c64_per_seq = wall64 / total;
    let path = update_bench_json(&[(
        "e2e_serve_tc_n4096_c64".to_string(),
        bench_entry(
            "e2e_serve",
            clients,
            total as usize,
            raw_per_seq,
            serial_per_seq,
            c64_per_seq,
        ),
    )])
    .map_err(|e| tcfft::error::TcFftError::msg(format!("writing bench json: {e}")))?;
    println!("recorded e2e_serve_tc_n4096_c64 -> {}", path.display());

    if smoke() {
        // the 65536-pt amortization section is minutes of interpreter
        // time; CI proves the serving path + JSON entry above instead
        println!("e2e_serve: OK (smoke)");
        return Ok(());
    }

    // --- amortization check at production transform size (65536-pt):
    // the DESIGN.md L3 target is "not the bottleneck" where device time
    // dominates; fixed ~0.1-0.2 ms/batch costs must vanish here.
    let key_big = "fft1d_tc_n65536_b4_fwd";
    rt.warm(key_big)?;
    let nbig = 65536usize;
    // raw path over DISTINCT inputs (cache-cold, same as the service
    // sees) — reusing one warm buffer would flatter the raw side
    // best-of-2 rounds on both sides: this container's timings have
    // occasional multi-ms scheduler noise
    let mut raw_big = f64::INFINITY;
    for round in 0..2 {
        let raw_ins: Vec<PlanarBatch> = (0..4)
            .map(|i| {
                PlanarBatch::from_complex(
                    &random_signal(4 * nbig, 900 + round * 10 + i as u64),
                    vec![4, nbig],
                )
            })
            .collect();
        if round == 0 {
            rt.execute(key_big, raw_ins[0].clone())?;
        }
        let t0 = Instant::now();
        for input in raw_ins {
            rt.execute(key_big, input)?;
        }
        raw_big = raw_big.min(t0.elapsed().as_secs_f64());
    }
    let svc2 = Arc::new(FftService::start(
        Arc::clone(&rt),
        ServiceConfig {
            max_wait: Duration::from_millis(500),
            ..ServiceConfig::default()
        },
    ));
    let mut served_big = f64::INFINITY;
    for round in 0..2u64 {
        let payloads: Vec<PlanarBatch> = (0..16)
            .map(|i| {
                PlanarBatch::from_complex(
                    &random_signal(nbig, 7 + round * 100 + i as u64),
                    vec![nbig],
                )
            })
            .collect();
        let t0 = Instant::now();
        let tickets: Vec<_> = payloads
            .into_iter()
            .map(|input| {
                svc2.submit(FftRequest {
                    op: Op::Fft1d { n: nbig },
                    algo: "tc".into(),
                    direction: Direction::Forward,
                    input,
                })
                .unwrap()
            })
            .collect();
        for t in tickets {
            t.wait()?;
        }
        served_big = served_big.min(t0.elapsed().as_secs_f64());
    }
    println!(
        "  raw {:.1} ms | served {:.1} ms | svc2 metrics: {}",
        raw_big * 1e3,
        served_big * 1e3,
        svc2.metrics().snapshot().to_string()
    );
    svc2.shutdown();
    let overhead_big = served_big / raw_big - 1.0;
    println!(
        "coordinator overhead vs raw (65536-pt, amortized): {:+.1}%",
        overhead_big * 100.0
    );
    // typical measurement: -5%..+6% (coordination fully amortized);
    // the threshold leaves room for this container's scheduler noise
    assert!(
        overhead_big < 0.25,
        "amortized coordinator overhead {:.0}% too high",
        overhead_big * 100.0
    );
    println!("e2e_serve: OK");
    Ok(())
}
