//! Bench: regenerate paper Fig 7 — performance vs batch size.
//! Fig 7(a): 1D 131072-point; Fig 7(b): 2D 512x256.
//!
//! Model series for the GPU figure + a measured batch sweep through
//! the batch-major engine (and, for the smallest batch, the pre-PR
//! reference interpreter so the sweep contributes before/after
//! entries to `BENCH_interp.json`).
//!
//!     cargo bench --bench fig7_batch
//!     TCFFT_BENCH_SMOKE=1 cargo bench --bench fig7_batch   # CI smoke

use tcfft::bench_harness::{bench, bench_entry, header, smoke, update_bench_json};
use tcfft::perfmodel::{figures as f, GpuSpec};
use tcfft::runtime::{Backend, CpuInterpreter, PlanarBatch, ReferenceInterpreter, Runtime};
use tcfft::util::json::Json;
use tcfft::util::table::Table;
use tcfft::workload::random_signal;

const N: usize = 131072;
const ENGINE_THREADS: usize = 4;

fn main() -> tcfft::error::Result<()> {
    header("Fig 7: performance of different batch sizes");
    let v100 = GpuSpec::v100();
    let a = f::fig7a_series(&v100);
    let b = f::fig7b_series(&v100);
    println!("{}", f::render_series("Fig 7(a) model: 1D 131072-pt, V100", "TFLOPS", &a));
    println!("{}", f::render_series("Fig 7(b) model: 2D 512x256, V100", "TFLOPS", &b));

    // paper: tcFFT overtakes cuFFT at batch > 4 (1D) and ~2 (2D)
    let cross_a = a.iter().position(|p| p.speedup() > 1.0).unwrap_or(usize::MAX);
    let cross_b = b.iter().position(|p| p.speedup() > 1.0).unwrap_or(usize::MAX);
    println!(
        "model crossover batch: 1D at {} (paper ~4), 2D at {} (paper ~2)\n",
        a.get(cross_a).map(|p| p.label.as_str()).unwrap_or("-"),
        b.get(cross_b).map(|p| p.label.as_str()).unwrap_or("-"),
    );
    assert!(cross_a <= 3, "1D crossover too late");
    assert!(cross_b <= cross_a, "2D should cross at smaller batch than 1D");

    // measured: batch sweep over the synthesized catalog's variants
    // (b=4 has no artifact — the dynamic batcher covers it in serving)
    let rt = Runtime::load_default()?;
    let iters = if smoke() { 2 } else { 3 };
    let batches: &[usize] = if smoke() { &[1, 16] } else { &[1, 2, 8, 16] };
    let parallel = CpuInterpreter::with_threads(ENGINE_THREADS);
    let mut entries: Vec<(String, Json)> = Vec::new();
    let mut t = Table::new(&["batch", "median ms", "ms/seq (scaling)"]);
    for &bsz in batches {
        let key = format!("fft1d_tc_n{N}_b{bsz}_fwd");
        let meta = rt.registry.get(&key)?.clone();
        let x: Vec<_> = (0..bsz).flat_map(|i| random_signal(N, i as u64)).collect();
        let input = PlanarBatch::from_complex(&x, vec![bsz, N]);
        parallel.execute(&meta, input.clone())?; // warm
        let r = bench(
            &key,
            || {
                parallel.execute(&meta, input.clone()).unwrap();
            },
            iters,
        );
        let med = r.summary.median();
        t.row(vec![
            bsz.to_string(),
            format!("{:.1}", med * 1e3),
            format!("{:.1}", med * 1e3 / bsz as f64),
        ]);

        if bsz == 1 {
            // before/after entry at the cheapest sweep point: the
            // row-major reference is too slow to sweep every batch
            let reference = ReferenceInterpreter::new();
            let serial = CpuInterpreter::with_threads(1);
            reference.execute(&meta, input.clone())?;
            serial.execute(&meta, input.clone())?;
            let r_ref = bench(
                &format!("{key} reference"),
                || {
                    reference.execute(&meta, input.clone()).unwrap();
                },
                iters,
            );
            let r_ser = bench(
                &format!("{key} engine 1t"),
                || {
                    serial.execute(&meta, input.clone()).unwrap();
                },
                iters,
            );
            entries.push((
                key,
                bench_entry(
                    "fig7_batch",
                    ENGINE_THREADS,
                    r.summary.len(),
                    r_ref.summary.median(),
                    r_ser.summary.median(),
                    med,
                ),
            ));
        } else {
            // engine-only scaling point (no before/after: the pre-PR
            // reference is too slow to sweep at every batch size)
            entries.push((
                key,
                Json::obj(vec![
                    ("bench", Json::str("fig7_batch")),
                    ("threads", Json::num(ENGINE_THREADS as f64)),
                    ("iters", Json::num(r.summary.len() as f64)),
                    ("engine_median_s", Json::num(med)),
                    ("engine_median_s_per_seq", Json::num(med / bsz as f64)),
                    ("smoke", Json::Bool(smoke())),
                ]),
            ));
        }
    }
    let path = update_bench_json(&entries)?;
    println!(
        "measured 1D {N}-pt batch sweep (engine, {ENGINE_THREADS} threads; JSON: {}):\n{}",
        path.display(),
        t.render()
    );
    println!("fig7_batch: OK");
    Ok(())
}
