//! Bench: regenerate paper Fig 7 — performance vs batch size.
//! Fig 7(a): 1D 131072-point; Fig 7(b): 2D 512x256.
//!
//! Model series for the GPU figure + measured batch-sweep artifacts on
//! the CPU substrate (real batched executions through the runtime).
//!
//!     cargo bench --bench fig7_batch

use tcfft::bench_harness::{bench, header};
use tcfft::perfmodel::{figures as f, GpuSpec};
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::util::table::Table;
use tcfft::workload::random_signal;

fn main() -> tcfft::error::Result<()> {
    header("Fig 7: performance of different batch sizes");
    let v100 = GpuSpec::v100();
    let a = f::fig7a_series(&v100);
    let b = f::fig7b_series(&v100);
    println!("{}", f::render_series("Fig 7(a) model: 1D 131072-pt, V100", "TFLOPS", &a));
    println!("{}", f::render_series("Fig 7(b) model: 2D 512x256, V100", "TFLOPS", &b));

    // paper: tcFFT overtakes cuFFT at batch > 4 (1D) and ~2 (2D)
    let cross_a = a.iter().position(|p| p.speedup() > 1.0).unwrap_or(usize::MAX);
    let cross_b = b.iter().position(|p| p.speedup() > 1.0).unwrap_or(usize::MAX);
    println!(
        "model crossover batch: 1D at {} (paper ~4), 2D at {} (paper ~2)\n",
        a.get(cross_a).map(|p| p.label.as_str()).unwrap_or("-"),
        b.get(cross_b).map(|p| p.label.as_str()).unwrap_or("-"),
    );
    assert!(cross_a <= 3, "1D crossover too late");
    assert!(cross_b <= cross_a, "2D should cross at smaller batch than 1D");

    // measured: batch sweep over the real artifacts (CPU substrate)
    let rt = Runtime::load_default()?;
    let mut t = Table::new(&["batch", "median ms", "ms/seq (scaling)"]);
    for bsz in [1usize, 2, 4, 8, 16] {
        let key = format!("fft1d_tc_n131072_b{bsz}_fwd");
        let meta = rt.registry.get(&key)?.clone();
        let x: Vec<_> = (0..bsz)
            .flat_map(|i| random_signal(131072, i as u64))
            .collect();
        let input = PlanarBatch::from_complex(&x, vec![bsz, 131072]);
        rt.execute(&key, input.clone())?; // warm
        let r = bench(&key, || {
            rt.execute(&key, input.clone()).unwrap();
        }, 3);
        let med = r.summary.median();
        t.row(vec![
            bsz.to_string(),
            format!("{:.1}", med * 1e3),
            format!("{:.1}", med * 1e3 / bsz as f64),
        ]);
        let _ = meta;
    }
    println!("measured 1D 131072-pt batch sweep (CPU substrate):\n{}", t.render());
    println!("fig7_batch: OK");
    Ok(())
}
