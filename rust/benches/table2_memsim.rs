//! Bench: regenerate paper Table 2 — achievable global-memory
//! bandwidth vs continuous size on V100 (memsim model vs paper rows).
//!
//!     cargo bench --bench table2_memsim

use tcfft::bench_harness::header;

fn main() {
    header("Table 2: achievable bandwidth vs continuous size");
    println!("{}", tcfft::memsim::table2::render());

    // calibration quality summary
    let (_, err) = tcfft::memsim::calibrate(tcfft::memsim::MemModel::v100());
    println!("max per-row deviation after calibration: {:.1}%", err * 100.0);
    assert!(err < 0.20, "model drifted from Table 2");
    println!("table2_memsim: OK");
}
