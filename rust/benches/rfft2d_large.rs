//! Bench: the large-2D `Plan2d` composition (the service's `rfft2d`
//! route beyond the 256x256 catalog ladder) vs a per-sequence
//! reference composed from [`tcfft::large::BaselineFourStep`], at the
//! acceptance shape 2048 x 2048, batch 4.
//!
//! The reference is what the large-2D route replaces: per image,
//! promote each real row to complex and run a ny-point per-sequence
//! baseline four-step keeping the `ny/2 + 1` packed bins, then gather
//! each packed bin column and run an nx-point baseline — element-wise
//! gather/scatter and a twiddle table recomputed every call. The
//! engine ([`tcfft::large::Plan2d`]) runs the batched row engine once
//! over all `b * nx` rows and cache-blocked panel column passes.
//! Medians merge into `BENCH_interp.json` (entry
//! `rfft2d_tc_nx2048x2048_b4_fwd`, fields: `reference_median_s` =
//! baseline composition, `engine_median_s` = Plan2d) and
//! `tcfft bench-validate` checks them in CI.
//!
//!     cargo bench --bench rfft2d_large
//!     TCFFT_BENCH_SMOKE=1 cargo bench --bench rfft2d_large   # CI smoke

use tcfft::bench_harness::{bench, bench_entry, header, smoke, update_bench_json};
use tcfft::error::relative_rmse;
use tcfft::hp::complex::widen;
use tcfft::hp::{C32, C64};
use tcfft::large::{BaselineFourStep, FourStepConfig, Plan2d};
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::util::table::Table;
use tcfft::workload::random_signal;

const NX: usize = 2048;
const NY: usize = 2048;
const BATCH: usize = 4;
/// Headline host-side thread count recorded in BENCH_interp.json
/// (matches the fig4_1d/fig7_batch/large_fourstep/rfft_2d entries).
const ENGINE_THREADS: usize = 4;

/// Per-sequence baseline 2D R2C of one real image: ny-point baseline
/// rows into packed bins, then nx-point baseline bin columns.
fn baseline_rfft2d(
    rt: &Runtime,
    rows: &BaselineFourStep,
    cols: &BaselineFourStep,
    img: &[f32],
) -> Vec<C32> {
    let bins = NY / 2 + 1;
    let mut packed = vec![C32::new(0.0, 0.0); NX * bins];
    let mut row = vec![C32::new(0.0, 0.0); NY];
    for r in 0..NX {
        for c in 0..NY {
            row[c] = C32::new(img[r * NY + c], 0.0);
        }
        let spec = rows.execute(rt, &row).unwrap();
        packed[r * bins..(r + 1) * bins].copy_from_slice(&spec[..bins]);
    }
    let mut col = vec![C32::new(0.0, 0.0); NX];
    for c in 0..bins {
        for r in 0..NX {
            col[r] = packed[r * bins + c];
        }
        let spec = cols.execute(rt, &col).unwrap();
        for r in 0..NX {
            packed[r * bins + c] = spec[r];
        }
    }
    packed
}

fn main() -> tcfft::error::Result<()> {
    header("Large-2D rfft2d: Plan2d composition vs per-sequence baseline");
    // the shape IS the acceptance headline, so smoke mode caps
    // iterations but never shrinks it; the baseline composition is
    // ~nx + ny/2 per-sequence calls per image, so it gets fewer iters
    let iters = if smoke() { 2 } else { 5 };
    let ref_iters = if smoke() { 1 } else { 3 };
    let rt = Runtime::load_default()?;

    let base_rows = BaselineFourStep::new(&rt, NY, "tc", false)?;
    let base_cols = BaselineFourStep::new(&rt, NX, "tc", false)?;
    let serial = Plan2d::with_config(
        &rt,
        NX,
        NY,
        false,
        FourStepConfig { threads: 1, ..FourStepConfig::default() },
    )?;
    let parallel = Plan2d::with_config(
        &rt,
        NX,
        NY,
        false,
        FourStepConfig { threads: ENGINE_THREADS, ..FourStepConfig::default() },
    )?;
    println!("{NX}x{NY}, batch {BATCH}: engine {}", parallel.describe());

    let sig: Vec<f32> = (0..BATCH)
        .flat_map(|b| random_signal(NX * NY, 0x2D20 + b as u64))
        .map(|c| c.re)
        .collect();
    let input = PlanarBatch::from_real(&sig, vec![BATCH, NX, NY]);

    // correctness gate before timing: engine field 0 vs the f64 oracle
    let bins = NY / 2 + 1;
    let out = parallel.execute_batch(&rt, input.clone())?;
    let q = input.slice_rows(0, 1).quantize_f16();
    let qc = widen(&q.to_complex());
    let want_full = tcfft::fft::oracle2d(&qc, NX, NY, false);
    let want: Vec<C64> = (0..NX)
        .flat_map(|r| want_full[r * NY..r * NY + bins].to_vec())
        .collect();
    let got = widen(&out.to_complex()[..NX * bins]);
    let err = relative_rmse(&want, &got);
    tcfft::ensure!(err < 5e-3, "large-2D engine rel-RMSE {err:.3e} over 5e-3");
    println!("engine vs 2D oracle (field 0, packed bins): rel-RMSE {err:.3e}\n");

    let r_ref = bench(
        &format!("baseline composed x{BATCH}"),
        || {
            for b in 0..BATCH {
                baseline_rfft2d(&rt, &base_rows, &base_cols, &sig[b * NX * NY..(b + 1) * NX * NY]);
            }
        },
        ref_iters,
    );
    let r_ser = bench(
        "Plan2d batched 1t",
        || {
            serial.execute_batch(&rt, input.clone()).unwrap();
        },
        iters,
    );
    let r_par = bench(
        &format!("Plan2d batched {ENGINE_THREADS}t"),
        || {
            parallel.execute_batch(&rt, input.clone()).unwrap();
        },
        iters,
    );
    let (m_ref, m_ser, m_par) =
        (r_ref.summary.median(), r_ser.summary.median(), r_par.summary.median());

    let key = format!("rfft2d_tc_nx{NX}x{NY}_b{BATCH}_fwd");
    let mut t = Table::new(&["key", "baseline ms", "engine 1t ms", "engine 4t ms", "speedup"]);
    t.row(vec![
        key.clone(),
        format!("{:.1}", m_ref * 1e3),
        format!("{:.1}", m_ser * 1e3),
        format!("{:.1}", m_par * 1e3),
        format!("{:.2}x", m_ref / m_par),
    ]);
    let entries = vec![(
        key,
        bench_entry("rfft2d_large", ENGINE_THREADS, r_par.summary.len(), m_ref, m_ser, m_par),
    )];
    let path = update_bench_json(&entries)?;
    println!(
        "Plan2d composition vs per-sequence baseline (recorded in {}):\n{}",
        path.display(),
        t.render()
    );
    println!("rfft2d_large: OK");
    Ok(())
}
