//! Bench: the paper's Sec 5.4 "Optimized TC" ablation — the benefit of
//! fragment-level twiddle/complex-split fusion (paper: 1.15x-1.32x).
//!
//! Two views:
//!  1. MEASURED: tc vs tc_split artifacts on the CPU substrate.  The
//!     tc_split variant de-fuses every radix-16 merge into a twiddle
//!     kernel + a matmul kernel (extra HBM round trips) and disables
//!     stage fusion — the structural analogue of the paper's
//!     shared-memory fallback.
//!  2. MODEL: the compute-penalty ablation on the V100 roofline.
//!
//!     cargo bench --bench ablation_tc_opt

use tcfft::bench_harness::{bench, header};
use tcfft::perfmodel::{model_fft1d, Algo, GpuSpec};
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::util::table::Table;
use tcfft::workload::random_signal;

fn main() -> tcfft::error::Result<()> {
    header("Sec 5.4 ablation: Optimized TC (fragment-level fusion)");

    // measured part
    let rt = Runtime::load_default()?;
    let mut t = Table::new(&["n", "tc ms", "tc_split ms", "split/tc", "paper band"]);
    let mut ratios = Vec::new();
    for n in [4096usize, 65536] {
        let mut med = Vec::new();
        for algo in ["tc", "tc_split"] {
            let key = format!("fft1d_{algo}_n{n}_b4_fwd");
            let x: Vec<_> = (0..4).flat_map(|b| random_signal(n, b as u64)).collect();
            let input = PlanarBatch::from_complex(&x, vec![4, n]);
            rt.execute(&key, input.clone())?; // warm
            let r = bench(&key, || {
                rt.execute(&key, input.clone()).unwrap();
            }, 10);
            med.push(r.summary.median());
        }
        let ratio = med[1] / med[0];
        ratios.push(ratio);
        t.row(vec![
            n.to_string(),
            format!("{:.2}", med[0] * 1e3),
            format!("{:.2}", med[1] * 1e3),
            format!("{ratio:.2}x"),
            "1.15x-1.32x".into(),
        ]);
    }
    println!("measured (CPU substrate):\n{}", t.render());
    assert!(
        ratios.iter().all(|&r| r > 1.0),
        "split variant must be slower: {ratios:?}"
    );

    // model part
    let gpu = GpuSpec::v100();
    let mut tm = Table::new(&["n", "model split/tc", "paper band"]);
    for t2 in [14usize, 16, 20, 24] {
        let n = 1usize << t2;
        let b = ((1usize << 24) / n).max(1);
        let tc = model_fft1d(&gpu, Algo::TcFft, n, b).seconds;
        let un = model_fft1d(&gpu, Algo::TcFftUnopt, n, b).seconds;
        tm.row(vec![
            format!("2^{t2}"),
            format!("{:.2}x", un / tc),
            "1.15x-1.32x".into(),
        ]);
    }
    println!("modelled (V100 roofline):\n{}", tm.render());
    println!("ablation_tc_opt: OK");
    Ok(())
}
