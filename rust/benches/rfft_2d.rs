//! Bench: the real-input 2D (R2C) path vs the same-shape complex
//! (C2C) 2D transform on identical real fields — the acceptance
//! evidence that real images get their ~2x back in two dimensions.
//!
//! The "before" series is what a real-image caller had to do without
//! the 2D R2C path: promote to complex (im = 0) and run the full
//! nx x ny C2C engine. The "after" series is the rfft2d path: row-wise
//! half-size real transforms into packed Hermitian rows, then complex
//! column transforms over the `ny/2 + 1` bins. Medians merge into
//! `BENCH_interp.json` (entry `rfft2d_tc_nx256x256_b8_fwd`, fields:
//! `reference_median_s` = C2C, `engine_median_s` = R2C) and
//! `tcfft bench-validate` checks them in CI. See BENCHMARKS.md for the
//! schema.
//!
//!     cargo bench --bench rfft_2d
//!     TCFFT_BENCH_SMOKE=1 cargo bench --bench rfft_2d   # CI smoke

use tcfft::bench_harness::{bench, bench_entry, header, smoke, update_bench_json};
use tcfft::error::relative_rmse;
use tcfft::hp::C64;
use tcfft::runtime::{Backend, CpuInterpreter, PlanarBatch, VariantMeta};
use tcfft::util::table::Table;
use tcfft::workload::random_signal;

const NX: usize = 256;
const NY: usize = 256;
const BATCH: usize = 8;
/// Headline thread count recorded in BENCH_interp.json (matches the
/// fig4_1d/fig7_batch/large_fourstep/rfft_1d entries).
const ENGINE_THREADS: usize = 4;

/// Bench-local variant descriptor (the synthesized catalog carries the
/// b=4 serving tiers; the bench compares engines at the headline batch
/// without perturbing the registry's tier selection — see rfft_1d).
fn bench_meta(op: &str, key: &str) -> VariantMeta {
    VariantMeta {
        key: key.to_string(),
        file: std::path::PathBuf::new(),
        op: op.to_string(),
        algo: "tc".to_string(),
        n: 0,
        nx: NX,
        ny: NY,
        batch: BATCH,
        inverse: false,
        // forward input is [b, nx, ny] real fields on both paths
        input_shape: vec![BATCH, NX, NY],
        stages: Vec::new(),
        flops_per_seq: 0.0,
        hbm_bytes_per_seq: 0.0,
        radix2_equiv_flops: 0.0,
    }
}

fn main() -> tcfft::error::Result<()> {
    header("Real-input 2D R2C vs same-shape complex C2C");
    let iters = if smoke() { 3 } else { 12 };

    let c2c_meta = bench_meta("fft2d", "bench_fft2d_tc_nx256x256_b8_fwd");
    let r2c_meta = bench_meta("rfft2d", "bench_rfft2d_tc_nx256x256_b8_fwd");

    // the same real fields drive both paths: C2C sees them promoted to
    // complex (im = 0), R2C consumes the re plane directly
    let sig: Vec<f32> = (0..BATCH)
        .flat_map(|b| random_signal(NX * NY, 0x2D + b as u64))
        .map(|c| c.re)
        .collect();
    let input = PlanarBatch::from_real(&sig, vec![BATCH, NX, NY]);

    let c2c = CpuInterpreter::with_threads(ENGINE_THREADS);
    let r2c_serial = CpuInterpreter::with_threads(1);
    let r2c = CpuInterpreter::with_threads(ENGINE_THREADS);
    c2c.execute(&c2c_meta, input.clone())?; // warm all three
    r2c_serial.execute(&r2c_meta, input.clone())?;
    let (packed, _) = r2c.execute(&r2c_meta, input.clone())?;

    // correctness gate before timing: packed field 0 vs the f64 oracle
    let bins = NY / 2 + 1;
    let q = input.slice_rows(0, 1).quantize_f16();
    let qc: Vec<C64> = q
        .to_complex()
        .iter()
        .map(|c| C64::new(c.re as f64, c.im as f64))
        .collect();
    let want_full = tcfft::fft::oracle2d(&qc, NX, NY, false);
    let want: Vec<C64> = (0..NX)
        .flat_map(|r| want_full[r * NY..r * NY + bins].to_vec())
        .collect();
    let got: Vec<C64> = packed.to_complex()[..NX * bins]
        .iter()
        .map(|c| C64::new(c.re as f64, c.im as f64))
        .collect();
    let err = relative_rmse(&want, &got);
    tcfft::ensure!(err < 5e-3, "2D R2C rel-RMSE {err:.3e} over 5e-3");
    println!("2D R2C vs radix2 oracle (field 0, packed bins): rel-RMSE {err:.3e}\n");

    let r_c2c = bench(
        &format!("C2C {NX}x{NY} b={BATCH} {ENGINE_THREADS}t"),
        || {
            c2c.execute(&c2c_meta, input.clone()).unwrap();
        },
        iters,
    );
    let r_ser = bench(
        &format!("R2C {NX}x{NY} b={BATCH} 1t"),
        || {
            r2c_serial.execute(&r2c_meta, input.clone()).unwrap();
        },
        iters,
    );
    let r_par = bench(
        &format!("R2C {NX}x{NY} b={BATCH} {ENGINE_THREADS}t"),
        || {
            r2c.execute(&r2c_meta, input.clone()).unwrap();
        },
        iters,
    );
    let (m_c2c, m_ser, m_par) =
        (r_c2c.summary.median(), r_ser.summary.median(), r_par.summary.median());

    let key = format!("rfft2d_tc_nx{NX}x{NY}_b{BATCH}_fwd");
    let mut t = Table::new(&["key", "C2C ms", "R2C 1t ms", "R2C 4t ms", "R2C speedup"]);
    t.row(vec![
        key.clone(),
        format!("{:.2}", m_c2c * 1e3),
        format!("{:.2}", m_ser * 1e3),
        format!("{:.2}", m_par * 1e3),
        format!("{:.2}x", m_c2c / m_par),
    ]);
    let entries = vec![(
        key,
        bench_entry("rfft_2d", ENGINE_THREADS, r_par.summary.len(), m_c2c, m_ser, m_par),
    )];
    let path = update_bench_json(&entries)?;
    println!(
        "2D R2C vs same-shape C2C on real fields (recorded in {}):\n{}",
        path.display(),
        t.render()
    );
    println!("rfft_2d: OK");
    Ok(())
}
