//! Bench: regenerate paper Fig 5 — 2D FFT performance (six shapes,
//! V100 + A100 model) plus measured CPU-substrate artifacts.
//!
//!     cargo bench --bench fig5_2d

use tcfft::bench_harness::{bench, header};
use tcfft::perfmodel::{figures as f, speedup_2d, GpuSpec};
use tcfft::plan::Plan;
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::util::table::Table;
use tcfft::workload::random_signal;

fn main() -> tcfft::error::Result<()> {
    header("Fig 5: 2D FFT performance of different sizes");

    let v100 = GpuSpec::v100();
    let a100 = GpuSpec::a100();
    println!("{}", f::render_series("Fig 5(a) model: V100", "TFLOPS", &f::fig5_series(&v100)));
    println!("{}", f::render_series("Fig 5(b) model: A100", "TFLOPS", &f::fig5_series(&a100)));
    println!(
        "model: V100 512-row speedup {:.2}x (paper 3.24x) vs 256-row {:.2}x (paper 1.29x)",
        speedup_2d(&v100, 512, 256, 128),
        speedup_2d(&v100, 256, 256, 256),
    );
    println!(
        "model: A100 512-row speedup {:.2}x (paper 3.03x)\n",
        speedup_2d(&a100, 512, 256, 128),
    );

    // measured artifacts (CPU substrate)
    let rt = Runtime::load_default()?;
    let mut t = Table::new(&["shape", "algo", "median ms"]);
    for (key, label) in [
        ("fft2d_tc_nx128x128_b2_fwd", "128x128 tc"),
        ("fft2d_tc_nx256x256_b2_fwd", "256x256 tc"),
        ("fft2d_r2_nx256x256_b2_fwd", "256x256 r2"),
        ("fft2d_tc_nx256x512_b2_fwd", "256x512 tc"),
        ("fft2d_tc_nx512x256_b2_fwd", "512x256 tc"),
        ("fft2d_r2_nx512x256_b2_fwd", "512x256 r2"),
        ("fft2d_tc_nx512x512_b2_fwd", "512x512 tc"),
    ] {
        let meta = rt.registry.get(key)?.clone();
        let x: Vec<_> = (0..meta.batch)
            .flat_map(|b| random_signal(meta.nx * meta.ny, b as u64))
            .collect();
        let input = PlanarBatch::from_complex(&x, vec![meta.batch, meta.nx, meta.ny]);
        rt.execute(key, input.clone())?; // warm
        let r = bench(label, || {
            rt.execute(key, input.clone()).unwrap();
        }, 10);
        t.row(vec![
            format!("{}x{}", meta.nx, meta.ny),
            meta.algo.clone(),
            format!("{:.2}", r.summary.median() * 1e3),
        ]);
    }
    println!("measured on CPU-PJRT (interpret substrate):\n{}", t.render());
    println!("fig5_2d: OK");
    Ok(())
}

// silence unused import if Plan is optimized away by feature drift
#[allow(unused)]
fn _keep(_: Option<Plan>) {}
