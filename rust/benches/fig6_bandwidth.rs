//! Bench: regenerate paper Fig 6 — global-memory throughput of 1D and
//! 2D FFTs on V100 (modelled useful bandwidth per library).
//!
//! The paper's qualitative claims asserted here:
//!   * short 1D sizes: tcFFT close to the achievable bandwidth peak;
//!   * moderate/long 1D: tcFFT ~2x cuFFT's throughput;
//!   * 2D: cuFFT drops sharply as the first dimension grows while
//!     tcFFT "almost remains the same".
//!
//!     cargo bench --bench fig6_bandwidth

use tcfft::bench_harness::header;
use tcfft::perfmodel::{figures as f, GpuSpec};

fn main() {
    header("Fig 6: global memory bandwidth of 1D and 2D FFT (V100)");
    let v100 = GpuSpec::v100();
    let s1 = f::fig6_series_1d(&v100);
    let s2 = f::fig6_series_2d(&v100);
    println!("{}", f::render_series("Fig 6(a) model: 1D bandwidth", "GB/s", &s1));
    println!("{}", f::render_series("Fig 6(b) model: 2D bandwidth", "GB/s", &s2));

    // short sizes near achievable peak
    let peak = v100.mem.achievable_bw(32) / 1e9;
    assert!(
        s1[0].tcfft > 0.85 * peak,
        "short tcFFT bw {:.0} should be near peak {:.0}",
        s1[0].tcfft,
        peak
    );
    // moderate/long: ~2x cuFFT
    for p in s1.iter().skip(7) {
        let ratio = p.tcfft / p.cufft;
        assert!(
            (1.3..=3.5).contains(&ratio),
            "1D {} bw ratio {ratio:.2} out of band",
            p.label
        );
    }
    // 2D: tcFFT stays flat while cuFFT drops with 512 rows
    let tc_drop = s2[0].tcfft / s2[3].tcfft;
    let cu_drop = s2[0].cufft / s2[3].cufft;
    assert!(
        cu_drop > tc_drop,
        "cuFFT must degrade more: tc {tc_drop:.2} vs cu {cu_drop:.2}"
    );
    println!("short-1D tcFFT at {:.0}% of achievable peak; 2D degradation tc {tc_drop:.2}x vs cuFFT {cu_drop:.2}x",
        100.0 * s1[0].tcfft / peak);
    println!("fig6_bandwidth: OK");
}
