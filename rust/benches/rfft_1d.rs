//! Bench: the real-input (R2C) path vs the same-size complex (C2C)
//! transform on identical real signals — the acceptance evidence that
//! real workloads get their ~2x back.
//!
//! The "before" series is what a real-signal caller had to do without
//! the R2C path: promote to complex (im = 0) and run the full n-point
//! C2C engine. The "after" series is the rfft1d path: the n/2-point
//! complex engine wrapped in the fused half-spectrum split. Medians
//! merge into `BENCH_interp.json` (entry `rfft1d_tc_n4096_b32_fwd`,
//! fields: `reference_median_s` = C2C, `engine_median_s` = R2C) and
//! `tcfft bench-validate` checks them in CI. A fourth timed series
//! runs the same R2C shape on the error-corrected `tc_ec` tier — a
//! printed cost column only (the JSON-recorded tc_ec entry lives in
//! fig4_1d as `fft1d_tc_ec_n4096_b32_fwd`).
//!
//!     cargo bench --bench rfft_1d
//!     TCFFT_BENCH_SMOKE=1 cargo bench --bench rfft_1d   # CI smoke

use tcfft::bench_harness::{bench, bench_entry, header, smoke, update_bench_json};
use tcfft::error::relative_rmse;
use tcfft::fft::radix2;
use tcfft::hp::complex::widen;
use tcfft::runtime::{Backend, CpuInterpreter, PlanarBatch, VariantMeta};
use tcfft::util::table::Table;
use tcfft::workload::random_signal;

const N: usize = 4096;
const BATCH: usize = 32;
/// Headline thread count recorded in BENCH_interp.json (matches the
/// fig4_1d/fig7_batch/large_fourstep entries).
const ENGINE_THREADS: usize = 4;

/// Bench-local variant descriptor (the synthesized catalog carries the
/// b=4 serving tiers; the bench compares engines at the headline batch
/// without perturbing `find_fft1d`'s tier selection — see fig4_1d).
fn bench_meta(op: &str, algo: &str, key: &str, n: usize, batch: usize) -> VariantMeta {
    VariantMeta {
        key: key.to_string(),
        file: std::path::PathBuf::new(),
        op: op.to_string(),
        algo: algo.to_string(),
        n,
        nx: 0,
        ny: 0,
        batch,
        inverse: false,
        input_shape: vec![batch, n],
        stages: Vec::new(),
        flops_per_seq: 0.0,
        hbm_bytes_per_seq: 0.0,
        radix2_equiv_flops: 0.0,
    }
}

fn main() -> tcfft::error::Result<()> {
    header("Real-input R2C vs same-size complex C2C");
    let iters = if smoke() { 3 } else { 12 };

    let c2c_meta = bench_meta("fft1d", "tc", "bench_fft1d_tc_n4096_b32_fwd", N, BATCH);
    let r2c_meta = bench_meta("rfft1d", "tc", "bench_rfft1d_tc_n4096_b32_fwd", N, BATCH);
    let ec_meta = bench_meta("rfft1d", "tc_ec", "bench_rfft1d_tc_ec_n4096_b32_fwd", N, BATCH);

    // the same real signal drives both paths: C2C sees it promoted to
    // complex (im = 0), R2C consumes the re plane directly
    let sig: Vec<f32> = (0..BATCH)
        .flat_map(|b| random_signal(N, 0x2C + b as u64))
        .map(|c| c.re)
        .collect();
    let input = PlanarBatch::from_real(&sig, vec![BATCH, N]);

    let c2c = CpuInterpreter::with_threads(ENGINE_THREADS);
    let r2c_serial = CpuInterpreter::with_threads(1);
    let r2c = CpuInterpreter::with_threads(ENGINE_THREADS);
    c2c.execute(&c2c_meta, input.clone())?; // warm all four
    r2c_serial.execute(&r2c_meta, input.clone())?;
    r2c.execute(&ec_meta, input.clone())?;
    let (packed, _) = r2c.execute(&r2c_meta, input.clone())?;

    // correctness gate before timing: packed row 0 vs the f64 oracle
    let bins = N / 2 + 1;
    let q = input.slice_rows(0, 1).quantize_f16();
    let want = radix2::fft_vec(&widen(&q.to_complex()), false);
    let got = widen(&packed.to_complex()[..bins]);
    let err = relative_rmse(&want[..bins], &got);
    tcfft::ensure!(err < 5e-3, "R2C rel-RMSE {err:.3e} over 5e-3");
    println!("R2C vs radix2 oracle (row 0, packed bins): rel-RMSE {err:.3e}\n");

    let r_c2c = bench(
        &format!("C2C n={N} b={BATCH} {ENGINE_THREADS}t"),
        || {
            c2c.execute(&c2c_meta, input.clone()).unwrap();
        },
        iters,
    );
    let r_ser = bench(
        &format!("R2C n={N} b={BATCH} 1t"),
        || {
            r2c_serial.execute(&r2c_meta, input.clone()).unwrap();
        },
        iters,
    );
    let r_par = bench(
        &format!("R2C n={N} b={BATCH} {ENGINE_THREADS}t"),
        || {
            r2c.execute(&r2c_meta, input.clone()).unwrap();
        },
        iters,
    );
    let r_ec = bench(
        &format!("R2C ec n={N} b={BATCH} {ENGINE_THREADS}t"),
        || {
            r2c.execute(&ec_meta, input.clone()).unwrap();
        },
        iters,
    );
    let (m_c2c, m_ser, m_par, m_ec) = (
        r_c2c.summary.median(),
        r_ser.summary.median(),
        r_par.summary.median(),
        r_ec.summary.median(),
    );

    let key = format!("rfft1d_tc_n{N}_b{BATCH}_fwd");
    let mut t =
        Table::new(&["key", "C2C ms", "R2C 1t ms", "R2C 4t ms", "R2C speedup", "ec 4t ms"]);
    t.row(vec![
        key.clone(),
        format!("{:.2}", m_c2c * 1e3),
        format!("{:.2}", m_ser * 1e3),
        format!("{:.2}", m_par * 1e3),
        format!("{:.2}x", m_c2c / m_par),
        format!("{:.2}", m_ec * 1e3),
    ]);
    let entries = vec![(
        key,
        bench_entry("rfft_1d", ENGINE_THREADS, r_par.summary.len(), m_c2c, m_ser, m_par),
    )];
    let path = update_bench_json(&entries)?;
    println!(
        "R2C vs same-size C2C on real input (recorded in {}):\n{}",
        path.display(),
        t.render()
    );
    println!("rfft_1d: OK");
    Ok(())
}
