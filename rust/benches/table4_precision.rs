//! Bench: regenerate paper Table 4 — average relative error of 1D and
//! 2D half-precision FFTs, tcFFT vs the cuFFT-half stand-in, against
//! the double-precision oracle (from-scratch Rust FFT = FFTW-f64
//! stand-in).
//!
//! Paper reports (eq. 5, per-bin normalization): cuFFT-1D 1.78+-0.5%,
//! tcFFT-1D 1.76+-0.5%, cuFFT-2D 1.65+-0.1%, tcFFT-2D 1.65+-0.1% —
//! i.e. *both libraries sit at the same error level*, which is the
//! claim this bench verifies. We print both the paper-style per-bin
//! metric and the scale-normalized metric.
//!
//!     cargo bench --bench table4_precision

use tcfft::bench_harness::header;
use tcfft::fft::radix2;
use tcfft::hp::C64;
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::util::table::Table;
use tcfft::workload::random_signal;

/// Paper eq. 5: mean over bins of |ref - got| / |ref| (per-bin).
fn paper_relative_error(reference: &[C64], got: &[C64]) -> f64 {
    let mut sum = 0.0;
    let mut cnt = 0.0;
    for (r, g) in reference.iter().zip(got) {
        let m = r.abs();
        if m > 1e-6 {
            sum += (*r - *g).abs() / m;
            cnt += 1.0;
        }
    }
    sum / cnt
}

fn run_1d(rt: &Runtime, key: &str) -> tcfft::error::Result<(f64, f64)> {
    let meta = rt.registry.get(key)?.clone();
    let (n, b) = (meta.n, meta.batch);
    let x: Vec<_> = (0..b).flat_map(|i| random_signal(n, 1000 + i as u64)).collect();
    let input = PlanarBatch::from_complex(&x, vec![b, n]);
    let (out, _) = rt.execute(key, input.clone())?;
    let q = input.quantize_f16();
    let mut per_bin = 0.0;
    let mut scale_err = 0.0;
    for row in 0..b {
        let xr: Vec<C64> = q.to_complex()[row * n..(row + 1) * n]
            .iter()
            .map(|c| C64::new(c.re as f64, c.im as f64))
            .collect();
        let want = radix2::fft_vec(&xr, false);
        let got: Vec<C64> = out.to_complex()[row * n..(row + 1) * n]
            .iter()
            .map(|c| C64::new(c.re as f64, c.im as f64))
            .collect();
        per_bin += paper_relative_error(&want, &got);
        scale_err += tcfft::error::relative_error(&want, &got);
    }
    Ok((per_bin / b as f64, scale_err / b as f64))
}

fn run_2d(rt: &Runtime, key: &str) -> tcfft::error::Result<(f64, f64)> {
    let meta = rt.registry.get(key)?.clone();
    let (nx, ny, b) = (meta.nx, meta.ny, meta.batch);
    let x: Vec<_> = (0..b)
        .flat_map(|i| random_signal(nx * ny, 2000 + i as u64))
        .collect();
    let input = PlanarBatch::from_complex(&x, vec![b, nx, ny]);
    let (out, _) = rt.execute(key, input.clone())?;
    let q = input.quantize_f16();
    let mut per_bin = 0.0;
    let mut scale_err = 0.0;
    for row in 0..b {
        let mut m: Vec<C64> = q.to_complex()[row * nx * ny..(row + 1) * nx * ny]
            .iter()
            .map(|c| C64::new(c.re as f64, c.im as f64))
            .collect();
        radix2::fft2(&mut m, nx, ny, false);
        let got: Vec<C64> = out.to_complex()[row * nx * ny..(row + 1) * nx * ny]
            .iter()
            .map(|c| C64::new(c.re as f64, c.im as f64))
            .collect();
        per_bin += paper_relative_error(&m, &got);
        scale_err += tcfft::error::relative_error(&m, &got);
    }
    Ok((per_bin / b as f64, scale_err / b as f64))
}

fn main() -> tcfft::error::Result<()> {
    header("Table 4: average relative error vs double-precision oracle");
    let rt = Runtime::load_default()?;

    let mut t = Table::new(&["case", "per-bin err (paper metric)", "scale-norm err", "paper"]);
    let mut tc_1d = Vec::new();
    let mut r2_1d = Vec::new();
    for n in [256usize, 1024, 4096, 16384, 65536] {
        for algo in ["tc", "r2"] {
            let key = format!("fft1d_{algo}_n{n}_b4_fwd");
            let (pb, se) = run_1d(&rt, &key)?;
            if algo == "tc" {
                tc_1d.push(pb);
            } else {
                r2_1d.push(pb);
            }
            t.row(vec![
                format!("1D {algo} n={n}"),
                format!("{:.3}%", pb * 100.0),
                format!("{se:.2e}"),
                if algo == "tc" { "1.76%" } else { "1.78%" }.into(),
            ]);
        }
    }
    for (key, label, paper) in [
        ("fft2d_tc_nx256x256_b2_fwd", "2D tc 256x256", "1.65%"),
        ("fft2d_r2_nx256x256_b2_fwd", "2D r2 256x256", "1.65%"),
        ("fft2d_tc_nx512x256_b2_fwd", "2D tc 512x256", "1.65%"),
        ("fft2d_r2_nx512x256_b2_fwd", "2D r2 512x256", "1.65%"),
    ] {
        let (pb, se) = run_2d(&rt, key)?;
        t.row(vec![
            label.into(),
            format!("{:.3}%", pb * 100.0),
            format!("{se:.2e}"),
            paper.into(),
        ]);
    }
    println!("{}", t.render());

    // the paper's claim: both libraries sit at the same error level
    let tc: f64 = tc_1d.iter().sum::<f64>() / tc_1d.len() as f64;
    let r2: f64 = r2_1d.iter().sum::<f64>() / r2_1d.len() as f64;
    println!("1D mean per-bin error: tcFFT {:.3}%  cuFFT-like {:.3}%  ratio {:.2}", tc * 100.0, r2 * 100.0, tc / r2);
    assert!(
        (0.3..=1.5).contains(&(tc / r2)),
        "error levels should be comparable (tc may be slightly better)"
    );
    println!("table4_precision: OK");
    Ok(())
}
