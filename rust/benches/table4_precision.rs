//! Bench: regenerate paper Table 4 — average relative error of 1D and
//! 2D half-precision FFTs, tcFFT vs the cuFFT-half stand-in, against
//! the double-precision oracle (from-scratch Rust FFT = FFTW-f64
//! stand-in).
//!
//! Paper reports (eq. 5, per-bin normalization): cuFFT-1D 1.78+-0.5%,
//! tcFFT-1D 1.76+-0.5%, cuFFT-2D 1.65+-0.1%, tcFFT-2D 1.65+-0.1% —
//! i.e. *both libraries sit at the same error level*, which is the
//! claim this bench verifies. We print both the paper-style per-bin
//! metric and the scale-normalized metric.
//!
//! On top of the paper table, the `tc_ec` error-corrected tier runs
//! the same 1D ladder plus the headline n=4096 b=32 case, and the
//! measured accuracy gain over plain `tc` is recorded as the
//! `precision_tc_ec_n4096_b32` entry in `BENCH_interp.json` (the
//! before/after medians reinterpreted as rel-RMSE: reference = tc,
//! engine = tc_ec, "speedup" = accuracy gain, floor 10x).  Tiers are
//! each charged for their own marshal: `tc`/`r2` are measured against
//! the oracle of the fp16-quantized input, `tc_ec` against the raw
//! input its hi+lo marshal carries.
//!
//!     cargo bench --bench table4_precision

use tcfft::bench_harness::{bench_entry, header, update_bench_json};
use tcfft::error::relative_rmse;
use tcfft::fft::radix2;
use tcfft::hp::C64;
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::util::table::Table;
use tcfft::workload::random_signal;

/// Paper eq. 5: mean over bins of |ref - got| / |ref| (per-bin).
fn paper_relative_error(reference: &[C64], got: &[C64]) -> f64 {
    let mut sum = 0.0;
    let mut cnt = 0.0;
    for (r, g) in reference.iter().zip(got) {
        let m = r.abs();
        if m > 1e-6 {
            sum += (*r - *g).abs() / m;
            cnt += 1.0;
        }
    }
    sum / cnt
}

fn run_1d(rt: &Runtime, key: &str) -> tcfft::error::Result<(f64, f64)> {
    let meta = rt.registry.get(key)?.clone();
    let (n, b) = (meta.n, meta.batch);
    let x: Vec<_> = (0..b).flat_map(|i| random_signal(n, 1000 + i as u64)).collect();
    let input = PlanarBatch::from_complex(&x, vec![b, n]);
    let (out, _) = rt.execute(key, input.clone())?;
    // the ec marshal carries the raw input as hi+lo pairs, so that tier
    // is measured against the un-quantized oracle
    let q = if meta.algo == "tc_ec" { input } else { input.quantize_f16() };
    let mut per_bin = 0.0;
    let mut scale_err = 0.0;
    for row in 0..b {
        let xr: Vec<C64> = q.to_complex()[row * n..(row + 1) * n]
            .iter()
            .map(|c| C64::new(c.re as f64, c.im as f64))
            .collect();
        let want = radix2::fft_vec(&xr, false);
        let got: Vec<C64> = out.to_complex()[row * n..(row + 1) * n]
            .iter()
            .map(|c| C64::new(c.re as f64, c.im as f64))
            .collect();
        per_bin += paper_relative_error(&want, &got);
        scale_err += tcfft::error::relative_error(&want, &got);
    }
    Ok((per_bin / b as f64, scale_err / b as f64))
}

fn run_2d(rt: &Runtime, key: &str) -> tcfft::error::Result<(f64, f64)> {
    let meta = rt.registry.get(key)?.clone();
    let (nx, ny, b) = (meta.nx, meta.ny, meta.batch);
    let x: Vec<_> = (0..b)
        .flat_map(|i| random_signal(nx * ny, 2000 + i as u64))
        .collect();
    let input = PlanarBatch::from_complex(&x, vec![b, nx, ny]);
    let (out, _) = rt.execute(key, input.clone())?;
    let q = input.quantize_f16();
    let mut per_bin = 0.0;
    let mut scale_err = 0.0;
    for row in 0..b {
        let mut m: Vec<C64> = q.to_complex()[row * nx * ny..(row + 1) * nx * ny]
            .iter()
            .map(|c| C64::new(c.re as f64, c.im as f64))
            .collect();
        radix2::fft2(&mut m, nx, ny, false);
        let got: Vec<C64> = out.to_complex()[row * nx * ny..(row + 1) * nx * ny]
            .iter()
            .map(|c| C64::new(c.re as f64, c.im as f64))
            .collect();
        per_bin += paper_relative_error(&m, &got);
        scale_err += tcfft::error::relative_error(&m, &got);
    }
    Ok((per_bin / b as f64, scale_err / b as f64))
}

fn main() -> tcfft::error::Result<()> {
    header("Table 4: average relative error vs double-precision oracle");
    let rt = Runtime::load_default()?;

    let mut t = Table::new(&["case", "per-bin err (paper metric)", "scale-norm err", "paper"]);
    let mut tc_1d = Vec::new();
    let mut r2_1d = Vec::new();
    for n in [256usize, 1024, 4096, 16384, 65536] {
        for algo in ["tc", "r2", "tc_ec"] {
            let key = format!("fft1d_{algo}_n{n}_b4_fwd");
            let (pb, se) = run_1d(&rt, &key)?;
            match algo {
                "tc" => tc_1d.push(pb),
                "r2" => r2_1d.push(pb),
                _ => {}
            }
            t.row(vec![
                format!("1D {algo} n={n}"),
                format!("{:.3}%", pb * 100.0),
                format!("{se:.2e}"),
                match algo {
                    "tc" => "1.76%",
                    "r2" => "1.78%",
                    _ => "- (ec tier)",
                }
                .into(),
            ]);
        }
    }
    for (key, label, paper) in [
        ("fft2d_tc_nx256x256_b2_fwd", "2D tc 256x256", "1.65%"),
        ("fft2d_r2_nx256x256_b2_fwd", "2D r2 256x256", "1.65%"),
        ("fft2d_tc_nx512x256_b2_fwd", "2D tc 512x256", "1.65%"),
        ("fft2d_r2_nx512x256_b2_fwd", "2D r2 512x256", "1.65%"),
    ] {
        let (pb, se) = run_2d(&rt, key)?;
        t.row(vec![
            label.into(),
            format!("{:.3}%", pb * 100.0),
            format!("{se:.2e}"),
            paper.into(),
        ]);
    }
    println!("{}", t.render());

    // the paper's claim: both libraries sit at the same error level
    let tc: f64 = tc_1d.iter().sum::<f64>() / tc_1d.len() as f64;
    let r2: f64 = r2_1d.iter().sum::<f64>() / r2_1d.len() as f64;
    println!("1D mean per-bin error: tcFFT {:.3}%  cuFFT-like {:.3}%  ratio {:.2}", tc * 100.0, r2 * 100.0, tc / r2);
    assert!(
        (0.3..=1.5).contains(&(tc / r2)),
        "error levels should be comparable (tc may be slightly better)"
    );

    // precision-ladder headline: tc vs tc_ec at n=4096 b=32, both
    // measured against the f64 oracle of the RAW input so each tier is
    // charged for its own marshal (calibrated: tc 4.909e-4, tc_ec
    // 1.770e-7, gain 2774x; acceptance floor 10x)
    let rmse_raw = |key: &str| -> tcfft::error::Result<f64> {
        let meta = rt.registry.get(key)?.clone();
        let (n, b) = (meta.n, meta.batch);
        let x: Vec<_> = (0..b).flat_map(|i| random_signal(n, 3000 + i as u64)).collect();
        let input = PlanarBatch::from_complex(&x, vec![b, n]);
        let (out, _) = rt.execute(key, input)?;
        let mut want = Vec::with_capacity(b * n);
        for i in 0..b {
            let xr: Vec<C64> = x[i * n..(i + 1) * n]
                .iter()
                .map(|c| C64::new(c.re as f64, c.im as f64))
                .collect();
            want.extend(radix2::fft_vec(&xr, false));
        }
        let got: Vec<C64> = out
            .to_complex()
            .iter()
            .map(|c| C64::new(c.re as f64, c.im as f64))
            .collect();
        Ok(relative_rmse(&want, &got))
    };
    let tc_rmse = rmse_raw("fft1d_tc_n4096_b32_fwd")?;
    let ec_rmse = rmse_raw("fft1d_tc_ec_n4096_b32_fwd")?;
    let gain = tc_rmse / ec_rmse;
    println!(
        "precision ladder n=4096 b=32: tc {tc_rmse:.3e}  tc_ec {ec_rmse:.3e}  gain {gain:.0}x"
    );
    assert!(ec_rmse <= 1e-4, "tc_ec rmse {ec_rmse:.3e} over the 1e-4 hard bound");
    assert!(gain >= 10.0, "accuracy gain {gain:.1}x below the 10x floor");
    let path = update_bench_json(&[(
        "precision_tc_ec_n4096_b32".to_string(),
        bench_entry("precision_tc_ec_n4096_b32", 1, 1, tc_rmse, ec_rmse, ec_rmse),
    )])?;
    println!("accuracy-gain entry recorded in {}", path.display());
    println!("table4_precision: OK");
    Ok(())
}
