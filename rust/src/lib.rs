//! # tcFFT — half-precision matrix-formulated FFT (paper reproduction)
//!
//! Reproduction of *"tcFFT: Accelerating Half-Precision FFT through
//! Tensor Cores"* (Li, Cheng, Lin 2021).  Radix stages are formulated
//! as fp16 matrix multiplies with f32 accumulation — the Tensor-Core /
//! MXU mma contract — and the whole stack (planner, runtime, serving
//! coordinator) builds and runs fully offline with zero external
//! dependencies.
//!
//! `ARCHITECTURE.md` at the repo root is the anchor document: module
//! map, request data flow through the service, the canonical fp16
//! rounding-point contract, and the oracle chain.
//!
//! ## Backends
//!
//! Execution is pluggable through the [`runtime::Backend`] trait:
//!
//! * [`runtime::CpuInterpreter`] — the **default**: a pure-Rust
//!   batch-major fused stage engine that executes the planner's
//!   radix-stage schedules directly on [`runtime::PlanarBatch`] planar
//!   fp16 buffers (fp16-rounded DFT/twiddle tables, f32 accumulation,
//!   fp16 intermediate stores), parallelized across batch-row chunks
//!   (`TCFFT_THREADS`).  Needs no artifacts: when no artifact
//!   directory exists, [`runtime::Registry`] synthesizes the full
//!   variant catalog (sizes, schedules, cost metadata) in process.
//!   [`runtime::ReferenceInterpreter`] keeps the row-at-a-time
//!   baseline for equivalence tests and `BENCH_interp.json`.
//! * `runtime::Executor` — PJRT execution of AOT HLO artifacts, gated
//!   behind the non-default `pjrt` cargo feature (requires a vendored
//!   `xla` binding and `make artifacts`; not available offline).
//!
//! Layer map:
//! * [`runtime`] — `Backend` trait, interpreter + PJRT engines,
//!   artifact/synthesized registry, planar buffers, and the R2C/C2R
//!   half-spectrum kernels ([`runtime::RealHalfSpectrum`]).
//! * [`plan`] — cuFFT-style planner: size -> radix schedule ->
//!   artifact, for `fft1d`/`fft2d` and the real-input
//!   `rfft1d`/`irfft1d` and `rfft2d`/`irfft2d` pairs.
//! * [`coordinator`] — the FFT service: router, dynamic batcher,
//!   worker scheduler, metrics, TCP server. Sizes with no direct
//!   artifact route to a cached four-step plan (complex or real);
//!   registered spectral filter banks serve batched convolution
//!   through the same queues.
//! * [`large`] — batched, multi-level four-step engine composing big
//!   FFTs from small artifacts (tiled transposes, cached flat twiddle
//!   tables, `TCFFT_THREADS` host parallelism), its real-input
//!   wrapper (half-spectrum pass fused into the final read-out
//!   transpose), plus the kept per-sequence baseline.
//! * [`workload`] — evaluation signals and the spectral-convolution
//!   filter banks (FIR/matched filtering over the real path).
//! * [`fft`], [`hp`] — host-side oracles and numeric substrates.
//! * [`memsim`], [`perfmodel`] — the GPU memory/roofline models that
//!   regenerate the paper's Table 2 and Figs 4-7.
//!
//! Quick start (no artifacts needed — the interpreter serves the
//! synthesized catalog):
//! ```
//! use tcfft::plan::Plan;
//! use tcfft::runtime::{PlanarBatch, Runtime};
//!
//! let rt = Runtime::load_default().unwrap();
//! let plan = Plan::fft1d(&rt.registry, 4096, 4).unwrap();
//! let x = PlanarBatch::new(vec![4, 4096]); // fill with your signal
//! let y = plan.execute(&rt, x).unwrap();
//! assert_eq!(y.shape, vec![4, 4096]);
//! ```
//!
//! Run the full offline test suite with `cargo test` (conformance of
//! the interpreter against the from-scratch f64 oracles is in
//! `tests/conformance_interpreter.rs`); `cargo bench --bench <name>`
//! regenerates the paper's tables and figures.

pub mod bench_harness;
pub mod coordinator;
pub mod error;
pub mod fft;
pub mod hp;
pub mod large;
pub mod memsim;
pub mod perfmodel;
pub mod plan;
pub mod recovery;
pub mod runtime;
pub mod util;
pub mod workload;
