//! # tcFFT — half-precision matrix-formulated FFT (paper reproduction)
//!
//! Reproduction of *"tcFFT: Accelerating Half-Precision FFT through
//! Tensor Cores"* (Li, Cheng, Lin 2021) as a three-layer Rust + JAX +
//! Pallas stack.  See DESIGN.md for the architecture and the
//! hardware-adaptation mapping (Tensor Cores -> TPU MXU, executed via
//! interpret-mode CPU PJRT).
//!
//! Layer map:
//! * [`runtime`] — PJRT execution of AOT artifacts (HLO text).
//! * [`plan`] — cuFFT-style planner: size -> radix schedule -> artifact.
//! * [`coordinator`] — the FFT service: router, dynamic batcher,
//!   worker scheduler, metrics, TCP server.
//! * [`large`] — four-step composition of big FFTs from small artifacts.
//! * [`fft`], [`hp`] — host-side oracles and numeric substrates.
//! * [`memsim`], [`perfmodel`] — the GPU memory/roofline models that
//!   regenerate the paper's Table 2 and Figs 4-7.
//!
//! Quick start (after `make artifacts`):
//! ```no_run
//! use tcfft::plan::Plan;
//! use tcfft::runtime::{PlanarBatch, Runtime};
//!
//! let rt = Runtime::load_default().unwrap();
//! let plan = Plan::fft1d(&rt.registry, 4096, 4).unwrap();
//! let x = PlanarBatch::new(vec![4, 4096]); // fill with your signal
//! let y = plan.execute(&rt, x).unwrap();
//! # drop(y);
//! ```

pub mod bench_harness;
pub mod coordinator;
pub mod error;
pub mod fft;
pub mod hp;
pub mod large;
pub mod memsim;
pub mod perfmodel;
pub mod plan;
pub mod recovery;
pub mod runtime;
pub mod util;
pub mod workload;
