//! PJRT execution engine (feature `pjrt`): owns the CPU client and the
//! compiled-executable cache; executions run directly on the calling
//! thread (PJRT's CPU client is internally synchronized and supports
//! concurrent `Execute`), compilation is serialized per artifact.
//!
//! NOT compiled by default: the offline toolchain has no `xla` crate.
//! Enable the `pjrt` cargo feature after vendoring an xla/PJRT binding
//! (see README "Backends") to execute real AOT HLO artifacts; every
//! test and example runs against the `CpuInterpreter` backend instead.
//!
//! The request path is: HLO text loaded once per artifact
//! (`HloModuleProto::from_text_file` — text, not serialized proto, see
//! DESIGN.md) -> compiled once -> executed many times with planar fp16
//! literals.  Python is never involved.
//!
//! ## Why not an actor thread?
//! The first implementation funneled every call through a dedicated
//! thread owning the (!Send) xla wrapper types.  That cost ~175 us of
//! channel/wakeup latency per batch — 108% overhead over the raw path
//! at service load (EXPERIMENTS.md SPerf iteration 2).  The xla crate
//! types are raw-pointer wrappers without Send/Sync markers, but the
//! underlying PJRT C API objects are thread-safe: `PJRT_Client` and
//! `PJRT_LoadedExecutable` are documented as usable from multiple
//! threads concurrently (the CPU client dispatches onto its own
//! Eigen thread pool).  We therefore wrap them in a struct that
//! asserts Send + Sync, serialize *compilation* behind a Mutex, and
//! let executions run concurrently from worker threads.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Mutex, RwLock};
use std::time::Instant;

use super::buffers::PlanarBatch;
use super::registry::VariantMeta;
use super::{Backend, ExecStats};
use crate::error::{Result, TcFftError};
use crate::hp::f16;

struct ClientBox(xla::PjRtClient);
// SAFETY: PJRT_Client is thread-safe per the PJRT C API contract; the
// Rust wrapper only forwards pointers. Compile and execute may be
// invoked from any thread.
unsafe impl Send for ClientBox {}
unsafe impl Sync for ClientBox {}

struct ExeBox(xla::PjRtLoadedExecutable);
// SAFETY: PJRT_LoadedExecutable::Execute is thread-safe; see above.
unsafe impl Send for ExeBox {}
unsafe impl Sync for ExeBox {}

/// The PJRT execution engine (shared via `Arc` by `Runtime`).
pub struct Executor {
    client: ClientBox,
    /// compiled executables; RwLock so the hot path is a shared read
    cache: RwLock<HashMap<String, &'static ExeBox>>,
    /// serializes compilation (PJRT compile is expensive; no need for
    /// concurrent compiles of the same artifact)
    compile_lock: Mutex<()>,
}

impl Executor {
    /// Initialize the PJRT CPU client.
    pub fn spawn() -> Result<Executor> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| TcFftError::msg(format!("PJRT CPU init: {e}")))?;
        Ok(Executor {
            client: ClientBox(client),
            cache: RwLock::new(HashMap::new()),
            compile_lock: Mutex::new(()),
        })
    }

    fn lookup(&self, key: &str) -> Option<&'static ExeBox> {
        self.cache.read().unwrap().get(key).copied()
    }

    /// Compile (once) and cache; returns true if this call compiled.
    ///
    /// Executables are leaked intentionally: they live for the process
    /// lifetime (a handful of artifacts), which lets the hot path hand
    /// out `&'static` references without reference-count traffic.
    fn ensure_compiled(&self, key: &str, hlo_path: &Path) -> Result<bool> {
        if self.lookup(key).is_some() {
            return Ok(false);
        }
        let _guard = self.compile_lock.lock().unwrap();
        if self.lookup(key).is_some() {
            return Ok(false); // raced: another thread compiled it
        }
        let path = hlo_path
            .to_str()
            .ok_or_else(|| TcFftError::msg("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| TcFftError::msg(format!("loading HLO text {path}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .0
            .compile(&comp)
            .map_err(|e| TcFftError::msg(format!("compiling {key}: {e}")))?;
        let boxed: &'static ExeBox = Box::leak(Box::new(ExeBox(exe)));
        self.cache.write().unwrap().insert(key.to_string(), boxed);
        Ok(true)
    }
}

impl Backend for Executor {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    /// Execute: quantizes input to fp16, runs the artifact, returns
    /// planar f32 output of the same shape. Thread-safe; concurrent
    /// calls execute in parallel on the PJRT CPU thread pool.
    fn execute(&self, meta: &VariantMeta, input: PlanarBatch) -> Result<(PlanarBatch, ExecStats)> {
        let key = &meta.key;
        let mut stats = ExecStats::default();
        stats.compiled = self.ensure_compiled(key, &meta.file)?;
        let exe = self.lookup(key).expect("just compiled");

        // marshal planar f32 -> fp16 literals
        let tm = Instant::now();
        let (re_bytes, im_bytes) = input.encode_f16();
        let dims = &input.shape;
        let lit_re = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F16,
            dims,
            &re_bytes,
        )
        .map_err(|e| TcFftError::msg(format!("building re literal: {e}")))?;
        let lit_im = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F16,
            dims,
            &im_bytes,
        )
        .map_err(|e| TcFftError::msg(format!("building im literal: {e}")))?;
        stats.marshal_seconds += tm.elapsed().as_secs_f64();

        // execute
        let te = Instant::now();
        let result = exe
            .0
            .execute::<xla::Literal>(&[lit_re, lit_im])
            .map_err(|e| TcFftError::msg(format!("executing {key}: {e}")))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| TcFftError::msg(format!("fetching result: {e}")))?;
        stats.exec_seconds = te.elapsed().as_secs_f64();

        // unmarshal: jax lowered with return_tuple=True -> (re, im)
        let tm = Instant::now();
        let (out_re, out_im) = out_lit
            .to_tuple2()
            .map_err(|e| TcFftError::msg(format!("result is not a 2-tuple: {e}")))?;
        let re = literal_f16_to_f32(&out_re)?;
        let im = literal_f16_to_f32(&out_im)?;
        stats.marshal_seconds += tm.elapsed().as_secs_f64();

        Ok((PlanarBatch { re, im, shape: input.shape }, stats))
    }

    /// Pre-compile an artifact; returns compile seconds (0 if cached).
    fn warm(&self, meta: &VariantMeta) -> Result<f64> {
        let t0 = Instant::now();
        let fresh = self.ensure_compiled(&meta.key, &meta.file)?;
        Ok(if fresh { t0.elapsed().as_secs_f64() } else { 0.0 })
    }
}

fn literal_f16_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    // Fast path: copy raw fp16 bytes and decode ourselves; fall back to
    // XLA-side conversion if the element type is unexpected.
    match lit.ty() {
        Ok(xla::ElementType::F16) => {
            let n = lit.element_count();
            let mut raw = vec![0u8; n * 2];
            match lit.copy_raw_to::<u8>(&mut raw) {
                Ok(()) => Ok(f16::decode_to_f32(&raw)),
                Err(_) => {
                    let conv = lit
                        .convert(xla::PrimitiveType::F32)
                        .map_err(|e| TcFftError::msg(format!("f16->f32 convert: {e}")))?;
                    conv.to_vec::<f32>()
                        .map_err(|e| TcFftError::msg(format!("to_vec: {e}")))
                }
            }
        }
        _ => {
            let conv = lit
                .convert(xla::PrimitiveType::F32)
                .map_err(|e| TcFftError::msg(format!("convert: {e}")))?;
            conv.to_vec::<f32>()
                .map_err(|e| TcFftError::msg(format!("to_vec: {e}")))
        }
    }
}
