//! Explicit-SIMD stage kernels behind safe runtime dispatch — the CPU
//! analog of the paper's Tensor-Core fragment kernels, with a hard
//! bitwise contract against the scalar micro-kernels.
//!
//! # Dispatch
//!
//! | path     | ISA gate                              | f32 lanes | availability |
//! |----------|---------------------------------------|-----------|--------------|
//! | `scalar` | none — the untouched scalar kernels   | 1         | always |
//! | `avx2`   | `target_feature(enable = "avx2")`     | 8         | x86_64 with runtime `avx2` |
//! | `avx512` | `target_feature(enable = "avx512f")`  | 16        | x86_64 with runtime `avx512f`, **and** the off-by-default `avx512` cargo feature (the `_mm512` intrinsics stabilized in Rust 1.89) |
//! | `neon`   | `target_feature(enable = "neon")`     | 4         | aarch64 with runtime `neon` |
//!
//! Selection order: a programmatic [`force`] override (tests/CI), else
//! the `TCFFT_SIMD` env knob (`auto|avx2|avx512|neon|scalar`, read
//! once), else [`detect_best`]. Requesting a path the CPU or build
//! lacks warns on stderr and falls back to `scalar` — it never
//! silently upgrades, so a forced-`scalar` CI lane really is scalar.
//! All `std::arch` intrinsics in the crate live in this module (gated
//! by `ci.sh`'s grep check), and every `unsafe` call is reached only
//! after the matching runtime CPU detection.
//!
//! # The bitwise-equality contract
//!
//! Every SIMD path must produce **bit-for-bit** the scalar kernels'
//! output on all tiers (`tests/simd_equivalence.rs` enforces this per
//! available path). The kernels get that by construction, not by
//! tolerance:
//!
//! * Vector lanes map to *independent output cells* — batch rows,
//!   stage groups, twiddle columns `k`, or 2D lanes `l`. Each lane
//!   executes exactly the scalar per-cell float-op sequence: separate
//!   IEEE mul/add/sub in scalar order (**no FMA**, which would skip
//!   an intermediate f32 rounding the scalar kernels perform).
//! * Vectorization may therefore reassociate *across* cells only —
//!   never inside a radix-R accumulation chain, whose left-to-right
//!   `acc += w*x` order (and, on `tc_ec`, the left-to-right
//!   `hi*hi + hi*lo + lo*hi` compensated-product order) is part of
//!   each tier's observable numeric contract.
//! * Every fp16 rounding point (`rnd16` stage stores, the `tc_split`
//!   operand rounding, the `tc_ec` `ec_split16`/`ec_store` split
//!   points including the finite-hi overflow guard) runs through the
//!   *same scalar helpers* on a per-lane staging buffer.
//!
//! Remainders that do not fill a vector run through the same generic
//! panel bodies monomorphized at width 1 (the `V1` scalar "vector"),
//! so tail cells share the vector code path rather than a hand-copied
//! scalar one.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use super::interpreter::{ec_mul, ec_split16, ec_store, rnd16};
use crate::error::Result;

/// Widest supported vector (AVX-512); sizes the per-panel staging
/// buffers the scalar rounding helpers run over.
const MAX_W: usize = 16;

/// One selectable kernel path. `Scalar` means "use the untouched
/// scalar micro-kernels in `interpreter.rs`" — it is the portable
/// fallback and the reference side of the bitwise contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdPath {
    /// Portable scalar kernels (byte-for-byte the pre-SIMD code path).
    Scalar,
    /// 8-lane f32 on x86_64 (`avx2`).
    Avx2,
    /// 16-lane f32 on x86_64 (`avx512f`; needs the `avx512` feature).
    Avx512,
    /// 4-lane f32 on aarch64 (`neon`).
    Neon,
}

impl SimdPath {
    /// Parse a concrete path name (`auto` is resolved by the caller).
    pub fn parse(s: &str) -> Option<SimdPath> {
        match s {
            "scalar" => Some(SimdPath::Scalar),
            "avx2" => Some(SimdPath::Avx2),
            "avx512" => Some(SimdPath::Avx512),
            "neon" => Some(SimdPath::Neon),
            _ => None,
        }
    }

    fn code(self) -> u8 {
        match self {
            SimdPath::Scalar => 1,
            SimdPath::Avx2 => 2,
            SimdPath::Avx512 => 3,
            SimdPath::Neon => 4,
        }
    }

    fn from_code(c: u8) -> SimdPath {
        match c {
            2 => SimdPath::Avx2,
            3 => SimdPath::Avx512,
            4 => SimdPath::Neon,
            _ => SimdPath::Scalar,
        }
    }
}

impl std::fmt::Display for SimdPath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SimdPath::Scalar => "scalar",
            SimdPath::Avx2 => "avx2",
            SimdPath::Avx512 => "avx512",
            SimdPath::Neon => "neon",
        })
    }
}

/// Whether `path` can actually execute on this CPU and build.
pub fn available(path: SimdPath) -> bool {
    match path {
        SimdPath::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        SimdPath::Avx2 => is_x86_feature_detected!("avx2"),
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        SimdPath::Avx512 => is_x86_feature_detected!("avx512f"),
        #[cfg(target_arch = "aarch64")]
        SimdPath::Neon => std::arch::is_aarch64_feature_detected!("neon"),
        _ => false,
    }
}

/// Every vector (non-scalar) path this CPU/build can execute, widest
/// first — what `tests/simd_equivalence.rs` iterates.
pub fn available_vector_paths() -> Vec<SimdPath> {
    [SimdPath::Avx512, SimdPath::Avx2, SimdPath::Neon]
        .into_iter()
        .filter(|&p| available(p))
        .collect()
}

/// The widest available path (`Scalar` when no vector ISA is usable).
pub fn detect_best() -> SimdPath {
    available_vector_paths().first().copied().unwrap_or(SimdPath::Scalar)
}

const FORCE_UNSET: u8 = 0;
static FORCED: AtomicU8 = AtomicU8::new(FORCE_UNSET);

/// Programmatic override of the active path — the in-process twin of
/// `TCFFT_SIMD`, for tests and CI harnesses that must flip paths
/// without respawning. `force(None)` restores env/auto selection.
/// Errors (and changes nothing) when the requested path is not
/// [`available`], so callers can skip-with-note instead of silently
/// testing the wrong kernels.
pub fn force(path: Option<SimdPath>) -> Result<()> {
    match path {
        None => {
            FORCED.store(FORCE_UNSET, Ordering::SeqCst);
            Ok(())
        }
        Some(p) => {
            crate::ensure!(
                available(p),
                "SIMD path {p} is not available on this CPU/build \
                 (arch {}, avx512 feature {})",
                std::env::consts::ARCH,
                cfg!(feature = "avx512")
            );
            FORCED.store(p.code(), Ordering::SeqCst);
            Ok(())
        }
    }
}

/// The path the stage dispatcher uses right now: a [`force`] override
/// if set, else the cached `TCFFT_SIMD`/auto selection. Always returns
/// an [`available`] path.
pub fn active() -> SimdPath {
    match FORCED.load(Ordering::Relaxed) {
        FORCE_UNSET => env_selected(),
        c => SimdPath::from_code(c),
    }
}

fn env_selected() -> SimdPath {
    static CHOICE: OnceLock<SimdPath> = OnceLock::new();
    *CHOICE.get_or_init(resolve_env)
}

fn resolve_env() -> SimdPath {
    let raw = match std::env::var("TCFFT_SIMD") {
        Err(_) => return detect_best(),
        Ok(v) => v,
    };
    let name = raw.trim().to_ascii_lowercase();
    if name.is_empty() || name == "auto" {
        return detect_best();
    }
    match SimdPath::parse(&name) {
        Some(p) if available(p) => p,
        Some(p) => {
            eprintln!(
                "tcfft: TCFFT_SIMD={name} requests {p}, which this CPU/build lacks; \
                 falling back to scalar kernels"
            );
            SimdPath::Scalar
        }
        None => {
            eprintln!(
                "tcfft: unknown TCFFT_SIMD value {raw:?} \
                 (want auto|avx2|avx512|neon|scalar); using auto"
            );
            detect_best()
        }
    }
}

// ---------------------------------------------------------------------
// stage operand view + panel descriptors
// ---------------------------------------------------------------------

/// Borrowed view of one `MergeStage`'s operand tables — what the panel
/// kernels read. Built by `interpreter::MergeStage::view`.
pub(crate) struct StageView<'a> {
    pub r: usize,
    pub n2: usize,
    /// F_r row-major `[m*r + j]`
    pub f_re: &'a [f32],
    pub f_im: &'a [f32],
    /// T row-major `[j*n2 + k]`
    pub t_re: &'a [f32],
    pub t_im: &'a [f32],
    /// fp16 lo residuals (`tc_ec` only, else empty)
    pub f_re_lo: &'a [f32],
    pub f_im_lo: &'a [f32],
    pub t_re_lo: &'a [f32],
    pub t_im_lo: &'a [f32],
    /// fused combined operand, k-major `[(k*r + m)*r + j]` (splat loads)
    pub w_re: &'a [f32],
    pub w_im: &'a [f32],
    /// fused combined operand, m-major `[(m*r + j)*n2 + k]` — the same
    /// bits laid out contiguously in `k` for vector loads
    pub w_re_mj: &'a [f32],
    pub w_im_mj: &'a [f32],
    pub split: bool,
    pub ec: bool,
}

/// The planar buffers one stage application reads and writes.
pub(crate) struct StageBufs<'a> {
    pub in_re: &'a [f32],
    pub in_im: &'a [f32],
    pub out_re: &'a mut [f32],
    pub out_im: &'a mut [f32],
    pub lane: usize,
}

/// One vector-wide panel of output cells. Lane `i` of the vector is
/// the cell whose input element (for digit `j`) sits at
/// `(gbase + j*n2 + k)*lane + l0 + i*stride`, at twiddle column
/// `k + i*k_step` — so lanes run across `k` (`stride == 1`,
/// `k_step == 1`, 1D), across `l` (`stride == 1`, `k_step == 0`, 2D
/// lanes), or across groups (`stride == block*lane`, `k_step == 0`).
#[derive(Clone, Copy)]
struct Panel {
    gbase: usize,
    k: usize,
    l0: usize,
    stride: usize,
    k_step: usize,
}

// ---------------------------------------------------------------------
// the vector abstraction
// ---------------------------------------------------------------------

/// A width-`W` f32 vector whose ops are the per-lane IEEE scalar ops.
/// All methods are `unsafe` because the intrinsic impls require their
/// ISA target-feature to be enabled in the calling context.
trait V32: Copy {
    const W: usize;
    /// Load `W` contiguous f32s at `s[i..]`.
    unsafe fn load(s: &[f32], i: usize) -> Self;
    /// Store the `W` lanes into the front of a staging buffer.
    unsafe fn store(self, out: &mut [f32; MAX_W]);
    /// Broadcast one f32 to every lane.
    unsafe fn splat(x: f32) -> Self;
    unsafe fn mul(self, b: Self) -> Self;
    unsafe fn add(self, b: Self) -> Self;
    unsafe fn sub(self, b: Self) -> Self;
}

/// Width-1 "vector": plain scalar f32 ops. Panel tails run the generic
/// bodies at this width, so remainder cells execute the same code path
/// (and trivially the same op order) as the full vectors.
#[derive(Clone, Copy)]
struct V1(f32);

impl V32 for V1 {
    const W: usize = 1;
    #[inline(always)]
    unsafe fn load(s: &[f32], i: usize) -> Self {
        V1(s[i])
    }
    #[inline(always)]
    unsafe fn store(self, out: &mut [f32; MAX_W]) {
        out[0] = self.0;
    }
    #[inline(always)]
    unsafe fn splat(x: f32) -> Self {
        V1(x)
    }
    #[inline(always)]
    unsafe fn mul(self, b: Self) -> Self {
        V1(self.0 * b.0)
    }
    #[inline(always)]
    unsafe fn add(self, b: Self) -> Self {
        V1(self.0 + b.0)
    }
    #[inline(always)]
    unsafe fn sub(self, b: Self) -> Self {
        V1(self.0 - b.0)
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{V32, MAX_W};
    use std::arch::x86_64::*;

    /// 8-lane AVX2 vector. Safety: every method requires the `avx`
    /// target feature (callers are `#[target_feature(enable="avx2")]`).
    #[derive(Clone, Copy)]
    pub(super) struct V8(__m256);

    impl V32 for V8 {
        const W: usize = 8;
        #[inline(always)]
        unsafe fn load(s: &[f32], i: usize) -> Self {
            debug_assert!(i + Self::W <= s.len());
            V8(_mm256_loadu_ps(s.as_ptr().add(i)))
        }
        #[inline(always)]
        unsafe fn store(self, out: &mut [f32; MAX_W]) {
            _mm256_storeu_ps(out.as_mut_ptr(), self.0)
        }
        #[inline(always)]
        unsafe fn splat(x: f32) -> Self {
            V8(_mm256_set1_ps(x))
        }
        #[inline(always)]
        unsafe fn mul(self, b: Self) -> Self {
            V8(_mm256_mul_ps(self.0, b.0))
        }
        #[inline(always)]
        unsafe fn add(self, b: Self) -> Self {
            V8(_mm256_add_ps(self.0, b.0))
        }
        #[inline(always)]
        unsafe fn sub(self, b: Self) -> Self {
            V8(_mm256_sub_ps(self.0, b.0))
        }
    }

    /// 16-lane AVX-512 vector, behind the `avx512` cargo feature (the
    /// `_mm512` intrinsics stabilized in Rust 1.89). Safety: every
    /// method requires the `avx512f` target feature.
    #[cfg(feature = "avx512")]
    #[derive(Clone, Copy)]
    pub(super) struct V16(__m512);

    #[cfg(feature = "avx512")]
    impl V32 for V16 {
        const W: usize = 16;
        #[inline(always)]
        unsafe fn load(s: &[f32], i: usize) -> Self {
            debug_assert!(i + Self::W <= s.len());
            V16(_mm512_loadu_ps(s.as_ptr().add(i)))
        }
        #[inline(always)]
        unsafe fn store(self, out: &mut [f32; MAX_W]) {
            _mm512_storeu_ps(out.as_mut_ptr(), self.0)
        }
        #[inline(always)]
        unsafe fn splat(x: f32) -> Self {
            V16(_mm512_set1_ps(x))
        }
        #[inline(always)]
        unsafe fn mul(self, b: Self) -> Self {
            V16(_mm512_mul_ps(self.0, b.0))
        }
        #[inline(always)]
        unsafe fn add(self, b: Self) -> Self {
            V16(_mm512_add_ps(self.0, b.0))
        }
        #[inline(always)]
        unsafe fn sub(self, b: Self) -> Self {
            V16(_mm512_sub_ps(self.0, b.0))
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::{V32, MAX_W};
    use std::arch::aarch64::*;

    /// 4-lane NEON vector. Safety: every method requires the `neon`
    /// target feature (callers are `#[target_feature(enable="neon")]`).
    #[derive(Clone, Copy)]
    pub(super) struct V4(float32x4_t);

    impl V32 for V4 {
        const W: usize = 4;
        #[inline(always)]
        unsafe fn load(s: &[f32], i: usize) -> Self {
            debug_assert!(i + Self::W <= s.len());
            V4(vld1q_f32(s.as_ptr().add(i)))
        }
        #[inline(always)]
        unsafe fn store(self, out: &mut [f32; MAX_W]) {
            vst1q_f32(out.as_mut_ptr(), self.0)
        }
        #[inline(always)]
        unsafe fn splat(x: f32) -> Self {
            V4(vdupq_n_f32(x))
        }
        #[inline(always)]
        unsafe fn mul(self, b: Self) -> Self {
            V4(vmulq_f32(self.0, b.0))
        }
        #[inline(always)]
        unsafe fn add(self, b: Self) -> Self {
            V4(vaddq_f32(self.0, b.0))
        }
        #[inline(always)]
        unsafe fn sub(self, b: Self) -> Self {
            V4(vsubq_f32(self.0, b.0))
        }
    }
}

/// Gather `W` lanes at `s[base + i*stride]` (plain contiguous load
/// when `stride == 1`).
#[inline(always)]
unsafe fn load_lanes<V: V32>(s: &[f32], base: usize, stride: usize) -> V {
    if stride == 1 {
        V::load(s, base)
    } else {
        let mut t = [0f32; MAX_W];
        for (i, slot) in t.iter_mut().enumerate().take(V::W) {
            *slot = s[base + i * stride];
        }
        V::load(&t, 0)
    }
}

/// Vector twin of the scalar `ec_mul`: the identical left-to-right
/// `(ah*bh + ah*bl) + al*bh` op sequence, per lane.
#[inline(always)]
unsafe fn ec_mul_v<V: V32>(ah: V, al: V, bh: V, bl: V) -> V {
    ah.mul(bh).add(ah.mul(bl)).add(al.mul(bh))
}

// ---------------------------------------------------------------------
// panel kernels (generic bodies, monomorphized per ISA via V32)
// ---------------------------------------------------------------------

/// Fused-tier panel: the scalar `stage_fused` per-cell sequence across
/// `V::W` cells. `OPC` selects contiguous m-major `W` loads (lanes run
/// across `k`) vs per-`(k,m,j)` splats (lanes run across `l`/groups).
#[inline(always)]
unsafe fn fused_panel<V: V32, const R: usize, const OPC: bool>(
    st: &StageView,
    bufs: &mut StageBufs,
    c: Panel,
) {
    let n2 = st.n2;
    let lane = bufs.lane;
    let mut xr = [V::splat(0.0); R];
    let mut xi = [V::splat(0.0); R];
    for j in 0..R {
        let base = (c.gbase + j * n2 + c.k) * lane + c.l0;
        xr[j] = load_lanes::<V>(bufs.in_re, base, c.stride);
        xi[j] = load_lanes::<V>(bufs.in_im, base, c.stride);
    }
    let mut sr = [0f32; MAX_W];
    let mut si = [0f32; MAX_W];
    for m in 0..R {
        let mut acc_re = V::splat(0.0);
        let mut acc_im = V::splat(0.0);
        for j in 0..R {
            let (wr, wi) = if OPC {
                let o = (m * R + j) * n2 + c.k;
                (V::load(st.w_re_mj, o), V::load(st.w_im_mj, o))
            } else {
                let o = (c.k * R + m) * R + j;
                (V::splat(st.w_re[o]), V::splat(st.w_im[o]))
            };
            acc_re = acc_re.add(wr.mul(xr[j]).sub(wi.mul(xi[j])));
            acc_im = acc_im.add(wr.mul(xi[j]).add(wi.mul(xr[j])));
        }
        acc_re.store(&mut sr);
        acc_im.store(&mut si);
        let base = (c.gbase + m * n2 + c.k) * lane + c.l0;
        for i in 0..V::W {
            bufs.out_re[base + i * c.stride] = rnd16(sr[i]);
            bufs.out_im[base + i * c.stride] = rnd16(si[i]);
        }
    }
}

/// Two-pass panel (`tc` past the fuse limit, and `tc_split` with its
/// operand rounding when `SPLIT`): the scalar `stage_unfused` per-cell
/// sequence across `V::W` cells.
#[inline(always)]
unsafe fn twopass_panel<V: V32, const R: usize, const SPLIT: bool, const OPC: bool>(
    st: &StageView,
    bufs: &mut StageBufs,
    c: Panel,
) {
    let n2 = st.n2;
    let lane = bufs.lane;
    let mut xr = [V::splat(0.0); R];
    let mut xi = [V::splat(0.0); R];
    let mut sr = [0f32; MAX_W];
    let mut si = [0f32; MAX_W];
    for j in 0..R {
        let base = (c.gbase + j * n2 + c.k) * lane + c.l0;
        let ar: V = load_lanes(bufs.in_re, base, c.stride);
        let ai: V = load_lanes(bufs.in_im, base, c.stride);
        let to = j * n2 + c.k;
        let (tr, ti) = if OPC {
            (V::load(st.t_re, to), V::load(st.t_im, to))
        } else {
            (V::splat(st.t_re[to]), V::splat(st.t_im[to]))
        };
        let mut yr = ar.mul(tr).sub(ai.mul(ti));
        let mut yi = ar.mul(ti).add(ai.mul(tr));
        if SPLIT {
            // the de-fused ablation's extra fp16 store, per lane via
            // the same scalar rounder
            yr.store(&mut sr);
            yi.store(&mut si);
            for (a, b) in sr.iter_mut().zip(si.iter_mut()).take(V::W) {
                *a = rnd16(*a);
                *b = rnd16(*b);
            }
            yr = V::load(&sr, 0);
            yi = V::load(&si, 0);
        }
        xr[j] = yr;
        xi[j] = yi;
    }
    for m in 0..R {
        let fo = m * R;
        let mut acc_re = V::splat(0.0);
        let mut acc_im = V::splat(0.0);
        for j in 0..R {
            let fr = V::splat(st.f_re[fo + j]);
            let fi = V::splat(st.f_im[fo + j]);
            acc_re = acc_re.add(fr.mul(xr[j]).sub(fi.mul(xi[j])));
            acc_im = acc_im.add(fr.mul(xi[j]).add(fi.mul(xr[j])));
        }
        acc_re.store(&mut sr);
        acc_im.store(&mut si);
        let base = (c.gbase + m * n2 + c.k) * lane + c.l0;
        for i in 0..V::W {
            bufs.out_re[base + i * c.stride] = rnd16(sr[i]);
            bufs.out_im[base + i * c.stride] = rnd16(si[i]);
        }
    }
}

/// Error-corrected panel: the twiddle/split phase stays scalar per
/// lane (every `ec_split16` rounding point is scalar by contract); the
/// O(R^2) compensated matmul accumulates vector-wide with the exact
/// scalar `ec_mul` op order per lane, and each accumulator lane goes
/// back through the scalar `ec_store` (finite-hi guard included).
#[inline(always)]
unsafe fn ec_panel<V: V32, const R: usize>(st: &StageView, bufs: &mut StageBufs, c: Panel) {
    let n2 = st.n2;
    let lane = bufs.lane;
    let mut xrh = [[0f32; MAX_W]; R];
    let mut xrl = [[0f32; MAX_W]; R];
    let mut xih = [[0f32; MAX_W]; R];
    let mut xil = [[0f32; MAX_W]; R];
    for j in 0..R {
        let base = (c.gbase + j * n2 + c.k) * lane + c.l0;
        for i in 0..V::W {
            let idx = base + i * c.stride;
            let to = j * n2 + c.k + i * c.k_step;
            let (arh, arl) = ec_split16(bufs.in_re[idx]);
            let (aih, ail) = ec_split16(bufs.in_im[idx]);
            let (trh, trl) = (st.t_re[to], st.t_re_lo[to]);
            let (tih, til) = (st.t_im[to], st.t_im_lo[to]);
            let yr = ec_mul(arh, arl, trh, trl) - ec_mul(aih, ail, tih, til);
            let yi = ec_mul(arh, arl, tih, til) + ec_mul(aih, ail, trh, trl);
            (xrh[j][i], xrl[j][i]) = ec_split16(yr);
            (xih[j][i], xil[j][i]) = ec_split16(yi);
        }
    }
    let mut sr = [0f32; MAX_W];
    let mut si = [0f32; MAX_W];
    for m in 0..R {
        let fo = m * R;
        let mut acc_re = V::splat(0.0);
        let mut acc_im = V::splat(0.0);
        for j in 0..R {
            let frh = V::splat(st.f_re[fo + j]);
            let frl = V::splat(st.f_re_lo[fo + j]);
            let fih = V::splat(st.f_im[fo + j]);
            let fil = V::splat(st.f_im_lo[fo + j]);
            let xrhv = V::load(&xrh[j], 0);
            let xrlv = V::load(&xrl[j], 0);
            let xihv = V::load(&xih[j], 0);
            let xilv = V::load(&xil[j], 0);
            acc_re =
                acc_re.add(ec_mul_v(frh, frl, xrhv, xrlv).sub(ec_mul_v(fih, fil, xihv, xilv)));
            acc_im =
                acc_im.add(ec_mul_v(frh, frl, xihv, xilv).add(ec_mul_v(fih, fil, xrhv, xrlv)));
        }
        acc_re.store(&mut sr);
        acc_im.store(&mut si);
        let base = (c.gbase + m * n2 + c.k) * lane + c.l0;
        for i in 0..V::W {
            bufs.out_re[base + i * c.stride] = ec_store(sr[i]);
            bufs.out_im[base + i * c.stride] = ec_store(si[i]);
        }
    }
}

// ---------------------------------------------------------------------
// panel sweep: one scaffold for every kernel family
// ---------------------------------------------------------------------

/// A kernel family the sweep scaffold can drive: fused, two-pass
/// (with/without the split rounding), or error-corrected.
trait Family {
    /// Run one panel. `OPC` = operand loads are contiguous across `k`
    /// (lanes run across `k`; only valid when `lane == 1`).
    unsafe fn panel<V: V32, const R: usize, const OPC: bool>(
        st: &StageView,
        bufs: &mut StageBufs,
        c: Panel,
    );
}

struct FusedF;
impl Family for FusedF {
    #[inline(always)]
    unsafe fn panel<V: V32, const R: usize, const OPC: bool>(
        st: &StageView,
        bufs: &mut StageBufs,
        c: Panel,
    ) {
        fused_panel::<V, R, OPC>(st, bufs, c)
    }
}

struct TwoPassF<const SPLIT: bool>;
impl<const SPLIT: bool> Family for TwoPassF<SPLIT> {
    #[inline(always)]
    unsafe fn panel<V: V32, const R: usize, const OPC: bool>(
        st: &StageView,
        bufs: &mut StageBufs,
        c: Panel,
    ) {
        twopass_panel::<V, R, SPLIT, OPC>(st, bufs, c)
    }
}

struct EcF;
impl Family for EcF {
    #[inline(always)]
    unsafe fn panel<V: V32, const R: usize, const OPC: bool>(
        st: &StageView,
        bufs: &mut StageBufs,
        c: Panel,
    ) {
        // ec operand loads are never vector-contiguous (the twiddle
        // phase is scalar per lane); `k_step` carries the across-k case
        ec_panel::<V, R>(st, bufs, c)
    }
}

/// Sweep every output cell of one stage application in vector panels.
/// Cell axes, in preference order:
/// * `lane == 1`, `n2 >= V::W` — lanes across `k` (contiguous input
///   *and* operand loads, the 1D hot path);
/// * `lane >= V::W` — lanes across `l` (contiguous input, splat
///   operands, the 2D packed-bin path);
/// * otherwise — lanes across stage groups at fixed `(k, l)` (strided
///   gathers, splat operands: first stages with `n2 == 1`, tiny lanes).
///
/// Tail cells that do not fill a vector run the same panel bodies at
/// width 1 ([`V1`]).
#[inline(always)]
unsafe fn sweep<F: Family, V: V32, const R: usize>(st: &StageView, bufs: &mut StageBufs) {
    let n2 = st.n2;
    let lane = bufs.lane;
    let block = R * n2;
    let groups = bufs.in_re.len() / (block * lane);
    if lane == 1 && n2 >= V::W {
        for g in 0..groups {
            let gbase = g * block;
            let mut k = 0;
            while k + V::W <= n2 {
                let c = Panel { gbase, k, l0: 0, stride: 1, k_step: 1 };
                F::panel::<V, R, true>(st, bufs, c);
                k += V::W;
            }
            while k < n2 {
                let c = Panel { gbase, k, l0: 0, stride: 1, k_step: 1 };
                F::panel::<V1, R, true>(st, bufs, c);
                k += 1;
            }
        }
    } else if lane >= V::W {
        for g in 0..groups {
            let gbase = g * block;
            for k in 0..n2 {
                let mut l = 0;
                while l + V::W <= lane {
                    let c = Panel { gbase, k, l0: l, stride: 1, k_step: 0 };
                    F::panel::<V, R, false>(st, bufs, c);
                    l += V::W;
                }
                while l < lane {
                    let c = Panel { gbase, k, l0: l, stride: 1, k_step: 0 };
                    F::panel::<V1, R, false>(st, bufs, c);
                    l += 1;
                }
            }
        }
    } else {
        let gstride = block * lane;
        let mut g = 0;
        while g + V::W <= groups {
            let gbase = g * block;
            for k in 0..n2 {
                for l in 0..lane {
                    let c = Panel { gbase, k, l0: l, stride: gstride, k_step: 0 };
                    F::panel::<V, R, false>(st, bufs, c);
                }
            }
            g += V::W;
        }
        while g < groups {
            let gbase = g * block;
            for k in 0..n2 {
                for l in 0..lane {
                    let c = Panel { gbase, k, l0: l, stride: 1, k_step: 0 };
                    F::panel::<V1, R, false>(st, bufs, c);
                }
            }
            g += 1;
        }
    }
}

/// Family + radix dispatch for one vector type.
#[inline(always)]
unsafe fn run_stage<V: V32>(st: &StageView, bufs: &mut StageBufs) {
    if st.ec {
        match st.r {
            2 => sweep::<EcF, V, 2>(st, bufs),
            4 => sweep::<EcF, V, 4>(st, bufs),
            8 => sweep::<EcF, V, 8>(st, bufs),
            _ => sweep::<EcF, V, 16>(st, bufs),
        }
    } else if !st.w_re.is_empty() {
        match st.r {
            2 => sweep::<FusedF, V, 2>(st, bufs),
            4 => sweep::<FusedF, V, 4>(st, bufs),
            8 => sweep::<FusedF, V, 8>(st, bufs),
            _ => sweep::<FusedF, V, 16>(st, bufs),
        }
    } else if st.split {
        match st.r {
            2 => sweep::<TwoPassF<true>, V, 2>(st, bufs),
            4 => sweep::<TwoPassF<true>, V, 4>(st, bufs),
            8 => sweep::<TwoPassF<true>, V, 8>(st, bufs),
            _ => sweep::<TwoPassF<true>, V, 16>(st, bufs),
        }
    } else {
        match st.r {
            2 => sweep::<TwoPassF<false>, V, 2>(st, bufs),
            4 => sweep::<TwoPassF<false>, V, 4>(st, bufs),
            8 => sweep::<TwoPassF<false>, V, 8>(st, bufs),
            _ => sweep::<TwoPassF<false>, V, 16>(st, bufs),
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn run_stage_avx2(st: &StageView, bufs: &mut StageBufs) {
    run_stage::<x86::V8>(st, bufs)
}

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
#[target_feature(enable = "avx512f")]
unsafe fn run_stage_avx512(st: &StageView, bufs: &mut StageBufs) {
    run_stage::<x86::V16>(st, bufs)
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn run_stage_neon(st: &StageView, bufs: &mut StageBufs) {
    run_stage::<arm::V4>(st, bufs)
}

/// Apply one merge stage through the SIMD kernels. Returns `false`
/// when `path` cannot run here (scalar path, off-arch request, or a
/// radix outside the planner's 2/4/8/16 set) — the caller then falls
/// through to the scalar kernels.
///
/// The `unsafe` ISA entry points are sound because `path` comes from
/// [`active`]/[`force`], which only hand out [`available`] paths
/// (runtime CPU detection); a defensive debug assert re-checks.
pub(crate) fn apply_stage(path: SimdPath, st: &StageView, bufs: &mut StageBufs) -> bool {
    if !matches!(st.r, 2 | 4 | 8 | 16) {
        return false;
    }
    debug_assert!(available(path), "dispatched unavailable SIMD path {path}");
    match path {
        SimdPath::Scalar => false,
        #[cfg(target_arch = "x86_64")]
        SimdPath::Avx2 => {
            unsafe { run_stage_avx2(st, bufs) };
            true
        }
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        SimdPath::Avx512 => {
            unsafe { run_stage_avx512(st, bufs) };
            true
        }
        #[cfg(target_arch = "aarch64")]
        SimdPath::Neon => {
            unsafe { run_stage_neon(st, bufs) };
            true
        }
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display_roundtrip() {
        for p in [SimdPath::Scalar, SimdPath::Avx2, SimdPath::Avx512, SimdPath::Neon] {
            assert_eq!(SimdPath::parse(&p.to_string()), Some(p));
        }
        assert_eq!(SimdPath::parse("auto"), None);
        assert_eq!(SimdPath::parse("sse9"), None);
    }

    #[test]
    fn detect_best_is_available() {
        assert!(available(detect_best()));
        assert!(available(SimdPath::Scalar));
    }

    #[test]
    fn vector_paths_exclude_scalar_and_are_available() {
        for p in available_vector_paths() {
            assert_ne!(p, SimdPath::Scalar);
            assert!(available(p));
        }
    }

    #[test]
    fn force_overrides_and_restores() {
        // scalar is always forcible; unavailable paths error and leave
        // the selection untouched. Restore auto selection on exit so
        // concurrently running tests keep their configured path (any
        // interleaving is bitwise-safe — that is the module contract).
        force(Some(SimdPath::Scalar)).unwrap();
        assert_eq!(active(), SimdPath::Scalar);
        let missing = [SimdPath::Avx2, SimdPath::Avx512, SimdPath::Neon]
            .into_iter()
            .find(|&p| !available(p));
        if let Some(p) = missing {
            assert!(force(Some(p)).is_err());
            assert_eq!(active(), SimdPath::Scalar, "failed force must not change the path");
        }
        force(None).unwrap();
        assert!(available(active()));
    }
}
