//! Artifact registry: parses `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) into typed variant metadata, and resolves
//! lookups from logical FFT descriptions to artifact keys.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One merging-kernel invocation inside an artifact (cost metadata).
#[derive(Clone, Debug)]
pub struct StageMeta {
    pub kernel: String,
    pub radix: usize,
    pub n2: usize,
    pub lane: usize,
    pub flops: f64,
    pub hbm_bytes: f64,
    pub vmem_bytes: f64,
}

/// One AOT-compiled artifact.
#[derive(Clone, Debug)]
pub struct VariantMeta {
    pub key: String,
    pub file: PathBuf,
    pub op: String,   // "fft1d" | "fft2d"
    pub algo: String, // "tc" | "tc_split" | "r2"
    pub n: usize,
    pub nx: usize,
    pub ny: usize,
    pub batch: usize,
    pub inverse: bool,
    pub input_shape: Vec<usize>,
    pub stages: Vec<StageMeta>,
    pub flops_per_seq: f64,
    pub hbm_bytes_per_seq: f64,
    pub radix2_equiv_flops: f64,
}

impl VariantMeta {
    /// Total complex elements per batch element.
    pub fn seq_len(&self) -> usize {
        if self.op == "fft1d" {
            self.n
        } else {
            self.nx * self.ny
        }
    }

    /// Total input elements (batch * sequence).
    pub fn total_elems(&self) -> usize {
        self.input_shape.iter().product()
    }
}

/// The parsed manifest with lookup indices.
pub struct Registry {
    pub dir: PathBuf,
    pub variants: BTreeMap<String, VariantMeta>,
}

fn req_usize(j: &Json, k: &str) -> Result<usize> {
    j.get(k)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow!("manifest: missing/invalid usize field '{k}'"))
}

fn req_f64(j: &Json, k: &str) -> Result<f64> {
    j.get(k)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow!("manifest: missing/invalid f64 field '{k}'"))
}

fn req_str(j: &Json, k: &str) -> Result<String> {
    Ok(j.get(k)
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("manifest: missing/invalid str field '{k}'"))?
        .to_string())
}

impl Registry {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::from_json_str(&text, dir)
    }

    pub fn from_json_str(text: &str, dir: PathBuf) -> Result<Registry> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
        let vars = root
            .get("variants")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest: no 'variants' array"))?;
        let mut variants = BTreeMap::new();
        for v in vars {
            let stages = v
                .get("stages")
                .and_then(|s| s.as_arr())
                .unwrap_or(&[])
                .iter()
                .map(|s| {
                    Ok(StageMeta {
                        kernel: req_str(s, "kernel")?,
                        radix: req_usize(s, "radix")?,
                        n2: req_usize(s, "n2")?,
                        lane: req_usize(s, "lane")?,
                        flops: req_f64(s, "flops")?,
                        hbm_bytes: req_f64(s, "hbm_bytes")?,
                        vmem_bytes: req_f64(s, "vmem_bytes")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let meta = VariantMeta {
                key: req_str(v, "key")?,
                file: dir.join(req_str(v, "file")?),
                op: req_str(v, "op")?,
                algo: req_str(v, "algo")?,
                n: req_usize(v, "n")?,
                nx: req_usize(v, "nx")?,
                ny: req_usize(v, "ny")?,
                batch: req_usize(v, "batch")?,
                inverse: v.get("inverse").and_then(|b| b.as_bool()).unwrap_or(false),
                input_shape: v
                    .get("input_shape")
                    .and_then(|a| a.as_arr())
                    .ok_or_else(|| anyhow!("manifest: missing input_shape"))?
                    .iter()
                    .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad shape entry")))
                    .collect::<Result<Vec<_>>>()?,
                stages,
                flops_per_seq: req_f64(v, "flops_per_seq")?,
                hbm_bytes_per_seq: req_f64(v, "hbm_bytes_per_seq")?,
                radix2_equiv_flops: req_f64(v, "radix2_equiv_flops")?,
            };
            variants.insert(meta.key.clone(), meta);
        }
        if variants.is_empty() {
            bail!("manifest has no variants");
        }
        Ok(Registry { dir, variants })
    }

    pub fn get(&self, key: &str) -> Result<&VariantMeta> {
        self.variants
            .get(key)
            .ok_or_else(|| anyhow!("no artifact '{key}' (have {})", self.variants.len()))
    }

    /// All variants matching a predicate.
    pub fn filter<'a>(
        &'a self,
        pred: impl Fn(&VariantMeta) -> bool + 'a,
    ) -> impl Iterator<Item = &'a VariantMeta> {
        self.variants.values().filter(move |v| pred(v))
    }

    /// Find a 1D variant: exact size/algo/direction; smallest batch >= wanted,
    /// else the largest available (the batcher splits oversize requests).
    pub fn find_fft1d(
        &self,
        n: usize,
        batch: usize,
        algo: &str,
        inverse: bool,
    ) -> Option<&VariantMeta> {
        let mut candidates: Vec<&VariantMeta> = self
            .variants
            .values()
            .filter(|v| v.op == "fft1d" && v.n == n && v.algo == algo && v.inverse == inverse)
            .collect();
        candidates.sort_by_key(|v| v.batch);
        candidates
            .iter()
            .find(|v| v.batch >= batch)
            .copied()
            .or_else(|| candidates.last().copied())
    }

    pub fn find_fft2d(
        &self,
        nx: usize,
        ny: usize,
        batch: usize,
        algo: &str,
        inverse: bool,
    ) -> Option<&VariantMeta> {
        let mut candidates: Vec<&VariantMeta> = self
            .variants
            .values()
            .filter(|v| {
                v.op == "fft2d" && v.nx == nx && v.ny == ny && v.algo == algo && v.inverse == inverse
            })
            .collect();
        candidates.sort_by_key(|v| v.batch);
        candidates
            .iter()
            .find(|v| v.batch >= batch)
            .copied()
            .or_else(|| candidates.last().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "format": 1, "dtype": "f16", "variants": [
        {"key": "fft1d_tc_n256_b4_fwd", "file": "a.hlo.txt", "op": "fft1d",
         "algo": "tc", "n": 256, "nx": 0, "ny": 0, "batch": 4,
         "inverse": false, "input_shape": [4, 256],
         "stages": [{"kernel": "fused256_first", "radix": 256, "n2": 1,
                     "lane": 1, "flops": 100, "hbm_bytes": 2048,
                     "vmem_bytes": 4096}],
         "flops_per_seq": 100, "hbm_bytes_per_seq": 2048,
         "radix2_equiv_flops": 24576},
        {"key": "fft1d_tc_n256_b16_fwd", "file": "b.hlo.txt", "op": "fft1d",
         "algo": "tc", "n": 256, "nx": 0, "ny": 0, "batch": 16,
         "inverse": false, "input_shape": [16, 256], "stages": [],
         "flops_per_seq": 100, "hbm_bytes_per_seq": 2048,
         "radix2_equiv_flops": 98304}
      ]}"#;

    #[test]
    fn parses_and_indexes() {
        let r = Registry::from_json_str(MINI, PathBuf::from("/tmp")).unwrap();
        assert_eq!(r.variants.len(), 2);
        let v = r.get("fft1d_tc_n256_b4_fwd").unwrap();
        assert_eq!(v.batch, 4);
        assert_eq!(v.stages.len(), 1);
        assert_eq!(v.stages[0].kernel, "fused256_first");
        assert_eq!(v.seq_len(), 256);
        assert_eq!(v.total_elems(), 1024);
    }

    #[test]
    fn batch_selection_prefers_smallest_sufficient() {
        let r = Registry::from_json_str(MINI, PathBuf::from("/tmp")).unwrap();
        assert_eq!(r.find_fft1d(256, 2, "tc", false).unwrap().batch, 4);
        assert_eq!(r.find_fft1d(256, 4, "tc", false).unwrap().batch, 4);
        assert_eq!(r.find_fft1d(256, 9, "tc", false).unwrap().batch, 16);
        // oversize: fall back to largest (caller splits)
        assert_eq!(r.find_fft1d(256, 100, "tc", false).unwrap().batch, 16);
        assert!(r.find_fft1d(512, 1, "tc", false).is_none());
    }

    #[test]
    fn rejects_bad_manifest() {
        assert!(Registry::from_json_str("{}", PathBuf::from("/tmp")).is_err());
        assert!(Registry::from_json_str("{\"variants\": []}", PathBuf::from("/tmp")).is_err());
        assert!(Registry::from_json_str("not json", PathBuf::from("/tmp")).is_err());
    }
}
