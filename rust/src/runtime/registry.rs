//! Artifact registry: parses `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) into typed variant metadata, and resolves
//! lookups from logical FFT descriptions to artifact keys.
//!
//! When no artifact directory exists (the default offline situation),
//! [`Registry::synthesize`] builds the same variant catalog the Python
//! AOT pipeline would emit — stage schedules, cost metadata and keys —
//! so the pure-Rust interpreter backend can serve every plan without
//! any files on disk.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Result, TcFftError};
use crate::plan::schedule::{
    kernel_schedule, radix2_equivalent_flops, rfft2d_schedule, rfft_schedule, split_schedule,
    PlannedStage,
};
use crate::util::json::Json;

/// One merging-kernel invocation inside an artifact (cost metadata).
#[derive(Clone, Debug)]
pub struct StageMeta {
    pub kernel: String,
    pub radix: usize,
    pub n2: usize,
    pub lane: usize,
    pub flops: f64,
    pub hbm_bytes: f64,
    pub vmem_bytes: f64,
}

/// One AOT-compiled artifact.
#[derive(Clone, Debug)]
pub struct VariantMeta {
    pub key: String,
    pub file: PathBuf,
    pub op: String,   // "fft1d" | "fft2d"
    pub algo: String, // "tc" | "tc_split" | "tc_ec" | "r2"
    pub n: usize,
    pub nx: usize,
    pub ny: usize,
    pub batch: usize,
    pub inverse: bool,
    pub input_shape: Vec<usize>,
    pub stages: Vec<StageMeta>,
    pub flops_per_seq: f64,
    pub hbm_bytes_per_seq: f64,
    pub radix2_equiv_flops: f64,
}

impl VariantMeta {
    /// Logical transform length per batch element (the real length `n`
    /// for `rfft1d`, whose packed spectrum holds `n/2 + 1` bins, and
    /// `nx * ny` for the 2D ops, where `rfft2d` packs `ny/2 + 1` bins
    /// per row).
    pub fn seq_len(&self) -> usize {
        if self.op == "fft2d" || self.op == "rfft2d" {
            self.nx * self.ny
        } else {
            self.n
        }
    }

    /// Total input elements (batch * sequence).
    pub fn total_elems(&self) -> usize {
        self.input_shape.iter().product()
    }
}

/// The parsed manifest with lookup indices.
pub struct Registry {
    pub dir: PathBuf,
    pub variants: BTreeMap<String, VariantMeta>,
    /// true when the catalog was synthesized in-process rather than
    /// parsed from an on-disk manifest
    pub synthesized: bool,
}

fn req_usize(j: &Json, k: &str) -> Result<usize> {
    j.get(k)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| TcFftError::msg(format!("manifest: missing/invalid usize field '{k}'")))
}

fn req_f64(j: &Json, k: &str) -> Result<f64> {
    j.get(k)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| TcFftError::msg(format!("manifest: missing/invalid f64 field '{k}'")))
}

fn req_str(j: &Json, k: &str) -> Result<String> {
    Ok(j.get(k)
        .and_then(|v| v.as_str())
        .ok_or_else(|| TcFftError::msg(format!("manifest: missing/invalid str field '{k}'")))?
        .to_string())
}

impl Registry {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            TcFftError::msg(format!("reading {path:?} — run `make artifacts` first: {e}"))
        })?;
        Self::from_json_str(&text, dir)
    }

    /// Load the manifest when present, otherwise fall back to the
    /// synthesized catalog (the offline default). A manifest that
    /// exists but fails to parse is an error, not a silent fallback.
    pub fn load_or_synthesize(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref();
        if dir.join("manifest.json").is_file() {
            Self::load(dir)
        } else {
            Ok(Self::synthesize())
        }
    }

    /// Parse a manifest from its JSON text (artifact files resolve
    /// relative to `dir`).
    pub fn from_json_str(text: &str, dir: PathBuf) -> Result<Registry> {
        let root = Json::parse(text)
            .map_err(|e| TcFftError::msg(format!("manifest parse error: {e}")))?;
        let vars = root
            .get("variants")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| TcFftError::msg("manifest: no 'variants' array"))?;
        let mut variants = BTreeMap::new();
        for v in vars {
            let stages = v
                .get("stages")
                .and_then(|s| s.as_arr())
                .unwrap_or(&[])
                .iter()
                .map(|s| {
                    Ok(StageMeta {
                        kernel: req_str(s, "kernel")?,
                        radix: req_usize(s, "radix")?,
                        n2: req_usize(s, "n2")?,
                        lane: req_usize(s, "lane")?,
                        flops: req_f64(s, "flops")?,
                        hbm_bytes: req_f64(s, "hbm_bytes")?,
                        vmem_bytes: req_f64(s, "vmem_bytes")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let meta = VariantMeta {
                key: req_str(v, "key")?,
                file: dir.join(req_str(v, "file")?),
                op: req_str(v, "op")?,
                algo: req_str(v, "algo")?,
                n: req_usize(v, "n")?,
                nx: req_usize(v, "nx")?,
                ny: req_usize(v, "ny")?,
                batch: req_usize(v, "batch")?,
                inverse: v.get("inverse").and_then(|b| b.as_bool()).unwrap_or(false),
                input_shape: v
                    .get("input_shape")
                    .and_then(|a| a.as_arr())
                    .ok_or_else(|| TcFftError::msg("manifest: missing input_shape"))?
                    .iter()
                    .map(|x| {
                        x.as_usize()
                            .ok_or_else(|| TcFftError::msg("bad shape entry"))
                    })
                    .collect::<Result<Vec<_>>>()?,
                stages,
                flops_per_seq: req_f64(v, "flops_per_seq")?,
                hbm_bytes_per_seq: req_f64(v, "hbm_bytes_per_seq")?,
                radix2_equiv_flops: req_f64(v, "radix2_equiv_flops")?,
            };
            variants.insert(meta.key.clone(), meta);
        }
        if variants.is_empty() {
            crate::bail!("manifest has no variants");
        }
        Ok(Registry { dir, variants, synthesized: false })
    }

    /// Build the in-process variant catalog: the Python AOT pipeline's
    /// `variant_matrix()` plus a full 1D power-of-two ladder so the
    /// conformance suite can exercise every size 2^1..=2^17 in both
    /// directions without any artifacts on disk.
    pub fn synthesize() -> Registry {
        let dir = PathBuf::from("<synthesized>");
        let mut variants = BTreeMap::new();
        let mut add = |m: VariantMeta| {
            variants.insert(m.key.clone(), m);
        };

        // full 1D ladder: tc forward + inverse at batch 4
        for t in 1..=17usize {
            let n = 1usize << t;
            add(synth_fft1d(&dir, "tc", n, 4, false));
            add(synth_fft1d(&dir, "tc", n, 4, true));
        }
        // 1D perf/precision ladder (Fig 4, Table 4): r2 baseline
        for n in [256usize, 1024, 4096, 16384, 65536, 131072] {
            add(synth_fft1d(&dir, "r2", n, 4, false));
        }
        // ablation variants (Sec 5.4 "Optimized TC")
        for n in [4096usize, 65536] {
            add(synth_fft1d(&dir, "tc_split", n, 4, false));
        }
        // error-corrected tier (Ootomo & Yokota): full 1D ladder so the
        // precision suite can sweep every size in both directions
        for t in 1..=17usize {
            let n = 1usize << t;
            add(synth_fft1d(&dir, "tc_ec", n, 4, false));
            add(synth_fft1d(&dir, "tc_ec", n, 4, true));
        }
        // ec four-step leaf + the Table-4 headline batch (the tc twin
        // exists so the precision bench can quote the accuracy gain)
        add(synth_fft1d(&dir, "tc_ec", 1024, 32, false));
        add(synth_fft1d(&dir, "tc_ec", 1024, 32, true));
        add(synth_fft1d(&dir, "tc_ec", 4096, 32, false));
        add(synth_fft1d(&dir, "tc", 4096, 32, false));
        // batch sweep at 131072 points (Fig 7a)
        for b in [1usize, 2, 8, 16] {
            add(synth_fft1d(&dir, "tc", 131072, b, false));
        }
        // four-step large-FFT building block: 1024-point with batch 32
        add(synth_fft1d(&dir, "tc", 1024, 32, false));
        add(synth_fft1d(&dir, "tc", 1024, 32, true));
        // real-input (R2C forward / C2R inverse) ladder at batch 4
        for t in 2..=17usize {
            let n = 1usize << t;
            add(synth_rfft1d(&dir, "tc", n, 4, false));
            add(synth_rfft1d(&dir, "tc", n, 4, true));
            add(synth_rfft1d(&dir, "tc_ec", n, 4, false));
            add(synth_rfft1d(&dir, "tc_ec", n, 4, true));
        }
        // real-input 2D ladder (square 8x8..256x256 plus the
        // rectangular shapes the conformance suite exercises), fwd+inv
        for t in 3..=8usize {
            let n = 1usize << t;
            add(synth_rfft2d(&dir, "tc", n, n, 4, false));
            add(synth_rfft2d(&dir, "tc", n, n, 4, true));
            add(synth_rfft2d(&dir, "tc_ec", n, n, 4, false));
            add(synth_rfft2d(&dir, "tc_ec", n, n, 4, true));
        }
        for (nx, ny) in [(64usize, 128usize), (128, 64)] {
            add(synth_rfft2d(&dir, "tc", nx, ny, 4, false));
            add(synth_rfft2d(&dir, "tc", nx, ny, 4, true));
            add(synth_rfft2d(&dir, "tc_ec", nx, ny, 4, false));
            add(synth_rfft2d(&dir, "tc_ec", nx, ny, 4, true));
        }
        // 2D shapes (Fig 5, Table 4)
        for (nx, ny) in [(128usize, 128usize), (256, 256), (256, 512), (512, 256), (512, 512)] {
            add(synth_fft2d(&dir, "tc", nx, ny, 2, false));
        }
        add(synth_fft2d(&dir, "tc", 256, 256, 2, true));
        add(synth_fft2d(&dir, "r2", 256, 256, 2, false));
        add(synth_fft2d(&dir, "r2", 512, 256, 2, false));
        add(synth_fft2d(&dir, "tc_split", 512, 256, 2, false));
        add(synth_fft2d(&dir, "tc_ec", 256, 256, 2, false));
        add(synth_fft2d(&dir, "tc_ec", 256, 256, 2, true));
        // batch sweep 2D 512x256 (Fig 7b)
        for b in [1usize, 4, 8] {
            add(synth_fft2d(&dir, "tc", 512, 256, b, false));
        }

        Registry { dir, variants, synthesized: true }
    }

    /// Look up a variant by its exact key.
    pub fn get(&self, key: &str) -> Result<&VariantMeta> {
        self.variants.get(key).ok_or_else(|| {
            TcFftError::NoArtifact(format!("'{key}' (have {})", self.variants.len()))
        })
    }

    /// All variants matching a predicate.
    pub fn filter<'a>(
        &'a self,
        pred: impl Fn(&VariantMeta) -> bool + 'a,
    ) -> impl Iterator<Item = &'a VariantMeta> {
        self.variants.values().filter(move |v| pred(v))
    }

    /// Batch-tier selection shared by every `find_*` lookup: among the
    /// variants matching `pred`, pick the smallest batch >= wanted,
    /// else the largest available (the batcher splits oversize
    /// requests).
    fn find_tier(
        &self,
        batch: usize,
        pred: impl Fn(&VariantMeta) -> bool,
    ) -> Option<&VariantMeta> {
        let mut candidates: Vec<&VariantMeta> =
            self.variants.values().filter(|v| pred(v)).collect();
        candidates.sort_by_key(|v| v.batch);
        candidates
            .iter()
            .find(|v| v.batch >= batch)
            .copied()
            .or_else(|| candidates.last().copied())
    }

    /// Find a real-input 1D variant (R2C when `inverse` is false, C2R
    /// when true): same batch-tier selection as [`find_fft1d`](Self::find_fft1d).
    pub fn find_rfft1d(
        &self,
        n: usize,
        batch: usize,
        algo: &str,
        inverse: bool,
    ) -> Option<&VariantMeta> {
        self.find_tier(batch, |v| {
            v.op == "rfft1d" && v.n == n && v.algo == algo && v.inverse == inverse
        })
    }

    /// Find a 1D variant: exact size/algo/direction; smallest batch >= wanted,
    /// else the largest available (the batcher splits oversize requests).
    pub fn find_fft1d(
        &self,
        n: usize,
        batch: usize,
        algo: &str,
        inverse: bool,
    ) -> Option<&VariantMeta> {
        self.find_tier(batch, |v| {
            v.op == "fft1d" && v.n == n && v.algo == algo && v.inverse == inverse
        })
    }

    /// Find a real-input 2D variant (R2C when `inverse` is false, C2R
    /// when true): exact shape/algo/direction, same batch-tier
    /// selection as [`find_fft1d`](Self::find_fft1d).
    pub fn find_rfft2d(
        &self,
        nx: usize,
        ny: usize,
        batch: usize,
        algo: &str,
        inverse: bool,
    ) -> Option<&VariantMeta> {
        self.find_tier(batch, |v| {
            v.op == "rfft2d" && v.nx == nx && v.ny == ny && v.algo == algo && v.inverse == inverse
        })
    }

    /// Find a 2D variant: exact shape/algo/direction, same batch-tier
    /// selection as [`find_fft1d`](Self::find_fft1d).
    pub fn find_fft2d(
        &self,
        nx: usize,
        ny: usize,
        batch: usize,
        algo: &str,
        inverse: bool,
    ) -> Option<&VariantMeta> {
        self.find_tier(batch, |v| {
            v.op == "fft2d" && v.nx == nx && v.ny == ny && v.algo == algo && v.inverse == inverse
        })
    }
}

fn stage_meta_from_planned(st: &PlannedStage, n_axis: usize) -> StageMeta {
    StageMeta {
        kernel: st.kernel.to_string(),
        radix: st.radix,
        n2: st.n2,
        lane: st.lane,
        flops: st.flops(n_axis) * st.lane as f64,
        hbm_bytes: st.hbm_bytes(n_axis) * st.lane as f64,
        vmem_bytes: st.vmem_bytes() as f64,
    }
}

/// Stage list for one staged axis (mirror of aot.py Variant.stages).
/// `tc_ec` shares the de-fused split schedule: its stages run the
/// two-pass kernel shape too (the hi/lo split points forbid fusion),
/// so the split cost model is the honest one.
fn synth_axis_stages(algo: &str, n_axis: usize, lane: usize) -> Vec<StageMeta> {
    let planned = if algo == "tc_split" || algo == "tc_ec" {
        split_schedule(n_axis, lane)
    } else {
        kernel_schedule(n_axis, lane)
    };
    planned
        .iter()
        .map(|s| stage_meta_from_planned(s, n_axis))
        .collect()
}

/// Stockham radix-2 baseline stage list (mirror of aot.py for algo "r2").
fn synth_r2_stages(total: usize) -> Vec<StageMeta> {
    let log2 = total.trailing_zeros() as usize;
    (0..log2)
        .map(|s| StageMeta {
            kernel: "stockham2".to_string(),
            radix: 2,
            n2: 1usize << s,
            lane: 1,
            flops: 10.0 * total as f64,
            hbm_bytes: 8.0 * total as f64,
            vmem_bytes: 0.0,
        })
        .collect()
}

fn synth_key(
    op: &str,
    algo: &str,
    n: usize,
    nx: usize,
    ny: usize,
    batch: usize,
    inverse: bool,
) -> String {
    let d = if inverse { "inv" } else { "fwd" };
    if op == "fft1d" {
        format!("fft1d_{algo}_n{n}_b{batch}_{d}")
    } else {
        format!("fft2d_{algo}_nx{nx}x{ny}_b{batch}_{d}")
    }
}

fn synth_fft1d(dir: &Path, algo: &str, n: usize, batch: usize, inverse: bool) -> VariantMeta {
    let key = synth_key("fft1d", algo, n, 0, 0, batch, inverse);
    let stages = if algo == "r2" {
        synth_r2_stages(n)
    } else {
        synth_axis_stages(algo, n, 1)
    };
    let flops_per_seq: f64 = stages.iter().map(|s| s.flops).sum();
    let hbm_bytes_per_seq: f64 = stages.iter().map(|s| s.hbm_bytes).sum();
    VariantMeta {
        file: dir.join(format!("{key}.hlo.txt")),
        key,
        op: "fft1d".to_string(),
        algo: algo.to_string(),
        n,
        nx: 0,
        ny: 0,
        batch,
        inverse,
        input_shape: vec![batch, n],
        stages,
        flops_per_seq,
        hbm_bytes_per_seq,
        radix2_equiv_flops: radix2_equivalent_flops(n, batch),
    }
}

/// Real-input 1D variant: an `n`-point real transform served by the
/// `n/2`-point complex schedule plus the half-spectrum pass. Forward
/// (R2C) consumes `[batch, n]` real rows and emits the Hermitian-packed
/// `[batch, n/2 + 1]` spectrum; inverse (C2R) is the mirror image.
fn synth_rfft1d(dir: &Path, algo: &str, n: usize, batch: usize, inverse: bool) -> VariantMeta {
    let d = if inverse { "inv" } else { "fwd" };
    let key = format!("rfft1d_{algo}_n{n}_b{batch}_{d}");
    let m = n / 2;
    let stages: Vec<StageMeta> = rfft_schedule(n, 1, inverse)
        .iter()
        .map(|s| {
            // the half-spectrum pass spans the full n; the complex
            // stages live inside the half-size transform
            let span = if s.kernel == "r2c_post" || s.kernel == "c2r_pre" { n } else { m };
            stage_meta_from_planned(s, span)
        })
        .collect();
    let flops_per_seq: f64 = stages.iter().map(|s| s.flops).sum();
    let hbm_bytes_per_seq: f64 = stages.iter().map(|s| s.hbm_bytes).sum();
    let input_shape = if inverse { vec![batch, m + 1] } else { vec![batch, n] };
    VariantMeta {
        file: dir.join(format!("{key}.hlo.txt")),
        key,
        op: "rfft1d".to_string(),
        algo: algo.to_string(),
        n,
        nx: 0,
        ny: 0,
        batch,
        inverse,
        input_shape,
        stages,
        flops_per_seq,
        hbm_bytes_per_seq,
        // a real transform carries half the equivalent complex work
        radix2_equiv_flops: radix2_equivalent_flops(n, batch) / 2.0,
    }
}

/// Real-input 2D variant: an `nx` x `ny` real transform served by
/// row-wise `ny`-point real transforms (half-size complex stages plus
/// the fused half-spectrum pass) followed by `nx`-point complex column
/// transforms over the packed `ny/2 + 1` Hermitian bins. Forward (R2C)
/// consumes `[batch, nx, ny]` real fields and emits the packed
/// `[batch, nx, ny/2 + 1]` spectrum; inverse (C2R) is the mirror
/// image, scaled by `nx * ny` (unnormalized).
fn synth_rfft2d(
    dir: &Path,
    algo: &str,
    nx: usize,
    ny: usize,
    batch: usize,
    inverse: bool,
) -> VariantMeta {
    let d = if inverse { "inv" } else { "fwd" };
    let key = format!("rfft2d_{algo}_nx{nx}x{ny}_b{batch}_{d}");
    let m = ny / 2;
    let stages: Vec<StageMeta> = rfft2d_schedule(nx, ny, inverse)
        .iter()
        .map(|s| {
            // the half-spectrum pass spans the full row length ny; the
            // other row stages live inside the half-size transform;
            // column stages (lane > 1) span the nx axis
            let span = if s.kernel == "r2c_post" || s.kernel == "c2r_pre" {
                ny
            } else if s.lane == 1 {
                m
            } else {
                nx
            };
            stage_meta_from_planned(s, span)
        })
        .collect();
    let flops_per_seq: f64 = stages.iter().map(|s| s.flops).sum();
    let hbm_bytes_per_seq: f64 = stages.iter().map(|s| s.hbm_bytes).sum();
    let input_shape = if inverse { vec![batch, nx, m + 1] } else { vec![batch, nx, ny] };
    VariantMeta {
        file: dir.join(format!("{key}.hlo.txt")),
        key,
        op: "rfft2d".to_string(),
        algo: algo.to_string(),
        n: 0,
        nx,
        ny,
        batch,
        inverse,
        input_shape,
        stages,
        flops_per_seq,
        hbm_bytes_per_seq,
        // a real transform carries half the equivalent complex work
        radix2_equiv_flops: radix2_equivalent_flops(nx * ny, batch) / 2.0,
    }
}

fn synth_fft2d(
    dir: &Path,
    algo: &str,
    nx: usize,
    ny: usize,
    batch: usize,
    inverse: bool,
) -> VariantMeta {
    let key = synth_key("fft2d", algo, 0, nx, ny, batch, inverse);
    let stages = if algo == "r2" {
        synth_r2_stages(nx * ny)
    } else {
        // contiguous ny pass first, then the strided nx pass (lane=ny)
        let mut st = synth_axis_stages(algo, ny, 1);
        st.extend(synth_axis_stages(algo, nx, ny));
        st
    };
    let flops_per_seq: f64 = stages.iter().map(|s| s.flops).sum();
    let hbm_bytes_per_seq: f64 = stages.iter().map(|s| s.hbm_bytes).sum();
    VariantMeta {
        file: dir.join(format!("{key}.hlo.txt")),
        key,
        op: "fft2d".to_string(),
        algo: algo.to_string(),
        n: 0,
        nx,
        ny,
        batch,
        inverse,
        input_shape: vec![batch, nx, ny],
        stages,
        flops_per_seq,
        hbm_bytes_per_seq,
        radix2_equiv_flops: radix2_equivalent_flops(nx * ny, batch),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "format": 1, "dtype": "f16", "variants": [
        {"key": "fft1d_tc_n256_b4_fwd", "file": "a.hlo.txt", "op": "fft1d",
         "algo": "tc", "n": 256, "nx": 0, "ny": 0, "batch": 4,
         "inverse": false, "input_shape": [4, 256],
         "stages": [{"kernel": "fused256_first", "radix": 256, "n2": 1,
                     "lane": 1, "flops": 100, "hbm_bytes": 2048,
                     "vmem_bytes": 4096}],
         "flops_per_seq": 100, "hbm_bytes_per_seq": 2048,
         "radix2_equiv_flops": 24576},
        {"key": "fft1d_tc_n256_b16_fwd", "file": "b.hlo.txt", "op": "fft1d",
         "algo": "tc", "n": 256, "nx": 0, "ny": 0, "batch": 16,
         "inverse": false, "input_shape": [16, 256], "stages": [],
         "flops_per_seq": 100, "hbm_bytes_per_seq": 2048,
         "radix2_equiv_flops": 98304}
      ]}"#;

    #[test]
    fn parses_and_indexes() {
        let r = Registry::from_json_str(MINI, PathBuf::from("/tmp")).unwrap();
        assert_eq!(r.variants.len(), 2);
        assert!(!r.synthesized);
        let v = r.get("fft1d_tc_n256_b4_fwd").unwrap();
        assert_eq!(v.batch, 4);
        assert_eq!(v.stages.len(), 1);
        assert_eq!(v.stages[0].kernel, "fused256_first");
        assert_eq!(v.seq_len(), 256);
        assert_eq!(v.total_elems(), 1024);
    }

    #[test]
    fn batch_selection_prefers_smallest_sufficient() {
        let r = Registry::from_json_str(MINI, PathBuf::from("/tmp")).unwrap();
        assert_eq!(r.find_fft1d(256, 2, "tc", false).unwrap().batch, 4);
        assert_eq!(r.find_fft1d(256, 4, "tc", false).unwrap().batch, 4);
        assert_eq!(r.find_fft1d(256, 9, "tc", false).unwrap().batch, 16);
        // oversize: fall back to largest (caller splits)
        assert_eq!(r.find_fft1d(256, 100, "tc", false).unwrap().batch, 16);
        assert!(r.find_fft1d(512, 1, "tc", false).is_none());
    }

    #[test]
    fn rejects_bad_manifest() {
        assert!(Registry::from_json_str("{}", PathBuf::from("/tmp")).is_err());
        assert!(Registry::from_json_str("{\"variants\": []}", PathBuf::from("/tmp")).is_err());
        assert!(Registry::from_json_str("not json", PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn synthesized_catalog_covers_the_aot_matrix() {
        let r = Registry::synthesize();
        assert!(r.synthesized);
        // keys used by benches, examples and the integration suites
        for key in [
            "fft1d_tc_n256_b4_fwd",
            "fft1d_tc_n1024_b4_fwd",
            "fft1d_tc_n1024_b32_fwd",
            "fft1d_tc_n4096_b4_fwd",
            "fft1d_tc_n4096_b4_inv",
            "fft1d_r2_n4096_b4_fwd",
            "fft1d_tc_split_n4096_b4_fwd",
            "fft1d_tc_ec_n4096_b4_fwd",
            "fft1d_tc_ec_n4096_b32_fwd",
            "fft1d_tc_ec_n1024_b32_fwd",
            "fft2d_tc_ec_nx256x256_b2_fwd",
            "fft1d_tc_n65536_b4_fwd",
            "fft1d_tc_n131072_b1_fwd",
            "fft1d_tc_n131072_b16_fwd",
            "fft2d_tc_nx128x128_b2_fwd",
            "fft2d_tc_nx256x256_b2_fwd",
            "fft2d_tc_nx256x256_b2_inv",
            "fft2d_r2_nx256x256_b2_fwd",
            "fft2d_tc_nx512x256_b2_fwd",
            "fft2d_r2_nx512x256_b2_fwd",
            "fft2d_tc_nx512x512_b2_fwd",
        ] {
            assert!(r.variants.contains_key(key), "missing {key}");
        }
        // the full forward+inverse tc ladder
        for t in 1..=17usize {
            let n = 1usize << t;
            assert!(r.find_fft1d(n, 1, "tc", false).is_some(), "no fwd n={n}");
            assert!(r.find_fft1d(n, 1, "tc", true).is_some(), "no inv n={n}");
        }
        // no catalog entry above 2^17 (tests rely on this failing)
        assert!(r.find_fft1d(1 << 20, 1, "tc", false).is_none());
    }

    #[test]
    fn synthesized_catalog_has_the_real_ladder() {
        let r = Registry::synthesize();
        for t in 2..=17usize {
            let n = 1usize << t;
            let fwd = r.find_rfft1d(n, 1, "tc", false).expect("fwd rfft variant");
            assert_eq!(fwd.input_shape, vec![4, n], "n={n}");
            let inv = r.find_rfft1d(n, 1, "tc", true).expect("inv rfft variant");
            assert_eq!(inv.input_shape, vec![4, n / 2 + 1], "n={n}");
            assert_eq!(inv.seq_len(), n);
        }
        // the real ladder mirrors the complex one's upper bound
        assert!(r.find_rfft1d(1 << 20, 1, "tc", false).is_none());
        // and does not leak into complex lookups
        assert_eq!(r.find_fft1d(4096, 4, "tc", false).unwrap().op, "fft1d");
    }

    #[test]
    fn synthesized_catalog_has_the_real_2d_ladder() {
        let r = Registry::synthesize();
        for t in 3..=8usize {
            let n = 1usize << t;
            let fwd = r.find_rfft2d(n, n, 1, "tc", false).expect("fwd rfft2d variant");
            assert_eq!(fwd.input_shape, vec![4, n, n], "{n}x{n}");
            assert_eq!(fwd.seq_len(), n * n);
            let inv = r.find_rfft2d(n, n, 1, "tc", true).expect("inv rfft2d variant");
            assert_eq!(inv.input_shape, vec![4, n, n / 2 + 1], "{n}x{n}");
        }
        // the rectangular shapes are distinct variants
        assert!(r.find_rfft2d(64, 128, 1, "tc", false).is_some());
        assert!(r.find_rfft2d(128, 64, 1, "tc", false).is_some());
        // no catalog entry beyond the ladder, and no leakage into the
        // complex 2D lookups
        assert!(r.find_rfft2d(512, 512, 1, "tc", false).is_none());
        assert_eq!(r.find_fft2d(128, 128, 1, "tc", false).unwrap().op, "fft2d");
    }

    #[test]
    fn synthesized_catalog_has_the_ec_ladder() {
        let r = Registry::synthesize();
        for t in 1..=17usize {
            let n = 1usize << t;
            assert!(r.find_fft1d(n, 1, "tc_ec", false).is_some(), "no ec fwd n={n}");
            assert!(r.find_fft1d(n, 1, "tc_ec", true).is_some(), "no ec inv n={n}");
        }
        for t in 2..=17usize {
            let n = 1usize << t;
            assert!(r.find_rfft1d(n, 1, "tc_ec", false).is_some(), "no ec rfft fwd n={n}");
            assert!(r.find_rfft1d(n, 1, "tc_ec", true).is_some(), "no ec rfft inv n={n}");
        }
        for t in 3..=8usize {
            let n = 1usize << t;
            assert!(r.find_rfft2d(n, n, 1, "tc_ec", false).is_some(), "no ec rfft2d {n}x{n}");
        }
        // ec stages carry the de-fused (split) schedule shape
        let v = r.get("fft1d_tc_ec_n4096_b4_fwd").unwrap();
        let s = r.get("fft1d_tc_split_n4096_b4_fwd").unwrap();
        let kernels =
            |m: &VariantMeta| m.stages.iter().map(|st| st.kernel.clone()).collect::<Vec<_>>();
        assert_eq!(kernels(v), kernels(s));
    }

    #[test]
    fn synthesized_stages_reconstruct_sizes() {
        let r = Registry::synthesize();
        for v in r.variants.values() {
            if v.algo == "r2" {
                continue; // baseline carries a stockham schedule
            }
            let product: usize = v.stages.iter().map(|s| s.radix).product();
            assert_eq!(product, v.seq_len(), "key {}", v.key);
            assert!(v.flops_per_seq > 0.0, "key {}", v.key);
        }
    }
}
