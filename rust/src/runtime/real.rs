//! Half-spectrum split/merge kernels for real-input transforms — the
//! shared numeric core of the R2C/C2R path.
//!
//! An N-point real FFT is computed as an M = N/2-point *complex* FFT
//! plus one O(N) post-processing pass (and symmetrically for the
//! inverse): pack the real samples pairwise into complex values
//! `z[j] = x[2j] + i*x[2j+1]`, transform, then split the half-size
//! spectrum `Z` into the Hermitian-packed spectrum `G[0..=M]` of the
//! real signal using the identities
//!
//! ```text
//!   E[k] = (Z[k] + conj(Z[M-k])) / 2          (FFT of the even samples)
//!   O[k] = (Z[k] - conj(Z[M-k])) / (2i)       (FFT of the odd samples)
//!   G[k]   = E[k] + W_N^k * O[k]              W_N^k = e^(-2*pi*i*k/N)
//!   G[M-k] = conj(E[k]) - conj(W_N^k * O[k])
//! ```
//!
//! so one table of `W_N^k` for `k = 0..=M/2` serves every bin pair.
//! The inverse pre-processing inverts the split exactly, scaled so the
//! unnormalized M-point inverse FFT yields `N * x` (the cuFFT C2R
//! convention, matching the crate-wide unnormalized inverse):
//!
//! ```text
//!   Z'[k] = (G[k] + conj(G[M-k])) + i * conj(W_N^k) * (G[k] - conj(G[M-k]))
//! ```
//!
//! # fp16 rounding points
//!
//! The pass honors the same device contract as the merge stages
//! (see [`crate::runtime::interpreter`]): the `W_N^k` operand table is
//! rounded to fp16 once at build time, inputs arrive as fp16 values,
//! all arithmetic accumulates in f32, and outputs are rounded back to
//! fp16 on store. Packing/unpacking are pure data movement and round
//! nothing.
//!
//! When built with [`RealHalfSpectrum::with_ec`] for the `tc_ec` tier,
//! the pass applies the same error-corrected scheme as the merge
//! stages: the `W` table keeps fp16 lo residuals alongside the hi
//! halves, every product of carried values is the three-term
//! compensated form, and stores write fresh hi + lo pairs. The
//! Hermitian-real endpoint bins still come out with exactly zero
//! imaginary part (every term of their lo correction is zero).
//!
//! Both execution engines — the [`crate::runtime::CpuInterpreter`]
//! stage pipeline and the [`crate::large::RealFourStepPlan`] four-step
//! composition — run these exact kernels, so the two R2C paths share
//! one numeric definition.

use crate::hp::F16;

/// fp16 rounding on the store path (bit-identical to the codec).
#[inline]
fn rnd16(x: f32) -> f32 {
    F16::round_f32(x)
}

/// `tc_ec` splitter: fp16 hi half plus fp16-rounded lo residual.
#[inline]
fn ec_split16(x: f32) -> (f32, f32) {
    let h = rnd16(x);
    (h, rnd16(x - h))
}

/// `tc_ec` store: carried hi + lo sum, saturating on fp16 overflow
/// (the `inf + -inf` residual would otherwise produce NaN).
#[inline]
fn ec_store(x: f32) -> f32 {
    let h = rnd16(x);
    if h.is_finite() { h + rnd16(x - h) } else { h }
}

/// Compensated hi/lo product `(ah*bh + ah*bl) + al*bh`, matching the
/// interpreter's `ec_mul` term order exactly.
#[inline]
fn ec_mul(ah: f32, al: f32, bh: f32, bl: f32) -> f32 {
    (ah * bh + ah * bl) + al * bh
}

/// Precomputed half-spectrum split/merge pass for one real size `n`.
///
/// Holds the fp16-rounded `W_N^k` twiddle table (`k = 0..=n/4`) and
/// applies the forward split ([`split_rows`](Self::split_rows)) or the
/// inverse merge ([`merge_rows`](Self::merge_rows)) batch-major over
/// planar rows, plus the lossless pack/unpack reshuffles.
pub struct RealHalfSpectrum {
    /// half size: the length of the underlying complex transform
    m: usize,
    /// fp16-rounded `cos(-2*pi*k/n)` for `k = 0..=m/2`
    w_re: Vec<f32>,
    /// fp16-rounded `sin(-2*pi*k/n)` for `k = 0..=m/2`
    w_im: Vec<f32>,
    /// fp16 lo residuals of the table (`tc_ec` only, else empty)
    w_re_lo: Vec<f32>,
    w_im_lo: Vec<f32>,
    /// error-corrected tier: compensated products, hi + lo stores
    ec: bool,
}

impl RealHalfSpectrum {
    /// Build the pass for an `n`-point real transform (`n` a power of
    /// two, `n >= 4`). The same table serves forward and inverse.
    pub fn new(n: usize) -> RealHalfSpectrum {
        Self::with_ec(n, false)
    }

    /// [`new`](Self::new) with the `tc_ec` error-corrected scheme
    /// switched on: the `W` table carries fp16 lo residuals and the
    /// split/merge kernels run compensated products with hi + lo
    /// stores.
    pub fn with_ec(n: usize, ec: bool) -> RealHalfSpectrum {
        assert!(n.is_power_of_two() && n >= 4, "real FFT size {n} must be a power of two >= 4");
        let m = n / 2;
        let half = m / 2;
        let mut w_re = Vec::with_capacity(half + 1);
        let mut w_im = Vec::with_capacity(half + 1);
        let mut w_re_lo = Vec::with_capacity(if ec { half + 1 } else { 0 });
        let mut w_im_lo = Vec::with_capacity(if ec { half + 1 } else { 0 });
        for k in 0..=half {
            let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
            let (cr, ci) = (ang.cos() as f32, ang.sin() as f32);
            let (hr, hi) = (rnd16(cr), rnd16(ci));
            w_re.push(hr);
            w_im.push(hi);
            if ec {
                w_re_lo.push(rnd16(cr - hr));
                w_im_lo.push(rnd16(ci - hi));
            }
        }
        RealHalfSpectrum { m, w_re, w_im, w_re_lo, w_im_lo, ec }
    }

    /// True when the pass runs the `tc_ec` error-corrected kernels.
    pub fn ec(&self) -> bool {
        self.ec
    }

    /// The real transform length `n`.
    pub fn n(&self) -> usize {
        2 * self.m
    }

    /// The underlying complex transform length `m = n/2`.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Bins in the Hermitian-packed spectrum: `n/2 + 1`.
    pub fn packed_len(&self) -> usize {
        self.m + 1
    }

    /// Pack `rows` real rows (length `n`, read from `src_re` with the
    /// row stride `n`) into complex rows `z[j] = x[2j] + i*x[2j+1]`
    /// (length `m`). Pure data movement — no rounding.
    pub fn pack_rows(&self, src_re: &[f32], z_re: &mut [f32], z_im: &mut [f32], rows: usize) {
        let (n, m) = (2 * self.m, self.m);
        assert_eq!(src_re.len(), rows * n, "pack: source/shape mismatch");
        assert_eq!(z_re.len(), rows * m, "pack: dest/shape mismatch");
        for row in 0..rows {
            let src = &src_re[row * n..(row + 1) * n];
            let base = row * m;
            for j in 0..m {
                z_re[base + j] = src[2 * j];
                z_im[base + j] = src[2 * j + 1];
            }
        }
    }

    /// The shared unpack body: `map(j)` is the in-row offset complex
    /// sample `j` is read from (identity for the contiguous layout, the
    /// transpose gather for the four-step pre-read-out layout). Pure
    /// data movement either way — no rounding.
    #[inline]
    fn unpack_rows_mapped(
        &self,
        z_re: &[f32],
        z_im: &[f32],
        out_re: &mut [f32],
        rows: usize,
        map: impl Fn(usize) -> usize,
    ) {
        let (n, m) = (2 * self.m, self.m);
        assert_eq!(z_re.len(), rows * m, "unpack: source/shape mismatch");
        assert_eq!(out_re.len(), rows * n, "unpack: dest/shape mismatch");
        for row in 0..rows {
            let base = row * m;
            let dst = &mut out_re[row * n..(row + 1) * n];
            for j in 0..m {
                let s = base + map(j);
                dst[2 * j] = z_re[s];
                dst[2 * j + 1] = z_im[s];
            }
        }
    }

    /// Unpack `rows` complex rows (length `m`) back into real rows
    /// (length `n`): `x[2j] = Re z[j]`, `x[2j + 1] = Im z[j]`, written
    /// to the `out_re` plane. Pure data movement — no rounding.
    pub fn unpack_rows(&self, z_re: &[f32], z_im: &[f32], out_re: &mut [f32], rows: usize) {
        self.unpack_rows_mapped(z_re, z_im, out_re, rows, |j| j);
    }

    /// [`unpack_rows`](Self::unpack_rows) fused with the four-step
    /// engine's final read-out transpose: sample `j = k*n1 + jj` of a
    /// length-`m = n1*n2` time-domain sequence is gathered from in-row
    /// offset `jj*n2 + k` (see
    /// [`split_rows_fourstep`](Self::split_rows_fourstep) for the
    /// layout), so the inverse path also skips the engine's final
    /// transpose and copy-back. Bit-identical to transposing first.
    pub fn unpack_rows_fourstep(
        &self,
        z_re: &[f32],
        z_im: &[f32],
        out_re: &mut [f32],
        rows: usize,
        (n1, n2): (usize, usize),
    ) {
        assert_eq!(n1 * n2, self.m, "unpack: four-step factors must multiply to m");
        self.unpack_rows_mapped(z_re, z_im, out_re, rows, move |j| (j % n1) * n2 + j / n1);
    }

    /// The shared split body: identical arithmetic for the contiguous
    /// and the four-step-layout variants, differing only in where bin
    /// `i` of `Z` is READ from (`map(i)`, an in-row offset). Writes are
    /// always to the contiguous packed `G` layout. Keeping one body
    /// guarantees the fused four-step read-out is bit-identical to the
    /// transpose-then-split formulation it replaces.
    #[inline]
    fn split_rows_mapped(
        &self,
        z_re: &[f32],
        z_im: &[f32],
        g_re: &mut [f32],
        g_im: &mut [f32],
        rows: usize,
        map: impl Fn(usize) -> usize,
    ) {
        let m = self.m;
        assert_eq!(z_re.len(), rows * m, "split: source/shape mismatch");
        assert_eq!(g_re.len(), rows * (m + 1), "split: dest/shape mismatch");
        for row in 0..rows {
            let zb = row * m;
            let gb = row * (m + 1);
            for k in 0..=m / 2 {
                // a = Z[k], b = Z[m-k] (Z[m] wraps to Z[0])
                let ia = zb + map(k % m);
                let ib = zb + map((m - k) % m);
                let (ar, ai) = (z_re[ia], z_im[ia]);
                let (br, bi) = (z_re[ib], z_im[ib]);
                let (er, ei) = (0.5 * (ar + br), 0.5 * (ai - bi));
                let (or_, oi) = (0.5 * (ai + bi), 0.5 * (br - ar));
                let (wr, wi) = (self.w_re[k], self.w_im[k]);
                let (tr, ti) = if self.ec {
                    // compensated W*O against the hi/lo table; O is a
                    // full f32 combination, so split it fresh
                    let (wrl, wil) = (self.w_re_lo[k], self.w_im_lo[k]);
                    let (orh, orl) = ec_split16(or_);
                    let (oih, oil) = ec_split16(oi);
                    (
                        ec_mul(orh, orl, wr, wrl) - ec_mul(oih, oil, wi, wil),
                        ec_mul(orh, orl, wi, wil) + ec_mul(oih, oil, wr, wrl),
                    )
                } else {
                    (wr * or_ - wi * oi, wr * oi + wi * or_)
                };
                if self.ec {
                    g_re[gb + k] = ec_store(er + tr);
                    g_im[gb + k] = ec_store(ei + ti);
                    g_re[gb + m - k] = ec_store(er - tr);
                    g_im[gb + m - k] = ec_store(ti - ei);
                } else {
                    g_re[gb + k] = rnd16(er + tr);
                    g_im[gb + k] = rnd16(ei + ti);
                    // k = m/2 writes its own (self-paired) bin twice
                    // with the identical value, so no guard is needed
                    g_re[gb + m - k] = rnd16(er - tr);
                    g_im[gb + m - k] = rnd16(ti - ei);
                }
            }
        }
    }

    /// Forward split: turn `rows` half-size spectra `Z` (length `m`)
    /// into Hermitian-packed real spectra `G` (length `m + 1`), one
    /// fused pass per bin pair against the fp16 `W` table, f32
    /// arithmetic, fp16 stores. Bins 0 and `m` come out with exactly
    /// zero imaginary part (they are real by Hermitian symmetry).
    pub fn split_rows(
        &self,
        z_re: &[f32],
        z_im: &[f32],
        g_re: &mut [f32],
        g_im: &mut [f32],
        rows: usize,
    ) {
        self.split_rows_mapped(z_re, z_im, g_re, g_im, rows, |i| i);
    }

    /// [`split_rows`](Self::split_rows) fused with the four-step
    /// engine's final read-out transpose: `Z` arrives in the engine's
    /// pre-read-out layout for top-level factors `(n1, n2)`, where
    /// logical bin `i = k*n1 + j` of a length-`m = n1*n2` sequence
    /// sits at in-row offset `j*n2 + k` (i.e. row-major `M[j][k]` with
    /// `X[k*n1 + j] = M[j][k]`). The split gathers straight from that
    /// layout, so the engine's final transpose pass and its copy-back
    /// are skipped entirely. Same arithmetic, same fp16 rounding
    /// points, bit-identical output to transposing first and then
    /// calling `split_rows`.
    pub fn split_rows_fourstep(
        &self,
        z_re: &[f32],
        z_im: &[f32],
        g_re: &mut [f32],
        g_im: &mut [f32],
        rows: usize,
        (n1, n2): (usize, usize),
    ) {
        assert_eq!(n1 * n2, self.m, "split: four-step factors must multiply to m");
        self.split_rows_mapped(z_re, z_im, g_re, g_im, rows, move |i| (i % n1) * n2 + i / n1);
    }

    /// Inverse merge: turn `rows` Hermitian-packed spectra `G` (length
    /// `m + 1`) into half-size spectra `Z'` (length `m`), scaled so the
    /// unnormalized inverse M-point FFT of `Z'` unpacks to `n * x`.
    /// Same fused structure, fp16 `W` table, f32 arithmetic, fp16
    /// stores.
    pub fn merge_rows(
        &self,
        g_re: &[f32],
        g_im: &[f32],
        z_re: &mut [f32],
        z_im: &mut [f32],
        rows: usize,
    ) {
        let m = self.m;
        assert_eq!(g_re.len(), rows * (m + 1), "merge: source/shape mismatch");
        assert_eq!(z_re.len(), rows * m, "merge: dest/shape mismatch");
        for row in 0..rows {
            let gb = row * (m + 1);
            let zb = row * m;
            for k in 0..=m / 2 {
                // g = G[k], h = G[m-k]; S = g + conj h, D = g - conj h
                let (gr, gi) = (g_re[gb + k], g_im[gb + k]);
                let (hr, hi) = (g_re[gb + m - k], g_im[gb + m - k]);
                let (sr, si) = (gr + hr, gi - hi);
                let (dr, di) = (gr - hr, gi + hi);
                let (wr, wi) = (self.w_re[k], self.w_im[k]);
                if self.ec {
                    // the four compensated products; both bins of the
                    // pair reuse them with the plain path's term order
                    let (wrl, wil) = (self.w_re_lo[k], self.w_im_lo[k]);
                    let (drh, drl) = ec_split16(dr);
                    let (dih, dil) = ec_split16(di);
                    let p_wr_di = ec_mul(dih, dil, wr, wrl);
                    let p_wi_dr = ec_mul(drh, drl, wi, wil);
                    let p_wr_dr = ec_mul(drh, drl, wr, wrl);
                    let p_wi_di = ec_mul(dih, dil, wi, wil);
                    z_re[zb + k % m] = ec_store(sr - p_wr_di + p_wi_dr);
                    z_im[zb + k % m] = ec_store(si + p_wr_dr + p_wi_di);
                    if k > 0 && m - k != k {
                        z_re[zb + m - k] = ec_store(sr + p_wr_di - p_wi_dr);
                        z_im[zb + m - k] = ec_store(p_wr_dr + p_wi_di - si);
                    }
                } else {
                    // Z'[k] = S + i * conj(W^k) * D
                    z_re[zb + k % m] = rnd16(sr - wr * di + wi * dr);
                    z_im[zb + k % m] = rnd16(si + wr * dr + wi * di);
                    if k > 0 && m - k != k {
                        // Z'[m-k] = conj-symmetric partner through -W^k
                        z_re[zb + m - k] = rnd16(sr + wr * di - wi * dr);
                        z_im[zb + m - k] = rnd16(wr * dr + wi * di - si);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::refdft;
    use crate::hp::C64;

    /// f64 model of one split+transform against the packed layout.
    fn oracle_packed(x: &[f64]) -> Vec<C64> {
        let n = x.len();
        let xc: Vec<C64> = x.iter().map(|&v| C64::new(v, 0.0)).collect();
        refdft::dft(&xc, false)[..n / 2 + 1].to_vec()
    }

    /// Exact f64 complex DFT of the packed pairs, quantized through the
    /// same fp16 codec the kernels use.
    fn fp16v(x: f64) -> f32 {
        F16::from_f32(x as f32).to_f32()
    }

    #[test]
    fn split_matches_definition_on_small_sizes() {
        for n in [4usize, 8, 16, 64] {
            let m = n / 2;
            let x: Vec<f64> = (0..n).map(|i| ((i * 37 + 11) % 17) as f64 / 17.0 - 0.5).collect();
            let xq: Vec<f32> = x.iter().map(|&v| fp16v(v)).collect();
            // exact half-size complex DFT of the packed pairs
            let z: Vec<C64> = (0..m)
                .map(|j| C64::new(xq[2 * j] as f64, xq[2 * j + 1] as f64))
                .collect();
            let zf = refdft::dft(&z, false);
            let (z_re, z_im): (Vec<f32>, Vec<f32>) = zf
                .iter()
                .map(|c| (fp16v(c.re), fp16v(c.im)))
                .unzip();
            let rs = RealHalfSpectrum::new(n);
            let mut g_re = vec![0f32; m + 1];
            let mut g_im = vec![0f32; m + 1];
            rs.split_rows(&z_re, &z_im, &mut g_re, &mut g_im, 1);
            let want = oracle_packed(&xq.iter().map(|&v| v as f64).collect::<Vec<_>>());
            for k in 0..=m {
                let got = C64::new(g_re[k] as f64, g_im[k] as f64);
                assert!(
                    (got - want[k]).abs() < 0.05 * (n as f64).sqrt(),
                    "n={n} bin {k}: got {got:?} want {:?}",
                    want[k]
                );
            }
            // Hermitian endpoints are exactly real
            assert_eq!(g_im[0], 0.0, "n={n}: bin 0 must be real");
            assert_eq!(g_im[m], 0.0, "n={n}: bin m must be real");
        }
    }

    #[test]
    fn merge_inverts_split() {
        // split then merge recovers 2*Z (the C2R doubling that makes
        // the unnormalized inverse land at N*x instead of (N/2)*x)
        let n = 32;
        let m = n / 2;
        let z_re: Vec<f32> = (0..m).map(|j| fp16v((j as f64 * 0.73).sin())).collect();
        let z_im: Vec<f32> = (0..m).map(|j| fp16v((j as f64 * 1.19).cos())).collect();
        let rs = RealHalfSpectrum::new(n);
        let mut g_re = vec![0f32; m + 1];
        let mut g_im = vec![0f32; m + 1];
        rs.split_rows(&z_re, &z_im, &mut g_re, &mut g_im, 1);
        let mut back_re = vec![0f32; m];
        let mut back_im = vec![0f32; m];
        rs.merge_rows(&g_re, &g_im, &mut back_re, &mut back_im, 1);
        for j in 0..m {
            assert!(
                (back_re[j] - 2.0 * z_re[j]).abs() < 0.01,
                "re[{j}]: {} vs {}",
                back_re[j],
                2.0 * z_re[j]
            );
            assert!((back_im[j] - 2.0 * z_im[j]).abs() < 0.01, "im[{j}]");
        }
    }

    #[test]
    fn pack_unpack_round_trip() {
        let n = 16;
        let rs = RealHalfSpectrum::new(n);
        let x: Vec<f32> = (0..n).map(|i| i as f32 * 0.25 - 2.0).collect();
        let mut z_re = vec![0f32; n / 2];
        let mut z_im = vec![0f32; n / 2];
        rs.pack_rows(&x, &mut z_re, &mut z_im, 1);
        assert_eq!(z_re[1], x[2]);
        assert_eq!(z_im[1], x[3]);
        let mut back = vec![0f32; n];
        rs.unpack_rows(&z_re, &z_im, &mut back, 1);
        assert_eq!(back, x);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_tiny_sizes() {
        RealHalfSpectrum::new(2);
    }

    #[test]
    fn ec_split_keeps_endpoints_real_and_merge_inverts_tightly() {
        let n = 32;
        let m = n / 2;
        // carried hi + lo inputs, as the ec pipeline produces
        let ec2 = |x: f32| {
            let h = fp16v(x as f64);
            h + fp16v((x - h) as f64)
        };
        let z_re: Vec<f32> = (0..m).map(|j| ec2((j as f32 * 0.73).sin())).collect();
        let z_im: Vec<f32> = (0..m).map(|j| ec2((j as f32 * 1.19).cos())).collect();
        let rs = RealHalfSpectrum::with_ec(n, true);
        assert!(rs.ec());
        let mut g_re = vec![0f32; m + 1];
        let mut g_im = vec![0f32; m + 1];
        rs.split_rows(&z_re, &z_im, &mut g_re, &mut g_im, 1);
        // Hermitian endpoints stay exactly real under compensation
        assert_eq!(g_im[0], 0.0);
        assert_eq!(g_im[m], 0.0);
        let mut back_re = vec![0f32; m];
        let mut back_im = vec![0f32; m];
        rs.merge_rows(&g_re, &g_im, &mut back_re, &mut back_im, 1);
        for j in 0..m {
            // split-then-merge recovers 2*Z; the ec round trip holds
            // orders of magnitude tighter than the fp16 one (~1e-2)
            assert!(
                (back_re[j] - 2.0 * z_re[j]).abs() < 1e-5,
                "re[{j}]: {} vs {}",
                back_re[j],
                2.0 * z_re[j]
            );
            assert!((back_im[j] - 2.0 * z_im[j]).abs() < 1e-5, "im[{j}]");
        }
    }

    /// Write a contiguous length-`m` row into the four-step
    /// pre-read-out layout: logical bin `k*n1 + j` lands at `j*n2 + k`.
    fn to_fourstep_layout(x: &[f32], n1: usize, n2: usize) -> Vec<f32> {
        let m = n1 * n2;
        assert_eq!(x.len(), m);
        let mut out = vec![0f32; m];
        for i in 0..m {
            out[(i % n1) * n2 + i / n1] = x[i];
        }
        out
    }

    #[test]
    fn fourstep_split_is_bitwise_identical_to_transpose_then_split() {
        let n = 64;
        let (m, n1, n2) = (n / 2, 8usize, 4usize);
        let z_re: Vec<f32> = (0..m).map(|j| fp16v((j as f64 * 0.61).sin())).collect();
        let z_im: Vec<f32> = (0..m).map(|j| fp16v((j as f64 * 1.37).cos())).collect();
        let rs = RealHalfSpectrum::new(n);
        let mut want_re = vec![0f32; m + 1];
        let mut want_im = vec![0f32; m + 1];
        rs.split_rows(&z_re, &z_im, &mut want_re, &mut want_im, 1);
        let (t_re, t_im) = (to_fourstep_layout(&z_re, n1, n2), to_fourstep_layout(&z_im, n1, n2));
        let mut got_re = vec![0f32; m + 1];
        let mut got_im = vec![0f32; m + 1];
        rs.split_rows_fourstep(&t_re, &t_im, &mut got_re, &mut got_im, 1, (n1, n2));
        for k in 0..=m {
            assert_eq!(want_re[k].to_bits(), got_re[k].to_bits(), "re[{k}]");
            assert_eq!(want_im[k].to_bits(), got_im[k].to_bits(), "im[{k}]");
        }
    }

    #[test]
    fn fourstep_unpack_is_bitwise_identical_to_transpose_then_unpack() {
        let n = 32;
        let (m, n1, n2) = (n / 2, 4usize, 4usize);
        let z_re: Vec<f32> = (0..m).map(|j| j as f32 * 0.125 - 1.0).collect();
        let z_im: Vec<f32> = (0..m).map(|j| 2.0 - j as f32 * 0.25).collect();
        let rs = RealHalfSpectrum::new(n);
        let mut want = vec![0f32; n];
        rs.unpack_rows(&z_re, &z_im, &mut want, 1);
        let (t_re, t_im) = (to_fourstep_layout(&z_re, n1, n2), to_fourstep_layout(&z_im, n1, n2));
        let mut got = vec![0f32; n];
        rs.unpack_rows_fourstep(&t_re, &t_im, &mut got, 1, (n1, n2));
        assert_eq!(want, got);
    }
}
