//! Runtime layer: PJRT execution of AOT artifacts.
//!
//! `Runtime` = artifact `Registry` (manifest metadata) + `Executor`
//! engine (PJRT client + executable cache; thread-safe, compile-once).
//! This is the only module that touches the `xla` crate on the request
//! path; everything above it works with `PlanarBatch` host buffers.

pub mod buffers;
pub mod executor;
pub mod registry;

pub use buffers::PlanarBatch;
pub use executor::{ExecStats, Executor};
pub use registry::{Registry, StageMeta, VariantMeta};

use anyhow::Result;
use std::path::Path;
use std::sync::Arc;

/// Self-contained runtime: load artifacts, execute by key.
pub struct Runtime {
    pub registry: Arc<Registry>,
    executor: Executor,
}

impl Runtime {
    pub fn load(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let registry = Arc::new(Registry::load(artifact_dir)?);
        let executor = Executor::spawn()?;
        Ok(Runtime { registry, executor })
    }

    /// Default artifact directory: $TCFFT_ARTIFACTS or ./artifacts.
    pub fn load_default() -> Result<Runtime> {
        let dir = std::env::var("TCFFT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(dir)
    }

    pub fn handle(&self) -> &Executor {
        self.executor.handle()
    }

    /// Execute an artifact by key on a planar batch (blocking).
    pub fn execute(&self, key: &str, input: PlanarBatch) -> Result<(PlanarBatch, ExecStats)> {
        let meta = self.registry.get(key)?;
        anyhow::ensure!(
            input.shape == meta.input_shape,
            "input shape {:?} != artifact shape {:?} for {key}",
            input.shape,
            meta.input_shape
        );
        self.executor.handle().execute(key, &meta.file, input)
    }

    /// Pre-compile an artifact; returns compile seconds (0 if cached).
    pub fn warm(&self, key: &str) -> Result<f64> {
        let meta = self.registry.get(key)?;
        self.executor.handle().warm(key, &meta.file)
    }
}
