//! Runtime layer: pluggable execution of planned FFT artifacts.
//!
//! `Runtime` = artifact `Registry` (manifest metadata, or a synthesized
//! catalog when no artifacts exist on disk) + a [`Backend`] that
//! executes variants on `PlanarBatch` host buffers.
//!
//! Backends:
//! * [`CpuInterpreter`] (default, always available): executes the
//!   planner's radix-stage schedules directly in process with fp16
//!   operands and f32 accumulation — the offline stand-in for the
//!   paper's Tensor-Core kernels.
//! * `Executor` (feature `pjrt`, requires a vendored `xla` crate and
//!   AOT artifacts): compiles and runs the HLO text artifacts through
//!   a PJRT CPU client.

pub mod buffers;
#[cfg(feature = "pjrt")]
pub mod executor;
pub mod interpreter;
pub mod real;
pub mod registry;
pub mod simd;

pub use buffers::PlanarBatch;
#[cfg(feature = "pjrt")]
pub use executor::Executor;
pub use interpreter::{CpuInterpreter, ReferenceInterpreter};
pub use real::RealHalfSpectrum;
pub use registry::{Registry, StageMeta, VariantMeta};
pub use simd::SimdPath;

use std::path::Path;
use std::sync::Arc;

use crate::error::Result;

/// Execution statistics for one call.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    /// backend wall-clock (compile excluded)
    pub exec_seconds: f64,
    /// marshalling (f32<->f16 encode/decode + staging)
    pub marshal_seconds: f64,
    /// true if this call compiled/built the executable (cold start)
    pub compiled: bool,
}

/// An execution engine that can run any registry variant on planar
/// host buffers. Implementations must be thread-safe: the coordinator
/// calls `execute` concurrently from its worker pool.
pub trait Backend: Send + Sync {
    /// Short backend identifier for logs and metrics.
    fn name(&self) -> &'static str;

    /// Execute one variant on a planar batch (blocking). The input
    /// shape has already been validated against `meta.input_shape`.
    fn execute(&self, meta: &VariantMeta, input: PlanarBatch) -> Result<(PlanarBatch, ExecStats)>;

    /// Pre-compile/build a variant; returns build seconds (0 if cached).
    fn warm(&self, meta: &VariantMeta) -> Result<f64> {
        let _ = meta;
        Ok(0.0)
    }
}

/// Self-contained runtime: resolve artifacts, execute by key.
pub struct Runtime {
    pub registry: Arc<Registry>,
    backend: Box<dyn Backend>,
}

impl Runtime {
    /// Load from an artifact directory. When `<dir>/manifest.json` is
    /// missing the registry falls back to the synthesized catalog; the
    /// backend is the pure-Rust interpreter unless the `pjrt` feature
    /// is enabled and real artifacts are present.
    pub fn load(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifact_dir.as_ref();
        #[cfg(feature = "pjrt")]
        {
            if dir.join("manifest.json").is_file() {
                let registry = Arc::new(Registry::load(dir)?);
                let backend: Box<dyn Backend> = Box::new(Executor::spawn()?);
                return Ok(Runtime { registry, backend });
            }
        }
        let registry = Arc::new(Registry::load_or_synthesize(dir)?);
        Ok(Runtime { registry, backend: Box::new(CpuInterpreter::new()) })
    }

    /// Default artifact directory: $TCFFT_ARTIFACTS or ./artifacts.
    pub fn load_default() -> Result<Runtime> {
        let dir = std::env::var("TCFFT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(dir)
    }

    /// Assemble a runtime from explicit parts (tests, custom backends).
    pub fn with_backend(registry: Arc<Registry>, backend: Box<dyn Backend>) -> Runtime {
        Runtime { registry, backend }
    }

    /// The active backend's identifier.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Execute an artifact by key on a planar batch (blocking).
    pub fn execute(&self, key: &str, input: PlanarBatch) -> Result<(PlanarBatch, ExecStats)> {
        let meta = self.registry.get(key)?;
        crate::ensure!(
            input.shape == meta.input_shape,
            "input shape {:?} != artifact shape {:?} for {key}",
            input.shape,
            meta.input_shape
        );
        self.backend.execute(meta, input)
    }

    /// Pre-compile an artifact; returns compile seconds (0 if cached).
    pub fn warm(&self, key: &str) -> Result<f64> {
        let meta = self.registry.get(key)?;
        self.backend.warm(meta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_without_artifacts_synthesizes() {
        let rt = Runtime::load("/definitely/not/a/dir").unwrap();
        assert!(rt.registry.synthesized);
        assert_eq!(rt.backend_name(), "cpu-interpreter");
    }

    #[test]
    fn execute_checks_shape() {
        let rt = Runtime::load("/definitely/not/a/dir").unwrap();
        let bad = PlanarBatch::new(vec![4, 128]);
        assert!(rt.execute("fft1d_tc_n256_b4_fwd", bad).is_err());
        assert!(rt.execute("no_such_key", PlanarBatch::new(vec![1, 2])).is_err());
    }

    #[test]
    fn warm_by_key() {
        let rt = Runtime::load("/definitely/not/a/dir").unwrap();
        let first = rt.warm("fft1d_tc_n256_b4_fwd").unwrap();
        let second = rt.warm("fft1d_tc_n256_b4_fwd").unwrap();
        assert!(first >= 0.0);
        assert_eq!(second, 0.0);
    }
}
