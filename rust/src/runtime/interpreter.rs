//! Pure-Rust interpreter backend: executes the planner's radix-stage
//! schedules directly on `PlanarBatch` fp16 planar buffers, emulating
//! the Tensor-Core/MXU mma semantics of the paper (fp16 operands,
//! f32 accumulation) without PJRT, XLA or any artifact files.
//!
//! # Numeric model (the fp16 rounding-point contract)
//!
//! Per merging stage `X_out = F_r (T (.) X_in)`:
//! * the DFT matrix `F_r` and twiddle table `T` are rounded to fp16
//!   once at "compile" time (the device holds them in half precision);
//! * inputs enter each stage as fp16 values (exactly representable in
//!   the f32 working registers — an fp16 x fp16 product is exact in
//!   f32, which is precisely the Tensor Core fragment contract);
//! * dot products accumulate in f32 (the mma accumulator);
//! * stage outputs are rounded back to fp16 (the device-memory store
//!   between merging kernels).
//!
//! The `tc_split` ablation additionally rounds the twiddled operand to
//! fp16 before the matrix multiply — the extra global-memory round
//! trip of the de-fused kernel — so the split variant is measurably
//! less fused both in time and in rounding, mirroring paper Sec 5.4.
//! That extra rounding point is part of the observable ablation
//! contract and is never optimized away.
//!
//! The `tc_ec` tier goes the other way (Ootomo & Yokota, "Recovering
//! single precision accuracy from Tensor Cores"): every fp16 value —
//! input plane, twiddle table, DFT table, stage store — is carried as
//! hi + lo fp16 halves whose exact f32 sum is what lives in the
//! planar buffer (the halves sit ~11 bits apart, so the sum fits
//! f32's 24-bit mantissa exactly). Each scalar product becomes the
//! three-term compensated form `hi*hi + hi*lo + lo*hi` accumulated in
//! f32 (the `lo*lo` term is below the correction's own rounding floor
//! and is dropped, as in the paper), and every store re-splits the
//! f32 accumulator into a fresh hi + lo pair. The operand format is
//! still pure fp16 — the hardware contract is unchanged, each mma
//! just runs on twice the fragments — but the result recovers most of
//! the bits fp16 stores throw away: measured rel-RMSE sits near 2e-7
//! where `tc` sits near 5e-4 (see `tests/precision_ladder.rs`). Like
//! `tc_split`, `tc_ec` stages are never fused: the hi/lo split points
//! are part of the tier's observable contract.
//!
//! The test-only `f32ref` tier drops the fp16 model entirely: tables
//! are raw `f64 -> f32` values, inputs are not quantized, and stage
//! stores keep the full f32 accumulator. It exists as the precision
//! ladder's top rung (what a plain f32 pipeline would produce — see
//! `tests/precision_ladder.rs`) and is deliberately complex-only:
//! the real half-spectrum tables are fp16, so `rfft*` ops reject it.
//!
//! # Execution engine (batch-major, fused, parallel)
//!
//! The engine is batch-major: each merge stage is applied to *all*
//! rows of (a chunk of) the batch before the next stage runs, so the
//! fp16 `F_r`/`T` operand tables are loaded once per stage instead of
//! once per row — the CPU mirror of the paper's "many fragments per
//! tile" batching. On top of that:
//!
//! * **Fused micro-kernels** — for the radices the planner emits
//!   (2/4/8/16) the twiddle multiply is folded into the `F_r` matmul
//!   loop by precomputing the combined per-(m,j,k) operand
//!   `W[m,j,k] = F_r[m,j] (.) T[j,k]` at compile time (products of
//!   fp16 values formed in f32). This changes only the f32-level
//!   association of the math — every fp16 rounding point above is
//!   unchanged, in the same order. `tc_split` stages are never fused
//!   (their operand rounding must stay observable), and very large
//!   stages fall back to the two-pass kernel where the combined table
//!   would blow the cache.
//! * **Scratch arena** — ping-pong stage buffers and the batched
//!   digit-reverse gather run out of a reusable per-backend arena; the
//!   serial path is allocation-free after warmup, and the parallel
//!   path allocates only a few task boxes per dispatch.
//! * **Row-chunk parallelism** — batch rows are split into chunks
//!   executed on the shared [`crate::util::threadpool::ThreadPool`]
//!   (`TCFFT_THREADS` env knob, default = available parallelism),
//!   with a serial fall-through below a work threshold so tiny
//!   transforms don't pay dispatch overhead. Rows are independent, so
//!   chunking cannot change results: the parallel engine is bit-exact
//!   with the serial one (enforced by `tests/engine_equivalence.rs`).
//! * **SIMD panel kernels** — [`super::simd`] re-runs the same stage
//!   math as explicit vector panels (AVX2/AVX-512/NEON behind runtime
//!   dispatch and the `TCFFT_SIMD` env knob), bit-for-bit identical to
//!   the scalar kernels below on every tier: lanes are independent
//!   output cells, each executing the exact scalar op sequence, so
//!   vectorization reassociates nothing inside an accumulation chain
//!   (enforced by `tests/simd_equivalence.rs`). The scalar kernels in
//!   this file remain the portable fallback and the semantic ground
//!   truth.
//!
//! # Real-input transforms (R2C / C2R)
//!
//! `rfft1d` variants run the same staged pipeline at the HALF size
//! `m = n/2` and wrap it in the fused half-spectrum pass of
//! [`super::real::RealHalfSpectrum`]: forward packs adjacent real
//! samples into complex pairs, transforms, and splits into the
//! Hermitian-packed `[0..=n/2]` spectrum; inverse merges the packed
//! spectrum, transforms, and unpacks to `n * x` (unnormalized, like
//! every inverse in this crate). The split/merge pass uses its own
//! fp16-rounded `W_N^k` operand table with f32 arithmetic and fp16
//! stores — the same rounding contract as the merge stages — so a real
//! transform costs roughly half its complex counterpart without
//! changing the numeric model.
//!
//! `rfft2d` variants extend the same machinery to two dimensions:
//! forward runs the 1D real path row-wise over all `batch * nx` rows
//! (pack, half-size `ny/2` pipeline, split into packed
//! `[b, nx, ny/2 + 1]` Hermitian rows) and then the complex `nx`-axis
//! pipeline striding over the packed bins (`lane = ny/2 + 1`), exactly
//! like the second pass of a complex 2D transform; the inverse is the
//! mirror image (columns, merge, half-size rows, unpack), scaled by
//! `nx * ny`.
//!
//! [`ReferenceInterpreter`] keeps the pre-PR row-at-a-time engine
//! (per-row table reloads, per-call allocations, full-codec fp16
//! rounding) as the numeric reference and the perf baseline recorded
//! in `BENCH_interp.json`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use super::buffers::PlanarBatch;
use super::real::RealHalfSpectrum;
use super::registry::VariantMeta;
use super::simd;
use super::{Backend, ExecStats};
use crate::error::Result;
use crate::fft::digitrev;
use crate::hp::F16;
use crate::util::threadpool::{default_threads, ScopedJob, ThreadPool};

/// Largest single-stage radix the schedules produce (16 from the
/// paper's radix-16 formulation; trailing stages are 2/4/8).
const MAX_RADIX: usize = 16;

/// Fuse the twiddle into the matmul operand only while the combined
/// tables stay cache-friendly; beyond this the two-pass kernel
/// re-reads the (r x smaller) `T` table instead. Fused stages carry
/// TWO layouts of the same `r*r*n2` table (k-major for the scalar
/// kernel's splat walk, m-major for the SIMD kernels' contiguous-in-k
/// loads), so the pricing charges `2 * r * r * n2` f32 elements.
const FUSE_LIMIT: usize = 1 << 18;

/// Minimum work (elements x stages) before fanning out to the pool;
/// below this the dispatch overhead beats the parallel win.
const PARALLEL_MIN_WORK: usize = 1 << 14;

/// Elements per ping-pong scratch buffer; bounds arena growth by
/// sub-chunking huge batches inside a worker.
const SCRATCH_ROW_BUDGET: usize = 1 << 19;

/// fp16 rounding on the hot path (fast in-range path, full codec
/// fallback — bit-identical to `rnd16_codec`, see `hp::f16` tests).
#[inline]
pub(crate) fn rnd16(x: f32) -> f32 {
    F16::round_f32(x)
}

/// fp16 rounding through the full encode/decode codec — what the
/// pre-PR engine did on every store; kept for the honest baseline.
#[inline]
fn rnd16_codec(x: f32) -> f32 {
    F16::from_f32(x).to_f32()
}

// --- tc_ec (error-corrected split-fp16) primitives -------------------
//
// A tc_ec value is the exact f32 sum `hi + lo` of two fp16 halves.
// Recovery is one fp16 rounding (hi) plus one f32 subtract (lo); for a
// carried sum the residual is itself fp16-representable, so the extra
// rounding in `ec_split16` is the identity there and only matters when
// splitting a full-precision f32 intermediate.

/// Split an f32 into its fp16 hi half and fp16-rounded lo residual.
#[inline]
pub(crate) fn ec_split16(x: f32) -> (f32, f32) {
    let h = rnd16(x);
    (h, rnd16(x - h))
}

/// Store an f32 accumulator as a carried hi + lo sum. On fp16 overflow
/// the hi half saturates to inf and the lo residual would be -inf;
/// `inf + -inf` is NaN, so keep the saturated store instead.
#[inline]
pub(crate) fn ec_store(x: f32) -> f32 {
    let h = rnd16(x);
    if h.is_finite() { h + rnd16(x - h) } else { h }
}

/// Compensated product of two hi/lo pairs, f32 left-to-right:
/// `(ah*bh + ah*bl) + al*bh`. The `al*bl` term is below the
/// correction's own rounding floor and is dropped (Ootomo & Yokota).
#[inline]
pub(crate) fn ec_mul(ah: f32, al: f32, bh: f32, bl: f32) -> f32 {
    (ah * bh + ah * bl) + al * bh
}

/// Codec twin of [`ec_split16`] for the reference engine (bit-identical
/// — `rnd16` and `rnd16_codec` agree on every fp16 value).
#[inline]
fn ec_split16_codec(x: f32) -> (f32, f32) {
    let h = rnd16_codec(x);
    (h, rnd16_codec(x - h))
}

/// Codec twin of [`ec_store`] for the reference engine.
#[inline]
fn ec_store_codec(x: f32) -> f32 {
    let h = rnd16_codec(x);
    if h.is_finite() { h + rnd16_codec(x - h) } else { h }
}

/// Which accuracy tier a stage belongs to (mutually exclusive flags;
/// all false = the plain `tc` tier).
#[derive(Clone, Copy, Default)]
struct StageTier {
    /// `tc_split`: round the twiddled operand before the matmul
    split: bool,
    /// `tc_ec`: hi/lo operands, compensated products
    ec: bool,
    /// `f32ref`: unrounded tables, no store rounding (test-only)
    raw: bool,
}

impl StageTier {
    fn from_algo(algo: &str) -> StageTier {
        StageTier {
            split: algo == "tc_split",
            ec: algo == "tc_ec",
            raw: algo == "f32ref",
        }
    }
}

/// One merge stage with fp16-rounded operand tables.
struct MergeStage {
    r: usize,
    n2: usize,
    /// F_r row-major [m*r + j], fp16 values widened to f32
    f_re: Vec<f32>,
    f_im: Vec<f32>,
    /// T[j][k] row-major [j*n2 + k], fp16 values widened to f32
    t_re: Vec<f32>,
    t_im: Vec<f32>,
    /// fp16 lo residuals of the tables (tc_ec stages only, else empty):
    /// `lo = fp16(v32 - hi)` against the pre-rounding f32 table value
    f_re_lo: Vec<f32>,
    f_im_lo: Vec<f32>,
    t_re_lo: Vec<f32>,
    t_im_lo: Vec<f32>,
    /// fused combined operand W = F_r (.) T, k-major [(k*r + m)*r + j];
    /// empty when the stage runs the two-pass kernel (split and ec
    /// stages always, huge stages past FUSE_LIMIT)
    w_re: Vec<f32>,
    w_im: Vec<f32>,
    /// the same fused operand m-major [(m*r + j)*n2 + k] — identical
    /// bits, contiguous in k for the SIMD kernels' vector loads
    w_re_mj: Vec<f32>,
    w_im_mj: Vec<f32>,
    /// de-fused ablation: round the twiddled operand before the matmul
    split: bool,
    /// error-corrected tier: hi/lo operands, compensated products
    ec: bool,
    /// test-only full-f32 tier: unrounded tables, no store rounding
    raw: bool,
}

impl MergeStage {
    fn build(r: usize, n2: usize, inverse: bool, tier: StageTier, fuse: bool) -> MergeStage {
        let StageTier { split, ec, raw } = tier;
        assert!(r >= 2 && r <= MAX_RADIX, "stage radix {r} out of range");
        assert!(!(split && ec), "split and ec tiers are mutually exclusive");
        assert!(!(raw && (split || ec)), "f32ref excludes the fp16 ablation tiers");
        // f32ref keeps the raw f64->f32 table values (no fp16 rounding)
        let quant = |v: f32| if raw { v } else { rnd16_codec(v) };
        let sign = if inverse { 2.0 } else { -2.0 };
        let mut f_re = vec![0f32; r * r];
        let mut f_im = vec![0f32; r * r];
        let mut f_re_lo = if ec { vec![0f32; r * r] } else { Vec::new() };
        let mut f_im_lo = if ec { vec![0f32; r * r] } else { Vec::new() };
        for m in 0..r {
            for j in 0..r {
                let e = ((m * j) % r) as f64;
                let ang = sign * std::f64::consts::PI * e / r as f64;
                let (cr, ci) = (ang.cos() as f32, ang.sin() as f32);
                let o = m * r + j;
                f_re[o] = quant(cr);
                f_im[o] = quant(ci);
                if ec {
                    f_re_lo[o] = rnd16_codec(cr - f_re[o]);
                    f_im_lo[o] = rnd16_codec(ci - f_im[o]);
                }
            }
        }
        let block = r * n2;
        let mut t_re = vec![0f32; r * n2];
        let mut t_im = vec![0f32; r * n2];
        let mut t_re_lo = if ec { vec![0f32; r * n2] } else { Vec::new() };
        let mut t_im_lo = if ec { vec![0f32; r * n2] } else { Vec::new() };
        for j in 0..r {
            for k in 0..n2 {
                let e = ((j * k) % block) as f64;
                let ang = sign * std::f64::consts::PI * e / block as f64;
                let (cr, ci) = (ang.cos() as f32, ang.sin() as f32);
                let o = j * n2 + k;
                t_re[o] = quant(cr);
                t_im[o] = quant(ci);
                if ec {
                    t_re_lo[o] = rnd16_codec(cr - t_re[o]);
                    t_im_lo[o] = rnd16_codec(ci - t_im[o]);
                }
            }
        }
        let (mut w_re, mut w_im) = (Vec::new(), Vec::new());
        let (mut w_re_mj, mut w_im_mj) = (Vec::new(), Vec::new());
        if fuse && !split && !ec && !raw && 2 * r * r * n2 <= FUSE_LIMIT {
            w_re = vec![0f32; r * r * n2];
            w_im = vec![0f32; r * r * n2];
            for k in 0..n2 {
                for m in 0..r {
                    for j in 0..r {
                        let (fr, fi) = (f_re[m * r + j], f_im[m * r + j]);
                        let (tr, ti) = (t_re[j * n2 + k], t_im[j * n2 + k]);
                        let o = (k * r + m) * r + j;
                        w_re[o] = fr * tr - fi * ti;
                        w_im[o] = fr * ti + fi * tr;
                    }
                }
            }
            // the m-major twin copies the SAME bits, so the SIMD and
            // scalar kernels read identical operand values
            w_re_mj = vec![0f32; r * r * n2];
            w_im_mj = vec![0f32; r * r * n2];
            for m in 0..r {
                for j in 0..r {
                    for k in 0..n2 {
                        let o_mj = (m * r + j) * n2 + k;
                        let o_km = (k * r + m) * r + j;
                        w_re_mj[o_mj] = w_re[o_km];
                        w_im_mj[o_mj] = w_im[o_km];
                    }
                }
            }
        }
        MergeStage {
            r,
            n2,
            f_re,
            f_im,
            t_re,
            t_im,
            f_re_lo,
            f_im_lo,
            t_re_lo,
            t_im_lo,
            w_re,
            w_im,
            w_re_mj,
            w_im_mj,
            split,
            ec,
            raw,
        }
    }

    /// Borrowed view handed to the SIMD kernels in [`super::simd`].
    fn view(&self) -> simd::StageView<'_> {
        simd::StageView {
            r: self.r,
            n2: self.n2,
            f_re: &self.f_re,
            f_im: &self.f_im,
            t_re: &self.t_re,
            t_im: &self.t_im,
            f_re_lo: &self.f_re_lo,
            f_im_lo: &self.f_im_lo,
            t_re_lo: &self.t_re_lo,
            t_im_lo: &self.t_im_lo,
            w_re: &self.w_re,
            w_im: &self.w_im,
            w_re_mj: &self.w_re_mj,
            w_im_mj: &self.w_im_mj,
            split: self.split,
            ec: self.ec,
        }
    }

    #[inline]
    fn fused(&self) -> bool {
        !self.w_re.is_empty()
    }
}

/// The staged pipeline for one transform axis.
struct AxisPipeline {
    n_axis: usize,
    perm: Vec<usize>,
    stages: Vec<MergeStage>,
}

impl AxisPipeline {
    fn build(n_axis: usize, algo: &str, inverse: bool, fuse: bool) -> AxisPipeline {
        let radices: Vec<usize> = if algo == "r2" {
            vec![2; n_axis.trailing_zeros() as usize]
        } else {
            digitrev::radix_schedule(n_axis)
        };
        let perm = digitrev::digit_reverse_indices(n_axis, &radices);
        let tier = StageTier::from_algo(algo);
        let mut stages = Vec::with_capacity(radices.len());
        let mut n2 = 1usize;
        for &r in &radices {
            stages.push(MergeStage::build(r, n2, inverse, tier, fuse));
            n2 *= r;
        }
        debug_assert_eq!(n2, n_axis);
        AxisPipeline { n_axis, perm, stages }
    }
}

// ---------------------------------------------------------------------
// batch-major stage kernels
// ---------------------------------------------------------------------

/// Fused micro-kernel, monomorphized per radix: one complex matmul
/// against the precomputed combined operand `W`, f32 accumulate, fp16
/// store. Processes every (group, k, lane) cell of the input slice —
/// which spans *all* rows of the chunk, so `W` is streamed once per
/// group rather than once per row.
fn stage_fused<const R: usize>(
    st: &MergeStage,
    in_re: &[f32],
    in_im: &[f32],
    out_re: &mut [f32],
    out_im: &mut [f32],
    lane: usize,
) {
    let n2 = st.n2;
    let block = R * n2;
    let groups = in_re.len() / (block * lane);
    for g in 0..groups {
        let gbase = g * block;
        for k in 0..n2 {
            let wbase = k * R * R;
            for l in 0..lane {
                let mut xr = [0f32; R];
                let mut xi = [0f32; R];
                for j in 0..R {
                    let idx = (gbase + j * n2 + k) * lane + l;
                    xr[j] = in_re[idx];
                    xi[j] = in_im[idx];
                }
                for m in 0..R {
                    let wo = wbase + m * R;
                    let mut acc_re = 0f32;
                    let mut acc_im = 0f32;
                    for j in 0..R {
                        let (wr, wi) = (st.w_re[wo + j], st.w_im[wo + j]);
                        acc_re += wr * xr[j] - wi * xi[j];
                        acc_im += wr * xi[j] + wi * xr[j];
                    }
                    let idx = (gbase + m * n2 + k) * lane + l;
                    out_re[idx] = rnd16(acc_re);
                    out_im[idx] = rnd16(acc_im);
                }
            }
        }
    }
}

/// Two-pass micro-kernel, monomorphized per radix: twiddle into
/// registers (rounded to fp16 when SPLIT — the de-fused ablation's
/// extra store), then the F_r matmul. Float-op order is identical to
/// the pre-PR reference engine, so SPLIT stages stay bit-identical
/// to it.
fn stage_unfused<const R: usize, const SPLIT: bool>(
    st: &MergeStage,
    in_re: &[f32],
    in_im: &[f32],
    out_re: &mut [f32],
    out_im: &mut [f32],
    lane: usize,
) {
    let n2 = st.n2;
    let block = R * n2;
    let groups = in_re.len() / (block * lane);
    for g in 0..groups {
        let gbase = g * block;
        for k in 0..n2 {
            for l in 0..lane {
                let mut xr = [0f32; R];
                let mut xi = [0f32; R];
                for j in 0..R {
                    let idx = (gbase + j * n2 + k) * lane + l;
                    let (ar, ai) = (in_re[idx], in_im[idx]);
                    let (tr, ti) = (st.t_re[j * n2 + k], st.t_im[j * n2 + k]);
                    let mut yr = ar * tr - ai * ti;
                    let mut yi = ar * ti + ai * tr;
                    if SPLIT {
                        yr = rnd16(yr);
                        yi = rnd16(yi);
                    }
                    xr[j] = yr;
                    xi[j] = yi;
                }
                for m in 0..R {
                    let fo = m * R;
                    let mut acc_re = 0f32;
                    let mut acc_im = 0f32;
                    for j in 0..R {
                        let (fr, fi) = (st.f_re[fo + j], st.f_im[fo + j]);
                        acc_re += fr * xr[j] - fi * xi[j];
                        acc_im += fr * xi[j] + fi * xr[j];
                    }
                    let idx = (gbase + m * n2 + k) * lane + l;
                    out_re[idx] = rnd16(acc_re);
                    out_im[idx] = rnd16(acc_im);
                }
            }
        }
    }
}

/// Error-corrected two-pass micro-kernel, monomorphized per radix:
/// recover hi/lo halves of each carried input, form the twiddled
/// operand from four compensated products, re-split it into fresh
/// hi/lo halves for the matmul, accumulate compensated F_r products in
/// f32, and store each accumulator as a new hi + lo pair. Never fused
/// — the hi/lo split points are part of the tier's contract, like the
/// `tc_split` rounding point.
fn stage_unfused_ec<const R: usize>(
    st: &MergeStage,
    in_re: &[f32],
    in_im: &[f32],
    out_re: &mut [f32],
    out_im: &mut [f32],
    lane: usize,
) {
    let n2 = st.n2;
    let block = R * n2;
    let groups = in_re.len() / (block * lane);
    for g in 0..groups {
        let gbase = g * block;
        for k in 0..n2 {
            for l in 0..lane {
                let mut xrh = [0f32; R];
                let mut xrl = [0f32; R];
                let mut xih = [0f32; R];
                let mut xil = [0f32; R];
                for j in 0..R {
                    let idx = (gbase + j * n2 + k) * lane + l;
                    let (arh, arl) = ec_split16(in_re[idx]);
                    let (aih, ail) = ec_split16(in_im[idx]);
                    let to = j * n2 + k;
                    let (trh, trl) = (st.t_re[to], st.t_re_lo[to]);
                    let (tih, til) = (st.t_im[to], st.t_im_lo[to]);
                    // y = T (.) a via four compensated real products
                    let yr = ec_mul(arh, arl, trh, trl) - ec_mul(aih, ail, tih, til);
                    let yi = ec_mul(arh, arl, tih, til) + ec_mul(aih, ail, trh, trl);
                    (xrh[j], xrl[j]) = ec_split16(yr);
                    (xih[j], xil[j]) = ec_split16(yi);
                }
                for m in 0..R {
                    let fo = m * R;
                    let mut acc_re = 0f32;
                    let mut acc_im = 0f32;
                    for j in 0..R {
                        let (frh, frl) = (st.f_re[fo + j], st.f_re_lo[fo + j]);
                        let (fih, fil) = (st.f_im[fo + j], st.f_im_lo[fo + j]);
                        acc_re +=
                            ec_mul(frh, frl, xrh[j], xrl[j]) - ec_mul(fih, fil, xih[j], xil[j]);
                        acc_im +=
                            ec_mul(frh, frl, xih[j], xil[j]) + ec_mul(fih, fil, xrh[j], xrl[j]);
                    }
                    let idx = (gbase + m * n2 + k) * lane + l;
                    out_re[idx] = ec_store(acc_re);
                    out_im[idx] = ec_store(acc_im);
                }
            }
        }
    }
}

/// Generic-radix twin of [`stage_unfused_ec`] (same float-op order)
/// for radices outside the planner's 2/4/8/16 set.
fn stage_generic_ec(
    st: &MergeStage,
    in_re: &[f32],
    in_im: &[f32],
    out_re: &mut [f32],
    out_im: &mut [f32],
    lane: usize,
) {
    let r = st.r;
    let n2 = st.n2;
    let block = r * n2;
    let groups = in_re.len() / (block * lane);
    let mut xrh = [0f32; MAX_RADIX];
    let mut xrl = [0f32; MAX_RADIX];
    let mut xih = [0f32; MAX_RADIX];
    let mut xil = [0f32; MAX_RADIX];
    for g in 0..groups {
        let gbase = g * block;
        for k in 0..n2 {
            for l in 0..lane {
                for j in 0..r {
                    let idx = (gbase + j * n2 + k) * lane + l;
                    let (arh, arl) = ec_split16(in_re[idx]);
                    let (aih, ail) = ec_split16(in_im[idx]);
                    let to = j * n2 + k;
                    let (trh, trl) = (st.t_re[to], st.t_re_lo[to]);
                    let (tih, til) = (st.t_im[to], st.t_im_lo[to]);
                    let yr = ec_mul(arh, arl, trh, trl) - ec_mul(aih, ail, tih, til);
                    let yi = ec_mul(arh, arl, tih, til) + ec_mul(aih, ail, trh, trl);
                    (xrh[j], xrl[j]) = ec_split16(yr);
                    (xih[j], xil[j]) = ec_split16(yi);
                }
                for m in 0..r {
                    let fo = m * r;
                    let mut acc_re = 0f32;
                    let mut acc_im = 0f32;
                    for j in 0..r {
                        let (frh, frl) = (st.f_re[fo + j], st.f_re_lo[fo + j]);
                        let (fih, fil) = (st.f_im[fo + j], st.f_im_lo[fo + j]);
                        acc_re +=
                            ec_mul(frh, frl, xrh[j], xrl[j]) - ec_mul(fih, fil, xih[j], xil[j]);
                        acc_im +=
                            ec_mul(frh, frl, xih[j], xil[j]) + ec_mul(fih, fil, xrh[j], xrl[j]);
                    }
                    let idx = (gbase + m * n2 + k) * lane + l;
                    out_re[idx] = ec_store(acc_re);
                    out_im[idx] = ec_store(acc_im);
                }
            }
        }
    }
}

/// Generic fallback for radices outside the planner's 2/4/8/16 set
/// (none are emitted today; kept so new schedules cannot panic).
fn stage_generic(
    st: &MergeStage,
    in_re: &[f32],
    in_im: &[f32],
    out_re: &mut [f32],
    out_im: &mut [f32],
    lane: usize,
) {
    let r = st.r;
    let n2 = st.n2;
    let block = r * n2;
    let groups = in_re.len() / (block * lane);
    let mut xr = [0f32; MAX_RADIX];
    let mut xi = [0f32; MAX_RADIX];
    for g in 0..groups {
        let gbase = g * block;
        for k in 0..n2 {
            for l in 0..lane {
                for j in 0..r {
                    let idx = (gbase + j * n2 + k) * lane + l;
                    let (ar, ai) = (in_re[idx], in_im[idx]);
                    let (tr, ti) = (st.t_re[j * n2 + k], st.t_im[j * n2 + k]);
                    let mut yr = ar * tr - ai * ti;
                    let mut yi = ar * ti + ai * tr;
                    if st.split {
                        yr = rnd16(yr);
                        yi = rnd16(yi);
                    }
                    xr[j] = yr;
                    xi[j] = yi;
                }
                for m in 0..r {
                    let fo = m * r;
                    let mut acc_re = 0f32;
                    let mut acc_im = 0f32;
                    for j in 0..r {
                        let (fr, fi) = (st.f_re[fo + j], st.f_im[fo + j]);
                        acc_re += fr * xr[j] - fi * xi[j];
                        acc_im += fr * xi[j] + fi * xr[j];
                    }
                    let idx = (gbase + m * n2 + k) * lane + l;
                    out_re[idx] = rnd16(acc_re);
                    out_im[idx] = rnd16(acc_im);
                }
            }
        }
    }
}

/// Full-f32 kernel for the test-only `f32ref` tier: the generic
/// two-pass structure with unrounded tables and no rounding at any
/// store — the precision ladder's top rung. Shared verbatim by both
/// engines (there is nothing engine-specific left to round), and
/// deliberately scalar: `f32ref` is a diagnostic, not a hot path.
fn stage_generic_raw(
    st: &MergeStage,
    in_re: &[f32],
    in_im: &[f32],
    out_re: &mut [f32],
    out_im: &mut [f32],
    lane: usize,
) {
    let r = st.r;
    let n2 = st.n2;
    let block = r * n2;
    let groups = in_re.len() / (block * lane);
    let mut xr = [0f32; MAX_RADIX];
    let mut xi = [0f32; MAX_RADIX];
    for g in 0..groups {
        let gbase = g * block;
        for k in 0..n2 {
            for l in 0..lane {
                for j in 0..r {
                    let idx = (gbase + j * n2 + k) * lane + l;
                    let (ar, ai) = (in_re[idx], in_im[idx]);
                    let (tr, ti) = (st.t_re[j * n2 + k], st.t_im[j * n2 + k]);
                    xr[j] = ar * tr - ai * ti;
                    xi[j] = ar * ti + ai * tr;
                }
                for m in 0..r {
                    let fo = m * r;
                    let mut acc_re = 0f32;
                    let mut acc_im = 0f32;
                    for j in 0..r {
                        let (fr, fi) = (st.f_re[fo + j], st.f_im[fo + j]);
                        acc_re += fr * xr[j] - fi * xi[j];
                        acc_im += fr * xi[j] + fi * xr[j];
                    }
                    let idx = (gbase + m * n2 + k) * lane + l;
                    out_re[idx] = acc_re;
                    out_im[idx] = acc_im;
                }
            }
        }
    }
}

/// Dispatch one batched stage application to its micro-kernel. The
/// SIMD panel kernels take the stage first when a vector path is
/// active (env/forced dispatch in [`simd::active`]) and the radix is
/// one they cover; their output is bit-identical to the scalar
/// kernels below, so this routing is unobservable in results.
fn apply_stage_batched(
    st: &MergeStage,
    in_re: &[f32],
    in_im: &[f32],
    out_re: &mut [f32],
    out_im: &mut [f32],
    lane: usize,
) {
    if st.raw {
        return stage_generic_raw(st, in_re, in_im, out_re, out_im, lane);
    }
    let path = simd::active();
    if path != simd::SimdPath::Scalar {
        let mut bufs = simd::StageBufs {
            in_re,
            in_im,
            out_re: &mut *out_re,
            out_im: &mut *out_im,
            lane,
        };
        if simd::apply_stage(path, &st.view(), &mut bufs) {
            return;
        }
    }
    if st.ec {
        return match st.r {
            2 => stage_unfused_ec::<2>(st, in_re, in_im, out_re, out_im, lane),
            4 => stage_unfused_ec::<4>(st, in_re, in_im, out_re, out_im, lane),
            8 => stage_unfused_ec::<8>(st, in_re, in_im, out_re, out_im, lane),
            16 => stage_unfused_ec::<16>(st, in_re, in_im, out_re, out_im, lane),
            _ => stage_generic_ec(st, in_re, in_im, out_re, out_im, lane),
        };
    }
    match (st.r, st.fused(), st.split) {
        (2, true, _) => stage_fused::<2>(st, in_re, in_im, out_re, out_im, lane),
        (4, true, _) => stage_fused::<4>(st, in_re, in_im, out_re, out_im, lane),
        (8, true, _) => stage_fused::<8>(st, in_re, in_im, out_re, out_im, lane),
        (16, true, _) => stage_fused::<16>(st, in_re, in_im, out_re, out_im, lane),
        (2, false, false) => stage_unfused::<2, false>(st, in_re, in_im, out_re, out_im, lane),
        (4, false, false) => stage_unfused::<4, false>(st, in_re, in_im, out_re, out_im, lane),
        (8, false, false) => stage_unfused::<8, false>(st, in_re, in_im, out_re, out_im, lane),
        (16, false, false) => stage_unfused::<16, false>(st, in_re, in_im, out_re, out_im, lane),
        (2, false, true) => stage_unfused::<2, true>(st, in_re, in_im, out_re, out_im, lane),
        (4, false, true) => stage_unfused::<4, true>(st, in_re, in_im, out_re, out_im, lane),
        (8, false, true) => stage_unfused::<8, true>(st, in_re, in_im, out_re, out_im, lane),
        (16, false, true) => stage_unfused::<16, true>(st, in_re, in_im, out_re, out_im, lane),
        _ => stage_generic(st, in_re, in_im, out_re, out_im, lane),
    }
}

// ---------------------------------------------------------------------
// scratch arena + batch-major driver
// ---------------------------------------------------------------------

/// Reusable ping-pong stage buffers; lives in the backend's arena so
/// steady-state execution allocates nothing.
#[derive(Default)]
struct Scratch {
    a_re: Vec<f32>,
    a_im: Vec<f32>,
    b_re: Vec<f32>,
    b_im: Vec<f32>,
    /// half-size staging planes for the real (R2C/C2R) path
    z_re: Vec<f32>,
    z_im: Vec<f32>,
}

impl Scratch {
    fn ensure(&mut self, len: usize) {
        if self.a_re.len() < len {
            self.a_re.resize(len, 0.0);
            self.a_im.resize(len, 0.0);
            self.b_re.resize(len, 0.0);
            self.b_im.resize(len, 0.0);
        }
    }
}

/// The real-transform wrapper shared by both engines: route the
/// quantized input (`[b, n]` real rows forward, `[b, n/2 + 1]` packed
/// spectra inverse) through pack/merge, the supplied half-size complex
/// pipeline runner, and split/unpack. Every fp16 rounding point lives
/// in [`RealHalfSpectrum`] and the pipeline itself; this function only
/// moves data. The half-size staging planes come from the caller
/// (`CpuInterpreter` hands in its scratch arena, so its steady state
/// allocates only the returned output); the output buffer itself is
/// owned by the caller's caller and is a fresh allocation by design.
fn run_real(
    real: &RealHalfSpectrum,
    inverse: bool,
    q: &PlanarBatch,
    z_re: &mut Vec<f32>,
    z_im: &mut Vec<f32>,
    run: impl FnOnce(&mut [f32], &mut [f32], usize),
) -> PlanarBatch {
    let b = q.shape[0];
    let (n, m) = (real.n(), real.m());
    let len = b * m;
    if z_re.len() < len {
        z_re.resize(len, 0.0);
        z_im.resize(len, 0.0);
    }
    if inverse {
        real.merge_rows(&q.re, &q.im, &mut z_re[..len], &mut z_im[..len], b);
        run(&mut z_re[..len], &mut z_im[..len], b);
        let mut out = PlanarBatch::new(vec![b, n]);
        real.unpack_rows(&z_re[..len], &z_im[..len], &mut out.re, b);
        out
    } else {
        real.pack_rows(&q.re, &mut z_re[..len], &mut z_im[..len], b);
        run(&mut z_re[..len], &mut z_im[..len], b);
        let mut out = PlanarBatch::new(vec![b, m + 1]);
        real.split_rows(&z_re[..len], &z_im[..len], &mut out.re, &mut out.im, b);
        out
    }
}

/// Transform `rows` whole rows batch-major: one batched digit-reverse
/// gather, then every stage over the full block, then one write-back.
fn run_rows_block(
    ax: &AxisPipeline,
    re: &mut [f32],
    im: &mut [f32],
    rows: usize,
    lane: usize,
    s: &mut Scratch,
) {
    let row_len = ax.n_axis * lane;
    let len = rows * row_len;
    s.ensure(len);
    for row in 0..rows {
        let base = row * row_len;
        for (i, &p) in ax.perm.iter().enumerate() {
            let src = base + p * lane;
            let dst = base + i * lane;
            s.a_re[dst..dst + lane].copy_from_slice(&re[src..src + lane]);
            s.a_im[dst..dst + lane].copy_from_slice(&im[src..src + lane]);
        }
    }
    let mut in_a = true;
    for st in &ax.stages {
        if in_a {
            apply_stage_batched(
                st,
                &s.a_re[..len],
                &s.a_im[..len],
                &mut s.b_re[..len],
                &mut s.b_im[..len],
                lane,
            );
        } else {
            apply_stage_batched(
                st,
                &s.b_re[..len],
                &s.b_im[..len],
                &mut s.a_re[..len],
                &mut s.a_im[..len],
                lane,
            );
        }
        in_a = !in_a;
    }
    let (fin_re, fin_im) = if in_a { (&s.a_re, &s.a_im) } else { (&s.b_re, &s.b_im) };
    re.copy_from_slice(&fin_re[..len]);
    im.copy_from_slice(&fin_im[..len]);
}

/// Serial batch-major pass over `rows` rows, sub-chunked to keep the
/// scratch arena within budget for huge batches.
fn run_rows(
    ax: &AxisPipeline,
    re: &mut [f32],
    im: &mut [f32],
    rows: usize,
    lane: usize,
    s: &mut Scratch,
) {
    let row_len = ax.n_axis * lane;
    let max_rows = (SCRATCH_ROW_BUDGET / row_len.max(1)).max(1);
    let mut lo = 0usize;
    while lo < rows {
        let rc = (rows - lo).min(max_rows);
        let a = lo * row_len;
        let b = (lo + rc) * row_len;
        run_rows_block(ax, &mut re[a..b], &mut im[a..b], rc, lane, s);
        lo += rc;
    }
}

/// The real-transform 2D wrapper shared by both engines: forward runs
/// the row-wise real path over all `b * nx` rows (pack, half-size
/// pipeline, split into packed Hermitian rows) and then the complex
/// `nx`-axis pass striding over the packed `ny/2 + 1` bins; inverse is
/// the exact mirror (columns first, then merge/transform/unpack),
/// scaled `nx * ny` by the unnormalized inverses. Every fp16 rounding
/// point lives in [`RealHalfSpectrum`] and the supplied pipeline
/// runners; this function only moves data. The half-size staging
/// planes come from the caller (`CpuInterpreter` hands in its scratch
/// arena); the returned output batch is a fresh allocation by design.
fn run_real_2d(
    real: &RealHalfSpectrum,
    inverse: bool,
    mut q: PlanarBatch,
    nx: usize,
    z: (&mut Vec<f32>, &mut Vec<f32>),
    run_rows_half: impl FnOnce(&mut [f32], &mut [f32], usize),
    run_cols: impl FnOnce(&mut [f32], &mut [f32], usize, usize),
) -> PlanarBatch {
    let (z_re, z_im) = z;
    let b = q.shape[0];
    let (ny, m) = (real.n(), real.m());
    let rows = b * nx;
    let len = rows * m;
    if z_re.len() < len {
        z_re.resize(len, 0.0);
        z_im.resize(len, 0.0);
    }
    if inverse {
        // undo the forward's last pass first: inverse nx-axis columns
        // over the packed bins, then the row-wise C2R path
        run_cols(&mut q.re, &mut q.im, b, m + 1);
        real.merge_rows(&q.re, &q.im, &mut z_re[..len], &mut z_im[..len], rows);
        run_rows_half(&mut z_re[..len], &mut z_im[..len], rows);
        let mut out = PlanarBatch::new(vec![b, nx, ny]);
        real.unpack_rows(&z_re[..len], &z_im[..len], &mut out.re, rows);
        out
    } else {
        real.pack_rows(&q.re, &mut z_re[..len], &mut z_im[..len], rows);
        run_rows_half(&mut z_re[..len], &mut z_im[..len], rows);
        let mut out = PlanarBatch::new(vec![b, nx, m + 1]);
        real.split_rows(&z_re[..len], &z_im[..len], &mut out.re, &mut out.im, rows);
        run_cols(&mut out.re, &mut out.im, b, m + 1);
        out
    }
}

/// A fully built transform: one axis pass for 1D (over the half size
/// for real transforms, with the half-spectrum pass attached), two
/// for 2D (the `rfft2d` row axis runs at the half size `ny/2`).
struct Compiled {
    axes: Vec<AxisPipeline>,
    /// the fused half-spectrum split/merge pass (real transforms only)
    real: Option<RealHalfSpectrum>,
}

impl Compiled {
    fn build(meta: &VariantMeta, fuse: bool) -> Compiled {
        if meta.op == "rfft1d" {
            // the complex pipeline runs at the half size m = n/2; the
            // real split (fwd) / merge (inv) pass wraps around it
            let m = meta.n / 2;
            return Compiled {
                axes: vec![AxisPipeline::build(m, &meta.algo, meta.inverse, fuse)],
                real: Some(RealHalfSpectrum::with_ec(meta.n, meta.algo == "tc_ec")),
            };
        }
        if meta.op == "rfft2d" {
            // rows run the 1D real path at ny/2; the nx axis runs the
            // ordinary complex pipeline over the packed bins
            let m = meta.ny / 2;
            return Compiled {
                axes: vec![
                    AxisPipeline::build(m, &meta.algo, meta.inverse, fuse),
                    AxisPipeline::build(meta.nx, &meta.algo, meta.inverse, fuse),
                ],
                real: Some(RealHalfSpectrum::with_ec(meta.ny, meta.algo == "tc_ec")),
            };
        }
        let axes = if meta.op == "fft1d" {
            vec![AxisPipeline::build(meta.n, &meta.algo, meta.inverse, fuse)]
        } else {
            // contiguous ny rows first, then the strided nx axis
            vec![
                AxisPipeline::build(meta.ny, &meta.algo, meta.inverse, fuse),
                AxisPipeline::build(meta.nx, &meta.algo, meta.inverse, fuse),
            ]
        };
        Compiled { axes, real: None }
    }
}

/// The pure-Rust interpreter backend (the offline default): batch-major
/// fused stage engine with a scratch arena and row-chunk parallelism.
pub struct CpuInterpreter {
    cache: RwLock<HashMap<String, Arc<Compiled>>>,
    threads: usize,
    pool: Mutex<Option<Arc<ThreadPool>>>,
    scratch: Mutex<Vec<Scratch>>,
}

impl CpuInterpreter {
    /// Thread count from `TCFFT_THREADS` (default: available cores).
    pub fn new() -> CpuInterpreter {
        Self::with_threads(default_threads())
    }

    /// Explicit worker count; `1` forces the serial engine.
    pub fn with_threads(threads: usize) -> CpuInterpreter {
        CpuInterpreter {
            cache: RwLock::new(HashMap::new()),
            threads: threads.max(1),
            pool: Mutex::new(None),
            scratch: Mutex::new(Vec::new()),
        }
    }

    /// The configured worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Fetch or build the staged pipeline for an artifact; the bool is
    /// true when this call built it (the "compile" in ExecStats).
    fn compiled(&self, meta: &VariantMeta) -> (Arc<Compiled>, bool) {
        if let Some(c) = self.cache.read().unwrap().get(&meta.key) {
            return (Arc::clone(c), false);
        }
        let built = Arc::new(Compiled::build(meta, true));
        let mut cache = self.cache.write().unwrap();
        match cache.get(&meta.key) {
            Some(c) => (Arc::clone(c), false), // raced: another thread built it
            None => {
                cache.insert(meta.key.clone(), Arc::clone(&built));
                (built, true)
            }
        }
    }

    /// The lazily spawned worker pool (never built in serial mode).
    fn pool(&self) -> Arc<ThreadPool> {
        let mut guard = self.pool.lock().unwrap();
        Arc::clone(guard.get_or_insert_with(|| Arc::new(ThreadPool::new(self.threads))))
    }

    /// Borrow a scratch set from the arena (or grow it), run `f`, and
    /// return the scratch for reuse.
    fn with_scratch<R>(&self, f: impl FnOnce(&mut Scratch) -> R) -> R {
        let mut s = self.scratch.lock().unwrap().pop().unwrap_or_default();
        let out = f(&mut s);
        let mut arena = self.scratch.lock().unwrap();
        if arena.len() < self.threads + 1 {
            arena.push(s);
        }
        out
    }

    /// Transform every row of a (rows, n_axis, lane) planar tensor
    /// along the middle axis, in place — chunked across the pool when
    /// the work is large enough, serial (and allocation-free after
    /// warmup) otherwise. Chunking is row-aligned, so parallel and
    /// serial execution are bit-identical.
    fn run_axis(
        &self,
        ax: &AxisPipeline,
        re: &mut [f32],
        im: &mut [f32],
        rows: usize,
        lane: usize,
    ) {
        let row_len = ax.n_axis * lane;
        if rows == 0 || row_len == 0 || ax.stages.is_empty() {
            return;
        }
        // hard assert (as the pre-PR engine had): a mis-shaped buffer
        // must panic, not be silently chunked into wrong transforms
        assert_eq!(re.len(), rows * row_len, "planar buffer/shape mismatch");
        assert_eq!(im.len(), rows * row_len, "planar buffer/shape mismatch");
        let threads = self.threads.min(rows);
        let work = rows * row_len * ax.stages.len();
        if threads <= 1 || work < PARALLEL_MIN_WORK {
            self.with_scratch(|s| run_rows(ax, re, im, rows, lane, s));
            return;
        }
        let chunk_rows = rows.div_ceil(threads);
        let chunk_len = chunk_rows * row_len;
        let pool = self.pool();
        let mut tasks: Vec<ScopedJob<'_>> = Vec::with_capacity(threads);
        for (re_c, im_c) in re.chunks_mut(chunk_len).zip(im.chunks_mut(chunk_len)) {
            tasks.push(Box::new(move || {
                let rows_c = re_c.len() / row_len;
                self.with_scratch(|s| run_rows(ax, re_c, im_c, rows_c, lane, s));
            }));
        }
        pool.scope(tasks);
    }
}

impl Default for CpuInterpreter {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for CpuInterpreter {
    fn name(&self) -> &'static str {
        "cpu-interpreter"
    }

    fn execute(&self, meta: &VariantMeta, input: PlanarBatch) -> Result<(PlanarBatch, ExecStats)> {
        crate::ensure!(
            meta.algo != "f32ref" || !meta.op.starts_with("rfft"),
            "f32ref is a complex-only diagnostic tier: the {} half-spectrum tables are fp16",
            meta.op
        );
        let (compiled, fresh) = self.compiled(meta);

        // marshal: quantize the host f32 input to the fp16 the device
        // sees — in place, the execute path owns its buffer. The ec
        // tier carries hi + lo fp16 pairs instead of one rounding;
        // f32ref skips quantization entirely.
        let tm = Instant::now();
        let mut q = input;
        if meta.algo == "tc_ec" {
            q.quantize_f16_ec_mut();
        } else if meta.algo != "f32ref" {
            q.quantize_f16_mut();
        }
        let marshal_seconds = tm.elapsed().as_secs_f64();

        let te = Instant::now();
        let batch = q.shape[0];
        if let Some(real) = &compiled.real {
            // real transform: half-size complex pipeline wrapped in the
            // fused half-spectrum pass (input im plane is ignored on
            // the R2C side — the signal is real by contract). Staging
            // planes come from the arena; run_axis nests its own
            // scratch borrow, so the arena settles at two entries.
            let out = if meta.op == "rfft2d" {
                self.with_scratch(|s| {
                    run_real_2d(
                        real,
                        meta.inverse,
                        q,
                        meta.nx,
                        (&mut s.z_re, &mut s.z_im),
                        |re, im, rows| self.run_axis(&compiled.axes[0], re, im, rows, 1),
                        |re, im, rows, lane| self.run_axis(&compiled.axes[1], re, im, rows, lane),
                    )
                })
            } else {
                self.with_scratch(|s| {
                    run_real(real, meta.inverse, &q, &mut s.z_re, &mut s.z_im, |re, im, rows| {
                        self.run_axis(&compiled.axes[0], re, im, rows, 1);
                    })
                })
            };
            let exec_seconds = te.elapsed().as_secs_f64();
            return Ok((out, ExecStats { exec_seconds, marshal_seconds, compiled: fresh }));
        }
        if meta.op == "fft1d" {
            self.run_axis(&compiled.axes[0], &mut q.re, &mut q.im, batch, 1);
        } else {
            let (nx, ny) = (meta.nx, meta.ny);
            self.run_axis(&compiled.axes[0], &mut q.re, &mut q.im, batch * nx, 1);
            self.run_axis(&compiled.axes[1], &mut q.re, &mut q.im, batch, ny);
        }
        let exec_seconds = te.elapsed().as_secs_f64();
        Ok((q, ExecStats { exec_seconds, marshal_seconds, compiled: fresh }))
    }

    fn warm(&self, meta: &VariantMeta) -> Result<f64> {
        let t0 = Instant::now();
        let (_, fresh) = self.compiled(meta);
        Ok(if fresh { t0.elapsed().as_secs_f64() } else { 0.0 })
    }
}

// ---------------------------------------------------------------------
// pre-PR reference engine
// ---------------------------------------------------------------------

/// The pre-PR interpreter, kept verbatim: row-at-a-time execution,
/// four scratch `Vec`s allocated per call, operand tables re-walked
/// for every row, full-codec fp16 rounding on every store, no operand
/// fusion and no parallelism. It is the "before" series in
/// `BENCH_interp.json` and the numeric reference for
/// `tests/engine_equivalence.rs` (bit-identical on `tc_split`, whose
/// kernels were never re-associated).
pub struct ReferenceInterpreter {
    cache: RwLock<HashMap<String, Arc<Compiled>>>,
}

impl ReferenceInterpreter {
    /// Fresh engine with an empty pipeline cache.
    pub fn new() -> ReferenceInterpreter {
        ReferenceInterpreter { cache: RwLock::new(HashMap::new()) }
    }

    fn compiled(&self, meta: &VariantMeta) -> (Arc<Compiled>, bool) {
        if let Some(c) = self.cache.read().unwrap().get(&meta.key) {
            return (Arc::clone(c), false);
        }
        let built = Arc::new(Compiled::build(meta, false));
        let mut cache = self.cache.write().unwrap();
        match cache.get(&meta.key) {
            Some(c) => (Arc::clone(c), false),
            None => {
                cache.insert(meta.key.clone(), Arc::clone(&built));
                (built, true)
            }
        }
    }
}

impl Default for ReferenceInterpreter {
    fn default() -> Self {
        Self::new()
    }
}

/// Error-corrected stage for the reference engine: the float-op order
/// of [`stage_generic_ec`] with the full-codec rounders. `rnd16` and
/// `rnd16_codec` agree on every fp16 value, so the two engines stay
/// bit-identical on the ec tier (pinned by `tests/engine_equivalence`).
fn reference_apply_stage_ec(
    st: &MergeStage,
    in_re: &[f32],
    in_im: &[f32],
    out_re: &mut [f32],
    out_im: &mut [f32],
    lane: usize,
) {
    let r = st.r;
    let n2 = st.n2;
    let block = r * n2;
    let groups = in_re.len() / (block * lane);
    let mut xrh = [0f32; MAX_RADIX];
    let mut xrl = [0f32; MAX_RADIX];
    let mut xih = [0f32; MAX_RADIX];
    let mut xil = [0f32; MAX_RADIX];
    for g in 0..groups {
        let gbase = g * block;
        for k in 0..n2 {
            for l in 0..lane {
                for j in 0..r {
                    let idx = (gbase + j * n2 + k) * lane + l;
                    let (arh, arl) = ec_split16_codec(in_re[idx]);
                    let (aih, ail) = ec_split16_codec(in_im[idx]);
                    let to = j * n2 + k;
                    let (trh, trl) = (st.t_re[to], st.t_re_lo[to]);
                    let (tih, til) = (st.t_im[to], st.t_im_lo[to]);
                    let yr = ec_mul(arh, arl, trh, trl) - ec_mul(aih, ail, tih, til);
                    let yi = ec_mul(arh, arl, tih, til) + ec_mul(aih, ail, trh, trl);
                    (xrh[j], xrl[j]) = ec_split16_codec(yr);
                    (xih[j], xil[j]) = ec_split16_codec(yi);
                }
                for m in 0..r {
                    let fo = m * r;
                    let mut acc_re = 0f32;
                    let mut acc_im = 0f32;
                    for j in 0..r {
                        let (frh, frl) = (st.f_re[fo + j], st.f_re_lo[fo + j]);
                        let (fih, fil) = (st.f_im[fo + j], st.f_im_lo[fo + j]);
                        acc_re +=
                            ec_mul(frh, frl, xrh[j], xrl[j]) - ec_mul(fih, fil, xih[j], xil[j]);
                        acc_im +=
                            ec_mul(frh, frl, xih[j], xil[j]) + ec_mul(fih, fil, xrh[j], xrl[j]);
                    }
                    let idx = (gbase + m * n2 + k) * lane + l;
                    out_re[idx] = ec_store_codec(acc_re);
                    out_im[idx] = ec_store_codec(acc_im);
                }
            }
        }
    }
}

/// One merge stage over a single row, pre-PR float-op order and
/// full-codec rounding.
fn reference_apply_stage(
    st: &MergeStage,
    in_re: &[f32],
    in_im: &[f32],
    out_re: &mut [f32],
    out_im: &mut [f32],
    lane: usize,
) {
    if st.raw {
        // f32ref has no rounding points left to differ on, so both
        // engines share the one raw kernel
        return stage_generic_raw(st, in_re, in_im, out_re, out_im, lane);
    }
    if st.ec {
        return reference_apply_stage_ec(st, in_re, in_im, out_re, out_im, lane);
    }
    let r = st.r;
    let n2 = st.n2;
    let block = r * n2;
    let groups = in_re.len() / (block * lane);
    let mut xr = [0f32; MAX_RADIX];
    let mut xi = [0f32; MAX_RADIX];
    for g in 0..groups {
        let gbase = g * block;
        for k in 0..n2 {
            for l in 0..lane {
                // gather + twiddle: y_j = T[j][k] * x[g, j, k]
                for j in 0..r {
                    let idx = (gbase + j * n2 + k) * lane + l;
                    let (ar, ai) = (in_re[idx], in_im[idx]);
                    let (tr, ti) = (st.t_re[j * n2 + k], st.t_im[j * n2 + k]);
                    let mut yr = ar * tr - ai * ti;
                    let mut yi = ar * ti + ai * tr;
                    if st.split {
                        yr = rnd16_codec(yr);
                        yi = rnd16_codec(yi);
                    }
                    xr[j] = yr;
                    xi[j] = yi;
                }
                // mma: out_m = sum_j F[m][j] * y_j (f32 accumulate)
                for m in 0..r {
                    let fo = m * r;
                    let mut acc_re = 0f32;
                    let mut acc_im = 0f32;
                    for j in 0..r {
                        let (fr, fi) = (st.f_re[fo + j], st.f_im[fo + j]);
                        acc_re += fr * xr[j] - fi * xi[j];
                        acc_im += fr * xi[j] + fi * xr[j];
                    }
                    let idx = (gbase + m * n2 + k) * lane + l;
                    out_re[idx] = rnd16_codec(acc_re);
                    out_im[idx] = rnd16_codec(acc_im);
                }
            }
        }
    }
}

/// Row-at-a-time axis pass (pre-PR structure: scratch allocated per
/// call, digit-reverse gather and stages per row).
fn reference_run_axis(ax: &AxisPipeline, re: &mut [f32], im: &mut [f32], rows: usize, lane: usize) {
    let row_len = ax.n_axis * lane;
    assert_eq!(re.len(), rows * row_len);
    let mut cur_re = vec![0f32; row_len];
    let mut cur_im = vec![0f32; row_len];
    let mut nxt_re = vec![0f32; row_len];
    let mut nxt_im = vec![0f32; row_len];
    for row in 0..rows {
        let base = row * row_len;
        for (i, &p) in ax.perm.iter().enumerate() {
            let s = base + p * lane;
            let d = i * lane;
            cur_re[d..d + lane].copy_from_slice(&re[s..s + lane]);
            cur_im[d..d + lane].copy_from_slice(&im[s..s + lane]);
        }
        for st in &ax.stages {
            reference_apply_stage(st, &cur_re, &cur_im, &mut nxt_re, &mut nxt_im, lane);
            std::mem::swap(&mut cur_re, &mut nxt_re);
            std::mem::swap(&mut cur_im, &mut nxt_im);
        }
        re[base..base + row_len].copy_from_slice(&cur_re);
        im[base..base + row_len].copy_from_slice(&cur_im);
    }
}

impl Backend for ReferenceInterpreter {
    fn name(&self) -> &'static str {
        "cpu-reference"
    }

    fn execute(&self, meta: &VariantMeta, input: PlanarBatch) -> Result<(PlanarBatch, ExecStats)> {
        crate::ensure!(
            meta.algo != "f32ref" || !meta.op.starts_with("rfft"),
            "f32ref is a complex-only diagnostic tier: the {} half-spectrum tables are fp16",
            meta.op
        );
        let (compiled, fresh) = self.compiled(meta);
        let tm = Instant::now();
        let mut q = if meta.algo == "tc_ec" {
            let mut q = input;
            q.quantize_f16_ec_mut();
            q
        } else if meta.algo == "f32ref" {
            input
        } else {
            input.quantize_f16()
        };
        let marshal_seconds = tm.elapsed().as_secs_f64();
        let te = Instant::now();
        let batch = q.shape[0];
        if let Some(real) = &compiled.real {
            // the reference engine allocates per call on purpose (the
            // honest pre-PR baseline), so its staging is local
            let (mut z_re, mut z_im) = (Vec::new(), Vec::new());
            let out = if meta.op == "rfft2d" {
                run_real_2d(
                    real,
                    meta.inverse,
                    q,
                    meta.nx,
                    (&mut z_re, &mut z_im),
                    |re, im, rows| reference_run_axis(&compiled.axes[0], re, im, rows, 1),
                    |re, im, rows, lane| reference_run_axis(&compiled.axes[1], re, im, rows, lane),
                )
            } else {
                run_real(real, meta.inverse, &q, &mut z_re, &mut z_im, |re, im, rows| {
                    reference_run_axis(&compiled.axes[0], re, im, rows, 1);
                })
            };
            let exec_seconds = te.elapsed().as_secs_f64();
            return Ok((out, ExecStats { exec_seconds, marshal_seconds, compiled: fresh }));
        }
        if meta.op == "fft1d" {
            reference_run_axis(&compiled.axes[0], &mut q.re, &mut q.im, batch, 1);
        } else {
            let (nx, ny) = (meta.nx, meta.ny);
            reference_run_axis(&compiled.axes[0], &mut q.re, &mut q.im, batch * nx, 1);
            reference_run_axis(&compiled.axes[1], &mut q.re, &mut q.im, batch, ny);
        }
        let exec_seconds = te.elapsed().as_secs_f64();
        Ok((q, ExecStats { exec_seconds, marshal_seconds, compiled: fresh }))
    }

    fn warm(&self, meta: &VariantMeta) -> Result<f64> {
        let t0 = Instant::now();
        let (_, fresh) = self.compiled(meta);
        Ok(if fresh { t0.elapsed().as_secs_f64() } else { 0.0 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::relative_rmse;
    use crate::fft::refdft;
    use crate::hp::complex::widen;
    use crate::runtime::Registry;
    use crate::workload::random_signal;

    #[test]
    fn impulse_gives_flat_spectrum() {
        let reg = Registry::synthesize();
        let meta = reg.get("fft1d_tc_n256_b4_fwd").unwrap();
        let be = CpuInterpreter::new();
        let mut x = PlanarBatch::new(vec![4, 256]);
        x.re[0] = 1.0; // impulse in row 0 only
        let (y, stats) = be.execute(meta, x).unwrap();
        assert!(stats.compiled);
        for k in 0..256 {
            assert!((y.re[k] - 1.0).abs() < 0.01, "bin {k}: {}", y.re[k]);
            assert!(y.im[k].abs() < 0.01, "bin {k}: {}", y.im[k]);
        }
        // remaining rows were zero and stay zero
        assert!(y.re[256..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn matches_refdft_small() {
        let reg = Registry::synthesize();
        let be = CpuInterpreter::new();
        let meta = reg.get("fft1d_tc_n64_b4_fwd").unwrap();
        let sig = random_signal(64, 7);
        let input = PlanarBatch::from_complex(&sig, vec![1, 64]).pad_batch(4);
        let (out, _) = be.execute(meta, input.clone()).unwrap();
        let want = refdft::dft(&widen(&input.quantize_f16().to_complex()[..64]), false);
        let got = widen(&out.to_complex()[..64]);
        let err = relative_rmse(&want, &got);
        assert!(err < 2e-3, "rmse {err}");
    }

    #[test]
    fn second_execute_hits_the_cache() {
        let reg = Registry::synthesize();
        let be = CpuInterpreter::new();
        let meta = reg.get("fft1d_tc_n16_b4_fwd").unwrap();
        let x = PlanarBatch::new(vec![4, 16]);
        let (_, s1) = be.execute(meta, x.clone()).unwrap();
        let (_, s2) = be.execute(meta, x).unwrap();
        assert!(s1.compiled);
        assert!(!s2.compiled);
    }

    #[test]
    fn warm_builds_once() {
        let reg = Registry::synthesize();
        let be = CpuInterpreter::new();
        let meta = reg.get("fft1d_tc_n1024_b4_fwd").unwrap();
        let first = be.warm(meta).unwrap();
        let second = be.warm(meta).unwrap();
        assert!(first >= 0.0);
        assert_eq!(second, 0.0);
    }

    #[test]
    fn parallel_is_bit_exact_with_serial() {
        // batch 7 across 3 workers exercises an uneven chunk split,
        // and 7*1024*3 stages is above the parallel work threshold
        let reg = Registry::synthesize();
        let meta = reg.get("fft1d_tc_n1024_b32_fwd").unwrap();
        let x: Vec<_> = (0..7).flat_map(|b| random_signal(1024, 90 + b as u64)).collect();
        let input = PlanarBatch::from_complex(&x, vec![7, 1024]);
        let serial = CpuInterpreter::with_threads(1);
        let parallel = CpuInterpreter::with_threads(3);
        let (ys, _) = serial.execute(meta, input.clone()).unwrap();
        let (yp, _) = parallel.execute(meta, input).unwrap();
        for i in 0..ys.len() {
            assert_eq!(ys.re[i].to_bits(), yp.re[i].to_bits(), "re[{i}]");
            assert_eq!(ys.im[i].to_bits(), yp.im[i].to_bits(), "im[{i}]");
        }
    }

    #[test]
    fn engine_tracks_reference_closely() {
        // fused f32 re-association vs the pre-PR engine: identical fp16
        // rounding points, so outputs agree to well under the fp16 noise
        let reg = Registry::synthesize();
        let meta = reg.get("fft1d_tc_n256_b4_fwd").unwrap();
        let x: Vec<_> = (0..4).flat_map(|b| random_signal(256, 5 + b as u64)).collect();
        let input = PlanarBatch::from_complex(&x, vec![4, 256]);
        let (y_new, _) = CpuInterpreter::new().execute(meta, input.clone()).unwrap();
        let (y_ref, _) = ReferenceInterpreter::new().execute(meta, input).unwrap();
        let err = relative_rmse(&widen(&y_ref.to_complex()), &widen(&y_new.to_complex()));
        assert!(err < 1e-3, "engine vs reference rmse {err}");
    }

    #[test]
    fn scratch_arena_is_reused() {
        let reg = Registry::synthesize();
        let be = CpuInterpreter::with_threads(1);
        let meta = reg.get("fft1d_tc_n256_b4_fwd").unwrap();
        let x = PlanarBatch::new(vec![4, 256]);
        be.execute(meta, x.clone()).unwrap();
        assert_eq!(be.scratch.lock().unwrap().len(), 1, "scratch returned to arena");
        be.execute(meta, x).unwrap();
        assert_eq!(be.scratch.lock().unwrap().len(), 1, "scratch reused, not duplicated");
    }

    #[test]
    fn real_path_settles_into_the_scratch_arena() {
        // the outer staging borrow nests the pipeline's own scratch
        // borrow, so the arena settles at two entries and stops growing
        let reg = Registry::synthesize();
        let be = CpuInterpreter::with_threads(1);
        let meta = reg.get("rfft1d_tc_n256_b4_fwd").unwrap();
        let x = PlanarBatch::new(vec![4, 256]);
        be.execute(meta, x.clone()).unwrap();
        let settled = be.scratch.lock().unwrap().len();
        assert!(settled <= 2, "arena grew to {settled}");
        be.execute(meta, x).unwrap();
        assert_eq!(be.scratch.lock().unwrap().len(), settled, "arena kept growing");
    }

    #[test]
    fn rfft_impulse_gives_flat_packed_spectrum() {
        let reg = Registry::synthesize();
        let meta = reg.get("rfft1d_tc_n256_b4_fwd").unwrap();
        let be = CpuInterpreter::new();
        let mut x = PlanarBatch::new(vec![4, 256]);
        x.re[0] = 1.0; // real impulse in row 0
        let (y, _) = be.execute(meta, x).unwrap();
        assert_eq!(y.shape, vec![4, 129]);
        for k in 0..129 {
            assert!((y.re[k] - 1.0).abs() < 0.01, "bin {k}: {}", y.re[k]);
            assert!(y.im[k].abs() < 0.01, "bin {k}: {}", y.im[k]);
        }
        // Hermitian endpoints are exactly real
        assert_eq!(y.im[0], 0.0);
        assert_eq!(y.im[128], 0.0);
    }

    #[test]
    fn rfft_matches_refdft_small() {
        let reg = Registry::synthesize();
        let be = CpuInterpreter::new();
        let meta = reg.get("rfft1d_tc_n64_b4_fwd").unwrap();
        let sig: Vec<f32> = random_signal(64, 9).iter().map(|c| c.re).collect();
        let input = PlanarBatch::from_real(&sig, vec![1, 64]).pad_batch(4);
        let (out, _) = be.execute(meta, input.clone()).unwrap();
        let q = input.quantize_f16();
        let want = refdft::dft(&widen(&q.to_complex()[..64]), false);
        let got = widen(&out.to_complex()[..33]);
        let err = relative_rmse(&want[..33], &got);
        assert!(err < 2e-3, "rfft rmse {err}");
    }

    #[test]
    fn rfft_engine_tracks_reference_closely() {
        let reg = Registry::synthesize();
        for key in ["rfft1d_tc_n256_b4_fwd", "rfft1d_tc_n256_b4_inv"] {
            let meta = reg.get(key).unwrap();
            let bins = meta.input_shape[1];
            let x: Vec<f32> = (0..4 * bins)
                .map(|i| ((i * 29 + 3) % 41) as f32 / 41.0 - 0.5)
                .collect();
            let mut input = PlanarBatch::new(vec![4, bins]);
            input.re.copy_from_slice(&x);
            if meta.inverse {
                // a plausible packed spectrum: reuse the same values in im
                // but keep the Hermitian-real endpoints real
                input.im.copy_from_slice(&x);
                for row in 0..4 {
                    input.im[row * bins] = 0.0;
                    input.im[row * bins + bins - 1] = 0.0;
                }
            }
            let (y_new, _) = CpuInterpreter::new().execute(meta, input.clone()).unwrap();
            let (y_ref, _) = ReferenceInterpreter::new().execute(meta, input).unwrap();
            let err = relative_rmse(&widen(&y_ref.to_complex()), &widen(&y_new.to_complex()));
            assert!(err < 1e-3, "{key}: engine vs reference rmse {err}");
        }
    }

    #[test]
    fn irfft_of_rfft_recovers_the_signal() {
        let reg = Registry::synthesize();
        let be = CpuInterpreter::new();
        let fwd = reg.get("rfft1d_tc_n256_b4_fwd").unwrap();
        let inv = reg.get("rfft1d_tc_n256_b4_inv").unwrap();
        let sig: Vec<f32> = random_signal(4 * 256, 5).iter().map(|c| c.re).collect();
        let input = PlanarBatch::from_real(&sig, vec![4, 256]);
        let (spec, _) = be.execute(fwd, input.clone()).unwrap();
        let (back, _) = be.execute(inv, spec).unwrap();
        assert_eq!(back.shape, vec![4, 256]);
        let q = input.quantize_f16();
        for i in 0..4 * 256 {
            // unnormalized: back = n * x
            assert!(
                (back.re[i] / 256.0 - q.re[i]).abs() < 0.01,
                "sample {i}: {} vs {}",
                back.re[i] / 256.0,
                q.re[i]
            );
            assert_eq!(back.im[i], 0.0, "C2R output must be real");
        }
    }

    #[test]
    fn rfft2d_impulse_gives_flat_packed_spectrum() {
        let reg = Registry::synthesize();
        let meta = reg.get("rfft2d_tc_nx16x16_b4_fwd").unwrap();
        let be = CpuInterpreter::new();
        let mut x = PlanarBatch::new(vec![4, 16, 16]);
        x.re[0] = 1.0; // real impulse at (0, 0) of field 0
        let (y, _) = be.execute(meta, x).unwrap();
        assert_eq!(y.shape, vec![4, 16, 9]);
        for i in 0..16 * 9 {
            assert!((y.re[i] - 1.0).abs() < 0.02, "bin {i}: {}", y.re[i]);
            assert!(y.im[i].abs() < 0.02, "bin {i}: {}", y.im[i]);
        }
        // remaining fields were zero and stay zero
        assert!(y.re[16 * 9..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn rfft2d_matches_the_2d_dft_definition() {
        let reg = Registry::synthesize();
        let be = CpuInterpreter::new();
        let meta = reg.get("rfft2d_tc_nx16x16_b4_fwd").unwrap();
        let sig: Vec<f32> = random_signal(16 * 16, 23).iter().map(|c| c.re).collect();
        let input = PlanarBatch::from_real(&sig, vec![1, 16, 16]).pad_batch(4);
        let (out, _) = be.execute(meta, input.clone()).unwrap();
        let q = input.quantize_f16();
        let want = crate::fft::oracle2d(&widen(&q.to_complex()[..256]), 16, 16, false);
        let got = widen(&out.to_complex()[..16 * 9]);
        for r in 0..16 {
            for c in 0..9 {
                let (w, g) = (want[r * 16 + c], got[r * 9 + c]);
                assert!((w - g).abs() < 0.4, "bin ({r},{c}): {g:?} vs {w:?}");
            }
        }
        let err = relative_rmse(
            &(0..16).flat_map(|r| want[r * 16..r * 16 + 9].to_vec()).collect::<Vec<_>>(),
            &got,
        );
        assert!(err < 5e-3, "rfft2d rmse {err}");
    }

    #[test]
    fn irfft2d_of_rfft2d_recovers_the_field() {
        let reg = Registry::synthesize();
        let be = CpuInterpreter::new();
        let fwd = reg.get("rfft2d_tc_nx32x32_b4_fwd").unwrap();
        let inv = reg.get("rfft2d_tc_nx32x32_b4_inv").unwrap();
        let sig: Vec<f32> = random_signal(4 * 32 * 32, 31).iter().map(|c| c.re).collect();
        let input = PlanarBatch::from_real(&sig, vec![4, 32, 32]);
        let (spec, _) = be.execute(fwd, input.clone()).unwrap();
        assert_eq!(spec.shape, vec![4, 32, 17]);
        let (back, _) = be.execute(inv, spec).unwrap();
        assert_eq!(back.shape, vec![4, 32, 32]);
        let q = input.quantize_f16();
        let scale = (32 * 32) as f32;
        for i in 0..4 * 32 * 32 {
            // unnormalized 2D inverse: back = nx * ny * x
            assert!(
                (back.re[i] / scale - q.re[i]).abs() < 0.02,
                "sample {i}: {} vs {}",
                back.re[i] / scale,
                q.re[i]
            );
            assert_eq!(back.im[i], 0.0, "C2R output must be real");
        }
    }

    #[test]
    fn rfft2d_engine_tracks_reference_closely() {
        let reg = Registry::synthesize();
        for key in ["rfft2d_tc_nx32x32_b4_fwd", "rfft2d_tc_nx32x32_b4_inv"] {
            let meta = reg.get(key).unwrap();
            let tail: usize = meta.input_shape[1..].iter().product();
            let x: Vec<f32> = (0..4 * tail)
                .map(|i| ((i * 31 + 7) % 43) as f32 / 43.0 - 0.5)
                .collect();
            let mut input = PlanarBatch::new(meta.input_shape.clone());
            input.re.copy_from_slice(&x);
            if meta.inverse {
                input.im.copy_from_slice(&x);
            }
            let (y_new, _) = CpuInterpreter::new().execute(meta, input.clone()).unwrap();
            let (y_ref, _) = ReferenceInterpreter::new().execute(meta, input).unwrap();
            let err = relative_rmse(&widen(&y_ref.to_complex()), &widen(&y_new.to_complex()));
            assert!(err < 1e-3, "{key}: engine vs reference rmse {err}");
        }
    }

    #[test]
    fn fusion_respects_split_and_limit() {
        // tc stages fuse (small n2), tc_split and tc_ec never fuse
        let tc = AxisPipeline::build(256, "tc", false, true);
        assert!(tc.stages.iter().all(|s| s.fused()));
        let split = AxisPipeline::build(256, "tc_split", false, true);
        assert!(split.stages.iter().all(|s| !s.fused()));
        let ec = AxisPipeline::build(256, "tc_ec", false, true);
        assert!(ec.stages.iter().all(|s| !s.fused() && s.ec));
        let raw = AxisPipeline::build(256, "f32ref", false, true);
        assert!(raw.stages.iter().all(|s| !s.fused() && s.raw));
        // the pricing charges both W layouts (2*r*r*n2): one element
        // past the boundary falls back to the two-pass kernel
        let boundary = FUSE_LIMIT / (2 * 16 * 16);
        let fits = MergeStage::build(16, boundary, false, StageTier::default(), true);
        assert!(fits.fused());
        let big = MergeStage::build(16, boundary + 1, false, StageTier::default(), true);
        assert!(!big.fused());
        // fuse=false (reference compile) never builds W
        let unfused = AxisPipeline::build(256, "tc", false, false);
        assert!(unfused.stages.iter().all(|s| !s.fused()));
    }

    #[test]
    fn fused_stages_carry_both_w_layouts_with_identical_bits() {
        let st = MergeStage::build(16, 4, false, StageTier::default(), true);
        assert!(st.fused());
        assert_eq!(st.w_re_mj.len(), st.w_re.len());
        for m in 0..16 {
            for j in 0..16 {
                for k in 0..4 {
                    let o_mj = (m * 16 + j) * 4 + k;
                    let o_km = (k * 16 + m) * 16 + j;
                    assert_eq!(st.w_re_mj[o_mj].to_bits(), st.w_re[o_km].to_bits());
                    assert_eq!(st.w_im_mj[o_mj].to_bits(), st.w_im[o_km].to_bits());
                }
            }
        }
    }

    #[test]
    fn ec_tables_carry_fp16_residuals() {
        let ec_tier = StageTier { ec: true, ..StageTier::default() };
        let st = MergeStage::build(16, 4, false, ec_tier, true);
        assert_eq!(st.f_re_lo.len(), st.f_re.len());
        assert_eq!(st.t_re_lo.len(), st.t_re.len());
        for i in 0..st.f_re.len() {
            // each lo half is itself an fp16 value well below its hi
            assert_eq!(rnd16(st.f_re_lo[i]).to_bits(), st.f_re_lo[i].to_bits());
            assert!(st.f_re_lo[i].abs() <= 5e-4, "lo[{i}] = {}", st.f_re_lo[i]);
        }
        // non-ec stages carry no residual tables
        let plain = MergeStage::build(16, 4, false, StageTier::default(), true);
        assert!(plain.f_re_lo.is_empty() && plain.t_re_lo.is_empty());
    }

    /// A hand-built `f32ref` variant (the synthesized catalog does not
    /// carry the diagnostic tier; tests construct it directly).
    fn meta_f32ref(op: &str, n: usize, batch: usize) -> VariantMeta {
        VariantMeta {
            key: format!("{op}_f32ref_n{n}_b{batch}_fwd"),
            file: std::path::PathBuf::new(),
            op: op.to_string(),
            algo: "f32ref".to_string(),
            n,
            nx: n,
            ny: n,
            batch,
            inverse: false,
            input_shape: vec![batch, n],
            stages: Vec::new(),
            flops_per_seq: 0.0,
            hbm_bytes_per_seq: 0.0,
            radix2_equiv_flops: 0.0,
        }
    }

    #[test]
    fn f32ref_tier_runs_unrounded_and_rejects_real_ops() {
        // the raw tier's tables keep bits fp16 rounding would drop
        let raw = AxisPipeline::build(64, "f32ref", false, true);
        assert!(raw
            .stages
            .iter()
            .any(|s| s.f_re.iter().any(|&v| rnd16(v).to_bits() != v.to_bits())));
        // unquantized input, unrounded stores: far tighter than tc
        let meta = meta_f32ref("fft1d", 64, 4);
        let sig = random_signal(64, 7);
        let input = PlanarBatch::from_complex(&sig, vec![1, 64]).pad_batch(4);
        let (out, _) = CpuInterpreter::new().execute(&meta, input.clone()).unwrap();
        let (out_ref, _) = ReferenceInterpreter::new().execute(&meta, input.clone()).unwrap();
        let want = refdft::dft(&widen(&input.to_complex()[..64]), false);
        let err = relative_rmse(&want, &widen(&out.to_complex()[..64]));
        assert!(err < 1e-6, "f32ref rmse {err}");
        for i in 0..out.len() {
            assert_eq!(out.re[i].to_bits(), out_ref.re[i].to_bits(), "re[{i}]");
            assert_eq!(out.im[i].to_bits(), out_ref.im[i].to_bits(), "im[{i}]");
        }
        // complex-only: the real path's half-spectrum tables are fp16
        let rmeta = meta_f32ref("rfft1d", 64, 4);
        let rin = PlanarBatch::new(vec![4, 64]);
        assert!(CpuInterpreter::new().execute(&rmeta, rin.clone()).is_err());
        assert!(ReferenceInterpreter::new().execute(&rmeta, rin).is_err());
    }

    #[test]
    fn ec_tier_tracks_the_oracle_tightly() {
        // measured ladder (tests/precision_ladder.rs): tc sits near
        // 3e-4 at this size; the compensated tier recovers to ~1e-7
        let reg = Registry::synthesize();
        let be = CpuInterpreter::new();
        let meta = reg.get("fft1d_tc_ec_n64_b4_fwd").unwrap();
        let sig = random_signal(64, 7);
        let input = PlanarBatch::from_complex(&sig, vec![1, 64]).pad_batch(4);
        let (out, _) = be.execute(meta, input.clone()).unwrap();
        let mut q = input;
        q.quantize_f16_ec_mut();
        let want = refdft::dft(&widen(&q.to_complex()[..64]), false);
        let got = widen(&out.to_complex()[..64]);
        let err = relative_rmse(&want, &got);
        assert!(err < 1e-5, "ec rmse {err}");
    }

    #[test]
    fn ec_engines_are_bit_identical() {
        let reg = Registry::synthesize();
        let meta = reg.get("fft1d_tc_ec_n256_b4_fwd").unwrap();
        let x: Vec<_> = (0..4).flat_map(|b| random_signal(256, 11 + b as u64)).collect();
        let input = PlanarBatch::from_complex(&x, vec![4, 256]);
        let (y_new, _) = CpuInterpreter::new().execute(meta, input.clone()).unwrap();
        let (y_ref, _) = ReferenceInterpreter::new().execute(meta, input).unwrap();
        for i in 0..y_new.len() {
            assert_eq!(y_new.re[i].to_bits(), y_ref.re[i].to_bits(), "re[{i}]");
            assert_eq!(y_new.im[i].to_bits(), y_ref.im[i].to_bits(), "im[{i}]");
        }
    }
}
