//! Pure-Rust interpreter backend: executes the planner's radix-stage
//! schedules directly on `PlanarBatch` fp16 planar buffers, emulating
//! the Tensor-Core/MXU mma semantics of the paper (fp16 operands,
//! f32 accumulation) without PJRT, XLA or any artifact files.
//!
//! Numeric model, per merging stage `X_out = F_r (T (.) X_in)`:
//! * the DFT matrix `F_r` and twiddle table `T` are rounded to fp16
//!   once at "compile" time (the device holds them in half precision);
//! * inputs enter each stage as fp16 values (exactly representable in
//!   the f32 working registers — an fp16 x fp16 product is exact in
//!   f32, which is precisely the Tensor Core fragment contract);
//! * dot products accumulate in f32 (the mma accumulator);
//! * stage outputs are rounded back to fp16 (the device-memory store
//!   between merging kernels).
//!
//! The `tc_split` ablation additionally rounds the twiddled operand to
//! fp16 before the matrix multiply — the extra global-memory round
//! trip of the de-fused kernel — so the split variant is measurably
//! less fused both in time and in rounding, mirroring paper Sec 5.4.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};
use std::time::Instant;

use super::buffers::PlanarBatch;
use super::registry::VariantMeta;
use super::{Backend, ExecStats};
use crate::error::Result;
use crate::fft::digitrev;
use crate::hp::F16;

/// Largest single-stage radix the schedules produce (16 from the
/// paper's radix-16 formulation; trailing stages are 2/4/8).
const MAX_RADIX: usize = 16;

#[inline]
fn rnd16(x: f32) -> f32 {
    F16::from_f32(x).to_f32()
}

/// One merge stage with fp16-rounded operand tables.
struct MergeStage {
    r: usize,
    n2: usize,
    /// F_r row-major [m*r + j], fp16 values widened to f32
    f_re: Vec<f32>,
    f_im: Vec<f32>,
    /// T[j][k] row-major [j*n2 + k], fp16 values widened to f32
    t_re: Vec<f32>,
    t_im: Vec<f32>,
    /// de-fused ablation: round the twiddled operand before the matmul
    split: bool,
}

impl MergeStage {
    fn build(r: usize, n2: usize, inverse: bool, split: bool) -> MergeStage {
        assert!(r >= 2 && r <= MAX_RADIX, "stage radix {r} out of range");
        let sign = if inverse { 2.0 } else { -2.0 };
        let mut f_re = vec![0f32; r * r];
        let mut f_im = vec![0f32; r * r];
        for m in 0..r {
            for j in 0..r {
                let e = ((m * j) % r) as f64;
                let ang = sign * std::f64::consts::PI * e / r as f64;
                f_re[m * r + j] = rnd16(ang.cos() as f32);
                f_im[m * r + j] = rnd16(ang.sin() as f32);
            }
        }
        let block = r * n2;
        let mut t_re = vec![0f32; r * n2];
        let mut t_im = vec![0f32; r * n2];
        for j in 0..r {
            for k in 0..n2 {
                let e = ((j * k) % block) as f64;
                let ang = sign * std::f64::consts::PI * e / block as f64;
                t_re[j * n2 + k] = rnd16(ang.cos() as f32);
                t_im[j * n2 + k] = rnd16(ang.sin() as f32);
            }
        }
        MergeStage { r, n2, f_re, f_im, t_re, t_im, split }
    }
}

/// The staged pipeline for one transform axis.
struct AxisPipeline {
    n_axis: usize,
    perm: Vec<usize>,
    stages: Vec<MergeStage>,
}

impl AxisPipeline {
    fn build(n_axis: usize, algo: &str, inverse: bool) -> AxisPipeline {
        let radices: Vec<usize> = if algo == "r2" {
            vec![2; n_axis.trailing_zeros() as usize]
        } else {
            digitrev::radix_schedule(n_axis)
        };
        let perm = digitrev::digit_reverse_indices(n_axis, &radices);
        let split = algo == "tc_split";
        let mut stages = Vec::with_capacity(radices.len());
        let mut n2 = 1usize;
        for &r in &radices {
            stages.push(MergeStage::build(r, n2, inverse, split));
            n2 *= r;
        }
        debug_assert_eq!(n2, n_axis);
        AxisPipeline { n_axis, perm, stages }
    }

    /// Transform every row of a (rows, n_axis, lane) planar tensor
    /// along the middle axis, in place.
    fn run(&self, re: &mut [f32], im: &mut [f32], rows: usize, lane: usize) {
        let row_len = self.n_axis * lane;
        assert_eq!(re.len(), rows * row_len);
        let mut cur_re = vec![0f32; row_len];
        let mut cur_im = vec![0f32; row_len];
        let mut nxt_re = vec![0f32; row_len];
        let mut nxt_im = vec![0f32; row_len];
        for row in 0..rows {
            let base = row * row_len;
            // digit-reverse gather into the working buffer
            for (i, &p) in self.perm.iter().enumerate() {
                let s = base + p * lane;
                let d = i * lane;
                cur_re[d..d + lane].copy_from_slice(&re[s..s + lane]);
                cur_im[d..d + lane].copy_from_slice(&im[s..s + lane]);
            }
            for st in &self.stages {
                apply_stage(st, &cur_re, &cur_im, &mut nxt_re, &mut nxt_im, lane);
                std::mem::swap(&mut cur_re, &mut nxt_re);
                std::mem::swap(&mut cur_im, &mut nxt_im);
            }
            re[base..base + row_len].copy_from_slice(&cur_re);
            im[base..base + row_len].copy_from_slice(&cur_im);
        }
    }
}

/// One merge stage over a single row: gather (r, n2) blocks, twiddle,
/// multiply by F_r with f32 accumulation, store rounded to fp16.
fn apply_stage(
    st: &MergeStage,
    in_re: &[f32],
    in_im: &[f32],
    out_re: &mut [f32],
    out_im: &mut [f32],
    lane: usize,
) {
    let r = st.r;
    let n2 = st.n2;
    let block = r * n2;
    let groups = in_re.len() / (block * lane);
    let mut xr = [0f32; MAX_RADIX];
    let mut xi = [0f32; MAX_RADIX];
    for g in 0..groups {
        let gbase = g * block;
        for k in 0..n2 {
            for l in 0..lane {
                // gather + twiddle: y_j = T[j][k] * x[g, j, k]
                for j in 0..r {
                    let idx = (gbase + j * n2 + k) * lane + l;
                    let (ar, ai) = (in_re[idx], in_im[idx]);
                    let (tr, ti) = (st.t_re[j * n2 + k], st.t_im[j * n2 + k]);
                    let mut yr = ar * tr - ai * ti;
                    let mut yi = ar * ti + ai * tr;
                    if st.split {
                        yr = rnd16(yr);
                        yi = rnd16(yi);
                    }
                    xr[j] = yr;
                    xi[j] = yi;
                }
                // mma: out_m = sum_j F[m][j] * y_j (f32 accumulate)
                for m in 0..r {
                    let fo = m * r;
                    let mut acc_re = 0f32;
                    let mut acc_im = 0f32;
                    for j in 0..r {
                        let (fr, fi) = (st.f_re[fo + j], st.f_im[fo + j]);
                        acc_re += fr * xr[j] - fi * xi[j];
                        acc_im += fr * xi[j] + fi * xr[j];
                    }
                    let idx = (gbase + m * n2 + k) * lane + l;
                    out_re[idx] = rnd16(acc_re);
                    out_im[idx] = rnd16(acc_im);
                }
            }
        }
    }
}

/// A fully built transform: one axis pass for 1D, two for 2D.
struct Compiled {
    axes: Vec<AxisPipeline>,
}

impl Compiled {
    fn build(meta: &VariantMeta) -> Compiled {
        let axes = if meta.op == "fft1d" {
            vec![AxisPipeline::build(meta.n, &meta.algo, meta.inverse)]
        } else {
            // contiguous ny rows first, then the strided nx axis
            vec![
                AxisPipeline::build(meta.ny, &meta.algo, meta.inverse),
                AxisPipeline::build(meta.nx, &meta.algo, meta.inverse),
            ]
        };
        Compiled { axes }
    }
}

/// The pure-Rust interpreter backend (the offline default).
pub struct CpuInterpreter {
    cache: RwLock<HashMap<String, Arc<Compiled>>>,
}

impl CpuInterpreter {
    pub fn new() -> CpuInterpreter {
        CpuInterpreter { cache: RwLock::new(HashMap::new()) }
    }

    /// Fetch or build the staged pipeline for an artifact; the bool is
    /// true when this call built it (the "compile" in ExecStats).
    fn compiled(&self, meta: &VariantMeta) -> (Arc<Compiled>, bool) {
        if let Some(c) = self.cache.read().unwrap().get(&meta.key) {
            return (Arc::clone(c), false);
        }
        let built = Arc::new(Compiled::build(meta));
        let mut cache = self.cache.write().unwrap();
        match cache.get(&meta.key) {
            Some(c) => (Arc::clone(c), false), // raced: another thread built it
            None => {
                cache.insert(meta.key.clone(), Arc::clone(&built));
                (built, true)
            }
        }
    }
}

impl Default for CpuInterpreter {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for CpuInterpreter {
    fn name(&self) -> &'static str {
        "cpu-interpreter"
    }

    fn execute(&self, meta: &VariantMeta, input: PlanarBatch) -> Result<(PlanarBatch, ExecStats)> {
        let (compiled, fresh) = self.compiled(meta);

        // marshal: quantize the host f32 input to the fp16 the device sees
        let tm = Instant::now();
        let mut q = input.quantize_f16();
        let marshal_seconds = tm.elapsed().as_secs_f64();

        let te = Instant::now();
        let batch = q.shape[0];
        if meta.op == "fft1d" {
            compiled.axes[0].run(&mut q.re, &mut q.im, batch, 1);
        } else {
            let (nx, ny) = (meta.nx, meta.ny);
            compiled.axes[0].run(&mut q.re, &mut q.im, batch * nx, 1);
            compiled.axes[1].run(&mut q.re, &mut q.im, batch, ny);
        }
        let exec_seconds = te.elapsed().as_secs_f64();
        Ok((q, ExecStats { exec_seconds, marshal_seconds, compiled: fresh }))
    }

    fn warm(&self, meta: &VariantMeta) -> Result<f64> {
        let t0 = Instant::now();
        let (_, fresh) = self.compiled(meta);
        Ok(if fresh { t0.elapsed().as_secs_f64() } else { 0.0 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::relative_rmse;
    use crate::fft::refdft;
    use crate::hp::{C32, C64};
    use crate::runtime::Registry;
    use crate::workload::random_signal;

    fn widen(x: &[C32]) -> Vec<C64> {
        x.iter().map(|c| C64::new(c.re as f64, c.im as f64)).collect()
    }

    #[test]
    fn impulse_gives_flat_spectrum() {
        let reg = Registry::synthesize();
        let meta = reg.get("fft1d_tc_n256_b4_fwd").unwrap();
        let be = CpuInterpreter::new();
        let mut x = PlanarBatch::new(vec![4, 256]);
        x.re[0] = 1.0; // impulse in row 0 only
        let (y, stats) = be.execute(meta, x).unwrap();
        assert!(stats.compiled);
        for k in 0..256 {
            assert!((y.re[k] - 1.0).abs() < 0.01, "bin {k}: {}", y.re[k]);
            assert!(y.im[k].abs() < 0.01, "bin {k}: {}", y.im[k]);
        }
        // remaining rows were zero and stay zero
        assert!(y.re[256..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn matches_refdft_small() {
        let reg = Registry::synthesize();
        let be = CpuInterpreter::new();
        let meta = reg.get("fft1d_tc_n64_b4_fwd").unwrap();
        let sig = random_signal(64, 7);
        let input = PlanarBatch::from_complex(&sig, vec![1, 64]).pad_batch(4);
        let (out, _) = be.execute(meta, input.clone()).unwrap();
        let want = refdft::dft(&widen(&input.quantize_f16().to_complex()[..64]), false);
        let got = widen(&out.to_complex()[..64]);
        let err = relative_rmse(&want, &got);
        assert!(err < 2e-3, "rmse {err}");
    }

    #[test]
    fn second_execute_hits_the_cache() {
        let reg = Registry::synthesize();
        let be = CpuInterpreter::new();
        let meta = reg.get("fft1d_tc_n16_b4_fwd").unwrap();
        let x = PlanarBatch::new(vec![4, 16]);
        let (_, s1) = be.execute(meta, x.clone()).unwrap();
        let (_, s2) = be.execute(meta, x).unwrap();
        assert!(s1.compiled);
        assert!(!s2.compiled);
    }

    #[test]
    fn warm_builds_once() {
        let reg = Registry::synthesize();
        let be = CpuInterpreter::new();
        let meta = reg.get("fft1d_tc_n1024_b4_fwd").unwrap();
        let first = be.warm(meta).unwrap();
        let second = be.warm(meta).unwrap();
        assert!(first >= 0.0);
        assert_eq!(second, 0.0);
    }
}
