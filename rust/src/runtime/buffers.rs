//! Planar complex buffer marshalling between host f32 and the fp16
//! PJRT literals the artifacts consume/produce.

use crate::hp::{f16, C32, F16};

/// A batch of planar complex data with a logical shape.
#[derive(Clone, Debug, Default)]
pub struct PlanarBatch {
    /// real plane, row-major over `shape`
    pub re: Vec<f32>,
    /// imaginary plane, same layout as `re`
    pub im: Vec<f32>,
    /// logical dims, e.g. [batch, n] or [batch, nx, ny]
    pub shape: Vec<usize>,
}

impl PlanarBatch {
    /// Zero-filled batch of the given logical shape.
    pub fn new(shape: Vec<usize>) -> Self {
        let len = shape.iter().product();
        PlanarBatch { re: vec![0.0; len], im: vec![0.0; len], shape }
    }

    /// Split an interleaved complex slice into the planar layout.
    pub fn from_complex(x: &[C32], shape: Vec<usize>) -> Self {
        assert_eq!(x.len(), shape.iter().product::<usize>());
        PlanarBatch {
            re: x.iter().map(|c| c.re).collect(),
            im: x.iter().map(|c| c.im).collect(),
            shape,
        }
    }

    /// Build a real-signal batch: the samples fill the `re` plane and
    /// the imaginary plane is zero — the input layout of the R2C path
    /// (`rfft1d` forward), which reads only `re`.
    pub fn from_real(x: &[f32], shape: Vec<usize>) -> Self {
        assert_eq!(x.len(), shape.iter().product::<usize>());
        PlanarBatch { re: x.to_vec(), im: vec![0.0; x.len()], shape }
    }

    /// Join the planes back into interleaved complex values.
    pub fn to_complex(&self) -> Vec<C32> {
        self.re
            .iter()
            .zip(&self.im)
            .map(|(&r, &i)| C32::new(r, i))
            .collect()
    }

    /// Total elements per plane (`shape` product).
    pub fn len(&self) -> usize {
        self.re.len()
    }

    /// True when the batch holds no elements.
    pub fn is_empty(&self) -> bool {
        self.re.is_empty()
    }

    /// fp16-encode the planar parts (the quantization the device sees).
    pub fn encode_f16(&self) -> (Vec<u8>, Vec<u8>) {
        (f16::encode_f32_slice(&self.re), f16::encode_f32_slice(&self.im))
    }

    /// Rebuild from raw fp16 bytes (device output).
    pub fn decode_f16(re: &[u8], im: &[u8], shape: Vec<usize>) -> Self {
        let re = f16::decode_to_f32(re);
        let im = f16::decode_to_f32(im);
        assert_eq!(re.len(), shape.iter().product::<usize>());
        assert_eq!(re.len(), im.len());
        PlanarBatch { re, im, shape }
    }

    /// Quantize through fp16 and back — what the host sees after a
    /// round trip, used to compute the paper's input quantization floor.
    pub fn quantize_f16(&self) -> Self {
        let (re, im) = self.encode_f16();
        Self::decode_f16(&re, &im, self.shape.clone())
    }

    /// In-place variant of [`quantize_f16`](Self::quantize_f16): same
    /// rounding, no byte staging and no new allocations. This is the
    /// marshal step of `Backend::execute`, which owns its input and has
    /// no reason to clone the whole batch just to round it.
    pub fn quantize_f16_mut(&mut self) {
        for v in self.re.iter_mut() {
            *v = F16::round_f32(*v);
        }
        for v in self.im.iter_mut() {
            *v = F16::round_f32(*v);
        }
    }

    /// Error-corrected marshal for the `tc_ec` tier: each element is
    /// replaced by the exact f32 sum of its fp16 hi half and the
    /// fp16-rounded residual `lo = fp16(x - hi)`. The two halves sit
    /// ~11 bits apart, so the sum fits f32's 24-bit mantissa exactly
    /// and downstream kernels recover `hi` with one fp16 rounding and
    /// `lo` by exact subtraction.
    pub fn quantize_f16_ec_mut(&mut self) {
        for v in self.re.iter_mut().chain(self.im.iter_mut()) {
            let h = F16::round_f32(*v);
            // fp16 overflow saturates to inf; adding the (-inf)
            // residual would turn it into NaN, so keep the plain store
            *v = if h.is_finite() { h + F16::round_f32(*v - h) } else { h };
        }
    }

    /// Slice out batch rows [lo, hi) (first-dim slicing).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Self {
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        PlanarBatch {
            re: self.re[lo * row..hi * row].to_vec(),
            im: self.im[lo * row..hi * row].to_vec(),
            shape,
        }
    }

    /// Concatenate along the batch dim; shapes after dim 0 must match.
    pub fn concat(parts: &[PlanarBatch]) -> Self {
        assert!(!parts.is_empty());
        let tail = &parts[0].shape[1..];
        let mut re = Vec::new();
        let mut im = Vec::new();
        let mut b = 0;
        for p in parts {
            assert_eq!(&p.shape[1..], tail, "ragged concat");
            b += p.shape[0];
            re.extend_from_slice(&p.re);
            im.extend_from_slice(&p.im);
        }
        let mut shape = vec![b];
        shape.extend_from_slice(tail);
        PlanarBatch { re, im, shape }
    }

    /// Zero-pad the batch dim up to `batch` rows. Reserves the exact
    /// target capacity up front instead of cloning at the source size
    /// and growing (which reallocated and re-copied every call).
    pub fn pad_batch(&self, batch: usize) -> Self {
        assert!(batch >= self.shape[0]);
        let row: usize = self.shape[1..].iter().product();
        let len = batch * row;
        let mut shape = self.shape.clone();
        shape[0] = batch;
        let mut re = Vec::with_capacity(len);
        re.extend_from_slice(&self.re);
        re.resize(len, 0.0);
        let mut im = Vec::with_capacity(len);
        im.extend_from_slice(&self.im);
        im.resize(len, 0.0);
        PlanarBatch { re, im, shape }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_real_zeroes_the_imaginary_plane() {
        let b = PlanarBatch::from_real(&[1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(b.re, vec![1.0, 2.0, 3.0, 4.0]);
        assert!(b.im.iter().all(|&v| v == 0.0));
        assert_eq!(b.shape, vec![2, 2]);
    }

    #[test]
    fn complex_round_trip() {
        let xs: Vec<C32> = (0..6).map(|i| C32::new(i as f32 * 0.25, -1.0)).collect();
        let b = PlanarBatch::from_complex(&xs, vec![2, 3]);
        assert_eq!(b.to_complex(), xs);
    }

    #[test]
    fn f16_quantization_is_idempotent() {
        let xs: Vec<C32> = (0..16).map(|i| C32::new(0.1 * i as f32, 0.7)).collect();
        let b = PlanarBatch::from_complex(&xs, vec![1, 16]);
        let q1 = b.quantize_f16();
        let q2 = q1.quantize_f16();
        assert_eq!(q1.re, q2.re);
        assert_eq!(q1.im, q2.im);
    }

    #[test]
    fn quantize_mut_matches_quantize() {
        let xs: Vec<C32> = (0..512)
            .map(|i| {
                let t = i as f32;
                C32::new((t * 0.731).sin() * 3.0e4, 1.0 / (t + 0.07) - 9.0e-6)
            })
            .collect();
        let b = PlanarBatch::from_complex(&xs, vec![2, 256]);
        let want = b.quantize_f16();
        let mut got = b.clone();
        got.quantize_f16_mut();
        // bit-exact: same fp16 rounding as the encode/decode round trip
        for i in 0..want.len() {
            assert_eq!(want.re[i].to_bits(), got.re[i].to_bits(), "re[{i}]");
            assert_eq!(want.im[i].to_bits(), got.im[i].to_bits(), "im[{i}]");
        }
        assert_eq!(want.shape, got.shape);
    }

    #[test]
    fn ec_quantization_carries_the_residual() {
        let xs: Vec<C32> = (0..256)
            .map(|i| {
                let t = i as f32;
                C32::new((t * 0.917).sin() * 2.0, (t * 0.31).cos() * 0.125)
            })
            .collect();
        let b = PlanarBatch::from_complex(&xs, vec![1, 256]);
        let mut ec = b.clone();
        ec.quantize_f16_ec_mut();
        let q = b.quantize_f16();
        for i in 0..b.len() {
            // the hi half is recovered by one fp16 rounding of the sum
            assert_eq!(F16::round_f32(ec.re[i]).to_bits(), q.re[i].to_bits(), "re[{i}]");
            // and the carried sum is at least as close to the source
            assert!(
                (ec.re[i] - b.re[i]).abs() <= (q.re[i] - b.re[i]).abs(),
                "re[{i}]: ec {} vs plain {}",
                ec.re[i],
                q.re[i]
            );
        }
        // idempotent: re-marshalling an ec sum keeps it bit-exact (the
        // plan batcher re-rounds split chunks, which must not drift)
        let mut twice = ec.clone();
        twice.quantize_f16_ec_mut();
        for i in 0..b.len() {
            assert_eq!(twice.re[i].to_bits(), ec.re[i].to_bits(), "re[{i}]");
            assert_eq!(twice.im[i].to_bits(), ec.im[i].to_bits(), "im[{i}]");
        }
    }

    #[test]
    fn pad_batch_reserves_exact_capacity() {
        let b = PlanarBatch::from_complex(
            &(0..8).map(|i| C32::new(i as f32, 0.0)).collect::<Vec<_>>(),
            vec![2, 4],
        );
        let p = b.pad_batch(16);
        // with_capacity only guarantees a lower bound, so assert the
        // robust form of "reserved up front": enough room, full length
        assert!(p.re.capacity() >= 64, "cap {}", p.re.capacity());
        assert!(p.im.capacity() >= 64, "cap {}", p.im.capacity());
        assert_eq!(p.re.len(), 64);
    }

    #[test]
    fn slicing_and_concat() {
        let b = PlanarBatch::from_complex(
            &(0..12).map(|i| C32::new(i as f32, 0.0)).collect::<Vec<_>>(),
            vec![4, 3],
        );
        let lo = b.slice_rows(0, 2);
        let hi = b.slice_rows(2, 4);
        assert_eq!(lo.shape, vec![2, 3]);
        let joined = PlanarBatch::concat(&[lo, hi]);
        assert_eq!(joined.re, b.re);
        assert_eq!(joined.shape, b.shape);
    }

    #[test]
    fn padding() {
        let b = PlanarBatch::from_complex(
            &(0..4).map(|i| C32::new(i as f32, 1.0)).collect::<Vec<_>>(),
            vec![1, 4],
        );
        let p = b.pad_batch(3);
        assert_eq!(p.shape, vec![3, 4]);
        assert_eq!(p.re[4..], [0.0; 8]);
        assert_eq!(p.slice_rows(0, 1).re, b.re);
    }
}
