//! IEEE 754 binary16 (half precision) codec, written from scratch.
//!
//! The offline toolchain has no `half` crate, and the request path must
//! marshal planar fp16 buffers into and out of PJRT literals, so we
//! implement the conversion ourselves. Round-to-nearest-even on encode,
//! full subnormal/inf/nan handling both ways.

/// A half-precision float stored as its raw bit pattern.
#[derive(Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct F16(pub u16);

impl F16 {
    pub const ZERO: F16 = F16(0);
    pub const ONE: F16 = F16(0x3C00);
    pub const NEG_ONE: F16 = F16(0xBC00);
    pub const INFINITY: F16 = F16(0x7C00);
    pub const NEG_INFINITY: F16 = F16(0xFC00);
    pub const NAN: F16 = F16(0x7E00);
    /// Largest finite value (65504.0).
    pub const MAX: F16 = F16(0x7BFF);
    /// Smallest positive normal (6.103515625e-5).
    pub const MIN_POSITIVE: F16 = F16(0x0400);
    /// Machine epsilon (2^-10).
    pub const EPSILON: F16 = F16(0x1400);

    #[inline]
    pub fn from_bits(bits: u16) -> F16 {
        F16(bits)
    }

    #[inline]
    pub fn to_bits(self) -> u16 {
        self.0
    }

    /// Convert from f32 with round-to-nearest-even (hardware semantics).
    pub fn from_f32(x: f32) -> F16 {
        let bits = x.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp = ((bits >> 23) & 0xFF) as i32;
        let frac = bits & 0x7F_FFFF;

        if exp == 0xFF {
            // inf or nan
            if frac == 0 {
                return F16(sign | 0x7C00);
            }
            // preserve a quiet nan, keep top fraction bits
            let f = ((frac >> 13) as u16) | 0x0200;
            return F16(sign | 0x7C00 | f);
        }

        // unbiased exponent
        let e = exp - 127;
        if e > 15 {
            // overflow -> inf (round-to-nearest maps just-above-max to inf)
            return F16(sign | 0x7C00);
        }
        if e >= -14 {
            // normal range: 10-bit mantissa, round to nearest even
            let mant = frac >> 13;
            let rest = frac & 0x1FFF;
            let mut h = sign | (((e + 15) as u16) << 10) | mant as u16;
            if rest > 0x1000 || (rest == 0x1000 && (mant & 1) == 1) {
                h = h.wrapping_add(1); // may carry into exponent: correct
            }
            return F16(h);
        }
        if e < -25 {
            // too small even for subnormal with rounding
            return F16(sign);
        }
        // subnormal: implicit leading 1 becomes explicit, shift right
        let full = 0x80_0000 | frac; // 24-bit significand
        let shift = (-14 - e) as u32 + 13;
        let mant = (full >> shift) as u16;
        let rest = full & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut h = sign | mant;
        if rest > half || (rest == half && (mant & 1) == 1) {
            h = h.wrapping_add(1);
        }
        F16(h)
    }

    /// Round an f32 to the nearest f16 value, returned as f32 — the
    /// interpreter's per-stage device-store rounding. Semantically
    /// identical to `F16::from_f32(x).to_f32()` (round-to-nearest-even)
    /// but with a branch-light fast path for the common case where the
    /// result is a normal f16: the 13 excess mantissa bits are rounded
    /// off directly on the f32 bit pattern. Subnormal, overflow, zero
    /// and nan inputs fall through to the full codec.
    #[inline]
    pub fn round_f32(x: f32) -> f32 {
        let bits = x.to_bits();
        let abs = bits & 0x7FFF_FFFF;
        // |x| in [2^-14, 65520): rounds to a normal f16. 65520 is the
        // first value that rounds up to infinity; below 2^-14 the
        // result is subnormal (and just-under inputs that round up to
        // 2^-14 are still handled correctly by the slow path).
        if (0x3880_0000..0x477F_F000).contains(&abs) {
            // round-to-nearest-even on the low 13 bits; a mantissa
            // carry propagates into the exponent field, which is
            // exactly the widening of the f16 carry in `from_f32`
            let lsb = (bits >> 13) & 1;
            return f32::from_bits(bits.wrapping_add(0xFFF + lsb) & !0x1FFF);
        }
        F16::from_f32(x).to_f32()
    }

    /// Convert to f32 (exact).
    pub fn to_f32(self) -> f32 {
        let sign = ((self.0 & 0x8000) as u32) << 16;
        let exp = ((self.0 >> 10) & 0x1F) as u32;
        let frac = (self.0 & 0x3FF) as u32;
        let bits = if exp == 0 {
            if frac == 0 {
                sign // +-0
            } else {
                // subnormal: normalize
                let lz = frac.leading_zeros() - 22; // zeros within 10-bit field
                let shift = lz + 1;
                let f = (frac << shift) & 0x3FF;
                let e = 127 - 15 - shift + 1;
                sign | (e << 23) | (f << 13)
            }
        } else if exp == 0x1F {
            sign | 0x7F80_0000 | (frac << 13) // inf / nan
        } else {
            sign | ((exp + 127 - 15) << 23) | (frac << 13)
        };
        f32::from_bits(bits)
    }

    pub fn from_f64(x: f64) -> F16 {
        F16::from_f32(x as f32)
    }

    pub fn to_f64(self) -> f64 {
        self.to_f32() as f64
    }

    pub fn is_nan(self) -> bool {
        (self.0 & 0x7C00) == 0x7C00 && (self.0 & 0x3FF) != 0
    }

    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7C00
    }

    pub fn is_finite(self) -> bool {
        (self.0 & 0x7C00) != 0x7C00
    }
}

impl std::fmt::Debug for F16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}f16", self.to_f32())
    }
}

impl From<f32> for F16 {
    fn from(x: f32) -> F16 {
        F16::from_f32(x)
    }
}

impl From<F16> for f32 {
    fn from(x: F16) -> f32 {
        x.to_f32()
    }
}

/// Encode a slice of f32 into raw fp16 little-endian bytes.
pub fn encode_f32_slice(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 2);
    for &x in xs {
        out.extend_from_slice(&F16::from_f32(x).to_bits().to_le_bytes());
    }
    out
}

/// Decode raw fp16 little-endian bytes into f32.
pub fn decode_to_f32(bytes: &[u8]) -> Vec<f32> {
    assert!(bytes.len() % 2 == 0, "fp16 byte buffer must be even-sized");
    bytes
        .chunks_exact(2)
        .map(|c| F16::from_bits(u16::from_le_bytes([c[0], c[1]])).to_f32())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        assert_eq!(F16::from_f32(0.0).to_bits(), 0x0000);
        assert_eq!(F16::from_f32(-0.0).to_bits(), 0x8000);
        assert_eq!(F16::from_f32(1.0).to_bits(), 0x3C00);
        assert_eq!(F16::from_f32(-1.0).to_bits(), 0xBC00);
        assert_eq!(F16::from_f32(2.0).to_bits(), 0x4000);
        assert_eq!(F16::from_f32(0.5).to_bits(), 0x3800);
        assert_eq!(F16::from_f32(65504.0).to_bits(), 0x7BFF);
        assert_eq!(F16::from_f32(1.0 / 3.0).to_bits(), 0x3555);
    }

    #[test]
    fn round_trip_all_finite_bit_patterns() {
        // every f16 -> f32 -> f16 must be the identity
        for bits in 0u16..=0xFFFF {
            let h = F16::from_bits(bits);
            if h.is_nan() {
                assert!(F16::from_f32(h.to_f32()).is_nan());
            } else {
                assert_eq!(F16::from_f32(h.to_f32()).to_bits(), bits, "bits {bits:#06x}");
            }
        }
    }

    #[test]
    fn subnormals() {
        // smallest positive subnormal = 2^-24
        let tiny = F16::from_bits(0x0001);
        assert_eq!(tiny.to_f32(), 2.0f32.powi(-24));
        assert_eq!(F16::from_f32(2.0f32.powi(-24)).to_bits(), 0x0001);
        // largest subnormal
        let sub = F16::from_bits(0x03FF);
        assert!(sub.to_f32() < F16::MIN_POSITIVE.to_f32());
    }

    #[test]
    fn overflow_and_underflow() {
        assert!(F16::from_f32(1e6).is_infinite());
        assert!(F16::from_f32(-1e6).is_infinite());
        assert_eq!(F16::from_f32(1e-10).to_bits(), 0x0000);
        assert_eq!(F16::from_f32(-1e-10).to_bits(), 0x8000);
        assert!(F16::from_f32(f32::NAN).is_nan());
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10: ties to even
        assert_eq!(F16::from_f32(1.0 + 2f32.powi(-11)).to_bits(), 0x3C00);
        // 1 + 3*2^-11 halfway between 1+2^-10 and 1+2^-9: ties to even (up)
        assert_eq!(F16::from_f32(1.0 + 3.0 * 2f32.powi(-11)).to_bits(), 0x3C02);
        // just above halfway rounds up
        assert_eq!(F16::from_f32(1.0 + 2f32.powi(-11) + 1e-6).to_bits(), 0x3C01);
    }

    #[test]
    fn mantissa_carry_into_exponent() {
        // 2047.5 rounds to 2048 (carry propagates cleanly)
        let h = F16::from_f32(2047.9);
        assert_eq!(h.to_f32(), 2048.0);
    }

    /// `round_f32` must agree bit-for-bit with the full codec
    /// (`from_f32` then `to_f32`) — exhaustively over every f16 bit
    /// pattern widened to f32 (the fixed points of the rounding).
    #[test]
    fn round_f32_agrees_on_all_f16_patterns() {
        for bits in 0u16..=0xFFFF {
            let h = F16::from_bits(bits);
            let x = h.to_f32();
            let fast = F16::round_f32(x);
            if h.is_nan() {
                assert!(fast.is_nan(), "bits {bits:#06x}");
            } else {
                assert_eq!(fast.to_bits(), x.to_bits(), "bits {bits:#06x}");
            }
        }
    }

    /// ... and over a dense strided sweep of raw f32 bit patterns
    /// (hits normals, subnormals, ties, overflow and nan encodings).
    #[test]
    fn round_f32_agrees_on_f32_sweep() {
        let mut bits = 0u32;
        loop {
            let x = f32::from_bits(bits);
            let slow = F16::from_f32(x).to_f32();
            let fast = F16::round_f32(x);
            assert!(
                fast.to_bits() == slow.to_bits() || (fast.is_nan() && slow.is_nan()),
                "bits {bits:#010x}: fast {fast} vs slow {slow}"
            );
            let (next, wrapped) = bits.overflowing_add(4_099);
            if wrapped {
                break;
            }
            bits = next;
        }
    }

    /// Targeted boundary cases around the fast-path range cut-offs.
    #[test]
    fn round_f32_boundaries() {
        for x in [
            0.0f32,
            -0.0,
            65503.99,
            65504.0,
            65519.99, // largest value still rounding down to 65504
            65520.0,  // tie: rounds up to infinity
            65536.0,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            2.0f32.powi(-14),                    // smallest normal f16
            2.0f32.powi(-14) - 2.0f32.powi(-30), // just below: subnormal result
            2.0f32.powi(-24),                    // smallest subnormal
            2.0f32.powi(-26),                    // underflows to zero
            1.0 + 2.0f32.powi(-11),              // tie at 1.0
            -(1.0 + 3.0 * 2.0f32.powi(-11)),     // tie, negative
        ] {
            let slow = F16::from_f32(x).to_f32();
            let fast = F16::round_f32(x);
            assert!(
                fast.to_bits() == slow.to_bits() || (fast.is_nan() && slow.is_nan()),
                "x {x}: fast {fast} vs slow {slow}"
            );
        }
    }

    #[test]
    fn byte_codec() {
        let xs = [0.0f32, 1.0, -2.5, 100.0, -0.125];
        let bytes = encode_f32_slice(&xs);
        assert_eq!(bytes.len(), 10);
        let back = decode_to_f32(&bytes);
        assert_eq!(back, xs.to_vec());
    }

    #[test]
    fn quantization_error_bounded() {
        // relative error of encode() is <= 2^-11 for normal range
        let mut x = 1.0f32;
        while x < 60000.0 {
            let q = F16::from_f32(x).to_f32();
            assert!(((q - x) / x).abs() <= 2f32.powi(-11) + 1e-9, "x={x}");
            x *= 1.37;
        }
    }
}
