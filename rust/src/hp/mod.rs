//! Half-precision and complex-number substrates (no external crates).

pub mod complex;
pub mod f16;

pub use complex::{Complex, Float, C32, C64};
pub use f16::F16;
