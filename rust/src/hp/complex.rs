//! Minimal generic complex arithmetic and planar/interleaved layout
//! conversions used across the host-side FFT oracles and the runtime
//! buffer marshalling.
//!
//! `num_traits` is unavailable offline, so the float abstraction the
//! generic complex type needs is defined here: just the handful of
//! operations the FFT substrates use.

/// The float operations `Complex<T>` requires (implemented for f32/f64;
/// the offline stand-in for `num_traits::Float`).
pub trait Float:
    Copy
    + PartialEq
    + PartialOrd
    + std::fmt::Debug
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::ops::Neg<Output = Self>
    + std::ops::AddAssign
{
    fn zero() -> Self;
    fn one() -> Self;
    fn sqrt(self) -> Self;
    fn sin(self) -> Self;
    fn cos(self) -> Self;
}

impl Float for f32 {
    fn zero() -> f32 {
        0.0
    }
    fn one() -> f32 {
        1.0
    }
    fn sqrt(self) -> f32 {
        f32::sqrt(self)
    }
    fn sin(self) -> f32 {
        f32::sin(self)
    }
    fn cos(self) -> f32 {
        f32::cos(self)
    }
}

impl Float for f64 {
    fn zero() -> f64 {
        0.0
    }
    fn one() -> f64 {
        1.0
    }
    fn sqrt(self) -> f64 {
        f64::sqrt(self)
    }
    fn sin(self) -> f64 {
        f64::sin(self)
    }
    fn cos(self) -> f64 {
        f64::cos(self)
    }
}

/// A complex number over any float type.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct Complex<T> {
    pub re: T,
    pub im: T,
}

pub type C64 = Complex<f64>;
pub type C32 = Complex<f32>;

impl<T: Float> Complex<T> {
    #[inline]
    pub fn new(re: T, im: T) -> Self {
        Complex { re, im }
    }

    #[inline]
    pub fn zero() -> Self {
        Complex { re: T::zero(), im: T::zero() }
    }

    #[inline]
    pub fn one() -> Self {
        Complex { re: T::one(), im: T::zero() }
    }

    /// e^{i theta}
    #[inline]
    pub fn cis(theta: T) -> Self {
        Complex { re: theta.cos(), im: theta.sin() }
    }

    #[inline]
    pub fn conj(self) -> Self {
        Complex { re: self.re, im: -self.im }
    }

    #[inline]
    pub fn norm_sqr(self) -> T {
        self.re * self.re + self.im * self.im
    }

    #[inline]
    pub fn abs(self) -> T {
        self.norm_sqr().sqrt()
    }

    #[inline]
    pub fn scale(self, s: T) -> Self {
        Complex { re: self.re * s, im: self.im * s }
    }
}

impl<T: Float> std::ops::Add for Complex<T> {
    type Output = Self;
    #[inline]
    fn add(self, o: Self) -> Self {
        Complex::new(self.re + o.re, self.im + o.im)
    }
}

impl<T: Float> std::ops::Sub for Complex<T> {
    type Output = Self;
    #[inline]
    fn sub(self, o: Self) -> Self {
        Complex::new(self.re - o.re, self.im - o.im)
    }
}

impl<T: Float> std::ops::Mul for Complex<T> {
    type Output = Self;
    #[inline]
    fn mul(self, o: Self) -> Self {
        Complex::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
}

impl<T: Float> std::ops::Neg for Complex<T> {
    type Output = Self;
    #[inline]
    fn neg(self) -> Self {
        Complex::new(-self.re, -self.im)
    }
}

impl<T: Float + std::ops::AddAssign> std::ops::AddAssign for Complex<T> {
    #[inline]
    fn add_assign(&mut self, o: Self) {
        self.re += o.re;
        self.im += o.im;
    }
}

/// Split interleaved complex `[re0, im0, re1, im1, ...]` into planar
/// (re, im) vectors — the layout the artifacts consume.
pub fn interleaved_to_planar(x: &[C32]) -> (Vec<f32>, Vec<f32>) {
    let re = x.iter().map(|c| c.re).collect();
    let im = x.iter().map(|c| c.im).collect();
    (re, im)
}

/// Join planar (re, im) back into complex values.
pub fn planar_to_interleaved(re: &[f32], im: &[f32]) -> Vec<C32> {
    assert_eq!(re.len(), im.len());
    re.iter().zip(im).map(|(&r, &i)| C32::new(r, i)).collect()
}

/// Widen a complex f32 slice to f64 (oracle input).
pub fn widen(x: &[C32]) -> Vec<C64> {
    x.iter().map(|c| C64::new(c.re as f64, c.im as f64)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = C64::new(1.0, 2.0);
        let b = C64::new(3.0, -1.0);
        assert_eq!(a + b, C64::new(4.0, 1.0));
        assert_eq!(a - b, C64::new(-2.0, 3.0));
        // (1+2i)(3-i) = 3 - i + 6i - 2i^2 = 5 + 5i
        assert_eq!(a * b, C64::new(5.0, 5.0));
        assert_eq!(a.conj(), C64::new(1.0, -2.0));
        assert!((a.abs() - 5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn cis_unit_circle() {
        let c = C64::cis(std::f64::consts::PI / 2.0);
        assert!((c.re - 0.0).abs() < 1e-12);
        assert!((c.im - 1.0).abs() < 1e-12);
    }

    #[test]
    fn layout_round_trip() {
        let xs: Vec<C32> = (0..8).map(|i| C32::new(i as f32, -(i as f32))).collect();
        let (re, im) = interleaved_to_planar(&xs);
        assert_eq!(planar_to_interleaved(&re, &im), xs);
    }
}
