//! cuFFT-style planner (paper Sec 3.1: `tcfftPlan1D` / `tcfftPlan2D`).
//!
//! A `Plan` binds a logical transform (op, size, batch, direction,
//! algorithm) to a concrete artifact plus the radix/kernel schedule.
//! Plan creation validates the Rust-side schedule against the manifest
//! the Python AOT pipeline emitted, so both sides of the AOT boundary
//! provably agree.

pub mod schedule;

use std::sync::Arc;

use crate::error::{Result, TcFftError};
use crate::fft::digitrev;
use crate::runtime::{PlanarBatch, Registry, Runtime, VariantMeta};

/// Transform direction. Inverse is UNNORMALIZED (cuFFT convention).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// Forward transform (`e^(-2*pi*i*jk/N)` kernel).
    Forward,
    /// Unnormalized inverse: `ifft(fft(x)) = N * x` — scale by `1/N`
    /// on the host to recover the signal.
    Inverse,
}

/// A bound execution plan.
///
/// The cuFFT-style lifecycle — plan once, execute many times — with no
/// artifacts required (the registry synthesizes its catalog offline):
///
/// ```
/// use tcfft::plan::Plan;
/// use tcfft::runtime::{PlanarBatch, Runtime};
///
/// let rt = Runtime::load_default().unwrap();
/// let plan = Plan::fft1d(&rt.registry, 4096, 4).unwrap();
/// let x = PlanarBatch::new(vec![4, 4096]); // fill with your signal
/// let y = plan.execute(&rt, x).unwrap();
/// assert_eq!(y.shape, vec![4, 4096]);
/// ```
#[derive(Clone, Debug)]
pub struct Plan {
    /// the bound artifact's metadata (key, shapes, stage schedule)
    pub meta: VariantMeta,
    /// transform direction the artifact was compiled for
    pub direction: Direction,
    /// merge-order radix schedule (per staged axis) for reporting
    pub radices_1d: Vec<usize>,
}

impl Plan {
    /// Plan a batched 1D FFT of length `n` (tcfftPlan1D analogue).
    pub fn fft1d(registry: &Arc<Registry>, n: usize, batch: usize) -> Result<Plan> {
        Self::fft1d_algo(registry, n, batch, "tc", Direction::Forward)
    }

    /// [`fft1d`](Self::fft1d) with an explicit algorithm
    /// (`"tc"` | `"tc_split"` | `"tc_ec"` | `"r2"`) and direction.
    pub fn fft1d_algo(
        registry: &Arc<Registry>,
        n: usize,
        batch: usize,
        algo: &str,
        direction: Direction,
    ) -> Result<Plan> {
        if !n.is_power_of_two() || n < 2 {
            crate::bail!(TcFftError::BadSize(n));
        }
        let inverse = direction == Direction::Inverse;
        let meta = registry
            .find_fft1d(n, batch, algo, inverse)
            .ok_or_else(|| {
                TcFftError::NoArtifact(format!("fft1d n={n} algo={algo} inverse={inverse}"))
            })?
            .clone();
        let plan = Plan {
            radices_1d: digitrev::radix_schedule(n),
            meta,
            direction,
        };
        plan.validate_against_manifest()?;
        Ok(plan)
    }

    /// Plan a batched R2C forward real FFT of length `n`: consumes
    /// `[batch, n]` real rows (the `re` plane; `im` is ignored) and
    /// produces the Hermitian-packed `[batch, n/2 + 1]` half spectrum.
    /// Costs roughly half the same-size complex transform — it runs an
    /// `n/2`-point complex FFT plus one fused split pass.
    ///
    /// ```
    /// use tcfft::plan::Plan;
    /// use tcfft::runtime::{PlanarBatch, Runtime};
    ///
    /// let rt = Runtime::load_default().unwrap();
    /// let plan = Plan::rfft1d(&rt.registry, 1024, 2).unwrap();
    /// let x = PlanarBatch::from_real(&[0.0f32; 2 * 1024], vec![2, 1024]);
    /// let spectrum = plan.execute(&rt, x).unwrap();
    /// assert_eq!(spectrum.shape, vec![2, 513]); // bins 0..=n/2
    /// ```
    pub fn rfft1d(registry: &Arc<Registry>, n: usize, batch: usize) -> Result<Plan> {
        Self::rfft1d_algo(registry, n, batch, "tc", Direction::Forward)
    }

    /// Plan a batched C2R inverse real FFT of length `n`: consumes the
    /// Hermitian-packed `[batch, n/2 + 1]` spectrum and produces
    /// `[batch, n]` real rows scaled by `n` (unnormalized, like every
    /// inverse in this crate — divide by `n` to recover the signal).
    pub fn irfft1d(registry: &Arc<Registry>, n: usize, batch: usize) -> Result<Plan> {
        Self::rfft1d_algo(registry, n, batch, "tc", Direction::Inverse)
    }

    /// [`rfft1d`](Self::rfft1d) / [`irfft1d`](Self::irfft1d) with an
    /// explicit leaf algorithm and direction.
    pub fn rfft1d_algo(
        registry: &Arc<Registry>,
        n: usize,
        batch: usize,
        algo: &str,
        direction: Direction,
    ) -> Result<Plan> {
        if !n.is_power_of_two() || n < 4 {
            crate::bail!(TcFftError::BadSize(n));
        }
        let inverse = direction == Direction::Inverse;
        let meta = registry
            .find_rfft1d(n, batch, algo, inverse)
            .ok_or_else(|| {
                TcFftError::NoArtifact(format!("rfft1d n={n} algo={algo} inverse={inverse}"))
            })?
            .clone();
        let plan = Plan {
            // the staged axis is the half-size complex pipeline
            radices_1d: digitrev::radix_schedule(n / 2),
            meta,
            direction,
        };
        plan.validate_against_manifest()?;
        Ok(plan)
    }

    /// Plan a batched R2C forward real 2D FFT of shape `nx` x `ny`
    /// (row-major): consumes `[batch, nx, ny]` real fields (the `re`
    /// plane; `im` is ignored) and produces the Hermitian-packed
    /// `[batch, nx, ny/2 + 1]` half spectrum — row-wise real
    /// transforms into packed rows, then complex column transforms
    /// over the packed bins. Costs roughly half the same-shape complex
    /// 2D transform.
    ///
    /// ```
    /// use tcfft::plan::Plan;
    /// use tcfft::runtime::{PlanarBatch, Runtime};
    ///
    /// let rt = Runtime::load_default().unwrap();
    /// let plan = Plan::rfft2d(&rt.registry, 64, 64, 2).unwrap();
    /// let img = PlanarBatch::from_real(&[0.0f32; 2 * 64 * 64], vec![2, 64, 64]);
    /// let spectrum = plan.execute(&rt, img).unwrap();
    /// assert_eq!(spectrum.shape, vec![2, 64, 33]); // bins 0..=ny/2 per row
    /// ```
    pub fn rfft2d(registry: &Arc<Registry>, nx: usize, ny: usize, batch: usize) -> Result<Plan> {
        Self::rfft2d_algo(registry, nx, ny, batch, "tc", Direction::Forward)
    }

    /// Plan a batched C2R inverse real 2D FFT of shape `nx` x `ny`:
    /// consumes the Hermitian-packed `[batch, nx, ny/2 + 1]` spectrum
    /// and produces `[batch, nx, ny]` real fields scaled by `nx * ny`
    /// (unnormalized, like every inverse in this crate).
    pub fn irfft2d(registry: &Arc<Registry>, nx: usize, ny: usize, batch: usize) -> Result<Plan> {
        Self::rfft2d_algo(registry, nx, ny, batch, "tc", Direction::Inverse)
    }

    /// [`rfft2d`](Self::rfft2d) / [`irfft2d`](Self::irfft2d) with an
    /// explicit leaf algorithm and direction.
    pub fn rfft2d_algo(
        registry: &Arc<Registry>,
        nx: usize,
        ny: usize,
        batch: usize,
        algo: &str,
        direction: Direction,
    ) -> Result<Plan> {
        if !nx.is_power_of_two() || !ny.is_power_of_two() || nx < 2 || ny < 4 {
            crate::bail!(TcFftError::BadSize(nx.max(ny)));
        }
        let inverse = direction == Direction::Inverse;
        let meta = registry
            .find_rfft2d(nx, ny, batch, algo, inverse)
            .ok_or_else(|| {
                TcFftError::NoArtifact(format!("rfft2d {nx}x{ny} algo={algo} inverse={inverse}"))
            })?
            .clone();
        let plan = Plan {
            // the strided axis, as for fft2d (rows run at ny/2)
            radices_1d: digitrev::radix_schedule(nx),
            meta,
            direction,
        };
        plan.validate_against_manifest()?;
        Ok(plan)
    }

    /// Plan a batched 2D FFT (tcfftPlan2D analogue). Row-major (nx, ny).
    pub fn fft2d(registry: &Arc<Registry>, nx: usize, ny: usize, batch: usize) -> Result<Plan> {
        Self::fft2d_algo(registry, nx, ny, batch, "tc", Direction::Forward)
    }

    /// [`fft2d`](Self::fft2d) with an explicit algorithm and direction.
    pub fn fft2d_algo(
        registry: &Arc<Registry>,
        nx: usize,
        ny: usize,
        batch: usize,
        algo: &str,
        direction: Direction,
    ) -> Result<Plan> {
        if !nx.is_power_of_two() || !ny.is_power_of_two() || nx < 2 || ny < 2 {
            crate::bail!(TcFftError::BadSize(nx.max(ny)));
        }
        let inverse = direction == Direction::Inverse;
        let meta = registry
            .find_fft2d(nx, ny, batch, algo, inverse)
            .ok_or_else(|| {
                TcFftError::NoArtifact(format!("fft2d {nx}x{ny} algo={algo} inverse={inverse}"))
            })?
            .clone();
        let plan = Plan {
            radices_1d: digitrev::radix_schedule(nx),
            meta,
            direction,
        };
        plan.validate_against_manifest()?;
        Ok(plan)
    }

    /// Cross-check the Rust schedule against the manifest's stage list:
    /// the product of merged radices per axis must reconstruct the size,
    /// and kernels must be drawn from the known collection.
    fn validate_against_manifest(&self) -> Result<()> {
        if self.meta.algo == "r2" {
            return Ok(()); // baseline artifacts carry a stockham schedule
        }
        let known = [
            "r16_first",
            "fused256_first",
            "r16",
            "merge256",
            "small",
            "r2c_post",
            "c2r_pre",
        ];
        let mut product: usize = 1;
        for st in &self.meta.stages {
            if !known.contains(&st.kernel.as_str()) {
                crate::bail!("manifest stage kernel '{}' unknown to planner", st.kernel);
            }
            product = product.saturating_mul(st.radix);
        }
        // the real ops carry the half-size complex stages plus the
        // radix-2 real stage, so their products also reconstruct the
        // full transform size
        let want = if self.meta.op == "fft2d" || self.meta.op == "rfft2d" {
            self.meta.nx * self.meta.ny
        } else {
            self.meta.n
        };
        if product != want {
            crate::bail!(
                "manifest schedule product {product} != transform size {want} for {}",
                self.meta.key
            );
        }
        Ok(())
    }

    /// Batch capacity of the bound artifact.
    pub fn artifact_batch(&self) -> usize {
        self.meta.batch
    }

    /// Estimated resident bytes of this plan for cache accounting:
    /// metadata strings plus a nominal per-stage descriptor cost. Plans
    /// hold no twiddle tables host-side (those live in the artifact),
    /// so this is small — the estimate exists so `Plan` satisfies the
    /// same byte-budget contract as the large-plan and bank caches.
    pub fn memory_bytes(&self) -> usize {
        let strings = self.meta.key.len()
            + self.meta.file.as_os_str().len()
            + self.meta.op.len()
            + self.meta.algo.len();
        let stages: usize = self
            .meta
            .stages
            .iter()
            .map(|st| st.kernel.len() + 64)
            .sum();
        strings + stages + (self.meta.input_shape.len() + self.radices_1d.len()) * 8 + 256
    }

    /// Execute on a batch; pads/splits to the artifact batch size.
    /// Input shape: [b, n] (1D) or [b, nx, ny] (2D) with any b >= 1.
    pub fn execute(&self, rt: &Runtime, input: PlanarBatch) -> Result<PlanarBatch> {
        let want_tail = &self.meta.input_shape[1..];
        crate::ensure!(
            &input.shape[1..] == want_tail,
            "input tail {:?} != plan tail {:?}",
            &input.shape[1..],
            want_tail
        );
        let cap = self.meta.batch;
        let b = input.shape[0];
        if b == cap {
            // exact fit: no pad, no slice, no concat — the common path
            // when a batched caller (the four-step engine, the service
            // batcher) already groups to artifact capacity
            let (out, _) = rt.execute(&self.meta.key, input)?;
            return Ok(out);
        }
        let mut outs = Vec::new();
        let mut lo = 0;
        while lo < b {
            let hi = (lo + cap).min(b);
            let chunk = input.slice_rows(lo, hi).pad_batch(cap);
            let (out, _) = rt.execute(&self.meta.key, chunk)?;
            outs.push(out.slice_rows(0, hi - lo));
            lo = hi;
        }
        Ok(PlanarBatch::concat(&outs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::registry::Registry;
    use std::path::PathBuf;

    fn mini_registry() -> Arc<Registry> {
        let json = r#"{
          "format": 1, "variants": [
            {"key": "fft1d_tc_n256_b4_fwd", "file": "x.hlo.txt",
             "op": "fft1d", "algo": "tc", "n": 256, "nx": 0, "ny": 0,
             "batch": 4, "inverse": false, "input_shape": [4, 256],
             "stages": [{"kernel": "fused256_first", "radix": 256,
                         "n2": 1, "lane": 1, "flops": 1, "hbm_bytes": 1,
                         "vmem_bytes": 1}],
             "flops_per_seq": 1, "hbm_bytes_per_seq": 1,
             "radix2_equiv_flops": 1}
          ]}"#;
        Arc::new(Registry::from_json_str(json, PathBuf::from("/tmp")).unwrap())
    }

    #[test]
    fn plans_valid_sizes() {
        let r = mini_registry();
        let p = Plan::fft1d(&r, 256, 4).unwrap();
        assert_eq!(p.meta.key, "fft1d_tc_n256_b4_fwd");
        assert_eq!(p.radices_1d, vec![16, 16]);
    }

    #[test]
    fn rejects_bad_sizes() {
        let r = mini_registry();
        assert!(Plan::fft1d(&r, 100, 1).is_err()); // not a power of two
        assert!(Plan::fft1d(&r, 512, 1).is_err()); // no artifact
        assert!(Plan::rfft1d(&r, 96, 1).is_err()); // not a power of two
        assert!(Plan::rfft1d(&r, 2, 1).is_err()); // too small to pack
    }

    #[test]
    fn real_plans_bind_packed_shapes() {
        let r = Arc::new(Registry::synthesize());
        let fwd = Plan::rfft1d(&r, 1024, 4).unwrap();
        assert_eq!(fwd.meta.op, "rfft1d");
        assert_eq!(fwd.meta.input_shape, vec![4, 1024]);
        assert_eq!(fwd.radices_1d, crate::fft::digitrev::radix_schedule(512));
        let inv = Plan::irfft1d(&r, 1024, 4).unwrap();
        assert_eq!(inv.meta.input_shape, vec![4, 513]);
        assert_eq!(inv.direction, Direction::Inverse);
    }

    #[test]
    fn real_2d_plans_bind_packed_shapes() {
        let r = Arc::new(Registry::synthesize());
        let fwd = Plan::rfft2d(&r, 64, 128, 4).unwrap();
        assert_eq!(fwd.meta.op, "rfft2d");
        assert_eq!(fwd.meta.input_shape, vec![4, 64, 128]);
        let inv = Plan::irfft2d(&r, 64, 128, 4).unwrap();
        assert_eq!(inv.meta.input_shape, vec![4, 64, 65]);
        assert_eq!(inv.direction, Direction::Inverse);
        // bad shapes fail fast
        assert!(Plan::rfft2d(&r, 100, 64, 1).is_err()); // not a power of two
        assert!(Plan::rfft2d(&r, 64, 2, 1).is_err()); // rows too small to pack
        assert!(Plan::rfft2d(&r, 512, 512, 1).is_err()); // beyond the ladder
    }

    #[test]
    fn schedule_product_validation_catches_mismatch() {
        let json = r#"{
          "format": 1, "variants": [
            {"key": "fft1d_tc_n256_b4_fwd", "file": "x.hlo.txt",
             "op": "fft1d", "algo": "tc", "n": 256, "nx": 0, "ny": 0,
             "batch": 4, "inverse": false, "input_shape": [4, 256],
             "stages": [{"kernel": "r16", "radix": 16, "n2": 1, "lane": 1,
                         "flops": 1, "hbm_bytes": 1, "vmem_bytes": 1}],
             "flops_per_seq": 1, "hbm_bytes_per_seq": 1,
             "radix2_equiv_flops": 1}
          ]}"#;
        let r = Arc::new(Registry::from_json_str(json, PathBuf::from("/tmp")).unwrap());
        // 16 != 256: planner must refuse the inconsistent manifest
        assert!(Plan::fft1d(&r, 256, 4).is_err());
    }
}
