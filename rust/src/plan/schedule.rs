//! Kernel schedule computation — the Rust mirror of
//! `python/compile/plans.py::kernel_schedule`, used for perf modelling
//! and manifest cross-validation.

/// VMEM budget a fused merge block may occupy (bytes); must match
/// plans.py::VMEM_FUSE_BUDGET.
pub const VMEM_FUSE_BUDGET: usize = 4 * 1024 * 1024;

/// One planned kernel invocation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlannedStage {
    /// kernel identifier (one of the planner's known collection)
    pub kernel: &'static str,
    /// merge radix of this stage (product over stages = transform size)
    pub radix: usize,
    /// span already merged when this stage runs
    pub n2: usize,
    /// contiguous lane width (1 for 1D; the row length for the strided
    /// 2D pass)
    pub lane: usize,
}

impl PlannedStage {
    /// Output span of this invocation (radix * n2).
    pub fn out_len(&self) -> usize {
        self.radix * self.n2
    }

    /// Real FLOPs over one length-`n` sequence (mirror of
    /// plans.py Stage.flops; complex mul = 6, complex add = 2).
    pub fn flops(&self, n: usize) -> f64 {
        let groups = (n / self.out_len()) as f64;
        let n2 = self.n2 as f64;
        let per_block = match self.kernel {
            "r16_first" => (16 * 16 * 6 + 16 * 15 * 2) as f64 * n2,
            "r16" => (16 * 16 * 6 + 16 * 15 * 2) as f64 * n2 + 16.0 * n2 * 6.0,
            "fused256_first" => (2 * 16 * (16 * 16 * 6 + 16 * 15 * 2) + 16 * 16 * 6) as f64,
            "merge256" => {
                let s1 = 16.0 * ((16 * 16 * 6 + 16 * 15 * 2) as f64 * n2 + 16.0 * n2 * 6.0);
                let s2 = (16 * 16 * 6) as f64 * (16.0 * n2)
                    + (16 * 15 * 2) as f64 * (16.0 * n2)
                    + 16.0 * (16.0 * n2) * 6.0;
                s1 + s2
            }
            "small" => {
                let r = self.radix as f64;
                r * n2 * 6.0 + r * r * n2 * 6.0 + r * (r - 1.0) * n2 * 2.0
            }
            "r2c_post" | "c2r_pre" => {
                // one fused pass over the half spectrum: n2/2 + 1 bin
                // pairs, each ~20 f32 ops against the fp16 W table
                (n2 / 2.0 + 1.0) * 20.0
            }
            other => panic!("unknown kernel {other}"),
        };
        groups * per_block
    }

    /// Global-memory traffic over one length-`n` sequence (mirror of
    /// plans.py Stage.hbm_bytes: read + write the sequence once).
    pub fn hbm_bytes(&self, n: usize) -> f64 {
        let bpc = 4.0; // planar complex fp16
        2.0 * n as f64 * bpc
    }

    /// Per-block VMEM bytes (mirror of plans.py Stage.vmem_bytes;
    /// constants follow the perf-pass tile sizes — see EXPERIMENTS.md).
    pub fn vmem_bytes(&self) -> usize {
        let bpc = 4; // planar complex fp16
        const FIRST_STAGE_ROWS: usize = 512;
        const R16_TILE: usize = 2048;
        const SMALL_TILE: usize = 32768;
        match self.kernel {
            "r16_first" => {
                let rows = (FIRST_STAGE_ROWS / self.lane).max(1);
                rows * 16 * self.lane * bpc * 2
            }
            "fused256_first" => {
                let rows = (FIRST_STAGE_ROWS / self.lane).max(1);
                rows * 256 * self.lane * bpc * 2 + 256 * bpc
            }
            "r16" => 16 * (self.n2 * self.lane).min(R16_TILE) * bpc * 3,
            "merge256" => {
                let blk = 256 * self.n2 * self.lane;
                let tw = (16 * self.n2 + 256 * self.n2) * bpc;
                blk * bpc * 2 + tw
            }
            "small" => self.radix * (self.n2 * self.lane).min(SMALL_TILE) * bpc * 3,
            "r2c_post" | "c2r_pre" => {
                // tiled half-spectrum pass: a bin-pair tile of the W
                // table plus in/out staging
                let tile = (self.n2 / 2 + 1).min(SMALL_TILE);
                tile * bpc * 5
            }
            other => panic!("unknown kernel {other}"),
        }
    }
}

/// The fused kernel schedule for one staged axis of length `n`.
pub fn kernel_schedule(n: usize, lane: usize) -> Vec<PlannedStage> {
    let radices = crate::fft::digitrev::radix_schedule(n);
    let a = radices.iter().filter(|&&r| r == 16).count();
    let small: Vec<usize> = radices.iter().copied().filter(|&r| r != 16).collect();
    let mut stages = Vec::new();
    let mut n2 = 1usize;
    let mut i = 0usize;
    if a >= 2 {
        stages.push(PlannedStage { kernel: "fused256_first", radix: 256, n2: 1, lane });
        n2 = 256;
        i = 2;
    } else if a == 1 {
        stages.push(PlannedStage { kernel: "r16_first", radix: 16, n2: 1, lane });
        n2 = 16;
        i = 1;
    }
    while i < a {
        let remaining = a - i;
        let fused = PlannedStage { kernel: "merge256", radix: 256, n2, lane };
        if remaining >= 2 && fused.vmem_bytes() <= VMEM_FUSE_BUDGET {
            stages.push(fused);
            n2 *= 256;
            i += 2;
        } else {
            stages.push(PlannedStage { kernel: "r16", radix: 16, n2, lane });
            n2 *= 16;
            i += 1;
        }
    }
    for r in small {
        stages.push(PlannedStage { kernel: "small", radix: r, n2, lane });
        n2 *= r;
    }
    assert_eq!(n2, n);
    stages
}

/// The paper's performance metric numerator (eq. 4): the FLOPs a
/// radix-2 FFT of the same size would execute, 6*2*log2(N)*N*batch.
/// Single source of truth for the CLI, the perf model and the
/// synthesized registry metadata.
pub fn radix2_equivalent_flops(n: usize, batch: usize) -> f64 {
    6.0 * 2.0 * (n as f64).log2() * n as f64 * batch as f64
}

/// The `tc_split` ablation schedule (mirror of model.py
/// `split_schedule`): no stage fusion, unfused radix-16 merges.
pub fn split_schedule(n: usize, lane: usize) -> Vec<PlannedStage> {
    let radices = crate::fft::digitrev::radix_schedule(n);
    let a = radices.iter().filter(|&&r| r == 16).count();
    let mut stages = Vec::new();
    let mut n2 = 1usize;
    if a >= 1 {
        stages.push(PlannedStage { kernel: "r16_first", radix: 16, n2: 1, lane });
        n2 = 16;
    }
    for _ in 1..a {
        stages.push(PlannedStage { kernel: "r16", radix: 16, n2, lane });
        n2 *= 16;
    }
    for r in radices.iter().copied().filter(|&r| r != 16) {
        stages.push(PlannedStage { kernel: "small", radix: r, n2, lane });
        n2 *= r;
    }
    assert_eq!(n2, n);
    stages
}

/// The real-input (R2C/C2R) schedule for an `n`-point real transform:
/// the fused complex schedule of the half size `m = n/2` plus the
/// half-spectrum pass — `r2c_post` appended for the forward transform,
/// `c2r_pre` prepended for the inverse. The real stage carries radix 2
/// and span `m`, so the stage radices still multiply out to `n`.
pub fn rfft_schedule(n: usize, lane: usize, inverse: bool) -> Vec<PlannedStage> {
    assert!(n.is_power_of_two() && n >= 4, "real FFT size {n} must be a power of two >= 4");
    let m = n / 2;
    let half = kernel_schedule(m, lane);
    let real = PlannedStage {
        kernel: if inverse { "c2r_pre" } else { "r2c_post" },
        radix: 2,
        n2: m,
        lane,
    };
    if inverse {
        let mut out = vec![real];
        out.extend(half);
        out
    } else {
        let mut out = half;
        out.push(real);
        out
    }
}

/// The row pass of the real-input 2D composition: the `ny`-point real
/// schedule over contiguous rows (`lane = 1`) — half-size complex
/// stages plus the half-spectrum pass, exactly [`rfft_schedule`].
/// Every 2D real path (catalog artifacts, the interpreter's
/// `run_real_2d`, `large::Plan2d`) reports its row pass through this
/// one helper, so the composition cannot drift between routes.
pub fn rfft2d_row_stages(ny: usize, inverse: bool) -> Vec<PlannedStage> {
    rfft_schedule(ny, 1, inverse)
}

/// The column pass of the real-input 2D composition: the `nx`-point
/// complex schedule striding over the packed `ny/2 + 1` Hermitian bins
/// (`lane = ny/2 + 1`). Direction-independent at the schedule level —
/// forward and inverse run the same stage shapes, twiddle conjugation
/// is a kernel-table detail.
pub fn rfft2d_col_stages(nx: usize, ny: usize) -> Vec<PlannedStage> {
    assert!(
        nx.is_power_of_two() && nx >= 2,
        "real 2D nx={nx} must be a power of two >= 2"
    );
    kernel_schedule(nx, ny / 2 + 1)
}

/// The real-input 2D schedule for an `nx` x `ny` transform: the
/// row-wise real schedule ([`rfft2d_row_stages`]) composed with the
/// packed-bin column schedule ([`rfft2d_col_stages`]). Forward runs
/// rows then columns; the inverse is the exact mirror (columns, then
/// the `c2r_pre` merge, then the half-size rows). Stage radices
/// multiply out to `nx * ny` either way, so manifest validation keeps
/// working.
pub fn rfft2d_schedule(nx: usize, ny: usize, inverse: bool) -> Vec<PlannedStage> {
    let rows = rfft2d_row_stages(ny, inverse);
    let cols = rfft2d_col_stages(nx, ny);
    if inverse {
        let mut out = cols;
        out.extend(rows);
        out
    } else {
        let mut out = rows;
        out.extend(cols);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kernels(n: usize) -> Vec<&'static str> {
        kernel_schedule(n, 1).iter().map(|s| s.kernel).collect()
    }

    #[test]
    fn canonical_schedules() {
        assert_eq!(kernels(16), vec!["r16_first"]);
        assert_eq!(kernels(32), vec!["r16_first", "small"]);
        assert_eq!(kernels(256), vec!["fused256_first"]);
        assert_eq!(kernels(512), vec!["fused256_first", "small"]);
        assert_eq!(kernels(4096), vec!["fused256_first", "r16"]);
        assert_eq!(kernels(65536), vec!["fused256_first", "merge256"]);
        assert_eq!(kernels(131072), vec!["fused256_first", "merge256", "small"]);
    }

    #[test]
    fn vmem_budget_respected() {
        for t in 1..=24 {
            let n = 1usize << t;
            for st in kernel_schedule(n, 1) {
                assert!(
                    st.vmem_bytes() <= VMEM_FUSE_BUDGET,
                    "n={n} stage {st:?} exceeds VMEM budget"
                );
            }
        }
    }

    #[test]
    fn radix_product_reconstructs_n() {
        for t in 1..=24 {
            let n = 1usize << t;
            let p: usize = kernel_schedule(n, 1).iter().map(|s| s.radix).product();
            assert_eq!(p, n);
        }
    }

    #[test]
    fn split_schedule_is_unfused_and_reconstructs_n() {
        for t in 1..=20 {
            let n = 1usize << t;
            let sts = split_schedule(n, 1);
            let p: usize = sts.iter().map(|s| s.radix).product();
            assert_eq!(p, n);
            assert!(sts.iter().all(|s| s.kernel != "merge256" && s.kernel != "fused256_first"));
        }
    }

    #[test]
    fn flops_positive_and_scale_with_n() {
        let small = kernel_schedule(256, 1).iter().map(|s| s.flops(256)).sum::<f64>();
        let big = kernel_schedule(4096, 1).iter().map(|s| s.flops(4096)).sum::<f64>();
        assert!(small > 0.0);
        assert!(big > small);
        for st in kernel_schedule(1 << 16, 1) {
            assert!(st.hbm_bytes(1 << 16) > 0.0);
        }
    }

    #[test]
    fn rfft_schedule_wraps_the_half_size() {
        for t in 2..=20usize {
            let n = 1usize << t;
            let fwd = rfft_schedule(n, 1, false);
            let inv = rfft_schedule(n, 1, true);
            // the real stage sits last (forward) / first (inverse)
            assert_eq!(fwd.last().unwrap().kernel, "r2c_post");
            assert_eq!(inv.first().unwrap().kernel, "c2r_pre");
            // radices reconstruct n, costs stay positive and bounded
            for sts in [&fwd, &inv] {
                let p: usize = sts.iter().map(|s| s.radix).product();
                assert_eq!(p, n, "n={n}");
                for st in sts.iter() {
                    let real_stage = st.kernel == "r2c_post" || st.kernel == "c2r_pre";
                    let span = if real_stage { n } else { n / 2 };
                    assert!(st.flops(span) > 0.0, "n={n} stage {st:?}");
                    assert!(st.vmem_bytes() <= VMEM_FUSE_BUDGET, "n={n} stage {st:?}");
                }
            }
        }
    }

    #[test]
    fn rfft2d_schedule_orders_rows_and_columns() {
        for (nx, ny) in [(8usize, 8usize), (64, 128), (256, 256)] {
            let fwd = rfft2d_schedule(nx, ny, false);
            let inv = rfft2d_schedule(nx, ny, true);
            // forward: the real stage separates the contiguous row pass
            // from the strided column pass; inverse mirrors it
            let split_at = fwd.iter().position(|s| s.kernel == "r2c_post").unwrap();
            assert!(fwd[..split_at].iter().all(|s| s.lane == 1), "{nx}x{ny}");
            assert!(
                fwd[split_at + 1..].iter().all(|s| s.lane == ny / 2 + 1),
                "{nx}x{ny}"
            );
            let merge_at = inv.iter().position(|s| s.kernel == "c2r_pre").unwrap();
            assert!(inv[..merge_at].iter().all(|s| s.lane == ny / 2 + 1), "{nx}x{ny}");
            // radices reconstruct the full 2D size in both directions
            for sts in [&fwd, &inv] {
                let p: usize = sts.iter().map(|s| s.radix).product();
                assert_eq!(p, nx * ny, "{nx}x{ny}");
            }
        }
    }

    #[test]
    fn rfft2d_schedule_is_exactly_the_shared_pass_helpers() {
        // the composed schedule must be the row/column helpers glued in
        // direction order — no private re-derivation anywhere
        for (nx, ny) in [(64usize, 128usize), (2048, 512)] {
            let rows_f = rfft2d_row_stages(ny, false);
            let rows_i = rfft2d_row_stages(ny, true);
            let cols = rfft2d_col_stages(nx, ny);
            let mut fwd = rows_f.clone();
            fwd.extend(cols.clone());
            assert_eq!(rfft2d_schedule(nx, ny, false), fwd, "{nx}x{ny}");
            let mut inv = cols.clone();
            inv.extend(rows_i.clone());
            assert_eq!(rfft2d_schedule(nx, ny, true), inv, "{nx}x{ny}");
            // rectangular shapes keep the axes distinct: the column
            // pass carries nx stages over the ny-derived lane
            assert_eq!(cols.iter().map(|s| s.radix).product::<usize>(), nx);
            assert!(cols.iter().all(|s| s.lane == ny / 2 + 1));
            assert_eq!(rows_f.iter().map(|s| s.radix).product::<usize>(), ny);
        }
    }

    #[test]
    fn large_lane_disables_fusion() {
        // 2D first-axis pass with lane=512: merge256 blocks would blow
        // VMEM, so the schedule must fall back to unfused r16 merges.
        let sts = kernel_schedule(1 << 16, 512);
        assert!(sts.iter().all(|s| s.kernel != "merge256"));
    }
}
