//! Iterative radix-2 Cooley–Tukey FFT in f64 — the "FFTW double"
//! stand-in used as the reference for the paper's Table 4 relative
//! error metric, and for frequency-domain work in the examples.
//!
//! Validated against the O(N^2) DFT oracle (`refdft`).

use crate::hp::C64;

/// In-place bit reversal permutation.
fn bit_reverse_permute(x: &mut [C64]) {
    let n = x.len();
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i.reverse_bits() >> (usize::BITS - bits)) as usize;
        if j > i {
            x.swap(i, j);
        }
    }
}

/// Radix-2 DIT FFT over a power-of-two length. Inverse is UNNORMALIZED.
pub fn fft(x: &mut [C64], inverse: bool) {
    let n = x.len();
    assert!(n.is_power_of_two(), "radix2 fft needs power-of-two length");
    if n <= 1 {
        return;
    }
    bit_reverse_permute(x);
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let wlen = C64::cis(ang);
        for start in (0..n).step_by(len) {
            let mut w = C64::one();
            for k in 0..len / 2 {
                let a = x[start + k];
                let b = x[start + k + len / 2] * w;
                x[start + k] = a + b;
                x[start + k + len / 2] = a - b;
                w = w * wlen;
            }
        }
        len <<= 1;
    }
}

/// Out-of-place convenience wrapper.
pub fn fft_vec(x: &[C64], inverse: bool) -> Vec<C64> {
    let mut y = x.to_vec();
    fft(&mut y, inverse);
    y
}

/// Normalized inverse FFT (divides by N) for callers that want the
/// mathematical inverse rather than the cuFFT convention.
pub fn ifft_normalized(x: &[C64]) -> Vec<C64> {
    let n = x.len() as f64;
    let mut y = fft_vec(x, true);
    for v in &mut y {
        *v = v.scale(1.0 / n);
    }
    y
}

/// Batched 2D FFT over a row-major (nx, ny) matrix.
pub fn fft2(x: &mut [C64], nx: usize, ny: usize, inverse: bool) {
    assert_eq!(x.len(), nx * ny);
    // contiguous rows
    for r in 0..nx {
        fft(&mut x[r * ny..(r + 1) * ny], inverse);
    }
    // strided columns through a scratch column buffer
    let mut col = vec![C64::zero(); nx];
    for c in 0..ny {
        for r in 0..nx {
            col[r] = x[r * ny + c];
        }
        fft(&mut col, inverse);
        for r in 0..nx {
            x[r * ny + c] = col[r];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::refdft::dft;
    use crate::util::rng::SplitMix64;

    fn rand_signal(n: usize, seed: u64) -> Vec<C64> {
        let mut rng = SplitMix64::new(seed);
        (0..n)
            .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
            .collect()
    }

    #[test]
    fn matches_dft_oracle() {
        for &n in &[2usize, 4, 8, 64, 256, 1024] {
            let x = rand_signal(n, n as u64);
            let want = dft(&x, false);
            let got = fft_vec(&x, false);
            let scale = want.iter().map(|c| c.abs()).fold(0.0, f64::max);
            for (w, g) in want.iter().zip(&got) {
                assert!((*w - *g).abs() / scale < 1e-10, "n={n}");
            }
        }
    }

    #[test]
    fn inverse_matches_dft_oracle() {
        let x = rand_signal(128, 7);
        let want = dft(&x, true);
        let got = fft_vec(&x, true);
        for (w, g) in want.iter().zip(&got) {
            assert!((*w - *g).abs() < 1e-9);
        }
    }

    #[test]
    fn round_trip_normalized() {
        let x = rand_signal(512, 3);
        let y = fft_vec(&x, false);
        let z = ifft_normalized(&y);
        for (a, b) in x.iter().zip(&z) {
            assert!((*a - *b).abs() < 1e-12);
        }
    }

    #[test]
    fn parseval_energy_preserved() {
        let x = rand_signal(256, 11);
        let y = fft_vec(&x, false);
        let ex: f64 = x.iter().map(|c| c.norm_sqr()).sum();
        let ey: f64 = y.iter().map(|c| c.norm_sqr()).sum();
        assert!((ey - 256.0 * ex).abs() / (256.0 * ex) < 1e-12);
    }

    #[test]
    fn fft2_matches_row_column_dft() {
        let nx = 8;
        let ny = 16;
        let mut x = rand_signal(nx * ny, 5);
        let orig = x.clone();
        fft2(&mut x, nx, ny, false);
        // oracle: dft rows then dft cols
        let mut want = orig;
        for r in 0..nx {
            let row = dft(&want[r * ny..(r + 1) * ny].to_vec(), false);
            want[r * ny..(r + 1) * ny].copy_from_slice(&row);
        }
        for c in 0..ny {
            let col: Vec<C64> = (0..nx).map(|r| want[r * ny + c]).collect();
            let f = dft(&col, false);
            for r in 0..nx {
                want[r * ny + c] = f[r];
            }
        }
        for (w, g) in want.iter().zip(&x) {
            assert!((*w - *g).abs() < 1e-8);
        }
    }
}
