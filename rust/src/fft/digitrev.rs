//! Mixed-radix digit-reversal permutations — the Rust mirror of
//! `python/compile/plans.py::digit_reverse_indices`.  The planner
//! cross-checks this against the manifest so both sides of the AOT
//! boundary agree on data layout.

/// The paper's radix schedule for N = 2^t: t = 4a + b -> [16]*a + [2^b],
/// small radix merging last (largest span), like the radix-512 kernel.
pub fn radix_schedule(n: usize) -> Vec<usize> {
    assert!(n.is_power_of_two() && n >= 2, "size must be a power of two >= 2");
    let t = n.trailing_zeros() as usize;
    let (a, b) = (t / 4, t % 4);
    let mut r = vec![16; a];
    if b > 0 {
        r.push(1 << b);
    }
    r
}

/// Digit-reversal permutation for a merge-ordered radix list: the
/// outermost decimation split corresponds to the LAST merge radix.
/// Returns `perm` such that `x[perm[i]]` is the staged pipeline's input.
pub fn digit_reverse_indices(n: usize, radices: &[usize]) -> Vec<usize> {
    assert_eq!(radices.iter().product::<usize>(), n);
    fn rec(idx: Vec<usize>, rads: &[usize]) -> Vec<usize> {
        match rads.split_last() {
            None => idx,
            Some((&r, rest)) => {
                let mut out = Vec::with_capacity(idx.len());
                for m in 0..r {
                    let sub: Vec<usize> = idx.iter().copied().skip(m).step_by(r).collect();
                    out.extend(rec(sub, rest));
                }
                out
            }
        }
    }
    rec((0..n).collect(), radices)
}

/// Convenience: permutation for the default schedule of `n`.
pub fn digit_reverse(n: usize) -> Vec<usize> {
    digit_reverse_indices(n, &radix_schedule(n))
}

/// Apply a permutation out of place: out[i] = x[perm[i]].
pub fn apply_permutation<T: Copy>(x: &[T], perm: &[usize]) -> Vec<T> {
    perm.iter().map(|&p| x[p]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_shapes() {
        assert_eq!(radix_schedule(16), vec![16]);
        assert_eq!(radix_schedule(32), vec![16, 2]);
        assert_eq!(radix_schedule(256), vec![16, 16]);
        assert_eq!(radix_schedule(512), vec![16, 16, 2]); // paper's radix-512
        assert_eq!(radix_schedule(4096), vec![16, 16, 16]);
        assert_eq!(radix_schedule(131072), vec![16, 16, 16, 16, 2]);
        assert_eq!(radix_schedule(2), vec![2]);
        assert_eq!(radix_schedule(8), vec![8]);
    }

    #[test]
    fn radix2_is_bit_reversal() {
        // [2,2,2] over 8 elements = classic bit reversal
        let p = digit_reverse_indices(8, &[2, 2, 2]);
        assert_eq!(p, vec![0, 4, 2, 6, 1, 5, 3, 7]);
    }

    #[test]
    fn is_a_permutation() {
        for &n in &[16usize, 32, 256, 512, 4096, 65536] {
            let p = digit_reverse(n);
            let mut seen = vec![false; n];
            for &i in &p {
                assert!(!seen[i], "duplicate index {i} for n={n}");
                seen[i] = true;
            }
        }
    }

    #[test]
    fn involution_for_symmetric_radices() {
        // for uniform radix lists, digit reversal is an involution
        let p = digit_reverse_indices(256, &[16, 16]);
        for i in 0..256 {
            assert_eq!(p[p[i]], i);
        }
    }

    #[test]
    fn matches_python_plans_small_case() {
        // n=32, radices [16, 2]: outer split by 2 (last merge), then 16.
        // evens digit-reversed over [16] (identity), then odds.
        let p = digit_reverse_indices(32, &[16, 2]);
        let want: Vec<usize> = (0..32).step_by(2).chain((1..32).step_by(2)).collect();
        assert_eq!(p, want);
    }
}
