//! Twiddle factor generation — W_N^{mk} tables matching
//! `python/compile/plans.py::twiddle_matrix` exactly (angle reduced
//! mod N before the trig call, f64 precision).

use crate::hp::C64;

/// The r x n2 twiddle matrix T[m][k] = W_{r*n2}^{m*k}.
pub fn twiddle_matrix(r: usize, n2: usize, inverse: bool) -> Vec<Vec<C64>> {
    let n = r * n2;
    let sign = if inverse { 2.0 } else { -2.0 };
    (0..r)
        .map(|m| {
            (0..n2)
                .map(|k| {
                    let e = ((m * k) % n) as f64;
                    C64::cis(sign * std::f64::consts::PI * e / n as f64)
                })
                .collect()
        })
        .collect()
}

/// The r-point DFT matrix F[m][j] = W_r^{m*j}.
pub fn dft_matrix(r: usize, inverse: bool) -> Vec<Vec<C64>> {
    let sign = if inverse { 2.0 } else { -2.0 };
    (0..r)
        .map(|m| {
            (0..r)
                .map(|j| {
                    let e = ((m * j) % r) as f64;
                    C64::cis(sign * std::f64::consts::PI * e / r as f64)
                })
                .collect()
        })
        .collect()
}

/// Four-step twiddles: W_N^{jk} for the N = n1*n2 decomposition,
/// indexed [j][k] with j < n1, k < n2.
pub fn four_step_twiddles(n1: usize, n2: usize, inverse: bool) -> Vec<Vec<C64>> {
    let n = n1 * n2;
    let sign = if inverse { 2.0 } else { -2.0 };
    (0..n1)
        .map(|j| {
            (0..n2)
                .map(|k| {
                    let e = ((j * k) % n) as f64;
                    C64::cis(sign * std::f64::consts::PI * e / n as f64)
                })
                .collect()
        })
        .collect()
}

/// Flattened planar four-step twiddles for the batched large-FFT
/// engine: `(re, im)` with `re[j*n2 + k] = Re W_N^{jk}` (row-major
/// `[n1][n2]`, the layout of the engine's twiddled transpose). Angles
/// are reduced mod N and evaluated in f64 like every other table here,
/// then stored as f32 — the next device call quantizes the product to
/// fp16, so the f32 store costs nothing observable.
pub fn four_step_twiddles_flat(n1: usize, n2: usize, inverse: bool) -> (Vec<f32>, Vec<f32>) {
    let n = n1 * n2;
    let sign = if inverse { 2.0 } else { -2.0 };
    let mut re = vec![0f32; n];
    let mut im = vec![0f32; n];
    for j in 0..n1 {
        for k in 0..n2 {
            let e = ((j * k) % n) as f64;
            let ang = sign * std::f64::consts::PI * e / n as f64;
            re[j * n2 + k] = ang.cos() as f32;
            im[j * n2 + k] = ang.sin() as f32;
        }
    }
    (re, im)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_magnitude_everywhere() {
        for row in twiddle_matrix(16, 32, false) {
            for w in row {
                assert!((w.abs() - 1.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn first_row_and_column_are_one() {
        let t = twiddle_matrix(16, 8, false);
        for k in 0..8 {
            assert!((t[0][k] - C64::one()).abs() < 1e-12);
        }
        for row in &t {
            assert!((row[0] - C64::one()).abs() < 1e-12);
        }
    }

    #[test]
    fn dft2_matrix() {
        let f = dft_matrix(2, false);
        assert!((f[1][1] - C64::new(-1.0, 0.0)).abs() < 1e-12);
    }

    #[test]
    fn inverse_is_conjugate() {
        let f = twiddle_matrix(16, 16, false);
        let fi = twiddle_matrix(16, 16, true);
        for (rf, ri) in f.iter().zip(&fi) {
            for (a, b) in rf.iter().zip(ri) {
                assert!((a.conj() - *b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn flat_four_step_twiddles_match_the_matrix_form() {
        for inverse in [false, true] {
            let m = four_step_twiddles(16, 8, inverse);
            let (re, im) = four_step_twiddles_flat(16, 8, inverse);
            for j in 0..16 {
                for k in 0..8 {
                    assert_eq!(re[j * 8 + k], m[j][k].re as f32, "re ({j},{k})");
                    assert_eq!(im[j * 8 + k], m[j][k].im as f32, "im ({j},{k})");
                }
            }
        }
    }

    #[test]
    fn dft_matrix_unitary_up_to_scale() {
        // F * conj(F)^T = N * I for the DFT matrix
        let r = 16;
        let f = dft_matrix(r, false);
        for i in 0..r {
            for j in 0..r {
                let mut acc = C64::zero();
                for k in 0..r {
                    acc += f[i][k] * f[j][k].conj();
                }
                let want = if i == j { r as f64 } else { 0.0 };
                assert!((acc - C64::new(want, 0.0)).abs() < 1e-9, "({i},{j})");
            }
        }
    }
}
