//! Twiddle factor generation — W_N^{mk} tables matching
//! `python/compile/plans.py::twiddle_matrix` exactly (angle reduced
//! mod N before the trig call, f64 precision).

use crate::hp::C64;

/// The r x n2 twiddle matrix T[m][k] = W_{r*n2}^{m*k}.
pub fn twiddle_matrix(r: usize, n2: usize, inverse: bool) -> Vec<Vec<C64>> {
    let n = r * n2;
    let sign = if inverse { 2.0 } else { -2.0 };
    (0..r)
        .map(|m| {
            (0..n2)
                .map(|k| {
                    let e = ((m * k) % n) as f64;
                    C64::cis(sign * std::f64::consts::PI * e / n as f64)
                })
                .collect()
        })
        .collect()
}

/// The r-point DFT matrix F[m][j] = W_r^{m*j}.
pub fn dft_matrix(r: usize, inverse: bool) -> Vec<Vec<C64>> {
    let sign = if inverse { 2.0 } else { -2.0 };
    (0..r)
        .map(|m| {
            (0..r)
                .map(|j| {
                    let e = ((m * j) % r) as f64;
                    C64::cis(sign * std::f64::consts::PI * e / r as f64)
                })
                .collect()
        })
        .collect()
}

/// Four-step twiddles: W_N^{jk} for the N = n1*n2 decomposition,
/// indexed [j][k] with j < n1, k < n2.
pub fn four_step_twiddles(n1: usize, n2: usize, inverse: bool) -> Vec<Vec<C64>> {
    let n = n1 * n2;
    let sign = if inverse { 2.0 } else { -2.0 };
    (0..n1)
        .map(|j| {
            (0..n2)
                .map(|k| {
                    let e = ((j * k) % n) as f64;
                    C64::cis(sign * std::f64::consts::PI * e / n as f64)
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_magnitude_everywhere() {
        for row in twiddle_matrix(16, 32, false) {
            for w in row {
                assert!((w.abs() - 1.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn first_row_and_column_are_one() {
        let t = twiddle_matrix(16, 8, false);
        for k in 0..8 {
            assert!((t[0][k] - C64::one()).abs() < 1e-12);
        }
        for row in &t {
            assert!((row[0] - C64::one()).abs() < 1e-12);
        }
    }

    #[test]
    fn dft2_matrix() {
        let f = dft_matrix(2, false);
        assert!((f[1][1] - C64::new(-1.0, 0.0)).abs() < 1e-12);
    }

    #[test]
    fn inverse_is_conjugate() {
        let f = twiddle_matrix(16, 16, false);
        let fi = twiddle_matrix(16, 16, true);
        for (rf, ri) in f.iter().zip(&fi) {
            for (a, b) in rf.iter().zip(ri) {
                assert!((a.conj() - *b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn dft_matrix_unitary_up_to_scale() {
        // F * conj(F)^T = N * I for the DFT matrix
        let r = 16;
        let f = dft_matrix(r, false);
        for i in 0..r {
            for j in 0..r {
                let mut acc = C64::zero();
                for k in 0..r {
                    acc += f[i][k] * f[j][k].conj();
                }
                let want = if i == j { r as f64 } else { 0.0 };
                assert!((acc - C64::new(want, 0.0)).abs() < 1e-9, "({i},{j})");
            }
        }
    }
}
