//! Host-side FFT substrates: oracles and a mirror of the artifact
//! algorithm.
//!
//! The paper's precision metric (Table 4) compares against FFTW in
//! double precision; offline we build the equivalent from scratch:
//! a recursive f64 FFT validated against the O(N^2) DFT definition.

pub mod digitrev;
pub mod mixed;
pub mod radix2;
pub mod refdft;
pub mod twiddle;
