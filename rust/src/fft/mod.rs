//! Host-side FFT substrates: oracles and a mirror of the artifact
//! algorithm.
//!
//! The paper's precision metric (Table 4) compares against FFTW in
//! double precision; offline we build the equivalent from scratch:
//! a recursive f64 FFT validated against the O(N^2) DFT definition.

pub mod digitrev;
pub mod mixed;
pub mod radix2;
pub mod refdft;
pub mod twiddle;

use crate::hp::C64;

/// f64 2D DFT oracle over one row-major `[nx][ny]` field: transform
/// the rows, then the columns. Each axis goes through the same rule
/// the 1D conformance oracles use — the O(N^2) DFT definition
/// ([`refdft`]) for short axes, the validated radix-2 FFT beyond that
/// — so every 2D verifier (conformance suite, benches, CLI) shares
/// one definition instead of re-deriving it.
pub fn oracle2d(q: &[C64], nx: usize, ny: usize, inverse: bool) -> Vec<C64> {
    let axis = |v: &[C64]| -> Vec<C64> {
        if v.len() <= 64 {
            refdft::dft(v, inverse)
        } else {
            radix2::fft_vec(v, inverse)
        }
    };
    assert_eq!(q.len(), nx * ny, "oracle2d: field/shape mismatch");
    let mut rows: Vec<C64> = Vec::with_capacity(nx * ny);
    for r in 0..nx {
        rows.extend(axis(&q[r * ny..(r + 1) * ny]));
    }
    let mut out = rows.clone();
    for c in 0..ny {
        let col: Vec<C64> = (0..nx).map(|r| rows[r * ny + c]).collect();
        for (r, v) in axis(&col).into_iter().enumerate() {
            out[r * ny + c] = v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle2d_matches_the_separable_definition() {
        // a 2D DFT of a rank-1 field f[r][c] = a[r]*b[c] is the outer
        // product of the two 1D spectra
        let (nx, ny) = (4usize, 8usize);
        let a: Vec<C64> = (0..nx).map(|r| C64::new(r as f64 * 0.3 - 0.5, 0.2)).collect();
        let b: Vec<C64> = (0..ny).map(|c| C64::new(0.1 * c as f64, -0.4)).collect();
        let field: Vec<C64> = (0..nx * ny).map(|i| a[i / ny] * b[i % ny]).collect();
        let got = oracle2d(&field, nx, ny, false);
        let fa = refdft::dft(&a, false);
        let fb = refdft::dft(&b, false);
        for r in 0..nx {
            for c in 0..ny {
                let want = fa[r] * fb[c];
                assert!((got[r * ny + c] - want).abs() < 1e-9, "bin ({r},{c})");
            }
        }
    }
}
