//! O(N^2) DFT straight from the definition — the ground-truth oracle
//! every other FFT in this repo is validated against.

use crate::hp::C64;

/// X[k] = sum_n x[n] e^{-2 pi i n k / N} (forward), conjugated for inverse.
/// Inverse is UNNORMALIZED (cuFFT convention used across this repo).
pub fn dft(x: &[C64], inverse: bool) -> Vec<C64> {
    let n = x.len();
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut out = vec![C64::zero(); n];
    for (k, o) in out.iter_mut().enumerate() {
        let mut acc = C64::zero();
        for (j, &xv) in x.iter().enumerate() {
            // reduce j*k mod n first: keeps the angle in [0, 2pi) and the
            // oracle accurate even for large N
            let e = ((j as u64 * k as u64) % n as u64) as f64;
            let w = C64::cis(sign * 2.0 * std::f64::consts::PI * e / n as f64);
            acc += xv * w;
        }
        *o = acc;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn impulse_is_flat() {
        let mut x = vec![C64::zero(); 8];
        x[0] = C64::one();
        for v in dft(&x, false) {
            assert!((v - C64::one()).abs() < 1e-12);
        }
    }

    #[test]
    fn constant_is_impulse() {
        let x = vec![C64::one(); 8];
        let y = dft(&x, false);
        assert!((y[0] - C64::new(8.0, 0.0)).abs() < 1e-12);
        for v in &y[1..] {
            assert!(v.abs() < 1e-12);
        }
    }

    #[test]
    fn single_tone() {
        // x[n] = e^{2 pi i 3 n / 16} -> X[3] = 16
        let n = 16;
        let x: Vec<C64> = (0..n)
            .map(|j| C64::cis(2.0 * std::f64::consts::PI * 3.0 * j as f64 / n as f64))
            .collect();
        let y = dft(&x, false);
        assert!((y[3] - C64::new(n as f64, 0.0)).abs() < 1e-9);
        for (k, v) in y.iter().enumerate() {
            if k != 3 {
                assert!(v.abs() < 1e-9, "bin {k} = {v:?}");
            }
        }
    }

    #[test]
    fn forward_inverse_round_trip() {
        let x: Vec<C64> = (0..12)
            .map(|j| C64::new((j as f64).sin(), (j as f64 * 0.7).cos()))
            .collect();
        let y = dft(&x, false);
        let z = dft(&y, true); // unnormalized: z = N * x
        for (a, b) in x.iter().zip(&z) {
            assert!((*a * C64::new(12.0, 0.0) - *b).abs() < 1e-9);
        }
    }

    #[test]
    fn linearity() {
        let a: Vec<C64> = (0..10).map(|j| C64::new(j as f64, 1.0)).collect();
        let b: Vec<C64> = (0..10).map(|j| C64::new(1.0, -(j as f64))).collect();
        let sum: Vec<C64> = a.iter().zip(&b).map(|(&x, &y)| x + y).collect();
        let fa = dft(&a, false);
        let fb = dft(&b, false);
        let fs = dft(&sum, false);
        for ((x, y), s) in fa.iter().zip(&fb).zip(&fs) {
            assert!((*x + *y - *s).abs() < 1e-9);
        }
    }
}
