//! Host mixed-radix FFT mirroring the artifact algorithm exactly:
//! digit-reverse permutation + staged merges `X_out = F_r (T (.) X_in)`
//! in f64.  Used to validate the planner's schedule independently of
//! the JAX pipeline, and as the reference in plan-equivalence tests.

use super::digitrev::{apply_permutation, digit_reverse_indices, radix_schedule};
use super::twiddle::{dft_matrix, twiddle_matrix};
use crate::hp::C64;

/// One merge stage: view the array as (groups, r, n2) blocks and apply
/// X_out = F_r . (T (.) X_in) to each block.
pub fn merge_stage(x: &[C64], r: usize, n2: usize, inverse: bool) -> Vec<C64> {
    let n = x.len();
    let block = r * n2;
    assert_eq!(n % block, 0, "array not divisible into (r, n2) blocks");
    let f = dft_matrix(r, inverse);
    let t = twiddle_matrix(r, n2, inverse);
    let mut out = vec![C64::zero(); n];
    for g in 0..n / block {
        let base = g * block;
        for m in 0..r {
            for k in 0..n2 {
                let mut acc = C64::zero();
                for j in 0..r {
                    acc += f[m][j] * t[j][k] * x[base + j * n2 + k];
                }
                out[base + m * n2 + k] = acc;
            }
        }
    }
    out
}

/// Full mixed-radix FFT with the paper's schedule. Inverse UNNORMALIZED.
pub fn fft_mixed(x: &[C64], inverse: bool) -> Vec<C64> {
    let n = x.len();
    let radices = radix_schedule(n);
    let perm = digit_reverse_indices(n, &radices);
    let mut y = apply_permutation(x, &perm);
    let mut n2 = 1;
    for &r in &radices {
        y = merge_stage(&y, r, n2, inverse);
        n2 *= r;
    }
    y
}

/// Batched variant over rows of a (batch, n) matrix.
pub fn fft_mixed_batch(x: &[C64], batch: usize, n: usize, inverse: bool) -> Vec<C64> {
    assert_eq!(x.len(), batch * n);
    let mut out = Vec::with_capacity(x.len());
    for b in 0..batch {
        out.extend(fft_mixed(&x[b * n..(b + 1) * n], inverse));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::radix2;
    use crate::fft::refdft::dft;
    use crate::util::rng::SplitMix64;

    fn rand_signal(n: usize, seed: u64) -> Vec<C64> {
        let mut rng = SplitMix64::new(seed);
        (0..n)
            .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
            .collect()
    }

    #[test]
    fn matches_dft_all_pow2_to_4096() {
        let mut n = 2;
        while n <= 4096 {
            let x = rand_signal(n, n as u64 + 1);
            let want = dft(&x, false);
            let got = fft_mixed(&x, false);
            let scale = want.iter().map(|c| c.abs()).fold(0.0, f64::max);
            for (w, g) in want.iter().zip(&got) {
                assert!((*w - *g).abs() / scale < 1e-9, "n={n}");
            }
            n *= 2;
        }
    }

    #[test]
    fn matches_radix2_large() {
        let n = 65536;
        let x = rand_signal(n, 42);
        let want = radix2::fft_vec(&x, false);
        let got = fft_mixed(&x, false);
        let scale = want.iter().map(|c| c.abs()).fold(0.0, f64::max);
        for (w, g) in want.iter().zip(&got) {
            assert!((*w - *g).abs() / scale < 1e-8);
        }
    }

    #[test]
    fn inverse_round_trip() {
        let n = 512; // exercises the paper's radix-512 = [16,16,2] path
        let x = rand_signal(n, 9);
        let y = fft_mixed(&x, false);
        let z = fft_mixed(&y, true);
        for (a, b) in x.iter().zip(&z) {
            assert!((a.scale(n as f64) - *b).abs() < 1e-7);
        }
    }

    #[test]
    fn single_merge_stage_equals_block_dft_when_n2_is_1() {
        // with n2 = 1, a merge is just independent r-point DFTs
        let x = rand_signal(64, 3);
        let y = merge_stage(&x, 16, 1, false);
        for g in 0..4 {
            let block: Vec<C64> = x[g * 16..(g + 1) * 16].to_vec();
            let want = dft(&block, false);
            for (w, gv) in want.iter().zip(&y[g * 16..(g + 1) * 16]) {
                assert!((*w - *gv).abs() < 1e-10);
            }
        }
    }
}
