//! Table 2 regeneration: achievable global memory bandwidth vs
//! continuous size (paper Sec 4.2), printed alongside the paper's
//! measured V100 numbers.

use super::{calibrate, MemModel, PAPER_TABLE2};
use crate::util::table::Table;

pub struct Table2Row {
    pub cont_elems: usize,
    pub cont_bytes: usize,
    pub model_gbps: f64,
    pub paper_gbps: f64,
    pub blocks: usize,
    pub paper_blocks: usize,
}

pub fn compute() -> (MemModel, Vec<Table2Row>) {
    let (model, _) = calibrate(MemModel::v100());
    let rows = PAPER_TABLE2
        .iter()
        .map(|&(c, gbps, blks)| Table2Row {
            cont_elems: c,
            cont_bytes: 4 * c,
            model_gbps: model.achievable_bw(c) / 1e9,
            paper_gbps: gbps,
            blocks: model.blocks_per_sm(c),
            paper_blocks: blks,
        })
        .collect();
    (model, rows)
}

pub fn render() -> String {
    let (model, rows) = compute();
    let mut t = Table::new(&[
        "Cont. Size",
        "Cont. Bytes",
        "model GB/s",
        "paper GB/s",
        "dev %",
        "BLKs",
        "paper BLKs",
    ]);
    for r in &rows {
        let dev = 100.0 * (r.model_gbps - r.paper_gbps) / r.paper_gbps;
        t.row(vec![
            r.cont_elems.to_string(),
            r.cont_bytes.to_string(),
            format!("{:.2}", r.model_gbps),
            format!("{:.2}", r.paper_gbps),
            format!("{dev:+.1}"),
            r.blocks.to_string(),
            r.paper_blocks.to_string(),
        ]);
    }
    format!(
        "Table 2: achievable GB/s vs continuous size (V100, radix-256 merge)\n\
         calibrated: request_rate={:.1}G/s line_oh={}B single_block_derate={}\n{}",
        model.request_rate / 1e9,
        model.line_oh,
        model.single_block_derate,
        t.render()
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn renders_all_rows() {
        let s = super::render();
        // every paper reference value appears in the rendered table
        for v in ["208.09", "384.58", "553.48", "836.25", "715.83"] {
            assert!(s.contains(v), "missing paper value {v} in:\n{s}");
        }
    }
}
