//! GPU global-memory coalescing simulator (paper Sec 4.2, Table 2).
//!
//! CPU interpret-mode execution cannot exhibit GPU coalescing, so the
//! continuous-size trade-off is reproduced with a transaction-level
//! model of a V100-class memory system.  Mechanisms:
//!
//! * **Occupancy**: a radix-256 merging kernel with continuous size C
//!   stages ~C KiB of shared memory per block; concurrent blocks/SM =
//!   min(HW cap, smem/SM / smem(C)).  This reproduces the paper's BLKs
//!   column *exactly*.
//! * **Partial-line overhead**: a C-element chunk (4 bytes/element,
//!   half2) that does not fill a 128-byte cache line drags `line_oh`
//!   extra bytes of fetch per chunk (sector prefetch waste); full-line
//!   chunks stream at the peak derate.
//! * **Request rate**: each chunk is one LSU/L2 request; the chip
//!   sustains a bounded request rate, which caps small-C bandwidth.
//! * **Single-block occupancy**: at 1 block/SM the block-wide barriers
//!   of the merge kernel cannot be hidden by a partner block (paper's
//!   explanation for the C=64 drop) — a flat derate applies.
//!
//! Physical constants are calibrated once against the paper's five
//! measured rows (`calibrate`), then *frozen*; tests assert the fitted
//! model stays within tolerance of every row and that the optimum sits
//! at C=32 with a drop at C=64.

pub mod table2;

/// Memory-system parameters (V100 defaults before calibration).
#[derive(Clone, Debug)]
pub struct MemModel {
    /// peak DRAM bandwidth (bytes/s)
    pub peak_bw: f64,
    /// achievable fraction of peak under perfect streaming
    pub peak_derate: f64,
    /// cache line size in bytes
    pub line_bytes: f64,
    /// extra bytes fetched per partial-line chunk (sector waste)
    pub line_oh: f64,
    /// sustained chunk-request rate (requests/s, whole chip)
    pub request_rate: f64,
    /// extra derate when only one block fits an SM (no overlap partner)
    pub single_block_derate: f64,
    /// shared memory per SM (bytes)
    pub smem_per_sm: f64,
    /// shared memory per block per continuous element (bytes)
    pub smem_per_elem: f64,
    /// hardware cap on concurrent blocks per SM
    pub max_blocks: usize,
}

impl MemModel {
    pub fn v100() -> MemModel {
        MemModel {
            peak_bw: 900e9,
            peak_derate: 0.93,
            line_bytes: 128.0,
            line_oh: 32.0,
            request_rate: 12.5e9,
            single_block_derate: 0.855,
            smem_per_sm: 96.0 * 1024.0,
            smem_per_elem: 1024.0,
            max_blocks: 8,
        }
    }

    pub fn a100() -> MemModel {
        MemModel {
            peak_bw: 1555e9,
            peak_derate: 0.92,
            smem_per_sm: 164.0 * 1024.0,
            request_rate: 12.5e9 * 1555.0 / 900.0,
            ..MemModel::v100()
        }
    }

    /// Concurrent blocks per SM for continuous size `c` (elements).
    pub fn blocks_per_sm(&self, c: usize) -> usize {
        let per_block = self.smem_per_elem * c as f64;
        ((self.smem_per_sm / per_block) as usize).clamp(1, self.max_blocks)
    }

    /// Useful fraction of DRAM traffic for a C-element chunk: full
    /// lines stream clean; partial lines drag `line_oh` wasted bytes.
    pub fn fetch_utilization(&self, c: usize) -> f64 {
        let chunk = 4.0 * c as f64; // half2 = 4 bytes
        if chunk >= self.line_bytes {
            1.0
        } else {
            chunk / (chunk + self.line_oh)
        }
    }

    /// Achievable useful bandwidth (bytes/s) at continuous size `c`.
    pub fn achievable_bw(&self, c: usize) -> f64 {
        let chunk = 4.0 * c as f64;
        // cap 1: streaming with partial-line fetch waste
        let stream = self.peak_bw * self.peak_derate * self.fetch_utilization(c);
        // cap 2: request issue rate x useful chunk bytes
        let req = self.request_rate * chunk;
        // derate 3: single-block occupancy (barriers cannot be hidden)
        let occ = if self.blocks_per_sm(c) == 1 {
            self.single_block_derate
        } else {
            1.0
        };
        stream.min(req) * occ
    }
}

/// Paper Table 2 (V100, radix-256 merge): (continuous elems, GB/s, blocks).
pub const PAPER_TABLE2: [(usize, f64, usize); 5] = [
    (4, 208.09, 8),
    (8, 384.58, 8),
    (16, 553.48, 6),
    (32, 836.25, 3),
    (64, 715.83, 1),
];

/// Calibrate (request_rate, line_oh, single_block_derate) by grid
/// search against the paper's measured rows; returns the fitted model
/// and the max relative row error.
pub fn calibrate(base: MemModel) -> (MemModel, f64) {
    let mut best = base.clone();
    let mut best_err = f64::INFINITY;
    for rr_g in 20..=32 {
        let rr = rr_g as f64 * 0.5e9; // 10G .. 16G requests/s
        for oh8 in 2..=6 {
            let oh = oh8 as f64 * 8.0; // 16 .. 48 bytes
            for sbd_pct in [80usize, 82, 85, 86, 88, 90, 92] {
                let m = MemModel {
                    request_rate: rr,
                    line_oh: oh,
                    single_block_derate: sbd_pct as f64 / 100.0,
                    ..base.clone()
                };
                let err = PAPER_TABLE2
                    .iter()
                    .map(|&(c, gbps, _)| {
                        let got = m.achievable_bw(c) / 1e9;
                        ((got - gbps) / gbps).abs()
                    })
                    .fold(0.0, f64::max);
                if err < best_err {
                    best_err = err;
                    best = m;
                }
            }
        }
    }
    (best, best_err)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_column_matches_paper_exactly() {
        let m = MemModel::v100();
        for &(c, _, blks) in &PAPER_TABLE2 {
            assert_eq!(m.blocks_per_sm(c), blks, "C={c}");
        }
    }

    #[test]
    fn fetch_utilization_shape() {
        let m = MemModel::v100();
        // partial lines waste fetch bytes; full lines (>=128B) are clean
        assert!(m.fetch_utilization(4) < m.fetch_utilization(8));
        assert!(m.fetch_utilization(8) < m.fetch_utilization(16));
        assert!((m.fetch_utilization(32) - 1.0).abs() < 1e-12);
        assert!((m.fetch_utilization(64) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn calibrated_model_fits_table2() {
        let (m, err) = calibrate(MemModel::v100());
        assert!(
            err < 0.20,
            "calibrated model deviates {:.1}% (> 20%) from Table 2; model {m:?}",
            err * 100.0
        );
    }

    #[test]
    fn optimum_is_c32_with_c64_drop() {
        let (m, _) = calibrate(MemModel::v100());
        let bw: Vec<f64> = [4usize, 8, 16, 32, 64]
            .iter()
            .map(|&c| m.achievable_bw(c))
            .collect();
        // monotone rise up to C=32 ...
        assert!(bw[0] < bw[1] && bw[1] < bw[2] && bw[2] < bw[3]);
        // ... then the single-block occupancy drop at C=64 (paper Sec 4.2)
        assert!(bw[4] < bw[3]);
    }

    #[test]
    fn a100_scales_up() {
        let v = MemModel::v100();
        let a = MemModel::a100();
        assert!(a.achievable_bw(32) > v.achievable_bw(32));
    }
}
