//! Per-client admission control: a token-bucket quota keyed by client
//! (connection) id, layered *in front of* the queue-level `QueueFull`
//! backpressure.
//!
//! Backpressure protects the engine from aggregate overload but is
//! blind to fairness — one greedy connection can occupy every queue
//! slot and starve the rest. The token bucket bounds each client's
//! sustained rate (`rate` tokens/sec) and burst (`burst` tokens)
//! before a request is even routed, so a quota rejection is cheap and
//! never consumes queue capacity.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::lock::LockExt;

struct Bucket {
    tokens: f64,
    last: Instant,
}

struct Buckets {
    map: HashMap<u64, Bucket>,
    /// next instant a prune scan is allowed — pruning is amortized to
    /// at most one O(clients) scan per refill interval (see `admit`)
    next_prune: Option<Instant>,
}

/// Token-bucket admission gate. `rate <= 0` disables metering (every
/// client is admitted), which is the default service configuration —
/// quota is opt-in for deployments that need fairness.
pub struct QuotaGate {
    rate: f64,
    burst: f64,
    buckets: Mutex<Buckets>,
    /// prune scans actually run (diagnostics; the amortization
    /// regression test asserts this stays far below the admit count)
    prune_scans: AtomicU64,
}

/// Prune bookkeeping for clients idle long enough to have fully
/// refilled; their bucket is indistinguishable from a fresh one.
const PRUNE_LEN: usize = 1024;

impl QuotaGate {
    /// Gate admitting `rate` requests/sec sustained with bursts up to
    /// `burst` per client. Non-positive `rate` disables the gate.
    pub fn new(rate: f64, burst: f64) -> Self {
        QuotaGate {
            rate,
            burst: burst.max(1.0),
            buckets: Mutex::new(Buckets { map: HashMap::new(), next_prune: None }),
            prune_scans: AtomicU64::new(0),
        }
    }

    /// True when the gate admits everything (rate <= 0).
    pub fn disabled(&self) -> bool {
        self.rate <= 0.0
    }

    /// Try to take one token for `client`; `false` means the request
    /// must be rejected with `QuotaExceeded`.
    ///
    /// Bookkeeping for idle clients is pruned lazily, and the scan is
    /// **amortized**: past `PRUNE_LEN` tracked clients, at most one
    /// O(clients) `retain` runs per refill interval (`burst / rate`
    /// seconds — any bucket idle that long is fully refilled, i.e.
    /// indistinguishable from a fresh one). The pre-fix pathology:
    /// with `> PRUNE_LEN` *active* buckets the scan freed nothing and
    /// ran again on the very next admit, turning every admit into an
    /// O(clients) walk.
    pub fn admit(&self, client: u64) -> bool {
        if self.disabled() {
            return true;
        }
        let now = Instant::now();
        let mut buckets = self.buckets.plock();
        if buckets.map.len() > PRUNE_LEN {
            let refill_secs = self.burst / self.rate;
            let due = match buckets.next_prune {
                None => true,
                Some(t) => now >= t,
            };
            if due {
                self.prune_scans.fetch_add(1, Ordering::Relaxed);
                buckets
                    .map
                    .retain(|_, b| now.duration_since(b.last).as_secs_f64() < refill_secs);
                // whether or not the scan shrank the map, the next one
                // can wait a full refill interval: nothing admitted
                // before then can have become prunable
                buckets.next_prune = Some(now + std::time::Duration::from_secs_f64(refill_secs));
            }
        }
        let b = buckets
            .map
            .entry(client)
            .or_insert(Bucket { tokens: self.burst, last: now });
        let dt = now.duration_since(b.last).as_secs_f64();
        b.last = now;
        b.tokens = (b.tokens + dt * self.rate).min(self.burst);
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Number of clients currently tracked (diagnostics/tests).
    pub fn tracked(&self) -> usize {
        self.buckets.plock().map.len()
    }

    /// Number of O(clients) prune scans run so far (diagnostics/tests).
    pub fn prune_scans(&self) -> u64 {
        self.prune_scans.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_admits_everything() {
        let g = QuotaGate::new(0.0, 4.0);
        assert!(g.disabled());
        for _ in 0..1000 {
            assert!(g.admit(1));
        }
        assert_eq!(g.tracked(), 0);
    }

    #[test]
    fn burst_bounds_rapid_fire() {
        // Refill is negligible within the test (1 token per ~3 hours),
        // so exactly `burst` requests are admitted per client.
        let g = QuotaGate::new(1e-4, 3.0);
        let admitted = (0..10).filter(|_| g.admit(7)).count();
        assert_eq!(admitted, 3);
        // An independent client has its own bucket.
        assert!(g.admit(8));
    }

    #[test]
    fn refill_restores_tokens() {
        let g = QuotaGate::new(200.0, 1.0);
        assert!(g.admit(1));
        assert!(!g.admit(1));
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(g.admit(1));
    }

    #[test]
    fn prune_is_amortized_under_all_active_clients() {
        // Regression for the prune pathology: with > PRUNE_LEN tracked,
        // ALL-ACTIVE buckets (nothing is idle long enough to free),
        // every admit used to run an O(clients) retain that freed
        // nothing. Amortized pruning bounds the scans to one per
        // refill interval — here the interval is huge (burst/rate =
        // 3e4 s), so across thousands of admits at 2048 active clients
        // at most ONE scan may run.
        let g = QuotaGate::new(1e-4, 3.0);
        let clients = 2 * PRUNE_LEN as u64; // 2048 — well past the threshold
        for c in 0..clients {
            g.admit(c);
        }
        assert!(g.tracked() > PRUNE_LEN, "test must exercise the over-threshold path");
        let scans_before = g.prune_scans();
        // a second full round: every admit sees len > PRUNE_LEN
        for c in 0..clients {
            g.admit(c);
        }
        let scans = g.prune_scans() - scans_before;
        assert!(
            scans <= 1,
            "{scans} prune scans across {clients} admits — pruning must be amortized"
        );
        // all buckets stayed (every client is active within the
        // refill window): pruning must not evict live state
        assert_eq!(g.tracked(), clients as usize);
    }

    #[test]
    fn prune_still_frees_idle_clients() {
        // short refill interval (burst/rate = 10ms): after sleeping it
        // out, a fresh admit past the threshold prunes the idle herd
        let g = QuotaGate::new(100.0, 1.0);
        for c in 0..(PRUNE_LEN as u64 + 8) {
            g.admit(c);
        }
        assert!(g.tracked() > PRUNE_LEN);
        std::thread::sleep(std::time::Duration::from_millis(25));
        g.admit(999_999);
        assert!(
            g.tracked() < PRUNE_LEN,
            "idle clients must still be pruned ({} tracked)",
            g.tracked()
        );
    }
}
