//! Per-client admission control: a token-bucket quota keyed by client
//! (connection) id, layered *in front of* the queue-level `QueueFull`
//! backpressure.
//!
//! Backpressure protects the engine from aggregate overload but is
//! blind to fairness — one greedy connection can occupy every queue
//! slot and starve the rest. The token bucket bounds each client's
//! sustained rate (`rate` tokens/sec) and burst (`burst` tokens)
//! before a request is even routed, so a quota rejection is cheap and
//! never consumes queue capacity.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Token-bucket admission gate. `rate <= 0` disables metering (every
/// client is admitted), which is the default service configuration —
/// quota is opt-in for deployments that need fairness.
pub struct QuotaGate {
    rate: f64,
    burst: f64,
    buckets: Mutex<HashMap<u64, Bucket>>,
}

/// Prune bookkeeping for clients idle long enough to have fully
/// refilled; their bucket is indistinguishable from a fresh one.
const PRUNE_LEN: usize = 1024;

impl QuotaGate {
    /// Gate admitting `rate` requests/sec sustained with bursts up to
    /// `burst` per client. Non-positive `rate` disables the gate.
    pub fn new(rate: f64, burst: f64) -> Self {
        QuotaGate { rate, burst: burst.max(1.0), buckets: Mutex::new(HashMap::new()) }
    }

    /// True when the gate admits everything (rate <= 0).
    pub fn disabled(&self) -> bool {
        self.rate <= 0.0
    }

    /// Try to take one token for `client`; `false` means the request
    /// must be rejected with `QuotaExceeded`.
    pub fn admit(&self, client: u64) -> bool {
        if self.disabled() {
            return true;
        }
        let now = Instant::now();
        let mut buckets = self.buckets.lock().unwrap();
        if buckets.len() > PRUNE_LEN {
            let refill_secs = self.burst / self.rate;
            buckets.retain(|_, b| now.duration_since(b.last).as_secs_f64() < refill_secs);
        }
        let b = buckets
            .entry(client)
            .or_insert(Bucket { tokens: self.burst, last: now });
        let dt = now.duration_since(b.last).as_secs_f64();
        b.last = now;
        b.tokens = (b.tokens + dt * self.rate).min(self.burst);
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Number of clients currently tracked (diagnostics/tests).
    pub fn tracked(&self) -> usize {
        self.buckets.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_admits_everything() {
        let g = QuotaGate::new(0.0, 4.0);
        assert!(g.disabled());
        for _ in 0..1000 {
            assert!(g.admit(1));
        }
        assert_eq!(g.tracked(), 0);
    }

    #[test]
    fn burst_bounds_rapid_fire() {
        // Refill is negligible within the test (1 token per ~3 hours),
        // so exactly `burst` requests are admitted per client.
        let g = QuotaGate::new(1e-4, 3.0);
        let admitted = (0..10).filter(|_| g.admit(7)).count();
        assert_eq!(admitted, 3);
        // An independent client has its own bucket.
        assert!(g.admit(8));
    }

    #[test]
    fn refill_restores_tokens() {
        let g = QuotaGate::new(200.0, 1.0);
        assert!(g.admit(1));
        assert!(!g.admit(1));
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(g.admit(1));
    }
}
