//! Line-delimited JSON TCP server in front of the FFT service — the
//! network launcher (`tcfft serve`).
//!
//! Protocol (one JSON object per line):
//!   request:  {"op": "fft1d", "n": 4096, "dir": "fwd", "algo": "tc",
//!              "re": [...], "im": [...]}
//!             {"op": "fft2d", "nx": 256, "ny": 256, ...}
//!             {"op": "rfft1d", "n": 4096, ...}  real input: fwd takes
//!               n real samples in "re" ("im" may be omitted) and
//!               returns the packed n/2+1 bins; "dir": "inv" takes the
//!               packed bins and returns n real samples (scaled by n)
//!             {"op": "rfft2d", "nx": 128, "ny": 128, ...}  real 2D:
//!               fwd takes nx*ny real samples row-major ("im" may be
//!               omitted) and returns the packed nx*(ny/2+1) bins;
//!               "dir": "inv" takes the packed bins and returns nx*ny
//!               real samples (scaled by nx*ny)
//!             {"op": "register_bank", "bank": "lp", "n": 1024,
//!              "filters": [[...], ...], "algo": "tc"} -> {"ok": true,
//!               "k": ...}  register a spectral filter bank
//!             {"op": "convolve", "bank": "lp", "re": [...]} -> all k
//!               filter outputs for the n input samples, concatenated
//!               row-major in "re" (+"k", "n" echoed)
//!             {"op": "metrics"}        -> metrics snapshot
//!             {"op": "ping"}           -> {"ok": true}
//!   response: {"ok": true, "re": [...], "im": [...], "latency_ms": x}
//!           | {"ok": false, "error": "...", "code": "..."}
//!
//! Error replies carry a stable machine-readable `"code"` — one of
//! `crate::error::ERROR_CODES` for service failures, or
//! `"bad_request"` for protocol-level problems (malformed JSON,
//! missing fields, shape mismatches caught before submission).
//!
//! Connections are served by a BOUNDED worker pool (the pre-pool
//! server spawned one thread per accepted socket and kept every join
//! handle forever — an unbounded resource under a reconnect storm).
//! Accepted sockets queue on a bounded channel; when both the pool and
//! the backlog are full, the accept loop itself blocks, which is the
//! correct backpressure (the kernel listen queue absorbs the burst).
//!
//! Each connection is read with a timeout, so an idle client no longer
//! pins its worker past a stop request: every `read_timeout` the
//! reader re-checks the stop flag (the pre-pool server blocked in
//! `lines()` until the client spoke). Requests are PIPELINED: the
//! reader thread parses and submits, and a per-connection writer
//! thread waits on tickets and writes replies in request order — a
//! client may have up to `pipeline_depth` requests in flight, so
//! same-connection requests can share a batch.
//!
//! Every connection gets a distinct client id, passed to the service
//! as the admission-quota key (`ServiceConfig::quota_rate`).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Result, TcFftError};

use super::faults::FaultInjector;
use super::lock::LockExt;
use super::service::{FftRequest, FftService, Op, Ticket};
use crate::plan::Direction;
use crate::runtime::PlanarBatch;
use crate::util::json::Json;

/// Hard cap on one protocol line (a 2^24-point transform serializes to
/// tens of MB of JSON; anything past this is a hostile or broken peer).
const MAX_LINE_BYTES: usize = 32 << 20;

/// TCP front-end configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// connection worker threads (each serves one connection at a time)
    pub workers: usize,
    /// accepted-but-unserved connections queued before the accept loop
    /// blocks (the kernel listen queue backstops beyond that)
    pub backlog: usize,
    /// socket read timeout; also the stop-flag poll period for idle
    /// connections
    pub read_timeout: Duration,
    /// requests one connection may have in flight before its reader
    /// blocks (replies always return in request order)
    pub pipeline_depth: usize,
    /// upper bound on one pipelined reply's ticket wait. The writer
    /// used to block on `ticket.wait()` forever, so one lost batch
    /// wedged its connection (and its pool worker) permanently; now it
    /// emits a coded `deadline_exceeded` error line and moves on.
    /// Generous by default — the service-side request deadline is the
    /// primary bound; this is the last-ditch connection protector.
    pub resolve_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 16,
            backlog: 32,
            read_timeout: Duration::from_millis(100),
            pipeline_depth: 32,
            resolve_timeout: Duration::from_secs(60),
        }
    }
}

/// The TCP front end: accepts line-delimited JSON connections and
/// forwards transform requests to an [`FftService`].
pub struct Server {
    listener: TcpListener,
    svc: Arc<FftService>,
    stop: Arc<AtomicBool>,
    cfg: ServerConfig,
    next_conn_id: Arc<AtomicU64>,
}

impl Server {
    /// Bind the listener (e.g. `"127.0.0.1:7070"`, port 0 for
    /// ephemeral) over a running service, with the default pool sizes.
    pub fn bind(addr: &str, svc: Arc<FftService>) -> Result<Server> {
        Self::bind_with(addr, svc, ServerConfig::default())
    }

    /// [`bind`](Self::bind) with explicit pool / timeout configuration.
    pub fn bind_with(addr: &str, svc: Arc<FftService>, cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        Ok(Server {
            listener,
            svc,
            stop: Arc::new(AtomicBool::new(false)),
            cfg,
            next_conn_id: Arc::new(AtomicU64::new(1)),
        })
    }

    /// The bound socket address (useful with ephemeral ports).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// A flag that stops [`run`](Self::run) when set to true. Workers
    /// notice within `ServerConfig::read_timeout` even when every
    /// client is idle.
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Accept loop over the bounded worker pool. Returns once the stop
    /// flag is set and every worker has drained.
    pub fn run(&self) -> Result<()> {
        let cfg = self.cfg.clone();
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(cfg.backlog.max(1));
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for wi in 0..cfg.workers.max(1) {
            let rx = Arc::clone(&conn_rx);
            let svc = Arc::clone(&self.svc);
            let stop = Arc::clone(&self.stop);
            let wcfg = cfg.clone();
            let ids = Arc::clone(&self.next_conn_id);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("tcfft-conn-{wi}"))
                    .spawn(move || loop {
                        let conn = { rx.plock().recv_timeout(Duration::from_millis(50)) };
                        match conn {
                            Ok(stream) => {
                                let id = ids.fetch_add(1, Ordering::SeqCst);
                                let _ = handle_conn(stream, &svc, &stop, &wcfg, id);
                            }
                            Err(mpsc::RecvTimeoutError::Timeout) => {
                                if stop.load(Ordering::SeqCst) {
                                    break;
                                }
                            }
                            Err(mpsc::RecvTimeoutError::Disconnected) => break,
                        }
                    })
                    .expect("spawn connection worker"),
            );
        }
        let mut result = Ok(());
        while !self.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                // send() blocking on a full backlog IS the accept
                // backpressure; Err means every worker exited
                Ok((stream, _)) => {
                    if conn_tx.send(stream).is_err() {
                        break;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    result = Err(e.into());
                    break;
                }
            }
        }
        drop(conn_tx); // workers see Disconnected once the queue drains
        for w in workers {
            let _ = w.join();
        }
        result
    }
}

/// A reply in the per-connection pipeline: already-final JSON (errors,
/// ping, metrics, register), or a submitted ticket the writer thread
/// resolves in request order.
enum Reply {
    Ready(Json),
    Fft { ticket: Ticket, t0: Instant },
    Conv { ticket: Ticket, t0: Instant, n: usize, k: usize },
}

/// Pull the first complete `\n`-terminated line out of `buf` (the
/// manual framing that lets reads time out without losing buffered
/// bytes — `BufRead::lines` drops its buffer state on an error return,
/// so a timed-out read would corrupt the stream).
fn take_line(buf: &mut Vec<u8>) -> Option<String> {
    let pos = buf.iter().position(|&b| b == b'\n')?;
    let mut line: Vec<u8> = buf.drain(..=pos).collect();
    line.pop(); // the newline
    if line.last() == Some(&b'\r') {
        line.pop();
    }
    Some(String::from_utf8_lossy(&line).into_owned())
}

fn handle_conn(
    stream: TcpStream,
    svc: &Arc<FftService>,
    stop: &AtomicBool,
    cfg: &ServerConfig,
    conn_id: u64,
) -> Result<()> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(cfg.read_timeout))?;
    let mut writer = stream.try_clone()?;
    let (reply_tx, reply_rx) = mpsc::sync_channel::<Reply>(cfg.pipeline_depth.max(1));
    let resolve_timeout = cfg.resolve_timeout;
    let faults = svc.faults();
    let writer_thread = std::thread::Builder::new()
        .name(format!("tcfft-conn-{conn_id}-w"))
        .spawn(move || {
            // replies resolve and write in request order, each wait
            // bounded by resolve_timeout so one lost batch cannot
            // wedge the connection; a dead socket ends the loop, and
            // the reader notices via send() failing
            while let Ok(reply) = reply_rx.recv() {
                let json = resolve_reply(reply, resolve_timeout);
                if write_frame(&mut writer, &json, &faults).is_err() {
                    break;
                }
            }
        })
        .expect("spawn connection writer");

    let mut stream = stream;
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 16 * 1024];
    'conn: while !stop.load(Ordering::SeqCst) {
        while let Some(line) = take_line(&mut buf) {
            if line.trim().is_empty() {
                continue;
            }
            let reply = handle_request(&line, svc, Some(conn_id));
            if reply_tx.send(reply).is_err() {
                break 'conn; // writer died (client hung up mid-reply)
            }
        }
        if buf.len() > MAX_LINE_BYTES {
            let _ = reply_tx.send(Reply::Ready(err_json(format!(
                "request line exceeds {MAX_LINE_BYTES} bytes"
            ))));
            break;
        }
        match stream.read(&mut chunk) {
            Ok(0) => break, // EOF
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            // timeout: nothing arrived within read_timeout — loop back
            // to re-check the stop flag (this is what lets an idle
            // connection release its worker on shutdown)
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break,
        }
    }
    drop(reply_tx);
    let _ = writer_thread.join();
    Ok(())
}

/// Write one reply line. Under an injected chop fault the frame goes
/// out as two partial writes with a flush between — a client must
/// reassemble on the `\n` framing, never on write boundaries.
fn write_frame(writer: &mut TcpStream, json: &Json, faults: &FaultInjector) -> std::io::Result<()> {
    let mut frame = json.to_string().into_bytes();
    frame.push(b'\n');
    if faults.is_active() && frame.len() >= 2 && faults.should_chop() {
        let mid = frame.len() / 2;
        writer.write_all(&frame[..mid])?;
        writer.flush()?;
        writer.write_all(&frame[mid..])?;
    } else {
        writer.write_all(&frame)?;
    }
    writer.flush()
}

/// Protocol-level error reply (bad JSON, missing fields, shape
/// mismatches caught before submission): stable code `bad_request`.
fn err_json(msg: impl std::fmt::Display) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg.to_string())),
        ("code", Json::str("bad_request")),
    ])
}

/// Service-error reply carrying the error's own stable code (the
/// machine-readable half of the error taxonomy contract).
fn err_coded(e: &TcFftError) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str(e.to_string())),
        ("code", Json::str(e.code())),
    ])
}

fn parse_floats(j: &Json, key: &str) -> Option<Vec<f32>> {
    j.get(key)?
        .as_arr()?
        .iter()
        .map(|v| v.as_f64().map(|x| x as f32))
        .collect()
}

/// Wait out a pipelined reply (bounded by `timeout` — an overdue
/// ticket becomes a coded `deadline_exceeded` error line, never a
/// wedged writer) and format the response line.
fn resolve_reply(reply: Reply, timeout: Duration) -> Json {
    match reply {
        Reply::Ready(j) => j,
        Reply::Fft { ticket, t0 } => match ticket.wait_timeout(timeout) {
            Err(e) => err_coded(&e),
            Ok(out) => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("re", Json::Arr(out.re.iter().map(|&x| Json::num(x as f64)).collect())),
                ("im", Json::Arr(out.im.iter().map(|&x| Json::num(x as f64)).collect())),
                ("latency_ms", Json::num(t0.elapsed().as_secs_f64() * 1e3)),
            ]),
        },
        Reply::Conv { ticket, t0, n, k } => match ticket.wait_timeout(timeout) {
            Err(e) => err_coded(&e),
            Ok(out) => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("k", Json::num(k as f64)),
                ("n", Json::num(n as f64)),
                ("re", Json::Arr(out.re.iter().map(|&x| Json::num(x as f64)).collect())),
                ("latency_ms", Json::num(t0.elapsed().as_secs_f64() * 1e3)),
            ]),
        },
    }
}

/// Handle one protocol line against the service and build the reply
/// (exposed for in-process protocol tests). Blocking: submits and
/// waits (bounded by the default `resolve_timeout`). The TCP path uses
/// [`handle_request`] + [`resolve_reply`] instead so the reader never
/// blocks on a ticket.
pub fn handle_line(line: &str, svc: &FftService) -> Json {
    resolve_reply(
        handle_request(line, svc, None),
        ServerConfig::default().resolve_timeout,
    )
}

/// Parse one protocol line, submit any transform it carries (tagged
/// with `client` for admission control), and return the reply — final
/// JSON, or a ticket to resolve later.
fn handle_request(line: &str, svc: &FftService, client: Option<u64>) -> Reply {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return Reply::Ready(err_json(format!("bad json: {e}"))),
    };
    let op = req.get("op").and_then(|o| o.as_str()).unwrap_or("");
    match op {
        "ping" => Reply::Ready(Json::obj(vec![("ok", Json::Bool(true))])),
        "metrics" => {
            let snap = svc.metrics().snapshot();
            Reply::Ready(Json::obj(vec![("ok", Json::Bool(true)), ("metrics", snap)]))
        }
        "register_bank" => {
            let name = match req.get("bank").and_then(|b| b.as_str()) {
                Some(b) => b,
                None => return Reply::Ready(err_json("missing 'bank' name")),
            };
            let n = match req.get("n").and_then(|v| v.as_usize()) {
                Some(n) => n,
                None => return Reply::Ready(err_json("missing 'n'")),
            };
            let algo = req.get("algo").and_then(|a| a.as_str()).unwrap_or("tc");
            let rows = match req.get("filters").and_then(|f| f.as_arr()) {
                Some(rows) if !rows.is_empty() => rows,
                _ => {
                    return Reply::Ready(err_json(
                        "missing/invalid 'filters' array of tap arrays",
                    ))
                }
            };
            let mut filters: Vec<Vec<f32>> = Vec::with_capacity(rows.len());
            for row in rows {
                let taps = row
                    .as_arr()
                    .map(|a| {
                        a.iter()
                            .map(|v| v.as_f64().map(|x| x as f32))
                            .collect::<Option<Vec<f32>>>()
                    })
                    .unwrap_or(None);
                match taps {
                    Some(t) => filters.push(t),
                    None => {
                        return Reply::Ready(err_json(
                            "missing/invalid 'filters' array of tap arrays",
                        ))
                    }
                }
            }
            Reply::Ready(match svc.register_filter_bank(name, n, &filters, algo) {
                Err(e) => err_coded(&e),
                Ok(k) => Json::obj(vec![("ok", Json::Bool(true)), ("k", Json::num(k as f64))]),
            })
        }
        "convolve" => {
            let name = match req.get("bank").and_then(|b| b.as_str()) {
                Some(b) => b,
                None => return Reply::Ready(err_json("missing 'bank' name")),
            };
            let Some((n, k)) = svc.filter_bank_shape(name) else {
                return Reply::Ready(err_json(format!(
                    "no filter bank named '{name}' is registered"
                )));
            };
            let re = match parse_floats(&req, "re") {
                Some(v) => v,
                None => return Reply::Ready(err_json("missing/invalid 're' array")),
            };
            if re.len() != n {
                return Reply::Ready(err_json(format!(
                    "'re' holds {} samples, bank expects {n}",
                    re.len()
                )));
            }
            let t0 = Instant::now();
            let input = PlanarBatch::from_real(&re, vec![n]);
            let submitted = match client {
                Some(c) => svc.submit_convolve_as(c, name, input),
                None => svc.submit_convolve(name, input),
            };
            match submitted {
                Err(e) => Reply::Ready(err_coded(&e)),
                Ok(ticket) => Reply::Conv { ticket, t0, n, k },
            }
        }
        "fft1d" | "fft2d" | "rfft1d" | "rfft2d" => {
            let algo = req.get("algo").and_then(|a| a.as_str()).unwrap_or("tc");
            let dir = match req.get("dir").and_then(|d| d.as_str()).unwrap_or("fwd") {
                "inv" => Direction::Inverse,
                _ => Direction::Forward,
            };
            let re = match parse_floats(&req, "re") {
                Some(v) => v,
                None => return Reply::Ready(err_json("missing/invalid 're' array")),
            };
            let im = match parse_floats(&req, "im") {
                Some(v) => v,
                // the R2C forward paths ignore the imaginary plane by
                // contract, so real-signal clients may omit "im"
                // entirely instead of serializing n literal zeros
                None if (op == "rfft1d" || op == "rfft2d") && dir == Direction::Forward => {
                    vec![0.0; re.len()]
                }
                None => return Reply::Ready(err_json("missing/invalid 'im' array")),
            };
            if re.len() != im.len() {
                return Reply::Ready(err_json("re/im length mismatch"));
            }
            let (op, shape) = match op {
                "fft1d" => {
                    let n = match req.get("n").and_then(|v| v.as_usize()) {
                        Some(n) => n,
                        None => re.len(),
                    };
                    (Op::Fft1d { n }, vec![n])
                }
                "rfft1d" => {
                    // forward sends n real samples; inverse sends the
                    // packed n/2+1 bins, so n defaults to 2*(len-1)
                    let n = match req.get("n").and_then(|v| v.as_usize()) {
                        Some(n) => n,
                        None if dir == Direction::Inverse => 2 * re.len().saturating_sub(1),
                        None => re.len(),
                    };
                    let len = if dir == Direction::Inverse { n / 2 + 1 } else { n };
                    (Op::Rfft1d { n }, vec![len])
                }
                "rfft2d" => {
                    // real 2D needs the explicit shape: forward sends
                    // nx*ny real samples, inverse the nx*(ny/2+1) bins
                    let nx = req.get("nx").and_then(|v| v.as_usize()).unwrap_or(0);
                    let ny = req.get("ny").and_then(|v| v.as_usize()).unwrap_or(0);
                    let tail = if dir == Direction::Inverse { ny / 2 + 1 } else { ny };
                    (Op::Rfft2d { nx, ny }, vec![nx, tail])
                }
                _ => {
                    let nx = req.get("nx").and_then(|v| v.as_usize()).unwrap_or(0);
                    let ny = req.get("ny").and_then(|v| v.as_usize()).unwrap_or(0);
                    (Op::Fft2d { nx, ny }, vec![nx, ny])
                }
            };
            if shape.iter().product::<usize>() != re.len() {
                return Reply::Ready(err_json("data length does not match shape"));
            }
            let t0 = Instant::now();
            let fftreq = FftRequest {
                op,
                algo: algo.to_string(),
                direction: dir,
                input: PlanarBatch { re, im, shape },
            };
            let submitted = match client {
                Some(c) => svc.submit_as(c, fftreq),
                None => svc.submit(fftreq),
            };
            match submitted {
                Err(e) => Reply::Ready(err_coded(&e)),
                Ok(ticket) => Reply::Fft { ticket, t0 },
            }
        }
        other => Reply::Ready(err_json(format!("unknown op '{other}'"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_paths_do_not_need_a_service() {
        // pure-JSON failures short-circuit before touching the service
        assert!(Json::parse("nope").is_err());
        let e = err_json("x");
        assert_eq!(e.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(e.get("code").and_then(|c| c.as_str()), Some("bad_request"));
    }

    #[test]
    fn coded_errors_carry_their_stable_code() {
        let e = err_coded(&TcFftError::DeadlineExceeded);
        assert_eq!(e.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(e.get("code").and_then(|c| c.as_str()), Some("deadline_exceeded"));
        let e = err_coded(&TcFftError::ExecPanic("boom".into()));
        assert_eq!(e.get("code").and_then(|c| c.as_str()), Some("exec_panic"));
        assert!(e.get("error").and_then(|m| m.as_str()).unwrap().contains("boom"));
    }

    #[test]
    fn take_line_frames_and_preserves_remainder() {
        let mut buf = b"{\"op\":\"ping\"}\r\n{\"op\":".to_vec();
        assert_eq!(take_line(&mut buf).as_deref(), Some("{\"op\":\"ping\"}"));
        assert_eq!(buf, b"{\"op\":");
        // no complete line yet: nothing is consumed
        assert_eq!(take_line(&mut buf), None);
        assert_eq!(buf, b"{\"op\":");
        buf.extend_from_slice(b"\"x\"}\n");
        assert_eq!(take_line(&mut buf).as_deref(), Some("{\"op\":\"x\"}"));
        assert!(buf.is_empty());
    }
}
