//! Line-delimited JSON TCP server in front of the FFT service — the
//! network launcher (`tcfft serve`).
//!
//! Protocol (one JSON object per line):
//!   request:  {"op": "fft1d", "n": 4096, "dir": "fwd", "algo": "tc",
//!              "re": [...], "im": [...]}
//!             {"op": "fft2d", "nx": 256, "ny": 256, ...}
//!             {"op": "rfft1d", "n": 4096, ...}  real input: fwd takes
//!               n real samples in "re" ("im" may be omitted) and
//!               returns the packed n/2+1 bins; "dir": "inv" takes the
//!               packed bins and returns n real samples (scaled by n)
//!             {"op": "rfft2d", "nx": 128, "ny": 128, ...}  real 2D:
//!               fwd takes nx*ny real samples row-major ("im" may be
//!               omitted) and returns the packed nx*(ny/2+1) bins;
//!               "dir": "inv" takes the packed bins and returns nx*ny
//!               real samples (scaled by nx*ny)
//!             {"op": "register_bank", "bank": "lp", "n": 1024,
//!              "filters": [[...], ...], "algo": "tc"} -> {"ok": true,
//!               "k": ...}  register a spectral filter bank
//!             {"op": "convolve", "bank": "lp", "re": [...]} -> all k
//!               filter outputs for the n input samples, concatenated
//!               row-major in "re" (+"k", "n" echoed)
//!             {"op": "metrics"}        -> metrics snapshot
//!             {"op": "ping"}           -> {"ok": true}
//!   response: {"ok": true, "re": [...], "im": [...], "latency_ms": x}
//!           | {"ok": false, "error": "..."}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::error::Result;

use super::service::{FftRequest, FftService, Op};
use crate::plan::Direction;
use crate::runtime::PlanarBatch;
use crate::util::json::Json;

/// The TCP front end: accepts line-delimited JSON connections and
/// forwards transform requests to an [`FftService`].
pub struct Server {
    listener: TcpListener,
    svc: Arc<FftService>,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind the listener (e.g. `"127.0.0.1:7070"`, port 0 for
    /// ephemeral) over a running service.
    pub fn bind(addr: &str, svc: Arc<FftService>) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        Ok(Server {
            listener,
            svc,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound socket address (useful with ephemeral ports).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// A flag that stops [`run`](Self::run) when set to true.
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Accept loop; one thread per connection (fine at service scale —
    /// heavy lifting is batched behind the PJRT actor anyway).
    pub fn run(&self) -> Result<()> {
        let mut handles = Vec::new();
        while !self.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let svc = Arc::clone(&self.svc);
                    let stop = Arc::clone(&self.stop);
                    handles.push(std::thread::spawn(move || {
                        let _ = handle_conn(stream, svc, stop);
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

fn handle_conn(stream: TcpStream, svc: Arc<FftService>, stop: Arc<AtomicBool>) -> Result<()> {
    stream.set_nodelay(true)?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle_line(&line, &svc);
        writer.write_all(resp.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}

fn err_json(msg: impl std::fmt::Display) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(msg.to_string()))])
}

fn parse_floats(j: &Json, key: &str) -> Option<Vec<f32>> {
    j.get(key)?
        .as_arr()?
        .iter()
        .map(|v| v.as_f64().map(|x| x as f32))
        .collect()
}

/// Handle one protocol line against the service and build the reply
/// (exposed for in-process protocol tests).
pub fn handle_line(line: &str, svc: &FftService) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return err_json(format!("bad json: {e}")),
    };
    let op = req.get("op").and_then(|o| o.as_str()).unwrap_or("");
    match op {
        "ping" => Json::obj(vec![("ok", Json::Bool(true))]),
        "metrics" => {
            let snap = svc.metrics().snapshot();
            Json::obj(vec![("ok", Json::Bool(true)), ("metrics", snap)])
        }
        "register_bank" => {
            let name = match req.get("bank").and_then(|b| b.as_str()) {
                Some(b) => b,
                None => return err_json("missing 'bank' name"),
            };
            let n = match req.get("n").and_then(|v| v.as_usize()) {
                Some(n) => n,
                None => return err_json("missing 'n'"),
            };
            let algo = req.get("algo").and_then(|a| a.as_str()).unwrap_or("tc");
            let rows = match req.get("filters").and_then(|f| f.as_arr()) {
                Some(rows) if !rows.is_empty() => rows,
                _ => return err_json("missing/invalid 'filters' array of tap arrays"),
            };
            let mut filters: Vec<Vec<f32>> = Vec::with_capacity(rows.len());
            for row in rows {
                let taps = row
                    .as_arr()
                    .map(|a| {
                        a.iter()
                            .map(|v| v.as_f64().map(|x| x as f32))
                            .collect::<Option<Vec<f32>>>()
                    })
                    .unwrap_or(None);
                match taps {
                    Some(t) => filters.push(t),
                    None => return err_json("missing/invalid 'filters' array of tap arrays"),
                }
            }
            match svc.register_filter_bank(name, n, &filters, algo) {
                Err(e) => err_json(e),
                Ok(k) => Json::obj(vec![("ok", Json::Bool(true)), ("k", Json::num(k as f64))]),
            }
        }
        "convolve" => {
            let name = match req.get("bank").and_then(|b| b.as_str()) {
                Some(b) => b,
                None => return err_json("missing 'bank' name"),
            };
            let Some((n, k)) = svc.filter_bank_shape(name) else {
                return err_json(format!("no filter bank named '{name}' is registered"));
            };
            let re = match parse_floats(&req, "re") {
                Some(v) => v,
                None => return err_json("missing/invalid 're' array"),
            };
            if re.len() != n {
                return err_json(format!("'re' holds {} samples, bank expects {n}", re.len()));
            }
            let t0 = Instant::now();
            let input = PlanarBatch::from_real(&re, vec![n]);
            match svc.submit_convolve(name, input).and_then(|t| t.wait()) {
                Err(e) => err_json(e),
                Ok(out) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("k", Json::num(k as f64)),
                    ("n", Json::num(n as f64)),
                    ("re", Json::Arr(out.re.iter().map(|&x| Json::num(x as f64)).collect())),
                    ("latency_ms", Json::num(t0.elapsed().as_secs_f64() * 1e3)),
                ]),
            }
        }
        "fft1d" | "fft2d" | "rfft1d" | "rfft2d" => {
            let algo = req.get("algo").and_then(|a| a.as_str()).unwrap_or("tc");
            let dir = match req.get("dir").and_then(|d| d.as_str()).unwrap_or("fwd") {
                "inv" => Direction::Inverse,
                _ => Direction::Forward,
            };
            let re = match parse_floats(&req, "re") {
                Some(v) => v,
                None => return err_json("missing/invalid 're' array"),
            };
            let im = match parse_floats(&req, "im") {
                Some(v) => v,
                // the R2C forward paths ignore the imaginary plane by
                // contract, so real-signal clients may omit "im"
                // entirely instead of serializing n literal zeros
                None if (op == "rfft1d" || op == "rfft2d") && dir == Direction::Forward => {
                    vec![0.0; re.len()]
                }
                None => return err_json("missing/invalid 'im' array"),
            };
            if re.len() != im.len() {
                return err_json("re/im length mismatch");
            }
            let (op, shape) = match op {
                "fft1d" => {
                    let n = match req.get("n").and_then(|v| v.as_usize()) {
                        Some(n) => n,
                        None => re.len(),
                    };
                    (Op::Fft1d { n }, vec![n])
                }
                "rfft1d" => {
                    // forward sends n real samples; inverse sends the
                    // packed n/2+1 bins, so n defaults to 2*(len-1)
                    let n = match req.get("n").and_then(|v| v.as_usize()) {
                        Some(n) => n,
                        None if dir == Direction::Inverse => {
                            2 * re.len().saturating_sub(1)
                        }
                        None => re.len(),
                    };
                    let len = if dir == Direction::Inverse { n / 2 + 1 } else { n };
                    (Op::Rfft1d { n }, vec![len])
                }
                "rfft2d" => {
                    // real 2D needs the explicit shape: forward sends
                    // nx*ny real samples, inverse the nx*(ny/2+1) bins
                    let nx = req.get("nx").and_then(|v| v.as_usize()).unwrap_or(0);
                    let ny = req.get("ny").and_then(|v| v.as_usize()).unwrap_or(0);
                    let tail = if dir == Direction::Inverse { ny / 2 + 1 } else { ny };
                    (Op::Rfft2d { nx, ny }, vec![nx, tail])
                }
                _ => {
                    let nx = req.get("nx").and_then(|v| v.as_usize()).unwrap_or(0);
                    let ny = req.get("ny").and_then(|v| v.as_usize()).unwrap_or(0);
                    (Op::Fft2d { nx, ny }, vec![nx, ny])
                }
            };
            if shape.iter().product::<usize>() != re.len() {
                return err_json("data length does not match shape");
            }
            let t0 = Instant::now();
            let fftreq = FftRequest {
                op,
                algo: algo.to_string(),
                direction: dir,
                input: PlanarBatch { re, im, shape },
            };
            match svc.submit(fftreq).and_then(|t| t.wait()) {
                Err(e) => err_json(e),
                Ok(out) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("re", Json::Arr(out.re.iter().map(|&x| Json::num(x as f64)).collect())),
                    ("im", Json::Arr(out.im.iter().map(|&x| Json::num(x as f64)).collect())),
                    ("latency_ms", Json::num(t0.elapsed().as_secs_f64() * 1e3)),
                ]),
            }
        }
        other => err_json(format!("unknown op '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_paths_do_not_need_a_service() {
        // pure-JSON failures short-circuit before touching the service
        assert!(Json::parse("nope").is_err());
        let e = err_json("x");
        assert_eq!(e.get("ok"), Some(&Json::Bool(false)));
    }
}
