//! L3 coordinator — the serving layer around the AOT FFT kernels.
//!
//! The paper ships tcFFT as a library (plan/execute); production users
//! embed such libraries behind a service.  This module supplies that
//! service: request router with a plan cache, per-plan dynamic batcher
//! with deadline-or-full flushing and backpressure, an execution pool
//! feeding the thread-safe PJRT engine (with an inline leader-execution
//! fast path), registered spectral filter banks served through the
//! same queues ([`FftService::register_filter_bank`] /
//! [`FftService::submit_convolve`]), metrics, and a TCP JSON front end.

pub mod batcher;
pub mod metrics;
pub mod server;
pub mod service;

pub use metrics::Metrics;
pub use server::Server;
pub use service::{FftRequest, FftService, Op, ServiceConfig, Ticket};
