//! L3 coordinator — the serving layer around the AOT FFT kernels.
//!
//! The paper ships tcFFT as a library (plan/execute); production users
//! embed such libraries behind a service.  This module supplies that
//! service: a sharded request router (queue keys hash to independent
//! shards, each with its own queue map, deadline flusher and execution
//! workers, with work-stealing of due batches between shards), plan /
//! large-plan / filter-bank stores behind byte-budgeted LRU caches
//! keyed by deterministic content fingerprints ([`cache`],
//! `util::fnv`), per-plan dynamic batching with deadline-or-full
//! flushing and backpressure, per-client token-bucket admission
//! control ([`quota`]), registered spectral filter banks served
//! through the same queues ([`FftService::register_filter_bank`] /
//! [`FftService::submit_convolve`]), bounded-reservoir metrics, and a
//! TCP JSON front end on a bounded worker pool with request
//! pipelining.
//!
//! The layer is fault-tolerant by construction: batch execution is
//! panic-isolated (`catch_unwind` → structured [`TcFftError::ExecPanic`]
//! replies to every batch member), dead workers and flushers are
//! respawned by a supervisor, every request carries an end-to-end
//! deadline shed at flush and pre-execution time, and every mutex in
//! this module is taken through the poison-recovering [`lock`]
//! helpers. The [`faults`] injector makes those paths deterministic to
//! test (see `tests/chaos_service.rs`).
//!
//! [`TcFftError::ExecPanic`]: crate::error::TcFftError::ExecPanic

pub mod batcher;
pub mod cache;
pub mod faults;
pub mod lock;
pub mod metrics;
pub mod quota;
pub mod server;
pub mod service;

pub use faults::{FaultInjector, FaultPlan};
pub use metrics::Metrics;
pub use server::{Server, ServerConfig};
pub use service::{FftRequest, FftService, Op, ServiceConfig, Ticket};
