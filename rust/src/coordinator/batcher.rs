//! Dynamic batcher: groups same-plan requests into artifact-sized
//! batches (vLLM-router-style).  Flush policy: a batch goes out when it
//! fills the artifact's batch capacity OR its oldest request exceeds
//! `max_wait` — whichever comes first.  Short batches are zero-padded
//! (padding is tracked in metrics; the padding-ratio ablation is one of
//! the serving benches).

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::runtime::PlanarBatch;

/// One pending single-sequence request.
pub struct Pending {
    /// service-assigned request id
    pub id: u64,
    /// shape [1, ...]: one sequence (multi-row submissions are split
    /// into per-row requests by the service)
    pub input: PlanarBatch,
    /// when the request entered the queue (drives the deadline flush)
    pub enqueued: Instant,
    /// end-to-end expiry (`ServiceConfig::request_deadline` stamped at
    /// submit time); `None` = the request never expires. Expired
    /// requests are shed with `DeadlineExceeded` at flush time
    /// ([`PlanQueue::shed_expired`]) and again at batch-assembly time
    /// (`run_batch`) — never silently executed late.
    pub deadline: Option<Instant>,
    /// per-request reply channel
    pub reply: mpsc::Sender<Result<PlanarBatch>>,
}

impl Pending {
    /// True once the request's deadline has passed.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| d <= now)
    }
}

/// A batch ready for execution.
pub struct ReadyBatch {
    /// the assembled (possibly padded) batch input
    pub input: PlanarBatch,
    /// the requests whose rows fill the batch, in row order
    pub members: Vec<Pending>,
    /// zero-padded slots appended after the member rows
    pub padded: usize,
}

/// Per-plan FIFO queue with deadline-or-full flushing.
pub struct PlanQueue {
    /// routing key (artifact key or four-step plan key)
    pub key: String,
    /// rows per flush (artifact batch size)
    pub capacity: usize,
    queue: VecDeque<Pending>,
    /// backpressure bound on queued requests
    pub max_queue: usize,
    /// zero-pad short flushes up to `capacity` (artifact-shaped
    /// batches). Large four-step queues run unpadded: the batched
    /// engine accepts any row count, and padding a 2^20-point slot
    /// would burn a whole transform's worth of work on zeros.
    pad: bool,
}

impl PlanQueue {
    /// Padded queue (flushes are zero-padded to `capacity` rows).
    pub fn new(key: impl Into<String>, capacity: usize, max_queue: usize) -> Self {
        PlanQueue {
            key: key.into(),
            capacity,
            queue: VecDeque::new(),
            max_queue,
            pad: true,
        }
    }

    /// A queue whose flushes carry exactly the pending rows (no zero
    /// padding) — for plans whose executor takes arbitrary batch sizes.
    pub fn unpadded(key: impl Into<String>, capacity: usize, max_queue: usize) -> Self {
        PlanQueue { pad: false, ..Self::new(key, capacity, max_queue) }
    }

    /// Pending requests in the queue.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// True when nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Enqueue; Err(req) if the queue is full (backpressure).
    ///
    /// Note the explicit `std::result::Result`: this is the one spot in
    /// the module that does not use the one-parameter `crate::error`
    /// alias (the rejected request rides back in the error slot).
    pub fn push(&mut self, req: Pending) -> std::result::Result<(), Pending> {
        if self.queue.len() >= self.max_queue {
            return Err(req);
        }
        self.queue.push_back(req);
        Ok(())
    }

    /// Age of the oldest pending request.
    pub fn oldest_age(&self, now: Instant) -> Option<std::time::Duration> {
        self.queue.front().map(|p| now.duration_since(p.enqueued))
    }

    /// Pop every already-expired request off the front of the queue.
    /// The caller replies `DeadlineExceeded` to each OUTSIDE the shard
    /// lock. Front-popping is exact because a queue is strict FIFO and
    /// every member shares the same service-wide deadline offset, so
    /// expiry order equals arrival order.
    pub fn shed_expired(&mut self, now: Instant) -> Vec<Pending> {
        let mut shed = Vec::new();
        while self.queue.front().is_some_and(|p| p.expired(now)) {
            shed.push(self.queue.pop_front().unwrap());
        }
        shed
    }

    /// Should we flush now under the given deadline?
    pub fn should_flush(&self, now: Instant, max_wait: std::time::Duration) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        self.queue.len() >= self.capacity
            || self.oldest_age(now).is_some_and(|age| age >= max_wait)
    }

    /// Pop up to `capacity` requests and assemble the padded batch.
    ///
    /// Inputs are MOVED out of the pending entries and written directly
    /// into one pre-sized padded buffer — a single copy per request
    /// (perf iteration 3, EXPERIMENTS.md SPerf).
    pub fn flush(&mut self) -> Option<ReadyBatch> {
        if self.queue.is_empty() {
            return None;
        }
        let take = self.queue.len().min(self.capacity);
        let mut members: Vec<Pending> = self.queue.drain(..take).collect();
        let tail: Vec<usize> = members[0].input.shape[1..].to_vec();
        let row: usize = tail.iter().product();
        let rows = if self.pad { self.capacity } else { take };
        let mut shape = vec![rows];
        shape.extend_from_slice(&tail);
        let mut input = PlanarBatch {
            re: vec![0.0; rows * row],
            im: vec![0.0; rows * row],
            shape,
        };
        for (i, m) in members.iter_mut().enumerate() {
            let part = std::mem::take(&mut m.input);
            debug_assert_eq!(&part.shape[1..], &tail[..], "ragged batch");
            input.re[i * row..(i + 1) * row].copy_from_slice(&part.re);
            input.im[i * row..(i + 1) * row].copy_from_slice(&part.im);
        }
        let padded = rows - take;
        Some(ReadyBatch { input, members, padded })
    }
}

/// Drain every due batch from a shard's queue map (`force` drains
/// everything pending, the shutdown path), then drop queues left
/// empty: a queue is cheap to recreate on the next submit, and under a
/// key-space-walking client the map would otherwise grow one entry per
/// key ever seen — the same unbounded-growth bug the plan caches had.
///
/// Expired requests are shed from each queue before its flush check
/// and returned separately; the caller replies `DeadlineExceeded` to
/// them outside the shard lock. Shedding first keeps a dead request
/// from holding `oldest_age` hostage or wasting a padded batch slot.
pub fn drain_due(
    queues: &mut HashMap<String, PlanQueue>,
    now: Instant,
    max_wait: Duration,
    force: bool,
) -> (Vec<(String, ReadyBatch)>, Vec<Pending>) {
    let mut ready = Vec::new();
    let mut shed = Vec::new();
    for q in queues.values_mut() {
        shed.extend(q.shed_expired(now));
        loop {
            let due = if force { !q.is_empty() } else { q.should_flush(now, max_wait) };
            if !due {
                break;
            }
            match q.flush() {
                Some(b) => ready.push((q.key.clone(), b)),
                None => break,
            }
        }
    }
    queues.retain(|_, q| !q.is_empty());
    (ready, shed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, n: usize) -> (Pending, mpsc::Receiver<Result<PlanarBatch>>) {
        req_deadline(id, n, None)
    }

    fn req_deadline(
        id: u64,
        n: usize,
        deadline: Option<Instant>,
    ) -> (Pending, mpsc::Receiver<Result<PlanarBatch>>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending {
                id,
                input: PlanarBatch::new(vec![1, n]),
                enqueued: Instant::now(),
                deadline,
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn flush_on_full() {
        let mut q = PlanQueue::new("k", 4, 64);
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (p, rx) = req(i, 8);
            q.push(p).map_err(|_| ()).unwrap();
            rxs.push(rx);
        }
        assert!(q.should_flush(Instant::now(), Duration::from_secs(60)));
        let b = q.flush().unwrap();
        assert_eq!(b.members.len(), 4);
        assert_eq!(b.padded, 0);
        assert_eq!(b.input.shape, vec![4, 8]);
        assert!(q.is_empty());
    }

    #[test]
    fn flush_on_deadline_with_padding() {
        let mut q = PlanQueue::new("k", 4, 64);
        let (p, _rx) = req(0, 8);
        q.push(p).map_err(|_| ()).unwrap();
        // deadline not reached yet
        assert!(!q.should_flush(Instant::now(), Duration::from_secs(60)));
        // zero deadline: flush immediately with padding
        assert!(q.should_flush(Instant::now(), Duration::ZERO));
        let b = q.flush().unwrap();
        assert_eq!(b.members.len(), 1);
        assert_eq!(b.padded, 3);
        assert_eq!(b.input.shape, vec![4, 8]);
    }

    #[test]
    fn unpadded_flush_carries_exact_rows() {
        let mut q = PlanQueue::unpadded("big", 4, 64);
        for i in 0..2 {
            let (p, _rx) = req(i, 8);
            q.push(p).map_err(|_| ()).unwrap();
        }
        let b = q.flush().unwrap();
        assert_eq!(b.members.len(), 2);
        assert_eq!(b.padded, 0, "unpadded queue must not synthesize rows");
        assert_eq!(b.input.shape, vec![2, 8]);
        // capacity still bounds one flush
        for i in 0..6 {
            let (p, _rx) = req(10 + i, 8);
            q.push(p).map_err(|_| ()).unwrap();
        }
        let b = q.flush().unwrap();
        assert_eq!(b.input.shape, vec![4, 8]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn backpressure_bound() {
        let mut q = PlanQueue::new("k", 2, 3);
        for i in 0..3 {
            let (p, _rx) = req(i, 4);
            assert!(q.push(p).is_ok());
        }
        let (p, _rx) = req(9, 4);
        assert!(q.push(p).is_err(), "4th push must be rejected");
    }

    #[test]
    fn drain_due_removes_empty_queues() {
        let mut queues = HashMap::new();
        let mut q = PlanQueue::new("full", 1, 64);
        let (p, _rx) = req(0, 4);
        q.push(p).map_err(|_| ()).unwrap();
        queues.insert("full".to_string(), q);
        let mut idle = PlanQueue::new("idle", 4, 64);
        let (p, _rx2) = req(1, 4);
        idle.push(p).map_err(|_| ()).unwrap();
        queues.insert("idle".to_string(), idle);
        queues.insert("empty".to_string(), PlanQueue::new("empty", 4, 64));
        let (ready, shed) =
            drain_due(&mut queues, Instant::now(), Duration::from_secs(3600), false);
        // "full" hit capacity and flushed; "empty" was reaped; "idle"
        // still holds its not-yet-due request
        assert_eq!(ready.len(), 1);
        assert!(shed.is_empty());
        assert_eq!(ready[0].0, "full");
        assert_eq!(queues.len(), 1);
        assert!(queues.contains_key("idle"));
        // force drains the rest and leaves the map empty
        let (ready, _) = drain_due(&mut queues, Instant::now(), Duration::from_secs(3600), true);
        assert_eq!(ready.len(), 1);
        assert!(queues.is_empty());
    }

    #[test]
    fn shed_expired_pops_only_expired_front() {
        let now = Instant::now();
        let mut q = PlanQueue::new("k", 8, 64);
        let (p, _rx0) = req_deadline(0, 4, Some(now - Duration::from_millis(1)));
        q.push(p).map_err(|_| ()).unwrap();
        let (p, _rx1) = req_deadline(1, 4, Some(now - Duration::from_millis(1)));
        q.push(p).map_err(|_| ()).unwrap();
        let (p, _rx2) = req_deadline(2, 4, Some(now + Duration::from_secs(60)));
        q.push(p).map_err(|_| ()).unwrap();
        let (p, _rx3) = req(3, 4); // no deadline: never expires
        q.push(p).map_err(|_| ()).unwrap();
        let shed = q.shed_expired(now);
        assert_eq!(shed.iter().map(|p| p.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(q.len(), 2);
        // nothing further to shed
        assert!(q.shed_expired(now).is_empty());
    }

    #[test]
    fn drain_due_sheds_before_flushing() {
        let now = Instant::now();
        let mut queues = HashMap::new();
        let mut q = PlanQueue::new("k", 2, 64);
        // expired front request would otherwise hold a batch slot and
        // trip the age-based flush
        let (p, _rx0) = req_deadline(0, 4, Some(now - Duration::from_millis(1)));
        q.push(p).map_err(|_| ()).unwrap();
        let (p, _rx1) = req_deadline(1, 4, Some(now + Duration::from_secs(60)));
        q.push(p).map_err(|_| ()).unwrap();
        queues.insert("k".to_string(), q);
        let (ready, shed) = drain_due(&mut queues, now, Duration::from_secs(3600), false);
        assert!(ready.is_empty(), "live request alone is not due yet");
        assert_eq!(shed.len(), 1);
        assert_eq!(shed[0].id, 0);
        assert_eq!(queues["k"].len(), 1);
        // a fully-shed queue is reaped like any other empty queue
        let mut q2 = PlanQueue::new("gone", 4, 64);
        let (p, _rx2) = req_deadline(9, 4, Some(now - Duration::from_millis(1)));
        q2.push(p).map_err(|_| ()).unwrap();
        queues.insert("gone".to_string(), q2);
        let (_, shed) = drain_due(&mut queues, now, Duration::from_secs(3600), false);
        assert_eq!(shed.len(), 1);
        assert!(!queues.contains_key("gone"));
    }

    #[test]
    fn flush_takes_at_most_capacity() {
        let mut q = PlanQueue::new("k", 2, 64);
        for i in 0..5 {
            let (p, _rx) = req(i, 4);
            q.push(p).map_err(|_| ()).unwrap();
        }
        let b = q.flush().unwrap();
        assert_eq!(b.members.len(), 2);
        assert_eq!(q.len(), 3);
        assert_eq!(b.members[0].id, 0);
        assert_eq!(b.members[1].id, 1);
    }
}
