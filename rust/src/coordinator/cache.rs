//! Byte-budgeted LRU cache for the service's plan / large-plan /
//! filter-bank stores.
//!
//! The pre-shard service kept three `HashMap`s that only ever grew
//! ("never evicted by design"), with a hard registration cap standing
//! in for a memory bound. Under a key-space-walking client that is an
//! unbounded leak; under the old cap it is a denial of service (the
//! 65th bank is refused forever). This cache replaces both with the
//! standard serving-cache contract:
//!
//! - every entry carries an explicit byte cost (`memory_bytes()` on
//!   the cached plan types);
//! - inserting evicts least-recently-used entries until the configured
//!   budget holds;
//! - hit / miss / eviction / byte / entry counters are shared with
//!   `Metrics::snapshot()` so operators can see churn.
//!
//! Keys are deterministic content fingerprints (see `util::fnv`): the
//! human-readable descriptor suffixed with `#<fnv1a64>` of the
//! canonical content, so identity survives eviction and process
//! restarts — an evicted plan rebuilt from the same descriptor lands
//! under the same key.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::lock::LockExt;

/// Shared hit/miss/eviction counters for one cache, snapshot by
/// `Metrics`. All counters are monotonically increasing except
/// `bytes`/`entries`, which track current occupancy.
#[derive(Debug, Default)]
pub struct CacheStats {
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub evictions: AtomicU64,
    pub bytes: AtomicU64,
    pub entries: AtomicU64,
}

impl CacheStats {
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
    pub fn entries(&self) -> u64 {
        self.entries.load(Ordering::Relaxed)
    }
}

struct Entry<V> {
    value: V,
    bytes: usize,
    /// logical access clock stamp; smallest = least recently used
    stamp: u64,
}

struct Inner<V> {
    map: HashMap<String, Entry<V>>,
    clock: u64,
    bytes: usize,
}

/// LRU cache with a byte budget. Values are cloned out on access, so
/// `V` is expected to be cheap to clone — in the service every cached
/// value is an `Arc` (or a small struct of `Arc`s), making eviction
/// safe while executions still hold a reference.
pub struct LruCache<V: Clone> {
    budget: usize,
    stats: std::sync::Arc<CacheStats>,
    inner: Mutex<Inner<V>>,
}

impl<V: Clone> LruCache<V> {
    /// Cache bounded to `budget` bytes of accounted content.
    pub fn new(budget: usize) -> Self {
        Self::with_stats(budget, std::sync::Arc::new(CacheStats::default()))
    }

    /// [`new`](Self::new) with externally owned counters — the service
    /// hands in the `Arc<CacheStats>` its `Metrics` snapshot reads.
    pub fn with_stats(budget: usize, stats: std::sync::Arc<CacheStats>) -> Self {
        LruCache {
            budget,
            stats,
            inner: Mutex::new(Inner { map: HashMap::new(), clock: 0, bytes: 0 }),
        }
    }

    /// The configured byte budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Shared counters (cloned `Arc`) for wiring into `Metrics`.
    pub fn stats(&self) -> std::sync::Arc<CacheStats> {
        self.stats.clone()
    }

    /// Look up and touch (counts a hit or a miss).
    pub fn get(&self, key: &str) -> Option<V> {
        let mut inner = self.inner.plock();
        inner.clock += 1;
        let clock = inner.clock;
        match inner.map.get_mut(key) {
            Some(e) => {
                e.stamp = clock;
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.value.clone())
            }
            None => {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Peek without touching LRU order or counting a hit/miss (used by
    /// validation paths that should not distort churn statistics).
    pub fn peek(&self, key: &str) -> Option<V> {
        let inner = self.inner.plock();
        inner.map.get(key).map(|e| e.value.clone())
    }

    /// Insert `value` under `key`, evicting LRU entries until the
    /// budget holds. Returns `false` (and caches nothing) when the
    /// entry alone exceeds the whole budget — evicting everything else
    /// would still not make it fit, so callers must be able to work
    /// uncached.
    pub fn insert(&self, key: &str, value: V, bytes: usize) -> bool {
        self.insert_inner(key, value, bytes).is_some()
    }

    /// Racing-builder insert: if `key` is already present (someone
    /// else built it first), return the existing value and `false`;
    /// otherwise insert and return `(value, true)`. Like `insert`,
    /// an over-budget entry is handed back uncached (`false`).
    pub fn get_or_insert(&self, key: &str, value: V, bytes: usize) -> (V, bool) {
        {
            let mut inner = self.inner.plock();
            inner.clock += 1;
            let clock = inner.clock;
            if let Some(e) = inner.map.get_mut(key) {
                e.stamp = clock;
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                return (e.value.clone(), false);
            }
        }
        match self.insert_inner(key, value.clone(), bytes) {
            Some(v) => (v, true),
            None => (value, false),
        }
    }

    fn insert_inner(&self, key: &str, value: V, bytes: usize) -> Option<V> {
        if bytes > self.budget {
            return None;
        }
        let mut inner = self.inner.plock();
        inner.clock += 1;
        let clock = inner.clock;
        if let Some(old) = inner.map.remove(key) {
            inner.bytes -= old.bytes;
        }
        // Evict least-recently-used until the new entry fits.
        while inner.bytes + bytes > self.budget {
            let victim = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    let e = inner.map.remove(&k).unwrap();
                    inner.bytes -= e.bytes;
                    self.stats.evictions.fetch_add(1, Ordering::Relaxed);
                }
                None => break,
            }
        }
        inner.bytes += bytes;
        inner
            .map
            .insert(key.to_string(), Entry { value: value.clone(), bytes, stamp: clock });
        self.stats.bytes.store(inner.bytes as u64, Ordering::Relaxed);
        self.stats.entries.store(inner.map.len() as u64, Ordering::Relaxed);
        Some(value)
    }

    /// Force-evict the current least-recently-used entry regardless of
    /// budget headroom, returning its key. Used by the fault injector
    /// (`forced cache eviction`) to exercise the eviction-rebuild path
    /// under load; counts in the eviction statistics like any other
    /// eviction. No-op on an empty cache.
    pub fn evict_oldest(&self) -> Option<String> {
        let mut inner = self.inner.plock();
        let victim = inner
            .map
            .iter()
            .min_by_key(|(_, e)| e.stamp)
            .map(|(k, _)| k.clone())?;
        let e = inner.map.remove(&victim).unwrap();
        inner.bytes -= e.bytes;
        self.stats.evictions.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes.store(inner.bytes as u64, Ordering::Relaxed);
        self.stats.entries.store(inner.map.len() as u64, Ordering::Relaxed);
        Some(victim)
    }

    /// Remove an entry (used by re-registration conflict handling).
    pub fn remove(&self, key: &str) -> Option<V> {
        let mut inner = self.inner.plock();
        let e = inner.map.remove(key)?;
        inner.bytes -= e.bytes;
        self.stats.bytes.store(inner.bytes as u64, Ordering::Relaxed);
        self.stats.entries.store(inner.map.len() as u64, Ordering::Relaxed);
        Some(e.value)
    }

    /// Current entry count.
    pub fn len(&self) -> usize {
        self.inner.plock().map.len()
    }

    /// True when the cache holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Currently accounted bytes (always <= budget).
    pub fn bytes(&self) -> usize {
        self.inner.plock().bytes
    }

    /// Snapshot of the keys currently cached (diagnostics/tests).
    pub fn keys(&self) -> Vec<String> {
        self.inner.plock().map.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_miss_and_touch() {
        let c: LruCache<u32> = LruCache::new(100);
        assert!(c.get("a").is_none());
        assert!(c.insert("a", 1, 10));
        assert_eq!(c.get("a"), Some(1));
        let s = c.stats();
        assert_eq!(s.hits(), 1);
        assert_eq!(s.misses(), 1);
        assert_eq!(s.bytes(), 10);
        assert_eq!(s.entries(), 1);
    }

    #[test]
    fn evicts_lru_to_fit_budget() {
        let c: LruCache<u32> = LruCache::new(30);
        c.insert("a", 1, 10);
        c.insert("b", 2, 10);
        c.insert("c", 3, 10);
        // Touch "a" so "b" becomes the LRU victim.
        c.get("a");
        c.insert("d", 4, 10);
        assert_eq!(c.get("b"), None);
        assert_eq!(c.get("a"), Some(1));
        assert_eq!(c.get("c"), Some(3));
        assert_eq!(c.get("d"), Some(4));
        assert_eq!(c.stats().evictions(), 1);
        assert!(c.bytes() <= 30);
    }

    #[test]
    fn oversized_entry_is_refused() {
        let c: LruCache<u32> = LruCache::new(30);
        c.insert("a", 1, 10);
        assert!(!c.insert("big", 9, 31));
        // Nothing was evicted to make room for an impossible fit.
        assert_eq!(c.get("a"), Some(1));
        assert_eq!(c.stats().evictions(), 0);
        let (v, inserted) = c.get_or_insert("big", 9, 31);
        assert_eq!(v, 9);
        assert!(!inserted);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn get_or_insert_returns_existing() {
        let c: LruCache<u32> = LruCache::new(100);
        let (v, inserted) = c.get_or_insert("k", 1, 10);
        assert_eq!((v, inserted), (1, true));
        let (v, inserted) = c.get_or_insert("k", 2, 10);
        assert_eq!((v, inserted), (1, false));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn reinsert_same_key_replaces_bytes() {
        let c: LruCache<u32> = LruCache::new(30);
        c.insert("a", 1, 10);
        c.insert("a", 2, 20);
        assert_eq!(c.bytes(), 20);
        assert_eq!(c.get("a"), Some(2));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn remove_releases_bytes() {
        let c: LruCache<u32> = LruCache::new(30);
        c.insert("a", 1, 10);
        assert_eq!(c.remove("a"), Some(1));
        assert_eq!(c.bytes(), 0);
        assert!(c.is_empty());
        assert_eq!(c.remove("a"), None);
    }

    #[test]
    fn evict_oldest_pops_lru_and_counts() {
        let c: LruCache<u32> = LruCache::new(100);
        c.insert("a", 1, 10);
        c.insert("b", 2, 10);
        c.get("a"); // "b" is now the LRU entry
        assert_eq!(c.evict_oldest().as_deref(), Some("b"));
        assert_eq!(c.stats().evictions(), 1);
        assert_eq!(c.bytes(), 10);
        assert_eq!(c.evict_oldest().as_deref(), Some("a"));
        assert_eq!(c.evict_oldest(), None, "empty cache is a no-op");
        assert_eq!(c.stats().entries(), 0);
    }

    #[test]
    fn budget_holds_under_key_walk() {
        let c: LruCache<u64> = LruCache::new(64);
        for i in 0..1000u64 {
            c.insert(&format!("k{i}"), i, 8);
            assert!(c.bytes() <= 64);
        }
        assert_eq!(c.len(), 8);
        assert_eq!(c.stats().evictions(), 1000 - 8);
    }
}
