//! Poison-recovering lock helpers — the only sanctioned way to take a
//! mutex in `coordinator/`.
//!
//! `std::sync::Mutex` poisons itself when a thread panics while
//! holding the guard. The serving layer isolates panics per batch
//! ([`catch_unwind`][std::panic::catch_unwind] in `run_batch`) and
//! respawns dead workers, so a poisoned mutex is an *expected, already
//! handled* condition — the data under the lock is counters, queue
//! maps and cache entries whose invariants hold between statements,
//! not mid-panic partial writes. A bare `lock().unwrap()` would turn
//! one isolated panic into a cascade: every later lock attempt
//! panics, every worker dies, and the whole service wedges. These
//! helpers recover the guard instead (`PoisonError::into_inner`), so
//! one fault stays one fault.
//!
//! CI enforces the contract: `./ci.sh` greps `rust/src/coordinator/`
//! and rejects any new bare `lock().unwrap()`.

use std::sync::{Condvar, Mutex, MutexGuard, TryLockError};
use std::time::Duration;

/// Poison-recovering extension methods for [`Mutex`].
pub trait LockExt<T> {
    /// [`Mutex::lock`], recovering the guard from a poisoned mutex
    /// instead of panicking.
    fn plock(&self) -> MutexGuard<'_, T>;

    /// [`Mutex::try_lock`], recovering a poisoned guard; `None` only
    /// when the lock is genuinely contended (`WouldBlock`).
    fn try_plock(&self) -> Option<MutexGuard<'_, T>>;
}

impl<T> LockExt<T> for Mutex<T> {
    fn plock(&self) -> MutexGuard<'_, T> {
        self.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    fn try_plock(&self) -> Option<MutexGuard<'_, T>> {
        match self.try_lock() {
            Ok(guard) => Some(guard),
            Err(TryLockError::Poisoned(poisoned)) => Some(poisoned.into_inner()),
            Err(TryLockError::WouldBlock) => None,
        }
    }
}

/// [`Condvar::wait_timeout`] with poison recovery on the re-acquired
/// guard. The timed-out/notified distinction is dropped on purpose:
/// every caller in the coordinator re-derives its condition from the
/// guarded state after waking (condvar waits are always loop-guarded).
pub fn wait_timeout_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, timeout) {
        Ok((guard, _timed_out)) => guard,
        Err(poisoned) => poisoned.into_inner().0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn plock_recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        // poison it: panic while holding the guard
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison");
        })
        .join();
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        assert_eq!(*m.plock(), 7, "plock must hand back the guarded value");
        *m.plock() = 8;
        assert_eq!(*m.plock(), 8);
    }

    #[test]
    fn try_plock_recovers_poison_but_respects_contention() {
        let m = Arc::new(Mutex::new(1u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison");
        })
        .join();
        assert_eq!(m.try_plock().map(|g| *g), Some(1));
        // held elsewhere -> None (WouldBlock), poisoned or not
        let held = m.plock();
        assert!(m.try_plock().is_none());
        drop(held);
    }

    #[test]
    fn wait_timeout_recover_survives_poisoned_condvar_pair() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = Arc::clone(&pair);
        let _ = std::thread::spawn(move || {
            let _guard = pair2.0.lock().unwrap();
            panic!("poison");
        })
        .join();
        let guard = pair.0.plock();
        let guard = wait_timeout_recover(&pair.1, guard, Duration::from_millis(1));
        assert!(!*guard);
    }
}
