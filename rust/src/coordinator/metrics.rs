//! Service metrics: counters + bounded latency reservoirs, snapshot as
//! JSON.
//!
//! Latency, queue-wait and execution samples go into fixed-capacity
//! [`Reservoir`] rings, not unbounded `Summary` vecs: a long-running
//! server must not grow 24 bytes per request forever, and a snapshot
//! must not clone-and-sort the full request history while holding the
//! mutex. Percentiles are therefore windowed over the most recent
//! `capacity` samples (`latency_total` still counts every request).
//! Benches keep the exact `Summary` type from `util::stats`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::cache::CacheStats;
use super::lock::LockExt;
use crate::error::{TcFftError, ERROR_CODES};
use crate::util::json::Json;
use crate::util::stats::{Reservoir, DEFAULT_RESERVOIR};

/// Service-wide counters and latency reservoirs, snapshot as JSON by
/// the `metrics` TCP op and the tests.
pub struct Metrics {
    /// total submitted requests (accepted or rejected by backpressure;
    /// counted only after routing + shape validation succeed)
    pub requests: AtomicU64,
    /// requests answered successfully
    pub completed: AtomicU64,
    /// requests answered with an execution error
    pub failed: AtomicU64,
    /// executed batches
    pub batches: AtomicU64,
    /// zero-padded batch slots across all executed batches
    pub padded_slots: AtomicU64,
    /// occupied batch slots across all executed batches
    pub busy_slots: AtomicU64,
    /// requests rejected by queue backpressure
    pub rejected: AtomicU64,
    /// requests rejected by the per-client admission quota (these never
    /// reach routing, so they are NOT in `requests`)
    pub quota_rejected: AtomicU64,
    /// requests that resolved to the four-step large-FFT route
    pub large_requests: AtomicU64,
    /// real-input (`Op::Rfft1d`) requests, direct or four-step routed
    pub rfft_requests: AtomicU64,
    /// real-input 2D (`Op::Rfft2d`) requests
    pub rfft2d_requests: AtomicU64,
    /// filter-bank convolution requests (the `submit_convolve` route)
    pub conv_batch_requests: AtomicU64,
    /// ready batches drained from a sibling shard's queues by another
    /// shard's flusher (work stealing)
    pub stolen_batches: AtomicU64,
    /// four-step plans rebuilt transparently at execution time after a
    /// cache eviction raced an in-flight batch
    pub large_rebuilds: AtomicU64,
    /// batches whose execution panicked (the panic was caught and
    /// isolated; every member got an `ExecPanic` reply)
    pub exec_panics: AtomicU64,
    /// exec workers / flushers respawned by the supervisor after dying
    /// to an uncaught panic
    pub worker_restarts: AtomicU64,
    /// requests shed with `DeadlineExceeded` before execution (at flush
    /// time or at batch-assembly time)
    pub deadline_shed: AtomicU64,
    /// error replies by stable code, indexed as [`ERROR_CODES`]
    /// (recorded at every serving-path reject/fail choke point)
    pub errors_by_code: [AtomicU64; ERROR_CODES.len()],
    /// direct-plan cache counters (shared with the service's LruCache)
    pub plan_cache: Arc<CacheStats>,
    /// four-step plan cache counters
    pub large_cache: Arc<CacheStats>,
    /// filter-bank cache counters
    pub bank_cache: Arc<CacheStats>,
    lat: Mutex<Reservoir>,        // end-to-end request latency (s)
    queue_wait: Mutex<Reservoir>, // time spent waiting in the batcher (s)
    exec: Mutex<Reservoir>,       // device execution time per batch (s)
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::with_reservoir(DEFAULT_RESERVOIR)
    }
}

impl Metrics {
    /// Fresh zeroed metrics with the default reservoir capacity.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Fresh zeroed metrics with an explicit per-reservoir sample
    /// capacity (`ServiceConfig::metrics_reservoir`).
    pub fn with_reservoir(capacity: usize) -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            padded_slots: AtomicU64::new(0),
            busy_slots: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            quota_rejected: AtomicU64::new(0),
            large_requests: AtomicU64::new(0),
            rfft_requests: AtomicU64::new(0),
            rfft2d_requests: AtomicU64::new(0),
            conv_batch_requests: AtomicU64::new(0),
            stolen_batches: AtomicU64::new(0),
            large_rebuilds: AtomicU64::new(0),
            exec_panics: AtomicU64::new(0),
            worker_restarts: AtomicU64::new(0),
            deadline_shed: AtomicU64::new(0),
            errors_by_code: std::array::from_fn(|_| AtomicU64::new(0)),
            plan_cache: Arc::new(CacheStats::default()),
            large_cache: Arc::new(CacheStats::default()),
            bank_cache: Arc::new(CacheStats::default()),
            lat: Mutex::new(Reservoir::with_capacity(capacity)),
            queue_wait: Mutex::new(Reservoir::with_capacity(capacity)),
            exec: Mutex::new(Reservoir::with_capacity(capacity)),
        }
    }

    /// Record one end-to-end request latency sample.
    pub fn record_latency(&self, seconds: f64) {
        self.lat.plock().add(seconds);
    }

    /// Record one batcher queue-wait sample.
    pub fn record_queue_wait(&self, seconds: f64) {
        self.queue_wait.plock().add(seconds);
    }

    /// Record one per-batch execution-time sample.
    pub fn record_exec(&self, seconds: f64) {
        self.exec.plock().add(seconds);
    }

    /// Tally one error reply under its stable code (the errors-by-code
    /// section of the snapshot). Call once per *reply sent*, at the
    /// serving-path choke point that produced the error.
    pub fn record_error(&self, e: &TcFftError) {
        self.errors_by_code[e.code_index()].fetch_add(1, Ordering::Relaxed);
    }

    /// Total error replies recorded under `code` (`0` for unknown
    /// codes — keeps test assertions total even if a code is renamed).
    pub fn errors_for(&self, code: &str) -> u64 {
        ERROR_CODES
            .iter()
            .position(|c| *c == code)
            .map(|i| self.errors_by_code[i].load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Samples currently held in the latency reservoir (bounded by its
    /// capacity) and the lifetime sample count.
    pub fn latency_counts(&self) -> (usize, u64) {
        let lat = self.lat.plock();
        (lat.len(), lat.total())
    }

    /// Fraction of executed batch slots that were padding.
    pub fn padding_ratio(&self) -> f64 {
        let pad = self.padded_slots.load(Ordering::Relaxed) as f64;
        let busy = self.busy_slots.load(Ordering::Relaxed) as f64;
        if pad + busy == 0.0 {
            0.0
        } else {
            pad / (pad + busy)
        }
    }

    fn cache_json(stats: &CacheStats) -> Json {
        Json::obj(vec![
            ("hits", Json::num(stats.hits() as f64)),
            ("misses", Json::num(stats.misses() as f64)),
            ("evictions", Json::num(stats.evictions() as f64)),
            ("bytes", Json::num(stats.bytes() as f64)),
            ("entries", Json::num(stats.entries() as f64)),
        ])
    }

    /// One JSON snapshot of every counter and reservoir statistic.
    pub fn snapshot(&self) -> Json {
        let lat = self.lat.plock();
        let qw = self.queue_wait.plock();
        let ex = self.exec.plock();
        let errors = Json::obj(
            ERROR_CODES
                .iter()
                .zip(&self.errors_by_code)
                .map(|(code, n)| (*code, Json::num(n.load(Ordering::Relaxed) as f64)))
                .collect(),
        );
        Json::obj(vec![
            ("requests", Json::num(self.requests.load(Ordering::Relaxed) as f64)),
            ("completed", Json::num(self.completed.load(Ordering::Relaxed) as f64)),
            ("failed", Json::num(self.failed.load(Ordering::Relaxed) as f64)),
            ("rejected", Json::num(self.rejected.load(Ordering::Relaxed) as f64)),
            ("quota_rejected", Json::num(self.quota_rejected.load(Ordering::Relaxed) as f64)),
            ("large_requests", Json::num(self.large_requests.load(Ordering::Relaxed) as f64)),
            ("rfft_requests", Json::num(self.rfft_requests.load(Ordering::Relaxed) as f64)),
            ("rfft2d_requests", Json::num(self.rfft2d_requests.load(Ordering::Relaxed) as f64)),
            (
                "conv_batch_requests",
                Json::num(self.conv_batch_requests.load(Ordering::Relaxed) as f64),
            ),
            ("batches", Json::num(self.batches.load(Ordering::Relaxed) as f64)),
            ("stolen_batches", Json::num(self.stolen_batches.load(Ordering::Relaxed) as f64)),
            ("large_rebuilds", Json::num(self.large_rebuilds.load(Ordering::Relaxed) as f64)),
            ("exec_panics", Json::num(self.exec_panics.load(Ordering::Relaxed) as f64)),
            ("worker_restarts", Json::num(self.worker_restarts.load(Ordering::Relaxed) as f64)),
            ("deadline_shed", Json::num(self.deadline_shed.load(Ordering::Relaxed) as f64)),
            ("errors_by_code", errors),
            ("padding_ratio", Json::num(self.padding_ratio())),
            ("latency_p50_ms", Json::num(lat.median() * 1e3)),
            ("latency_p95_ms", Json::num(lat.p95() * 1e3)),
            ("latency_p99_ms", Json::num(lat.p99() * 1e3)),
            ("latency_mean_ms", Json::num(lat.mean() * 1e3)),
            ("latency_samples", Json::num(lat.len() as f64)),
            ("latency_total", Json::num(lat.total() as f64)),
            ("queue_wait_p50_ms", Json::num(qw.median() * 1e3)),
            ("exec_mean_ms", Json::num(ex.mean() * 1e3)),
            ("plan_cache", Self::cache_json(&self.plan_cache)),
            ("large_cache", Self::cache_json(&self.large_cache)),
            ("bank_cache", Self::cache_json(&self.bank_cache)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_ratio() {
        let m = Metrics::new();
        m.requests.fetch_add(10, Ordering::Relaxed);
        m.busy_slots.fetch_add(6, Ordering::Relaxed);
        m.padded_slots.fetch_add(2, Ordering::Relaxed);
        assert!((m.padding_ratio() - 0.25).abs() < 1e-12);
        m.record_latency(0.010);
        m.record_latency(0.020);
        let snap = m.snapshot();
        assert_eq!(snap.get("requests").unwrap().as_i64(), Some(10));
        let p50 = snap.get("latency_p50_ms").unwrap().as_f64().unwrap();
        assert!((p50 - 15.0).abs() < 1e-9);
    }

    #[test]
    fn empty_ratio_is_zero() {
        assert_eq!(Metrics::new().padding_ratio(), 0.0);
    }

    #[test]
    fn reservoirs_stay_bounded() {
        let m = Metrics::with_reservoir(64);
        for i in 0..1000 {
            m.record_latency(i as f64 * 1e-3);
        }
        let (held, total) = m.latency_counts();
        assert_eq!(held, 64, "reservoir must cap retained samples");
        assert_eq!(total, 1000, "lifetime count must keep every sample");
        let snap = m.snapshot();
        assert_eq!(snap.get("latency_samples").unwrap().as_i64(), Some(64));
        assert_eq!(snap.get("latency_total").unwrap().as_i64(), Some(1000));
        // the window holds the most recent 64 samples (936..999 ms)
        let p50 = snap.get("latency_p50_ms").unwrap().as_f64().unwrap();
        assert!(p50 > 900.0, "windowed p50 {p50} should reflect recent samples");
    }

    #[test]
    fn errors_by_code_tallies_and_snapshots() {
        let m = Metrics::new();
        m.record_error(&TcFftError::DeadlineExceeded);
        m.record_error(&TcFftError::DeadlineExceeded);
        m.record_error(&TcFftError::ExecPanic("boom".into()));
        m.record_error(&TcFftError::QueueFull);
        assert_eq!(m.errors_for("deadline_exceeded"), 2);
        assert_eq!(m.errors_for("exec_panic"), 1);
        assert_eq!(m.errors_for("queue_full"), 1);
        assert_eq!(m.errors_for("bad_size"), 0);
        assert_eq!(m.errors_for("not_a_code"), 0);
        let snap = m.snapshot();
        let errs = snap.get("errors_by_code").unwrap();
        assert_eq!(errs.get("deadline_exceeded").unwrap().as_i64(), Some(2));
        assert_eq!(errs.get("exec_panic").unwrap().as_i64(), Some(1));
        // every stable code appears, even at zero
        for code in ERROR_CODES {
            assert!(errs.get(code).is_some(), "missing code {code}");
        }
        assert_eq!(snap.get("exec_panics").unwrap().as_i64(), Some(0));
        assert_eq!(snap.get("worker_restarts").unwrap().as_i64(), Some(0));
        assert_eq!(snap.get("deadline_shed").unwrap().as_i64(), Some(0));
    }

    #[test]
    fn snapshot_carries_cache_sections() {
        let m = Metrics::new();
        m.plan_cache.hits.fetch_add(3, Ordering::Relaxed);
        let snap = m.snapshot();
        let pc = snap.get("plan_cache").unwrap();
        assert_eq!(pc.get("hits").unwrap().as_i64(), Some(3));
        assert!(snap.get("large_cache").is_some());
        assert!(snap.get("bank_cache").is_some());
    }
}
