//! Service metrics: counters + latency histograms, snapshot as JSON.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Service-wide counters and latency summaries, snapshot as JSON by
/// the `metrics` TCP op and the tests.
#[derive(Default)]
pub struct Metrics {
    /// total submitted requests (accepted or rejected)
    pub requests: AtomicU64,
    /// requests answered successfully
    pub completed: AtomicU64,
    /// requests answered with an execution error
    pub failed: AtomicU64,
    /// executed batches
    pub batches: AtomicU64,
    /// zero-padded batch slots across all executed batches
    pub padded_slots: AtomicU64,
    /// occupied batch slots across all executed batches
    pub busy_slots: AtomicU64,
    /// requests rejected by queue backpressure
    pub rejected: AtomicU64,
    /// requests that resolved to the four-step large-FFT route
    pub large_requests: AtomicU64,
    /// real-input (`Op::Rfft1d`) requests, direct or four-step routed
    pub rfft_requests: AtomicU64,
    /// real-input 2D (`Op::Rfft2d`) requests
    pub rfft2d_requests: AtomicU64,
    /// filter-bank convolution requests (the `submit_convolve` route)
    pub conv_batch_requests: AtomicU64,
    lat: Mutex<Summary>,        // end-to-end request latency (s)
    queue_wait: Mutex<Summary>, // time spent waiting in the batcher (s)
    exec: Mutex<Summary>,       // device execution time per batch (s)
}

impl Metrics {
    /// Fresh zeroed metrics.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one end-to-end request latency sample.
    pub fn record_latency(&self, seconds: f64) {
        self.lat.lock().unwrap().add(seconds);
    }

    /// Record one batcher queue-wait sample.
    pub fn record_queue_wait(&self, seconds: f64) {
        self.queue_wait.lock().unwrap().add(seconds);
    }

    /// Record one per-batch execution-time sample.
    pub fn record_exec(&self, seconds: f64) {
        self.exec.lock().unwrap().add(seconds);
    }

    /// Fraction of executed batch slots that were padding.
    pub fn padding_ratio(&self) -> f64 {
        let pad = self.padded_slots.load(Ordering::Relaxed) as f64;
        let busy = self.busy_slots.load(Ordering::Relaxed) as f64;
        if pad + busy == 0.0 {
            0.0
        } else {
            pad / (pad + busy)
        }
    }

    /// One JSON snapshot of every counter and summary statistic.
    pub fn snapshot(&self) -> Json {
        let lat = self.lat.lock().unwrap();
        let qw = self.queue_wait.lock().unwrap();
        let ex = self.exec.lock().unwrap();
        Json::obj(vec![
            ("requests", Json::num(self.requests.load(Ordering::Relaxed) as f64)),
            ("completed", Json::num(self.completed.load(Ordering::Relaxed) as f64)),
            ("failed", Json::num(self.failed.load(Ordering::Relaxed) as f64)),
            ("rejected", Json::num(self.rejected.load(Ordering::Relaxed) as f64)),
            ("large_requests", Json::num(self.large_requests.load(Ordering::Relaxed) as f64)),
            ("rfft_requests", Json::num(self.rfft_requests.load(Ordering::Relaxed) as f64)),
            ("rfft2d_requests", Json::num(self.rfft2d_requests.load(Ordering::Relaxed) as f64)),
            (
                "conv_batch_requests",
                Json::num(self.conv_batch_requests.load(Ordering::Relaxed) as f64),
            ),
            ("batches", Json::num(self.batches.load(Ordering::Relaxed) as f64)),
            ("padding_ratio", Json::num(self.padding_ratio())),
            ("latency_p50_ms", Json::num(lat.median() * 1e3)),
            ("latency_p99_ms", Json::num(lat.p99() * 1e3)),
            ("latency_mean_ms", Json::num(lat.mean() * 1e3)),
            ("queue_wait_p50_ms", Json::num(qw.median() * 1e3)),
            ("exec_mean_ms", Json::num(ex.mean() * 1e3)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_ratio() {
        let m = Metrics::new();
        m.requests.fetch_add(10, Ordering::Relaxed);
        m.busy_slots.fetch_add(6, Ordering::Relaxed);
        m.padded_slots.fetch_add(2, Ordering::Relaxed);
        assert!((m.padding_ratio() - 0.25).abs() < 1e-12);
        m.record_latency(0.010);
        m.record_latency(0.020);
        let snap = m.snapshot();
        assert_eq!(snap.get("requests").unwrap().as_i64(), Some(10));
        let p50 = snap.get("latency_p50_ms").unwrap().as_f64().unwrap();
        assert!((p50 - 15.0).abs() < 1e-9);
    }

    #[test]
    fn empty_ratio_is_zero() {
        assert_eq!(Metrics::new().padding_ratio(), 0.0);
    }
}
