//! Deterministic fault injection for the serving layer.
//!
//! Robustness claims are only as good as the faults they were tested
//! against, and ad-hoc "kill a thread and see" experiments do not
//! reproduce. [`FaultInjector`] makes the failure modes the coordinator
//! defends against *injectable on a schedule*:
//!
//! - **exec panic** — panic inside batch execution for every Nth batch
//!   whose queue key matches a pattern, exercising `catch_unwind`
//!   isolation and `ExecPanic` fan-out;
//! - **worker kill** — panic *outside* the isolation boundary after a
//!   worker finishes a batch, exercising supervisor respawn
//!   (`worker_restarts`);
//! - **exec delay** — artificial pre-execution sleep with a seeded
//!   probability, exercising deadline shedding and bounded waits;
//! - **forced cache eviction** — pop the LRU plan every Nth batch,
//!   exercising the eviction-rebuild path under load;
//! - **TCP frame chop** — split a reply frame into two partial writes,
//!   exercising client-side reassembly.
//!
//! Counting faults (`panic_every`, `kill_worker_every`, `evict_every`)
//! are fully deterministic: global atomic counters, independent of
//! thread interleaving, so a chaos test can assert *exact* injected
//! totals against `exec_panics` / `worker_restarts`. Probabilistic
//! faults (`exec_delay_prob`, `chop_prob`) draw from one
//! [`SplitMix64`] seeded stream — reproducible per seed up to thread
//! scheduling. The default plan is a no-op and the hot path pays a
//! single `bool` load when no faults are configured.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, Once};
use std::time::Duration;

use super::lock::LockExt;
use crate::util::rng::SplitMix64;

/// Marker embedded in every injected panic payload. The quiet panic
/// hook ([`install_quiet_panic_hook`]) suppresses the default stderr
/// backtrace for payloads carrying this tag so a 100-panic chaos soak
/// does not drown test output; real (non-injected) panics still print.
pub const INJECTED_PANIC_TAG: &str = "[chaos-injected]";

/// What to inject and when. `Default` is a complete no-op.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// seed for the probabilistic faults' random stream
    pub seed: u64,
    /// panic inside batch execution on every Nth batch whose queue key
    /// contains [`panic_key_pattern`](Self::panic_key_pattern)
    /// (`0` = never)
    pub panic_every: u64,
    /// substring of the queue key that arms `panic_every` (empty
    /// matches every key)
    pub panic_key_pattern: String,
    /// stop injecting exec panics after this many (`0` = unlimited)
    pub panic_limit: u64,
    /// kill the exec worker thread (panic OUTSIDE the batch isolation
    /// boundary) after every Nth worker-executed batch (`0` = never)
    pub kill_worker_every: u64,
    /// stop killing workers after this many (`0` = unlimited)
    pub kill_worker_limit: u64,
    /// artificial delay inserted before batch execution...
    pub exec_delay: Duration,
    /// ...with this probability per batch (`0.0` = never)
    pub exec_delay_prob: f64,
    /// force one LRU eviction from the direct-plan cache every Nth
    /// executed batch (`0` = never)
    pub evict_every: u64,
    /// probability a TCP reply frame is chopped into two partial
    /// writes with a flush between (`0.0` = never)
    pub chop_prob: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0x7c3a_11e5,
            panic_every: 0,
            panic_key_pattern: String::new(),
            panic_limit: 0,
            kill_worker_every: 0,
            kill_worker_limit: 0,
            exec_delay: Duration::ZERO,
            exec_delay_prob: 0.0,
            evict_every: 0,
            chop_prob: 0.0,
        }
    }
}

impl FaultPlan {
    fn is_noop(&self) -> bool {
        self.panic_every == 0
            && self.kill_worker_every == 0
            && self.exec_delay_prob <= 0.0
            && self.evict_every == 0
            && self.chop_prob <= 0.0
    }
}

/// Scheduled fault source shared by the service, its workers, and the
/// TCP server (`ServiceConfig::faults`). All methods are cheap no-ops
/// when the plan is empty.
#[derive(Debug)]
pub struct FaultInjector {
    active: bool,
    plan: FaultPlan,
    panic_matches: AtomicU64,
    panics_injected: AtomicU64,
    worker_batches: AtomicU64,
    kills_injected: AtomicU64,
    exec_batches: AtomicU64,
    evicts_forced: AtomicU64,
    delays_injected: AtomicU64,
    chops_injected: AtomicU64,
    rng: Mutex<SplitMix64>,
}

impl Default for FaultInjector {
    fn default() -> Self {
        FaultInjector::disabled()
    }
}

impl FaultInjector {
    /// The no-op injector every production config carries by default.
    pub fn disabled() -> FaultInjector {
        FaultInjector::new(FaultPlan::default())
    }

    /// Injector following `plan`.
    pub fn new(plan: FaultPlan) -> FaultInjector {
        FaultInjector {
            active: !plan.is_noop(),
            rng: Mutex::new(SplitMix64::new(plan.seed)),
            plan,
            panic_matches: AtomicU64::new(0),
            panics_injected: AtomicU64::new(0),
            worker_batches: AtomicU64::new(0),
            kills_injected: AtomicU64::new(0),
            exec_batches: AtomicU64::new(0),
            evicts_forced: AtomicU64::new(0),
            delays_injected: AtomicU64::new(0),
            chops_injected: AtomicU64::new(0),
        }
    }

    /// True when any fault is scheduled (one branch on the hot path).
    pub fn is_active(&self) -> bool {
        self.active
    }

    /// The plan this injector follows.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    fn chance(&self, p: f64) -> bool {
        if p <= 0.0 {
            return false;
        }
        if p >= 1.0 {
            return true;
        }
        self.rng.plock().next_f64() < p
    }

    /// Call at the top of batch execution, INSIDE the `catch_unwind`
    /// boundary. May sleep (`exec_delay`) and may panic
    /// (`panic_every`); an injected panic is tagged with
    /// [`INJECTED_PANIC_TAG`] and must surface to every batch member
    /// as `ExecPanic`.
    pub fn before_exec(&self, queue_key: &str) {
        if !self.active {
            return;
        }
        if self.chance(self.plan.exec_delay_prob) {
            self.delays_injected.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(self.plan.exec_delay);
        }
        if self.plan.panic_every > 0 && queue_key.contains(&self.plan.panic_key_pattern) {
            let nth = self.panic_matches.fetch_add(1, Ordering::Relaxed) + 1;
            if nth % self.plan.panic_every == 0 {
                // reserve a slot under the limit atomically so
                // concurrent workers never overshoot it
                let mine = self.panics_injected.fetch_add(1, Ordering::Relaxed) + 1;
                if self.plan.panic_limit == 0 || mine <= self.plan.panic_limit {
                    panic!("{INJECTED_PANIC_TAG} exec panic #{mine} (batch key {queue_key})");
                }
                self.panics_injected.fetch_sub(1, Ordering::Relaxed);
            }
        }
    }

    /// Call from the exec-worker loop after a batch completes, OUTSIDE
    /// the batch isolation boundary — never from the inline-exec
    /// (leader) path, where the "worker" is a client thread. An
    /// injected panic here kills the worker thread so the supervisor's
    /// respawn path is exercised.
    pub fn after_worker_batch(&self) {
        if !self.active || self.plan.kill_worker_every == 0 {
            return;
        }
        let nth = self.worker_batches.fetch_add(1, Ordering::Relaxed) + 1;
        if nth % self.plan.kill_worker_every == 0 {
            let mine = self.kills_injected.fetch_add(1, Ordering::Relaxed) + 1;
            if self.plan.kill_worker_limit == 0 || mine <= self.plan.kill_worker_limit {
                panic!("{INJECTED_PANIC_TAG} worker kill #{mine}");
            }
            self.kills_injected.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Should this executed batch force one LRU eviction from the plan
    /// cache? Counted per executed batch, deterministic.
    pub fn should_force_evict(&self) -> bool {
        if !self.active || self.plan.evict_every == 0 {
            return false;
        }
        let nth = self.exec_batches.fetch_add(1, Ordering::Relaxed) + 1;
        let fire = nth % self.plan.evict_every == 0;
        if fire {
            self.evicts_forced.fetch_add(1, Ordering::Relaxed);
        }
        fire
    }

    /// Should this TCP reply frame be chopped into two partial writes?
    pub fn should_chop(&self) -> bool {
        if !self.active {
            return false;
        }
        let fire = self.chance(self.plan.chop_prob);
        if fire {
            self.chops_injected.fetch_add(1, Ordering::Relaxed);
        }
        fire
    }

    /// Exec panics injected so far (== expected `exec_panics` metric).
    pub fn panics_injected(&self) -> u64 {
        let n = self.panics_injected.load(Ordering::Relaxed);
        if self.plan.panic_limit > 0 {
            n.min(self.plan.panic_limit)
        } else {
            n
        }
    }

    /// Worker kills injected so far (== expected `worker_restarts`
    /// from this fault; flusher restarts add on top).
    pub fn kills_injected(&self) -> u64 {
        let n = self.kills_injected.load(Ordering::Relaxed);
        if self.plan.kill_worker_limit > 0 {
            n.min(self.plan.kill_worker_limit)
        } else {
            n
        }
    }

    /// Forced evictions fired so far.
    pub fn evicts_forced(&self) -> u64 {
        self.evicts_forced.load(Ordering::Relaxed)
    }

    /// Artificial delays inserted so far.
    pub fn delays_injected(&self) -> u64 {
        self.delays_injected.load(Ordering::Relaxed)
    }

    /// Reply frames chopped so far.
    pub fn chops_injected(&self) -> u64 {
        self.chops_injected.load(Ordering::Relaxed)
    }
}

/// Install (once, process-wide) a panic hook that suppresses the
/// default report for panics tagged [`INJECTED_PANIC_TAG`], chaining
/// to the previous hook for everything else. Chaos tests and
/// `serve_demo --chaos` call this so hundreds of *expected* panics do
/// not bury real output; untagged panics keep the standard report.
pub fn install_quiet_panic_hook() {
    static INSTALL: Once = Once::new();
    INSTALL.call_once(|| {
        let previous = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let tagged = info
                .payload()
                .downcast_ref::<String>()
                .map(|s| s.contains(INJECTED_PANIC_TAG))
                .or_else(|| {
                    info.payload()
                        .downcast_ref::<&str>()
                        .map(|s| s.contains(INJECTED_PANIC_TAG))
                })
                .unwrap_or(false);
            if !tagged {
                previous(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_inert() {
        let f = FaultInjector::disabled();
        assert!(!f.is_active());
        f.before_exec("fft1d:n=4096:tc:fwd"); // must not panic
        f.after_worker_batch();
        assert!(!f.should_force_evict());
        assert!(!f.should_chop());
        assert_eq!(f.panics_injected(), 0);
        assert_eq!(f.kills_injected(), 0);
    }

    #[test]
    fn panics_on_schedule_for_matching_keys() {
        install_quiet_panic_hook();
        let f = FaultInjector::new(FaultPlan {
            panic_every: 3,
            panic_key_pattern: "n=4096".into(),
            panic_limit: 2,
            ..FaultPlan::default()
        });
        assert!(f.is_active());
        let mut panicked = 0;
        for i in 0..12 {
            let key = if i % 2 == 0 { "fft1d:n=4096:tc:fwd" } else { "fft1d:n=64:tc:fwd" };
            let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                f.before_exec(key);
            }))
            .is_err();
            if hit {
                panicked += 1;
                assert_eq!(i % 2, 0, "only matching keys may panic");
            }
        }
        // 6 matching batches, every 3rd panics -> 2; limit 2 also caps it
        assert_eq!(panicked, 2);
        assert_eq!(f.panics_injected(), 2);
    }

    #[test]
    fn panic_limit_is_respected_and_counters_stay_exact() {
        install_quiet_panic_hook();
        let f = FaultInjector::new(FaultPlan {
            panic_every: 1,
            panic_limit: 4,
            ..FaultPlan::default()
        });
        let mut panicked = 0;
        for _ in 0..50 {
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f.before_exec("k")))
                .is_err()
            {
                panicked += 1;
            }
        }
        assert_eq!(panicked, 4);
        assert_eq!(f.panics_injected(), 4);
    }

    #[test]
    fn worker_kills_fire_on_their_own_schedule() {
        install_quiet_panic_hook();
        let f = FaultInjector::new(FaultPlan {
            kill_worker_every: 5,
            kill_worker_limit: 2,
            ..FaultPlan::default()
        });
        let mut killed = 0;
        for _ in 0..30 {
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f.after_worker_batch()))
                .is_err()
            {
                killed += 1;
            }
        }
        assert_eq!(killed, 2);
        assert_eq!(f.kills_injected(), 2);
    }

    #[test]
    fn evictions_count_batches_deterministically() {
        let f = FaultInjector::new(FaultPlan { evict_every: 4, ..FaultPlan::default() });
        let fired: Vec<bool> = (0..8).map(|_| f.should_force_evict()).collect();
        assert_eq!(fired, [false, false, false, true, false, false, false, true]);
        assert_eq!(f.evicts_forced(), 2);
    }

    #[test]
    fn chop_probability_extremes() {
        let always = FaultInjector::new(FaultPlan { chop_prob: 1.0, ..FaultPlan::default() });
        let never = FaultInjector::new(FaultPlan { chop_prob: 1.0, ..FaultPlan::default() });
        assert!(always.should_chop());
        assert_eq!(always.chops_injected(), 1);
        // active via chop_prob, but other faults must stay quiet
        never.before_exec("any");
        assert!(!never.should_force_evict());
    }

    #[test]
    fn delay_fires_with_certainty_probability() {
        let f = FaultInjector::new(FaultPlan {
            exec_delay: Duration::from_millis(1),
            exec_delay_prob: 1.0,
            ..FaultPlan::default()
        });
        let t0 = std::time::Instant::now();
        f.before_exec("k");
        assert!(t0.elapsed() >= Duration::from_millis(1));
        assert_eq!(f.delays_injected(), 1);
    }

    #[test]
    fn seeded_chance_is_reproducible() {
        let a = FaultInjector::new(FaultPlan { chop_prob: 0.5, seed: 9, ..FaultPlan::default() });
        let b = FaultInjector::new(FaultPlan { chop_prob: 0.5, seed: 9, ..FaultPlan::default() });
        let sa: Vec<bool> = (0..64).map(|_| a.should_chop()).collect();
        let sb: Vec<bool> = (0..64).map(|_| b.should_chop()).collect();
        assert_eq!(sa, sb, "same seed must give the same fault schedule");
        assert!(sa.iter().any(|x| *x) && sa.iter().any(|x| !*x));
    }
}
