//! The FFT service: router + dynamic batcher + execution scheduler.
//!
//! Architecture (vLLM-router-like, on OS threads since the offline
//! image has no tokio):
//!
//! ```text
//!   clients ──submit()──> [router: plan cache] ──> per-plan queues
//!                │                                     │
//!                │ (leader: batch filled?  run inline) │
//!                │                                     │
//!                └──> event-driven flusher (deadline) ─┤
//!                                                      │
//!                          execution pool ──> PJRT engine (thread-safe)
//!                                                      │
//!                              replies via per-request channels
//! ```

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::error::{Result, TcFftError};

use super::batcher::{Pending, PlanQueue, ReadyBatch};
use super::metrics::Metrics;
use crate::large::{FourStepConfig, FourStepPlan, RealFourStepPlan};
use crate::plan::{Direction, Plan};
use crate::runtime::{PlanarBatch, Runtime};
use crate::workload::SpectralConv;

/// A logical FFT request (one sequence).
#[derive(Clone, Debug)]
pub struct FftRequest {
    /// transform kind and size
    pub op: Op,
    /// algorithm variant (`"tc"` | `"tc_split"` | `"r2"`)
    pub algo: String,
    /// forward or (unnormalized) inverse
    pub direction: Direction,
    /// planar input, shape [n] (1D), [nx, ny] (2D), [n] real rows
    /// (R2C forward) or [n/2 + 1] packed bins (C2R inverse)
    pub input: PlanarBatch,
}

/// The transform kinds the service routes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    /// Batched 1D complex transform of length `n`.
    Fft1d {
        /// transform length (power of two)
        n: usize,
    },
    /// Batched 2D complex transform, row-major `nx` x `ny`.
    Fft2d {
        /// first (strided) axis length
        nx: usize,
        /// second (contiguous) axis length
        ny: usize,
    },
    /// Batched real-input 1D transform of length `n`: R2C forward
    /// (real rows in, Hermitian-packed `n/2 + 1` bins out) or C2R
    /// inverse, selected by [`FftRequest::direction`].
    Rfft1d {
        /// real transform length (power of two)
        n: usize,
    },
    /// Batched real-input 2D transform, row-major `nx` x `ny`: R2C
    /// forward (`[nx, ny]` real fields in, packed `[nx, ny/2 + 1]`
    /// Hermitian spectra out) or C2R inverse (the mirror image, scaled
    /// by `nx * ny`), selected by [`FftRequest::direction`]. Served by
    /// the catalog only — sizes without an `rfft2d` artifact fail fast
    /// (there is no 2D four-step route).
    Rfft2d {
        /// first (strided) axis length
        nx: usize,
        /// second (contiguous, packed) axis length
        ny: usize,
    },
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// max time a request waits for batchmates before a padded flush
    pub max_wait: Duration,
    /// per-plan queue bound (backpressure)
    pub max_queue: usize,
    /// execution pool size (overlaps marshalling with PJRT execution)
    pub exec_threads: usize,
    /// legacy flusher scan period — ignored since the flusher became
    /// deadline-driven (it now parks until the earliest pending
    /// deadline instead of polling); kept so existing configs build
    pub tick: Duration,
    /// leader execution: the submit() call that fills a batch runs it
    /// inline on the submitting thread, skipping two thread hand-offs
    /// (perf iteration 4). Deadline flushes still go through the pool.
    pub inline_exec: bool,
    /// batch capacity of the four-step large-FFT queues (`Op::Fft1d` /
    /// `Op::Rfft1d` sizes with no direct artifact). Flushed unpadded —
    /// the batched engines take any row count, and a padded
    /// 2^20-point slot would burn a whole transform's worth of work on
    /// zeros.
    pub large_batch: usize,
    /// largest size the four-step route will serve. Plans are cached
    /// per (n, algo, dir) and never evicted, and each costs O(n)
    /// twiddle memory — this bound keeps a client walking the size
    /// space from ballooning the cache.
    pub max_large_n: usize,
    /// most filter banks that may be registered. Banks are cached and
    /// never evicted (each holds k packed spectra, O(k*n) memory), and
    /// `register_bank` is reachable over TCP — without this cap a
    /// client minting fresh names could exhaust memory.
    pub max_banks: usize,
    /// most filters one bank may hold (bounds both the registration
    /// cost — k R2C transforms run synchronously — and the resident
    /// spectra).
    pub max_bank_filters: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_wait: Duration::from_millis(2),
            max_queue: 1024,
            // PJRT executions are thread-safe, but on the CPU backend
            // concurrent executes contend for the same Eigen pool and
            // lose ~2x (measured, EXPERIMENTS.md SPerf iteration 3) —
            // default to one execution worker; raise on real multi-die
            // hardware
            exec_threads: 1,
            tick: Duration::from_micros(200),
            inline_exec: true,
            large_batch: 4,
            max_large_n: 1 << 24,
            max_banks: 64,
            max_bank_filters: 64,
        }
    }
}

/// Handle for one submitted request.
pub struct Ticket {
    /// service-assigned request id (monotonic)
    pub id: u64,
    rx: mpsc::Receiver<Result<PlanarBatch>>,
}

impl Ticket {
    /// Block until the transform completes.
    pub fn wait(self) -> Result<PlanarBatch> {
        self.rx
            .recv()
            .map_err(|_| TcFftError::msg("service dropped the request"))?
    }

    /// [`wait`](Self::wait) with a timeout.
    pub fn wait_timeout(self, d: Duration) -> Result<PlanarBatch> {
        match self.rx.recv_timeout(d) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => Err(TcFftError::msg("request timed out")),
            Err(_) => Err(TcFftError::msg("service dropped the request")),
        }
    }
}

/// How a request executes: through a direct artifact plan, or through
/// the batched four-step engine for sizes with no artifact. Carries
/// only what `submit` needs to queue the request (key, batch capacity,
/// expected per-request shape tail).
enum Route {
    Direct { key: String, capacity: usize, tail: Vec<usize> },
    Large { key: String, tail: Vec<usize> },
}

/// A cached batch-executing engine behind a queue key: the complex
/// four-step engine, its real-input (R2C/C2R) wrapper, or a registered
/// spectral filter bank. All execute whole `PlanarBatch`es, so
/// `run_batch` dispatches them uniformly.
#[derive(Clone)]
enum LargePlan {
    Complex(Arc<FourStepPlan>),
    Real(Arc<RealFourStepPlan>),
    Conv(Arc<SpectralConv>),
}

impl LargePlan {
    fn execute_batch(&self, rt: &Runtime, input: PlanarBatch) -> Result<PlanarBatch> {
        match self {
            LargePlan::Complex(p) => p.execute_batch(rt, input),
            LargePlan::Real(p) => p.execute_batch(rt, input),
            LargePlan::Conv(c) => c.convolve_batch(rt, input),
        }
    }
}

struct Shared {
    queues: Mutex<HashMap<String, PlanQueue>>,
    /// signalled when a request is enqueued; the flusher parks on this
    /// instead of polling (perf iteration 5: a 200 us polling loop
    /// stole cycles from XLA's execution pool and slowed device time
    /// by ~15%)
    pending_cv: std::sync::Condvar,
    plans: Mutex<HashMap<String, Plan>>,
    /// cached four-step plans for large sizes, keyed by the queue key
    /// (`4step:{n}:{algo}:{dir}` complex, `4stepr:...` real).
    /// `run_batch` consults this map to decide whether a ready batch
    /// executes through a batched four-step engine or directly through
    /// the runtime.
    large_plans: Mutex<HashMap<String, LargePlan>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    shutting_down: AtomicBool,
    cfg: ServiceConfig,
}

/// Collect all due batches (queue lock held only while draining).
fn collect_due(shared: &Shared, force: bool) -> Vec<(String, ReadyBatch)> {
    let now = Instant::now();
    let mut ready = Vec::new();
    let mut queues = shared.queues.lock().unwrap();
    for q in queues.values_mut() {
        loop {
            let due = if force {
                !q.is_empty()
            } else {
                q.should_flush(now, shared.cfg.max_wait)
            };
            if !due {
                break;
            }
            match q.flush() {
                Some(b) => ready.push((q.key.clone(), b)),
                None => break,
            }
        }
    }
    ready
}

/// Scan all queues and ship due batches to the execution pool.
fn flush_due(shared: &Shared, tx: &mpsc::Sender<(String, ReadyBatch)>, force: bool) {
    for item in collect_due(shared, force) {
        let _ = tx.send(item);
    }
}

fn run_batch(rt: &Runtime, shared: &Shared, key: &str, batch: ReadyBatch) {
    shared.metrics.batches.fetch_add(1, Ordering::Relaxed);
    shared
        .metrics
        .busy_slots
        .fetch_add(batch.members.len() as u64, Ordering::Relaxed);
    shared
        .metrics
        .padded_slots
        .fetch_add(batch.padded as u64, Ordering::Relaxed);
    // four-step queues execute through the cached batched engine; every
    // other key is a direct artifact execution
    let large = shared.large_plans.lock().unwrap().get(key).cloned();
    let t_exec = Instant::now();
    let result = match large {
        Some(plan) => plan.execute_batch(rt, batch.input),
        None => rt.execute(key, batch.input).map(|(out, _stats)| out),
    };
    let exec_s = t_exec.elapsed().as_secs_f64();
    shared.metrics.record_exec(exec_s);
    match result {
        Ok(out) => {
            let now = Instant::now();
            for (i, m) in batch.members.iter().enumerate() {
                let row = out.slice_rows(i, i + 1);
                shared
                    .metrics
                    .record_latency(now.duration_since(m.enqueued).as_secs_f64());
                shared
                    .metrics
                    .record_queue_wait(t_exec.duration_since(m.enqueued).as_secs_f64());
                shared.metrics.completed.fetch_add(1, Ordering::Relaxed);
                let _ = m.reply.send(Ok(row));
            }
        }
        Err(e) => {
            for m in &batch.members {
                shared.metrics.failed.fetch_add(1, Ordering::Relaxed);
                let _ = m
                    .reply
                    .send(Err(TcFftError::msg(format!("batch execution failed: {e}"))));
            }
        }
    }
}

/// The FFT service. Create with [`FftService::start`].
pub struct FftService {
    rt: Arc<Runtime>,
    shared: Arc<Shared>,
    batch_tx: mpsc::Sender<(String, ReadyBatch)>,
    flusher: Mutex<Option<thread::JoinHandle<()>>>,
    exec_threads: Mutex<Vec<thread::JoinHandle<()>>>,
}

impl FftService {
    /// Spawn the service threads (flusher + execution workers) over a
    /// runtime. Shut down with [`shutdown`](Self::shutdown) or by
    /// dropping the service.
    pub fn start(rt: Arc<Runtime>, cfg: ServiceConfig) -> FftService {
        let shared = Arc::new(Shared {
            queues: Mutex::new(HashMap::new()),
            pending_cv: std::sync::Condvar::new(),
            plans: Mutex::new(HashMap::new()),
            large_plans: Mutex::new(HashMap::new()),
            metrics: Arc::new(Metrics::new()),
            next_id: AtomicU64::new(1),
            shutting_down: AtomicBool::new(false),
            cfg,
        });
        let (batch_tx, batch_rx) = mpsc::channel::<(String, ReadyBatch)>();

        // execution workers: drain ready batches onto the PJRT actor
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let n_exec = shared.cfg.exec_threads;
        let exec_threads = (0..n_exec)
            .map(|i| {
                let rx = Arc::clone(&batch_rx);
                let rt2 = Arc::clone(&rt);
                let sh = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("tcfft-exec-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Err(_) => break,
                            Ok((key, batch)) => run_batch(&rt2, &sh, &key, batch),
                        }
                    })
                    .expect("spawn exec worker")
            })
            .collect();

        // flusher thread: owns only Shared + the batch sender (no Arc
        // cycle with the service)
        let sh = Arc::clone(&shared);
        let tx = batch_tx.clone();
        let flusher = thread::Builder::new()
            .name("tcfft-flusher".into())
            .spawn(move || {
                // Deadline-driven: flush everything already due, THEN
                // park until the earliest pending deadline (the pre-PR
                // flusher slept a full tick before flushing, taxing
                // batches already past max_wait with up to a tick of
                // extra latency). The park is capped so shutdown stays
                // responsive and floored so a deadline landing mid-scan
                // cannot spin the thread.
                const PARK_CAP: Duration = Duration::from_millis(20);
                const PARK_FLOOR: Duration = Duration::from_micros(50);
                while !sh.shutting_down.load(Ordering::SeqCst) {
                    flush_due(&sh, &tx, false);
                    let now = Instant::now();
                    let guard = sh.queues.lock().unwrap();
                    let next_deadline = guard
                        .values()
                        .filter_map(|q| q.oldest_age(now))
                        .map(|age| sh.cfg.max_wait.saturating_sub(age))
                        .min();
                    let park = next_deadline.unwrap_or(PARK_CAP).min(PARK_CAP).max(PARK_FLOOR);
                    let _ = sh.pending_cv.wait_timeout(guard, park).unwrap();
                }
                flush_due(&sh, &tx, true); // final drain
            })
            .expect("spawn flusher");

        FftService {
            rt,
            shared,
            batch_tx,
            flusher: Mutex::new(Some(flusher)),
            exec_threads: Mutex::new(exec_threads),
        }
    }

    /// The service's live metrics (counters + latency summaries).
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.shared.metrics)
    }

    /// The runtime the service executes on.
    pub fn runtime(&self) -> Arc<Runtime> {
        Arc::clone(&self.rt)
    }

    /// Resolve (and cache) the plan for a request shape.
    fn plan_for(&self, req: &FftRequest) -> Result<Plan> {
        let inverse = req.direction == Direction::Inverse;
        let cache_key = match req.op {
            Op::Fft1d { n } => format!("1d:{n}:{}:{}", req.algo, inverse),
            Op::Fft2d { nx, ny } => format!("2d:{nx}x{ny}:{}:{}", req.algo, inverse),
            Op::Rfft1d { n } => format!("r1d:{n}:{}:{}", req.algo, inverse),
            Op::Rfft2d { nx, ny } => format!("r2d:{nx}x{ny}:{}:{}", req.algo, inverse),
        };
        {
            let plans = self.shared.plans.lock().unwrap();
            if let Some(p) = plans.get(&cache_key) {
                return Ok(p.clone());
            }
        }
        let plan = match req.op {
            Op::Fft1d { n } => {
                Plan::fft1d_algo(&self.rt.registry, n, 1, &req.algo, req.direction)?
            }
            Op::Fft2d { nx, ny } => {
                Plan::fft2d_algo(&self.rt.registry, nx, ny, 1, &req.algo, req.direction)?
            }
            Op::Rfft1d { n } => {
                Plan::rfft1d_algo(&self.rt.registry, n, 1, &req.algo, req.direction)?
            }
            Op::Rfft2d { nx, ny } => {
                Plan::rfft2d_algo(&self.rt.registry, nx, ny, 1, &req.algo, req.direction)?
            }
        };
        self.shared
            .plans
            .lock()
            .unwrap()
            .insert(cache_key, plan.clone());
        Ok(plan)
    }

    /// Resolve a request to its execution route: a direct artifact
    /// plan, or — for `Op::Fft1d` / `Op::Rfft1d` power-of-two sizes
    /// with no artifact — a cached four-step large-FFT plan (paper
    /// Sec 3.1; the real wrapper for `Rfft1d`). `Op::Fft2d` and
    /// `Op::Rfft2d` have no large route and fail fast beyond the
    /// catalog.
    fn route_for(&self, req: &FftRequest) -> Result<Route> {
        match self.plan_for(req) {
            Ok(plan) => Ok(Route::Direct {
                key: plan.meta.key,
                capacity: plan.meta.batch,
                tail: plan.meta.input_shape[1..].to_vec(),
            }),
            Err(TcFftError::NoArtifact(reason)) => match req.op {
                Op::Fft1d { n }
                    if n.is_power_of_two() && n >= 4 && n <= self.shared.cfg.max_large_n =>
                {
                    self.large_route_for(n, req)
                }
                Op::Rfft1d { n }
                    if n.is_power_of_two() && n >= 8 && n <= self.shared.cfg.max_large_n =>
                {
                    self.large_route_for(n, req)
                }
                _ => Err(TcFftError::NoArtifact(reason)),
            },
            Err(e) => Err(e),
        }
    }

    /// Find or build the cached four-step plan for (op, n, algo, dir).
    fn large_route_for(&self, n: usize, req: &FftRequest) -> Result<Route> {
        // Only known algos may mint cache entries: plans cost megabytes
        // of twiddle tables and are never evicted, so an unvalidated
        // string from the TCP surface must not grow `large_plans` (and
        // a typo should fail loudly, like the direct-artifact path,
        // instead of silently computing with the tc fallback).
        if !matches!(req.algo.as_str(), "tc" | "tc_split" | "r2") {
            return Err(TcFftError::NoArtifact(format!(
                "n={n} algo={} (unknown algo has no four-step route)",
                req.algo
            )));
        }
        let inverse = req.direction == Direction::Inverse;
        let real = matches!(req.op, Op::Rfft1d { .. });
        let dir = if inverse { "inv" } else { "fwd" };
        let key = if real {
            format!("4stepr:{n}:{}:{dir}", req.algo)
        } else {
            format!("4step:{n}:{}:{dir}", req.algo)
        };
        // the per-request shape the submit path validates against:
        // C2R consumes packed spectra, everything else full rows
        let tail = if real && inverse { vec![n / 2 + 1] } else { vec![n] };
        {
            let cache = self.shared.large_plans.lock().unwrap();
            if cache.contains_key(&key) {
                return Ok(Route::Large { key, tail });
            }
        }
        // build outside the lock (twiddle precompute is real work);
        // a racing builder just loses to or_insert
        let cfg = FourStepConfig { algo: req.algo.clone(), ..FourStepConfig::default() };
        let plan = if real {
            LargePlan::Real(Arc::new(RealFourStepPlan::with_config(&self.rt, n, inverse, cfg)?))
        } else {
            LargePlan::Complex(Arc::new(FourStepPlan::with_config(&self.rt, n, inverse, cfg)?))
        };
        self.shared
            .large_plans
            .lock()
            .unwrap()
            .entry(key.clone())
            .or_insert(plan);
        Ok(Route::Large { key, tail })
    }

    /// Submit one request; returns a ticket to wait on.
    pub fn submit(&self, req: FftRequest) -> Result<Ticket> {
        if self.shared.shutting_down.load(Ordering::SeqCst) {
            return Err(TcFftError::ShuttingDown);
        }
        let route = self.route_for(&req)?;
        self.shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
        match req.op {
            Op::Rfft1d { .. } => {
                self.shared.metrics.rfft_requests.fetch_add(1, Ordering::Relaxed);
            }
            Op::Rfft2d { .. } => {
                self.shared.metrics.rfft2d_requests.fetch_add(1, Ordering::Relaxed);
            }
            _ => {}
        }

        // normalize input to [1, ...]
        let mut shape = vec![1usize];
        shape.extend_from_slice(&req.input.shape);
        let input = PlanarBatch { re: req.input.re, im: req.input.im, shape };
        let (queue_key, capacity, pad) = match &route {
            Route::Direct { key, capacity, tail } => {
                crate::ensure!(
                    input.shape[1..] == tail[..],
                    "request shape {:?} does not match plan {:?}",
                    &input.shape[1..],
                    &tail[..]
                );
                (key.clone(), *capacity, true)
            }
            Route::Large { key, tail } => {
                crate::ensure!(
                    input.shape[1..] == tail[..],
                    "request shape {:?} does not match four-step tail {:?}",
                    &input.shape[1..],
                    &tail[..]
                );
                self.shared.metrics.large_requests.fetch_add(1, Ordering::Relaxed);
                (key.clone(), self.shared.cfg.large_batch.max(1), false)
            }
        };
        self.enqueue(queue_key, capacity, pad, input)
    }

    /// Shared enqueue tail of [`submit`](Self::submit) and
    /// [`submit_convolve`](Self::submit_convolve): queue the pending
    /// request (backpressure-bounded) and run the leader-execution /
    /// opportunistic-flush policy.
    fn enqueue(
        &self,
        queue_key: String,
        capacity: usize,
        pad: bool,
        input: PlanarBatch,
    ) -> Result<Ticket> {
        let id = self.shared.next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = mpsc::channel();
        let pending = Pending { id, input, enqueued: Instant::now(), reply: tx };
        let mut full_queue = false;
        {
            let mut queues = self.shared.queues.lock().unwrap();
            let q = queues.entry(queue_key.clone()).or_insert_with(|| {
                if pad {
                    PlanQueue::new(queue_key.clone(), capacity, self.shared.cfg.max_queue)
                } else {
                    PlanQueue::unpadded(queue_key.clone(), capacity, self.shared.cfg.max_queue)
                }
            });
            if let Err(reject) = q.push(pending) {
                full_queue = true;
                self.shared.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                let _ = reject.reply.send(Err(TcFftError::QueueFull));
            }
            self.shared.pending_cv.notify_one();
        }
        if !full_queue {
            if self.shared.cfg.inline_exec {
                // leader execution: if this submit filled a batch, run it
                // here and now — no hand-off, no wakeups
                let ready = collect_due(&self.shared, false);
                for (key, batch) in ready {
                    run_batch(&self.rt, &self.shared, &key, batch);
                }
            } else {
                // opportunistic flush for full batches (next tick would
                // add latency)
                flush_due(&self.shared, &self.batch_tx, false);
            }
        }
        Ok(Ticket { id, rx })
    }

    /// Register a named spectral filter bank for the batched convolve
    /// route: `k` FIR filters over real length-`n` signals, prepared
    /// once (one batched R2C over the taps) and applied to queued
    /// signals by [`submit_convolve`](Self::submit_convolve).
    ///
    /// Registration is guarded like the four-step route, because banks
    /// are cached, never evicted, and reachable over TCP: only known
    /// algos (`tc` | `tc_split` | `r2`), `n` a power of two within
    /// `ServiceConfig::max_large_n`, at most
    /// `ServiceConfig::max_bank_filters` filters per bank and
    /// `ServiceConfig::max_banks` banks total (each bank holds `k`
    /// packed spectra and its registration runs `k` R2C transforms
    /// synchronously), and a name that is not already taken
    /// (re-registering under a live queue key would let
    /// differently-shaped requests meet in one batch). Returns the
    /// filter count `k`.
    pub fn register_filter_bank<T: AsRef<[f32]>>(
        &self,
        name: &str,
        n: usize,
        filters: &[T],
        algo: &str,
    ) -> Result<usize> {
        crate::ensure!(
            !name.is_empty() && name.len() <= 64,
            "bank name must be 1..=64 characters"
        );
        if !matches!(algo, "tc" | "tc_split" | "r2") {
            return Err(TcFftError::NoArtifact(format!(
                "filter bank '{name}': unknown algo '{algo}'"
            )));
        }
        crate::ensure!(
            n.is_power_of_two() && n >= 4 && n <= self.shared.cfg.max_large_n,
            "filter bank '{name}': n={n} outside the served range"
        );
        crate::ensure!(
            filters.len() <= self.shared.cfg.max_bank_filters,
            "filter bank '{name}': {} filters over the {} cap",
            filters.len(),
            self.shared.cfg.max_bank_filters
        );
        let key = format!("conv:{name}");
        {
            let cache = self.shared.large_plans.lock().unwrap();
            crate::ensure!(!cache.contains_key(&key), "filter bank '{name}' already registered");
            let banks = cache.keys().filter(|b| b.starts_with("conv:")).count();
            crate::ensure!(
                banks < self.shared.cfg.max_banks,
                "filter bank '{name}': bank cap ({}) reached",
                self.shared.cfg.max_banks
            );
        }
        // build outside the lock (k R2C transforms of the taps); the
        // re-checks under the lock below catch racing registrations
        let bank = Arc::new(SpectralConv::new_bank_algo(&self.rt, n, filters, algo)?);
        let k = bank.k();
        let mut cache = self.shared.large_plans.lock().unwrap();
        crate::ensure!(!cache.contains_key(&key), "filter bank '{name}' already registered");
        let banks = cache.keys().filter(|b| b.starts_with("conv:")).count();
        crate::ensure!(
            banks < self.shared.cfg.max_banks,
            "filter bank '{name}': bank cap ({}) reached",
            self.shared.cfg.max_banks
        );
        cache.insert(key, LargePlan::Conv(bank));
        Ok(k)
    }

    /// The registered bank's (n, k), if any — the TCP front end uses
    /// this to validate request shapes before queuing.
    pub fn filter_bank_shape(&self, name: &str) -> Option<(usize, usize)> {
        let cache = self.shared.large_plans.lock().unwrap();
        match cache.get(&format!("conv:{name}")) {
            Some(LargePlan::Conv(c)) => Some((c.n(), c.k())),
            _ => None,
        }
    }

    /// Submit one real signal (shape `[n]`) to a registered filter
    /// bank. Replies carry shape `[1, k, n]` — every filter's output
    /// for the signal, at unit scale. Requests ride the same bounded
    /// unpadded queues as the four-step route (the bank's
    /// `convolve_batch` takes any row count), so backpressure
    /// (`QueueFull`) and batching behave identically.
    pub fn submit_convolve(&self, bank: &str, input: PlanarBatch) -> Result<Ticket> {
        if self.shared.shutting_down.load(Ordering::SeqCst) {
            return Err(TcFftError::ShuttingDown);
        }
        let key = format!("conv:{bank}");
        let n = {
            let cache = self.shared.large_plans.lock().unwrap();
            match cache.get(&key) {
                Some(LargePlan::Conv(c)) => c.n(),
                _ => {
                    return Err(TcFftError::NoArtifact(format!(
                        "no filter bank named '{bank}' is registered"
                    )))
                }
            }
        };
        let mut shape = vec![1usize];
        shape.extend_from_slice(&input.shape);
        let input = PlanarBatch { re: input.re, im: input.im, shape };
        crate::ensure!(
            input.shape[1..] == [n],
            "convolve request shape {:?} does not match bank signal length [{n}]",
            &input.shape[1..]
        );
        // count only requests that actually reach a queue, mirroring
        // submit()'s routed-then-counted ordering
        self.shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.shared.metrics.conv_batch_requests.fetch_add(1, Ordering::Relaxed);
        self.enqueue(key, self.shared.cfg.large_batch.max(1), false, input)
    }

    /// Convenience: blocking filter-bank convolution of a (possibly
    /// multi-row) real batch `[b, n]`; returns `[b, k, n]`.
    pub fn convolve_blocking(&self, bank: &str, x: PlanarBatch) -> Result<PlanarBatch> {
        crate::ensure!(x.shape.len() == 2, "expected [b, n]");
        self.blocking_rows_with(x, |input| self.submit_convolve(bank, input))
    }

    /// Shared body of every blocking helper: submit each row of `x`
    /// through `submit_row` (shape = the batch tail), wait in row
    /// order, and concatenate the replies.
    fn blocking_rows_with(
        &self,
        x: PlanarBatch,
        submit_row: impl Fn(PlanarBatch) -> Result<Ticket>,
    ) -> Result<PlanarBatch> {
        let rows = x.shape[0];
        let tail = x.shape[1..].to_vec();
        let mut tickets = Vec::new();
        for r in 0..rows {
            let row = x.slice_rows(r, r + 1);
            tickets.push(submit_row(PlanarBatch { re: row.re, im: row.im, shape: tail.clone() })?);
        }
        let outs = tickets
            .into_iter()
            .map(|t| t.wait())
            .collect::<Result<Vec<_>>>()?;
        Ok(PlanarBatch::concat(&outs))
    }

    /// [`blocking_rows_with`](Self::blocking_rows_with) for transform
    /// requests: each row becomes its own [`FftRequest`].
    fn blocking_rows(
        &self,
        x: PlanarBatch,
        op: Op,
        algo: &str,
        dir: Direction,
    ) -> Result<PlanarBatch> {
        self.blocking_rows_with(x, |input| {
            self.submit(FftRequest { op, algo: algo.to_string(), direction: dir, input })
        })
    }

    /// Convenience: blocking 1D transform of a (possibly multi-row) batch.
    pub fn fft1d_blocking(
        &self,
        x: PlanarBatch,
        algo: &str,
        dir: Direction,
    ) -> Result<PlanarBatch> {
        let n = *x.shape.last().unwrap();
        self.blocking_rows(x, Op::Fft1d { n }, algo, dir)
    }

    /// Convenience: blocking real 1D transform of a (possibly
    /// multi-row) batch — R2C forward (`[b, n]` real rows in,
    /// `[b, n/2 + 1]` packed spectra out) or C2R inverse (the mirror
    /// image, output scaled by `n`).
    pub fn rfft1d_blocking(
        &self,
        x: PlanarBatch,
        algo: &str,
        dir: Direction,
    ) -> Result<PlanarBatch> {
        crate::ensure!(x.shape.len() == 2, "expected [b, len]");
        let len = x.shape[1];
        let n = if dir == Direction::Inverse {
            crate::ensure!(len >= 2, "packed spectrum needs at least 2 bins, got {len}");
            2 * (len - 1)
        } else {
            len
        };
        self.blocking_rows(x, Op::Rfft1d { n }, algo, dir)
    }

    /// Convenience: blocking real 2D transform of a (possibly
    /// multi-row) batch — R2C forward (`[b, nx, ny]` real fields in,
    /// `[b, nx, ny/2 + 1]` packed spectra out) or C2R inverse (the
    /// mirror image, output scaled by `nx * ny`). The inverse infers
    /// `ny` from the packed tail: `ny = 2 * (bins - 1)`.
    pub fn rfft2d_blocking(
        &self,
        x: PlanarBatch,
        algo: &str,
        dir: Direction,
    ) -> Result<PlanarBatch> {
        crate::ensure!(x.shape.len() == 3, "expected [b, nx, tail]");
        let nx = x.shape[1];
        let ny = if dir == Direction::Inverse {
            let bins = x.shape[2];
            crate::ensure!(bins >= 2, "packed spectrum needs at least 2 bins per row, got {bins}");
            2 * (bins - 1)
        } else {
            x.shape[2]
        };
        self.blocking_rows(x, Op::Rfft2d { nx, ny }, algo, dir)
    }

    /// Same for 2D.
    pub fn fft2d_blocking(
        &self,
        x: PlanarBatch,
        algo: &str,
        dir: Direction,
    ) -> Result<PlanarBatch> {
        crate::ensure!(x.shape.len() == 3, "expected [b, nx, ny]");
        let (nx, ny) = (x.shape[1], x.shape[2]);
        self.blocking_rows(x, Op::Fft2d { nx, ny }, algo, dir)
    }

    /// Graceful shutdown: drain queues, stop threads.
    pub fn shutdown(&self) {
        self.shared.shutting_down.store(true, Ordering::SeqCst);
        if let Some(j) = self.flusher.lock().unwrap().take() {
            let _ = j.join();
        }
    }
}

impl Drop for FftService {
    fn drop(&mut self) {
        self.shutdown();
        // closing batch_tx by replacing it ends the exec workers
        let (dead_tx, _) = mpsc::channel();
        self.batch_tx = dead_tx;
        for j in self.exec_threads.lock().unwrap().drain(..) {
            let _ = j.join();
        }
    }
}
