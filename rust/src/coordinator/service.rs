//! The FFT service: sharded router + dynamic batcher + execution
//! scheduler.
//!
//! Architecture (vLLM-router-like, on OS threads since the offline
//! image has no tokio):
//!
//! ```text
//!   clients ──submit()/submit_as()──> [quota gate] ──> [router: plan caches]
//!                │                                          │
//!                │              hash(queue key) picks a shard
//!                │                                          │
//!            ┌── shard 0 ──┐  ┌── shard 1 ──┐ ... ┌── shard N-1 ──┐
//!            │ queues + cv │  │ queues + cv │     │ queues + cv   │
//!            │ flusher ────┼──┼─ work-steals due batches ─────────┤
//!            │ exec pool   │  │ exec pool   │     │ exec pool     │
//!            └──────┬──────┘  └──────┬──────┘     └──────┬────────┘
//!                   └────────> PJRT engine (thread-safe) <┘
//!                                      │
//!                      replies via per-request channels
//! ```
//!
//! Each shard owns its queue map, condvar, deadline flusher and exec
//! workers; requests hash to a shard by queue key, so one plan's queue
//! always lives on one shard (batches never fragment). Flushers steal
//! due batches from sibling shards so a loaded shard's deadline work
//! drains even while its own flusher is parked or behind.
//!
//! All three plan stores — direct plans, four-step large plans and
//! registered filter banks — are byte-budgeted LRU caches keyed by
//! deterministic content fingerprints (`{descriptor}#{fnv1a64}`), with
//! hit/miss/eviction counters in the metrics snapshot. An evicted
//! four-step plan is rebuilt transparently at execution time from its
//! own key; an evicted filter bank must be re-registered (its taps are
//! client content the service cannot reconstruct).
//!
//! ## Fault tolerance
//!
//! Batch execution is panic-isolated: `run_batch` wraps the engine
//! call in `catch_unwind`, so a panicking kernel produces one
//! [`TcFftError::ExecPanic`] reply per batch member instead of a dead
//! worker and hung tickets. Workers and flushers that die to a panic
//! *outside* that boundary are respawned by a supervisor thread
//! (metrics `worker_restarts`). Every request carries an end-to-end
//! deadline ([`ServiceConfig::request_deadline`]) shed at flush time
//! and again at batch-assembly time, so an expired request is answered
//! `DeadlineExceeded` promptly rather than executed late. All locks go
//! through the poison-recovering [`super::lock`] helpers.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::error::{Result, TcFftError};

use super::batcher::{drain_due, Pending, PlanQueue, ReadyBatch};
use super::cache::LruCache;
use super::faults::FaultInjector;
use super::lock::{wait_timeout_recover, LockExt};
use super::metrics::Metrics;
use super::quota::QuotaGate;
use crate::large::{FourStepConfig, FourStepPlan, Plan2d, RealFourStepPlan};
use crate::plan::{Direction, Plan};
use crate::runtime::{PlanarBatch, Runtime};
use crate::util::fnv::{fnv1a64, Fnv1a};
use crate::workload::SpectralConv;

/// A logical FFT request (one sequence).
#[derive(Clone, Debug)]
pub struct FftRequest {
    /// transform kind and size
    pub op: Op,
    /// algorithm variant (`"tc"` | `"tc_split"` | `"tc_ec"` | `"r2"`)
    pub algo: String,
    /// forward or (unnormalized) inverse
    pub direction: Direction,
    /// planar input, shape [n] (1D), [nx, ny] (2D), [n] real rows
    /// (R2C forward) or [n/2 + 1] packed bins (C2R inverse)
    pub input: PlanarBatch,
}

/// The transform kinds the service routes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    /// Batched 1D complex transform of length `n`.
    Fft1d {
        /// transform length (power of two)
        n: usize,
    },
    /// Batched 2D complex transform, row-major `nx` x `ny`.
    Fft2d {
        /// first (strided) axis length
        nx: usize,
        /// second (contiguous) axis length
        ny: usize,
    },
    /// Batched real-input 1D transform of length `n`: R2C forward
    /// (real rows in, Hermitian-packed `n/2 + 1` bins out) or C2R
    /// inverse, selected by [`FftRequest::direction`].
    Rfft1d {
        /// real transform length (power of two)
        n: usize,
    },
    /// Batched real-input 2D transform, row-major `nx` x `ny`: R2C
    /// forward (`[nx, ny]` real fields in, packed `[nx, ny/2 + 1]`
    /// Hermitian spectra out) or C2R inverse (the mirror image, scaled
    /// by `nx * ny`), selected by [`FftRequest::direction`]. Sizes
    /// with an `rfft2d` artifact route direct; power-of-two sides in
    /// [`LARGE_2D_MIN_SIDE`]..=[`LARGE_2D_MAX_SIDE`] whose area fits
    /// `ServiceConfig::max_large_n` route to a cached
    /// [`Plan2d`](crate::large::Plan2d) four-step composition;
    /// everything else fails fast with a `no_artifact` error naming
    /// both sets of limits.
    Rfft2d {
        /// first (strided) axis length
        nx: usize,
        /// second (contiguous, packed) axis length
        ny: usize,
    },
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// max time a request waits for batchmates before a padded flush
    pub max_wait: Duration,
    /// per-plan queue bound (backpressure)
    pub max_queue: usize,
    /// execution workers PER SHARD (overlaps marshalling with PJRT
    /// execution; the engine is thread-safe)
    pub exec_threads: usize,
    /// legacy flusher scan period — ignored since the flusher became
    /// deadline-driven (it now parks until the earliest pending
    /// deadline instead of polling); kept so existing configs build
    pub tick: Duration,
    /// leader execution: the submit() call that fills a batch runs it
    /// here and now on the submitting thread, skipping two thread
    /// hand-offs (perf iteration 4). Deadline flushes still go through
    /// the shard pools.
    pub inline_exec: bool,
    /// batch capacity of the four-step large-FFT queues (`Op::Fft1d` /
    /// `Op::Rfft1d` / `Op::Rfft2d` sizes with no direct artifact).
    /// Flushed unpadded —
    /// the batched engines take any row count, and a padded
    /// 2^20-point slot would burn a whole transform's worth of work on
    /// zeros.
    pub large_batch: usize,
    /// largest size the four-step route will serve (bounds the cost of
    /// building any single plan; the byte budget below bounds the
    /// aggregate)
    pub max_large_n: usize,
    /// most filters one bank may hold (bounds the registration cost —
    /// `k` R2C transforms run synchronously on the registering thread)
    pub max_bank_filters: usize,
    /// number of independent service shards (queue maps + flushers +
    /// exec pools); requests hash to a shard by queue key
    pub shards: usize,
    /// upper bound on a flusher's park between deadline scans; also
    /// the worst-case latency for noticing shutdown from a fully idle
    /// park (shutdown additionally notifies every shard's condvar)
    pub park_cap: Duration,
    /// byte budget of the direct-plan cache (metadata-sized entries)
    pub plan_cache_bytes: usize,
    /// byte budget of the four-step plan cache (each plan holds O(n)
    /// twiddles + scratch; evicted plans rebuild transparently)
    pub large_cache_bytes: usize,
    /// byte budget of the filter-bank cache (each bank holds `k`
    /// packed spectra; evicted banks must be re-registered)
    pub bank_cache_bytes: usize,
    /// per-client admission quota: sustained requests/sec per client
    /// id. `<= 0` disables admission control (the default) — quota
    /// applies only to `submit_as`/`submit_convolve_as` callers with a
    /// client id (the TCP front end tags each connection)
    pub quota_rate: f64,
    /// token-bucket burst size per client (max requests admitted
    /// back-to-back before the rate limit bites)
    pub quota_burst: f64,
    /// per-reservoir sample capacity of the metrics windows
    pub metrics_reservoir: usize,
    /// end-to-end deadline stamped into every request at submit time.
    /// Expired requests are shed with `DeadlineExceeded` at flush time
    /// and again just before execution — never executed late. `None`
    /// disables expiry (requests wait forever, the pre-PR-7 behavior)
    pub request_deadline: Option<Duration>,
    /// scheduled fault injection (chaos tests, `serve_demo --chaos`);
    /// the default injector is inert and costs one branch per batch
    pub faults: Arc<FaultInjector>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_wait: Duration::from_millis(2),
            max_queue: 1024,
            // PJRT executions are thread-safe, but on the CPU backend
            // concurrent executes contend for the same Eigen pool and
            // lose ~2x (measured, EXPERIMENTS.md SPerf iteration 3) —
            // default to one execution worker per shard; raise on real
            // multi-die hardware
            exec_threads: 1,
            tick: Duration::from_micros(200),
            inline_exec: true,
            large_batch: 4,
            max_large_n: 1 << 24,
            max_bank_filters: 64,
            shards: 4,
            park_cap: Duration::from_millis(20),
            plan_cache_bytes: 1 << 20,
            large_cache_bytes: 512 << 20,
            bank_cache_bytes: 64 << 20,
            quota_rate: 0.0,
            quota_burst: 32.0,
            metrics_reservoir: crate::util::stats::DEFAULT_RESERVOIR,
            // generous production default: far above any sane batch
            // latency (a 2^24 four-step transform completes in
            // seconds), tight enough that a wedged batch releases its
            // clients rather than holding them forever
            request_deadline: Some(Duration::from_secs(30)),
            faults: Arc::new(FaultInjector::disabled()),
        }
    }
}

/// Handle for one submitted request.
pub struct Ticket {
    /// service-assigned request id (monotonic)
    pub id: u64,
    rx: mpsc::Receiver<Result<PlanarBatch>>,
}

impl Ticket {
    /// Block until the transform completes. `Dropped` if the service
    /// tore down the reply channel without answering.
    pub fn wait(self) -> Result<PlanarBatch> {
        self.rx.recv().map_err(|_| TcFftError::Dropped)?
    }

    /// [`wait`](Self::wait) with a timeout: `DeadlineExceeded` if no
    /// reply arrived in time (the request may still execute; its reply
    /// is discarded), `Dropped` on a torn-down channel.
    pub fn wait_timeout(self, d: Duration) -> Result<PlanarBatch> {
        match self.rx.recv_timeout(d) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => Err(TcFftError::DeadlineExceeded),
            Err(_) => Err(TcFftError::Dropped),
        }
    }
}

/// How a request executes: through a direct artifact plan, or through
/// the batched four-step engine for sizes with no artifact. Carries
/// only what `submit` needs to queue the request (key, batch capacity,
/// expected per-request shape tail).
enum Route {
    Direct { key: String, capacity: usize, tail: Vec<usize> },
    Large { key: String, tail: Vec<usize> },
}

/// A cached batch-executing four-step engine behind a queue key: the
/// complex engine, its real-input (R2C/C2R) wrapper, or the 2D
/// row/column composition. Filter banks live in their own cache
/// (`Shared::banks`).
#[derive(Clone)]
enum LargePlan {
    Complex(Arc<FourStepPlan>),
    Real(Arc<RealFourStepPlan>),
    Plan2d(Arc<Plan2d>),
}

impl LargePlan {
    fn execute_batch(&self, rt: &Runtime, input: PlanarBatch) -> Result<PlanarBatch> {
        match self {
            LargePlan::Complex(p) => p.execute_batch(rt, input),
            LargePlan::Real(p) => p.execute_batch(rt, input),
            LargePlan::Plan2d(p) => p.execute_batch(rt, input),
        }
    }

    fn memory_bytes(&self) -> usize {
        match self {
            LargePlan::Complex(p) => p.memory_bytes(),
            LargePlan::Real(p) => p.memory_bytes(),
            LargePlan::Plan2d(p) => p.memory_bytes(),
        }
    }
}

/// Smallest image side the large-2D `rfft2d` route serves: below this
/// the catalog ladder (squares 8x8..256x256 plus 64x128/128x64) is the
/// intended path, and the four-step composition's per-plan cost is not
/// worth caching.
pub const LARGE_2D_MIN_SIDE: usize = 512;

/// Largest image side the large-2D `rfft2d` route serves (the paper's
/// top 2D evaluation scale). The area guard
/// (`ServiceConfig::max_large_n`) additionally bounds `nx * ny`, so
/// serving 16k x 16k requires raising that knob too.
pub const LARGE_2D_MAX_SIDE: usize = 16384;

/// A registered filter bank plus the content fingerprint that makes
/// re-registration idempotent (same name + same content = same bank).
#[derive(Clone)]
struct BankEntry {
    conv: Arc<SpectralConv>,
    fingerprint: u64,
}

/// One service shard: its own queue map and wakeup condvar. The
/// shard's flusher parks on `pending_cv`; `enqueue` and `shutdown`
/// notify it.
struct Shard {
    queues: Mutex<HashMap<String, PlanQueue>>,
    pending_cv: Condvar,
}

struct Shared {
    shards: Vec<Shard>,
    /// direct-plan cache (artifact-bound `Plan`s, metadata-sized)
    plans: LruCache<Plan>,
    /// four-step plan cache, keyed `4step:{n}:{algo}:{dir}#{fp}`
    /// (complex) / `4stepr:...` (real). `run_batch` consults this to
    /// decide whether a ready batch executes through a batched
    /// four-step engine or directly through the runtime — and rebuilds
    /// the plan from its key on a post-eviction miss.
    large_plans: LruCache<LargePlan>,
    /// registered filter banks, keyed `conv:{name}`
    banks: LruCache<BankEntry>,
    quota: QuotaGate,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    shutting_down: AtomicBool,
    cfg: ServiceConfig,
}

impl Shared {
    /// The shard a queue key lives on (stable hash, so every request
    /// for one plan always lands on the same shard's queues).
    fn shard_for(&self, key: &str) -> usize {
        (fnv1a64(key.as_bytes()) % self.shards.len() as u64) as usize
    }
}

/// Suffix a human-readable cache descriptor with its own FNV-1a 64
/// fingerprint — the deterministic content-fingerprint key contract:
/// the same descriptor always mints the same key, across processes and
/// across an eviction/rebuild cycle.
fn fingerprint_key(desc: &str) -> String {
    format!("{desc}#{:016x}", fnv1a64(desc.as_bytes()))
}

/// Reply `DeadlineExceeded` to requests shed from the queues. Always
/// called OUTSIDE the shard lock (reply channels are unbounded sends,
/// but metrics and the client wakeup need not serialize queue access).
fn shed_replies(shared: &Shared, shed: Vec<Pending>) {
    for m in shed {
        shared.metrics.deadline_shed.fetch_add(1, Ordering::Relaxed);
        reply_error(shared, &m, TcFftError::DeadlineExceeded);
    }
}

/// Send one error reply, keeping the failure counters consistent.
fn reply_error(shared: &Shared, m: &Pending, e: TcFftError) {
    shared.metrics.failed.fetch_add(1, Ordering::Relaxed);
    shared.metrics.record_error(&e);
    let _ = m.reply.send(Err(e));
}

/// Drain every due batch from one shard (`force` drains everything),
/// answering deadline-shed requests on the way out.
fn collect_due_shard(shared: &Shared, si: usize, force: bool) -> Vec<(String, ReadyBatch)> {
    let (ready, shed) = {
        let mut queues = shared.shards[si].queues.plock();
        drain_due(&mut queues, Instant::now(), shared.cfg.max_wait, force)
    };
    shed_replies(shared, shed);
    ready
}

/// Rebuild an evicted four-step plan from its queue key (the key IS
/// the plan descriptor — that is what the fingerprint-key contract
/// buys) and re-insert it.
fn rebuild_large(rt: &Runtime, shared: &Shared, key: &str) -> Result<LargePlan> {
    let desc = key.split('#').next().unwrap_or(key);
    let parts: Vec<&str> = desc.split(':').collect();
    crate::ensure!(parts.len() == 4, "malformed four-step queue key '{key}'");
    let inverse = parts[3] == "inv";
    let cfg = FourStepConfig { algo: parts[2].to_string(), ..FourStepConfig::default() };
    let plan = match parts[0] {
        "4stepr" => {
            let n: usize = parts[1].parse()?;
            LargePlan::Real(Arc::new(RealFourStepPlan::with_config(rt, n, inverse, cfg)?))
        }
        "4step2d" => {
            let (sx, sy) = parts[1].split_once('x').ok_or_else(|| {
                TcFftError::msg(format!("malformed 2D four-step queue key '{key}'"))
            })?;
            let (nx, ny) = (sx.parse::<usize>()?, sy.parse::<usize>()?);
            LargePlan::Plan2d(Arc::new(Plan2d::with_config(rt, nx, ny, inverse, cfg)?))
        }
        _ => {
            let n: usize = parts[1].parse()?;
            LargePlan::Complex(Arc::new(FourStepPlan::with_config(rt, n, inverse, cfg)?))
        }
    };
    shared.metrics.large_rebuilds.fetch_add(1, Ordering::Relaxed);
    let bytes = plan.memory_bytes();
    let (plan, _inserted) = shared.large_plans.get_or_insert(key, plan, bytes);
    Ok(plan)
}

/// Execute a ready batch through whatever its key routes to: a filter
/// bank, a four-step engine (rebuilt transparently if evicted), or a
/// direct artifact.
fn execute_routed(
    rt: &Runtime,
    shared: &Shared,
    key: &str,
    input: PlanarBatch,
) -> Result<PlanarBatch> {
    if let Some(name) = key.strip_prefix("conv:") {
        let entry = shared.banks.get(key).ok_or_else(|| {
            TcFftError::NoArtifact(format!(
                "filter bank '{name}' was evicted from the bank cache; re-register it"
            ))
        })?;
        // Re-validate at execution time: the bank may have been
        // evicted and re-registered with a different signal length
        // while these requests sat in the queue.
        crate::ensure!(
            input.shape.len() == 2 && input.shape[1] == entry.conv.n(),
            "queued convolve batch shape {:?} no longer matches bank '{name}' (n = {})",
            input.shape,
            entry.conv.n()
        );
        return entry.conv.convolve_batch(rt, input);
    }
    if key.starts_with("4step") {
        let plan = match shared.large_plans.get(key) {
            Some(p) => p,
            None => rebuild_large(rt, shared, key)?,
        };
        return plan.execute_batch(rt, input);
    }
    rt.execute(key, input).map(|(out, _stats)| out)
}

/// Render a caught panic payload for the `ExecPanic` reply.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Execute one ready batch and reply to every member exactly once.
///
/// This is the panic-isolation boundary: the engine call (plus any
/// injected faults) runs under `catch_unwind`, so a panicking kernel
/// becomes one `ExecPanic` reply per member — no dropped senders, no
/// hung `Ticket::wait`, and the calling thread (exec worker OR
/// inline-exec client thread) survives. Members whose deadline passed
/// while the batch was assembled are answered `DeadlineExceeded`
/// up front; their rows ride along as padding-equivalent work unless
/// the whole batch expired, in which case execution is skipped.
fn run_batch(rt: &Runtime, shared: &Shared, key: &str, batch: ReadyBatch) {
    let ReadyBatch { input, members, padded } = batch;
    shared.metrics.batches.fetch_add(1, Ordering::Relaxed);
    shared
        .metrics
        .busy_slots
        .fetch_add(members.len() as u64, Ordering::Relaxed);
    shared
        .metrics
        .padded_slots
        .fetch_add(padded as u64, Ordering::Relaxed);
    // pre-execution shed: the flush-time shed cannot catch a deadline
    // that expires between assembly and this worker picking the batch
    // up (queue backlog, injected delay)
    let now = Instant::now();
    let expired: Vec<bool> = members.iter().map(|m| m.expired(now)).collect();
    for (m, _) in members.iter().zip(&expired).filter(|(_, ex)| **ex) {
        shared.metrics.deadline_shed.fetch_add(1, Ordering::Relaxed);
        reply_error(shared, m, TcFftError::DeadlineExceeded);
    }
    if expired.iter().all(|ex| *ex) {
        return;
    }
    let faults = &shared.cfg.faults;
    let t_exec = Instant::now();
    let result = catch_unwind(AssertUnwindSafe(|| {
        if faults.is_active() {
            faults.before_exec(key);
        }
        execute_routed(rt, shared, key, input)
    }));
    let exec_s = t_exec.elapsed().as_secs_f64();
    shared.metrics.record_exec(exec_s);
    if faults.is_active() && faults.should_force_evict() {
        // chaos: evict the coldest plan of whichever store serves this
        // key, forcing the rebuild / re-register recovery path
        if key.starts_with("4step") {
            let _ = shared.large_plans.evict_oldest();
        } else {
            let _ = shared.plans.evict_oldest();
        }
    }
    let result = match result {
        Ok(r) => r,
        Err(payload) => {
            shared.metrics.exec_panics.fetch_add(1, Ordering::Relaxed);
            Err(TcFftError::ExecPanic(panic_message(payload.as_ref())))
        }
    };
    match result {
        Ok(out) => {
            let now = Instant::now();
            for (i, m) in members.iter().enumerate() {
                if expired[i] {
                    continue;
                }
                let row = out.slice_rows(i, i + 1);
                shared
                    .metrics
                    .record_latency(now.duration_since(m.enqueued).as_secs_f64());
                shared
                    .metrics
                    .record_queue_wait(t_exec.duration_since(m.enqueued).as_secs_f64());
                shared.metrics.completed.fetch_add(1, Ordering::Relaxed);
                let _ = m.reply.send(Ok(row));
            }
        }
        Err(e) => {
            // the typed error (with its stable code) fans out to every
            // live member — ExecPanic and engine errors alike
            for (i, m) in members.iter().enumerate() {
                if expired[i] {
                    continue;
                }
                reply_error(shared, m, e.clone());
            }
        }
    }
}

/// One shard's flusher loop: flush own due batches, steal due batches
/// from sibling shards, park until the earliest pending deadline.
fn flusher_loop(sh: &Shared, si: usize, tx: &mpsc::Sender<(String, ReadyBatch)>) {
    const PARK_FLOOR: Duration = Duration::from_micros(50);
    let n = sh.shards.len();
    while !sh.shutting_down.load(Ordering::SeqCst) {
        for item in collect_due_shard(sh, si, false) {
            let _ = tx.send(item);
        }
        // Work stealing: drain due batches a sibling's flusher has not
        // picked up yet (it may be parked, or behind on a burst) into
        // THIS shard's exec channel. try_lock only — if the sibling's
        // own flusher or a leader holds the lock, the work is already
        // being handled. Never holds two queue locks at once.
        for j in (0..n).filter(|&j| j != si) {
            let (stolen, shed) = {
                let mut queues = match sh.shards[j].queues.try_plock() {
                    Some(guard) => guard,
                    None => continue,
                };
                drain_due(&mut queues, Instant::now(), sh.cfg.max_wait, false)
            };
            shed_replies(sh, shed);
            if !stolen.is_empty() {
                sh.metrics
                    .stolen_batches
                    .fetch_add(stolen.len() as u64, Ordering::Relaxed);
                for item in stolen {
                    let _ = tx.send(item);
                }
            }
        }
        // Park until the earliest pending deadline across ALL shards
        // (sibling deadlines bound the next steal scan). Sibling maps
        // are snapshotted briefly first; the own-shard lock is the one
        // the condvar parks on.
        let now = Instant::now();
        let mut next: Option<Duration> = None;
        for j in (0..n).filter(|&j| j != si) {
            if let Some(queues) = sh.shards[j].queues.try_plock() {
                for q in queues.values() {
                    if let Some(age) = q.oldest_age(now) {
                        let d = sh.cfg.max_wait.saturating_sub(age);
                        next = Some(next.map_or(d, |x| x.min(d)));
                    }
                }
            }
        }
        let guard = sh.shards[si].queues.plock();
        // shutdown() sets the flag BEFORE taking this lock to notify,
        // so re-checking here (under the lock, right before parking)
        // closes the lost-wakeup window where the notify fires while
        // this thread is still in the scan above
        if sh.shutting_down.load(Ordering::SeqCst) {
            break;
        }
        for q in guard.values() {
            if let Some(age) = q.oldest_age(now) {
                let d = sh.cfg.max_wait.saturating_sub(age);
                next = Some(next.map_or(d, |x| x.min(d)));
            }
        }
        let park = next
            .unwrap_or(sh.cfg.park_cap)
            .min(sh.cfg.park_cap)
            .max(PARK_FLOOR);
        let _ = wait_timeout_recover(&sh.shards[si].pending_cv, guard, park);
    }
    // final drain: ship everything still pending on this shard
    for item in collect_due_shard(sh, si, true) {
        let _ = tx.send(item);
    }
}

/// Obituary a dying worker sends its supervisor. `Shutdown` is the
/// sentinel `shutdown()` uses to end the supervisor (it cannot rely on
/// channel disconnect: it holds a sender clone of its own to hand to
/// respawned workers).
enum Died {
    Exec { si: usize, wi: usize },
    Flusher { si: usize },
    Shutdown,
}

type BatchRx = Arc<Mutex<mpsc::Receiver<(String, ReadyBatch)>>>;
type BatchTx = mpsc::Sender<(String, ReadyBatch)>;

/// One exec worker's receive loop. `after_worker_batch` is the
/// worker-kill fault hook — OUTSIDE run_batch's `catch_unwind`, so an
/// injected kill here dies for real and exercises supervisor respawn.
/// It must never run on the inline-exec path, where the "worker" is a
/// client thread.
fn exec_worker_loop(rt: &Runtime, shared: &Shared, rx: &BatchRx) {
    loop {
        let msg = { rx.plock().recv() };
        match msg {
            Err(_) => break,
            Ok((key, batch)) => {
                run_batch(rt, shared, &key, batch);
                let faults = &shared.cfg.faults;
                if faults.is_active() {
                    faults.after_worker_batch();
                }
            }
        }
    }
}

/// Spawn one supervised exec worker: the loop runs under
/// `catch_unwind`, and a panicking worker reports to the supervisor
/// (unless the service is shutting down) instead of dying silently.
fn spawn_exec_worker(
    rt: Arc<Runtime>,
    shared: Arc<Shared>,
    rx: BatchRx,
    si: usize,
    wi: usize,
    sup: mpsc::Sender<Died>,
) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name(format!("tcfft-exec-{si}-{wi}"))
        .spawn(move || {
            let outcome = catch_unwind(AssertUnwindSafe(|| exec_worker_loop(&rt, &shared, &rx)));
            if outcome.is_err() && !shared.shutting_down.load(Ordering::SeqCst) {
                let _ = sup.send(Died::Exec { si, wi });
            }
        })
        .expect("spawn exec worker")
}

/// Spawn one supervised flusher (same contract as
/// [`spawn_exec_worker`]).
fn spawn_flusher(
    shared: Arc<Shared>,
    si: usize,
    tx: BatchTx,
    sup: mpsc::Sender<Died>,
) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name(format!("tcfft-flusher-{si}"))
        .spawn(move || {
            let outcome = catch_unwind(AssertUnwindSafe(|| flusher_loop(&shared, si, &tx)));
            if outcome.is_err() && !shared.shutting_down.load(Ordering::SeqCst) {
                let _ = sup.send(Died::Flusher { si });
            }
        })
        .expect("spawn flusher")
}

/// The FFT service. Create with [`FftService::start`].
pub struct FftService {
    rt: Arc<Runtime>,
    shared: Arc<Shared>,
    /// per-shard senders into the exec pools. NOT inside `Shared`:
    /// exec workers hold `Arc<Shared>`, and a sender living there
    /// would keep its own channel open forever (workers would never
    /// see disconnect on drop). The supervisor holds its own clones,
    /// which is why `shutdown()` must join it before `Drop` can rely
    /// on clearing these to disconnect the exec channels.
    shard_txs: Vec<BatchTx>,
    /// shared with the supervisor: respawned handles land here so
    /// shutdown/drop join every generation, not just the first
    flushers: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
    exec_threads: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
    sup_tx: mpsc::Sender<Died>,
    supervisor: Mutex<Option<thread::JoinHandle<()>>>,
}

impl FftService {
    /// Spawn the service threads (per-shard flushers + execution
    /// workers, plus the supervisor that respawns whichever of them
    /// dies to a panic). Shut down with [`shutdown`](Self::shutdown)
    /// or by dropping the service.
    pub fn start(rt: Arc<Runtime>, cfg: ServiceConfig) -> FftService {
        let metrics = Arc::new(Metrics::with_reservoir(cfg.metrics_reservoir));
        let n_shards = cfg.shards.max(1);
        let shards = (0..n_shards)
            .map(|_| Shard { queues: Mutex::new(HashMap::new()), pending_cv: Condvar::new() })
            .collect();
        let shared = Arc::new(Shared {
            shards,
            plans: LruCache::with_stats(cfg.plan_cache_bytes, metrics.plan_cache.clone()),
            large_plans: LruCache::with_stats(cfg.large_cache_bytes, metrics.large_cache.clone()),
            banks: LruCache::with_stats(cfg.bank_cache_bytes, metrics.bank_cache.clone()),
            quota: QuotaGate::new(cfg.quota_rate, cfg.quota_burst),
            metrics,
            next_id: AtomicU64::new(1),
            shutting_down: AtomicBool::new(false),
            cfg,
        });
        let (sup_tx, sup_rx) = mpsc::channel::<Died>();
        let mut shard_txs = Vec::with_capacity(n_shards);
        let mut shard_rxs: Vec<BatchRx> = Vec::with_capacity(n_shards);
        let flushers = Arc::new(Mutex::new(Vec::with_capacity(n_shards)));
        let exec_threads = Arc::new(Mutex::new(Vec::new()));
        for si in 0..n_shards {
            let (tx, rx) = mpsc::channel::<(String, ReadyBatch)>();
            let rx = Arc::new(Mutex::new(rx));
            for wi in 0..shared.cfg.exec_threads.max(1) {
                exec_threads.plock().push(spawn_exec_worker(
                    Arc::clone(&rt),
                    Arc::clone(&shared),
                    Arc::clone(&rx),
                    si,
                    wi,
                    sup_tx.clone(),
                ));
            }
            flushers.plock().push(spawn_flusher(
                Arc::clone(&shared),
                si,
                tx.clone(),
                sup_tx.clone(),
            ));
            shard_txs.push(tx);
            shard_rxs.push(rx);
        }
        // Supervisor: respawn whatever dies, bump `worker_restarts`.
        // Ends on the `Died::Shutdown` sentinel from shutdown(); its
        // tx clones (needed to equip respawned flushers) die with it,
        // which is what lets Drop's shard_txs.clear() actually
        // disconnect the exec channels.
        let supervisor = {
            let rt = Arc::clone(&rt);
            let shared = Arc::clone(&shared);
            let txs = shard_txs.clone();
            let rxs = shard_rxs;
            let flushers = Arc::clone(&flushers);
            let exec_threads = Arc::clone(&exec_threads);
            let sup_tx = sup_tx.clone();
            thread::Builder::new()
                .name("tcfft-supervisor".to_string())
                .spawn(move || loop {
                    match sup_rx.recv() {
                        Err(_) | Ok(Died::Shutdown) => break,
                        Ok(Died::Exec { si, wi }) => {
                            if shared.shutting_down.load(Ordering::SeqCst) {
                                continue;
                            }
                            shared.metrics.worker_restarts.fetch_add(1, Ordering::Relaxed);
                            exec_threads.plock().push(spawn_exec_worker(
                                Arc::clone(&rt),
                                Arc::clone(&shared),
                                Arc::clone(&rxs[si]),
                                si,
                                wi,
                                sup_tx.clone(),
                            ));
                        }
                        Ok(Died::Flusher { si }) => {
                            if shared.shutting_down.load(Ordering::SeqCst) {
                                continue;
                            }
                            shared.metrics.worker_restarts.fetch_add(1, Ordering::Relaxed);
                            flushers.plock().push(spawn_flusher(
                                Arc::clone(&shared),
                                si,
                                txs[si].clone(),
                                sup_tx.clone(),
                            ));
                        }
                    }
                })
                .expect("spawn supervisor")
        };
        FftService {
            rt,
            shared,
            shard_txs,
            flushers,
            exec_threads,
            sup_tx,
            supervisor: Mutex::new(Some(supervisor)),
        }
    }

    /// The service's live metrics (counters + latency reservoirs).
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.shared.metrics)
    }

    /// The runtime the service executes on.
    pub fn runtime(&self) -> Arc<Runtime> {
        Arc::clone(&self.rt)
    }

    /// Number of shards the service is running.
    pub fn shards(&self) -> usize {
        self.shared.shards.len()
    }

    /// The fault injector this service was configured with (the TCP
    /// front end consults it for frame-chop faults; chaos tests read
    /// its injection counters).
    pub fn faults(&self) -> Arc<FaultInjector> {
        Arc::clone(&self.shared.cfg.faults)
    }

    /// Resolve (and cache) the plan for a request shape.
    fn plan_for(&self, req: &FftRequest) -> Result<Plan> {
        let inverse = req.direction == Direction::Inverse;
        let desc = match req.op {
            Op::Fft1d { n } => format!("1d:{n}:{}:{}", req.algo, inverse),
            Op::Fft2d { nx, ny } => format!("2d:{nx}x{ny}:{}:{}", req.algo, inverse),
            Op::Rfft1d { n } => format!("r1d:{n}:{}:{}", req.algo, inverse),
            Op::Rfft2d { nx, ny } => format!("r2d:{nx}x{ny}:{}:{}", req.algo, inverse),
        };
        let cache_key = fingerprint_key(&desc);
        if let Some(p) = self.shared.plans.get(&cache_key) {
            return Ok(p);
        }
        let plan = match req.op {
            Op::Fft1d { n } => {
                Plan::fft1d_algo(&self.rt.registry, n, 1, &req.algo, req.direction)?
            }
            Op::Fft2d { nx, ny } => {
                Plan::fft2d_algo(&self.rt.registry, nx, ny, 1, &req.algo, req.direction)?
            }
            Op::Rfft1d { n } => {
                Plan::rfft1d_algo(&self.rt.registry, n, 1, &req.algo, req.direction)?
            }
            Op::Rfft2d { nx, ny } => {
                Plan::rfft2d_algo(&self.rt.registry, nx, ny, 1, &req.algo, req.direction)?
            }
        };
        let bytes = plan.memory_bytes();
        let (plan, _inserted) = self.shared.plans.get_or_insert(&cache_key, plan, bytes);
        Ok(plan)
    }

    /// Resolve a request to its execution route: a direct artifact
    /// plan, or — for power-of-two sizes with no artifact — a cached
    /// four-step large-FFT plan (paper Sec 3.1): the complex engine
    /// for `Fft1d`, the real wrapper for `Rfft1d`, and the 2D
    /// row/column composition ([`Plan2d`](crate::large::Plan2d)) for
    /// `Rfft2d` images with sides in
    /// [`LARGE_2D_MIN_SIDE`]..=[`LARGE_2D_MAX_SIDE`]. `Op::Fft2d` has
    /// no large route and fails fast beyond the catalog; ineligible
    /// `Rfft2d` sizes fail fast with a message naming both the catalog
    /// and the large-route bounds.
    fn route_for(&self, req: &FftRequest) -> Result<Route> {
        match self.plan_for(req) {
            Ok(plan) => Ok(Route::Direct {
                key: plan.meta.key,
                capacity: plan.meta.batch,
                tail: plan.meta.input_shape[1..].to_vec(),
            }),
            Err(TcFftError::NoArtifact(reason)) => match req.op {
                Op::Fft1d { n }
                    if n.is_power_of_two() && n >= 4 && n <= self.shared.cfg.max_large_n =>
                {
                    self.large_route_for(n, req)
                }
                Op::Rfft1d { n }
                    if n.is_power_of_two() && n >= 8 && n <= self.shared.cfg.max_large_n =>
                {
                    self.large_route_for(n, req)
                }
                Op::Rfft2d { nx, ny } if self.large_2d_eligible(nx, ny) => {
                    self.large_2d_route_for(nx, ny, req)
                }
                Op::Rfft2d { nx, ny } => Err(TcFftError::NoArtifact(format!(
                    "rfft2d {nx}x{ny}: {reason}; the catalog serves squares \
                     8x8..256x256 plus 64x128/128x64, and the large-2D four-step \
                     route serves power-of-two sides \
                     {LARGE_2D_MIN_SIDE}..{LARGE_2D_MAX_SIDE} with area \
                     nx*ny <= {} (max_large_n)",
                    self.shared.cfg.max_large_n
                ))),
                _ => Err(TcFftError::NoArtifact(reason)),
            },
            Err(e) => Err(e),
        }
    }

    /// Find or build the cached four-step plan for (op, n, algo, dir).
    fn large_route_for(&self, n: usize, req: &FftRequest) -> Result<Route> {
        // Only known algos may mint cache entries: a typo should fail
        // loudly, like the direct-artifact path, instead of silently
        // computing with the tc fallback — and an unvalidated string
        // from the TCP surface must not mint cache keys.
        if !matches!(req.algo.as_str(), "tc" | "tc_split" | "tc_ec" | "r2") {
            return Err(TcFftError::NoArtifact(format!(
                "n={n} algo={} (unknown algo has no four-step route)",
                req.algo
            )));
        }
        let inverse = req.direction == Direction::Inverse;
        let real = matches!(req.op, Op::Rfft1d { .. });
        let dir = if inverse { "inv" } else { "fwd" };
        let desc = if real {
            format!("4stepr:{n}:{}:{dir}", req.algo)
        } else {
            format!("4step:{n}:{}:{dir}", req.algo)
        };
        let key = fingerprint_key(&desc);
        // the per-request shape the submit path validates against:
        // C2R consumes packed spectra, everything else full rows
        let tail = if real && inverse { vec![n / 2 + 1] } else { vec![n] };
        if self.shared.large_plans.get(&key).is_some() {
            return Ok(Route::Large { key, tail });
        }
        // build outside any lock (twiddle precompute is real work); a
        // racing builder loses to get_or_insert and drops its copy
        let cfg = FourStepConfig { algo: req.algo.clone(), ..FourStepConfig::default() };
        let plan = if real {
            LargePlan::Real(Arc::new(RealFourStepPlan::with_config(&self.rt, n, inverse, cfg)?))
        } else {
            LargePlan::Complex(Arc::new(FourStepPlan::with_config(&self.rt, n, inverse, cfg)?))
        };
        let bytes = plan.memory_bytes();
        let _ = self.shared.large_plans.get_or_insert(&key, plan, bytes);
        Ok(Route::Large { key, tail })
    }

    /// Whether an `Op::Rfft2d` image qualifies for the large-2D
    /// four-step route: power-of-two sides in
    /// [`LARGE_2D_MIN_SIDE`]..=[`LARGE_2D_MAX_SIDE`] whose area fits
    /// the `max_large_n` budget (the 2D analogue of the 1D size
    /// guard, applied to `nx * ny`).
    fn large_2d_eligible(&self, nx: usize, ny: usize) -> bool {
        let side_ok =
            |s: usize| s.is_power_of_two() && (LARGE_2D_MIN_SIDE..=LARGE_2D_MAX_SIDE).contains(&s);
        side_ok(nx)
            && side_ok(ny)
            && nx.checked_mul(ny).is_some_and(|area| area <= self.shared.cfg.max_large_n)
    }

    /// Find or build the cached 2D four-step composition for
    /// (nx, ny, algo, dir) — the `Op::Rfft2d` analogue of
    /// [`large_route_for`](Self::large_route_for), sharing the same
    /// LRU, fingerprint keys, and build-outside-locks discipline.
    fn large_2d_route_for(&self, nx: usize, ny: usize, req: &FftRequest) -> Result<Route> {
        if !matches!(req.algo.as_str(), "tc" | "tc_split" | "tc_ec" | "r2") {
            return Err(TcFftError::NoArtifact(format!(
                "rfft2d {nx}x{ny} algo={} (unknown algo has no four-step route)",
                req.algo
            )));
        }
        let inverse = req.direction == Direction::Inverse;
        let dir = if inverse { "inv" } else { "fwd" };
        let desc = format!("4step2d:{nx}x{ny}:{}:{dir}", req.algo);
        let key = fingerprint_key(&desc);
        // C2R consumes packed spectra, R2C full images
        let tail = if inverse { vec![nx, ny / 2 + 1] } else { vec![nx, ny] };
        if self.shared.large_plans.get(&key).is_some() {
            return Ok(Route::Large { key, tail });
        }
        let cfg = FourStepConfig { algo: req.algo.clone(), ..FourStepConfig::default() };
        let built = Plan2d::with_config(&self.rt, nx, ny, inverse, cfg)?;
        let plan = LargePlan::Plan2d(Arc::new(built));
        let bytes = plan.memory_bytes();
        let _ = self.shared.large_plans.get_or_insert(&key, plan, bytes);
        Ok(Route::Large { key, tail })
    }

    /// Submit one request; returns a ticket to wait on. Unmetered (no
    /// client id): in-process callers bypass admission control.
    pub fn submit(&self, req: FftRequest) -> Result<Ticket> {
        self.submit_from(None, req)
    }

    /// [`submit`](Self::submit) on behalf of a client id (the TCP
    /// front end passes its connection id). Subject to the per-client
    /// admission quota when `ServiceConfig::quota_rate` is set.
    pub fn submit_as(&self, client: u64, req: FftRequest) -> Result<Ticket> {
        self.submit_from(Some(client), req)
    }

    /// Tally a submit-path rejection in the errors-by-code counters on
    /// its way back to the caller.
    fn track_err<T>(&self, r: Result<T>) -> Result<T> {
        if let Err(e) = &r {
            self.shared.metrics.record_error(e);
        }
        r
    }

    fn submit_from(&self, client: Option<u64>, req: FftRequest) -> Result<Ticket> {
        let r = self.submit_from_inner(client, req);
        self.track_err(r)
    }

    fn submit_from_inner(&self, client: Option<u64>, req: FftRequest) -> Result<Ticket> {
        if self.shared.shutting_down.load(Ordering::SeqCst) {
            return Err(TcFftError::ShuttingDown);
        }
        self.admit(client)?;
        let route = self.route_for(&req)?;

        // normalize input to [1, ...]
        let mut shape = vec![1usize];
        shape.extend_from_slice(&req.input.shape);
        let input = PlanarBatch { re: req.input.re, im: req.input.im, shape };
        let (queue_key, capacity, pad, large) = match &route {
            Route::Direct { key, capacity, tail } => {
                crate::ensure!(
                    input.shape[1..] == tail[..],
                    "request shape {:?} does not match plan {:?}",
                    &input.shape[1..],
                    &tail[..]
                );
                (key.clone(), *capacity, true, false)
            }
            Route::Large { key, tail } => {
                crate::ensure!(
                    input.shape[1..] == tail[..],
                    "request shape {:?} does not match four-step tail {:?}",
                    &input.shape[1..],
                    &tail[..]
                );
                (key.clone(), self.shared.cfg.large_batch.max(1), false, true)
            }
        };
        // routed AND shape-validated: only now may counters move — a
        // malformed request must leave every counter untouched (the
        // ordering submit_convolve() documents; regression-tested)
        self.shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
        if large {
            self.shared.metrics.large_requests.fetch_add(1, Ordering::Relaxed);
        }
        match req.op {
            Op::Rfft1d { .. } => {
                self.shared.metrics.rfft_requests.fetch_add(1, Ordering::Relaxed);
            }
            Op::Rfft2d { .. } => {
                self.shared.metrics.rfft2d_requests.fetch_add(1, Ordering::Relaxed);
            }
            _ => {}
        }
        self.enqueue(queue_key, capacity, pad, input)
    }

    /// Token-bucket admission for metered callers; `None` (in-process)
    /// is always admitted. Quota rejections are counted separately
    /// from backpressure and never reach routing.
    fn admit(&self, client: Option<u64>) -> Result<()> {
        if let Some(c) = client {
            if !self.shared.quota.admit(c) {
                self.shared.metrics.quota_rejected.fetch_add(1, Ordering::Relaxed);
                return Err(TcFftError::QuotaExceeded);
            }
        }
        Ok(())
    }

    /// Shared enqueue tail of [`submit`](Self::submit) and
    /// [`submit_convolve`](Self::submit_convolve): queue the pending
    /// request on its key's shard (backpressure-bounded) and run the
    /// leader-execution / opportunistic-flush policy.
    fn enqueue(
        &self,
        queue_key: String,
        capacity: usize,
        pad: bool,
        input: PlanarBatch,
    ) -> Result<Ticket> {
        let id = self.shared.next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = mpsc::channel();
        let enqueued = Instant::now();
        let pending = Pending {
            id,
            input,
            enqueued,
            deadline: self.shared.cfg.request_deadline.map(|d| enqueued + d),
            reply: tx,
        };
        let si = self.shared.shard_for(&queue_key);
        let shard = &self.shared.shards[si];
        let mut full_queue = false;
        {
            let mut queues = shard.queues.plock();
            let q = queues.entry(queue_key.clone()).or_insert_with(|| {
                if pad {
                    PlanQueue::new(queue_key.clone(), capacity, self.shared.cfg.max_queue)
                } else {
                    PlanQueue::unpadded(queue_key.clone(), capacity, self.shared.cfg.max_queue)
                }
            });
            if let Err(reject) = q.push(pending) {
                full_queue = true;
                self.shared.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                self.shared.metrics.record_error(&TcFftError::QueueFull);
                let _ = reject.reply.send(Err(TcFftError::QueueFull));
            }
            shard.pending_cv.notify_one();
        }
        if !full_queue {
            if self.shared.cfg.inline_exec {
                // leader execution: if this submit filled a batch, run
                // it here and now — no hand-off, no wakeups
                let ready = collect_due_shard(&self.shared, si, false);
                for (key, batch) in ready {
                    run_batch(&self.rt, &self.shared, &key, batch);
                }
            } else {
                // opportunistic flush for full batches (the deadline
                // park would add latency)
                for item in collect_due_shard(&self.shared, si, false) {
                    let _ = self.shard_txs[si].send(item);
                }
            }
        }
        Ok(Ticket { id, rx })
    }

    /// Register a named spectral filter bank for the batched convolve
    /// route: `k` FIR filters over real length-`n` signals, prepared
    /// once (one batched R2C over the taps) and applied to queued
    /// signals by [`submit_convolve`](Self::submit_convolve).
    ///
    /// Registration is guarded because it is reachable over TCP: only
    /// known algos (`tc` | `tc_split` | `tc_ec` | `r2`), `n` a power of two
    /// within `ServiceConfig::max_large_n`, and at most
    /// `ServiceConfig::max_bank_filters` filters per bank (each
    /// registration runs `k` R2C transforms synchronously). Aggregate
    /// memory is bounded by the bank cache's byte budget
    /// (`ServiceConfig::bank_cache_bytes`) — LRU banks are evicted to
    /// admit new ones, and a single bank larger than the whole budget
    /// is refused outright.
    ///
    /// Identity follows the content-fingerprint contract:
    /// re-registering the same name with the SAME content (n, algo,
    /// taps) is an idempotent success — the natural recovery after an
    /// eviction — while the same name with DIFFERENT content is an
    /// error (replacing a bank under a live queue key would let
    /// differently-shaped requests meet in one batch). Returns the
    /// filter count `k`.
    pub fn register_filter_bank<T: AsRef<[f32]>>(
        &self,
        name: &str,
        n: usize,
        filters: &[T],
        algo: &str,
    ) -> Result<usize> {
        crate::ensure!(
            !name.is_empty() && name.len() <= 64,
            "bank name must be 1..=64 characters"
        );
        if !matches!(algo, "tc" | "tc_split" | "tc_ec" | "r2") {
            return Err(TcFftError::NoArtifact(format!(
                "filter bank '{name}': unknown algo '{algo}'"
            )));
        }
        crate::ensure!(
            n.is_power_of_two() && n >= 4 && n <= self.shared.cfg.max_large_n,
            "filter bank '{name}': n={n} outside the served range"
        );
        crate::ensure!(
            filters.len() <= self.shared.cfg.max_bank_filters,
            "filter bank '{name}': {} filters over the {} cap",
            filters.len(),
            self.shared.cfg.max_bank_filters
        );
        let key = format!("conv:{name}");
        let fp = bank_fingerprint(n, algo, filters);
        if let Some(existing) = self.shared.banks.peek(&key) {
            if existing.fingerprint == fp {
                return Ok(existing.conv.k()); // idempotent re-registration
            }
            crate::bail!("filter bank '{name}' already registered with different content");
        }
        // build outside any lock (k R2C transforms of the taps); a
        // racing same-content registration loses to get_or_insert
        let bank = Arc::new(SpectralConv::new_bank_algo(&self.rt, n, filters, algo)?);
        let bytes = bank.memory_bytes();
        crate::ensure!(
            bytes <= self.shared.cfg.bank_cache_bytes,
            "filter bank '{name}': ~{bytes} bytes exceeds the whole bank budget ({})",
            self.shared.cfg.bank_cache_bytes
        );
        let k = bank.k();
        let entry = BankEntry { conv: bank, fingerprint: fp };
        let (existing, inserted) = self.shared.banks.get_or_insert(&key, entry, bytes);
        if !inserted {
            // racing registration landed first; same content is fine
            if existing.fingerprint == fp {
                return Ok(existing.conv.k());
            }
            crate::bail!("filter bank '{name}' already registered with different content");
        }
        Ok(k)
    }

    /// The registered bank's (n, k), if any — the TCP front end uses
    /// this to validate request shapes before queuing. Does not touch
    /// LRU order or hit/miss counters.
    pub fn filter_bank_shape(&self, name: &str) -> Option<(usize, usize)> {
        self.shared
            .banks
            .peek(&format!("conv:{name}"))
            .map(|e| (e.conv.n(), e.conv.k()))
    }

    /// Submit one real signal (shape `[n]`) to a registered filter
    /// bank. Replies carry shape `[1, k, n]` — every filter's output
    /// for the signal, at unit scale. Requests ride the same bounded
    /// unpadded queues as the four-step route (the bank's
    /// `convolve_batch` takes any row count), so backpressure
    /// (`QueueFull`) and batching behave identically. Unmetered; see
    /// [`submit_convolve_as`](Self::submit_convolve_as).
    pub fn submit_convolve(&self, bank: &str, input: PlanarBatch) -> Result<Ticket> {
        self.submit_convolve_from(None, bank, input)
    }

    /// [`submit_convolve`](Self::submit_convolve) on behalf of a
    /// client id, subject to the same admission quota as
    /// [`submit_as`](Self::submit_as).
    pub fn submit_convolve_as(&self, client: u64, bank: &str, input: PlanarBatch) -> Result<Ticket> {
        self.submit_convolve_from(Some(client), bank, input)
    }

    fn submit_convolve_from(
        &self,
        client: Option<u64>,
        bank: &str,
        input: PlanarBatch,
    ) -> Result<Ticket> {
        let r = self.submit_convolve_inner(client, bank, input);
        self.track_err(r)
    }

    fn submit_convolve_inner(
        &self,
        client: Option<u64>,
        bank: &str,
        input: PlanarBatch,
    ) -> Result<Ticket> {
        if self.shared.shutting_down.load(Ordering::SeqCst) {
            return Err(TcFftError::ShuttingDown);
        }
        self.admit(client)?;
        let key = format!("conv:{bank}");
        let n = match self.shared.banks.get(&key) {
            Some(entry) => entry.conv.n(),
            None => {
                return Err(TcFftError::NoArtifact(format!(
                    "no filter bank named '{bank}' is registered"
                )))
            }
        };
        let mut shape = vec![1usize];
        shape.extend_from_slice(&input.shape);
        let input = PlanarBatch { re: input.re, im: input.im, shape };
        crate::ensure!(
            input.shape[1..] == [n],
            "convolve request shape {:?} does not match bank signal length [{n}]",
            &input.shape[1..]
        );
        // count only requests that actually reach a queue, mirroring
        // submit()'s routed-then-counted ordering
        self.shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.shared.metrics.conv_batch_requests.fetch_add(1, Ordering::Relaxed);
        self.enqueue(key, self.shared.cfg.large_batch.max(1), false, input)
    }

    /// Convenience: blocking filter-bank convolution of a (possibly
    /// multi-row) real batch `[b, n]`; returns `[b, k, n]`.
    pub fn convolve_blocking(&self, bank: &str, x: PlanarBatch) -> Result<PlanarBatch> {
        crate::ensure!(x.shape.len() == 2, "expected [b, n]");
        self.blocking_rows_with(x, |input| self.submit_convolve(bank, input))
    }

    /// Shared body of every blocking helper: submit each row of `x`
    /// through `submit_row` (shape = the batch tail), wait in row
    /// order, and concatenate the replies.
    fn blocking_rows_with(
        &self,
        x: PlanarBatch,
        submit_row: impl Fn(PlanarBatch) -> Result<Ticket>,
    ) -> Result<PlanarBatch> {
        let rows = x.shape[0];
        let tail = x.shape[1..].to_vec();
        let mut tickets = Vec::new();
        for r in 0..rows {
            let row = x.slice_rows(r, r + 1);
            tickets.push(submit_row(PlanarBatch { re: row.re, im: row.im, shape: tail.clone() })?);
        }
        let outs = tickets
            .into_iter()
            .map(|t| t.wait())
            .collect::<Result<Vec<_>>>()?;
        Ok(PlanarBatch::concat(&outs))
    }

    /// [`blocking_rows_with`](Self::blocking_rows_with) for transform
    /// requests: each row becomes its own [`FftRequest`].
    fn blocking_rows(
        &self,
        x: PlanarBatch,
        op: Op,
        algo: &str,
        dir: Direction,
    ) -> Result<PlanarBatch> {
        self.blocking_rows_with(x, |input| {
            self.submit(FftRequest { op, algo: algo.to_string(), direction: dir, input })
        })
    }

    /// Convenience: blocking 1D transform of a (possibly multi-row) batch.
    pub fn fft1d_blocking(
        &self,
        x: PlanarBatch,
        algo: &str,
        dir: Direction,
    ) -> Result<PlanarBatch> {
        let n = *x.shape.last().unwrap();
        self.blocking_rows(x, Op::Fft1d { n }, algo, dir)
    }

    /// Convenience: blocking real 1D transform of a (possibly
    /// multi-row) batch — R2C forward (`[b, n]` real rows in,
    /// `[b, n/2 + 1]` packed spectra out) or C2R inverse (the mirror
    /// image, output scaled by `n`).
    pub fn rfft1d_blocking(
        &self,
        x: PlanarBatch,
        algo: &str,
        dir: Direction,
    ) -> Result<PlanarBatch> {
        crate::ensure!(x.shape.len() == 2, "expected [b, len]");
        let len = x.shape[1];
        let n = if dir == Direction::Inverse {
            crate::ensure!(len >= 2, "packed spectrum needs at least 2 bins, got {len}");
            2 * (len - 1)
        } else {
            len
        };
        self.blocking_rows(x, Op::Rfft1d { n }, algo, dir)
    }

    /// Convenience: blocking real 2D transform of a (possibly
    /// multi-row) batch — R2C forward (`[b, nx, ny]` real fields in,
    /// `[b, nx, ny/2 + 1]` packed spectra out) or C2R inverse (the
    /// mirror image, output scaled by `nx * ny`). The inverse infers
    /// `ny` from the packed tail: `ny = 2 * (bins - 1)`.
    pub fn rfft2d_blocking(
        &self,
        x: PlanarBatch,
        algo: &str,
        dir: Direction,
    ) -> Result<PlanarBatch> {
        crate::ensure!(x.shape.len() == 3, "expected [b, nx, tail]");
        let nx = x.shape[1];
        let ny = if dir == Direction::Inverse {
            let bins = x.shape[2];
            crate::ensure!(bins >= 2, "packed spectrum needs at least 2 bins per row, got {bins}");
            2 * (bins - 1)
        } else {
            x.shape[2]
        };
        self.blocking_rows(x, Op::Rfft2d { nx, ny }, algo, dir)
    }

    /// Same for 2D.
    pub fn fft2d_blocking(
        &self,
        x: PlanarBatch,
        algo: &str,
        dir: Direction,
    ) -> Result<PlanarBatch> {
        crate::ensure!(x.shape.len() == 3, "expected [b, nx, ny]");
        let (nx, ny) = (x.shape[1], x.shape[2]);
        self.blocking_rows(x, Op::Fft2d { nx, ny }, algo, dir)
    }

    /// Graceful shutdown: wake every parked flusher immediately (a
    /// flusher otherwise finishes its up-to-`park_cap` park before
    /// noticing the flag — the pre-shard service had exactly that bug),
    /// retire the supervisor, let each flusher run its final drain, and
    /// join them. Idempotent.
    pub fn shutdown(&self) {
        self.shared.shutting_down.store(true, Ordering::SeqCst);
        for shard in &self.shared.shards {
            // take the queues lock so the notify cannot slip into the
            // window between a flusher's flag check and its park
            let _guard = shard.queues.plock();
            shard.pending_cv.notify_all();
        }
        // Retire the supervisor BEFORE joining flushers: once it is
        // gone no new flusher can be pushed (so the drain below is
        // complete) and its exec-channel sender clones are dropped (so
        // Drop's shard_txs.clear() actually disconnects the workers).
        if let Some(sup) = self.supervisor.plock().take() {
            let _ = self.sup_tx.send(Died::Shutdown);
            let _ = sup.join();
        }
        for j in self.flushers.plock().drain(..) {
            let _ = j.join();
        }
    }
}

/// Deterministic content fingerprint of a filter bank: the transform
/// size, algo, and every tap's f32 bit pattern (per-filter lengths
/// separate the digests of `[[a, b]]` and `[[a], [b]]`).
fn bank_fingerprint<T: AsRef<[f32]>>(n: usize, algo: &str, filters: &[T]) -> u64 {
    let mut h = Fnv1a::new();
    h.write_u64(n as u64).write_str(algo);
    for taps in filters {
        let taps = taps.as_ref();
        h.write_u64(taps.len() as u64);
        for &t in taps {
            h.write_f32(t);
        }
    }
    h.finish()
}

impl Drop for FftService {
    fn drop(&mut self) {
        self.shutdown();
        // the flushers and the supervisor are joined (their sender
        // clones are gone); dropping ours closes every shard channel,
        // which ends the exec workers — every generation, including
        // supervisor respawns — once they drain
        self.shard_txs.clear();
        for j in self.exec_threads.plock().drain(..) {
            let _ = j.join();
        }
    }
}
