//! Workload generators for the evaluation and the examples, plus the
//! spectral-convolution workload ([`spectral`]) built on the real-FFT
//! (R2C/C2R) path.

pub mod spectral;

pub use spectral::SpectralConv;

use crate::hp::{C32, C64};
use crate::util::rng::SplitMix64;

/// Paper TestCase: inputs uniform in [-1, 1) (both components).
pub fn random_signal(n: usize, seed: u64) -> Vec<C32> {
    let mut rng = SplitMix64::new(seed);
    (0..n)
        .map(|_| C32::new(rng.uniform(-1.0, 1.0) as f32, rng.uniform(-1.0, 1.0) as f32))
        .collect()
}

pub fn random_signal_f64(n: usize, seed: u64) -> Vec<C64> {
    random_signal(n, seed)
        .into_iter()
        .map(|c| C64::new(c.re as f64, c.im as f64))
        .collect()
}

/// A gravitational-wave-like chirp (pyCBC motivation, paper Sec 1):
/// instantaneous frequency sweeps f0 -> f1 over n samples, with an
/// amplitude envelope that rises toward merger then rings down.
pub fn chirp(n: usize, f0: f64, f1: f64, merger_frac: f64) -> Vec<C32> {
    let mut out = Vec::with_capacity(n);
    let merger = (n as f64 * merger_frac) as usize;
    for i in 0..n {
        let t = i as f64 / n as f64;
        // quadratic frequency sweep
        let f = f0 + (f1 - f0) * t * t;
        let phase = 2.0 * std::f64::consts::PI * f * i as f64 / n as f64;
        let amp = if i < merger {
            0.1 + 0.9 * (i as f64 / merger as f64).powi(2)
        } else {
            (-(5.0 * (i - merger) as f64 / (n - merger).max(1) as f64)).exp()
        };
        out.push(C32::new((amp * phase.cos()) as f32, (amp * phase.sin()) as f32));
    }
    out
}

/// Additive white noise.
pub fn add_noise(x: &mut [C32], sigma: f64, seed: u64) {
    let mut rng = SplitMix64::new(seed);
    for v in x {
        v.re += (sigma * rng.normal()) as f32;
        v.im += (sigma * rng.normal()) as f32;
    }
}

/// A synthetic "CT-slice-like" test image (medical-imaging motivation):
/// smooth background + a few ellipses, values in [0, 1]. Row-major nx x ny.
pub fn phantom_image(nx: usize, ny: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    let mut img = vec![0.0f32; nx * ny];
    // smooth background gradient
    for r in 0..nx {
        for c in 0..ny {
            img[r * ny + c] = 0.1 + 0.05 * ((r as f32 / nx as f32) + (c as f32 / ny as f32));
        }
    }
    // random ellipses
    for _ in 0..6 {
        let cx = rng.uniform(0.2, 0.8) * nx as f64;
        let cy = rng.uniform(0.2, 0.8) * ny as f64;
        let ax = rng.uniform(0.05, 0.25) * nx as f64;
        let ay = rng.uniform(0.05, 0.25) * ny as f64;
        let val = rng.uniform(0.2, 0.8) as f32;
        for r in 0..nx {
            for c in 0..ny {
                let dx = (r as f64 - cx) / ax;
                let dy = (c as f64 - cy) / ay;
                if dx * dx + dy * dy <= 1.0 {
                    img[r * ny + c] = (img[r * ny + c] + val).min(1.0);
                }
            }
        }
    }
    img
}

/// Poisson arrival times (seconds) with the given rate over a horizon.
pub fn poisson_arrivals(rate_hz: f64, horizon_s: f64, seed: u64) -> Vec<f64> {
    let mut rng = SplitMix64::new(seed);
    let mut t = 0.0;
    let mut out = Vec::new();
    loop {
        t += rng.exp(rate_hz);
        if t >= horizon_s {
            break;
        }
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_signal_in_range() {
        for c in random_signal(1024, 7) {
            assert!((-1.0..1.0).contains(&c.re));
            assert!((-1.0..1.0).contains(&c.im));
        }
    }

    #[test]
    fn chirp_energy_concentrated_after_fft() {
        // a chirp sweeping the lower quarter of the band must put most
        // energy in the lower half of the spectrum
        let x = chirp(1024, 10.0, 120.0, 0.8);
        let xd: Vec<crate::hp::C64> = x
            .iter()
            .map(|c| crate::hp::C64::new(c.re as f64, c.im as f64))
            .collect();
        let y = crate::fft::radix2::fft_vec(&xd, false);
        let lower: f64 = y[..512].iter().map(|c| c.norm_sqr()).sum();
        let upper: f64 = y[512..].iter().map(|c| c.norm_sqr()).sum();
        assert!(lower > 5.0 * upper, "lower {lower:.1} upper {upper:.1}");
    }

    #[test]
    fn phantom_in_unit_range() {
        let img = phantom_image(64, 64, 3);
        assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // non-trivial content
        let mean = img.iter().sum::<f32>() / img.len() as f32;
        assert!(mean > 0.05 && mean < 0.95);
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let arr = poisson_arrivals(1000.0, 2.0, 5);
        assert!((arr.len() as f64 - 2000.0).abs() < 300.0, "{}", arr.len());
        assert!(arr.windows(2).all(|w| w[1] >= w[0]));
    }
}
