//! Spectral convolution of real signals — the workload the R2C/C2R
//! path exists for: FIR filtering and matched filtering computed as
//! `irfft(rfft(x) * H)` with both transforms running through the
//! half-precision real-FFT plans.
//!
//! The filter spectrum `H` is computed once at build time (one R2C
//! pass over the zero-padded taps); each [`SpectralConv::convolve`]
//! call then costs one R2C, one O(n) pointwise complex multiply on the
//! host (f32, scaled by `1/n` so the unnormalized C2R lands at unit
//! scale), and one C2R — against two full-size complex transforms for
//! the promote-to-complex alternative.
//!
//! Convolution is CIRCULAR (period `n`), the native product of the
//! DFT; callers wanting linear convolution zero-pad in the usual way.

use crate::error::Result;
use crate::plan::Plan;
use crate::runtime::{PlanarBatch, Runtime};

/// A prepared circular convolution of real length-`n` signals with a
/// fixed real filter, evaluated in the frequency domain.
pub struct SpectralConv {
    n: usize,
    fwd: Plan,
    inv: Plan,
    /// packed filter spectrum, bins 0..=n/2 (real plane)
    h_re: Vec<f32>,
    /// packed filter spectrum, bins 0..=n/2 (imaginary plane)
    h_im: Vec<f32>,
}

impl SpectralConv {
    /// Build the convolver for signal length `n` (power of two >= 4)
    /// and the given FIR taps (`taps.len() <= n`; zero-padded).
    pub fn new(rt: &Runtime, n: usize, taps: &[f32]) -> Result<SpectralConv> {
        crate::ensure!(taps.len() <= n, "filter ({}) longer than signal ({n})", taps.len());
        let fwd = Plan::rfft1d(&rt.registry, n, 1)?;
        let inv = Plan::irfft1d(&rt.registry, n, 1)?;
        let mut h = PlanarBatch::new(vec![1, n]);
        h.re[..taps.len()].copy_from_slice(taps);
        let spec = fwd.execute(rt, h)?;
        Ok(SpectralConv { n, fwd, inv, h_re: spec.re, h_im: spec.im })
    }

    /// Build a matched filter for a real template: circular correlation
    /// against the template, i.e. convolution with its time reversal.
    /// The output of [`convolve`](Self::convolve) then peaks at the lag
    /// where the template sits in the input.
    pub fn matched_filter(rt: &Runtime, n: usize, template: &[f32]) -> Result<SpectralConv> {
        crate::ensure!(template.len() <= n, "template longer than signal");
        let mut taps = vec![0f32; n];
        for (i, &t) in template.iter().enumerate() {
            taps[(n - i) % n] = t;
        }
        Self::new(rt, n, &taps)
    }

    /// The signal length `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Circularly convolve a batch of real rows (`[b, n]`, samples in
    /// the `re` plane) with the prepared filter. Output has the same
    /// shape with the result in the `re` plane at unit scale (the
    /// `1/n` of the unnormalized inverse is folded into the pointwise
    /// multiply, which also keeps the C2R input inside fp16 range).
    pub fn convolve_batch(&self, rt: &Runtime, x: PlanarBatch) -> Result<PlanarBatch> {
        crate::ensure!(
            x.shape.len() == 2 && x.shape[1] == self.n,
            "input shape {:?} != [b, {}]",
            x.shape,
            self.n
        );
        let b = x.shape[0];
        let mut spec = self.fwd.execute(rt, x)?;
        let bins = self.n / 2 + 1;
        let scale = 1.0 / self.n as f32;
        for row in 0..b {
            let base = row * bins;
            for k in 0..bins {
                let (xr, xi) = (spec.re[base + k], spec.im[base + k]);
                let (hr, hi) = (self.h_re[k], self.h_im[k]);
                spec.re[base + k] = (xr * hr - xi * hi) * scale;
                spec.im[base + k] = (xr * hi + xi * hr) * scale;
            }
        }
        self.inv.execute(rt, spec)
    }

    /// Single-signal convenience over
    /// [`convolve_batch`](Self::convolve_batch): returns the real
    /// output samples.
    pub fn convolve(&self, rt: &Runtime, x: &[f32]) -> Result<Vec<f32>> {
        crate::ensure!(x.len() == self.n, "length {} != {}", x.len(), self.n);
        let out = self.convolve_batch(rt, PlanarBatch::from_real(x, vec![1, self.n]))?;
        Ok(out.re)
    }
}

/// O(n^2) f64 circular convolution — the oracle the spectral path is
/// validated against: `out[j] = sum_k x[(j - k) mod n] * h[k]`.
pub fn circular_convolve_ref(x: &[f64], h: &[f64]) -> Vec<f64> {
    let n = x.len();
    assert_eq!(h.len(), n);
    let mut out = vec![0.0; n];
    for (j, o) in out.iter_mut().enumerate() {
        let mut acc = 0.0;
        for (k, &hv) in h.iter().enumerate() {
            acc += x[(j + n - k) % n] * hv;
        }
        *o = acc;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hp::F16;
    use crate::workload::random_signal;

    fn rt() -> Runtime {
        Runtime::load("/definitely/not/a/dir").unwrap()
    }

    #[test]
    fn identity_filter_returns_the_signal() {
        let rt = rt();
        // h = delta: convolution is the identity
        let conv = SpectralConv::new(&rt, 64, &[1.0]).unwrap();
        let x: Vec<f32> = random_signal(64, 3).iter().map(|c| c.re).collect();
        let y = conv.convolve(&rt, &x).unwrap();
        for i in 0..64 {
            let q = F16::from_f32(x[i]).to_f32();
            assert!((y[i] - q).abs() < 0.01, "sample {i}: {} vs {q}", y[i]);
        }
    }

    #[test]
    fn matches_the_time_domain_oracle() {
        let rt = rt();
        let n = 128;
        let taps = [0.25f32, 0.5, 0.25, -0.1];
        let conv = SpectralConv::new(&rt, n, &taps).unwrap();
        let x: Vec<f32> = random_signal(n, 17).iter().map(|c| c.re).collect();
        let y = conv.convolve(&rt, &x).unwrap();
        // oracle over the fp16-quantized operands
        let xq: Vec<f64> = x.iter().map(|&v| F16::from_f32(v).to_f32() as f64).collect();
        let mut hq = vec![0.0f64; n];
        for (i, &t) in taps.iter().enumerate() {
            hq[i] = F16::from_f32(t).to_f32() as f64;
        }
        let want = circular_convolve_ref(&xq, &hq);
        let num: f64 = y
            .iter()
            .zip(&want)
            .map(|(&a, &b)| (a as f64 - b) * (a as f64 - b))
            .sum();
        let den: f64 = want.iter().map(|&w| w * w).sum();
        let rmse = (num / den.max(f64::MIN_POSITIVE)).sqrt();
        assert!(rmse < 1e-2, "conv vs oracle rel-RMSE {rmse:.3e}");
    }

    #[test]
    fn matched_filter_peaks_at_the_injected_lag() {
        let rt = rt();
        let n = 256;
        let template: Vec<f32> = (0..32)
            .map(|i| ((i as f32 * 0.9).sin() * (1.0 - i as f32 / 40.0)))
            .collect();
        let inject_at = 77usize;
        let mut strain = vec![0f32; n];
        for (i, &t) in template.iter().enumerate() {
            strain[(inject_at + i) % n] += 0.8 * t;
        }
        // mild noise
        for (i, s) in strain.iter_mut().enumerate() {
            *s += 0.02 * (((i * 37 + 5) % 19) as f32 / 19.0 - 0.5);
        }
        let mf = SpectralConv::matched_filter(&rt, n, &template).unwrap();
        let y = mf.convolve(&rt, &strain).unwrap();
        let peak = y
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        assert_eq!(peak, inject_at, "matched filter missed the injection");
    }

    #[test]
    fn rejects_oversized_filters() {
        let rt = rt();
        assert!(SpectralConv::new(&rt, 16, &[0.0; 17]).is_err());
    }
}
