//! Spectral convolution of real signals — the workload the R2C/C2R
//! path exists for: FIR filtering and matched filtering computed as
//! `irfft(rfft(x) * H)` with both transforms running through the
//! half-precision real-FFT plans.
//!
//! A [`SpectralConv`] is a **filter bank**: `k >= 1` filters whose
//! packed spectra `H_f` are computed once at build time (one batched
//! R2C pass over the zero-padded tap rows). Each
//! [`convolve_batch`](SpectralConv::convolve_batch) call then applies
//! every filter to every input signal in ONE planar round trip: one
//! R2C over the `b` input rows, one O(b*k*n) pointwise complex
//! multiply on the host, and one C2R over the `b*k` product rows —
//! against `2*b*k` full-size complex transforms for the
//! promote-to-complex alternative.
//!
//! # The 1/n normalization folding
//!
//! Every inverse in this crate is UNNORMALIZED (`irfft(rfft(x)) =
//! n * x`, the cuFFT convention), so a naive spectral convolution
//! would come back scaled by `n`. The `1/n` correction is folded into
//! the pointwise multiply — each product bin is scaled by `1/n` before
//! the C2R — which (a) lands the output at unit scale with zero extra
//! passes and (b) keeps the C2R *input* inside fp16 range: the product
//! spectrum of unit-scale operands grows like `n`, and fp16 overflows
//! at 65504, so dividing after the inverse would already have clipped
//! on the device for large `n`. The multiply itself runs in f32 on the
//! host (it models the f32 epilogue of a fused device kernel, not an
//! fp16 store).
//!
//! Convolution is CIRCULAR (period `n`), the native product of the
//! DFT; callers wanting linear convolution zero-pad in the usual way.

use crate::error::Result;
use crate::plan::Plan;
use crate::runtime::{PlanarBatch, Runtime};

/// A prepared circular-convolution filter bank: `k` fixed real filters
/// applied to real length-`n` signals in the frequency domain.
///
/// Built by [`new`](Self::new) (one filter), [`matched_filter`](Self::matched_filter)
/// (one correlation filter), or [`new_bank`](Self::new_bank) (`k`
/// filters sharing one R2C/C2R plan pair).
pub struct SpectralConv {
    n: usize,
    k: usize,
    fwd: Plan,
    inv: Plan,
    /// packed filter spectra, row-major `[k, n/2 + 1]` (real plane)
    h_re: Vec<f32>,
    /// packed filter spectra, row-major `[k, n/2 + 1]` (imaginary plane)
    h_im: Vec<f32>,
}

impl SpectralConv {
    /// Build a single-filter convolver for signal length `n` (power of
    /// two >= 4) and the given FIR taps (`taps.len() <= n`;
    /// zero-padded).
    pub fn new(rt: &Runtime, n: usize, taps: &[f32]) -> Result<SpectralConv> {
        Self::new_bank(rt, n, &[taps])
    }

    /// Build a `k`-filter bank: every filter's packed spectrum is
    /// computed in one batched R2C pass, and
    /// [`convolve_batch`](Self::convolve_batch) applies all `k` to a
    /// whole signal batch per call. Each tap row may be any length
    /// `<= n` (zero-padded independently).
    ///
    /// ```
    /// use tcfft::runtime::{PlanarBatch, Runtime};
    /// use tcfft::workload::SpectralConv;
    ///
    /// let rt = Runtime::load_default().unwrap();
    /// let bank = SpectralConv::new_bank(
    ///     &rt,
    ///     256,
    ///     &[vec![0.25f32, 0.5, 0.25], vec![1.0, -1.0]], // smooth + edge
    /// )
    /// .unwrap();
    /// let x = PlanarBatch::from_real(&[0.0f32; 2 * 256], vec![2, 256]);
    /// let y = bank.convolve_batch(&rt, x).unwrap();
    /// assert_eq!(y.shape, vec![2, 2, 256]); // [batch, filter, samples]
    /// ```
    pub fn new_bank<T: AsRef<[f32]>>(
        rt: &Runtime,
        n: usize,
        filters: &[T],
    ) -> Result<SpectralConv> {
        Self::new_bank_algo(rt, n, filters, "tc")
    }

    /// [`new_bank`](Self::new_bank) with an explicit leaf algorithm
    /// (`"tc"` | `"tc_split"` | `"tc_ec"` | `"r2"`) for both transform plans — the
    /// constructor the service's guarded bank registration calls.
    pub fn new_bank_algo<T: AsRef<[f32]>>(
        rt: &Runtime,
        n: usize,
        filters: &[T],
        algo: &str,
    ) -> Result<SpectralConv> {
        use crate::plan::Direction;
        let k = filters.len();
        crate::ensure!(k >= 1, "filter bank must hold at least one filter");
        for (f, taps) in filters.iter().enumerate() {
            crate::ensure!(
                taps.as_ref().len() <= n,
                "filter {f} ({}) longer than signal ({n})",
                taps.as_ref().len()
            );
        }
        let fwd = Plan::rfft1d_algo(&rt.registry, n, k, algo, Direction::Forward)?;
        let inv = Plan::rfft1d_algo(&rt.registry, n, k, algo, Direction::Inverse)?;
        let mut h = PlanarBatch::new(vec![k, n]);
        for (f, taps) in filters.iter().enumerate() {
            let taps = taps.as_ref();
            h.re[f * n..f * n + taps.len()].copy_from_slice(taps);
        }
        let spec = fwd.execute(rt, h)?;
        Ok(SpectralConv { n, k, fwd, inv, h_re: spec.re, h_im: spec.im })
    }

    /// Build a matched filter for a real template: circular correlation
    /// against the template, i.e. convolution with its time reversal.
    /// The output of [`convolve`](Self::convolve) then peaks at the lag
    /// where the template sits in the input.
    pub fn matched_filter(rt: &Runtime, n: usize, template: &[f32]) -> Result<SpectralConv> {
        crate::ensure!(template.len() <= n, "template longer than signal");
        let mut taps = vec![0f32; n];
        for (i, &t) in template.iter().enumerate() {
            taps[(n - i) % n] = t;
        }
        Self::new(rt, n, &taps)
    }

    /// The signal length `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The number of filters in the bank.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Estimated resident bytes for cache accounting: the packed
    /// filter spectra (the dominant term, `2 * k * (n/2 + 1)` f32s)
    /// plus the two bound plans.
    pub fn memory_bytes(&self) -> usize {
        (self.h_re.len() + self.h_im.len()) * std::mem::size_of::<f32>()
            + self.fwd.memory_bytes()
            + self.inv.memory_bytes()
    }

    /// Circularly convolve a batch of real rows (`[b, n]`, samples in
    /// the `re` plane) with every filter of the bank, in one planar
    /// round trip: one R2C over the `b` rows, the pointwise product
    /// against all `k` filter spectra (f32, `1/n` folded in — see the
    /// module docs), one C2R over the `b*k` product rows. Output shape
    /// `[b, k, n]`, results in the `re` plane at unit scale, ordered
    /// `[signal][filter]`.
    pub fn convolve_batch(&self, rt: &Runtime, x: PlanarBatch) -> Result<PlanarBatch> {
        crate::ensure!(
            x.shape.len() == 2 && x.shape[1] == self.n,
            "input shape {:?} != [b, {}]",
            x.shape,
            self.n
        );
        let b = x.shape[0];
        let spec = self.fwd.execute(rt, x)?;
        let bins = self.n / 2 + 1;
        let scale = 1.0 / self.n as f32;
        // the [b*k, bins] product spectra: row (row*k + f) = X_row * H_f
        let mut prod = PlanarBatch::new(vec![b * self.k, bins]);
        for row in 0..b {
            let sb = row * bins;
            for f in 0..self.k {
                let hb = f * bins;
                let pb = (row * self.k + f) * bins;
                for kk in 0..bins {
                    let (xr, xi) = (spec.re[sb + kk], spec.im[sb + kk]);
                    let (hr, hi) = (self.h_re[hb + kk], self.h_im[hb + kk]);
                    prod.re[pb + kk] = (xr * hr - xi * hi) * scale;
                    prod.im[pb + kk] = (xr * hi + xi * hr) * scale;
                }
            }
        }
        let out = self.inv.execute(rt, prod)?;
        Ok(PlanarBatch { re: out.re, im: out.im, shape: vec![b, self.k, self.n] })
    }

    /// Single-signal, single-filter convenience over
    /// [`convolve_batch`](Self::convolve_batch): returns the real
    /// output samples. Errors on multi-filter banks — address those
    /// through the batch API, whose output carries the filter axis.
    pub fn convolve(&self, rt: &Runtime, x: &[f32]) -> Result<Vec<f32>> {
        crate::ensure!(x.len() == self.n, "length {} != {}", x.len(), self.n);
        crate::ensure!(self.k == 1, "convolve() is for single-filter banks (k = {})", self.k);
        let out = self.convolve_batch(rt, PlanarBatch::from_real(x, vec![1, self.n]))?;
        Ok(out.re)
    }
}

/// O(n^2) f64 circular convolution — the oracle the spectral path is
/// validated against: `out[j] = sum_k x[(j - k) mod n] * h[k]`.
pub fn circular_convolve_ref(x: &[f64], h: &[f64]) -> Vec<f64> {
    let n = x.len();
    assert_eq!(h.len(), n);
    let mut out = vec![0.0; n];
    for (j, o) in out.iter_mut().enumerate() {
        let mut acc = 0.0;
        for (k, &hv) in h.iter().enumerate() {
            acc += x[(j + n - k) % n] * hv;
        }
        *o = acc;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hp::F16;
    use crate::workload::random_signal;

    fn rt() -> Runtime {
        Runtime::load("/definitely/not/a/dir").unwrap()
    }

    #[test]
    fn identity_filter_returns_the_signal() {
        let rt = rt();
        // h = delta: convolution is the identity
        let conv = SpectralConv::new(&rt, 64, &[1.0]).unwrap();
        assert_eq!(conv.k(), 1);
        let x: Vec<f32> = random_signal(64, 3).iter().map(|c| c.re).collect();
        let y = conv.convolve(&rt, &x).unwrap();
        for i in 0..64 {
            let q = F16::from_f32(x[i]).to_f32();
            assert!((y[i] - q).abs() < 0.01, "sample {i}: {} vs {q}", y[i]);
        }
    }

    #[test]
    fn matches_the_time_domain_oracle() {
        let rt = rt();
        let n = 128;
        let taps = [0.25f32, 0.5, 0.25, -0.1];
        let conv = SpectralConv::new(&rt, n, &taps).unwrap();
        let x: Vec<f32> = random_signal(n, 17).iter().map(|c| c.re).collect();
        let y = conv.convolve(&rt, &x).unwrap();
        // oracle over the fp16-quantized operands
        let xq: Vec<f64> = x.iter().map(|&v| F16::from_f32(v).to_f32() as f64).collect();
        let mut hq = vec![0.0f64; n];
        for (i, &t) in taps.iter().enumerate() {
            hq[i] = F16::from_f32(t).to_f32() as f64;
        }
        let want = circular_convolve_ref(&xq, &hq);
        let num: f64 = y
            .iter()
            .zip(&want)
            .map(|(&a, &b)| (a as f64 - b) * (a as f64 - b))
            .sum();
        let den: f64 = want.iter().map(|&w| w * w).sum();
        let rmse = (num / den.max(f64::MIN_POSITIVE)).sqrt();
        assert!(rmse < 1e-2, "conv vs oracle rel-RMSE {rmse:.3e}");
    }

    #[test]
    fn bank_matches_per_filter_single_convolutions() {
        // a k-filter bank over a b-signal batch must reproduce each
        // (signal, filter) pair's single-filter result exactly — the
        // bank batches the SAME plans, it does not change the math
        let rt = rt();
        let n = 128;
        let filters: Vec<Vec<f32>> = vec![
            vec![1.0],
            vec![0.25, 0.5, 0.25],
            (0..16).map(|i| 0.4 / (1.0 + i as f32)).collect(),
        ];
        let bank = SpectralConv::new_bank(&rt, n, &filters).unwrap();
        assert_eq!(bank.k(), 3);
        let x: Vec<f32> = (0..2)
            .flat_map(|b| random_signal(n, 90 + b as u64))
            .map(|c| c.re)
            .collect();
        let out = bank
            .convolve_batch(&rt, PlanarBatch::from_real(&x, vec![2, n]))
            .unwrap();
        assert_eq!(out.shape, vec![2, 3, n]);
        for (f, taps) in filters.iter().enumerate() {
            let single = SpectralConv::new(&rt, n, taps).unwrap();
            for row in 0..2 {
                let want = single.convolve(&rt, &x[row * n..(row + 1) * n]).unwrap();
                let got = &out.re[(row * 3 + f) * n..(row * 3 + f + 1) * n];
                for i in 0..n {
                    assert!(
                        (got[i] - want[i]).abs() < 1e-3,
                        "row {row} filter {f} sample {i}: {} vs {}",
                        got[i],
                        want[i]
                    );
                }
            }
        }
    }

    #[test]
    fn bank_filters_match_the_oracle_per_filter() {
        // each filter of the bank against the O(n^2) time-domain
        // oracle on the fp16-quantized operands
        let rt = rt();
        let n = 256;
        let filters: Vec<Vec<f32>> = vec![
            vec![0.5, 0.25, 0.125],
            vec![1.0, -1.0],
        ];
        let bank = SpectralConv::new_bank(&rt, n, &filters).unwrap();
        let x: Vec<f32> = random_signal(n, 44).iter().map(|c| c.re).collect();
        let out = bank
            .convolve_batch(&rt, PlanarBatch::from_real(&x, vec![1, n]))
            .unwrap();
        let xq: Vec<f64> = x.iter().map(|&v| F16::from_f32(v).to_f32() as f64).collect();
        for (f, taps) in filters.iter().enumerate() {
            let mut hq = vec![0.0f64; n];
            for (i, &t) in taps.iter().enumerate() {
                hq[i] = F16::from_f32(t).to_f32() as f64;
            }
            let want = circular_convolve_ref(&xq, &hq);
            let got = &out.re[f * n..(f + 1) * n];
            let num: f64 = got
                .iter()
                .zip(&want)
                .map(|(&a, &b)| (a as f64 - b) * (a as f64 - b))
                .sum();
            let den: f64 = want.iter().map(|&w| w * w).sum();
            let rmse = (num / den.max(f64::MIN_POSITIVE)).sqrt();
            assert!(rmse < 1e-2, "filter {f} vs oracle rel-RMSE {rmse:.3e}");
        }
    }

    #[test]
    fn matched_filter_peaks_at_the_injected_lag() {
        let rt = rt();
        let n = 256;
        let template: Vec<f32> = (0..32)
            .map(|i| ((i as f32 * 0.9).sin() * (1.0 - i as f32 / 40.0)))
            .collect();
        let inject_at = 77usize;
        let mut strain = vec![0f32; n];
        for (i, &t) in template.iter().enumerate() {
            strain[(inject_at + i) % n] += 0.8 * t;
        }
        // mild noise
        for (i, s) in strain.iter_mut().enumerate() {
            *s += 0.02 * (((i * 37 + 5) % 19) as f32 / 19.0 - 0.5);
        }
        let mf = SpectralConv::matched_filter(&rt, n, &template).unwrap();
        let y = mf.convolve(&rt, &strain).unwrap();
        let peak = y
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        assert_eq!(peak, inject_at, "matched filter missed the injection");
    }

    #[test]
    fn rejects_oversized_filters_and_empty_banks() {
        let rt = rt();
        assert!(SpectralConv::new(&rt, 16, &[0.0; 17]).is_err());
        assert!(SpectralConv::new_bank::<Vec<f32>>(&rt, 16, &[]).is_err());
        let bank = SpectralConv::new_bank(&rt, 16, &[vec![1.0], vec![0.5]]).unwrap();
        let x = vec![0f32; 16];
        assert!(bank.convolve(&rt, &x).is_err(), "convolve() must reject k > 1");
    }
}
