//! Precision recovery for half-precision FFTs — the paper's future
//! work item #2 ("introduce some precision recovery algorithms to
//! improve the precision of tcFFT on low precision Matrix Operation
//! Units"), in the style of EGEMM-TC [Feng et al., PPoPP'21].
//!
//! Idea: fp16 quantization error of the *input* dominates the error
//! floor for well-scaled signals.  Split each input value into two
//! fp16 numbers, `hi = fp16(x)` and `lo = fp16(x - hi)`; since the DFT
//! is linear, `FFT(x) = FFT(hi) + FFT(lo)`.  Running the existing fp16
//! artifact twice and combining in f32 recovers most of the input
//! quantization error at exactly 2x the device cost.  The pipeline's
//! internal fp16 rounding (twiddles, intermediate stores) is NOT
//! recovered — measured gains are therefore bounded, and reported
//! honestly by `examples`/benches.

use crate::error::Result;
use crate::hp::F16;
use crate::plan::Plan;
use crate::runtime::{PlanarBatch, Runtime};

/// Split a planar batch into (hi, lo) fp16-representable parts.
pub fn split_hi_lo(x: &PlanarBatch) -> (PlanarBatch, PlanarBatch) {
    let mut hi = PlanarBatch::new(x.shape.clone());
    let mut lo = PlanarBatch::new(x.shape.clone());
    for i in 0..x.len() {
        let hr = F16::from_f32(x.re[i]).to_f32();
        let hi_i = F16::from_f32(x.im[i]).to_f32();
        hi.re[i] = hr;
        hi.im[i] = hi_i;
        lo.re[i] = x.re[i] - hr;
        lo.im[i] = x.im[i] - hi_i;
    }
    (hi, lo)
}

/// Execute a plan with hi/lo precision recovery: two device passes,
/// f32 combination on the host.
pub fn execute_recovered(plan: &Plan, rt: &Runtime, x: &PlanarBatch) -> Result<PlanarBatch> {
    let (hi, lo) = split_hi_lo(x);
    let y_hi = plan.execute(rt, hi)?;
    let y_lo = plan.execute(rt, lo)?;
    let mut out = y_hi;
    for i in 0..out.len() {
        out.re[i] += y_lo.re[i];
        out.im[i] += y_lo.im[i];
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hp::C32;

    #[test]
    fn split_reconstructs_exactly_for_fp16_values() {
        let xs: Vec<C32> = (0..64).map(|i| C32::new(0.125 * i as f32, -1.0)).collect();
        let b = PlanarBatch::from_complex(&xs, vec![1, 64]);
        let (hi, lo) = split_hi_lo(&b);
        for i in 0..64 {
            assert_eq!(hi.re[i] + lo.re[i], b.re[i]);
            // exactly representable values leave no residual
            assert_eq!(lo.im[i], 0.0);
        }
    }

    #[test]
    fn split_residual_is_small() {
        // residual is bounded by half an fp16 ulp of the value
        let mut rng = crate::util::rng::SplitMix64::new(3);
        for _ in 0..200 {
            let x = rng.uniform(-1.0, 1.0) as f32;
            let h = F16::from_f32(x).to_f32();
            let lo = x - h;
            assert!(lo.abs() <= 2f32.powi(-11) * x.abs().max(2f32.powi(-14)) * 1.01);
            // and the residual encodes to fp16 with at most one more
            // rounding step (subnormal residuals round absolutely)
            let requant = (F16::from_f32(lo).to_f32() - lo).abs();
            assert!(requant <= lo.abs() * 2f32.powi(-11) + 2f32.powi(-24));
        }
    }
}
