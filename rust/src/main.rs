//! tcFFT CLI — the launcher.
//!
//! Subcommands:
//!   info                         list artifacts + plans
//!   plan   --n N | --nx X --ny Y show the kernel schedule for a size
//!   run    --n N [--batch B]     run a random-input FFT, check vs oracle
//!   serve  --addr HOST:PORT      TCP JSON service
//!   bench  --n N [--iters K]     quick throughput measurement
//!   bench-validate [--file F]    check BENCH_interp.json (CI smoke step)
//!   precision                    Table 4 (relative error vs f64 oracle)
//!   table2                       memsim Table 2
//!   figures                      perfmodel Figs 4-7 summaries

use std::sync::Arc;

use tcfft::coordinator::{FftService, Server, ServiceConfig};
use tcfft::error::{relative_error, Result};
use tcfft::fft::mixed::fft_mixed_batch;
use tcfft::hp::C64;
use tcfft::plan::schedule::kernel_schedule;
use tcfft::plan::{Direction, Plan};
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::util::cli::Args;
use tcfft::util::table::Table;
use tcfft::workload::random_signal;

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("info") => info(),
        Some("plan") => plan_cmd(args),
        Some("run") => run_cmd(args),
        Some("serve") => serve_cmd(args),
        Some("bench") => bench_cmd(args),
        Some("bench-validate") => bench_validate_cmd(args),
        Some("precision") => precision_cmd(args),
        Some("table2") => {
            println!("{}", tcfft::memsim::table2::render());
            Ok(())
        }
        Some("figures") => figures_cmd(),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand '{o}'\n");
            }
            print!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "\
tcfft — half-precision matrix-formulated FFT (tcFFT reproduction)

USAGE: tcfft <SUBCOMMAND> [OPTIONS]

  info                          list loaded artifacts
  plan --n N | --nx X --ny Y    show the merging-kernel schedule
  run --n N [--batch B] [--algo tc|tc_split|tc_ec|r2] [--real]
  run --real --nx X --ny Y [--batch B]
                                execute on random input, verify vs f64
                                oracle (--real: R2C half-spectrum path,
                                1D by --n or 2D by --nx/--ny)
  serve [--addr 127.0.0.1:7070] TCP JSON FFT service
  bench --n N [--batch B]       quick wall-clock throughput
  bench-validate [--file BENCH_interp.json]
                                validate the bench JSON emitted by
                                fig4_1d/fig7_batch/large_fourstep/
                                rfft_1d/rfft_2d/rfft2d_large/e2e_serve/
                                table4_precision (run those first; see
                                BENCHMARKS.md for the schema)
  precision                     Table 4: relative error vs FFTW-f64 stand-in
  table2                        Table 2: memsim bandwidth vs continuous size
  figures                       Figs 4-7: modelled V100/A100 series
";

fn info() -> Result<()> {
    let rt = Runtime::load_default()?;
    let mut t = Table::new(&["key", "op", "algo", "shape", "batch", "dir", "stages"]);
    for v in rt.registry.variants.values() {
        let shape = if v.op == "fft2d" || v.op == "rfft2d" {
            format!("{}x{}", v.nx, v.ny)
        } else {
            format!("{}", v.n)
        };
        t.row(vec![
            v.key.clone(),
            v.op.clone(),
            v.algo.clone(),
            shape,
            v.batch.to_string(),
            if v.inverse { "inv" } else { "fwd" }.into(),
            v.stages.len().to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn plan_cmd(args: &Args) -> Result<()> {
    let render = |n: usize, lane: usize| {
        let mut t = Table::new(&["#", "kernel", "radix", "n2", "lane", "VMEM"]);
        for (i, st) in kernel_schedule(n, lane).iter().enumerate() {
            t.row(vec![
                i.to_string(),
                st.kernel.to_string(),
                st.radix.to_string(),
                st.n2.to_string(),
                st.lane.to_string(),
                tcfft::util::table::fmt_bytes(st.vmem_bytes() as f64),
            ]);
        }
        t.render()
    };
    if let Some(nx) = args.get("nx") {
        let nx: usize = nx.parse()?;
        let ny = args.get_usize("ny", nx);
        println!("2D {nx}x{ny}: pass 1 (contiguous, n={ny}):\n{}", render(ny, 1));
        println!("pass 2 (strided, n={nx}, lane={ny}):\n{}", render(nx, ny));
    } else {
        let n = args.get_usize("n", 4096);
        println!("1D n={n}:\n{}", render(n, 1));
    }
    Ok(())
}

fn run_cmd(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 4096);
    let batch = args.get_usize("batch", 4);
    let algo = args.get_str("algo", "tc");
    let rt = Runtime::load_default()?;
    if args.has_flag("real") {
        if let Some(nx) = args.get("nx") {
            let nx: usize = nx.parse()?;
            let ny = args.get_usize("ny", nx);
            return run_real_2d_cmd(&rt, nx, ny, batch, algo);
        }
        return run_real_cmd(&rt, n, batch, algo);
    }
    let plan = Plan::fft1d_algo(&rt.registry, n, batch, algo, Direction::Forward)?;
    println!("plan: {} (artifact batch {})", plan.meta.key, plan.meta.batch);

    let x: Vec<_> = (0..batch)
        .flat_map(|b| random_signal(n, 42 + b as u64))
        .collect();
    let input = PlanarBatch::from_complex(&x, vec![batch, n]);
    let t0 = std::time::Instant::now();
    let out = plan.execute(&rt, input.clone())?;
    let dt = t0.elapsed().as_secs_f64();

    // verify against the f64 mixed-radix oracle on the fp16-quantized input
    let q = input.quantize_f16();
    let xq: Vec<C64> = q
        .to_complex()
        .iter()
        .map(|c| C64::new(c.re as f64, c.im as f64))
        .collect();
    let want = fft_mixed_batch(&xq, batch, n, false);
    let got: Vec<C64> = out
        .to_complex()
        .iter()
        .map(|c| C64::new(c.re as f64, c.im as f64))
        .collect();
    let mut worst = 0.0f64;
    for b in 0..batch {
        let e = relative_error(&want[b * n..(b + 1) * n], &got[b * n..(b + 1) * n]);
        worst = worst.max(e);
    }
    println!(
        "executed {batch}x{n}-point {algo} FFT in {:.2} ms  |  max mean-relative-error {:.3e}",
        dt * 1e3,
        worst
    );
    tcfft::ensure!(worst < 0.05, "relative error too high");
    println!("OK");
    Ok(())
}

/// `run --real`: R2C forward on random real rows, verified against the
/// f64 oracle on the Hermitian-packed bins. The requested `--algo`
/// passes through (and fails loudly if the catalog has no real variant
/// for it, rather than silently verifying `tc`).
fn run_real_cmd(rt: &Runtime, n: usize, batch: usize, algo: &str) -> Result<()> {
    let plan = Plan::rfft1d_algo(&rt.registry, n, batch, algo, Direction::Forward)?;
    println!("plan: {} (artifact batch {})", plan.meta.key, plan.meta.batch);
    let sig: Vec<f32> = (0..batch)
        .flat_map(|b| random_signal(n, 42 + b as u64))
        .map(|c| c.re)
        .collect();
    let input = PlanarBatch::from_real(&sig, vec![batch, n]);
    let t0 = std::time::Instant::now();
    let out = plan.execute(rt, input.clone())?;
    let dt = t0.elapsed().as_secs_f64();
    let bins = n / 2 + 1;
    tcfft::ensure!(out.shape == vec![batch, bins], "packed shape {:?}", out.shape);

    let q = input.quantize_f16();
    let xq: Vec<C64> = q
        .to_complex()
        .iter()
        .map(|c| C64::new(c.re as f64, c.im as f64))
        .collect();
    let want = fft_mixed_batch(&xq, batch, n, false);
    let got: Vec<C64> = out
        .to_complex()
        .iter()
        .map(|c| C64::new(c.re as f64, c.im as f64))
        .collect();
    let mut worst = 0.0f64;
    for b in 0..batch {
        let e = relative_error(&want[b * n..b * n + bins], &got[b * bins..(b + 1) * bins]);
        worst = worst.max(e);
    }
    println!(
        "executed {batch}x{n}-point R2C FFT in {:.2} ms  |  max mean-relative-error {:.3e}",
        dt * 1e3,
        worst
    );
    tcfft::ensure!(worst < 0.05, "relative error too high");
    println!("OK");
    Ok(())
}

/// `run --real --nx X --ny Y`: R2C forward on random real fields,
/// verified against the shared f64 2D oracle (`fft::oracle2d`) on the
/// packed `[nx, ny/2 + 1]` Hermitian bins.
fn run_real_2d_cmd(rt: &Runtime, nx: usize, ny: usize, batch: usize, algo: &str) -> Result<()> {
    let plan = Plan::rfft2d_algo(&rt.registry, nx, ny, batch, algo, Direction::Forward)?;
    println!("plan: {} (artifact batch {})", plan.meta.key, plan.meta.batch);
    let sig: Vec<f32> = (0..batch)
        .flat_map(|b| random_signal(nx * ny, 42 + b as u64))
        .map(|c| c.re)
        .collect();
    let input = PlanarBatch::from_real(&sig, vec![batch, nx, ny]);
    let t0 = std::time::Instant::now();
    let out = plan.execute(rt, input.clone())?;
    let dt = t0.elapsed().as_secs_f64();
    let bins = ny / 2 + 1;
    tcfft::ensure!(out.shape == vec![batch, nx, bins], "packed shape {:?}", out.shape);

    let q = input.quantize_f16();
    let xq: Vec<C64> = q
        .to_complex()
        .iter()
        .map(|c| C64::new(c.re as f64, c.im as f64))
        .collect();
    let got: Vec<C64> = out
        .to_complex()
        .iter()
        .map(|c| C64::new(c.re as f64, c.im as f64))
        .collect();
    let mut worst = 0.0f64;
    for b in 0..batch {
        let field = &xq[b * nx * ny..(b + 1) * nx * ny];
        let want = tcfft::fft::oracle2d(field, nx, ny, false);
        let want_packed: Vec<C64> = (0..nx)
            .flat_map(|r| want[r * ny..r * ny + bins].to_vec())
            .collect();
        let e = relative_error(&want_packed, &got[b * nx * bins..(b + 1) * nx * bins]);
        worst = worst.max(e);
    }
    println!(
        "executed {batch}x{nx}x{ny}-point 2D R2C FFT in {:.2} ms  |  max mean-relative-error {:.3e}",
        dt * 1e3,
        worst
    );
    tcfft::ensure!(worst < 0.05, "relative error too high");
    println!("OK");
    Ok(())
}

fn serve_cmd(args: &Args) -> Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7070");
    let rt = Arc::new(Runtime::load_default()?);
    let svc = Arc::new(FftService::start(rt, ServiceConfig::default()));
    let server = Server::bind(addr, Arc::clone(&svc))?;
    println!("tcfft service listening on {}", server.local_addr()?);
    server.run()
}

fn bench_cmd(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 4096);
    let batch = args.get_usize("batch", 4);
    let algo = args.get_str("algo", "tc");
    let rt = Runtime::load_default()?;
    let plan = Plan::fft1d_algo(&rt.registry, n, batch, algo, Direction::Forward)?;
    let x: Vec<_> = (0..batch).flat_map(|b| random_signal(n, b as u64)).collect();
    let input = PlanarBatch::from_complex(&x, vec![batch, n]);
    plan.execute(&rt, input.clone())?; // warm (compile)
    let r = tcfft::bench_harness::bench(
        &format!("fft1d n={n} b={batch} {algo}"),
        || {
            plan.execute(&rt, input.clone()).unwrap();
        },
        args.get_usize("iters", 50),
    );
    println!("{}", r.report());
    let r2 = tcfft::plan::schedule::radix2_equivalent_flops(n, batch);
    println!(
        "radix-2-equivalent throughput: {:.3} GFLOPS (CPU interpret mode)",
        r2 / r.summary.median() / 1e9
    );
    Ok(())
}

/// CI smoke check: `BENCH_interp.json` (emitted by the fig4_1d,
/// fig7_batch, large_fourstep, rfft_1d, rfft_2d, rfft2d_large,
/// e2e_serve and table4_precision benches) parses, carries the
/// expected schema, and holds the headline before/after entry, the
/// batch-sweep anchor, the four-step large-FFT acceptance entry, the
/// 1D and 2D R2C-vs-C2C acceptance entries, the large-2D composition
/// entry, the 64-client serving entry, the tc_ec accuracy-gain entry
/// (>= 10x), and the tc_ec time-cost entry (its "speedup" is tc/tc_ec
/// and is expected below 1). The schema and every entry key are
/// documented in BENCHMARKS.md.
fn bench_validate_cmd(args: &Args) -> Result<()> {
    use tcfft::bench_harness::BENCH_SCHEMA;
    use tcfft::util::json::Json;

    const HEADLINE: &str = "fft1d_tc_n4096_b32_fwd";
    const SWEEP_ANCHOR: &str = "fft1d_tc_n131072_b1_fwd";
    const FOURSTEP: &str = "fourstep_tc_n1048576_b8_fwd";
    const RFFT: &str = "rfft1d_tc_n4096_b32_fwd";
    const RFFT2D: &str = "rfft2d_tc_nx256x256_b8_fwd";
    const RFFT2D_LARGE: &str = "rfft2d_tc_nx2048x2048_b4_fwd";
    const E2E: &str = "e2e_serve_tc_n4096_c64";
    const PRECISION_EC: &str = "precision_tc_ec_n4096_b32";
    const EC_COST: &str = "fft1d_tc_ec_n4096_b32_fwd";

    // same default resolution as the emitting benches (cwd-independent)
    let default_file = tcfft::bench_harness::bench_json_path().display().to_string();
    let file = args.get_str("file", &default_file);
    let text = std::fs::read_to_string(file)
        .map_err(|e| tcfft::error::TcFftError::msg(format!("reading {file}: {e}")))?;
    let doc = Json::parse(&text)
        .map_err(|e| tcfft::error::TcFftError::msg(format!("{file}: parse error: {e}")))?;
    tcfft::ensure!(
        doc.get("schema").and_then(|s| s.as_str()) == Some(BENCH_SCHEMA),
        "{file}: missing/unexpected schema (want {BENCH_SCHEMA})"
    );
    let entries = match doc.get("entries") {
        Some(e @ Json::Obj(m)) if !m.is_empty() => e.clone(),
        _ => tcfft::bail!("{file}: no entries — run the fig4_1d/fig7_batch benches first"),
    };

    let pos = |key: &str, field: &str| -> Result<f64> {
        let v = entries
            .get(key)
            .and_then(|e| e.get(field))
            .and_then(|x| x.as_f64())
            .ok_or_else(|| {
                tcfft::error::TcFftError::msg(format!("{file}: {key}.{field} missing"))
            })?;
        tcfft::ensure!(v.is_finite() && v > 0.0, "{file}: {key}.{field} = {v} not positive");
        Ok(v)
    };

    // the acceptance headline: before AND after numbers plus speedups
    let m_ref = pos(HEADLINE, "reference_median_s")?;
    let m_ser = pos(HEADLINE, "engine_serial_median_s")?;
    let m_par = pos(HEADLINE, "engine_median_s")?;
    pos(HEADLINE, "speedup")?;
    pos(HEADLINE, "speedup_serial")?;
    // the fig7 sweep anchor
    pos(SWEEP_ANCHOR, "engine_median_s")?;
    // the large-FFT acceptance entry: batched four-step engine vs the
    // kept per-sequence baseline at n=2^20 batch=8
    let m4_ref = pos(FOURSTEP, "reference_median_s")?;
    let m4_par = pos(FOURSTEP, "engine_median_s")?;
    pos(FOURSTEP, "engine_serial_median_s")?;
    pos(FOURSTEP, "speedup")?;
    // the real-input acceptance entry: R2C vs the same-size C2C
    // transform (the "reference" median IS the C2C run)
    let mr_c2c = pos(RFFT, "reference_median_s")?;
    let mr_r2c = pos(RFFT, "engine_median_s")?;
    pos(RFFT, "engine_serial_median_s")?;
    pos(RFFT, "speedup")?;
    // the 2D real-input acceptance entry: 2D R2C vs same-shape C2C
    let m2_c2c = pos(RFFT2D, "reference_median_s")?;
    let m2_r2c = pos(RFFT2D, "engine_median_s")?;
    pos(RFFT2D, "engine_serial_median_s")?;
    pos(RFFT2D, "speedup")?;
    // the large-2D acceptance entry: Plan2d composition (the service's
    // large rfft2d route) vs the per-sequence baseline composition
    let ml_ref = pos(RFFT2D_LARGE, "reference_median_s")?;
    let ml_par = pos(RFFT2D_LARGE, "engine_median_s")?;
    pos(RFFT2D_LARGE, "engine_serial_median_s")?;
    pos(RFFT2D_LARGE, "speedup")?;
    // the serving acceptance entry: 64 closed-loop clients through the
    // sharded service core vs the raw batch-4 runtime path
    let me_raw = pos(E2E, "reference_median_s")?;
    let me_c64 = pos(E2E, "engine_median_s")?;
    pos(E2E, "engine_serial_median_s")?;
    pos(E2E, "speedup")?;
    // the precision-ladder acceptance entry (table4_precision): the
    // medians are rel-RMSE values (reference = tc, engine = tc_ec), so
    // "speedup" is the accuracy gain of the error-corrected tier
    let mp_tc = pos(PRECISION_EC, "reference_median_s")?;
    let mp_ec = pos(PRECISION_EC, "engine_median_s")?;
    let mp_gain = pos(PRECISION_EC, "speedup")?;
    tcfft::ensure!(
        mp_gain >= 10.0,
        "{file}: {PRECISION_EC} accuracy gain {mp_gain:.1}x below the 10x floor"
    );
    // the tc_ec time-cost entry (fig4_1d part 4): the "reference" median
    // is the plain tc engine at the same shape, so speedup = tc/tc_ec —
    // the multiply overhead the accuracy gain above is paid for with
    let mc_tc = pos(EC_COST, "reference_median_s")?;
    let mc_ec = pos(EC_COST, "engine_median_s")?;
    pos(EC_COST, "engine_serial_median_s")?;
    pos(EC_COST, "speedup")?;

    let mut t = Table::new(&["entry", "bench", "engine median ms", "speedup vs pre-PR"]);
    if let Json::Obj(m) = &entries {
        for (k, e) in m {
            t.row(vec![
                k.clone(),
                e.get("bench").and_then(|b| b.as_str()).unwrap_or("?").to_string(),
                e.get("engine_median_s")
                    .and_then(|x| x.as_f64())
                    .map(|x| format!("{:.2}", x * 1e3))
                    .unwrap_or_else(|| "-".into()),
                e.get("speedup")
                    .and_then(|x| x.as_f64())
                    .map(|x| format!("{x:.2}x"))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "headline {HEADLINE}: reference {:.2} ms -> engine {:.2} ms serial / {:.2} ms parallel ({:.2}x)",
        m_ref * 1e3,
        m_ser * 1e3,
        m_par * 1e3,
        m_ref / m_par
    );
    println!(
        "large-FFT {FOURSTEP}: per-seq baseline {:.1} ms -> batched engine {:.1} ms ({:.2}x)",
        m4_ref * 1e3,
        m4_par * 1e3,
        m4_ref / m4_par
    );
    println!(
        "real-input {RFFT}: C2C {:.2} ms -> R2C {:.2} ms ({:.2}x)",
        mr_c2c * 1e3,
        mr_r2c * 1e3,
        mr_c2c / mr_r2c
    );
    println!(
        "real-input 2D {RFFT2D}: C2C {:.2} ms -> R2C {:.2} ms ({:.2}x)",
        m2_c2c * 1e3,
        m2_r2c * 1e3,
        m2_c2c / m2_r2c
    );
    println!(
        "large-2D {RFFT2D_LARGE}: baseline composed {:.1} ms -> Plan2d {:.1} ms ({:.2}x)",
        ml_ref * 1e3,
        ml_par * 1e3,
        ml_ref / ml_par
    );
    println!(
        "serving {E2E}: raw per-seq {:.2} ms -> 64-client per-seq {:.2} ms ({:.2}x)",
        me_raw * 1e3,
        me_c64 * 1e3,
        me_raw / me_c64
    );
    println!(
        "precision {PRECISION_EC}: tc rel-RMSE {mp_tc:.3e} -> tc_ec {mp_ec:.3e} ({mp_gain:.0}x more accurate)"
    );
    println!(
        "ec cost {EC_COST}: tc {:.2} ms -> tc_ec {:.2} ms ({:.2}x the tc time)",
        mc_tc * 1e3,
        mc_ec * 1e3,
        mc_ec / mc_tc
    );
    println!("bench-validate: OK ({file})");
    Ok(())
}

fn precision_cmd(_args: &Args) -> Result<()> {
    println!("run `cargo bench --bench table4_precision` for the full table;");
    println!("quick version over two artifacts:\n");
    let rt = Runtime::load_default()?;
    let mut t = Table::new(&["artifact", "rel err", "paper band"]);
    for key in ["fft1d_tc_n4096_b4_fwd", "fft1d_r2_n4096_b4_fwd"] {
        if let Ok(meta) = rt.registry.get(key) {
            let n = meta.n;
            let b = meta.batch;
            let x: Vec<_> = (0..b).flat_map(|i| random_signal(n, 7 + i as u64)).collect();
            let input = PlanarBatch::from_complex(&x, vec![b, n]);
            let (out, _) = rt.execute(key, input.clone())?;
            let q = input.quantize_f16();
            let xq: Vec<C64> = q
                .to_complex()
                .iter()
                .map(|c| C64::new(c.re as f64, c.im as f64))
                .collect();
            let want = fft_mixed_batch(&xq, b, n, false);
            let got: Vec<C64> = out
                .to_complex()
                .iter()
                .map(|c| C64::new(c.re as f64, c.im as f64))
                .collect();
            let e = relative_error(&want, &got);
            t.row(vec![key.into(), format!("{e:.3e}"), "~1.7e-2 (paper, half)".into()]);
        }
    }
    println!("{}", t.render());
    Ok(())
}

fn figures_cmd() -> Result<()> {
    use tcfft::perfmodel::{figures as f, GpuSpec};
    let v100 = GpuSpec::v100();
    let a100 = GpuSpec::a100();
    println!("{}", f::render_series("Fig 4(a): 1D FFT, V100 (modelled TFLOPS)", "TFLOPS", &f::fig4_series(&v100)));
    println!("{}", f::render_series("Fig 4(b): 1D FFT, A100 (modelled TFLOPS)", "TFLOPS", &f::fig4_series(&a100)));
    println!("{}", f::render_series("Fig 5(a): 2D FFT, V100", "TFLOPS", &f::fig5_series(&v100)));
    println!("{}", f::render_series("Fig 5(b): 2D FFT, A100", "TFLOPS", &f::fig5_series(&a100)));
    println!("{}", f::render_series("Fig 6(a): 1D bandwidth, V100", "GB/s", &f::fig6_series_1d(&v100)));
    println!("{}", f::render_series("Fig 6(b): 2D bandwidth, V100", "GB/s", &f::fig6_series_2d(&v100)));
    println!("{}", f::render_series("Fig 7(a): 1D 131072-pt batch sweep, V100", "TFLOPS", &f::fig7a_series(&v100)));
    println!("{}", f::render_series("Fig 7(b): 2D 512x256 batch sweep, V100", "TFLOPS", &f::fig7b_series(&v100)));
    Ok(())
}
