//! Bench harness (criterion is unavailable offline): warmup + timed
//! iterations with summary statistics, and a tiny registration macro so
//! `cargo bench` binaries share structure.

use crate::util::stats::{time_iters, Summary};

pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} median {:>10.3} ms  mean {:>10.3} ms  p99 {:>10.3} ms  (n={})",
            self.name,
            self.summary.median() * 1e3,
            self.summary.mean() * 1e3,
            self.summary.p99() * 1e3,
            self.summary.len(),
        )
    }
}

/// Run a closure with warmup; auto-scales iteration count so quick
/// benches get more samples (min 5, max `max_iters`).
pub fn bench<F: FnMut()>(name: &str, mut f: F, max_iters: usize) -> BenchResult {
    // one probe run to size the iteration count
    let t0 = std::time::Instant::now();
    f();
    let probe = t0.elapsed().as_secs_f64();
    let target_time = 2.0; // seconds per bench
    let lo = 5usize.min(max_iters.max(1));
    let hi = max_iters.max(1).max(lo);
    let iters = ((target_time / probe.max(1e-6)) as usize).clamp(lo, hi);
    let warmup = (iters / 5).clamp(1, 10);
    let summary = time_iters(f, warmup, iters);
    BenchResult { name: name.to_string(), summary }
}

/// Standard header printed by every bench binary.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "host: {} | artifacts: {}",
        std::env::consts::ARCH,
        std::env::var("TCFFT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_samples() {
        let r = bench("noop", || { std::hint::black_box(1 + 1); }, 50);
        assert!(r.summary.len() >= 5);
        assert!(r.report().contains("noop"));
    }
}
