//! Bench harness (criterion is unavailable offline): warmup + timed
//! iterations with summary statistics, plus the machine-readable
//! `BENCH_interp.json` emitter that records the repo's perf trajectory
//! (pre-PR reference engine vs the batch-major parallel engine).
//!
//! Env knobs:
//! * `TCFFT_BENCH_SMOKE=1` — capped iterations / reduced matrix, for
//!   the CI smoke step (entries are still emitted);
//! * `TCFFT_BENCH_JSON` — output path. Default: `BENCH_interp.json`
//!   at the **workspace root**, resolved from `CARGO_MANIFEST_DIR` so
//!   it is independent of the invoker's cwd (`cargo bench` runs bench
//!   binaries with cwd = the package root `rust/`, while `cargo run`
//!   inherits the caller's cwd — both must agree on one file).

use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::util::stats::{time_iters, Summary};

pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} median {:>10.3} ms  mean {:>10.3} ms  p99 {:>10.3} ms  (n={})",
            self.name,
            self.summary.median() * 1e3,
            self.summary.mean() * 1e3,
            self.summary.p99() * 1e3,
            self.summary.len(),
        )
    }
}

/// Run a closure with warmup; auto-scales iteration count so quick
/// benches get more samples (min 5, max `max_iters`).
pub fn bench<F: FnMut()>(name: &str, mut f: F, max_iters: usize) -> BenchResult {
    // one probe run to size the iteration count
    let t0 = std::time::Instant::now();
    f();
    let probe = t0.elapsed().as_secs_f64();
    let target_time = 2.0; // seconds per bench
    let lo = 5usize.min(max_iters.max(1));
    let hi = max_iters.max(1).max(lo);
    let iters = ((target_time / probe.max(1e-6)) as usize).clamp(lo, hi);
    let warmup = (iters / 5).clamp(1, 10);
    let summary = time_iters(f, warmup, iters);
    BenchResult { name: name.to_string(), summary }
}

/// True when the CI smoke mode is on: benches shrink their matrix and
/// iteration counts but still emit every expected JSON entry.
pub fn smoke() -> bool {
    std::env::var("TCFFT_BENCH_SMOKE").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

/// Resolve the `BENCH_interp.json` path: `TCFFT_BENCH_JSON` if set,
/// else `<workspace-root>/BENCH_interp.json` (cwd-independent — see
/// the module docs for why).
pub fn bench_json_path() -> PathBuf {
    if let Ok(p) = std::env::var("TCFFT_BENCH_JSON") {
        return PathBuf::from(p);
    }
    // the crate lives in <workspace-root>/rust
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().unwrap_or(manifest).join("BENCH_interp.json")
}

/// Schema tag checked by `tcfft bench-validate`.
pub const BENCH_SCHEMA: &str = "tcfft-bench-interp/1";

/// Merge `entries` into `BENCH_interp.json` (keyed by artifact key, so
/// `fig4_1d` and `fig7_batch` can each contribute their slice without
/// clobbering the other's). Creates the file if missing or unreadable.
pub fn update_bench_json(entries: &[(String, Json)]) -> std::io::Result<PathBuf> {
    let path = bench_json_path();
    let mut existing = std::fs::read_to_string(&path)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .and_then(|j| match j.get("entries") {
            Some(Json::Obj(m)) => Some(m.clone()),
            _ => None,
        })
        .unwrap_or_default();
    for (k, v) in entries {
        existing.insert(k.clone(), v.clone());
    }
    let doc = Json::obj(vec![
        ("schema", Json::str(BENCH_SCHEMA)),
        ("host_arch", Json::str(std::env::consts::ARCH)),
        ("entries", Json::Obj(existing)),
    ]);
    std::fs::write(&path, doc.to_string() + "\n")?;
    Ok(path)
}

/// Standard per-entry payload: before/after medians plus the speedup.
pub fn bench_entry(
    bench: &str,
    threads: usize,
    iters: usize,
    reference_median_s: f64,
    engine_serial_median_s: f64,
    engine_median_s: f64,
) -> Json {
    Json::obj(vec![
        ("bench", Json::str(bench)),
        ("threads", Json::num(threads as f64)),
        ("iters", Json::num(iters as f64)),
        ("reference_median_s", Json::num(reference_median_s)),
        ("engine_serial_median_s", Json::num(engine_serial_median_s)),
        ("engine_median_s", Json::num(engine_median_s)),
        ("speedup_serial", Json::num(reference_median_s / engine_serial_median_s)),
        ("speedup", Json::num(reference_median_s / engine_median_s)),
        ("smoke", Json::Bool(smoke())),
    ])
}

/// Standard header printed by every bench binary.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "host: {} | artifacts: {}",
        std::env::consts::ARCH,
        std::env::var("TCFFT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_samples() {
        let r = bench("noop", || { std::hint::black_box(1 + 1); }, 50);
        assert!(r.summary.len() >= 5);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn bench_entry_shape() {
        let e = bench_entry("fig4_1d", 4, 12, 0.4, 0.2, 0.1);
        assert_eq!(e.get("bench").and_then(|v| v.as_str()), Some("fig4_1d"));
        assert_eq!(e.get("threads").and_then(|v| v.as_usize()), Some(4));
        let sp = e.get("speedup").and_then(|v| v.as_f64()).unwrap();
        assert!((sp - 4.0).abs() < 1e-12);
        let sps = e.get("speedup_serial").and_then(|v| v.as_f64()).unwrap();
        assert!((sps - 2.0).abs() < 1e-12);
        // round-trips through the writer grammar
        let parsed = Json::parse(&e.to_string()).unwrap();
        assert_eq!(parsed.get("iters").and_then(|v| v.as_usize()), Some(12));
    }
}
