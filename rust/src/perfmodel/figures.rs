//! Figure-series generators: the exact size/batch grids of the paper's
//! Figs 4-7, rendered as tables by the bench binaries.

use super::{model_fft1d, model_fft2d, Algo, GpuSpec};
use crate::util::table::Table;

/// Paper's 1D size grid: 2^8 .. 2^27.
pub fn fig4_sizes() -> Vec<usize> {
    (8..=27).map(|t| 1usize << t).collect()
}

/// "Batch size big enough to fully utilize" (paper TestCase): cap total
/// work at ~2^24 points.
pub fn big_batch(n: usize) -> usize {
    ((1usize << 24) / n).max(1)
}

/// Paper's 2D shapes (Fig 5): six common sizes.
pub const FIG5_SHAPES: [(usize, usize); 6] = [
    (256, 256),
    (256, 512),
    (256, 1024),
    (512, 256),
    (512, 512),
    (512, 1024),
];

/// One modelled figure row.
pub struct SeriesPoint {
    pub label: String,
    pub tcfft: f64,
    pub tcfft_unopt: f64,
    pub cufft: f64,
}

impl SeriesPoint {
    pub fn speedup(&self) -> f64 {
        self.tcfft / self.cufft
    }
}

/// Fig 4: 1D TFLOPS vs size for one GPU.
pub fn fig4_series(gpu: &GpuSpec) -> Vec<SeriesPoint> {
    fig4_sizes()
        .into_iter()
        .map(|n| {
            let b = big_batch(n);
            SeriesPoint {
                label: format!("2^{}", n.trailing_zeros()),
                tcfft: model_fft1d(gpu, Algo::TcFft, n, b).tflops_r2,
                tcfft_unopt: model_fft1d(gpu, Algo::TcFftUnopt, n, b).tflops_r2,
                cufft: model_fft1d(gpu, Algo::CuFftHalf, n, b).tflops_r2,
            }
        })
        .collect()
}

/// Fig 5: 2D TFLOPS for the six shapes.
pub fn fig5_series(gpu: &GpuSpec) -> Vec<SeriesPoint> {
    FIG5_SHAPES
        .iter()
        .map(|&(nx, ny)| {
            let b = ((1usize << 24) / (nx * ny)).max(1);
            SeriesPoint {
                label: format!("{nx}x{ny}"),
                tcfft: model_fft2d(gpu, Algo::TcFft, nx, ny, b).tflops_r2,
                tcfft_unopt: model_fft2d(gpu, Algo::TcFftUnopt, nx, ny, b).tflops_r2,
                cufft: model_fft2d(gpu, Algo::CuFftHalf, nx, ny, b).tflops_r2,
            }
        })
        .collect()
}

/// Fig 6: useful global-memory throughput (GB/s), 1D and 2D, V100.
pub fn fig6_series_1d(gpu: &GpuSpec) -> Vec<SeriesPoint> {
    fig4_sizes()
        .into_iter()
        .map(|n| {
            let b = big_batch(n);
            SeriesPoint {
                label: format!("2^{}", n.trailing_zeros()),
                tcfft: model_fft1d(gpu, Algo::TcFft, n, b).bw_useful / 1e9,
                tcfft_unopt: model_fft1d(gpu, Algo::TcFftUnopt, n, b).bw_useful / 1e9,
                cufft: model_fft1d(gpu, Algo::CuFftHalf, n, b).bw_useful / 1e9,
            }
        })
        .collect()
}

pub fn fig6_series_2d(gpu: &GpuSpec) -> Vec<SeriesPoint> {
    FIG5_SHAPES
        .iter()
        .map(|&(nx, ny)| {
            let b = ((1usize << 24) / (nx * ny)).max(1);
            SeriesPoint {
                label: format!("{nx}x{ny}"),
                tcfft: model_fft2d(gpu, Algo::TcFft, nx, ny, b).bw_useful / 1e9,
                tcfft_unopt: model_fft2d(gpu, Algo::TcFftUnopt, nx, ny, b).bw_useful / 1e9,
                cufft: model_fft2d(gpu, Algo::CuFftHalf, nx, ny, b).bw_useful / 1e9,
            }
        })
        .collect()
}

/// Fig 7a: TFLOPS vs batch at 131072 points; Fig 7b: 2D 512x256.
pub fn fig7a_series(gpu: &GpuSpec) -> Vec<SeriesPoint> {
    (0..=7)
        .map(|t| {
            let b = 1usize << t;
            SeriesPoint {
                label: b.to_string(),
                tcfft: model_fft1d(gpu, Algo::TcFft, 131072, b).tflops_r2,
                tcfft_unopt: model_fft1d(gpu, Algo::TcFftUnopt, 131072, b).tflops_r2,
                cufft: model_fft1d(gpu, Algo::CuFftHalf, 131072, b).tflops_r2,
            }
        })
        .collect()
}

pub fn fig7b_series(gpu: &GpuSpec) -> Vec<SeriesPoint> {
    (0..=7)
        .map(|t| {
            let b = 1usize << t;
            SeriesPoint {
                label: b.to_string(),
                tcfft: model_fft2d(gpu, Algo::TcFft, 512, 256, b).tflops_r2,
                tcfft_unopt: model_fft2d(gpu, Algo::TcFftUnopt, 512, 256, b).tflops_r2,
                cufft: model_fft2d(gpu, Algo::CuFftHalf, 512, 256, b).tflops_r2,
            }
        })
        .collect()
}

/// Render a series with a speedup column.
pub fn render_series(title: &str, unit: &str, pts: &[SeriesPoint]) -> String {
    let mut t = Table::new(&["size/batch", &format!("tcFFT {unit}"),
        &format!("unopt-TC {unit}"), &format!("cuFFT {unit}"), "tc/cuFFT"]);
    for p in pts {
        t.row(vec![
            p.label.clone(),
            format!("{:.2}", p.tcfft),
            format!("{:.2}", p.tcfft_unopt),
            format!("{:.2}", p.cufft),
            format!("{:.2}x", p.speedup()),
        ]);
    }
    format!("{title}\n{}", t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_has_19_sizes() {
        assert_eq!(fig4_sizes().len(), 20);
    }

    #[test]
    fn fig4_v100_trend() {
        let pts = fig4_series(&GpuSpec::v100());
        // small sizes bandwidth-bound: speedup ~1; largest sizes >1.5x
        assert!(pts[0].speedup() < 1.15);
        assert!(pts.last().unwrap().speedup() > 1.5);
        // optimized tcFFT never loses to the un-optimized variant
        for p in &pts {
            assert!(p.tcfft >= p.tcfft_unopt * 0.999, "{}", p.label);
        }
    }

    #[test]
    fn fig5_512_rows_beat_256_rows() {
        // paper: speedup 3.24x at nx=512 vs 1.29x at nx=256
        let pts = fig5_series(&GpuSpec::v100());
        let s256 = pts[0].speedup();
        let s512 = pts[3].speedup();
        assert!(s512 > s256, "512-row {s512:.2} vs 256-row {s256:.2}");
    }

    #[test]
    fn fig7_monotone_in_batch() {
        let pts = fig7a_series(&GpuSpec::v100());
        for w in pts.windows(2) {
            assert!(w[1].tcfft >= w[0].tcfft * 0.99);
        }
    }

    #[test]
    fn render_contains_speedup_column() {
        let s = render_series("t", "TFLOPS", &fig7a_series(&GpuSpec::v100()));
        assert!(s.contains("tc/cuFFT"));
    }
}
