//! Analytic roofline model of tcFFT and cuFFT-half on V100/A100
//! (paper Figs 4-7).
//!
//! CPU interpret-mode wall-clock says nothing about Tensor-Core GPUs,
//! so the figure *shapes* are regenerated from first principles.  A
//! transform is a sequence of global-memory PASSES; each pass merges a
//! radix product of up to 8192 through shared memory (the paper's
//! merging-kernel collection covers radices 16..8192; cuFFT's smem
//! kernels are comparable).  Per pass:
//!
//! * memory time = bytes / achievable_bw(continuous size), with the
//!   continuous size determined by the library's data arrangement —
//!   tcFFT's Sec 4.2 redesign keeps accesses coalesced on strided
//!   passes, cuFFT-half degrades (paper Fig 6);
//! * compute time = flops / (engine peak x efficiency) — Tensor Cores
//!   for tcFFT merges, CUDA cores for cuFFT butterflies;
//! * passes whose working set fits shared memory overlap compute with
//!   memory (max); strided passes block-synchronize and serialize
//!   (mem + compute), the paper's Sec 5.3 observation;
//! * chip utilization scales with total concurrent work (Fig 7).
//!
//! The A100 keeps the same structure with 1.73x bandwidth, 2.5x
//! compute, and a larger L2 that lifts the *uncoalesced* baseline's
//! strided continuous size — reproducing the paper's finding that
//! tcFFT's margin shrinks on Ampere (1.90x -> 1.24x average).
//!
//! All constants are documented; benches print model vs paper speedups
//! so deviations are visible, and tests assert the qualitative claims.

pub mod figures;

use crate::memsim::MemModel;

/// GPU platform description (paper Table 1/3).
#[derive(Clone, Debug)]
pub struct GpuSpec {
    pub name: &'static str,
    /// FP16 CUDA-core peak (flops/s)
    pub fp16_cuda: f64,
    /// FP16 Tensor-Core peak (flops/s)
    pub fp16_tc: f64,
    pub mem: MemModel,
    /// continuous size of the uncoalesced baseline on strided 1D passes
    /// (larger on A100: 40 MB L2 absorbs part of the stride penalty)
    pub cufft_strided_cont: usize,
    /// same for 2D column passes with few rows (<= 256)
    pub cufft_2d_small_cont: usize,
}

impl GpuSpec {
    pub fn v100() -> GpuSpec {
        GpuSpec {
            name: "V100",
            fp16_cuda: 31.4e12,
            fp16_tc: 125e12,
            mem: crate::memsim::calibrate(MemModel::v100()).0,
            cufft_strided_cont: 4,
            cufft_2d_small_cont: 8,
        }
    }

    pub fn a100() -> GpuSpec {
        let v = crate::memsim::calibrate(MemModel::v100()).0;
        GpuSpec {
            name: "A100",
            fp16_cuda: 78e12,
            fp16_tc: 312e12,
            mem: MemModel {
                peak_bw: 1555e9,
                smem_per_sm: 164.0 * 1024.0,
                request_rate: v.request_rate * 1555.0 / 900.0,
                ..v
            },
            cufft_strided_cont: 8,
            cufft_2d_small_cont: 12,
        }
    }
}

/// Which library is being modelled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// tcFFT with both optimizations (Sec 4.1 + 4.2)
    TcFft,
    /// tcFFT without the fragment-level optimization (Sec 5.4 ablation)
    TcFftUnopt,
    /// cuFFT half-precision kernels on CUDA cores
    CuFftHalf,
}

/// Model constants.
mod k {
    /// max radix product one shared-memory pass can merge (paper: the
    /// merging kernel collection tops out at radix 8192)
    pub const PASS_RADIX_MAX_LOG2: usize = 13;
    /// Tensor-Core utilization of the radix-16 merge pipeline
    pub const TC_EFF: f64 = 0.25;
    /// CUDA-core utilization of butterfly kernels
    pub const CUDA_EFF: f64 = 0.50;
    /// tcFFT flops per element per radix-16 sub-merge (16x16 complex
    /// MAC row + twiddle, amortized per element)
    pub const TC_FLOPS_PER_SUBMERGE: f64 = 28.0;
    /// cuFFT flops per element per radix-2-equivalent level
    pub const CU_FLOPS_PER_LEVEL: f64 = 10.0;
    /// compute penalty without the Sec 4.1 fragment optimization:
    /// twiddle + complex split bounce through shared memory
    pub const UNOPT_COMPUTE_PENALTY: f64 = 2.4;
    /// bytes of concurrent work that saturate the chip
    pub const TC_SAT_BYTES: f64 = 2.0 * 1024.0 * 1024.0;
    pub const CU_SAT_BYTES: f64 = 0.5 * 1024.0 * 1024.0;
    /// minimum chip utilization (tiny single transforms)
    pub const MIN_UTIL: f64 = 0.02;
}

/// One global-memory pass.
#[derive(Clone, Debug)]
struct Pass {
    /// log2 of the radix product merged by this pass
    levels: usize,
    /// element stride at the pass input (1 = contiguous)
    stride: usize,
    /// true for the 2D first-axis (lane-contiguous for tcFFT)
    lane_contig: bool,
}

/// Greedy pass decomposition: merge up to 2^13 per smem pass.
fn passes_for_axis(n: usize, axis_stride: usize, lane_contig: bool) -> Vec<Pass> {
    let mut t = n.trailing_zeros() as usize;
    let mut out = Vec::new();
    let mut n2 = 1usize;
    while t > 0 {
        let step = t.min(k::PASS_RADIX_MAX_LOG2);
        out.push(Pass {
            levels: step,
            stride: n2 * axis_stride,
            lane_contig,
        });
        n2 <<= step;
        t -= step;
    }
    out
}

impl Pass {
    /// Element span a block must gather: radix x stride.  A pass is
    /// shared-memory-resident iff the span fits (~8192 fp16 complex).
    fn span(&self) -> usize {
        (1usize << self.levels) * self.stride
    }

    fn smem_resident(&self) -> bool {
        self.span() <= 8192 && !self.lane_contig
    }
}

/// Continuous size the library achieves on a pass.
fn cont_size(gpu: &GpuSpec, algo: Algo, p: &Pass) -> usize {
    match algo {
        Algo::CuFftHalf => {
            if p.smem_resident() {
                32 // smem-resident contiguous pass: coalesced
            } else if p.lane_contig {
                // 2D column pass: smem tile transpose helps small spans
                if p.span() <= 65536 {
                    gpu.cufft_2d_small_cont
                } else {
                    gpu.cufft_strided_cont
                }
            } else {
                gpu.cufft_strided_cont
            }
        }
        _ => {
            // tcFFT Sec 4.2: in-place changing order + variable
            // continuous size keeps accesses coalesced
            if p.smem_resident() || p.lane_contig {
                32
            } else if p.stride <= 65536 {
                16
            } else {
                8
            }
        }
    }
}

/// Modelled cost of one transform.
#[derive(Clone, Debug, Default)]
pub struct Cost {
    pub seconds: f64,
    pub mem_seconds: f64,
    pub compute_seconds: f64,
    pub hbm_bytes: f64,
    /// radix-2-equivalent TFLOPS (paper eq. 4)
    pub tflops_r2: f64,
    /// useful global-memory throughput (bytes/s)
    pub bw_useful: f64,
}

fn model_passes(gpu: &GpuSpec, algo: Algo, passes: &[Pass], total_elems: f64, util: f64) -> Cost {
    let mut cost = Cost::default();
    for p in passes {
        let bytes = 2.0 * 4.0 * total_elems; // read + write planar fp16
        let bw = gpu.mem.achievable_bw(cont_size(gpu, algo, p)) * util;
        let mem_t = bytes / bw;
        let (flops_pe, peak, eff) = match algo {
            Algo::CuFftHalf => (
                k::CU_FLOPS_PER_LEVEL * p.levels as f64,
                gpu.fp16_cuda,
                k::CUDA_EFF,
            ),
            _ => {
                // ceil(levels/4) radix-16 sub-merges per pass
                let sub = (p.levels + 3) / 4;
                (
                    k::TC_FLOPS_PER_SUBMERGE * sub as f64,
                    gpu.fp16_tc,
                    k::TC_EFF,
                )
            }
        };
        let mut comp_t = flops_pe * total_elems / (peak * eff * util);
        if algo == Algo::TcFftUnopt {
            comp_t *= k::UNOPT_COMPUTE_PENALTY;
        }
        // overlap rule (paper Sec 5.3): smem-resident passes overlap;
        // strided passes synchronize across blocks and serialize
        let t = if p.smem_resident() {
            mem_t.max(comp_t)
        } else {
            mem_t + comp_t
        };
        cost.seconds += t;
        cost.mem_seconds += mem_t;
        cost.compute_seconds += comp_t;
        cost.hbm_bytes += bytes;
    }
    cost
}

fn utilization(algo: Algo, total_elems: f64) -> f64 {
    let work_bytes = 4.0 * total_elems;
    let sat = match algo {
        Algo::CuFftHalf => k::CU_SAT_BYTES,
        _ => k::TC_SAT_BYTES,
    };
    (work_bytes / sat).min(1.0).max(k::MIN_UTIL)
}

/// Model a batched 1D FFT.
pub fn model_fft1d(gpu: &GpuSpec, algo: Algo, n: usize, batch: usize) -> Cost {
    let total = (n * batch) as f64;
    let util = utilization(algo, total);
    let passes = passes_for_axis(n, 1, false);
    let mut cost = model_passes(gpu, algo, &passes, total, util);
    finish(&mut cost, n, batch);
    cost
}

/// Model a batched 2D FFT (row-major nx x ny).
pub fn model_fft2d(gpu: &GpuSpec, algo: Algo, nx: usize, ny: usize, batch: usize) -> Cost {
    let total = (nx * ny * batch) as f64;
    let util = utilization(algo, total);
    let mut passes = passes_for_axis(ny, 1, false);
    passes.extend(passes_for_axis(nx, ny, true));
    let mut cost = model_passes(gpu, algo, &passes, total, util);
    finish(&mut cost, nx * ny, batch);
    cost
}

fn finish(cost: &mut Cost, n: usize, batch: usize) {
    let r2 = crate::plan::schedule::radix2_equivalent_flops(n, batch);
    cost.tflops_r2 = r2 / cost.seconds / 1e12;
    cost.bw_useful = cost.hbm_bytes / cost.mem_seconds.max(1e-30);
}

/// Convenience: modelled speedup of tcFFT over cuFFT-half.
pub fn speedup_1d(gpu: &GpuSpec, n: usize, batch: usize) -> f64 {
    let tc = model_fft1d(gpu, Algo::TcFft, n, batch);
    let cu = model_fft1d(gpu, Algo::CuFftHalf, n, batch);
    cu.seconds / tc.seconds
}

pub fn speedup_2d(gpu: &GpuSpec, nx: usize, ny: usize, batch: usize) -> f64 {
    let tc = model_fft2d(gpu, Algo::TcFft, nx, ny, batch);
    let cu = model_fft2d(gpu, Algo::CuFftHalf, nx, ny, batch);
    cu.seconds / tc.seconds
}

#[cfg(test)]
mod tests {
    use super::*;

    fn big_batch(n: usize) -> usize {
        // paper: "batch size big enough to fully utilize the GPU"
        ((1 << 24) / n).max(1)
    }

    #[test]
    fn bandwidth_bound_small_sizes_are_close() {
        // paper Sec 5.3: short 1D FFTs: tcFFT reaches 96.4%-97.8% of
        // cuFFT on V100 (both bandwidth-bound). Model: within 10%.
        let gpu = GpuSpec::v100();
        for n in [256usize, 512, 1024, 4096, 8192] {
            let s = speedup_1d(&gpu, n, big_batch(n));
            assert!((0.90..=1.10).contains(&s), "n={n} speedup {s}");
        }
    }

    #[test]
    fn long_1d_speedup_matches_paper_band_v100() {
        // paper: minimum 1.84x, average 1.90x on V100 for non-bw-bound
        let gpu = GpuSpec::v100();
        let mut sum = 0.0;
        let mut cnt = 0.0;
        for t in 14..=27 {
            let n = 1usize << t;
            let s = speedup_1d(&gpu, n, big_batch(n));
            assert!((1.4..=2.8).contains(&s), "n=2^{t} speedup {s:.2}");
            sum += s;
            cnt += 1.0;
        }
        let avg = sum / cnt;
        assert!((1.6..=2.4).contains(&avg), "avg V100 speedup {avg:.2} (paper 1.90)");
    }

    #[test]
    fn a100_speedup_smaller_than_v100() {
        // paper: A100 average 1.24x < V100 1.90x
        let v = GpuSpec::v100();
        let a = GpuSpec::a100();
        let mut sv = 0.0;
        let mut sa = 0.0;
        for t in 14..=27 {
            let n = 1usize << t;
            sv += speedup_1d(&v, n, big_batch(n));
            sa += speedup_1d(&a, n, big_batch(n));
        }
        assert!(sa < sv, "V100 sum {sv:.2} vs A100 sum {sa:.2}");
        assert!(sa / 14.0 > 1.0, "tcFFT must still win on A100: {:.2}", sa / 14.0);
        assert!(sa / 14.0 < 1.7, "A100 advantage too large: {:.2}", sa / 14.0);
    }

    #[test]
    fn fft2d_with_512_first_dim_has_large_speedup() {
        // paper: 512-row 2D FFTs: 3.24x (V100); 256-row: 1.29x
        let gpu = GpuSpec::v100();
        let s512 = speedup_2d(&gpu, 512, 256, 128);
        let s256 = speedup_2d(&gpu, 256, 256, 256);
        assert!(s512 > 1.8, "2D 512x256 speedup {s512:.2}");
        assert!(s512 > s256, "512-row {s512:.2} must beat 256-row {s256:.2}");
    }

    #[test]
    fn unopt_ablation_band() {
        // paper Sec 5.4: fragment optimization buys 1.15x-1.32x
        let gpu = GpuSpec::v100();
        for t in [14usize, 17, 20, 24] {
            let n = 1usize << t;
            let tc = model_fft1d(&gpu, Algo::TcFft, n, big_batch(n));
            let un = model_fft1d(&gpu, Algo::TcFftUnopt, n, big_batch(n));
            let r = un.seconds / tc.seconds;
            assert!((1.05..=1.6).contains(&r), "n=2^{t} ablation ratio {r:.2}");
        }
    }

    #[test]
    fn batch_crossover_fig7a() {
        // paper Fig 7a: at 131072 points, tcFFT overtakes cuFFT once
        // batch size exceeds ~4; speedup grows with batch
        let gpu = GpuSpec::v100();
        let hi = speedup_1d(&gpu, 131072, 64);
        let lo = speedup_1d(&gpu, 131072, 1);
        assert!(hi > 1.4, "batch 64 speedup {hi:.2}");
        assert!(lo < hi, "speedup must grow with batch: {lo:.2} vs {hi:.2}");
    }

    #[test]
    fn tcfft_bandwidth_beats_cufft_on_long_ffts() {
        // paper Fig 6a: tcFFT sustains ~2x cuFFT's bandwidth on
        // moderate/long sizes
        let gpu = GpuSpec::v100();
        let n = 1 << 20;
        let tc = model_fft1d(&gpu, Algo::TcFft, n, 16);
        let cu = model_fft1d(&gpu, Algo::CuFftHalf, n, 16);
        let ratio = tc.bw_useful / cu.bw_useful;
        assert!((1.4..=3.5).contains(&ratio), "bw ratio {ratio:.2}");
    }
}
