//! Tiny argv parser (no clap offline): subcommand + `--key value` /
//! `--flag` options + positional arguments.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        // positionals come before options (or use --key=value); a bare
        // token after `--flag` would be consumed as its value
        let a = parse("run input.bin --n 4096 --batch 4 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get_usize("n", 0), 4096);
        assert_eq!(a.get_usize("batch", 0), 4);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["input.bin"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("serve --port=7000");
        assert_eq!(a.get_usize("port", 0), 7000);
    }

    #[test]
    fn flag_before_end() {
        let a = parse("bench --quick --n 8");
        assert!(a.has_flag("quick"));
        assert_eq!(a.get_usize("n", 0), 8);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_f64("missing", 1.5), 1.5);
        assert_eq!(a.get_str("missing", "d"), "d");
    }
}
