//! SplitMix64 PRNG — deterministic, fast, good-enough statistics for
//! workload generation and property tests (no `rand` crate offline).

/// SplitMix64 (Steele, Lea, Flood 2014). One u64 of state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Exponentially distributed sample with the given rate (1/mean) —
    /// used for Poisson arrival processes in the serving benchmarks.
    pub fn exp(&mut self, rate: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // (0, 1]
        -u.ln() / rate
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fork a statistically independent stream (for parallel workers).
    pub fn fork(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn known_first_outputs() {
        // reference values for seed 1234567 (from the published algorithm)
        let mut r = SplitMix64::new(1234567);
        let first = r.next_u64();
        let mut r2 = SplitMix64::new(1234567);
        assert_eq!(first, r2.next_u64());
        assert_ne!(first, r.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = SplitMix64::new(99);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.uniform(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64).abs() < 0.02, "mean {}", sum / n as f64);
    }

    #[test]
    fn exp_mean() {
        let mut r = SplitMix64::new(5);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(8);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02);
        assert!((var - 1.0).abs() < 0.05);
    }

    #[test]
    fn forked_streams_differ() {
        let mut a = SplitMix64::new(7);
        let mut b = a.fork();
        let xs: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
