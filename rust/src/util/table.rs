//! ASCII table rendering for bench/report output — prints the paper's
//! tables and figure series as aligned monospace rows.

/// A simple right-aligned table with a header row.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let sep = |out: &mut String| {
            for w in &width {
                out.push('+');
                out.push_str(&"-".repeat(w + 2));
            }
            out.push_str("+\n");
        };
        sep(&mut out);
        out.push('|');
        for (h, w) in self.headers.iter().zip(&width) {
            out.push_str(&format!(" {h:>w$} |", w = w));
        }
        out.push('\n');
        sep(&mut out);
        for row in &self.rows {
            out.push('|');
            for (c, w) in row.iter().zip(&width) {
                out.push_str(&format!(" {c:>w$} |", w = w));
            }
            out.push('\n');
        }
        sep(&mut out);
        out
    }
}

/// Format helpers shared by benches.
pub fn fmt_si(x: f64) -> String {
    let ax = x.abs();
    if ax >= 1e12 {
        format!("{:.2}T", x / 1e12)
    } else if ax >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if ax >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.2}K", x / 1e3)
    } else {
        format!("{x:.2}")
    }
}

pub fn fmt_bytes(x: f64) -> String {
    if x >= 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2} GiB", x / (1024.0 * 1024.0 * 1024.0))
    } else if x >= 1024.0 * 1024.0 {
        format!("{:.2} MiB", x / (1024.0 * 1024.0))
    } else if x >= 1024.0 {
        format!("{:.2} KiB", x / 1024.0)
    } else {
        format!("{x:.0} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["size", "GB/s"]);
        t.row(vec!["4".into(), "208.09".into()]);
        t.row(vec!["64".into(), "715.83".into()]);
        let s = t.render();
        assert!(s.contains("| size |   GB/s |"));
        assert!(s.contains("|    4 | 208.09 |"));
        assert_eq!(s.lines().count(), 6); // 3 separators + header + 2 rows
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn si_format() {
        assert_eq!(fmt_si(1.25e12), "1.25T");
        assert_eq!(fmt_si(3.0e9), "3.00G");
        assert_eq!(fmt_si(42.0), "42.00");
    }

    #[test]
    fn bytes_format() {
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
        assert_eq!(fmt_bytes(3.0 * 1024.0 * 1024.0), "3.00 MiB");
    }
}
