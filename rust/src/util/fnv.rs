//! Deterministic 64-bit FNV-1a hashing for cache fingerprints.
//!
//! Cache identity in the coordinator follows the content-fingerprint
//! rule from ROADMAP item 3: a cache key must be a pure function of
//! the *content* it names (transform descriptor, filter taps), stable
//! across processes and runs. `std::hash::DefaultHasher` explicitly
//! does not guarantee a stable algorithm between releases, so we roll
//! FNV-1a 64 — tiny, allocation-free, and fully specified.

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a 64 hasher for fingerprinting structured content
/// (mixed strings, integers and float bit patterns) without building
/// an intermediate byte buffer.
#[derive(Clone, Debug)]
pub struct Fnv1a(u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Fnv1a::new()
    }
}

impl Fnv1a {
    pub fn new() -> Self {
        Fnv1a(FNV_OFFSET)
    }

    /// Absorb raw bytes.
    pub fn write(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
        self
    }

    /// Absorb a string, then a NUL separator so `("ab","c")` and
    /// `("a","bc")` fingerprint differently.
    pub fn write_str(&mut self, s: &str) -> &mut Self {
        self.write(s.as_bytes()).write(&[0])
    }

    /// Absorb a u64 as little-endian bytes.
    pub fn write_u64(&mut self, x: u64) -> &mut Self {
        self.write(&x.to_le_bytes())
    }

    /// Absorb an f32 by bit pattern (so -0.0 != 0.0 and NaNs are
    /// distinguished — content identity, not numeric equality).
    pub fn write_f32(&mut self, x: f32) -> &mut Self {
        self.write(&x.to_bits().to_le_bytes())
    }

    /// The digest so far.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// One-shot FNV-1a 64 of a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.write(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85dd_1e2d_6b87_7f63);
    }

    #[test]
    fn deterministic_and_separated() {
        let mut a = Fnv1a::new();
        a.write_str("ab").write_str("c");
        let mut b = Fnv1a::new();
        b.write_str("a").write_str("bc");
        assert_ne!(a.finish(), b.finish());

        let mut c = Fnv1a::new();
        c.write_str("ab").write_str("c");
        assert_eq!(a.finish(), c.finish());
    }

    #[test]
    fn float_bit_patterns() {
        let mut a = Fnv1a::new();
        a.write_f32(0.0);
        let mut b = Fnv1a::new();
        b.write_f32(-0.0);
        assert_ne!(a.finish(), b.finish());
    }
}
