//! Summary statistics and timing helpers for the bench harness and the
//! service metrics (no criterion offline — we roll our own).

/// Online summary of a stream of f64 samples.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { samples: Vec::new() }
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Percentile by linear interpolation (q in [0, 1]).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
        }
    }

    /// Raw samples (for merging summaries).
    pub fn raw(&self) -> &[f64] {
        &self.samples
    }

    pub fn median(&self) -> f64 {
        self.percentile(0.5)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }
}

/// Default sample capacity of a [`Reservoir`] (the service metrics'
/// bounded window).
pub const DEFAULT_RESERVOIR: usize = 4096;

/// Bounded sample store for long-running services: a fixed-capacity
/// ring holding the most recent `capacity` samples, plus a lifetime
/// counter. Unlike [`Summary`], which keeps every sample forever (fine
/// for benches, a memory leak for a server), a `Reservoir` caps both
/// memory and the cost of a percentile query: `add` is O(1) and
/// percentiles copy-and-sort at most `capacity` values.
///
/// Percentiles are therefore *windowed* — they describe the most
/// recent `capacity` samples, which is what a serving dashboard wants
/// anyway (a p99 diluted by last week's traffic hides a regression).
#[derive(Clone, Debug)]
pub struct Reservoir {
    capacity: usize,
    buf: Vec<f64>,
    /// ring write cursor (valid once `buf` is full)
    next: usize,
    /// lifetime sample count (not capped)
    total: u64,
}

impl Default for Reservoir {
    fn default() -> Self {
        Reservoir::with_capacity(DEFAULT_RESERVOIR)
    }
}

impl Reservoir {
    /// Ring of at most `capacity` samples (clamped to >= 1).
    pub fn with_capacity(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Reservoir { capacity, buf: Vec::new(), next: 0, total: 0 }
    }

    /// Record one sample, overwriting the oldest once full. O(1).
    pub fn add(&mut self, x: f64) {
        self.total += 1;
        if self.buf.len() < self.capacity {
            self.buf.push(x);
        } else {
            self.buf[self.next] = x;
            self.next = (self.next + 1) % self.capacity;
        }
    }

    /// Samples currently held (<= capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when no sample has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// The configured capacity bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Lifetime samples recorded (including overwritten ones).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Mean of the retained window.
    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            return f64::NAN;
        }
        self.buf.iter().sum::<f64>() / self.buf.len() as f64
    }

    /// Windowed percentile by linear interpolation (q in [0, 1]).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.buf.is_empty() {
            return f64::NAN;
        }
        let mut s = self.buf.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = q.clamp(0.0, 1.0) * (s.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (pos - lo as f64) * (s[hi] - s[lo])
        }
    }

    /// Windowed median.
    pub fn median(&self) -> f64 {
        self.percentile(0.5)
    }

    /// Windowed 95th percentile.
    pub fn p95(&self) -> f64 {
        self.percentile(0.95)
    }

    /// Windowed 99th percentile.
    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }
}

/// Measure a closure `iters` times after `warmup` runs; returns seconds
/// per iteration samples.
pub fn time_iters<F: FnMut()>(mut f: F, warmup: usize, iters: usize) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        s.add(t0.elapsed().as_secs_f64());
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.std() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::new();
        for x in 0..100 {
            s.add(x as f64);
        }
        assert_eq!(s.median(), 49.5);
        assert!((s.percentile(0.99) - 98.01).abs() < 1e-9);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(1.0), 99.0);
    }

    #[test]
    fn empty_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.median().is_nan());
    }

    #[test]
    fn timing_runs() {
        let mut n = 0u64;
        let s = time_iters(|| n += 1, 2, 5);
        assert_eq!(s.len(), 5);
        assert_eq!(n, 7);
        assert!(s.min() >= 0.0);
    }
}
