//! Minimal JSON parser and writer (no serde offline).
//!
//! Supports the full JSON grammar; used for the artifact manifest, the
//! TCP wire protocol, and metrics dumps.  Numbers parse as f64 with an
//! integer fast path preserved through `as_i64`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Build an object from pairs (ergonomic constructor for writers).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(j.get("d"), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn round_trip() {
        let src = r#"{"k":[1,2.5,"x","\"q\"",null,true],"z":{"w":-7}}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn integer_preservation() {
        let j = Json::parse("1234567890123").unwrap();
        assert_eq!(j.as_i64(), Some(1234567890123));
        assert_eq!(Json::Num(1.5).as_i64(), None);
    }
}
