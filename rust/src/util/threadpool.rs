//! Fixed-size worker thread pool (no tokio offline).  The coordinator's
//! execution backend: jobs are boxed closures; the pool drains cleanly
//! on drop.  Channel-based, no unsafe.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let in_flight = Arc::clone(&in_flight);
                thread::Builder::new()
                    .name(format!("tcfft-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                in_flight.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, in_flight }
    }

    /// Submit a job for execution.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yield) until all submitted jobs finish.
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            thread::yield_now();
        }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallelism_is_real() {
        // two jobs that must overlap to finish fast
        let pool = ThreadPool::new(2);
        let barrier = Arc::new(std::sync::Barrier::new(2));
        let t0 = std::time::Instant::now();
        for _ in 0..2 {
            let b = Arc::clone(&barrier);
            pool.execute(move || {
                b.wait(); // deadlocks unless both run concurrently
            });
        }
        pool.wait_idle();
        assert!(t0.elapsed().as_secs() < 5);
    }

    #[test]
    fn drop_drains() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..16 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // drop without wait_idle: must still finish all jobs
        }
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn results_via_channel() {
        let pool = ThreadPool::new(3);
        let (tx, rx) = mpsc::channel();
        for i in 0..10u64 {
            let tx = tx.clone();
            pool.execute(move || tx.send(i * i).unwrap());
        }
        drop(tx);
        let mut got: Vec<u64> = rx.iter().collect();
        got.sort();
        assert_eq!(got, (0..10u64).map(|i| i * i).collect::<Vec<_>>());
    }
}
