//! Fixed-size worker thread pool (no tokio offline).  Shared by the
//! coordinator and the interpreter's batch-parallel execution engine:
//! jobs are boxed closures; `wait_idle` blocks on a condvar (no
//! spinning); `scope` runs a set of borrowing closures to completion.
//! The pool drains cleanly on drop and survives panicking jobs.
//!
//! The only `unsafe` is the lifetime erasure inside [`ThreadPool::scope`],
//! which is sound because `scope` does not return until every submitted
//! closure has finished running (enforced by a completion guard that
//! fires even when a closure panics).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Resolve the crate-wide worker-count knob shared by every parallel
/// engine (the interpreter's batch engine and the four-step large-FFT
/// engine): `TCFFT_THREADS` env var (accepted range 1..=64), else the
/// machine's available parallelism capped at 16.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("TCFFT_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n.min(64);
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
}

/// A job submitted through [`ThreadPool::scope`]: may borrow from the
/// submitting stack frame ('env outlives the scope call).
pub type ScopedJob<'env> = Box<dyn FnOnce() + Send + 'env>;

/// Count of submitted-but-unfinished jobs plus the condvar that
/// announces the pool going idle.
struct InFlight {
    count: Mutex<usize>,
    idle: Condvar,
}

impl InFlight {
    fn incr(&self) {
        *self.count.lock().unwrap() += 1;
    }

    fn decr(&self) {
        let mut n = self.count.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            self.idle.notify_all();
        }
    }
}

pub struct ThreadPool {
    /// Mutex-wrapped so submission is `Sync` on every toolchain the
    /// repo supports (`mpsc::Sender` itself only became `Sync` in
    /// Rust 1.72); contention is negligible — sends are tiny.
    tx: Option<Mutex<mpsc::Sender<Job>>>,
    workers: Vec<thread::JoinHandle<()>>,
    in_flight: Arc<InFlight>,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(InFlight { count: Mutex::new(0), idle: Condvar::new() });
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let in_flight = Arc::clone(&in_flight);
                thread::Builder::new()
                    .name(format!("tcfft-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                // a panicking job must not kill the
                                // worker or leak the in-flight count
                                let _ = catch_unwind(AssertUnwindSafe(job));
                                in_flight.decr();
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(Mutex::new(tx)), workers, in_flight }
    }

    /// Submit a job for execution.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.incr();
        self.tx
            .as_ref()
            .expect("pool shut down")
            .lock()
            .unwrap()
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        *self.in_flight.count.lock().unwrap()
    }

    /// Block until all submitted jobs finish (condvar wait, no spin).
    pub fn wait_idle(&self) {
        let mut n = self.in_flight.count.lock().unwrap();
        while *n > 0 {
            n = self.in_flight.idle.wait(n).unwrap();
        }
    }

    /// Run a set of closures that may borrow from the caller's stack
    /// and block until every one has completed. Panics from the
    /// closures are re-raised here (after all of them have finished),
    /// so a failing task cannot leave dangling borrows behind.
    pub fn scope<'env>(&self, tasks: Vec<ScopedJob<'env>>) {
        let total = tasks.len();
        if total == 0 {
            return;
        }
        struct ScopeState {
            done: Mutex<usize>,
            all_done: Condvar,
            panicked: AtomicBool,
        }
        struct DoneGuard(Arc<ScopeState>);
        impl Drop for DoneGuard {
            fn drop(&mut self) {
                // runs even when the task unwinds: the scope's wait
                // below must never miss a completion
                *self.0.done.lock().unwrap() += 1;
                self.0.all_done.notify_all();
            }
        }
        let state = Arc::new(ScopeState {
            done: Mutex::new(0),
            all_done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        for task in tasks {
            // SAFETY: the borrows captured by `task` live for 'env,
            // which outlives this function body; we block below until
            // the DoneGuard of every task has fired, so no worker can
            // touch the closure (or its borrows) after `scope` returns.
            let task: Job = unsafe { std::mem::transmute::<ScopedJob<'env>, Job>(task) };
            let state = Arc::clone(&state);
            self.execute(move || {
                let guard = DoneGuard(Arc::clone(&state));
                if catch_unwind(AssertUnwindSafe(task)).is_err() {
                    state.panicked.store(true, Ordering::SeqCst);
                }
                drop(guard);
            });
        }
        let mut done = state.done.lock().unwrap();
        while *done < total {
            done = state.all_done.wait(done).unwrap();
        }
        drop(done);
        if state.panicked.load(Ordering::SeqCst) {
            panic!("thread-pool scope task panicked");
        }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallelism_is_real() {
        // two jobs that must overlap to finish fast
        let pool = ThreadPool::new(2);
        let barrier = Arc::new(std::sync::Barrier::new(2));
        let t0 = std::time::Instant::now();
        for _ in 0..2 {
            let b = Arc::clone(&barrier);
            pool.execute(move || {
                b.wait(); // deadlocks unless both run concurrently
            });
        }
        pool.wait_idle();
        assert!(t0.elapsed().as_secs() < 5);
    }

    #[test]
    fn drop_drains() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..16 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // drop without wait_idle: must still finish all jobs
        }
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn results_via_channel() {
        let pool = ThreadPool::new(3);
        let (tx, rx) = mpsc::channel();
        for i in 0..10u64 {
            let tx = tx.clone();
            pool.execute(move || tx.send(i * i).unwrap());
        }
        drop(tx);
        let mut got: Vec<u64> = rx.iter().collect();
        got.sort();
        assert_eq!(got, (0..10u64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn wait_idle_survives_panicking_job() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        let ok = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&ok);
        pool.execute(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle(); // must not hang on the panicked job
        assert_eq!(pool.in_flight(), 0);
        assert_eq!(ok.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn scope_borrows_stack_data() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u64; 64];
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = data
            .chunks_mut(16)
            .enumerate()
            .map(|(i, chunk)| {
                let f: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    for v in chunk.iter_mut() {
                        *v = i as u64 + 1;
                    }
                });
                f
            })
            .collect();
        pool.scope(tasks);
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, (i / 16) as u64 + 1, "slot {i}");
        }
    }

    #[test]
    fn scope_blocks_until_all_complete() {
        let pool = ThreadPool::new(2);
        let counter = AtomicU64::new(0);
        // more tasks than workers: scope must wait for the queue tail
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..16)
            .map(|_| {
                let c = &counter;
                let f: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
                f
            })
            .collect();
        pool.scope(tasks);
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn scope_propagates_panics_after_completion() {
        let pool = ThreadPool::new(2);
        let finished = Arc::new(AtomicU64::new(0));
        let f2 = Arc::clone(&finished);
        let tasks: Vec<Box<dyn FnOnce() + Send + 'static>> = vec![
            Box::new(|| panic!("task failed")),
            Box::new(move || {
                f2.fetch_add(1, Ordering::SeqCst);
            }),
        ];
        let res = catch_unwind(AssertUnwindSafe(|| pool.scope(tasks)));
        assert!(res.is_err(), "scope must re-raise task panics");
        assert_eq!(finished.load(Ordering::SeqCst), 1, "other tasks still ran");
        pool.wait_idle();
    }

    #[test]
    fn empty_scope_is_a_noop() {
        let pool = ThreadPool::new(1);
        pool.scope(Vec::new());
        assert_eq!(pool.in_flight(), 0);
    }

    #[test]
    fn default_threads_is_in_contract_range() {
        // env-dependent, so only the documented bounds are asserted
        let t = default_threads();
        assert!((1..=64).contains(&t), "threads {t}");
    }
}
