//! From-scratch utility substrates (the offline toolchain has no clap,
//! serde, rand, criterion or tokio — see DESIGN.md system inventory #14).

pub mod cli;
pub mod fnv;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;
