//! Error taxonomy and the paper's precision metrics.

use thiserror::Error;

use crate::hp::C64;

/// Library error type (coordination-level failures; numeric code uses
/// anyhow at the boundaries).
#[derive(Debug, Error)]
pub enum TcFftError {
    #[error("unsupported FFT size {0}: must be a power of two >= 2")]
    BadSize(usize),
    #[error("no artifact available for {0}")]
    NoArtifact(String),
    #[error("service is shutting down")]
    ShuttingDown,
    #[error("request queue is full (backpressure)")]
    QueueFull,
}

/// The paper's relative error metric (eq. 5): mean over bins of
/// |X_ref[i] - X[i]| / max|X_ref| — normalized by the reference scale
/// so near-zero bins do not blow up the average.
pub fn relative_error(reference: &[C64], got: &[C64]) -> f64 {
    assert_eq!(reference.len(), got.len());
    let scale = reference
        .iter()
        .map(|c| c.abs())
        .fold(0.0f64, f64::max)
        .max(f64::MIN_POSITIVE);
    let sum: f64 = reference
        .iter()
        .zip(got)
        .map(|(r, g)| (*r - *g).abs() / scale)
        .sum();
    sum / reference.len() as f64
}

/// Max relative error variant (stricter; used in tests).
pub fn max_relative_error(reference: &[C64], got: &[C64]) -> f64 {
    assert_eq!(reference.len(), got.len());
    let scale = reference
        .iter()
        .map(|c| c.abs())
        .fold(0.0f64, f64::max)
        .max(f64::MIN_POSITIVE);
    reference
        .iter()
        .zip(got)
        .map(|(r, g)| (*r - *g).abs() / scale)
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_for_identical() {
        let x = vec![C64::new(1.0, 2.0), C64::new(-3.0, 0.5)];
        assert_eq!(relative_error(&x, &x), 0.0);
        assert_eq!(max_relative_error(&x, &x), 0.0);
    }

    #[test]
    fn scales_by_reference_magnitude() {
        let r = vec![C64::new(10.0, 0.0), C64::new(0.0, 0.0)];
        let g = vec![C64::new(10.0, 0.0), C64::new(0.1, 0.0)];
        // error 0.1 against scale 10 -> 0.01, averaged over 2 bins
        assert!((relative_error(&r, &g) - 0.005).abs() < 1e-12);
        assert!((max_relative_error(&r, &g) - 0.01).abs() < 1e-12);
    }
}
