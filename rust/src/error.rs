//! Error taxonomy and the paper's precision metrics.
//!
//! The offline toolchain has no `anyhow`/`thiserror`; this module is
//! the crate's single error substrate: a typed enum for the failure
//! classes the service distinguishes, a `Msg` catch-all for everything
//! else, and `bail!`/`ensure!` macros mirroring the anyhow idiom.
//!
//! Every variant carries a **stable machine-readable code**
//! ([`TcFftError::code`]) that the TCP protocol exposes as a `"code"`
//! field in error replies and the metrics snapshot aggregates into
//! errors-by-code counters. Codes are part of the wire contract: new
//! failure classes get new codes; existing codes never change meaning.

use crate::hp::C64;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, TcFftError>;

/// Every stable error code, in [`TcFftError::code_index`] order — the
/// index the metrics errors-by-code counters are keyed by.
pub const ERROR_CODES: [&str; 9] = [
    "bad_size",
    "no_artifact",
    "shutting_down",
    "queue_full",
    "quota_exceeded",
    "deadline_exceeded",
    "exec_panic",
    "dropped",
    "internal",
];

/// Library error type. `Clone` so one batch-level failure can fan out
/// to every batch member's reply channel.
#[derive(Debug, Clone)]
pub enum TcFftError {
    /// Unsupported FFT size: must be a power of two >= 2.
    BadSize(usize),
    /// No artifact available for the requested transform.
    NoArtifact(String),
    /// Service is shutting down.
    ShuttingDown,
    /// Request queue is full (backpressure).
    QueueFull,
    /// Per-client admission quota exhausted (token bucket empty).
    QuotaExceeded,
    /// The request's end-to-end deadline elapsed before execution
    /// (shed at flush time or just before execution) or before a
    /// bounded wait observed a reply.
    DeadlineExceeded,
    /// Batch execution panicked; the panic was isolated to the batch
    /// (every member gets this reply) and the service keeps serving.
    ExecPanic(String),
    /// The service dropped the request's reply channel without
    /// answering (e.g. torn down mid-flight).
    Dropped,
    /// Anything else (I/O, parse, shape mismatches, backend failures).
    Msg(String),
}

impl TcFftError {
    /// Build the catch-all variant from any displayable value.
    pub fn msg(m: impl std::fmt::Display) -> TcFftError {
        TcFftError::Msg(m.to_string())
    }

    /// The stable machine-readable code for this failure class — the
    /// `"code"` field of TCP error replies and the key of the metrics
    /// errors-by-code counters.
    pub fn code(&self) -> &'static str {
        ERROR_CODES[self.code_index()]
    }

    /// Index of [`code`](Self::code) within [`ERROR_CODES`].
    pub fn code_index(&self) -> usize {
        match self {
            TcFftError::BadSize(_) => 0,
            TcFftError::NoArtifact(_) => 1,
            TcFftError::ShuttingDown => 2,
            TcFftError::QueueFull => 3,
            TcFftError::QuotaExceeded => 4,
            TcFftError::DeadlineExceeded => 5,
            TcFftError::ExecPanic(_) => 6,
            TcFftError::Dropped => 7,
            TcFftError::Msg(_) => 8,
        }
    }
}

impl std::fmt::Display for TcFftError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TcFftError::BadSize(n) => {
                write!(f, "unsupported FFT size {n}: must be a power of two >= 2")
            }
            TcFftError::NoArtifact(what) => write!(f, "no artifact available for {what}"),
            TcFftError::ShuttingDown => write!(f, "service is shutting down"),
            TcFftError::QueueFull => write!(f, "request queue is full (backpressure)"),
            TcFftError::QuotaExceeded => write!(f, "client admission quota exceeded"),
            TcFftError::DeadlineExceeded => write!(f, "request deadline exceeded"),
            TcFftError::ExecPanic(what) => {
                write!(f, "batch execution panicked (isolated): {what}")
            }
            TcFftError::Dropped => write!(f, "service dropped the request"),
            TcFftError::Msg(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for TcFftError {}

impl From<std::io::Error> for TcFftError {
    fn from(e: std::io::Error) -> TcFftError {
        TcFftError::Msg(e.to_string())
    }
}

impl From<std::num::ParseIntError> for TcFftError {
    fn from(e: std::num::ParseIntError) -> TcFftError {
        TcFftError::Msg(e.to_string())
    }
}

impl From<std::num::ParseFloatError> for TcFftError {
    fn from(e: std::num::ParseFloatError) -> TcFftError {
        TcFftError::Msg(e.to_string())
    }
}

/// Return early with a `TcFftError`. Accepts either a format string
/// (producing `TcFftError::Msg`) or an error value convertible into
/// `TcFftError`.
#[macro_export]
macro_rules! bail {
    ($fmt:literal $(, $arg:expr)* $(,)?) => {
        return Err($crate::error::TcFftError::msg(format!($fmt $(, $arg)*)))
    };
    ($err:expr) => {
        return Err($crate::error::TcFftError::from($err))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $fmt:literal $(, $arg:expr)* $(,)?) => {
        if !($cond) {
            $crate::bail!($fmt $(, $arg)*);
        }
    };
    ($cond:expr, $err:expr) => {
        if !($cond) {
            $crate::bail!($err);
        }
    };
}

/// The paper's relative error metric (eq. 5): mean over bins of
/// |X_ref[i] - X[i]| / max|X_ref| — normalized by the reference scale
/// so near-zero bins do not blow up the average.
pub fn relative_error(reference: &[C64], got: &[C64]) -> f64 {
    assert_eq!(reference.len(), got.len());
    let scale = reference
        .iter()
        .map(|c| c.abs())
        .fold(0.0f64, f64::max)
        .max(f64::MIN_POSITIVE);
    let sum: f64 = reference
        .iter()
        .zip(got)
        .map(|(r, g)| (*r - *g).abs() / scale)
        .sum();
    sum / reference.len() as f64
}

/// Max relative error variant (stricter; used in tests).
pub fn max_relative_error(reference: &[C64], got: &[C64]) -> f64 {
    assert_eq!(reference.len(), got.len());
    let scale = reference
        .iter()
        .map(|c| c.abs())
        .fold(0.0f64, f64::max)
        .max(f64::MIN_POSITIVE);
    reference
        .iter()
        .zip(got)
        .map(|(r, g)| (*r - *g).abs() / scale)
        .fold(0.0, f64::max)
}

/// Relative root-mean-square error — the conformance-suite metric
/// (Table 4 spirit): ||X - X_ref||_2 / ||X_ref||_2.
pub fn relative_rmse(reference: &[C64], got: &[C64]) -> f64 {
    assert_eq!(reference.len(), got.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (r, g) in reference.iter().zip(got) {
        num += (*r - *g).norm_sqr();
        den += r.norm_sqr();
    }
    (num / den.max(f64::MIN_POSITIVE)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_for_identical() {
        let x = vec![C64::new(1.0, 2.0), C64::new(-3.0, 0.5)];
        assert_eq!(relative_error(&x, &x), 0.0);
        assert_eq!(max_relative_error(&x, &x), 0.0);
        assert_eq!(relative_rmse(&x, &x), 0.0);
    }

    #[test]
    fn scales_by_reference_magnitude() {
        let r = vec![C64::new(10.0, 0.0), C64::new(0.0, 0.0)];
        let g = vec![C64::new(10.0, 0.0), C64::new(0.1, 0.0)];
        // error 0.1 against scale 10 -> 0.01, averaged over 2 bins
        assert!((relative_error(&r, &g) - 0.005).abs() < 1e-12);
        assert!((max_relative_error(&r, &g) - 0.01).abs() < 1e-12);
        // rmse: |err| = 0.1 over ||ref|| = 10
        assert!((relative_rmse(&r, &g) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn display_and_variants() {
        assert!(TcFftError::BadSize(7).to_string().contains("7"));
        assert!(TcFftError::NoArtifact("x".into()).to_string().contains("x"));
        assert!(TcFftError::msg("boom").to_string().contains("boom"));
        assert!(TcFftError::QuotaExceeded.to_string().contains("quota"));
        assert!(TcFftError::DeadlineExceeded.to_string().contains("deadline"));
        assert!(TcFftError::ExecPanic("kaboom".into()).to_string().contains("kaboom"));
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        assert!(TcFftError::from(io).to_string().contains("gone"));
    }

    #[test]
    fn codes_are_stable_and_cover_every_variant() {
        let all = [
            TcFftError::BadSize(2),
            TcFftError::NoArtifact("x".into()),
            TcFftError::ShuttingDown,
            TcFftError::QueueFull,
            TcFftError::QuotaExceeded,
            TcFftError::DeadlineExceeded,
            TcFftError::ExecPanic("p".into()),
            TcFftError::Dropped,
            TcFftError::msg("m"),
        ];
        assert_eq!(all.len(), ERROR_CODES.len());
        for (i, e) in all.iter().enumerate() {
            assert_eq!(e.code_index(), i, "{e}");
            assert_eq!(e.code(), ERROR_CODES[i]);
        }
        // the wire contract: these strings never change meaning
        assert_eq!(TcFftError::ExecPanic(String::new()).code(), "exec_panic");
        assert_eq!(TcFftError::DeadlineExceeded.code(), "deadline_exceeded");
        assert_eq!(TcFftError::QueueFull.code(), "queue_full");
        assert_eq!(TcFftError::ShuttingDown.code(), "shutting_down");
    }

    #[test]
    fn macros_return_errors() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "too big: {x}");
            if x == 5 {
                bail!(TcFftError::BadSize(x));
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(matches!(f(5), Err(TcFftError::BadSize(5))));
        assert!(f(11).unwrap_err().to_string().contains("too big: 11"));
    }
}
