//! Batched, multi-level four-step composition of large FFTs from
//! small AOT artifacts (paper Sec 3.1: "larger size FFTs can be
//! realized by combining these basic kernels").
//!
//! For N = N1 * N2, viewing each sequence as a row-major N1 x N2
//! matrix M:
//!   1. transpose to [N2][N1] (tiled) and FFT the N2 rows (length N1)
//!      — batched small FFTs on the device;
//!   2. transpose back to [N1][N2] while multiplying element (j, k) by
//!      W_N^{jk} — the twiddle correction fused into the transpose,
//!      against a flat f32 table precomputed once per plan;
//!   3. FFT the N1 rows (length N2) — batched small FFTs;
//!   4. final tiled transpose: X[k*N1 + j] = M[j][k].
//!
//! The engine differs from the kept per-sequence baseline
//! ([`BaselineFourStep`]) in four ways:
//!
//! * **batched** — [`FourStepPlan::execute_batch`] transforms a whole
//!   `PlanarBatch` of sequences per call; the device steps run over
//!   `batch * N2` (resp. `batch * N1`) rows at artifact capacity, so
//!   per-call overheads amortize across the batch;
//! * **cache-blocked** — the three transposes are tiled
//!   ([`TILE`]x[`TILE`]), not element-wise gather/scatter loops;
//! * **twiddle-cached** — the flat `[N1][N2]` f32 table is built once
//!   at plan time (the baseline recomputes an N1 x N2 `C64` table on
//!   every call) and fused into the middle transpose;
//! * **parallel** — host-side steps are chunked over contiguous
//!   output-row ranges on the shared [`crate::util::threadpool`] pool
//!   (`TCFFT_THREADS`, same contract as the interpreter engine), with
//!   a serial fall-through below a work threshold.
//!
//! Factors larger than the leaf cap ([`FourStepConfig::max_leaf_log2`],
//! default 2^11) recurse through another four-step level, so sizes
//! beyond 2^22 decompose multi-level; leaves resolve to the requested
//! algorithm's artifacts with a `tc` fallback. The coordinator routes
//! `Op::Fft1d` sizes with no direct artifact to a cached plan from
//! this module, `Op::Rfft1d` sizes to a [`RealFourStepPlan`] — the
//! R2C/C2R wrapper that runs the half-size complex engine inside the
//! fused half-spectrum pass — and `Op::Rfft2d` images to a
//! [`Plan2d`] ([`plan2d`]), which composes a row-wise
//! `RealFourStepPlan` with a column-wise [`FourStepPlan`] over the
//! packed Hermitian layout.

pub mod baseline;
pub mod plan2d;

pub use baseline::BaselineFourStep;
pub use plan2d::Plan2d;

use std::collections::HashSet;
use std::sync::{Arc, Mutex, OnceLock};

use crate::error::{Result, TcFftError};
use crate::fft::twiddle::four_step_twiddles_flat;
use crate::hp::C32;
use crate::runtime::{PlanarBatch, RealHalfSpectrum, Runtime};
use crate::util::threadpool::{default_threads, ScopedJob, ThreadPool};

/// Transpose tile edge: a 32x32 f32 tile is 4 KiB per plane, so a
/// src/dst tile pair stays L1-resident while the strided reads walk it.
const TILE: usize = 32;

/// Minimum elements in a host-side step before fanning out to the
/// pool; below this the dispatch overhead beats the parallel win.
const PAR_MIN_ELEMS: usize = 1 << 16;

/// Default leaf cap (log2): factors above 2^11 recurse through another
/// four-step level, so a single level covers up to 2^22 and anything
/// beyond decomposes multi-level. 2^11 keeps a leaf's operand tables
/// and the transpose working set cache-friendly even though the
/// synthesized catalog carries artifacts up to 2^17.
pub const DEFAULT_MAX_LEAF_LOG2: usize = 11;

/// Tuning knobs for [`FourStepPlan`].
#[derive(Clone, Debug)]
pub struct FourStepConfig {
    /// preferred leaf algorithm (`"tc"` | `"tc_split"` | `"tc_ec"` |
    /// `"r2"`); factors without artifacts for it fall back to `"tc"`
    pub algo: String,
    /// largest factor solved by a single artifact call (log2); factors
    /// above this recurse through another four-step level
    pub max_leaf_log2: usize,
    /// worker threads for the host-side transpose/twiddle steps:
    /// 0 = shared crate default (`TCFFT_THREADS`, same contract as the
    /// interpreter engine), 1 = serial
    pub threads: usize,
}

impl Default for FourStepConfig {
    fn default() -> Self {
        FourStepConfig {
            algo: "tc".to_string(),
            max_leaf_log2: DEFAULT_MAX_LEAF_LOG2,
            threads: 0,
        }
    }
}

/// One level of the decomposition tree.
enum Node {
    /// Solved by one batched artifact.
    Leaf {
        key: String,
        cap: usize,
        n: usize,
        algo: &'static str,
    },
    /// Four-step split n = n1 * n2 with a cached flat twiddle table.
    Split {
        n1: usize,
        n2: usize,
        left: Box<Node>,
        right: Box<Node>,
        tw_re: Vec<f32>,
        tw_im: Vec<f32>,
    },
}

/// Pick the canonical algo string so leaves can carry `&'static str`.
fn algo_static(algo: &str) -> &'static str {
    match algo {
        "tc_split" => "tc_split",
        "tc_ec" => "tc_ec",
        "r2" => "r2",
        _ => "tc",
    }
}

/// Build the decomposition for `n`: leaf if an artifact exists within
/// the leaf cap (first algo in `algos` that has one wins), else the
/// most balanced split whose halves both build. `memo` caches sizes
/// that failed so the search stays O(log^2 n).
fn build_node(
    rt: &Runtime,
    n: usize,
    algos: &[String],
    inverse: bool,
    max_leaf: usize,
    force_split: bool,
    memo: &mut HashSet<usize>,
) -> Result<Node> {
    if !force_split && n <= max_leaf {
        for algo in algos {
            if let Some(v) = rt.registry.find_fft1d(n, usize::MAX, algo, inverse) {
                return Ok(Node::Leaf {
                    key: v.key.clone(),
                    cap: v.batch,
                    n,
                    algo: algo_static(algo),
                });
            }
        }
    }
    if memo.contains(&n) {
        return Err(TcFftError::NoArtifact(format!("four-step factor {n}")));
    }
    let t = n.trailing_zeros() as usize;
    if t < 2 {
        memo.insert(n);
        return Err(TcFftError::NoArtifact(format!(
            "no 1D artifact for n={n} and it is too small to split"
        )));
    }
    // candidate split points, most balanced first (ties: larger n1)
    let mut cands: Vec<usize> = (1..t).collect();
    cands.sort_by_key(|&t1| {
        let balance = (t1 as isize - (t as isize - t1 as isize)).abs();
        (balance, std::cmp::Reverse(t1))
    });
    for &t1 in &cands {
        let (n1, n2) = (1usize << t1, n >> t1);
        let left = match build_node(rt, n1, algos, inverse, max_leaf, false, memo) {
            Ok(l) => l,
            Err(_) => continue,
        };
        let right = match build_node(rt, n2, algos, inverse, max_leaf, false, memo) {
            Ok(r) => r,
            Err(_) => continue,
        };
        let (tw_re, tw_im) = four_step_twiddles_flat(n1, n2, inverse);
        return Ok(Node::Split {
            n1,
            n2,
            left: Box::new(left),
            right: Box::new(right),
            tw_re,
            tw_im,
        });
    }
    memo.insert(n);
    Err(TcFftError::NoArtifact(format!(
        "no four-step decomposition of n={n} (algos {algos:?}, leaf cap {max_leaf})"
    )))
}

/// A reusable pair of planar scratch planes.
type ScratchPair = (Vec<f32>, Vec<f32>);

/// The process-wide host-step pool every default-config plan shares
/// (sized by [`default_threads`], i.e. the `TCFFT_THREADS` contract).
/// Without this, the coordinator's never-evicted plan cache would
/// accumulate one private pool per (n, algo, dir) key.
fn shared_pool() -> Arc<ThreadPool> {
    static POOL: OnceLock<Arc<ThreadPool>> = OnceLock::new();
    Arc::clone(POOL.get_or_init(|| Arc::new(ThreadPool::new(default_threads()))))
}

/// Host-side execution context: the shared pool (None = serial) plus
/// the plan's scratch arena, so steady-state execution of a cached
/// plan allocates nothing for its transpose buffers.
struct ExecCtx<'a> {
    pool: Option<Arc<ThreadPool>>,
    threads: usize,
    scratch: &'a Mutex<Option<ScratchPair>>,
}

impl ExecCtx<'_> {
    fn pool_for(&self, total_elems: usize) -> Option<&Arc<ThreadPool>> {
        match &self.pool {
            Some(p) if self.threads > 1 && total_elems >= PAR_MIN_ELEMS => Some(p),
            _ => None,
        }
    }

    /// Borrow a scratch pair of at least `len` elements per plane.
    fn take_scratch(&self, len: usize) -> ScratchPair {
        let popped = self.scratch.lock().unwrap().take();
        let (mut re, mut im) = popped.unwrap_or_default();
        if re.len() < len {
            re.resize(len, 0.0);
            im.resize(len, 0.0);
        }
        (re, im)
    }

    /// Return a scratch pair, retaining only the most recent one. A
    /// run's last return is the top level's (largest) pair — exactly
    /// the next same-shape request's need — so retained memory stays
    /// at one working set per plan instead of growing with nesting
    /// depth or concurrency.
    fn give_scratch(&self, pair: ScratchPair) {
        *self.scratch.lock().unwrap() = Some(pair);
    }
}

/// Tiled transpose of one sequence, output rows `rows.0..rows.1`:
/// `dst[r*oc + c] = src[c*or + r]`, times `tw[r*oc + c]` when a
/// twiddle table is given. `dims = (or, oc)` are the OUTPUT rows/cols;
/// `dst` starts at output row `rows.0`; `src`/`tw` span the sequence.
/// Output rows are written compactly (`out_cols` apart); the 2D
/// composition's panel scatter uses [`transpose_range_strided`] when
/// they must land `dst_stride` apart instead.
fn transpose_range(
    src: (&[f32], &[f32]),
    dst: (&mut [f32], &mut [f32]),
    rows: (usize, usize),
    dims: (usize, usize),
    tw: Option<(&[f32], &[f32])>,
) {
    debug_assert_eq!(dst.0.len(), (rows.1 - rows.0) * dims.1);
    transpose_range_strided(src, dst, rows, dims, dims.1, tw)
}

/// [`transpose_range`] with an explicit distance between consecutive
/// output rows: `dst[(r - rows.0)*dst_stride + c] = src[c*or + r]`.
/// With `dst_stride > out_cols` the transposed rows scatter into a
/// wider row-major destination (the packed `[nx, L]` image a column
/// panel writes back into); `dst` must cover
/// `(rows.1 - rows.0 - 1) * dst_stride + out_cols` elements.
fn transpose_range_strided(
    src: (&[f32], &[f32]),
    dst: (&mut [f32], &mut [f32]),
    rows: (usize, usize),
    dims: (usize, usize),
    dst_stride: usize,
    tw: Option<(&[f32], &[f32])>,
) {
    let (src_re, src_im) = src;
    let (dst_re, dst_im) = dst;
    let (r0, r1) = rows;
    let (out_rows, out_cols) = dims;
    debug_assert!(dst_stride >= out_cols);
    debug_assert!(r0 == r1 || dst_re.len() >= (r1 - r0 - 1) * dst_stride + out_cols);
    for rb in (r0..r1).step_by(TILE) {
        let row_end = (rb + TILE).min(r1);
        for cb in (0..out_cols).step_by(TILE) {
            let ce = (cb + TILE).min(out_cols);
            for r in rb..row_end {
                let d = (r - r0) * dst_stride;
                match tw {
                    None => {
                        for c in cb..ce {
                            let s = c * out_rows + r;
                            dst_re[d + c] = src_re[s];
                            dst_im[d + c] = src_im[s];
                        }
                    }
                    Some((tw_re, tw_im)) => {
                        let t = r * out_cols;
                        for c in cb..ce {
                            let s = c * out_rows + r;
                            let (ar, ai) = (src_re[s], src_im[s]);
                            let (wr, wi) = (tw_re[t + c], tw_im[t + c]);
                            dst_re[d + c] = ar * wr - ai * wi;
                            dst_im[d + c] = ar * wi + ai * wr;
                        }
                    }
                }
            }
        }
    }
}

/// Transpose (optionally twiddling) every sequence of a batch,
/// row-chunked over the pool when the work is large enough. Chunks are
/// contiguous output-row ranges, so parallel and serial execution
/// write identical bytes.
fn par_transpose(
    ctx: &ExecCtx<'_>,
    src: (&[f32], &[f32]),
    dst: (&mut [f32], &mut [f32]),
    seqs: usize,
    dims: (usize, usize),
    tw: Option<(&[f32], &[f32])>,
) {
    let (out_rows, out_cols) = dims;
    let n = out_rows * out_cols;
    let (src_re, src_im) = src;
    let (dst_re, dst_im) = dst;
    debug_assert_eq!(src_re.len(), seqs * n);
    debug_assert_eq!(dst_re.len(), seqs * n);
    let Some(pool) = ctx.pool_for(seqs * n) else {
        for s in 0..seqs {
            let (a, b) = (s * n, (s + 1) * n);
            transpose_range(
                (&src_re[a..b], &src_im[a..b]),
                (&mut dst_re[a..b], &mut dst_im[a..b]),
                (0, out_rows),
                dims,
                tw,
            );
        }
        return;
    };
    let chunks_per_seq = (ctx.threads * 2).div_ceil(seqs).max(1);
    let rows_per_task = out_rows.div_ceil(chunks_per_seq).max(1);
    let mut tasks: Vec<ScopedJob<'_>> = Vec::new();
    for (s, (dre_seq, dim_seq)) in dst_re.chunks_mut(n).zip(dst_im.chunks_mut(n)).enumerate() {
        let sre = &src_re[s * n..(s + 1) * n];
        let sim = &src_im[s * n..(s + 1) * n];
        let mut r0 = 0usize;
        for (dre, dim) in dre_seq
            .chunks_mut(rows_per_task * out_cols)
            .zip(dim_seq.chunks_mut(rows_per_task * out_cols))
        {
            let rows_here = dre.len() / out_cols;
            let range = (r0, r0 + rows_here);
            tasks.push(Box::new(move || {
                transpose_range((sre, sim), (dre, dim), range, dims, tw);
            }));
            r0 += rows_here;
        }
    }
    pool.scope(tasks);
}

/// Run `rows` length-`n` sequences through artifact `key` in place,
/// chunked to the artifact batch capacity (the tail chunk is
/// zero-padded, as the artifact shape demands). The backend returns
/// ownership of the staging buffer it was handed, so one allocation
/// serves every chunk of the loop.
fn run_leaf(
    rt: &Runtime,
    key: &str,
    cap: usize,
    n: usize,
    re: &mut [f32],
    im: &mut [f32],
    rows: usize,
) -> Result<()> {
    debug_assert_eq!(re.len(), rows * n);
    let mut chunk = PlanarBatch::new(vec![cap, n]);
    let mut lo = 0usize;
    while lo < rows {
        let take = (rows - lo).min(cap);
        let (a, b) = (lo * n, (lo + take) * n);
        chunk.re[..b - a].copy_from_slice(&re[a..b]);
        chunk.im[..b - a].copy_from_slice(&im[a..b]);
        if take < cap {
            // reused buffer: clear stale rows in the padded tail
            chunk.re[b - a..].fill(0.0);
            chunk.im[b - a..].fill(0.0);
        }
        let (out, _) = rt.execute(key, std::mem::take(&mut chunk))?;
        re[a..b].copy_from_slice(&out.re[..b - a]);
        im[a..b].copy_from_slice(&out.im[..b - a]);
        chunk = out; // same shape [cap, n]; recycle for the next chunk
        debug_assert_eq!(chunk.re.len(), cap * n);
        lo += take;
    }
    Ok(())
}

impl Node {
    fn n(&self) -> usize {
        match self {
            Node::Leaf { n, .. } => *n,
            Node::Split { n1, n2, .. } => n1 * n2,
        }
    }

    fn depth(&self) -> usize {
        match self {
            Node::Leaf { .. } => 0,
            Node::Split { left, right, .. } => 1 + left.depth().max(right.depth()),
        }
    }

    fn describe(&self) -> String {
        match self {
            Node::Leaf { n, algo, .. } => format!("{n}[{algo}]"),
            Node::Split { left, right, .. } => {
                format!("({} x {})", left.describe(), right.describe())
            }
        }
    }

    /// Resident bytes of this subtree's cached twiddle tables.
    fn memory_bytes(&self) -> usize {
        match self {
            Node::Leaf { key, .. } => key.len() + 64,
            Node::Split { left, right, tw_re, tw_im, .. } => {
                (tw_re.len() + tw_im.len()) * std::mem::size_of::<f32>()
                    + left.memory_bytes()
                    + right.memory_bytes()
                    + 64
            }
        }
    }

    /// Transform `rows` length-`self.n()` sequences in place. With
    /// `skip_final` a top-level Split stops after step 4, leaving each
    /// sequence in the pre-read-out layout `M[j][k]` at `j*n2 + k`
    /// (logical `X[k*n1 + j] = M[j][k]`) — the caller fuses the final
    /// transpose into its own read-out pass. Children always run to
    /// completion (their outputs feed steps 2/4 as finished FFTs).
    fn run(
        &self,
        rt: &Runtime,
        re: &mut [f32],
        im: &mut [f32],
        rows: usize,
        ctx: &ExecCtx<'_>,
        skip_final: bool,
    ) -> Result<()> {
        match self {
            Node::Leaf { key, cap, n, .. } => run_leaf(rt, key, *cap, *n, re, im, rows),
            Node::Split { n1, n2, left, right, tw_re, tw_im } => {
                let (n1, n2) = (*n1, *n2);
                let n = n1 * n2;
                let len = rows * n;
                debug_assert_eq!(re.len(), len);
                let (mut s_re, mut s_im) = ctx.take_scratch(len);
                // step 1: tiled transpose [n1][n2] -> [n2][n1]
                par_transpose(
                    ctx,
                    (&*re, &*im),
                    (&mut s_re[..len], &mut s_im[..len]),
                    rows,
                    (n2, n1),
                    None,
                );
                // step 2: length-n1 FFTs over the rows*n2 columns
                left.run(rt, &mut s_re[..len], &mut s_im[..len], rows * n2, ctx, false)?;
                // step 3: transpose back, twiddle fused: [n2][n1] -> [n1][n2]
                par_transpose(
                    ctx,
                    (&s_re[..len], &s_im[..len]),
                    (&mut *re, &mut *im),
                    rows,
                    (n1, n2),
                    Some((tw_re.as_slice(), tw_im.as_slice())),
                );
                // step 4: length-n2 FFTs over the rows*n1 rows
                right.run(rt, re, im, rows * n1, ctx, false)?;
                if !skip_final {
                    // step 5: final transpose [n1][n2] -> [n2][n1] is
                    // the natural-order read-out X[k*n1 + j] = M[j][k]
                    par_transpose(
                        ctx,
                        (&*re, &*im),
                        (&mut s_re[..len], &mut s_im[..len]),
                        rows,
                        (n2, n1),
                        None,
                    );
                    re.copy_from_slice(&s_re[..len]);
                    im.copy_from_slice(&s_im[..len]);
                }
                ctx.give_scratch((s_re, s_im));
                Ok(())
            }
        }
    }
}

/// A cached, batched four-step plan for one (n, algo, direction).
///
/// Build once (the decomposition tree and every level's flat twiddle
/// table are precomputed here), then call
/// [`execute_batch`](Self::execute_batch) per request batch. Plans are
/// `Send + Sync`; the coordinator shares them behind `Arc`.
pub struct FourStepPlan {
    n: usize,
    inverse: bool,
    algo: String,
    root: Node,
    threads: usize,
    /// true when `FourStepConfig::threads` pinned an explicit count —
    /// those plans own a private pool (benches, tests); default-config
    /// plans all share [`shared_pool`]
    explicit_pool: bool,
    pool: Mutex<Option<Arc<ThreadPool>>>,
    /// the most recently used transpose plane pair; steady-state
    /// execution of a cached plan allocates nothing here
    scratch: Mutex<Option<ScratchPair>>,
}

impl FourStepPlan {
    /// Default-config plan (algo `"tc"`), kept signature-compatible
    /// with the pre-PR constructor.
    pub fn new(rt: &Runtime, n: usize, inverse: bool) -> Result<FourStepPlan> {
        Self::with_config(rt, n, inverse, FourStepConfig::default())
    }

    /// Plan with an explicit leaf algorithm (falls back to `"tc"` for
    /// factors the requested algo has no artifacts for).
    pub fn with_algo(rt: &Runtime, n: usize, algo: &str, inverse: bool) -> Result<FourStepPlan> {
        Self::with_config(
            rt,
            n,
            inverse,
            FourStepConfig { algo: algo.to_string(), ..FourStepConfig::default() },
        )
    }

    /// Plan with explicit tuning knobs (leaf algo, leaf cap, threads).
    pub fn with_config(
        rt: &Runtime,
        n: usize,
        inverse: bool,
        cfg: FourStepConfig,
    ) -> Result<FourStepPlan> {
        if !n.is_power_of_two() || n < 4 {
            crate::bail!(TcFftError::BadSize(n));
        }
        let max_leaf = 1usize << cfg.max_leaf_log2.clamp(1, 20);
        let mut algos = vec![cfg.algo.clone()];
        if cfg.algo != "tc" {
            algos.push("tc".to_string());
        }
        let mut memo = HashSet::new();
        // the top level always splits: a four-step plan exists to
        // compose sizes, direct artifact or not
        let root = build_node(rt, n, &algos, inverse, max_leaf, true, &mut memo)?;
        let (threads, explicit_pool) = if cfg.threads == 0 {
            (default_threads(), false)
        } else {
            (cfg.threads.clamp(1, 64), true)
        };
        Ok(FourStepPlan {
            n,
            inverse,
            algo: cfg.algo,
            root,
            threads,
            explicit_pool,
            pool: Mutex::new(None),
            scratch: Mutex::new(None),
        })
    }

    /// The transform length `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// True for the inverse (unnormalized) direction.
    pub fn inverse(&self) -> bool {
        self.inverse
    }

    /// The requested leaf algorithm (individual leaves may have fallen
    /// back to `"tc"`; see [`describe`](Self::describe)).
    pub fn algo(&self) -> &str {
        &self.algo
    }

    /// Host-side worker count (the `TCFFT_THREADS` contract).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Top-level factors (n1, n2).
    pub fn factors(&self) -> (usize, usize) {
        match &self.root {
            Node::Split { n1, n2, .. } => (*n1, *n2),
            Node::Leaf { n, .. } => (*n, 1),
        }
    }

    /// Top-level first factor (`factors().0`).
    pub fn n1(&self) -> usize {
        self.factors().0
    }

    /// Top-level second factor (`factors().1`).
    pub fn n2(&self) -> usize {
        self.factors().1
    }

    /// Number of four-step levels (1 = single split, 2+ = multi-level).
    pub fn depth(&self) -> usize {
        self.root.depth()
    }

    /// Human-readable decomposition, e.g. `(1024[tc] x 1024[tc])`.
    pub fn describe(&self) -> String {
        self.root.describe()
    }

    /// Estimated resident bytes for cache accounting: the twiddle
    /// tables held by the decomposition tree plus the retained
    /// transpose scratch at its steady-state size (one `[n]` planar
    /// pair per buffer of the pair, `2 * 2 * 4 = 16` bytes/element for
    /// a single-row batch — multi-row scratch grows with the batch, but
    /// the nominal single-row figure is the stable floor every cached
    /// plan reaches).
    pub fn memory_bytes(&self) -> usize {
        self.root.memory_bytes() + 16 * self.n
    }

    fn pool(&self) -> Arc<ThreadPool> {
        if !self.explicit_pool {
            return shared_pool();
        }
        let mut guard = self.pool.lock().unwrap();
        Arc::clone(guard.get_or_insert_with(|| Arc::new(ThreadPool::new(self.threads))))
    }

    /// Transform a whole batch of sequences (shape `[b, n]`) in one
    /// call — the batched entry point the service routes to.
    pub fn execute_batch(&self, rt: &Runtime, x: PlanarBatch) -> Result<PlanarBatch> {
        self.run_batch(rt, x, false)
    }

    /// [`execute_batch`](Self::execute_batch) minus the final
    /// read-out transpose: the top-level split stops after step 4, so
    /// each output sequence arrives in the pre-read-out layout where
    /// logical element `X[k*n1 + j]` sits at offset `j*n2 + k`
    /// (`(n1, n2)` = [`factors`](Self::factors)). Callers that gather
    /// anyway — the real-input wrapper's half-spectrum split — fuse
    /// their pass into the read-out instead of paying an extra
    /// transpose plus copy-back. The values are the exact f32s the
    /// full `execute_batch` would have moved, just not yet permuted.
    pub fn execute_batch_pretransposed(&self, rt: &Runtime, x: PlanarBatch) -> Result<PlanarBatch> {
        self.run_batch(rt, x, true)
    }

    fn run_batch(&self, rt: &Runtime, x: PlanarBatch, skip_final: bool) -> Result<PlanarBatch> {
        crate::ensure!(
            x.shape.len() == 2 && x.shape[1] == self.n,
            "four-step input shape {:?} != [b, {}]",
            x.shape,
            self.n
        );
        debug_assert_eq!(self.root.n(), self.n);
        let b = x.shape[0];
        if b == 0 {
            return Ok(x);
        }
        let pool = if self.threads > 1 && b * self.n >= PAR_MIN_ELEMS {
            Some(self.pool())
        } else {
            None
        };
        let ctx = ExecCtx { pool, threads: self.threads, scratch: &self.scratch };
        let mut re = x.re;
        let mut im = x.im;
        self.root.run(rt, &mut re, &mut im, b, &ctx, skip_final)?;
        Ok(PlanarBatch { re, im, shape: vec![b, self.n] })
    }

    /// Single-sequence convenience wrapper over the batched engine.
    pub fn execute(&self, rt: &Runtime, x: &[C32]) -> Result<Vec<C32>> {
        crate::ensure!(x.len() == self.n, "length {} != {}", x.len(), self.n);
        let out = self.execute_batch(rt, PlanarBatch::from_complex(x, vec![1, self.n]))?;
        Ok(out.to_complex())
    }
}

/// A cached, batched four-step plan for REAL-input transforms of one
/// (n, algo, direction): the R2C/C2R analogue of [`FourStepPlan`] for
/// sizes beyond the artifact catalog.
///
/// The real transform wraps an `n/2`-point complex four-step engine in
/// the fused half-spectrum pass of
/// [`RealHalfSpectrum`](crate::runtime::RealHalfSpectrum) — the same
/// split/merge kernels (and fp16 rounding points) the interpreter's
/// `rfft1d` path uses, so both R2C engines share one numeric
/// definition. Forward consumes `[b, n]` real rows and emits the
/// Hermitian-packed `[b, n/2 + 1]` spectrum; inverse is the mirror
/// image, scaled by `n` (unnormalized). The coordinator routes
/// `Op::Rfft1d` sizes with no direct artifact to a cached plan from
/// this type.
///
/// The half-spectrum pass is FUSED into the inner engine's final
/// read-out transpose: the complex engine stops after step 4
/// ([`FourStepPlan::execute_batch_pretransposed`]) and the split
/// (forward) / unpack (inverse) gathers straight from the pre-read-out
/// layout, skipping the engine's last transpose and its copy-back
/// entirely. The gathered values are the exact f32s the separate
/// post-pass formulation would have read, so the output is
/// bit-identical to transposing first — enforced by
/// `tests/conformance_rfft.rs`. Steady-state execution allocates only
/// the returned output batch (the half-size staging pair and the inner
/// engine's transpose scratch are retained across calls).
pub struct RealFourStepPlan {
    n: usize,
    inverse: bool,
    /// the half-size complex engine (same direction)
    inner: FourStepPlan,
    /// the fused half-spectrum split/merge pass
    real: RealHalfSpectrum,
    /// retained half-size staging planes (same most-recent-pair policy
    /// as the inner engine's transpose scratch): steady-state execution
    /// allocates only the returned output batch
    scratch: Mutex<Option<ScratchPair>>,
}

impl RealFourStepPlan {
    /// Default-config plan (leaf algo `"tc"`).
    pub fn new(rt: &Runtime, n: usize, inverse: bool) -> Result<RealFourStepPlan> {
        Self::with_config(rt, n, inverse, FourStepConfig::default())
    }

    /// Plan with explicit tuning knobs; `n` must be a power of two
    /// >= 8 so the half size still splits four-step.
    pub fn with_config(
        rt: &Runtime,
        n: usize,
        inverse: bool,
        cfg: FourStepConfig,
    ) -> Result<RealFourStepPlan> {
        if !n.is_power_of_two() || n < 8 {
            crate::bail!(TcFftError::BadSize(n));
        }
        let ec = cfg.algo == "tc_ec";
        let inner = FourStepPlan::with_config(rt, n / 2, inverse, cfg)?;
        Ok(RealFourStepPlan {
            n,
            inverse,
            inner,
            real: RealHalfSpectrum::with_ec(n, ec),
            scratch: Mutex::new(None),
        })
    }

    /// The real transform length `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// True for the C2R (inverse) direction.
    pub fn inverse(&self) -> bool {
        self.inverse
    }

    /// The requested leaf algorithm of the inner complex engine.
    pub fn algo(&self) -> &str {
        self.inner.algo()
    }

    /// Human-readable decomposition of the inner half-size engine.
    pub fn describe(&self) -> String {
        format!("r2c({} x {})", self.n, self.inner.describe())
    }

    /// Estimated resident bytes for cache accounting: the inner
    /// half-size engine plus the split/merge twiddle table (about
    /// `n/4 + 1` complex f32 entries) and the retained half-size
    /// staging pair (16 bytes per half-size element = `8 * n`).
    pub fn memory_bytes(&self) -> usize {
        self.inner.memory_bytes() + (self.n / 4 + 1) * 8 + 8 * self.n
    }

    /// Transform a whole batch in one call: forward `[b, n]` real rows
    /// -> `[b, n/2 + 1]` packed spectra; inverse the mirror image with
    /// the crate-wide unnormalized scaling (`n * x`).
    pub fn execute_batch(&self, rt: &Runtime, x: PlanarBatch) -> Result<PlanarBatch> {
        let m = self.n / 2;
        let want_tail = if self.inverse { m + 1 } else { self.n };
        crate::ensure!(
            x.shape.len() == 2 && x.shape[1] == want_tail,
            "real four-step input shape {:?} != [b, {want_tail}]",
            x.shape
        );
        let b = x.shape[0];
        // no empty-batch early return: input and output tails differ
        // for real transforms, so even b = 0 must flow through to get
        // the correctly shaped output (every pass below is a no-op)
        // quantize up front: the split/merge pass must see the fp16
        // values the device sees (leaf artifacts re-round harmlessly;
        // the ec tier re-marshals its carried sums bit-exactly)
        let mut q = x;
        if self.real.ec() {
            q.quantize_f16_ec_mut();
        } else {
            q.quantize_f16_mut();
        }
        // staging planes from the retained pair (pack/merge overwrite
        // every element, so resizing is the only initialization needed)
        let (mut z_re, mut z_im) = self.scratch.lock().unwrap().take().unwrap_or_default();
        z_re.resize(b * m, 0.0);
        z_im.resize(b * m, 0.0);
        let mut z = PlanarBatch { re: z_re, im: z_im, shape: vec![b, m] };
        // the inner engine stops after step 4; the split/unpack below
        // gathers from the pre-read-out layout (n1, n2), fusing the
        // half-spectrum pass into the skipped final transpose
        let (n1, n2) = self.inner.factors();
        if self.inverse {
            self.real.merge_rows(&q.re, &q.im, &mut z.re, &mut z.im, b);
            let z = self.inner.execute_batch_pretransposed(rt, z)?;
            let mut out = PlanarBatch::new(vec![b, self.n]);
            self.real.unpack_rows_fourstep(&z.re, &z.im, &mut out.re, b, (n1, n2));
            *self.scratch.lock().unwrap() = Some((z.re, z.im));
            Ok(out)
        } else {
            self.real.pack_rows(&q.re, &mut z.re, &mut z.im, b);
            let z = self.inner.execute_batch_pretransposed(rt, z)?;
            let mut out = PlanarBatch::new(vec![b, m + 1]);
            self.real.split_rows_fourstep(&z.re, &z.im, &mut out.re, &mut out.im, b, (n1, n2));
            *self.scratch.lock().unwrap() = Some((z.re, z.im));
            Ok(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::relative_rmse;
    use crate::fft::refdft;
    use crate::hp::complex::widen;
    use crate::workload::random_signal;

    fn rt() -> Runtime {
        Runtime::load("/definitely/not/a/dir").unwrap()
    }

    #[test]
    fn balanced_single_level_decomposition() {
        let rt = rt();
        let p = FourStepPlan::new(&rt, 1 << 18, false).unwrap();
        assert_eq!(p.n(), 1 << 18);
        assert_eq!(p.factors(), (512, 512));
        assert_eq!(p.depth(), 1);
        assert!(p.describe().contains("[tc]"), "{}", p.describe());
    }

    #[test]
    fn small_leaf_cap_forces_multi_level() {
        let rt = rt();
        let cfg = FourStepConfig { max_leaf_log2: 3, ..FourStepConfig::default() };
        let p = FourStepPlan::with_config(&rt, 256, false, cfg).unwrap();
        // 256 = 16 x 16, each 16 = 4 x 4 under an 8-point leaf cap
        assert_eq!(p.factors(), (16, 16));
        assert_eq!(p.depth(), 2, "decomposition: {}", p.describe());
    }

    #[test]
    fn rejects_bad_sizes() {
        let rt = rt();
        assert!(FourStepPlan::new(&rt, 100, false).is_err()); // not a power of two
        assert!(FourStepPlan::new(&rt, 2, false).is_err()); // too small to split
    }

    #[test]
    fn thread_knob_is_respected() {
        let rt = rt();
        let cfg = FourStepConfig { threads: 3, ..FourStepConfig::default() };
        let p = FourStepPlan::with_config(&rt, 1 << 12, false, cfg).unwrap();
        assert_eq!(p.threads(), 3);
        let auto = FourStepPlan::new(&rt, 1 << 12, false).unwrap();
        assert!((1..=64).contains(&auto.threads()));
    }

    #[test]
    fn tiny_four_step_matches_the_dft_definition() {
        let rt = rt();
        for inverse in [false, true] {
            let p = FourStepPlan::new(&rt, 64, inverse).unwrap();
            let x: Vec<C32> = (0..2u64).flat_map(|b| random_signal(64, 7 + b)).collect();
            let input = PlanarBatch::from_complex(&x, vec![2, 64]);
            let out = p.execute_batch(&rt, input.clone()).unwrap();
            let q = input.quantize_f16();
            for b in 0..2 {
                let want = refdft::dft(&widen(&q.to_complex()[b * 64..(b + 1) * 64]), inverse);
                let got = widen(&out.to_complex()[b * 64..(b + 1) * 64]);
                let err = relative_rmse(&want, &got);
                assert!(err < 5e-3, "inverse={inverse} row={b}: rmse {err:.3e}");
            }
        }
    }

    #[test]
    fn real_four_step_matches_the_dft_definition() {
        let rt = rt();
        let n = 128; // forced through the four-step composition (m = 64)
        let p = RealFourStepPlan::new(&rt, n, false).unwrap();
        assert_eq!(p.n(), n);
        assert!(p.describe().starts_with("r2c("), "{}", p.describe());
        let sig: Vec<f32> = random_signal(2 * n, 11).iter().map(|c| c.re).collect();
        let input = PlanarBatch::from_real(&sig, vec![2, n]);
        let out = p.execute_batch(&rt, input.clone()).unwrap();
        assert_eq!(out.shape, vec![2, n / 2 + 1]);
        let q = input.quantize_f16();
        for b in 0..2 {
            let want = refdft::dft(&widen(&q.to_complex()[b * n..(b + 1) * n]), false);
            let got = widen(&out.to_complex()[b * (n / 2 + 1)..(b + 1) * (n / 2 + 1)]);
            let err = relative_rmse(&want[..n / 2 + 1], &got);
            assert!(err < 5e-3, "row {b}: rmse {err:.3e}");
        }
    }

    #[test]
    fn real_four_step_round_trip() {
        let rt = rt();
        let n = 256;
        let fwd = RealFourStepPlan::new(&rt, n, false).unwrap();
        let inv = RealFourStepPlan::new(&rt, n, true).unwrap();
        assert!(inv.inverse());
        let sig: Vec<f32> = random_signal(n, 21).iter().map(|c| c.re).collect();
        let input = PlanarBatch::from_real(&sig, vec![1, n]);
        let spec = fwd.execute_batch(&rt, input.clone()).unwrap();
        let back = inv.execute_batch(&rt, spec).unwrap();
        let q = input.quantize_f16();
        for i in 0..n {
            assert!(
                (back.re[i] / n as f32 - q.re[i]).abs() < 0.01,
                "sample {i}: {} vs {}",
                back.re[i] / n as f32,
                q.re[i]
            );
            assert_eq!(back.im[i], 0.0, "C2R output must be real");
        }
    }

    #[test]
    fn real_four_step_empty_batch_keeps_the_output_tail() {
        // input and output tails differ on the real path, so even an
        // empty batch must come back with the OUTPUT shape
        let rt = rt();
        let fwd = RealFourStepPlan::new(&rt, 64, false).unwrap();
        let out = fwd.execute_batch(&rt, PlanarBatch::new(vec![0, 64])).unwrap();
        assert_eq!(out.shape, vec![0, 33]);
        let inv = RealFourStepPlan::new(&rt, 64, true).unwrap();
        let out = inv.execute_batch(&rt, PlanarBatch::new(vec![0, 33])).unwrap();
        assert_eq!(out.shape, vec![0, 64]);
    }

    #[test]
    fn real_four_step_rejects_bad_sizes() {
        let rt = rt();
        assert!(RealFourStepPlan::new(&rt, 100, false).is_err());
        assert!(RealFourStepPlan::new(&rt, 4, false).is_err()); // half too small
    }

    #[test]
    fn single_sequence_wrapper_agrees_with_batch() {
        let rt = rt();
        let p = FourStepPlan::new(&rt, 256, false).unwrap();
        let x = random_signal(256, 42);
        let single = p.execute(&rt, &x).unwrap();
        let batch = p
            .execute_batch(&rt, PlanarBatch::from_complex(&x, vec![1, 256]))
            .unwrap()
            .to_complex();
        for (a, b) in single.iter().zip(&batch) {
            assert_eq!(a.re.to_bits(), b.re.to_bits());
            assert_eq!(a.im.to_bits(), b.im.to_bits());
        }
    }
}
