//! The pre-PR per-sequence four-step path, kept verbatim as the
//! "before" series of `BENCH_interp.json` (entry
//! `fourstep_tc_n1048576_b8_fwd`) and as a cross-check oracle for the
//! batched engine in `large::FourStepPlan`.
//!
//! Its costs are the point: one sequence per call, element-wise
//! gather/scatter transposes, per-call recomputation of the full
//! N1 x N2 `C64` twiddle table, and fresh allocations for every
//! intermediate. Do not "fix" those here — the batched engine in
//! `large/mod.rs` is the fix, and this module is what it is measured
//! against.

use crate::error::{Result, TcFftError};
use crate::fft::twiddle::four_step_twiddles;
use crate::hp::C32;
use crate::runtime::{PlanarBatch, Runtime};

/// A single-level four-step plan for N = n1 * n2 built on 1D batched
/// artifacts, executed one sequence at a time (the kept baseline).
pub struct BaselineFourStep {
    pub n1: usize,
    pub n2: usize,
    key_n1: String,
    key_n2: String,
    batch_n1: usize,
    batch_n2: usize,
    inverse: bool,
}

impl BaselineFourStep {
    /// Choose a balanced decomposition whose factors both have
    /// artifacts for `algo` (no fallback: the baseline is single-algo).
    pub fn new(rt: &Runtime, n: usize, algo: &str, inverse: bool) -> Result<BaselineFourStep> {
        if !n.is_power_of_two() {
            crate::bail!("four-step size must be a power of two, got {n}");
        }
        // prefer balanced factors with available artifacts
        let mut best: Option<(usize, usize, String, String, usize, usize)> = None;
        let t = n.trailing_zeros() as usize;
        for t1 in 1..t {
            let n1 = 1usize << t1;
            let n2 = n / n1;
            let v1 = rt.registry.find_fft1d(n1, usize::MAX, algo, inverse);
            let v2 = rt.registry.find_fft1d(n2, usize::MAX, algo, inverse);
            if let (Some(v1), Some(v2)) = (v1, v2) {
                let balance = (t1 as isize - (t - t1) as isize).abs();
                let cur = best
                    .as_ref()
                    .map(|(b1, b2, ..)| {
                        let bt1 = b1.trailing_zeros() as isize;
                        let bt2 = b2.trailing_zeros() as isize;
                        (bt1 - bt2).abs()
                    })
                    .unwrap_or(isize::MAX);
                if balance < cur {
                    best = Some((
                        n1,
                        n2,
                        v1.key.clone(),
                        v2.key.clone(),
                        v1.batch,
                        v2.batch,
                    ));
                }
            }
        }
        let (n1, n2, key_n1, key_n2, batch_n1, batch_n2) = best.ok_or_else(|| {
            TcFftError::NoArtifact(format!("pair factoring {n}; build more 1D variants"))
        })?;
        Ok(BaselineFourStep { n1, n2, key_n1, key_n2, batch_n1, batch_n2, inverse })
    }

    /// The composed transform length `n1 * n2`.
    pub fn n(&self) -> usize {
        self.n1 * self.n2
    }

    /// Run batched column FFTs of length `len` over a row-major
    /// (rows x cols) matrix laid out in `x`, using artifact `key`.
    fn device_fft_cols(
        &self,
        rt: &Runtime,
        key: &str,
        cap: usize,
        x: &mut [C32],
        rows: usize,
        cols: usize,
    ) -> Result<()> {
        // gather columns into a (cols, rows) planar batch, run, scatter
        let mut seqs = PlanarBatch::new(vec![cols, rows]);
        for c in 0..cols {
            for r in 0..rows {
                seqs.re[c * rows + r] = x[r * cols + c].re;
                seqs.im[c * rows + r] = x[r * cols + c].im;
            }
        }
        let out = self.run_batched(rt, key, cap, seqs)?;
        for c in 0..cols {
            for r in 0..rows {
                x[r * cols + c] = C32::new(out.re[c * rows + r], out.im[c * rows + r]);
            }
        }
        Ok(())
    }

    fn device_fft_rows(
        &self,
        rt: &Runtime,
        key: &str,
        cap: usize,
        x: &mut [C32],
        rows: usize,
        cols: usize,
    ) -> Result<()> {
        let mut seqs = PlanarBatch::new(vec![rows, cols]);
        for (i, c) in x.iter().enumerate() {
            seqs.re[i] = c.re;
            seqs.im[i] = c.im;
        }
        let out = self.run_batched(rt, key, cap, seqs)?;
        for (i, c) in x.iter_mut().enumerate() {
            *c = C32::new(out.re[i], out.im[i]);
        }
        Ok(())
    }

    fn run_batched(
        &self,
        rt: &Runtime,
        key: &str,
        cap: usize,
        x: PlanarBatch,
    ) -> Result<PlanarBatch> {
        let b = x.shape[0];
        let mut outs = Vec::new();
        let mut lo = 0;
        while lo < b {
            let hi = (lo + cap).min(b);
            let chunk = x.slice_rows(lo, hi).pad_batch(cap);
            let (out, _) = rt.execute(key, chunk)?;
            outs.push(out.slice_rows(0, hi - lo));
            lo = hi;
        }
        Ok(PlanarBatch::concat(&outs))
    }

    /// Execute the four-step FFT over one length-N sequence.
    pub fn execute(&self, rt: &Runtime, x: &[C32]) -> Result<Vec<C32>> {
        let (n1, n2) = (self.n1, self.n2);
        crate::ensure!(x.len() == n1 * n2, "length {} != {}", x.len(), n1 * n2);
        // row-major matrix M[j][k] = x[j*n2 + k]
        let mut m = x.to_vec();
        // step 1: FFT columns (length n1)
        self.device_fft_cols(rt, &self.key_n1, self.batch_n1, &mut m, n1, n2)?;
        // step 2: twiddle M[j][k] *= W_N^{jk} (table rebuilt every call
        // — the cost the cached flat table in the batched engine kills)
        let tw = four_step_twiddles(n1, n2, self.inverse);
        for j in 0..n1 {
            for k in 0..n2 {
                let w = tw[j][k];
                let v = m[j * n2 + k];
                m[j * n2 + k] = C32::new(
                    (v.re as f64 * w.re - v.im as f64 * w.im) as f32,
                    (v.re as f64 * w.im + v.im as f64 * w.re) as f32,
                );
            }
        }
        // step 3: FFT rows (length n2)
        self.device_fft_rows(rt, &self.key_n2, self.batch_n2, &mut m, n1, n2)?;
        // step 4: transpose read-out X[k*n1 + j] = M[j][k]
        let mut out = vec![C32::new(0.0, 0.0); n1 * n2];
        for j in 0..n1 {
            for k in 0..n2 {
                out[k * n1 + j] = m[j * n2 + k];
            }
        }
        Ok(out)
    }
}
