//! 2D schedule composition for large real images: the `rfft2d` /
//! `irfft2d` route beyond the artifact catalog.
//!
//! [`Plan2d`] composes the two batched four-step engines of the parent
//! module into a full 2D real transform over the Hermitian-packed
//! `[b, nx, ny/2 + 1]` layout — the same packing contract the catalog
//! artifacts and the interpreter's `run_real_2d` wrapper use, built
//! from the same pass primitives (the `RealHalfSpectrum` split/merge
//! kernels via [`RealFourStepPlan`], the tiled transposes of
//! `large::transpose_range`), so all three 2D paths share one numeric
//! definition:
//!
//! * **row pass** — every image row runs through a ny-point
//!   [`RealFourStepPlan`]: forward packs `[b*nx, ny]` real rows into
//!   the half-size complex pipeline and splits (fused into the inner
//!   engine's skipped read-out transpose) into packed `[b*nx, L]`
//!   Hermitian rows, `L = ny/2 + 1`; inverse mirrors it (merge, half
//!   pipeline, unpack), scaled by `ny`;
//! * **column pass** — each of the `L` packed bin columns runs through
//!   an nx-point complex [`FourStepPlan`] (inverse scaled by `nx`, so
//!   the round trip carries the crate-wide unnormalized `nx * ny`).
//!
//! The column pass is **cache-blocked**: panels of `w` adjacent bin
//! columns are gathered with the parent module's tiled transpose into a
//! `[b*w, nx]` row batch (contiguous rows — exactly what the column
//! engine batches over), transformed, and scattered back through the
//! strided transpose variant. The panel width is chosen so the gathered
//! working set stays inside [`PANEL_BUDGET_ELEMS`], and the panel planes
//! are retained across calls like every other scratch pair in `large/`,
//! so steady-state execution allocates only the returned batch.
//!
//! Pass boundaries stay explicit (row pass, panel gather, column pass,
//! panel scatter) rather than fusing into a monolith: the streaming
//! work in ROADMAP item 4 reuses this composition shape with resident
//! spectra between the passes. The stage-level view of the same
//! composition lives in `plan::schedule::rfft2d_schedule`, built from
//! the shared `rfft2d_row_stages` / `rfft2d_col_stages` helpers this
//! plan's [`stages`](Plan2d::stages) also reports.

use std::sync::Mutex;

use super::{
    transpose_range, transpose_range_strided, FourStepConfig, FourStepPlan, RealFourStepPlan,
    ScratchPair,
};
use crate::error::{Result, TcFftError};
use crate::plan::schedule::{rfft2d_col_stages, rfft2d_row_stages, PlannedStage};
use crate::runtime::{PlanarBatch, Runtime};

/// Per-panel element budget for the cache-blocked column pass: the
/// gathered panel holds `b * w * nx` complex elements (two f32 planes,
/// 8 bytes each), so 2^19 elements caps the panel working set at 4 MiB
/// — small enough to stay cache-warm next to the column engine's own
/// transpose scratch, large enough that the per-panel engine dispatch
/// amortizes.
const PANEL_BUDGET_ELEMS: usize = 1 << 19;

/// A cached, batched 2D four-step composition for one
/// (nx, ny, algo, direction): real `[b, nx, ny]` images to packed
/// `[b, nx, ny/2 + 1]` Hermitian spectra (forward) and back (inverse,
/// unnormalized — the round trip returns `nx * ny * x`).
///
/// Build once (both inner engines precompute their decomposition trees
/// and twiddle tables here), then call
/// [`execute_batch`](Self::execute_batch) per request batch. Plans are
/// `Send + Sync`; the coordinator shares them behind `Arc` in the same
/// LRU `large_plans` cache as the 1D four-step plans.
pub struct Plan2d {
    nx: usize,
    ny: usize,
    inverse: bool,
    /// the ny-point real row engine (same direction)
    rows: RealFourStepPlan,
    /// the nx-point complex column engine (same direction)
    cols: FourStepPlan,
    /// retained panel planes for the cache-blocked column pass (same
    /// most-recent-pair policy as the engines' transpose scratch)
    panel: Mutex<Option<ScratchPair>>,
}

impl Plan2d {
    /// Default-config plan (leaf algo `"tc"`).
    pub fn new(rt: &Runtime, nx: usize, ny: usize, inverse: bool) -> Result<Plan2d> {
        Self::with_config(rt, nx, ny, inverse, FourStepConfig::default())
    }

    /// Plan with explicit tuning knobs, shared by both inner engines.
    /// `nx` must be a power of two >= 4 with a four-step decomposition
    /// (>= 16 against the synthesized catalog), `ny` a power of two
    /// >= 8 so the row transform's half size still splits.
    pub fn with_config(
        rt: &Runtime,
        nx: usize,
        ny: usize,
        inverse: bool,
        cfg: FourStepConfig,
    ) -> Result<Plan2d> {
        if !nx.is_power_of_two() || nx < 4 {
            crate::bail!(TcFftError::BadSize(nx));
        }
        if !ny.is_power_of_two() || ny < 8 {
            crate::bail!(TcFftError::BadSize(ny));
        }
        let rows = RealFourStepPlan::with_config(rt, ny, inverse, cfg.clone())?;
        let cols = FourStepPlan::with_config(rt, nx, inverse, cfg)?;
        Ok(Plan2d { nx, ny, inverse, rows, cols, panel: Mutex::new(None) })
    }

    /// Image rows (the outer, column-transformed dimension).
    pub fn nx(&self) -> usize {
        self.nx
    }

    /// Image columns (the inner, real-transformed dimension).
    pub fn ny(&self) -> usize {
        self.ny
    }

    /// True for the C2R (inverse) direction.
    pub fn inverse(&self) -> bool {
        self.inverse
    }

    /// The requested leaf algorithm of the inner engines.
    pub fn algo(&self) -> &str {
        self.cols.algo()
    }

    /// Packed Hermitian bins per row, `ny/2 + 1`.
    pub fn bins(&self) -> usize {
        self.ny / 2 + 1
    }

    /// Human-readable composition, e.g.
    /// `r2c2d(2048x2048: rows r2c(2048 x (32[tc] x 32[tc])), cols (64[tc] x 32[tc]))`.
    pub fn describe(&self) -> String {
        format!(
            "r2c2d({}x{}: rows {}, cols {})",
            self.nx,
            self.ny,
            self.rows.describe(),
            self.cols.describe()
        )
    }

    /// The planner-level stage sequence of this composition — the same
    /// shared row/column stage helpers the catalog's `rfft2d_schedule`
    /// composes, in this plan's direction order.
    pub fn stages(&self) -> Vec<PlannedStage> {
        let rows = rfft2d_row_stages(self.ny, self.inverse);
        let cols = rfft2d_col_stages(self.nx, self.ny);
        if self.inverse {
            cols.into_iter().chain(rows).collect()
        } else {
            rows.into_iter().chain(cols).collect()
        }
    }

    /// Estimated resident bytes for cache accounting: both inner
    /// engines plus the retained panel pair at its nominal single-image
    /// steady-state size (8 bytes per panel element, capped by the
    /// panel budget).
    pub fn memory_bytes(&self) -> usize {
        let panel = PANEL_BUDGET_ELEMS.min(self.bins() * self.nx);
        self.rows.memory_bytes() + self.cols.memory_bytes() + 8 * panel
    }

    /// Transform a whole batch of images in one call: forward
    /// `[b, nx, ny]` real images -> `[b, nx, ny/2 + 1]` packed spectra;
    /// inverse the mirror image with the crate-wide unnormalized
    /// scaling (`nx * ny * x`). Row and column passes run in this
    /// plan's direction order (forward rows-then-columns, inverse
    /// columns-then-rows), exactly like the interpreter's catalog path.
    pub fn execute_batch(&self, rt: &Runtime, x: PlanarBatch) -> Result<PlanarBatch> {
        let l = self.bins();
        let want_tail = if self.inverse { [self.nx, l] } else { [self.nx, self.ny] };
        crate::ensure!(
            x.shape.len() == 3 && x.shape[1..] == want_tail,
            "2D four-step input shape {:?} != [b, {}, {}]",
            x.shape,
            want_tail[0],
            want_tail[1]
        );
        let b = x.shape[0];
        if self.inverse {
            // column pass over the packed bins first, then the C2R rows
            // (the forward order mirrored). The packed spectrum is
            // quantized up front so the column engine sees the fp16
            // values the interpreter path sees; the row engine's merge
            // pass re-quantizes its own input as always.
            let mut packed = PlanarBatch { re: x.re, im: x.im, shape: vec![b * self.nx, l] };
            if self.algo() == "tc_ec" {
                packed.quantize_f16_ec_mut();
            } else {
                packed.quantize_f16_mut();
            }
            self.column_pass(rt, &mut packed, b)?;
            let out = self.rows.execute_batch(rt, packed)?;
            Ok(PlanarBatch { re: out.re, im: out.im, shape: vec![b, self.nx, self.ny] })
        } else {
            // row pass: [b*nx, ny] real rows -> [b*nx, L] packed rows,
            // which IS the packed [b, nx, L] image contiguously
            let rows_in = PlanarBatch { re: x.re, im: x.im, shape: vec![b * self.nx, self.ny] };
            let mut packed = self.rows.execute_batch(rt, rows_in)?;
            self.column_pass(rt, &mut packed, b)?;
            Ok(PlanarBatch { re: packed.re, im: packed.im, shape: vec![b, self.nx, l] })
        }
    }

    /// The nx-point complex pass down the packed bin columns of `b`
    /// images (`packed` holds `b * nx * L` elements): panels of up to
    /// `pw` adjacent bin columns are gathered per image with the tiled
    /// transpose into a `[b*w, nx]` row batch, run through the column
    /// engine, and scattered back through the strided transpose. The
    /// gather/scatter sweeps are serial (panel order is part of the
    /// bitwise contract); the column engine parallelizes internally
    /// with its own serial==parallel guarantee.
    fn column_pass(&self, rt: &Runtime, packed: &mut PlanarBatch, b: usize) -> Result<()> {
        let (nx, l) = (self.nx, self.bins());
        debug_assert_eq!(packed.re.len(), b * nx * l);
        if b == 0 {
            return Ok(());
        }
        let pw = (PANEL_BUDGET_ELEMS / (b * nx)).clamp(1, l);
        let (mut p_re, mut p_im) = self.panel.lock().unwrap().take().unwrap_or_default();
        p_re.resize(b * pw * nx, 0.0);
        p_im.resize(b * pw * nx, 0.0);
        let img = nx * l;
        let mut c0 = 0usize;
        while c0 < l {
            let w = pw.min(l - c0);
            // the width only shrinks (last partial panel), so truncate
            // keeps the recycled planes exactly [b*w, nx]
            p_re.truncate(b * w * nx);
            p_im.truncate(b * w * nx);
            // gather: panel row i*w + (c - c0) is bin column c of
            // image i — panel[(c-c0)*nx + x] = img_i[x*L + c]
            for i in 0..b {
                let (s, d) = (i * img, i * w * nx);
                transpose_range(
                    (&packed.re[s..s + img], &packed.im[s..s + img]),
                    (&mut p_re[d..d + w * nx], &mut p_im[d..d + w * nx]),
                    (c0, c0 + w),
                    (l, nx),
                    None,
                );
            }
            let out = self
                .cols
                .execute_batch(rt, PlanarBatch { re: p_re, im: p_im, shape: vec![b * w, nx] })?;
            // scatter back with the packed row stride L:
            // img_i[x*L + c0 + c] = out_i[c*nx + x]
            for i in 0..b {
                let s = i * w * nx;
                let d0 = i * img + c0;
                let d1 = (i + 1) * img;
                transpose_range_strided(
                    (&out.re[s..s + w * nx], &out.im[s..s + w * nx]),
                    (&mut packed.re[d0..d1], &mut packed.im[d0..d1]),
                    (0, nx),
                    (nx, w),
                    l,
                    None,
                );
            }
            // recycle the engine-returned planes for the next panel
            p_re = out.re;
            p_im = out.im;
            c0 += w;
        }
        *self.panel.lock().unwrap() = Some((p_re, p_im));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::relative_rmse;
    use crate::fft::oracle2d;
    use crate::hp::complex::widen;
    use crate::hp::C64;
    use crate::workload::random_signal;

    fn rt() -> Runtime {
        Runtime::load("/definitely/not/a/dir").unwrap()
    }

    fn real_fields(nx: usize, ny: usize, batch: usize, seed: u64) -> Vec<f32> {
        (0..batch)
            .flat_map(|b| random_signal(nx * ny, seed + b as u64))
            .map(|c| c.re)
            .collect()
    }

    /// Forward Plan2d vs the f64 2D oracle on the packed bins, for a
    /// rectangular shape in both orientations (no baked-in squareness).
    #[test]
    fn forward_matches_the_2d_oracle_rectangular() {
        let rt = rt();
        for (nx, ny) in [(32usize, 64usize), (64, 32)] {
            let l = ny / 2 + 1;
            let p = Plan2d::new(&rt, nx, ny, false).unwrap();
            assert_eq!((p.nx(), p.ny(), p.bins()), (nx, ny, l));
            assert!(p.describe().starts_with("r2c2d("), "{}", p.describe());
            let sig = real_fields(nx, ny, 2, 31);
            let input = PlanarBatch::from_real(&sig, vec![2, nx, ny]);
            let out = p.execute_batch(&rt, input.clone()).unwrap();
            assert_eq!(out.shape, vec![2, nx, l]);
            let q = input.quantize_f16();
            for b in 0..2 {
                let img = widen(&q.to_complex()[b * nx * ny..(b + 1) * nx * ny]);
                let full = oracle2d(&img, nx, ny, false);
                let want: Vec<C64> =
                    (0..nx).flat_map(|r| full[r * ny..r * ny + l].to_vec()).collect();
                let got = widen(&out.to_complex()[b * nx * l..(b + 1) * nx * l]);
                let err = relative_rmse(&want, &got);
                assert!(err < 5e-3, "{nx}x{ny} field {b}: rmse {err:.3e}");
            }
        }
    }

    #[test]
    fn round_trip_recovers_the_quantized_image() {
        let rt = rt();
        let (nx, ny) = (64usize, 32usize);
        let fwd = Plan2d::new(&rt, nx, ny, false).unwrap();
        let inv = Plan2d::new(&rt, nx, ny, true).unwrap();
        assert!(inv.inverse());
        let sig = real_fields(nx, ny, 1, 7);
        let input = PlanarBatch::from_real(&sig, vec![1, nx, ny]);
        let spec = fwd.execute_batch(&rt, input.clone()).unwrap();
        let back = inv.execute_batch(&rt, spec).unwrap();
        assert_eq!(back.shape, vec![1, nx, ny]);
        let q = input.quantize_f16();
        let scale = (nx * ny) as f32;
        for i in 0..nx * ny {
            assert!(
                (back.re[i] / scale - q.re[i]).abs() < 0.01,
                "sample {i}: {} vs {}",
                back.re[i] / scale,
                q.re[i]
            );
            assert_eq!(back.im[i], 0.0, "C2R output must be real");
        }
    }

    #[test]
    fn stages_compose_rows_and_columns_in_direction_order() {
        let rt = rt();
        let fwd = Plan2d::new(&rt, 32, 64, false).unwrap();
        let st = fwd.stages();
        assert_eq!(st.last().unwrap().lane, 33, "forward ends on the column pass");
        assert_eq!(st.first().unwrap().lane, 1, "forward starts on the row pass");
        let inv = Plan2d::new(&rt, 32, 64, true).unwrap();
        let st = inv.stages();
        assert_eq!(st.first().unwrap().lane, 33, "inverse starts on the column pass");
    }

    #[test]
    fn empty_batch_keeps_the_output_tail() {
        let rt = rt();
        let fwd = Plan2d::new(&rt, 32, 64, false).unwrap();
        let out = fwd.execute_batch(&rt, PlanarBatch::new(vec![0, 32, 64])).unwrap();
        assert_eq!(out.shape, vec![0, 32, 33]);
        let inv = Plan2d::new(&rt, 32, 64, true).unwrap();
        let out = inv.execute_batch(&rt, PlanarBatch::new(vec![0, 32, 33])).unwrap();
        assert_eq!(out.shape, vec![0, 32, 64]);
    }

    #[test]
    fn rejects_bad_shapes_and_sizes() {
        let rt = rt();
        assert!(Plan2d::new(&rt, 100, 64, false).is_err()); // nx not pow2
        assert!(Plan2d::new(&rt, 32, 4, false).is_err()); // ny half too small
        let p = Plan2d::new(&rt, 32, 64, false).unwrap();
        // 2D input must be rank 3 with the exact [nx, ny] tail
        assert!(p.execute_batch(&rt, PlanarBatch::new(vec![32, 64])).is_err());
        assert!(p.execute_batch(&rt, PlanarBatch::new(vec![1, 64, 32])).is_err());
    }
}
