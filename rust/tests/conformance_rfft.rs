//! Conformance of the real-input (R2C/C2R) path against the host f64
//! oracles, for BOTH engines: the interpreter's `rfft1d`/`rfft2d`
//! plans (1D: every power-of-two size 2^4..=2^16; 2D: squares
//! 8x8..256x256 plus rectangles — each at request batches {1, 4, 32})
//! and the `large::RealFourStepPlan` four-step composition, plus the
//! `large::Plan2d` 2D row/column composition (rectangular large sizes
//! and its serial==parallel bitwise contract). Checked by
//! relative RMSE over the Hermitian-packed bins, plus the
//! packed-layout property tests (Hermitian symmetry, real endpoints,
//! the 2D conjugate mirror against the C2C `fft2d` spectrum), the
//! irfft(rfft(x)) / irfft2d(rfft2d(x)) round trips, R2C-vs-C2C
//! agreement on promoted real inputs, and the bitwise equivalence of
//! the fused four-step read-out with the separate post-pass
//! formulation it replaced.
//!
//! Oracle strategy matches `conformance_interpreter.rs`: sizes <= 512
//! go straight to the O(N^2) DFT definition (`fft::refdft`); larger
//! sizes use the f64 radix-2 FFT (2D oracles apply the same rule per
//! axis, rows then columns). The fp16 pipeline simulation of this path
//! measures forward rel-RMSE 4e-4..6e-4 over 2^4..2^16, so the 5e-3
//! bound keeps ~10x margin while failing on structural errors.

use std::sync::{Arc, OnceLock};

use tcfft::error::relative_rmse;
use tcfft::fft::{radix2, refdft};
use tcfft::hp::{C32, C64};
use tcfft::large::{FourStepConfig, FourStepPlan, Plan2d, RealFourStepPlan};
use tcfft::plan::Plan;
use tcfft::runtime::{PlanarBatch, RealHalfSpectrum, Registry, Runtime};
use tcfft::workload::random_signal;

const RMSE_TOL: f64 = 5e-3;

fn runtime() -> &'static Runtime {
    static RT: OnceLock<Runtime> = OnceLock::new();
    RT.get_or_init(|| {
        Runtime::with_backend(
            Arc::new(Registry::synthesize()),
            Box::new(tcfft::runtime::CpuInterpreter::new()),
        )
    })
}

fn widen(x: &[C32]) -> Vec<C64> {
    x.iter().map(|c| C64::new(c.re as f64, c.im as f64)).collect()
}

/// Uniform [-1, 1) real rows (the re parts of the paper TestCase).
fn real_rows(n: usize, batch: usize, seed: u64) -> Vec<f32> {
    (0..batch)
        .flat_map(|b| random_signal(n, seed + b as u64))
        .map(|c| c.re)
        .collect()
}

/// f64 oracle spectrum of one fp16-quantized real row.
fn oracle_row(quantized: &[C64], inverse: bool) -> Vec<C64> {
    if quantized.len() <= 512 {
        refdft::dft(quantized, inverse)
    } else {
        radix2::fft_vec(quantized, inverse)
    }
}

fn check_r2c(n: usize, batch: usize, seed: u64) {
    let rt = runtime();
    let plan = Plan::rfft1d(&rt.registry, n, batch).unwrap();
    let input = PlanarBatch::from_real(&real_rows(n, batch, seed), vec![batch, n]);
    let out = plan.execute(rt, input.clone()).unwrap();
    let bins = n / 2 + 1;
    assert_eq!(out.shape, vec![batch, bins]);

    let q = widen(&input.quantize_f16().to_complex());
    let got = widen(&out.to_complex());
    for b in 0..batch {
        let want = oracle_row(&q[b * n..(b + 1) * n], false);
        let rmse = relative_rmse(&want[..bins], &got[b * bins..(b + 1) * bins]);
        assert!(
            rmse < RMSE_TOL,
            "n={n} batch={batch} row={b}: packed rel-RMSE {rmse:.3e} over {RMSE_TOL:.1e}"
        );
    }
}

#[test]
fn r2c_all_sizes_batch_1() {
    for t in 4..=16usize {
        check_r2c(1 << t, 1, 0x1A00 + t as u64);
    }
}

#[test]
fn r2c_all_sizes_batch_4() {
    for t in 4..=16usize {
        check_r2c(1 << t, 4, 0x2B00 + t as u64);
    }
}

#[test]
fn r2c_all_sizes_batch_32() {
    for t in 4..=16usize {
        check_r2c(1 << t, 32, 0x3C00 + t as u64);
    }
}

// ---------------------------------------------------------------------
// 2D real transforms
// ---------------------------------------------------------------------

fn check_r2c2d(rt: &Runtime, nx: usize, ny: usize, batch: usize, seed: u64) {
    let plan = Plan::rfft2d(&rt.registry, nx, ny, batch).unwrap();
    let input = PlanarBatch::from_real(&real_rows(nx * ny, batch, seed), vec![batch, nx, ny]);
    let out = plan.execute(rt, input.clone()).unwrap();
    let bins = ny / 2 + 1;
    assert_eq!(out.shape, vec![batch, nx, bins]);

    let q = widen(&input.quantize_f16().to_complex());
    let got = widen(&out.to_complex());
    for b in 0..batch {
        let want = tcfft::fft::oracle2d(&q[b * nx * ny..(b + 1) * nx * ny], nx, ny, false);
        // the packed output holds bins 0..=ny/2 of every row
        let want_packed: Vec<C64> = (0..nx)
            .flat_map(|r| want[r * ny..r * ny + bins].to_vec())
            .collect();
        let rmse = relative_rmse(&want_packed, &got[b * nx * bins..(b + 1) * nx * bins]);
        assert!(
            rmse < RMSE_TOL,
            "{nx}x{ny} batch={batch} field={b}: packed rel-RMSE {rmse:.3e} over {RMSE_TOL:.1e}"
        );
    }
}

#[test]
fn r2c2d_all_sizes_batch_1() {
    for t in 3..=8usize {
        check_r2c2d(runtime(), 1 << t, 1 << t, 1, 0x7100 + t as u64);
    }
}

#[test]
fn r2c2d_all_sizes_batch_4() {
    for t in 3..=8usize {
        check_r2c2d(runtime(), 1 << t, 1 << t, 4, 0x7200 + t as u64);
    }
    // the rectangular shapes exercise nx != ny routing
    check_r2c2d(runtime(), 64, 128, 4, 0x72F0);
    check_r2c2d(runtime(), 128, 64, 4, 0x72F1);
}

#[test]
fn r2c2d_all_sizes_batch_32() {
    for t in 3..=8usize {
        check_r2c2d(runtime(), 1 << t, 1 << t, 32, 0x7300 + t as u64);
    }
}

#[test]
fn r2c2d_matches_the_oracle_on_the_reference_engine_too() {
    // the acceptance criterion names BOTH engines: the batch-major
    // CpuInterpreter (every test above) and the kept pre-PR
    // ReferenceInterpreter must each match the f64 oracle
    let reference = Runtime::with_backend(
        Arc::new(Registry::synthesize()),
        Box::new(tcfft::runtime::ReferenceInterpreter::new()),
    );
    check_r2c2d(&reference, 16, 16, 4, 0x7400);
    check_r2c2d(&reference, 64, 128, 2, 0x7401);
}

#[test]
fn packed_2d_output_mirrors_the_c2c_spectrum() {
    // the packed rfft2d bins must agree with the full fft2d spectrum
    // of the promoted input on the stored half, and with its conjugate
    // mirror X[(nx-r)%nx, (ny-c)%ny] = conj X[r, c] on the half the
    // packing never materializes; the four corner bins (kx and ky both
    // 0 or the Nyquist) are real up to fp16 noise
    let rt = runtime();
    let (nx, ny) = (128usize, 128usize);
    let bins = ny / 2 + 1;
    let sig = real_rows(nx * ny, 1, 0xE1);
    let rplan = Plan::rfft2d(&rt.registry, nx, ny, 1).unwrap();
    let packed = rplan
        .execute(rt, PlanarBatch::from_real(&sig, vec![1, nx, ny]))
        .unwrap();
    let cplan = Plan::fft2d(&rt.registry, nx, ny, 1).unwrap();
    let full = cplan
        .execute(rt, PlanarBatch::from_real(&sig, vec![1, nx, ny]))
        .unwrap();
    let fullc = widen(&full.to_complex());
    let packc = widen(&packed.to_complex());
    let scale = fullc.iter().map(|c| c.abs()).fold(0.0, f64::max);
    for r in 0..nx {
        for c in 0..bins {
            let p = packc[r * bins + c];
            let direct = fullc[r * ny + c];
            let mirror = fullc[((nx - r) % nx) * ny + (ny - c) % ny].conj();
            assert!(
                (p - direct).abs() < 0.02 * scale,
                "bin ({r},{c}): packed vs full"
            );
            assert!(
                (p - mirror).abs() < 0.02 * scale,
                "bin ({r},{c}): packed vs conj mirror"
            );
        }
    }
    for (r, c) in [(0usize, 0usize), (nx / 2, 0), (0, ny / 2), (nx / 2, ny / 2)] {
        assert!(
            packc[r * bins + c].im.abs() < 1e-2 * scale,
            "corner bin ({r},{c}) must be real up to fp16 noise"
        );
    }
}

#[test]
fn irfft2d_of_rfft2d_round_trips() {
    // forward then unnormalized inverse, scaled back by 1/(nx*ny),
    // recovers the quantized field
    let rt = runtime();
    for (nx, ny) in [(16usize, 16usize), (64, 64), (64, 128)] {
        let fwd = Plan::rfft2d(&rt.registry, nx, ny, 4).unwrap();
        let inv = Plan::irfft2d(&rt.registry, nx, ny, 4).unwrap();
        let input = PlanarBatch::from_real(
            &real_rows(nx * ny, 4, 0xF000 + (nx * ny) as u64),
            vec![4, nx, ny],
        );
        let spec = fwd.execute(rt, input.clone()).unwrap();
        let back = inv.execute(rt, spec).unwrap();
        assert_eq!(back.shape, vec![4, nx, ny]);
        let q = input.quantize_f16();
        let scale = (nx * ny) as f64;
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for i in 0..4 * nx * ny {
            let d = back.re[i] as f64 / scale - q.re[i] as f64;
            num += d * d;
            den += (q.re[i] as f64) * (q.re[i] as f64);
            assert_eq!(back.im[i], 0.0, "C2R output must be real");
        }
        let rmse = (num / den).sqrt();
        assert!(rmse < 2.0 * RMSE_TOL, "{nx}x{ny}: round-trip rmse {rmse:.3e}");
    }
}

#[test]
fn packed_output_is_hermitian() {
    // the packed bins must agree with the conjugate-symmetric full
    // spectrum: X[n-k] = conj(X[k]) — checked against the C2C engine
    // on the promoted input — and the endpoint bins are exactly real
    let rt = runtime();
    for n in [64usize, 1024, 8192] {
        let bins = n / 2 + 1;
        let sig = real_rows(n, 1, 0xD0 + n as u64);
        let rplan = Plan::rfft1d(&rt.registry, n, 1).unwrap();
        let packed = rplan
            .execute(rt, PlanarBatch::from_real(&sig, vec![1, n]))
            .unwrap();
        assert_eq!(packed.im[0], 0.0, "n={n}: bin 0 must be exactly real");
        assert_eq!(packed.im[bins - 1], 0.0, "n={n}: bin n/2 must be exactly real");

        let cplan = Plan::fft1d(&rt.registry, n, 1).unwrap();
        let full = cplan
            .execute(rt, PlanarBatch::from_real(&sig, vec![1, n]))
            .unwrap();
        // the full spectrum of a real signal is Hermitian; its first
        // half must match the packed output, its second half the
        // conjugate mirror — both within the two engines' fp16 noise
        let fullc = widen(&full.to_complex());
        let packc = widen(&packed.to_complex());
        let mirror: Vec<C64> = (0..bins).map(|k| fullc[(n - k) % n].conj()).collect();
        let scale = fullc.iter().map(|c| c.abs()).fold(0.0, f64::max);
        for k in 0..bins {
            assert!(
                (packc[k] - fullc[k]).abs() < 0.02 * scale,
                "n={n} bin {k}: packed vs full"
            );
            assert!(
                (packc[k] - mirror[k]).abs() < 0.02 * scale,
                "n={n} bin {k}: packed vs conj mirror"
            );
        }
    }
}

#[test]
fn irfft_of_rfft_round_trips() {
    // forward then unnormalized inverse, scaled back by 1/n, recovers
    // the quantized signal. Sizes stay <= 2^14 for the same fp16
    // dynamic-range reason as the complex round-trip test.
    let rt = runtime();
    for t in [4usize, 8, 12, 14] {
        let n = 1 << t;
        let fwd = Plan::rfft1d(&rt.registry, n, 4).unwrap();
        let inv = Plan::irfft1d(&rt.registry, n, 4).unwrap();
        let input = PlanarBatch::from_real(&real_rows(n, 4, 0x4E00 + t as u64), vec![4, n]);
        let spec = fwd.execute(rt, input.clone()).unwrap();
        let back = inv.execute(rt, spec).unwrap();
        assert_eq!(back.shape, vec![4, n]);
        let q = input.quantize_f16();
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for i in 0..4 * n {
            let d = back.re[i] as f64 / n as f64 - q.re[i] as f64;
            num += d * d;
            den += (q.re[i] as f64) * (q.re[i] as f64);
            assert_eq!(back.im[i], 0.0, "C2R output must be real");
        }
        let rmse = (num / den).sqrt();
        assert!(rmse < 2.0 * RMSE_TOL, "n={n}: round-trip rmse {rmse:.3e}");
    }
}

#[test]
fn r2c_agrees_with_c2c_on_promoted_input() {
    // both paths compute the same transform of a real signal; they
    // differ only in fp16 rounding order (n-point pipeline vs n/2
    // pipeline + split), so mutual error is bounded by 2x the oracle
    // tolerance each side satisfies
    let rt = runtime();
    for n in [256usize, 4096, 65536] {
        let bins = n / 2 + 1;
        let sig = real_rows(n, 4, 0x5F00 + n as u64);
        let rplan = Plan::rfft1d(&rt.registry, n, 4).unwrap();
        let cplan = Plan::fft1d(&rt.registry, n, 4).unwrap();
        let packed = rplan
            .execute(rt, PlanarBatch::from_real(&sig, vec![4, n]))
            .unwrap();
        let full = cplan
            .execute(rt, PlanarBatch::from_real(&sig, vec![4, n]))
            .unwrap();
        let pc = widen(&packed.to_complex());
        let fc = widen(&full.to_complex());
        for b in 0..4 {
            let half: Vec<C64> = fc[b * n..b * n + bins].to_vec();
            let rmse = relative_rmse(&half, &pc[b * bins..(b + 1) * bins]);
            assert!(rmse < 2.0 * RMSE_TOL, "n={n} row={b}: R2C vs C2C rmse {rmse:.3e}");
        }
    }
}

#[test]
fn large_four_step_r2c_matches_the_oracle() {
    // beyond the artifact catalog: the four-step real engine at 2^18
    let rt = runtime();
    let n = 1 << 18;
    let bins = n / 2 + 1;
    let plan = RealFourStepPlan::new(rt, n, false).unwrap();
    let input = PlanarBatch::from_real(&real_rows(n, 2, 0x6A), vec![2, n]);
    let out = plan.execute_batch(rt, input.clone()).unwrap();
    assert_eq!(out.shape, vec![2, bins]);
    let q = widen(&input.quantize_f16().to_complex());
    let got = widen(&out.to_complex());
    for b in 0..2 {
        let want = radix2::fft_vec(&q[b * n..(b + 1) * n], false);
        let rmse = relative_rmse(&want[..bins], &got[b * bins..(b + 1) * bins]);
        assert!(rmse < RMSE_TOL, "row {b}: four-step R2C rmse {rmse:.3e}");
    }
}

#[test]
fn large_four_step_real_round_trips() {
    // C2R at large n: pre-scale the spectrum by 1/n on the host (the
    // unnormalized inverse would overflow fp16 at this size), then the
    // inverse recovers the signal at unit scale
    let rt = runtime();
    let n = 1 << 18;
    let fwd = RealFourStepPlan::new(rt, n, false).unwrap();
    let inv = RealFourStepPlan::new(rt, n, true).unwrap();
    let input = PlanarBatch::from_real(&real_rows(n, 1, 0x7B), vec![1, n]);
    let mut spec = fwd.execute_batch(rt, input.clone()).unwrap();
    for v in spec.re.iter_mut().chain(spec.im.iter_mut()) {
        *v /= n as f32;
    }
    let back = inv.execute_batch(rt, spec).unwrap();
    let q = input.quantize_f16();
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for i in 0..n {
        let d = back.re[i] as f64 - q.re[i] as f64;
        num += d * d;
        den += (q.re[i] as f64) * (q.re[i] as f64);
    }
    let rmse = (num / den).sqrt();
    assert!(rmse < 2.0 * RMSE_TOL, "four-step real round-trip rmse {rmse:.3e}");
}

/// Forward `large::Plan2d` composition vs the f64 2D oracle on the
/// packed bins — the large-route analogue of `check_r2c2d`, sharing
/// its oracle and packing conventions.
fn check_plan2d(nx: usize, ny: usize, batch: usize, seed: u64) {
    let rt = runtime();
    let plan = Plan2d::new(rt, nx, ny, false).unwrap();
    let input = PlanarBatch::from_real(&real_rows(nx * ny, batch, seed), vec![batch, nx, ny]);
    let out = plan.execute_batch(rt, input.clone()).unwrap();
    let bins = ny / 2 + 1;
    assert_eq!(out.shape, vec![batch, nx, bins]);
    let q = widen(&input.quantize_f16().to_complex());
    let got = widen(&out.to_complex());
    for b in 0..batch {
        let want = tcfft::fft::oracle2d(&q[b * nx * ny..(b + 1) * nx * ny], nx, ny, false);
        let want_packed: Vec<C64> = (0..nx)
            .flat_map(|r| want[r * ny..r * ny + bins].to_vec())
            .collect();
        let rmse = relative_rmse(&want_packed, &got[b * nx * bins..(b + 1) * nx * bins]);
        assert!(
            rmse < RMSE_TOL,
            "Plan2d {nx}x{ny} field={b}: packed rel-RMSE {rmse:.3e} over {RMSE_TOL:.1e}"
        );
    }
}

#[test]
fn large_2d_composition_matches_the_oracle_at_512x2048() {
    // rectangular, large-route-sized (beyond the catalog): the 2D
    // composition must not bake in squareness in either orientation
    check_plan2d(512, 2048, 1, 0xA210);
}

#[test]
fn large_2d_composition_matches_the_oracle_at_2048x512() {
    check_plan2d(2048, 512, 1, 0xA211);
}

#[test]
fn large_2d_serial_and_parallel_are_bitwise_identical() {
    // the composed path inherits the inner engines' serial==parallel
    // bitwise contract: the panel gather/scatter sweeps are serial by
    // construction, and both the row and column engines guarantee
    // thread-count-independent bits — so the whole composition must too
    let rt = runtime();
    let (nx, ny) = (512usize, 512usize);
    let serial = Plan2d::with_config(
        rt,
        nx,
        ny,
        false,
        FourStepConfig { threads: 1, ..FourStepConfig::default() },
    )
    .unwrap();
    let par = Plan2d::with_config(
        rt,
        nx,
        ny,
        false,
        FourStepConfig { threads: 4, ..FourStepConfig::default() },
    )
    .unwrap();
    let input = PlanarBatch::from_real(&real_rows(nx * ny, 2, 0xB52D), vec![2, nx, ny]);
    let a = serial.execute_batch(rt, input.clone()).unwrap();
    let b = par.execute_batch(rt, input).unwrap();
    assert_eq!(a.shape, b.shape);
    for i in 0..a.len() {
        assert_eq!(a.re[i].to_bits(), b.re[i].to_bits(), "re[{i}]");
        assert_eq!(a.im[i].to_bits(), b.im[i].to_bits(), "im[{i}]");
    }
}

#[test]
fn fused_four_step_readout_is_bitwise_identical_to_the_post_pass_path() {
    // the half-spectrum split is now fused into the inner engine's
    // final read-out transpose; the PR-4 formulation — run the
    // half-size complex engine to completion, then split as a separate
    // post-pass — must produce the exact same bits
    let rt = runtime();
    let n = 1 << 12;
    let m = n / 2;
    let plan = RealFourStepPlan::new(rt, n, false).unwrap();
    let input = PlanarBatch::from_real(&real_rows(n, 2, 0x9D), vec![2, n]);
    let fused = plan.execute_batch(rt, input.clone()).unwrap();

    // PR-4 post-pass path, reconstructed from the public parts
    let rs = RealHalfSpectrum::new(n);
    let mut q = input;
    q.quantize_f16_mut();
    let mut z = PlanarBatch::new(vec![2, m]);
    rs.pack_rows(&q.re, &mut z.re, &mut z.im, 2);
    let inner = FourStepPlan::new(rt, m, false).unwrap();
    let z = inner.execute_batch(rt, z).unwrap();
    let mut want = PlanarBatch::new(vec![2, m + 1]);
    rs.split_rows(&z.re, &z.im, &mut want.re, &mut want.im, 2);

    assert_eq!(fused.shape, want.shape);
    for i in 0..fused.len() {
        assert_eq!(fused.re[i].to_bits(), want.re[i].to_bits(), "re[{i}]");
        assert_eq!(fused.im[i].to_bits(), want.im[i].to_bits(), "im[{i}]");
    }
}

#[test]
fn fused_four_step_inverse_readout_is_bitwise_identical_too() {
    // same property for C2R: the unpack gather from the pre-read-out
    // layout equals transpose-then-unpack, bit for bit
    let rt = runtime();
    let n = 1 << 12;
    let m = n / 2;
    let plan = RealFourStepPlan::new(rt, n, true).unwrap();
    // a plausible packed spectrum, pre-scaled into fp16 range
    let mut input = PlanarBatch::new(vec![1, m + 1]);
    for k in 0..=m {
        input.re[k] = ((k * 13 + 5) % 37) as f32 / 37.0 - 0.5;
        input.im[k] = ((k * 7 + 2) % 29) as f32 / 29.0 - 0.5;
    }
    input.im[0] = 0.0;
    input.im[m] = 0.0;
    let fused = plan.execute_batch(rt, input.clone()).unwrap();

    let rs = RealHalfSpectrum::new(n);
    let mut q = input;
    q.quantize_f16_mut();
    let mut z = PlanarBatch::new(vec![1, m]);
    rs.merge_rows(&q.re, &q.im, &mut z.re, &mut z.im, 1);
    let inner = FourStepPlan::with_algo(rt, m, "tc", true).unwrap();
    let z = inner.execute_batch(rt, z).unwrap();
    let mut want = PlanarBatch::new(vec![1, n]);
    rs.unpack_rows(&z.re, &z.im, &mut want.re, 1);

    assert_eq!(fused.shape, want.shape);
    for i in 0..fused.len() {
        assert_eq!(fused.re[i].to_bits(), want.re[i].to_bits(), "re[{i}]");
        assert_eq!(fused.im[i], 0.0, "C2R output must be real");
    }
}

#[test]
fn rfft_convolution_matches_the_time_domain_oracle() {
    // the acceptance workload: rfft -> pointwise multiply -> irfft
    // equals direct circular convolution of the quantized operands
    use tcfft::hp::F16;
    use tcfft::workload::spectral::{circular_convolve_ref, SpectralConv};
    let rt = runtime();
    let n = 1024;
    let taps: Vec<f32> = (0..16).map(|i| 0.5 / (1.0 + i as f32)).collect();
    let conv = SpectralConv::new(rt, n, &taps).unwrap();
    let x = real_rows(n, 1, 0x8C);
    let y = conv.convolve(rt, &x).unwrap();
    let xq: Vec<f64> = x.iter().map(|&v| F16::from_f32(v).to_f32() as f64).collect();
    let mut hq = vec![0.0f64; n];
    for (i, &t) in taps.iter().enumerate() {
        hq[i] = F16::from_f32(t).to_f32() as f64;
    }
    let want = circular_convolve_ref(&xq, &hq);
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for i in 0..n {
        let d = y[i] as f64 - want[i];
        num += d * d;
        den += want[i] * want[i];
    }
    let rmse = (num / den).sqrt();
    assert!(rmse < 1e-2, "spectral conv vs oracle rmse {rmse:.3e}");
}

#[test]
fn filter_bank_matches_the_time_domain_oracle_per_filter() {
    // the batched filter-bank API: every (signal, filter) pair of the
    // [b, k, n] output must match its own O(n^2) circular convolution
    use tcfft::hp::F16;
    use tcfft::workload::spectral::{circular_convolve_ref, SpectralConv};
    let rt = runtime();
    let n = 512;
    let filters: Vec<Vec<f32>> = vec![
        vec![1.0],
        vec![0.25, 0.5, 0.25],
        (0..24).map(|i| 0.3 * (1.0 - i as f32 / 24.0)).collect(),
    ];
    let bank = SpectralConv::new_bank(rt, n, &filters).unwrap();
    let x = real_rows(n, 2, 0xB7);
    let out = bank
        .convolve_batch(rt, PlanarBatch::from_real(&x, vec![2, n]))
        .unwrap();
    assert_eq!(out.shape, vec![2, 3, n]);
    for row in 0..2 {
        let xq: Vec<f64> = x[row * n..(row + 1) * n]
            .iter()
            .map(|&v| F16::from_f32(v).to_f32() as f64)
            .collect();
        for (f, taps) in filters.iter().enumerate() {
            let mut hq = vec![0.0f64; n];
            for (i, &t) in taps.iter().enumerate() {
                hq[i] = F16::from_f32(t).to_f32() as f64;
            }
            let want = circular_convolve_ref(&xq, &hq);
            let got = &out.re[(row * 3 + f) * n..(row * 3 + f + 1) * n];
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for i in 0..n {
                let d = got[i] as f64 - want[i];
                num += d * d;
                den += want[i] * want[i];
            }
            let rmse = (num / den).sqrt();
            assert!(rmse < 1e-2, "row {row} filter {f} vs oracle rmse {rmse:.3e}");
        }
    }
}
