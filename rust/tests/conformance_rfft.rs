//! Conformance of the real-input (R2C/C2R) path against the host f64
//! oracles, for BOTH engines: the interpreter's `rfft1d` plans (every
//! power-of-two size 2^4..=2^16 at request batches {1, 4, 32}) and the
//! `large::RealFourStepPlan` four-step composition. Checked by relative
//! RMSE over the Hermitian-packed bins, plus the packed-layout property
//! tests (Hermitian symmetry, real endpoints), the irfft(rfft(x))
//! round trip, and R2C-vs-C2C agreement on promoted real inputs.
//!
//! Oracle strategy matches `conformance_interpreter.rs`: sizes <= 512
//! go straight to the O(N^2) DFT definition (`fft::refdft`); larger
//! sizes use the f64 radix-2 FFT. The fp16 pipeline simulation of this
//! path measures forward rel-RMSE 4e-4..6e-4 over 2^4..2^16, so the
//! 5e-3 bound keeps ~10x margin while failing on structural errors.

use std::sync::{Arc, OnceLock};

use tcfft::error::relative_rmse;
use tcfft::fft::{radix2, refdft};
use tcfft::hp::{C32, C64};
use tcfft::large::RealFourStepPlan;
use tcfft::plan::Plan;
use tcfft::runtime::{PlanarBatch, Registry, Runtime};
use tcfft::workload::random_signal;

const RMSE_TOL: f64 = 5e-3;

fn runtime() -> &'static Runtime {
    static RT: OnceLock<Runtime> = OnceLock::new();
    RT.get_or_init(|| {
        Runtime::with_backend(
            Arc::new(Registry::synthesize()),
            Box::new(tcfft::runtime::CpuInterpreter::new()),
        )
    })
}

fn widen(x: &[C32]) -> Vec<C64> {
    x.iter().map(|c| C64::new(c.re as f64, c.im as f64)).collect()
}

/// Uniform [-1, 1) real rows (the re parts of the paper TestCase).
fn real_rows(n: usize, batch: usize, seed: u64) -> Vec<f32> {
    (0..batch)
        .flat_map(|b| random_signal(n, seed + b as u64))
        .map(|c| c.re)
        .collect()
}

/// f64 oracle spectrum of one fp16-quantized real row.
fn oracle_row(quantized: &[C64], inverse: bool) -> Vec<C64> {
    if quantized.len() <= 512 {
        refdft::dft(quantized, inverse)
    } else {
        radix2::fft_vec(quantized, inverse)
    }
}

fn check_r2c(n: usize, batch: usize, seed: u64) {
    let rt = runtime();
    let plan = Plan::rfft1d(&rt.registry, n, batch).unwrap();
    let input = PlanarBatch::from_real(&real_rows(n, batch, seed), vec![batch, n]);
    let out = plan.execute(rt, input.clone()).unwrap();
    let bins = n / 2 + 1;
    assert_eq!(out.shape, vec![batch, bins]);

    let q = widen(&input.quantize_f16().to_complex());
    let got = widen(&out.to_complex());
    for b in 0..batch {
        let want = oracle_row(&q[b * n..(b + 1) * n], false);
        let rmse = relative_rmse(&want[..bins], &got[b * bins..(b + 1) * bins]);
        assert!(
            rmse < RMSE_TOL,
            "n={n} batch={batch} row={b}: packed rel-RMSE {rmse:.3e} over {RMSE_TOL:.1e}"
        );
    }
}

#[test]
fn r2c_all_sizes_batch_1() {
    for t in 4..=16usize {
        check_r2c(1 << t, 1, 0x1A00 + t as u64);
    }
}

#[test]
fn r2c_all_sizes_batch_4() {
    for t in 4..=16usize {
        check_r2c(1 << t, 4, 0x2B00 + t as u64);
    }
}

#[test]
fn r2c_all_sizes_batch_32() {
    for t in 4..=16usize {
        check_r2c(1 << t, 32, 0x3C00 + t as u64);
    }
}

#[test]
fn packed_output_is_hermitian() {
    // the packed bins must agree with the conjugate-symmetric full
    // spectrum: X[n-k] = conj(X[k]) — checked against the C2C engine
    // on the promoted input — and the endpoint bins are exactly real
    let rt = runtime();
    for n in [64usize, 1024, 8192] {
        let bins = n / 2 + 1;
        let sig = real_rows(n, 1, 0xD0 + n as u64);
        let rplan = Plan::rfft1d(&rt.registry, n, 1).unwrap();
        let packed = rplan
            .execute(rt, PlanarBatch::from_real(&sig, vec![1, n]))
            .unwrap();
        assert_eq!(packed.im[0], 0.0, "n={n}: bin 0 must be exactly real");
        assert_eq!(packed.im[bins - 1], 0.0, "n={n}: bin n/2 must be exactly real");

        let cplan = Plan::fft1d(&rt.registry, n, 1).unwrap();
        let full = cplan
            .execute(rt, PlanarBatch::from_real(&sig, vec![1, n]))
            .unwrap();
        // the full spectrum of a real signal is Hermitian; its first
        // half must match the packed output, its second half the
        // conjugate mirror — both within the two engines' fp16 noise
        let fullc = widen(&full.to_complex());
        let packc = widen(&packed.to_complex());
        let mirror: Vec<C64> = (0..bins).map(|k| fullc[(n - k) % n].conj()).collect();
        let scale = fullc.iter().map(|c| c.abs()).fold(0.0, f64::max);
        for k in 0..bins {
            assert!(
                (packc[k] - fullc[k]).abs() < 0.02 * scale,
                "n={n} bin {k}: packed vs full"
            );
            assert!(
                (packc[k] - mirror[k]).abs() < 0.02 * scale,
                "n={n} bin {k}: packed vs conj mirror"
            );
        }
    }
}

#[test]
fn irfft_of_rfft_round_trips() {
    // forward then unnormalized inverse, scaled back by 1/n, recovers
    // the quantized signal. Sizes stay <= 2^14 for the same fp16
    // dynamic-range reason as the complex round-trip test.
    let rt = runtime();
    for t in [4usize, 8, 12, 14] {
        let n = 1 << t;
        let fwd = Plan::rfft1d(&rt.registry, n, 4).unwrap();
        let inv = Plan::irfft1d(&rt.registry, n, 4).unwrap();
        let input = PlanarBatch::from_real(&real_rows(n, 4, 0x4E00 + t as u64), vec![4, n]);
        let spec = fwd.execute(rt, input.clone()).unwrap();
        let back = inv.execute(rt, spec).unwrap();
        assert_eq!(back.shape, vec![4, n]);
        let q = input.quantize_f16();
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for i in 0..4 * n {
            let d = back.re[i] as f64 / n as f64 - q.re[i] as f64;
            num += d * d;
            den += (q.re[i] as f64) * (q.re[i] as f64);
            assert_eq!(back.im[i], 0.0, "C2R output must be real");
        }
        let rmse = (num / den).sqrt();
        assert!(rmse < 2.0 * RMSE_TOL, "n={n}: round-trip rmse {rmse:.3e}");
    }
}

#[test]
fn r2c_agrees_with_c2c_on_promoted_input() {
    // both paths compute the same transform of a real signal; they
    // differ only in fp16 rounding order (n-point pipeline vs n/2
    // pipeline + split), so mutual error is bounded by 2x the oracle
    // tolerance each side satisfies
    let rt = runtime();
    for n in [256usize, 4096, 65536] {
        let bins = n / 2 + 1;
        let sig = real_rows(n, 4, 0x5F00 + n as u64);
        let rplan = Plan::rfft1d(&rt.registry, n, 4).unwrap();
        let cplan = Plan::fft1d(&rt.registry, n, 4).unwrap();
        let packed = rplan
            .execute(rt, PlanarBatch::from_real(&sig, vec![4, n]))
            .unwrap();
        let full = cplan
            .execute(rt, PlanarBatch::from_real(&sig, vec![4, n]))
            .unwrap();
        let pc = widen(&packed.to_complex());
        let fc = widen(&full.to_complex());
        for b in 0..4 {
            let half: Vec<C64> = fc[b * n..b * n + bins].to_vec();
            let rmse = relative_rmse(&half, &pc[b * bins..(b + 1) * bins]);
            assert!(rmse < 2.0 * RMSE_TOL, "n={n} row={b}: R2C vs C2C rmse {rmse:.3e}");
        }
    }
}

#[test]
fn large_four_step_r2c_matches_the_oracle() {
    // beyond the artifact catalog: the four-step real engine at 2^18
    let rt = runtime();
    let n = 1 << 18;
    let bins = n / 2 + 1;
    let plan = RealFourStepPlan::new(rt, n, false).unwrap();
    let input = PlanarBatch::from_real(&real_rows(n, 2, 0x6A), vec![2, n]);
    let out = plan.execute_batch(rt, input.clone()).unwrap();
    assert_eq!(out.shape, vec![2, bins]);
    let q = widen(&input.quantize_f16().to_complex());
    let got = widen(&out.to_complex());
    for b in 0..2 {
        let want = radix2::fft_vec(&q[b * n..(b + 1) * n], false);
        let rmse = relative_rmse(&want[..bins], &got[b * bins..(b + 1) * bins]);
        assert!(rmse < RMSE_TOL, "row {b}: four-step R2C rmse {rmse:.3e}");
    }
}

#[test]
fn large_four_step_real_round_trips() {
    // C2R at large n: pre-scale the spectrum by 1/n on the host (the
    // unnormalized inverse would overflow fp16 at this size), then the
    // inverse recovers the signal at unit scale
    let rt = runtime();
    let n = 1 << 18;
    let fwd = RealFourStepPlan::new(rt, n, false).unwrap();
    let inv = RealFourStepPlan::new(rt, n, true).unwrap();
    let input = PlanarBatch::from_real(&real_rows(n, 1, 0x7B), vec![1, n]);
    let mut spec = fwd.execute_batch(rt, input.clone()).unwrap();
    for v in spec.re.iter_mut().chain(spec.im.iter_mut()) {
        *v /= n as f32;
    }
    let back = inv.execute_batch(rt, spec).unwrap();
    let q = input.quantize_f16();
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for i in 0..n {
        let d = back.re[i] as f64 - q.re[i] as f64;
        num += d * d;
        den += (q.re[i] as f64) * (q.re[i] as f64);
    }
    let rmse = (num / den).sqrt();
    assert!(rmse < 2.0 * RMSE_TOL, "four-step real round-trip rmse {rmse:.3e}");
}

#[test]
fn rfft_convolution_matches_the_time_domain_oracle() {
    // the acceptance workload: rfft -> pointwise multiply -> irfft
    // equals direct circular convolution of the quantized operands
    use tcfft::hp::F16;
    use tcfft::workload::spectral::{circular_convolve_ref, SpectralConv};
    let rt = runtime();
    let n = 1024;
    let taps: Vec<f32> = (0..16).map(|i| 0.5 / (1.0 + i as f32)).collect();
    let conv = SpectralConv::new(rt, n, &taps).unwrap();
    let x = real_rows(n, 1, 0x8C);
    let y = conv.convolve(rt, &x).unwrap();
    let xq: Vec<f64> = x.iter().map(|&v| F16::from_f32(v).to_f32() as f64).collect();
    let mut hq = vec![0.0f64; n];
    for (i, &t) in taps.iter().enumerate() {
        hq[i] = F16::from_f32(t).to_f32() as f64;
    }
    let want = circular_convolve_ref(&xq, &hq);
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for i in 0..n {
        let d = y[i] as f64 - want[i];
        num += d * d;
        den += want[i] * want[i];
    }
    let rmse = (num / den).sqrt();
    assert!(rmse < 1e-2, "spectral conv vs oracle rmse {rmse:.3e}");
}
