//! Property-based tests (hand-rolled generators — no proptest offline):
//! randomized invariants over the planner, digit reversal, host FFTs,
//! fp16 codec, JSON round trips, the batcher and the `tc_ec`
//! compensated tier (linearity, round trips, Hermitian symmetry at
//! error-corrected accuracy).  Each property runs over many random
//! cases from a seeded generator, printing the failing seed on
//! assertion (deterministic replay).

use tcfft::error::relative_rmse;
use tcfft::fft::{digitrev, mixed, radix2, refdft};
use tcfft::hp::complex::widen;
use tcfft::hp::{C32, C64, F16};
use tcfft::plan::schedule::kernel_schedule;
use tcfft::runtime::{Backend, CpuInterpreter, PlanarBatch, VariantMeta};
use tcfft::util::json::Json;
use tcfft::util::rng::SplitMix64;
use tcfft::workload::random_signal;

const CASES: usize = 200;

#[test]
fn prop_digit_reverse_is_permutation_and_matches_schedule() {
    let mut rng = SplitMix64::new(11);
    for case in 0..CASES {
        let t = 1 + rng.below(16); // n in 2..=65536
        let n = 1usize << t;
        let radices = digitrev::radix_schedule(n);
        assert_eq!(radices.iter().product::<usize>(), n, "case {case}");
        let p = digitrev::digit_reverse(n);
        let mut seen = vec![false; n];
        for &i in &p {
            assert!(!seen[i], "case {case}: duplicate");
            seen[i] = true;
        }
    }
}

#[test]
fn prop_schedule_radix_product_and_vmem() {
    let mut rng = SplitMix64::new(22);
    for case in 0..CASES {
        let t = 1 + rng.below(22);
        let n = 1usize << t;
        let lane = 1usize << (rng.below(3) * 4); // 1, 16, 256
        let stages = kernel_schedule(n, lane);
        let prod: usize = stages.iter().map(|s| s.radix).product();
        assert_eq!(prod, n, "case {case} n={n} lane={lane}");
        for s in &stages {
            assert!(
                s.kernel != "merge256"
                    || s.vmem_bytes() <= tcfft::plan::schedule::VMEM_FUSE_BUDGET,
                "case {case}: fused stage over budget: {s:?}"
            );
        }
    }
}

#[test]
fn prop_mixed_fft_matches_dft_small_sizes() {
    let mut rng = SplitMix64::new(33);
    for case in 0..40 {
        let t = 1 + rng.below(9); // up to 512: DFT oracle is O(N^2)
        let n = 1usize << t;
        let x: Vec<C64> = (0..n)
            .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
            .collect();
        let inverse = rng.below(2) == 1;
        let want = refdft::dft(&x, inverse);
        let got = mixed::fft_mixed(&x, inverse);
        let scale = want.iter().map(|c| c.abs()).fold(1e-30, f64::max);
        for (w, g) in want.iter().zip(&got) {
            assert!(
                (*w - *g).abs() / scale < 1e-9,
                "case {case} n={n} inverse={inverse}"
            );
        }
    }
}

#[test]
fn prop_parseval_and_shift_theorems() {
    let mut rng = SplitMix64::new(44);
    for case in 0..60 {
        let t = 3 + rng.below(8);
        let n = 1usize << t;
        let x: Vec<C64> = (0..n)
            .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
            .collect();
        let y = radix2::fft_vec(&x, false);
        // Parseval
        let ex: f64 = x.iter().map(|c| c.norm_sqr()).sum();
        let ey: f64 = y.iter().map(|c| c.norm_sqr()).sum();
        assert!(
            (ey - n as f64 * ex).abs() / (n as f64 * ex) < 1e-10,
            "case {case}: parseval"
        );
        // circular shift theorem: FFT(shift_s x)[k] = W^{sk} FFT(x)[k]
        let s = rng.below(n);
        let shifted: Vec<C64> = (0..n).map(|i| x[(i + s) % n]).collect();
        let ys = radix2::fft_vec(&shifted, false);
        for k in 0..n {
            let w = C64::cis(2.0 * std::f64::consts::PI * (s * k % n) as f64 / n as f64);
            let want = y[k] * w;
            assert!(
                (want - ys[k]).abs() < 1e-7 * (1.0 + want.abs()),
                "case {case}: shift theorem k={k}"
            );
        }
    }
}

#[test]
fn prop_f16_round_trip_and_monotone() {
    let mut rng = SplitMix64::new(55);
    for case in 0..CASES {
        // encode(decode(h)) == h for random bit patterns
        let bits = (rng.next_u64() & 0xFFFF) as u16;
        let h = F16::from_bits(bits);
        if h.is_nan() {
            assert!(F16::from_f32(h.to_f32()).is_nan());
        } else {
            assert_eq!(F16::from_f32(h.to_f32()).to_bits(), bits, "case {case}");
        }
        // quantization error bound on the normal range
        let x = rng.uniform(-60000.0, 60000.0) as f32;
        let q = F16::from_f32(x).to_f32();
        if x.abs() > 1e-4 {
            assert!(
                ((q - x) / x).abs() <= 2f32.powi(-10),
                "case {case}: x={x} q={q}"
            );
        }
    }
}

#[test]
fn prop_json_round_trip_arbitrary_trees() {
    fn gen(rng: &mut SplitMix64, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 1),
            2 => Json::Num((rng.uniform(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => Json::Str(format!("s{}-\"\\\n{}", rng.below(100), rng.below(10))),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = SplitMix64::new(66);
    for case in 0..CASES {
        let tree = gen(&mut rng, 3);
        let text = tree.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(tree, back, "case {case}");
    }
}

#[test]
fn prop_f16_exhaustive_finite_round_trip() {
    // EVERY finite bit pattern (normals AND subnormals) must survive
    // decode -> encode exactly; NaNs must stay NaN.
    for bits in 0u16..=0xFFFF {
        let h = F16::from_bits(bits);
        if h.is_nan() {
            assert!(F16::from_f32(h.to_f32()).is_nan(), "bits {bits:#06x}");
        } else {
            assert_eq!(F16::from_f32(h.to_f32()).to_bits(), bits, "bits {bits:#06x}");
        }
    }
}

#[test]
fn prop_f16_subnormal_round_trip_through_f64() {
    // subnormal range: 2^-24 .. 2^-14; exact f64 representations of
    // every subnormal must encode back to the same pattern
    for bits in 1u16..0x0400 {
        let h = F16::from_bits(bits);
        assert!(h.is_finite());
        let wide = h.to_f64();
        assert!(wide > 0.0 && wide < 6.104e-5, "bits {bits:#06x} -> {wide}");
        assert_eq!(F16::from_f64(wide).to_bits(), bits, "bits {bits:#06x}");
    }
}

#[test]
fn prop_f16_round_to_nearest_even_at_mantissa_boundary() {
    // For every normal fp16 value h with even mantissa, h + half-ulp is
    // an exact tie and must round DOWN to h (ties-to-even); with odd
    // mantissa it must round UP to the next (even) pattern.  Scan a
    // spread of exponents across the normal range.
    let mut rng = SplitMix64::new(88);
    for case in 0..CASES {
        let exp = 1 + rng.below(29) as u16; // biased exponent, normal range
        let mant = (rng.next_u64() & 0x3FF) as u16;
        let bits = (exp << 10) | mant;
        let h = F16::from_bits(bits);
        let next = F16::from_bits(bits + 1);
        if next.is_infinite() {
            continue; // h is MAX at this exponent path end
        }
        let tie = (h.to_f64() + next.to_f64()) * 0.5; // exact in f64
        let rounded = F16::from_f64(tie).to_bits();
        let want = if mant & 1 == 0 { bits } else { bits + 1 };
        assert_eq!(rounded, want, "case {case}: bits {bits:#06x} tie {tie}");
        // just above / below the tie must round toward the nearer value
        let ulp = next.to_f64() - h.to_f64();
        assert_eq!(F16::from_f64(tie - 0.26 * ulp).to_bits(), bits, "case {case}");
        assert_eq!(F16::from_f64(tie + 0.26 * ulp).to_bits(), bits + 1, "case {case}");
    }
}

#[test]
fn prop_twiddle_conjugate_symmetry() {
    // inverse tables are exact conjugates of forward tables
    let mut rng = SplitMix64::new(99);
    for _ in 0..40 {
        let r = 1usize << (1 + rng.below(4)); // 2..16
        let n2 = 1usize << rng.below(7); // 1..64
        let fwd = tcfft::fft::twiddle::twiddle_matrix(r, n2, false);
        let inv = tcfft::fft::twiddle::twiddle_matrix(r, n2, true);
        for m in 0..r {
            for k in 0..n2 {
                assert!((fwd[m][k].conj() - inv[m][k]).abs() < 1e-12, "({m},{k})");
            }
        }
    }
}

#[test]
fn prop_twiddle_periodicity_and_group_structure() {
    // W_N^{m k} depends only on (m*k) mod N: the table equals the
    // direct cis form, first row/col are 1, and the N/2 offset negates
    let mut rng = SplitMix64::new(111);
    for _ in 0..40 {
        let r = 1usize << (1 + rng.below(4));
        let n2 = 1usize << (1 + rng.below(6));
        let n = r * n2;
        let t = tcfft::fft::twiddle::twiddle_matrix(r, n2, false);
        for _ in 0..20 {
            let m = rng.below(r);
            let k = rng.below(n2);
            let direct =
                C64::cis(-2.0 * std::f64::consts::PI * ((m * k) % n) as f64 / n as f64);
            assert!((t[m][k] - direct).abs() < 1e-12, "({m},{k}) of {r}x{n2}");
        }
        for k in 0..n2 {
            assert!((t[0][k] - C64::one()).abs() < 1e-12);
        }
        // unit magnitude everywhere (pure rotations)
        for row in &t {
            for w in row {
                assert!((w.abs() - 1.0).abs() < 1e-12);
            }
        }
    }
    // explicit periodicity/negation on a full-resolution table: r = N
    let n = 32;
    let full = tcfft::fft::twiddle::dft_matrix(n, false);
    for m in 0..n {
        for j in 0..n {
            let wrapped = full[m][j];
            let direct = full[1][(m * j) % n];
            assert!((wrapped - direct).abs() < 1e-12, "periodicity ({m},{j})");
        }
    }
    for j in 0..n {
        let neg = full[1][(j + n / 2) % n];
        assert!((full[1][j] + neg).abs() < 1e-12, "half-period negation {j}");
    }
}

/// Ad-hoc 1D variant for driving the interpreter without a manifest.
fn ec_meta(algo: &str, n: usize, batch: usize, inverse: bool) -> VariantMeta {
    let d = if inverse { "inv" } else { "fwd" };
    VariantMeta {
        key: format!("prop_fft1d_{algo}_n{n}_b{batch}_{d}"),
        file: std::path::PathBuf::new(),
        op: "fft1d".to_string(),
        algo: algo.to_string(),
        n,
        nx: 0,
        ny: 0,
        batch,
        inverse,
        input_shape: vec![batch, n],
        stages: Vec::new(),
        flops_per_seq: 0.0,
        hbm_bytes_per_seq: 0.0,
        radix2_equiv_flops: 0.0,
    }
}

fn ec_run(algo: &str, n: usize, inverse: bool, x: &[C32]) -> Vec<C64> {
    let be = CpuInterpreter::with_threads(1);
    let meta = ec_meta(algo, n, 1, inverse);
    let input = PlanarBatch::from_complex(x, vec![1, n]);
    let (y, _) = be.execute(&meta, input).unwrap();
    widen(&y.to_complex())
}

/// fp16-quantize a random signal so linear combinations with
/// power-of-two scalars stay exactly representable as hi+lo pairs.
fn fp16_signal(n: usize, seed: u64) -> Vec<C32> {
    random_signal(n, seed)
        .iter()
        .map(|c| C32::new(F16::from_f32(c.re).to_f32(), F16::from_f32(c.im).to_f32()))
        .collect()
}

#[test]
fn prop_tc_ec_is_linear_at_compensated_accuracy() {
    // With fp16 inputs and power-of-two scalars, a*x + b*y is the sum
    // of two fp16 values, whose rounding residual is itself
    // fp16-representable — so the ec marshal carries the combination
    // exactly and F(a x + b y) == a F(x) + b F(y) up to the tiny
    // compensated compute error.  The plain tc tier only achieves this
    // at fp16 noise (~1e-3); tc_ec must hold it near 1e-6.
    let mut rng = SplitMix64::new(222);
    for case in 0..6 {
        let n = 1usize << (8 + rng.below(3)); // 256..1024
        let x = fp16_signal(n, 0xE0 + case);
        let y = fp16_signal(n, 0xF0 + case);
        let (a, b) = (0.5f32, 0.25f32);
        let z: Vec<C32> = x
            .iter()
            .zip(&y)
            .map(|(u, v)| C32::new(a * u.re + b * v.re, a * u.im + b * v.im))
            .collect();
        let fz = ec_run("tc_ec", n, false, &z);
        let fx = ec_run("tc_ec", n, false, &x);
        let fy = ec_run("tc_ec", n, false, &y);
        let combo: Vec<C64> = fx
            .iter()
            .zip(&fy)
            .map(|(u, v)| u.scale(a as f64) + v.scale(b as f64))
            .collect();
        let err = relative_rmse(&combo, &fz);
        assert!(err < 1e-5, "case {case} n={n}: linearity rmse {err:.3e}");
    }
}

#[test]
fn prop_tc_ec_round_trip_recovers_input_at_compensated_accuracy() {
    // forward then unnormalized inverse scaled by 1/N.  The spectrum
    // re-enters the engine as carried hi+lo sums, so the ec re-marshal
    // is near-lossless and the trip error stays ~1e-6 — three orders
    // below the plain-fp16 round trip.
    let mut rng = SplitMix64::new(333);
    for case in 0..6 {
        let n = 1usize << (8 + rng.below(3));
        let x = fp16_signal(n, 0x1A0 + case);
        let be = CpuInterpreter::with_threads(1);
        let input = PlanarBatch::from_complex(&x, vec![1, n]);
        let (spec, _) = be.execute(&ec_meta("tc_ec", n, 1, false), input).unwrap();
        let (mut back, _) = be.execute(&ec_meta("tc_ec", n, 1, true), spec).unwrap();
        for v in back.re.iter_mut().chain(back.im.iter_mut()) {
            *v /= n as f32;
        }
        let want = widen(&x);
        let got = widen(&back.to_complex());
        let err = relative_rmse(&want, &got);
        assert!(err < 1e-5, "case {case} n={n}: round-trip rmse {err:.3e}");
    }
}

#[test]
fn prop_tc_ec_real_input_spectrum_is_hermitian() {
    // real input => X[k] == conj(X[n-k]) and the DC/Nyquist bins are
    // real.  The complex kernel doesn't know the input is real, so the
    // symmetry holds at compute accuracy, not bitwise — for tc_ec that
    // is the compensated level, far below fp16 noise.
    let mut rng = SplitMix64::new(444);
    for case in 0..6 {
        let n = 1usize << (8 + rng.below(3));
        let x: Vec<C32> = fp16_signal(n, 0x2B0 + case)
            .iter()
            .map(|c| C32::new(c.re, 0.0))
            .collect();
        let spec = ec_run("tc_ec", n, false, &x);
        let scale = spec.iter().map(|c| c.abs()).fold(1e-30, f64::max);
        for k in 1..n / 2 {
            let d = spec[k] - spec[n - k].conj();
            assert!(
                d.abs() < 1e-5 * scale,
                "case {case} n={n} k={k}: asymmetry {:.3e}",
                d.abs()
            );
        }
        assert!(spec[0].im.abs() < 1e-5 * scale, "case {case}: DC bin not real");
        assert!(
            spec[n / 2].im.abs() < 1e-5 * scale,
            "case {case}: Nyquist bin not real"
        );
    }
}

#[test]
fn prop_four_step_twiddles_match_direct() {
    let mut rng = SplitMix64::new(77);
    for _ in 0..40 {
        let n1 = 1usize << (1 + rng.below(5));
        let n2 = 1usize << (1 + rng.below(5));
        let tw = tcfft::fft::twiddle::four_step_twiddles(n1, n2, false);
        let n = n1 * n2;
        for _ in 0..10 {
            let j = rng.below(n1);
            let k = rng.below(n2);
            let want = C64::cis(-2.0 * std::f64::consts::PI * ((j * k) % n) as f64 / n as f64);
            assert!((tw[j][k] - want).abs() < 1e-12);
        }
    }
}
