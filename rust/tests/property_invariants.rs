//! Property-based tests (hand-rolled generators — no proptest offline):
//! randomized invariants over the planner, digit reversal, host FFTs,
//! fp16 codec, JSON round trips and the batcher.  Each property runs
//! over many random cases from a seeded generator, printing the failing
//! seed on assertion (deterministic replay).

use tcfft::fft::{digitrev, mixed, radix2, refdft};
use tcfft::hp::{C64, F16};
use tcfft::plan::schedule::kernel_schedule;
use tcfft::util::json::Json;
use tcfft::util::rng::SplitMix64;

const CASES: usize = 200;

#[test]
fn prop_digit_reverse_is_permutation_and_matches_schedule() {
    let mut rng = SplitMix64::new(11);
    for case in 0..CASES {
        let t = 1 + rng.below(16); // n in 2..=65536
        let n = 1usize << t;
        let radices = digitrev::radix_schedule(n);
        assert_eq!(radices.iter().product::<usize>(), n, "case {case}");
        let p = digitrev::digit_reverse(n);
        let mut seen = vec![false; n];
        for &i in &p {
            assert!(!seen[i], "case {case}: duplicate");
            seen[i] = true;
        }
    }
}

#[test]
fn prop_schedule_radix_product_and_vmem() {
    let mut rng = SplitMix64::new(22);
    for case in 0..CASES {
        let t = 1 + rng.below(22);
        let n = 1usize << t;
        let lane = 1usize << (rng.below(3) * 4); // 1, 16, 256
        let stages = kernel_schedule(n, lane);
        let prod: usize = stages.iter().map(|s| s.radix).product();
        assert_eq!(prod, n, "case {case} n={n} lane={lane}");
        for s in &stages {
            assert!(
                s.kernel != "merge256"
                    || s.vmem_bytes() <= tcfft::plan::schedule::VMEM_FUSE_BUDGET,
                "case {case}: fused stage over budget: {s:?}"
            );
        }
    }
}

#[test]
fn prop_mixed_fft_matches_dft_small_sizes() {
    let mut rng = SplitMix64::new(33);
    for case in 0..40 {
        let t = 1 + rng.below(9); // up to 512: DFT oracle is O(N^2)
        let n = 1usize << t;
        let x: Vec<C64> = (0..n)
            .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
            .collect();
        let inverse = rng.below(2) == 1;
        let want = refdft::dft(&x, inverse);
        let got = mixed::fft_mixed(&x, inverse);
        let scale = want.iter().map(|c| c.abs()).fold(1e-30, f64::max);
        for (w, g) in want.iter().zip(&got) {
            assert!(
                (*w - *g).abs() / scale < 1e-9,
                "case {case} n={n} inverse={inverse}"
            );
        }
    }
}

#[test]
fn prop_parseval_and_shift_theorems() {
    let mut rng = SplitMix64::new(44);
    for case in 0..60 {
        let t = 3 + rng.below(8);
        let n = 1usize << t;
        let x: Vec<C64> = (0..n)
            .map(|_| C64::new(rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)))
            .collect();
        let y = radix2::fft_vec(&x, false);
        // Parseval
        let ex: f64 = x.iter().map(|c| c.norm_sqr()).sum();
        let ey: f64 = y.iter().map(|c| c.norm_sqr()).sum();
        assert!(
            (ey - n as f64 * ex).abs() / (n as f64 * ex) < 1e-10,
            "case {case}: parseval"
        );
        // circular shift theorem: FFT(shift_s x)[k] = W^{sk} FFT(x)[k]
        let s = rng.below(n);
        let shifted: Vec<C64> = (0..n).map(|i| x[(i + s) % n]).collect();
        let ys = radix2::fft_vec(&shifted, false);
        for k in 0..n {
            let w = C64::cis(2.0 * std::f64::consts::PI * (s * k % n) as f64 / n as f64);
            let want = y[k] * w;
            assert!(
                (want - ys[k]).abs() < 1e-7 * (1.0 + want.abs()),
                "case {case}: shift theorem k={k}"
            );
        }
    }
}

#[test]
fn prop_f16_round_trip_and_monotone() {
    let mut rng = SplitMix64::new(55);
    for case in 0..CASES {
        // encode(decode(h)) == h for random bit patterns
        let bits = (rng.next_u64() & 0xFFFF) as u16;
        let h = F16::from_bits(bits);
        if h.is_nan() {
            assert!(F16::from_f32(h.to_f32()).is_nan());
        } else {
            assert_eq!(F16::from_f32(h.to_f32()).to_bits(), bits, "case {case}");
        }
        // quantization error bound on the normal range
        let x = rng.uniform(-60000.0, 60000.0) as f32;
        let q = F16::from_f32(x).to_f32();
        if x.abs() > 1e-4 {
            assert!(
                ((q - x) / x).abs() <= 2f32.powi(-10),
                "case {case}: x={x} q={q}"
            );
        }
    }
}

#[test]
fn prop_json_round_trip_arbitrary_trees() {
    fn gen(rng: &mut SplitMix64, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 1),
            2 => Json::Num((rng.uniform(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => Json::Str(format!("s{}-\"\\\n{}", rng.below(100), rng.below(10))),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = SplitMix64::new(66);
    for case in 0..CASES {
        let tree = gen(&mut rng, 3);
        let text = tree.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(tree, back, "case {case}");
    }
}

#[test]
fn prop_four_step_twiddles_match_direct() {
    let mut rng = SplitMix64::new(77);
    for _ in 0..40 {
        let n1 = 1usize << (1 + rng.below(5));
        let n2 = 1usize << (1 + rng.below(5));
        let tw = tcfft::fft::twiddle::four_step_twiddles(n1, n2, false);
        let n = n1 * n2;
        for _ in 0..10 {
            let j = rng.below(n1);
            let k = rng.below(n2);
            let want = C64::cis(-2.0 * std::f64::consts::PI * ((j * k) % n) as f64 / n as f64);
            assert!((tw[j][k] - want).abs() < 1e-12);
        }
    }
}
