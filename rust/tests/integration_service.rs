//! Integration tests of the coordinator: batching, routing, metrics,
//! backpressure, TCP server — over the interpreter backend (no
//! artifacts on disk required).

use std::sync::{Arc, OnceLock};
use std::time::Duration;

use tcfft::coordinator::{FftRequest, FftService, Op, Server, ServiceConfig};
use tcfft::error::{relative_error, relative_rmse};
use tcfft::fft::{mixed, radix2};
use tcfft::hp::{C32, C64};
use tcfft::plan::Direction;
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::workload::random_signal;

// One shared runtime across the binary; each test builds its own
// service on top (cheap) while staged pipelines build once.
fn shared_runtime() -> &'static Arc<Runtime> {
    static RT: OnceLock<Arc<Runtime>> = OnceLock::new();
    RT.get_or_init(|| {
        Arc::new(Runtime::load_default().expect("runtime must load without artifacts"))
    })
}

fn service() -> Arc<FftService> {
    Arc::new(FftService::start(
        Arc::clone(shared_runtime()),
        ServiceConfig {
            max_wait: Duration::from_millis(2),
            ..ServiceConfig::default()
        },
    ))
}

fn widen(x: &[C32]) -> Vec<C64> {
    x.iter().map(|c| C64::new(c.re as f64, c.im as f64)).collect()
}

#[test]
fn concurrent_requests_batch_and_return_correct_rows() {
    let svc = service();
    let n = 1024;
    // submit 8 distinct sequences concurrently; the batcher groups them
    // into artifact-batch-4 executions; each reply must match ITS row
    let signals: Vec<Vec<C32>> = (0..8).map(|i| random_signal(n, 100 + i as u64)).collect();
    let tickets: Vec<_> = signals
        .iter()
        .map(|sig| {
            svc.submit(FftRequest {
                op: Op::Fft1d { n },
                algo: "tc".into(),
                direction: Direction::Forward,
                input: PlanarBatch::from_complex(sig, vec![n]),
            })
            .unwrap()
        })
        .collect();
    for (sig, t) in signals.iter().zip(tickets) {
        let out = t.wait().unwrap();
        let q = PlanarBatch::from_complex(sig, vec![1, n]).quantize_f16();
        let want = mixed::fft_mixed_batch(&widen(&q.to_complex()), 1, n, false);
        let err = relative_error(&want, &widen(&out.to_complex()));
        assert!(err < 5e-3, "row mismatch: err {err}");
    }
    let m = svc.metrics();
    let snap = m.snapshot();
    assert_eq!(snap.get("completed").unwrap().as_i64(), Some(8));
    // 8 requests into batch-capacity-4 queues: at most 8 batches, and
    // batching must have grouped at least two requests somewhere
    let batches = snap.get("batches").unwrap().as_i64().unwrap();
    assert!(batches <= 8, "batches {batches}");
    svc.shutdown();
}

#[test]
fn mixed_op_routing() {
    let svc = service();
    // 1D and 2D requests in flight together route to different queues
    let sig1 = random_signal(1024, 1);
    let sig2 = random_signal(256 * 256, 2);
    let t1 = svc
        .submit(FftRequest {
            op: Op::Fft1d { n: 1024 },
            algo: "tc".into(),
            direction: Direction::Forward,
            input: PlanarBatch::from_complex(&sig1, vec![1024]),
        })
        .unwrap();
    let t2 = svc
        .submit(FftRequest {
            op: Op::Fft2d { nx: 256, ny: 256 },
            algo: "tc".into(),
            direction: Direction::Forward,
            input: PlanarBatch::from_complex(&sig2, vec![256, 256]),
        })
        .unwrap();
    assert_eq!(t1.wait().unwrap().shape, vec![1, 1024]);
    assert_eq!(t2.wait().unwrap().shape, vec![1, 256, 256]);
    svc.shutdown();
}

#[test]
fn large_fft1d_routes_through_four_step() {
    // the synthesized ladder stops at 2^17; 2^20 has no direct
    // artifact, so the service resolves a cached four-step plan — the
    // acceptance round trip: result matches the radix2 oracle to 5e-3
    let svc = service();
    let n = 1 << 20;
    let sig = random_signal(n, 3);
    let t = svc
        .submit(FftRequest {
            op: Op::Fft1d { n },
            algo: "tc".into(),
            direction: Direction::Forward,
            input: PlanarBatch::from_complex(&sig, vec![n]),
        })
        .unwrap();
    let out = t.wait().unwrap();
    assert_eq!(out.shape, vec![1, n]);
    let q = PlanarBatch::from_complex(&sig, vec![1, n]).quantize_f16();
    let want = radix2::fft_vec(&widen(&q.to_complex()), false);
    let rmse = relative_rmse(&want, &widen(&out.to_complex()));
    assert!(rmse <= 5e-3, "service four-step rel-RMSE {rmse:.3e} over 5e-3");
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.get("large_requests").unwrap().as_i64(), Some(1));
    assert_eq!(snap.get("completed").unwrap().as_i64(), Some(1));
    svc.shutdown();
}

#[test]
fn concurrent_large_requests_batch_and_return_their_rows() {
    // several distinct 2^18 sequences in flight: the unpadded large
    // queue groups them, and each reply must match ITS oracle row
    let svc = service();
    let n = 1 << 18;
    let signals: Vec<Vec<C32>> = (0..3).map(|i| random_signal(n, 500 + i as u64)).collect();
    let tickets: Vec<_> = signals
        .iter()
        .map(|sig| {
            svc.submit(FftRequest {
                op: Op::Fft1d { n },
                algo: "tc".into(),
                direction: Direction::Forward,
                input: PlanarBatch::from_complex(sig, vec![n]),
            })
            .unwrap()
        })
        .collect();
    for (sig, t) in signals.iter().zip(tickets) {
        let out = t.wait().unwrap();
        let q = PlanarBatch::from_complex(sig, vec![1, n]).quantize_f16();
        let want = radix2::fft_vec(&widen(&q.to_complex()), false);
        let rmse = relative_rmse(&want, &widen(&out.to_complex()));
        assert!(rmse <= 5e-3, "row mismatch: rel-RMSE {rmse:.3e}");
    }
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.get("large_requests").unwrap().as_i64(), Some(3));
    assert_eq!(snap.get("completed").unwrap().as_i64(), Some(3));
    svc.shutdown();
}

#[test]
fn rfft_requests_route_direct_and_return_packed_rows() {
    let svc = service();
    let n = 1024;
    let bins = n / 2 + 1;
    let sig: Vec<f32> = random_signal(n, 40).iter().map(|c| c.re).collect();
    let t = svc
        .submit(FftRequest {
            op: Op::Rfft1d { n },
            algo: "tc".into(),
            direction: Direction::Forward,
            input: PlanarBatch::from_real(&sig, vec![n]),
        })
        .unwrap();
    let out = t.wait().unwrap();
    assert_eq!(out.shape, vec![1, bins]);
    let q = PlanarBatch::from_real(&sig, vec![1, n]).quantize_f16();
    let want = mixed::fft_mixed_batch(&widen(&q.to_complex()), 1, n, false);
    let rmse = relative_rmse(&want[..bins], &widen(&out.to_complex()));
    assert!(rmse < 5e-3, "service R2C rel-RMSE {rmse:.3e}");
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.get("rfft_requests").unwrap().as_i64(), Some(1));
    assert_eq!(snap.get("large_requests").unwrap().as_i64(), Some(0));
    svc.shutdown();
}

#[test]
fn large_rfft_routes_through_the_real_four_step() {
    // 2^18 has no direct rfft artifact: the service resolves a cached
    // RealFourStepPlan and the packed result matches the radix2 oracle
    let svc = service();
    let n = 1 << 18;
    let bins = n / 2 + 1;
    let sig: Vec<f32> = random_signal(n, 41).iter().map(|c| c.re).collect();
    let t = svc
        .submit(FftRequest {
            op: Op::Rfft1d { n },
            algo: "tc".into(),
            direction: Direction::Forward,
            input: PlanarBatch::from_real(&sig, vec![n]),
        })
        .unwrap();
    let out = t.wait().unwrap();
    assert_eq!(out.shape, vec![1, bins]);
    let q = PlanarBatch::from_real(&sig, vec![1, n]).quantize_f16();
    let want = radix2::fft_vec(&widen(&q.to_complex()), false);
    let rmse = relative_rmse(&want[..bins], &widen(&out.to_complex()));
    assert!(rmse <= 5e-3, "service four-step R2C rel-RMSE {rmse:.3e}");
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.get("rfft_requests").unwrap().as_i64(), Some(1));
    assert_eq!(snap.get("large_requests").unwrap().as_i64(), Some(1));
    svc.shutdown();
}

#[test]
fn rfft2d_requests_route_direct_and_round_trip() {
    // forward R2C 2D through submit(), inverse through the blocking
    // helper; /(nx*ny) recovers the quantized field
    let svc = service();
    let (nx, ny) = (64usize, 64usize);
    let bins = ny / 2 + 1;
    let sig: Vec<f32> = random_signal(nx * ny, 45).iter().map(|c| c.re).collect();
    let t = svc
        .submit(FftRequest {
            op: Op::Rfft2d { nx, ny },
            algo: "tc".into(),
            direction: Direction::Forward,
            input: PlanarBatch::from_real(&sig, vec![nx, ny]),
        })
        .unwrap();
    let spec = t.wait().unwrap();
    assert_eq!(spec.shape, vec![1, nx, bins]);
    let back = svc
        .rfft2d_blocking(spec, "tc", Direction::Inverse)
        .unwrap();
    assert_eq!(back.shape, vec![1, nx, ny]);
    let q = PlanarBatch::from_real(&sig, vec![1, nx, ny]).quantize_f16();
    let scale = (nx * ny) as f32;
    for i in 0..nx * ny {
        assert!(
            (back.re[i] / scale - q.re[i]).abs() < 0.02,
            "sample {i}: {} vs {}",
            back.re[i] / scale,
            q.re[i]
        );
    }
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.get("rfft2d_requests").unwrap().as_i64(), Some(2));
    assert_eq!(snap.get("large_requests").unwrap().as_i64(), Some(0));
    svc.shutdown();
}

#[test]
fn convolve_route_applies_every_filter_of_the_bank() {
    use tcfft::hp::F16;
    use tcfft::workload::spectral::circular_convolve_ref;
    let svc = service();
    let n = 256;
    let filters: Vec<Vec<f32>> = vec![vec![1.0], vec![0.5, 0.25, -0.125]];
    assert_eq!(svc.register_filter_bank("test", n, &filters, "tc").unwrap(), 2);
    // re-registering the same name with the SAME content is an
    // idempotent success (the natural recovery after a cache eviction)
    assert_eq!(svc.register_filter_bank("test", n, &filters, "tc").unwrap(), 2);
    // guards: same name with DIFFERENT content, unknown algos,
    // out-of-range sizes, and unknown banks all fail fast instead of
    // minting or replacing cache entries
    assert!(svc.register_filter_bank("test", n, &[vec![0.9f32]], "tc").is_err());
    assert!(svc.register_filter_bank("x", n, &filters, "nonsense").is_err());
    assert!(svc.register_filter_bank("x", 1000, &filters, "tc").is_err());
    assert!(svc
        .register_filter_bank("x", 1 << 30, &filters, "tc")
        .is_err());
    // resource caps: oversized banks are refused (banks are cached
    // forever and registration is reachable over TCP)
    let too_many: Vec<Vec<f32>> = (0..65).map(|_| vec![1.0f32]).collect();
    assert!(svc.register_filter_bank("x", n, &too_many, "tc").is_err());
    assert!(svc.submit_convolve("nope", PlanarBatch::new(vec![n])).is_err());
    // wrong signal length fails before queuing
    assert!(svc.submit_convolve("test", PlanarBatch::new(vec![n / 2])).is_err());

    let sig: Vec<f32> = (0..2)
        .flat_map(|b| random_signal(n, 80 + b as u64))
        .map(|c| c.re)
        .collect();
    let out = svc
        .convolve_blocking("test", PlanarBatch::from_real(&sig, vec![2, n]))
        .unwrap();
    assert_eq!(out.shape, vec![2, 2, n]);
    for row in 0..2 {
        let xq: Vec<f64> = sig[row * n..(row + 1) * n]
            .iter()
            .map(|&v| F16::from_f32(v).to_f32() as f64)
            .collect();
        for (f, taps) in filters.iter().enumerate() {
            let mut hq = vec![0.0f64; n];
            for (i, &t) in taps.iter().enumerate() {
                hq[i] = F16::from_f32(t).to_f32() as f64;
            }
            let want = circular_convolve_ref(&xq, &hq);
            let got = &out.re[(row * 2 + f) * n..(row * 2 + f + 1) * n];
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for i in 0..n {
                let d = got[i] as f64 - want[i];
                num += d * d;
                den += want[i] * want[i];
            }
            let rmse = (num / den.max(f64::MIN_POSITIVE)).sqrt();
            assert!(rmse < 1e-2, "row {row} filter {f}: rmse {rmse:.3e}");
        }
    }
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.get("conv_batch_requests").unwrap().as_i64(), Some(2));
    svc.shutdown();
}

#[test]
fn convolve_queue_backpressure_rejects_when_full() {
    // the convolve route rides the same bounded queues: with the
    // flusher effectively disabled, overflow submissions get QueueFull
    let svc = Arc::new(FftService::start(
        Arc::clone(shared_runtime()),
        ServiceConfig {
            max_wait: Duration::from_secs(3600), // never deadline-flush
            max_queue: 2,
            inline_exec: false, // keep queued requests queued
            ..ServiceConfig::default()
        },
    ));
    let n = 1024;
    svc.register_filter_bank("bp", n, &[vec![1.0f32]], "tc").unwrap();
    let mut errors = 0;
    let mut tickets = Vec::new();
    for i in 0..4 {
        let sig: Vec<f32> = random_signal(n, i as u64).iter().map(|c| c.re).collect();
        let t = svc
            .submit_convolve("bp", PlanarBatch::from_real(&sig, vec![n]))
            .unwrap();
        tickets.push(t);
    }
    for t in tickets {
        if t.wait_timeout(Duration::from_millis(200)).is_err() {
            errors += 1;
        }
    }
    assert!(errors >= 2, "expected convolve-queue rejections, got {errors}");
    let snap = svc.metrics().snapshot();
    assert!(snap.get("rejected").unwrap().as_i64().unwrap() >= 2);
    assert_eq!(snap.get("conv_batch_requests").unwrap().as_i64(), Some(4));
    svc.shutdown();
}

#[test]
fn rfft_blocking_helper_round_trips() {
    // R2C then C2R through the service helpers recovers the signal
    // (unnormalized inverse: divide by n on the host)
    let svc = service();
    let n = 512;
    let sig: Vec<f32> = (0..2)
        .flat_map(|b| random_signal(n, 70 + b as u64))
        .map(|c| c.re)
        .collect();
    let input = PlanarBatch::from_real(&sig, vec![2, n]);
    let spec = svc
        .rfft1d_blocking(input.clone(), "tc", Direction::Forward)
        .unwrap();
    assert_eq!(spec.shape, vec![2, n / 2 + 1]);
    let back = svc.rfft1d_blocking(spec, "tc", Direction::Inverse).unwrap();
    assert_eq!(back.shape, vec![2, n]);
    let q = input.quantize_f16();
    for i in 0..2 * n {
        assert!(
            (back.re[i] / n as f32 - q.re[i]).abs() < 0.01,
            "sample {i}: {} vs {}",
            back.re[i] / n as f32,
            q.re[i]
        );
    }
    svc.shutdown();
}

#[test]
fn unroutable_requests_fail_fast() {
    let svc = service();
    // not a power of two: no plan and no four-step route
    let r = svc.submit(FftRequest {
        op: Op::Fft1d { n: 1000 },
        algo: "tc".into(),
        direction: Direction::Forward,
        input: PlanarBatch::new(vec![1000]),
    });
    assert!(r.is_err(), "n=1000 must fail fast");
    // 2D sizes without artifacts have no large route either
    let r = svc.submit(FftRequest {
        op: Op::Fft2d { nx: 1024, ny: 1024 },
        algo: "tc".into(),
        direction: Direction::Forward,
        input: PlanarBatch::new(vec![1024, 1024]),
    });
    assert!(r.is_err(), "unknown 2D size must fail fast");
    // unknown algo strings must not mint cached four-step plans
    let r = svc.submit(FftRequest {
        op: Op::Fft1d { n: 1 << 18 },
        algo: "nonsense".into(),
        direction: Direction::Forward,
        input: PlanarBatch::new(vec![1 << 18]),
    });
    assert!(r.is_err(), "unknown algo must fail fast, not fall back");
    // same rules on the real route
    let r = svc.submit(FftRequest {
        op: Op::Rfft1d { n: 1000 },
        algo: "tc".into(),
        direction: Direction::Forward,
        input: PlanarBatch::new(vec![1000]),
    });
    assert!(r.is_err(), "non-power-of-two rfft must fail fast");
    // real 2D sizes beyond the catalog have no large route either
    let r = svc.submit(FftRequest {
        op: Op::Rfft2d { nx: 512, ny: 512 },
        algo: "tc".into(),
        direction: Direction::Forward,
        input: PlanarBatch::new(vec![512, 512]),
    });
    assert!(r.is_err(), "unknown rfft2d size must fail fast");
    let r = svc.submit(FftRequest {
        op: Op::Rfft2d { nx: 100, ny: 100 },
        algo: "tc".into(),
        direction: Direction::Forward,
        input: PlanarBatch::new(vec![100, 100]),
    });
    assert!(r.is_err(), "non-power-of-two rfft2d must fail fast");
    svc.shutdown();
}

#[test]
fn large_queue_backpressure_rejects_when_full() {
    // QueueFull must keep working on the four-step route: a bounded
    // large queue with the flusher effectively disabled rejects the
    // overflow submissions
    let svc = Arc::new(FftService::start(
        Arc::clone(shared_runtime()),
        ServiceConfig {
            max_wait: Duration::from_secs(3600), // never deadline-flush
            max_queue: 2,
            inline_exec: false, // keep queued requests queued
            ..ServiceConfig::default()
        },
    ));
    let n = 1 << 18;
    let mut errors = 0;
    let mut tickets = Vec::new();
    for i in 0..4 {
        let sig = random_signal(n, i as u64);
        let t = svc
            .submit(FftRequest {
                op: Op::Fft1d { n },
                algo: "tc".into(),
                direction: Direction::Forward,
                input: PlanarBatch::from_complex(&sig, vec![n]),
            })
            .unwrap();
        tickets.push(t);
    }
    for t in tickets {
        if t.wait_timeout(Duration::from_millis(200)).is_err() {
            errors += 1;
        }
    }
    assert!(errors >= 2, "expected large-queue rejections, got {errors}");
    let snap = svc.metrics().snapshot();
    assert!(snap.get("rejected").unwrap().as_i64().unwrap() >= 2);
    assert_eq!(snap.get("large_requests").unwrap().as_i64(), Some(4));
    svc.shutdown();
}

#[test]
fn blocking_helper_preserves_order() {
    let svc = service();
    let n = 1024;
    let x: Vec<C32> = (0..3).flat_map(|b| random_signal(n, 60 + b as u64)).collect();
    let input = PlanarBatch::from_complex(&x, vec![3, n]);
    let out = svc
        .fft1d_blocking(input.clone(), "tc", Direction::Forward)
        .unwrap();
    assert_eq!(out.shape, vec![3, n]);
    let want = mixed::fft_mixed_batch(&widen(&input.quantize_f16().to_complex()), 3, n, false);
    let err = relative_error(&want, &widen(&out.to_complex()));
    assert!(err < 5e-3, "order scrambled? err {err}");
    svc.shutdown();
}

#[test]
fn tcp_server_round_trip() {
    let svc = service();
    let server = Server::bind("127.0.0.1:0", Arc::clone(&svc)).unwrap();
    let addr = server.local_addr().unwrap();
    let stop = server.stop_handle();
    let h = std::thread::spawn(move || server.run());

    use std::io::{BufRead, BufReader, Write};
    let mut conn = std::net::TcpStream::connect(addr).unwrap();
    // ping
    conn.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("true"), "ping reply: {line}");

    // small fft1d over the wire
    let sig = random_signal(256, 5);
    let re: Vec<String> = sig.iter().map(|c| format!("{:.4}", c.re)).collect();
    let im: Vec<String> = sig.iter().map(|c| format!("{:.4}", c.im)).collect();
    let req = format!(
        "{{\"op\":\"fft1d\",\"n\":256,\"re\":[{}],\"im\":[{}]}}\n",
        re.join(","),
        im.join(",")
    );
    conn.write_all(req.as_bytes()).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let resp = tcfft::util::json::Json::parse(line.trim()).unwrap();
    assert_eq!(resp.get("ok").and_then(|b| b.as_bool()), Some(true), "{line}");
    assert_eq!(resp.get("re").unwrap().as_arr().unwrap().len(), 256);

    // small rfft1d over the wire: 32 real samples -> 17 packed bins
    // ("im" omitted — the R2C forward protocol doesn't require it)
    let sig: Vec<f32> = random_signal(32, 6).iter().map(|c| c.re).collect();
    let re: Vec<String> = sig.iter().map(|v| format!("{v:.4}")).collect();
    let req = format!("{{\"op\":\"rfft1d\",\"n\":32,\"re\":[{}]}}\n", re.join(","));
    conn.write_all(req.as_bytes()).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let resp = tcfft::util::json::Json::parse(line.trim()).unwrap();
    assert_eq!(resp.get("ok").and_then(|b| b.as_bool()), Some(true), "{line}");
    assert_eq!(resp.get("re").unwrap().as_arr().unwrap().len(), 17);

    // small rfft2d over the wire: 16x16 real samples -> 16x9 bins
    let sig: Vec<f32> = random_signal(256, 7).iter().map(|c| c.re).collect();
    let re: Vec<String> = sig.iter().map(|v| format!("{v:.4}")).collect();
    let req = format!(
        "{{\"op\":\"rfft2d\",\"nx\":16,\"ny\":16,\"re\":[{}]}}\n",
        re.join(",")
    );
    conn.write_all(req.as_bytes()).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let resp = tcfft::util::json::Json::parse(line.trim()).unwrap();
    assert_eq!(resp.get("ok").and_then(|b| b.as_bool()), Some(true), "{line}");
    assert_eq!(resp.get("re").unwrap().as_arr().unwrap().len(), 16 * 9);

    // register a 2-filter bank and convolve over the wire
    let req = "{\"op\":\"register_bank\",\"bank\":\"w\",\"n\":64,\
               \"filters\":[[1.0],[0.5,0.25]]}\n";
    conn.write_all(req.as_bytes()).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let resp = tcfft::util::json::Json::parse(line.trim()).unwrap();
    assert_eq!(resp.get("ok").and_then(|b| b.as_bool()), Some(true), "{line}");
    assert_eq!(resp.get("k").and_then(|v| v.as_usize()), Some(2));
    let sig: Vec<f32> = random_signal(64, 8).iter().map(|c| c.re).collect();
    let re: Vec<String> = sig.iter().map(|v| format!("{v:.4}")).collect();
    let req = format!("{{\"op\":\"convolve\",\"bank\":\"w\",\"re\":[{}]}}\n", re.join(","));
    conn.write_all(req.as_bytes()).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let resp = tcfft::util::json::Json::parse(line.trim()).unwrap();
    assert_eq!(resp.get("ok").and_then(|b| b.as_bool()), Some(true), "{line}");
    // all k=2 filter outputs back, concatenated
    assert_eq!(resp.get("re").unwrap().as_arr().unwrap().len(), 2 * 64);
    // unknown banks fail over the wire too
    conn.write_all(b"{\"op\":\"convolve\",\"bank\":\"zz\",\"re\":[0.0]}\n")
        .unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("false"), "unknown bank must error: {line}");

    // metrics op
    conn.write_all(b"{\"op\":\"metrics\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("latency_p50_ms"), "{line}");
    assert!(line.contains("conv_batch_requests"), "{line}");

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    // drop BOTH fds (conn and its clone inside reader) so the server's
    // connection handler sees EOF and run() can join it
    drop(reader);
    drop(conn);
    let _ = h.join();
    svc.shutdown();
}

#[test]
fn backpressure_rejects_when_queue_full() {
    let svc = Arc::new(FftService::start(
        Arc::clone(shared_runtime()),
        ServiceConfig {
            max_wait: Duration::from_secs(3600), // never deadline-flush
            max_queue: 2,
            exec_threads: 1,
            inline_exec: false, // keep queued requests queued
            ..ServiceConfig::default()
        },
    ));
    // capacity 4 queue bounded at 2: the 3rd+ submissions are rejected
    let mut errors = 0;
    let mut tickets = Vec::new();
    for i in 0..4 {
        let sig = random_signal(1024, i as u64);
        let t = svc
            .submit(FftRequest {
                op: Op::Fft1d { n: 1024 },
                algo: "tc".into(),
                direction: Direction::Forward,
                input: PlanarBatch::from_complex(&sig, vec![1024]),
            })
            .unwrap();
        tickets.push(t);
    }
    for t in tickets {
        if t.wait_timeout(Duration::from_millis(200)).is_err() {
            errors += 1;
        }
    }
    assert!(errors >= 2, "expected rejections, got {errors}");
    let m = svc.metrics();
    assert!(m.snapshot().get("rejected").unwrap().as_i64().unwrap() >= 2);
    svc.shutdown();
}
