//! Integration tests of the runtime: registry -> plan -> execute ->
//! verify against the host f64 oracles.  Runs against the default
//! backend: the pure-Rust interpreter over the synthesized catalog, so
//! no artifacts on disk are required.

use std::sync::OnceLock;

use tcfft::error::relative_error;
use tcfft::fft::{mixed, radix2};
use tcfft::hp::{C32, C64};
use tcfft::plan::{Direction, Plan};
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::workload::random_signal;

// One shared runtime per test binary: the backend builds each staged
// pipeline once.
fn runtime() -> &'static Runtime {
    static RT: OnceLock<Runtime> = OnceLock::new();
    RT.get_or_init(|| Runtime::load_default().expect("runtime must load without artifacts"))
}

fn widen(x: &[C32]) -> Vec<C64> {
    x.iter().map(|c| C64::new(c.re as f64, c.im as f64)).collect()
}

#[test]
fn fft1d_256_matches_oracle() {
    let rt = runtime();
    let plan = Plan::fft1d(&rt.registry, 256, 4).unwrap();
    let x: Vec<C32> = (0..4).flat_map(|b| random_signal(256, b as u64)).collect();
    let input = PlanarBatch::from_complex(&x, vec![4, 256]);
    let out = plan.execute(&rt, input.clone()).unwrap();
    let want = mixed::fft_mixed_batch(&widen(&input.quantize_f16().to_complex()), 4, 256, false);
    let err = relative_error(&want, &widen(&out.to_complex()));
    assert!(err < 5e-3, "rel err {err}");
}

#[test]
fn fft1d_all_algos_agree() {
    let rt = runtime();
    let n = 4096;
    let x: Vec<C32> = (0..4).flat_map(|b| random_signal(n, 7 + b as u64)).collect();
    let input = PlanarBatch::from_complex(&x, vec![4, n]);
    let mut outs = Vec::new();
    for algo in ["tc", "tc_split", "r2"] {
        let plan = Plan::fft1d_algo(&rt.registry, n, 4, algo, Direction::Forward).unwrap();
        outs.push(widen(&plan.execute(&rt, input.clone()).unwrap().to_complex()));
    }
    // all three algorithms compute the same transform (fp16 tolerance)
    let e01 = relative_error(&outs[0], &outs[1]);
    let e02 = relative_error(&outs[0], &outs[2]);
    assert!(e01 < 3e-3, "tc vs tc_split {e01}");
    assert!(e02 < 3e-3, "tc vs r2 {e02}");
}

#[test]
fn batch_padding_and_splitting() {
    let rt = runtime();
    // artifact batch is 4; drive it with 1, 3, 5 and 9 rows
    let n = 1024;
    let plan = Plan::fft1d(&rt.registry, n, 4).unwrap();
    for rows in [1usize, 3, 5, 9] {
        let x: Vec<C32> = (0..rows).flat_map(|b| random_signal(n, b as u64)).collect();
        let input = PlanarBatch::from_complex(&x, vec![rows, n]);
        let out = plan.execute(&rt, input.clone()).unwrap();
        assert_eq!(out.shape, vec![rows, n]);
        let want =
            mixed::fft_mixed_batch(&widen(&input.quantize_f16().to_complex()), rows, n, false);
        let err = relative_error(&want, &widen(&out.to_complex()));
        assert!(err < 5e-3, "rows={rows} err {err}");
    }
}

#[test]
fn inverse_round_trip_1d() {
    let rt = runtime();
    let n = 4096;
    let fwd = Plan::fft1d(&rt.registry, n, 4).unwrap();
    let inv = Plan::fft1d_algo(&rt.registry, n, 4, "tc", Direction::Inverse).unwrap();
    let x: Vec<C32> = (0..4).flat_map(|b| random_signal(n, 31 + b as u64)).collect();
    let input = PlanarBatch::from_complex(&x, vec![4, n]);
    let spec = fwd.execute(&rt, input.clone()).unwrap();
    let mut back = inv.execute(&rt, spec).unwrap();
    for v in back.re.iter_mut().chain(back.im.iter_mut()) {
        *v /= n as f32; // unnormalized inverse (cuFFT convention)
    }
    let err = relative_error(
        &widen(&input.quantize_f16().to_complex()),
        &widen(&back.to_complex()),
    );
    assert!(err < 5e-3, "round-trip err {err}");
}

#[test]
fn fft2d_matches_host_fft2() {
    let rt = runtime();
    let (nx, ny) = (128, 128);
    let plan = Plan::fft2d(&rt.registry, nx, ny, 2).unwrap();
    let x: Vec<C32> = (0..2).flat_map(|b| random_signal(nx * ny, b as u64)).collect();
    let input = PlanarBatch::from_complex(&x, vec![2, nx, ny]);
    let out = plan.execute(&rt, input.clone()).unwrap();
    let q = input.quantize_f16().to_complex();
    let mut want = Vec::new();
    for b in 0..2 {
        let mut m = widen(&q[b * nx * ny..(b + 1) * nx * ny]);
        radix2::fft2(&mut m, nx, ny, false);
        want.extend(m);
    }
    let err = relative_error(&want, &widen(&out.to_complex()));
    assert!(err < 5e-3, "2D err {err}");
}

#[test]
fn linearity_through_the_device() {
    let rt = runtime();
    // FFT(a + b) == FFT(a) + FFT(b) within fp16 tolerance
    let n = 1024;
    let plan = Plan::fft1d(&rt.registry, n, 4).unwrap();
    let a: Vec<C32> = random_signal(n, 1).iter().map(|c| c.scale(0.5)).collect();
    let b: Vec<C32> = random_signal(n, 2).iter().map(|c| c.scale(0.5)).collect();
    let sum: Vec<C32> = a.iter().zip(&b).map(|(&x, &y)| x + y).collect();
    let run = |sig: &[C32]| {
        let input = PlanarBatch::from_complex(sig, vec![1, n]);
        widen(&plan.execute(&rt, input).unwrap().to_complex())
    };
    let fa = run(&a);
    let fb = run(&b);
    let fs = run(&sum);
    let lin: Vec<C64> = fa.iter().zip(&fb).map(|(&x, &y)| x + y).collect();
    let err = relative_error(&lin, &fs);
    assert!(err < 1e-2, "linearity err {err}");
}

#[test]
fn registry_rejects_missing_artifacts() {
    let rt = runtime();
    // the synthesized 1D ladder stops at 2^17
    assert!(Plan::fft1d(&rt.registry, 1 << 20, 4).is_err()); // size not built
    assert!(Plan::fft1d_algo(&rt.registry, 256, 4, "nonsense", Direction::Forward).is_err());
    assert!(Plan::fft1d(&rt.registry, 100, 1).is_err()); // not a power of two
}

#[test]
fn exec_stats_reported() {
    let rt = runtime();
    let key = "fft1d_tc_n256_b4_fwd";
    let x: Vec<C32> = (0..4).flat_map(|b| random_signal(256, b as u64)).collect();
    let input = PlanarBatch::from_complex(&x, vec![4, 256]);
    let (_, s1) = rt.execute(key, input.clone()).unwrap();
    let (_, s2) = rt.execute(key, input).unwrap();
    assert!(s1.exec_seconds > 0.0);
    // second call must hit the executable cache
    assert!(!s2.compiled);
}

#[test]
fn precision_recovery_reduces_error() {
    let rt = runtime();
    // paper future-work #2: hi/lo split recovers input-quantization
    // error; internal fp16 rounding remains, so expect a measurable
    // (not order-of-magnitude) improvement.
    let n = 4096;
    let plan = Plan::fft1d(&rt.registry, n, 4).unwrap();
    let x: Vec<C32> = random_signal(n, 12345);
    let input = PlanarBatch::from_complex(&x, vec![1, n]);
    // oracle on the EXACT (f32) input this time — recovery targets the
    // quantization of the input itself
    let want = mixed::fft_mixed_batch(&widen(&x), 1, n, false);
    let plain = plan.execute(&rt, input.clone()).unwrap();
    let recovered = tcfft::recovery::execute_recovered(&plan, &rt, &input).unwrap();
    let e_plain = relative_error(&want, &widen(&plain.to_complex()));
    let e_rec = relative_error(&want, &widen(&recovered.to_complex()));
    eprintln!("plain {e_plain:.3e} recovered {e_rec:.3e} (gain {:.2}x)", e_plain / e_rec);
    assert!(e_rec < e_plain, "recovery must not hurt: {e_rec} vs {e_plain}");
}

#[test]
fn four_step_composition_matches_oracle() {
    let rt = runtime();
    // paper Sec 3.1: large FFTs composed from basic kernels
    let n = 1 << 16; // 256 x 256 over the available artifacts
    let plan = tcfft::large::FourStepPlan::new(rt, n, false).unwrap();
    assert_eq!(plan.n(), n);
    let x = random_signal(n, 2024);
    let y = plan.execute(rt, &x).unwrap();
    let xq: Vec<C64> = PlanarBatch::from_complex(&x, vec![1, n])
        .quantize_f16()
        .to_complex()
        .iter()
        .map(|c| C64::new(c.re as f64, c.im as f64))
        .collect();
    let want = radix2::fft_vec(&xq, false);
    let got: Vec<C64> = y.iter().map(|c| C64::new(c.re as f64, c.im as f64)).collect();
    let err = relative_error(&want, &got);
    assert!(err < 5e-3, "four-step err {err}");
}

#[test]
fn warm_reports_compile_time_once() {
    let rt = runtime();
    let key = "fft1d_tc_n1024_b4_fwd";
    let first = rt.warm(key).unwrap();
    let second = rt.warm(key).unwrap();
    let _ = first; // may be 0 if another test already compiled it
    assert_eq!(second, 0.0, "second warm must hit the cache");
}
