//! Conformance of the batched four-step large-FFT engine against the
//! host f64 oracles: batched execution across decompositions
//! (including the forced multi-level path), inverse round trip, algo
//! selection/fallback, and agreement with the kept per-sequence
//! baseline.
//!
//! Oracle strategy mirrors `conformance_interpreter.rs`: the f64
//! radix-2 FFT (itself anchored to the O(N^2) DFT definition) applied
//! to the fp16-quantized input, checked by relative RMSE with the same
//! 5e-3 bound.

use std::sync::{Arc, OnceLock};

use tcfft::error::relative_rmse;
use tcfft::fft::radix2;
use tcfft::hp::complex::widen;
use tcfft::hp::{C32, C64};
use tcfft::large::{BaselineFourStep, FourStepConfig, FourStepPlan};
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::workload::random_signal;

const RMSE_TOL: f64 = 5e-3;

fn runtime() -> &'static Arc<Runtime> {
    static RT: OnceLock<Arc<Runtime>> = OnceLock::new();
    RT.get_or_init(|| {
        Arc::new(Runtime::load("/definitely/not/a/dir").expect("synthesized runtime"))
    })
}

/// f64 radix-2 oracle per batch row, on the fp16-quantized input.
fn oracle_rows(q: &PlanarBatch, inverse: bool) -> Vec<C64> {
    let n = q.shape[1];
    let x = widen(&q.to_complex());
    let mut out = Vec::with_capacity(x.len());
    for row in x.chunks(n) {
        out.extend(radix2::fft_vec(row, inverse));
    }
    out
}

fn check_rows(plan: &FourStepPlan, input: &PlanarBatch, inverse: bool, what: &str) {
    let rt = runtime();
    let out = plan.execute_batch(rt, input.clone()).unwrap();
    assert_eq!(out.shape, input.shape, "{what}: shape");
    let n = input.shape[1];
    let want = oracle_rows(&input.quantize_f16(), inverse);
    let got = widen(&out.to_complex());
    for b in 0..input.shape[0] {
        let (lo, hi) = (b * n, (b + 1) * n);
        let rmse = relative_rmse(&want[lo..hi], &got[lo..hi]);
        assert!(
            rmse < RMSE_TOL,
            "{what} row={b}: rel-RMSE {rmse:.3e} over {RMSE_TOL:.1e} (plan {})",
            plan.describe()
        );
    }
}

fn batch_input(n: usize, b: usize, seed: u64) -> PlanarBatch {
    let x: Vec<C32> = (0..b as u64)
        .flat_map(|i| random_signal(n, seed + i))
        .collect();
    PlanarBatch::from_complex(&x, vec![b, n])
}

#[test]
fn batched_single_level_matches_radix2_oracle() {
    let rt = runtime();
    let plan = FourStepPlan::new(rt, 1 << 18, false).unwrap();
    assert_eq!(plan.depth(), 1);
    check_rows(&plan, &batch_input(1 << 18, 3, 0x51), false, "n=2^18 b=3");
}

#[test]
fn decomposition_sweep_matches_oracle() {
    // a spread of sizes, including one with a direct artifact (2^16)
    // and one odd log2 (unbalanced factors)
    let rt = runtime();
    for t in [14usize, 15, 16] {
        let plan = FourStepPlan::new(rt, 1 << t, false).unwrap();
        check_rows(&plan, &batch_input(1 << t, 2, 0x60 + t as u64), false, &format!("n=2^{t}"));
    }
}

#[test]
fn forced_multi_level_matches_oracle() {
    // a small leaf cap forces two four-step levels at a size the f64
    // oracle covers instantly
    let rt = runtime();
    let cfg = FourStepConfig { max_leaf_log2: 5, ..FourStepConfig::default() };
    let plan = FourStepPlan::with_config(rt, 1 << 12, false, cfg).unwrap();
    assert!(plan.depth() >= 2, "expected multi-level, got {}", plan.describe());
    check_rows(&plan, &batch_input(1 << 12, 4, 0x71), false, "multi-level n=2^12");
}

#[test]
fn inverse_round_trip_recovers_the_quantized_input() {
    // forward then unnormalized inverse, scaled by 1/N. Inputs are
    // scaled down so the unnormalized inverse peaks (~N * max|x|) stay
    // inside fp16 range at n=2^16 — a dynamic-range property of half
    // precision, not an engine artifact.
    let rt = runtime();
    let n = 1 << 16;
    let fwd = FourStepPlan::new(rt, n, false).unwrap();
    let inv = FourStepPlan::new(rt, n, true).unwrap();
    let x: Vec<C32> = random_signal(n, 0x81).iter().map(|c| c.scale(1.0 / 64.0)).collect();
    let input = PlanarBatch::from_complex(&x, vec![1, n]);
    let spec = fwd.execute_batch(rt, input.clone()).unwrap();
    let mut back = inv.execute_batch(rt, spec).unwrap();
    for v in back.re.iter_mut().chain(back.im.iter_mut()) {
        *v /= n as f32;
    }
    let want = widen(&input.quantize_f16().to_complex());
    let got = widen(&back.to_complex());
    let rmse = relative_rmse(&want, &got);
    assert!(rmse < 2.0 * RMSE_TOL, "round-trip rel-RMSE {rmse:.3e}");
}

#[test]
fn r2_leaves_serve_the_four_step() {
    // 2^16 = 256 x 256 and the r2 catalog has forward 256-point
    // artifacts, so the requested algo is honored end to end
    let rt = runtime();
    let plan = FourStepPlan::with_algo(rt, 1 << 16, "r2", false).unwrap();
    assert_eq!(plan.algo(), "r2");
    assert!(plan.describe().contains("[r2]"), "decomposition: {}", plan.describe());
    check_rows(&plan, &batch_input(1 << 16, 1, 0x91), false, "r2 n=2^16");
}

#[test]
fn tc_ec_leaves_serve_the_four_step_and_match_the_direct_path() {
    // 2^16 = 256 x 256 and the catalog has tc_ec artifacts both for
    // the direct 65536-point transform and the 256-point leaves, so
    // the requested tier is honored end to end AND the two routes can
    // be compared.  The four-step host twiddles are plain f32
    // (~6e-8), so both paths sit at compensated accuracy and must
    // agree far below fp16 noise.
    let rt = runtime();
    let n = 1 << 16;
    let plan = FourStepPlan::with_algo(rt, n, "tc_ec", false).unwrap();
    assert_eq!(plan.algo(), "tc_ec");
    assert!(plan.describe().contains("[tc_ec]"), "decomposition: {}", plan.describe());
    let input = batch_input(n, 4, 0xD1);
    check_rows(&plan, &input, false, "tc_ec n=2^16");
    let four = plan.execute_batch(rt, input.clone()).unwrap();
    let (direct, _) = rt.execute(&format!("fft1d_tc_ec_n{n}_b4_fwd"), input).unwrap();
    let rmse = relative_rmse(&widen(&direct.to_complex()), &widen(&four.to_complex()));
    assert!(rmse < 1e-5, "four-step vs direct tc_ec rel-RMSE {rmse:.3e}");
}

#[test]
fn tc_ec_four_step_hosts_are_bit_identical() {
    // same chunked-by-rows contract as the tc host path, under the ec
    // marshal and ec leaf kernels
    let rt = runtime();
    let n = 1 << 16;
    let mk = |threads| {
        FourStepPlan::with_config(
            rt,
            n,
            false,
            FourStepConfig { algo: "tc_ec".to_string(), threads, ..FourStepConfig::default() },
        )
        .unwrap()
    };
    let input = batch_input(n, 3, 0xD7);
    let a = mk(1).execute_batch(rt, input.clone()).unwrap();
    let b = mk(3).execute_batch(rt, input).unwrap();
    for i in 0..a.len() {
        assert_eq!(a.re[i].to_bits(), b.re[i].to_bits(), "re[{i}]");
        assert_eq!(a.im[i].to_bits(), b.im[i].to_bits(), "im[{i}]");
    }
}

#[test]
fn unavailable_algo_falls_back_to_tc() {
    // tc_split artifacts exist only at 4096/65536, so a 2^14 plan falls
    // back to tc leaves instead of failing (the PR-2 behavior)
    let rt = runtime();
    let plan = FourStepPlan::with_algo(rt, 1 << 14, "tc_split", false).unwrap();
    assert_eq!(plan.algo(), "tc_split");
    assert!(plan.describe().contains("[tc]"), "decomposition: {}", plan.describe());
    check_rows(&plan, &batch_input(1 << 14, 2, 0xA1), false, "fallback n=2^14");
}

#[test]
fn batched_engine_agrees_with_the_per_sequence_baseline() {
    let rt = runtime();
    let n = 1 << 16;
    let engine = FourStepPlan::new(rt, n, false).unwrap();
    let baseline = BaselineFourStep::new(rt, n, "tc", false).unwrap();
    assert_eq!((baseline.n1, baseline.n2), engine.factors(), "same balanced split");
    let x = random_signal(n, 0xB1);
    let got_engine = widen(&engine.execute(rt, &x).unwrap());
    let got_base = widen(&baseline.execute(rt, &x).unwrap());
    // identical artifacts and rounding points; only the twiddle
    // multiply differs (f32 table vs per-call f64), far below fp16 noise
    let rmse = relative_rmse(&got_base, &got_engine);
    assert!(rmse < 1e-3, "engine vs baseline rel-RMSE {rmse:.3e}");
}

#[test]
fn serial_and_parallel_hosts_are_bit_identical() {
    // transposes and twiddles are chunked by contiguous output rows, so
    // the parallel host path must write exactly the serial bytes
    let rt = runtime();
    let n = 1 << 16;
    let serial = FourStepPlan::with_config(
        rt,
        n,
        false,
        FourStepConfig { threads: 1, ..FourStepConfig::default() },
    )
    .unwrap();
    let parallel = FourStepPlan::with_config(
        rt,
        n,
        false,
        FourStepConfig { threads: 3, ..FourStepConfig::default() },
    )
    .unwrap();
    let input = batch_input(n, 3, 0xC1);
    let a = serial.execute_batch(rt, input.clone()).unwrap();
    let b = parallel.execute_batch(rt, input).unwrap();
    for i in 0..a.len() {
        assert_eq!(a.re[i].to_bits(), b.re[i].to_bits(), "re[{i}]");
        assert_eq!(a.im[i].to_bits(), b.im[i].to_bits(), "im[{i}]");
    }
}
