//! Cross-engine precision ladder: rel-RMSE of every accuracy tier
//! against the f64 oracle, asserting the tiers actually form a ladder
//!
//!     rmse(tc_split) >= rmse(tc) >> rmse(tc_ec)
//!
//! with a hard absolute bound on the error-corrected tier.  The oracle
//! is the f64 FFT of the **raw** f32 input, so each tier is charged
//! for its own marshal: `tc`/`tc_split` pay the plain fp16 input
//! quantization (~3e-4 rel), while `tc_ec` carries the input as
//! hi+lo fp16 pairs and keeps the whole transform at compensated
//! accuracy.
//!
//! Calibration (numpy simulation of the exact kernel arithmetic,
//! oracle = f64 FFT, random complex inputs in [-1, 1)):
//!
//! | case                        | tc_split  | tc        | tc_ec     | f32ref    |
//! |-----------------------------|-----------|-----------|-----------|-----------|
//! | 1D fwd n=2^4                | 2.97e-4   | 2.97e-4   | 8.47e-8   |           |
//! | 1D fwd n=2^16               | 6.70e-4   | 5.75e-4   | 2.11e-7   |           |
//! | 1D fwd n=4096 b=32 (head)   | 5.627e-4  | 4.909e-4  | 1.770e-7  | 1.563e-7  |
//! | four-step 64x64 b=4         |           |           | 1.710e-7  |           |
//! | four-step 256x256 b=2       |           |           | 2.005e-7  |           |
//!
//! The `f32ref` column is the ladder's top rung: the test-only raw-f32
//! diagnostic tier (unrounded tables, unquantized input, unrounded
//! stores) — what a plain single-precision pipeline of the same shape
//! would produce.  At the headline point `tc_ec` sits within 1.13x of
//! it; the assertion allows 4x for association differences between the
//! calibration's einsum and the kernels' per-j accumulation.
//!
//! Headline accuracy gain at n=4096 b=32: tc / tc_ec = 2774x (the
//! acceptance floor is 10x).  Notes baked into the assertions:
//!
//! * at single-stage sizes (n = 2^4) `tc_split` and `tc` are **bit
//!   identical** (nothing to de-fuse), so the ordering check is
//!   `split >= 0.98 * tc`, not strict;
//! * the Rust kernels accumulate the radix-R matmul per-j, a slightly
//!   different association than the sim's einsum — covered by the
//!   >400x headroom on the 1e-4 hard bound;
//! * large-n batch coverage is trimmed (b=4 above 2^10) to keep the
//!   debug-build runtime of this suite in check; the full {1,4,32}
//!   grid runs at the small sizes where it is cheap.

use std::sync::{Arc, OnceLock};

use tcfft::error::relative_rmse;
use tcfft::fft::{oracle2d, radix2};
use tcfft::hp::complex::widen;
use tcfft::hp::{C32, C64};
use tcfft::large::{FourStepConfig, FourStepPlan};
use tcfft::runtime::{
    Backend, CpuInterpreter, PlanarBatch, ReferenceInterpreter, Runtime, VariantMeta,
};
use tcfft::workload::random_signal;

/// Hard ceiling for the error-corrected tier (calibrated ~2e-7).
const EC_BOUND: f64 = 1e-4;
/// The compensated tier must beat plain tc by at least this factor
/// (the acceptance floor; calibrated ~2800x at the headline size).
const EC_GAIN: f64 = 10.0;

const ALGOS: [&str; 3] = ["tc_split", "tc", "tc_ec"];

fn meta_for(
    op: &str,
    algo: &str,
    n: usize,
    nx: usize,
    ny: usize,
    batch: usize,
    inverse: bool,
) -> VariantMeta {
    let d = if inverse { "inv" } else { "fwd" };
    let dims = if op == "rfft2d" { format!("nx{nx}x{ny}") } else { format!("n{n}") };
    let input_shape = if op == "rfft2d" { vec![batch, nx, ny] } else { vec![batch, n] };
    VariantMeta {
        key: format!("ladder_{op}_{algo}_{dims}_b{batch}_{d}"),
        file: std::path::PathBuf::new(),
        op: op.to_string(),
        algo: algo.to_string(),
        n,
        nx,
        ny,
        batch,
        inverse,
        input_shape,
        stages: Vec::new(),
        flops_per_seq: 0.0,
        hbm_bytes_per_seq: 0.0,
        radix2_equiv_flops: 0.0,
    }
}

fn run(meta: &VariantMeta, input: PlanarBatch) -> PlanarBatch {
    let be = CpuInterpreter::with_threads(1);
    be.execute(meta, input).unwrap().0
}

/// rel-RMSE of one 1D complex variant against the f64 radix-2 oracle
/// applied to the raw (un-quantized) input.
fn rmse_fft1d(algo: &str, n: usize, batch: usize, inverse: bool, seed: u64) -> f64 {
    let x: Vec<C32> = (0..batch as u64).flat_map(|b| random_signal(n, seed + b)).collect();
    let input = PlanarBatch::from_complex(&x, vec![batch, n]);
    let out = run(&meta_for("fft1d", algo, n, 0, 0, batch, inverse), input);
    let xw = widen(&x);
    let mut want = Vec::with_capacity(xw.len());
    for row in xw.chunks(n) {
        want.extend(radix2::fft_vec(row, inverse));
    }
    relative_rmse(&want, &widen(&out.to_complex()))
}

/// rel-RMSE of the raw-f32 diagnostic tier (through the reference
/// engine, where the test-only tier lives) against the same oracle.
fn rmse_f32ref(n: usize, batch: usize, seed: u64) -> f64 {
    let x: Vec<C32> = (0..batch as u64).flat_map(|b| random_signal(n, seed + b)).collect();
    let input = PlanarBatch::from_complex(&x, vec![batch, n]);
    let be = ReferenceInterpreter::new();
    let out = be.execute(&meta_for("fft1d", "f32ref", n, 0, 0, batch, false), input).unwrap().0;
    let xw = widen(&x);
    let mut want = Vec::with_capacity(xw.len());
    for row in xw.chunks(n) {
        want.extend(radix2::fft_vec(row, false));
    }
    relative_rmse(&want, &widen(&out.to_complex()))
}

/// rel-RMSE of one forward R2C variant against the f64 oracle's
/// packed half-spectrum.
fn rmse_rfft1d(algo: &str, n: usize, batch: usize, seed: u64) -> f64 {
    let bins = n / 2 + 1;
    let sig: Vec<f32> = (0..batch as u64)
        .flat_map(|b| random_signal(n, seed + b))
        .map(|c| c.re)
        .collect();
    let input = PlanarBatch::from_real(&sig, vec![batch, n]);
    let out = run(&meta_for("rfft1d", algo, n, 0, 0, batch, false), input);
    assert_eq!(out.shape, vec![batch, bins]);
    let mut want = Vec::with_capacity(batch * bins);
    for row in sig.chunks(n) {
        let xw: Vec<C64> = row.iter().map(|&r| C64::new(r as f64, 0.0)).collect();
        let full = radix2::fft_vec(&xw, false);
        want.extend_from_slice(&full[..bins]);
    }
    relative_rmse(&want, &widen(&out.to_complex()))
}

/// rel-RMSE of one forward 2D R2C variant against the f64 2D oracle's
/// packed rows.
fn rmse_rfft2d(algo: &str, nx: usize, ny: usize, batch: usize, seed: u64) -> f64 {
    let bins = ny / 2 + 1;
    let sig: Vec<f32> = (0..batch as u64)
        .flat_map(|b| random_signal(nx * ny, seed + b))
        .map(|c| c.re)
        .collect();
    let input = PlanarBatch::from_real(&sig, vec![batch, nx, ny]);
    let out = run(&meta_for("rfft2d", algo, 0, nx, ny, batch, false), input);
    assert_eq!(out.shape, vec![batch, nx, bins]);
    let mut want = Vec::with_capacity(batch * nx * bins);
    for img in sig.chunks(nx * ny) {
        let xw: Vec<C64> = img.iter().map(|&r| C64::new(r as f64, 0.0)).collect();
        let full = oracle2d(&xw, nx, ny, false);
        for r in 0..nx {
            want.extend_from_slice(&full[r * ny..r * ny + bins]);
        }
    }
    relative_rmse(&want, &widen(&out.to_complex()))
}

/// The ladder contract.  `what` names the case in failure messages.
fn assert_ladder(split: f64, tc: f64, ec: f64, what: &str) {
    assert!(
        ec <= EC_BOUND,
        "{what}: tc_ec rmse {ec:.3e} over the {EC_BOUND:.0e} hard bound"
    );
    assert!(
        tc >= EC_GAIN * ec,
        "{what}: tc rmse {tc:.3e} under {EC_GAIN}x the tc_ec rmse {ec:.3e}"
    );
    // at single-stage sizes tc_split == tc bitwise, so allow equality
    // with a little float slack instead of a strict inequality
    assert!(
        split >= 0.98 * tc,
        "{what}: tc_split rmse {split:.3e} below the tc rmse {tc:.3e}"
    );
}

fn ladder_1d(n: usize, batch: usize, inverse: bool, seed: u64) {
    let [split, tc, ec] =
        ALGOS.map(|algo| rmse_fft1d(algo, n, batch, inverse, seed));
    let d = if inverse { "inv" } else { "fwd" };
    assert_ladder(split, tc, ec, &format!("fft1d n={n} b={batch} {d}"));
}

#[test]
fn ladder_holds_across_small_sizes_and_batches() {
    // the full batch grid at the cheap sizes: 2^4..2^10 x {1,4,32}
    for t in 4..=10usize {
        for batch in [1usize, 4, 32] {
            for inverse in [false, true] {
                ladder_1d(1 << t, batch, inverse, 0x1000 + t as u64);
            }
        }
    }
}

#[test]
fn ladder_holds_across_large_sizes() {
    // 2^11..2^16 at b=4 (the batch dimension is covered above; these
    // sizes exist to walk the stage count up to 16 levels)
    for t in 11..=16usize {
        for inverse in [false, true] {
            ladder_1d(1 << t, 4, inverse, 0x2000 + t as u64);
        }
    }
}

#[test]
fn headline_n4096_b32_meets_the_acceptance_gain() {
    // the acceptance case: n=4096 b=32 forward.  Calibrated values:
    // tc_split 5.627e-4, tc 4.909e-4, tc_ec 1.770e-7 (gain 2774x).
    let [split, tc, ec] = ALGOS.map(|algo| rmse_fft1d(algo, 4096, 32, false, 0x4096));
    assert_ladder(split, tc, ec, "headline fft1d n=4096 b=32 fwd");
    // the headline holds with an order of magnitude to spare over the
    // generic gain floor
    assert!(
        tc / ec >= 100.0,
        "headline accuracy gain tc/tc_ec = {:.1}x below 100x (tc {tc:.3e}, ec {ec:.3e})",
        tc / ec
    );
    // the top rung: the compensated tier must sit within a calibrated
    // factor of the raw-f32 diagnostic (measured 1.13x; 4x allows for
    // association differences against the calibration's einsum)
    let f32ref = rmse_f32ref(4096, 32, 0x4096);
    assert!(
        f32ref < 1e-6,
        "f32ref rmse {f32ref:.3e} is not single-precision quality"
    );
    assert!(
        ec <= 4.0 * f32ref,
        "tc_ec rmse {ec:.3e} over 4x the f32ref top rung {f32ref:.3e}"
    );
    assert!(
        f32ref < tc,
        "f32ref rmse {f32ref:.3e} should sit far below tc {tc:.3e}"
    );
}

#[test]
fn ladder_holds_for_rfft1d() {
    for t in [4usize, 8, 12] {
        let [split, tc, ec] = ALGOS.map(|algo| rmse_rfft1d(algo, 1 << t, 4, 0x3000 + t as u64));
        assert_ladder(split, tc, ec, &format!("rfft1d n=2^{t} b=4"));
    }
}

#[test]
fn ladder_holds_for_rfft2d() {
    for (nx, ny) in [(64usize, 64usize), (64, 32)] {
        let [split, tc, ec] =
            ALGOS.map(|algo| rmse_rfft2d(algo, nx, ny, 2, 0x5000 + (nx + ny) as u64));
        assert_ladder(split, tc, ec, &format!("rfft2d {nx}x{ny} b=2"));
    }
}

fn runtime() -> &'static Arc<Runtime> {
    static RT: OnceLock<Arc<Runtime>> = OnceLock::new();
    RT.get_or_init(|| {
        Arc::new(Runtime::load("/definitely/not/a/dir").expect("synthesized runtime"))
    })
}

#[test]
fn ladder_holds_through_a_forced_multi_level_four_step() {
    // a small leaf cap forces two four-step levels at n=2^12; the ec
    // tier must survive the host transpose/twiddle hops (plain f32,
    // ~6e-8) without losing its compensated accuracy.  tc_split has no
    // artifacts at these leaf sizes and falls back to tc leaves — the
    // ladder's >= comparison covers that case by design.
    let rt = runtime();
    let n = 1 << 12;
    let batch = 4;
    let rmse_of = |algo: &str| {
        let cfg = FourStepConfig {
            algo: algo.to_string(),
            max_leaf_log2: 5,
            ..FourStepConfig::default()
        };
        let plan = FourStepPlan::with_config(rt, n, false, cfg).unwrap();
        assert!(plan.depth() >= 2, "expected multi-level, got {}", plan.describe());
        let x: Vec<C32> = (0..batch as u64).flat_map(|b| random_signal(n, 0x6000 + b)).collect();
        let input = PlanarBatch::from_complex(&x, vec![batch, n]);
        let out = plan.execute_batch(rt, input).unwrap();
        let xw = widen(&x);
        let mut want = Vec::with_capacity(xw.len());
        for row in xw.chunks(n) {
            want.extend(radix2::fft_vec(row, false));
        }
        relative_rmse(&want, &widen(&out.to_complex()))
    };
    let [split, tc, ec] = ALGOS.map(rmse_of);
    assert_ladder(split, tc, ec, "multi-level four-step n=2^12 b=4");
}
