//! The bitwise SIMD-vs-scalar contract of `runtime::simd`.
//!
//! Every vector path available on this CPU/build must reproduce the
//! scalar kernels **bit for bit** on every tier — `tc` fused, `tc`
//! two-pass past `FUSE_LIMIT`, `tc_split` (operand rounding), `tc_ec`
//! (compensated products, finite-hi store guard) — across every radix
//! the planner emits (2/4/8/16), forward and inverse, batches that do
//! and do not fill a vector, and the strided 2D packed-bin lanes.
//!
//! Paths are flipped with `simd::force`, the in-process twin of the
//! `TCFFT_SIMD` env knob (`ci.sh` additionally runs the whole suite
//! under `TCFFT_SIMD=scalar`). Forcing is process-global, so every
//! test that flips paths serializes on one mutex and restores auto
//! selection before releasing it; the surrounding tests are immune to
//! the flipping by the module's own contract (any path is bitwise
//! identical), which is exactly what this suite verifies. Machines
//! with no vector ISA skip with a note rather than silently passing.

use std::sync::Mutex;

use tcfft::runtime::simd::{self, SimdPath};
use tcfft::runtime::{Backend, CpuInterpreter, PlanarBatch, VariantMeta};
use tcfft::workload::random_signal;

/// Serializes `simd::force` across the test binary's worker threads.
static FORCE_LOCK: Mutex<()> = Mutex::new(());

fn meta(op: &str, algo: &str, n: usize, batch: usize, inverse: bool) -> VariantMeta {
    let d = if inverse { "inv" } else { "fwd" };
    let input_shape = match (op, inverse) {
        ("fft1d", _) => vec![batch, n],
        ("rfft1d", false) => vec![batch, n],
        ("rfft1d", true) => vec![batch, n / 2 + 1],
        ("fft2d", _) => vec![batch, n, n],
        ("rfft2d", false) => vec![batch, n, n],
        _ => vec![batch, n, n / 2 + 1],
    };
    VariantMeta {
        key: format!("simd_{op}_{algo}_n{n}_b{batch}_{d}"),
        file: std::path::PathBuf::new(),
        op: op.to_string(),
        algo: algo.to_string(),
        n,
        nx: n,
        ny: n,
        batch,
        inverse,
        input_shape,
        stages: Vec::new(),
        flops_per_seq: 0.0,
        hbm_bytes_per_seq: 0.0,
        radix2_equiv_flops: 0.0,
    }
}

/// A deterministic input for `meta`: complex planes for the complex
/// ops, a real plane forward / a Hermitian-plausible packed spectrum
/// inverse for the real ops.
fn input_for(meta: &VariantMeta, seed: u64) -> PlanarBatch {
    let total: usize = meta.input_shape.iter().product();
    let sig = random_signal(total, seed);
    let mut x = PlanarBatch::new(meta.input_shape.clone());
    for (i, c) in sig.iter().enumerate() {
        x.re[i] = c.re;
        x.im[i] = c.im;
    }
    if meta.op.starts_with("rfft") {
        if meta.inverse {
            // packed rows must keep the Hermitian-real endpoints real
            let bins = *meta.input_shape.last().unwrap();
            let rows = total / bins;
            for row in 0..rows {
                x.im[row * bins] = 0.0;
                x.im[row * bins + bins - 1] = 0.0;
            }
        } else {
            // R2C input is real by contract
            x.im.iter_mut().for_each(|v| *v = 0.0);
        }
    }
    x
}

fn assert_bit_identical(a: &PlanarBatch, b: &PlanarBatch, what: &str) {
    assert_eq!(a.shape, b.shape, "{what}: shape");
    for i in 0..a.len() {
        assert_eq!(
            a.re[i].to_bits(),
            b.re[i].to_bits(),
            "{what}: re[{i}] {} vs {}",
            a.re[i],
            b.re[i]
        );
        assert_eq!(
            a.im[i].to_bits(),
            b.im[i].to_bits(),
            "{what}: im[{i}] {} vs {}",
            a.im[i],
            b.im[i]
        );
    }
}

/// True when this machine has no vector path; prints the skip note.
fn skip_no_vector(test: &str) -> bool {
    if simd::available_vector_paths().is_empty() {
        eprintln!(
            "note: {test} skipped — no SIMD path available on this CPU/build \
             (arch {}, avx512 feature {})",
            std::env::consts::ARCH,
            cfg!(feature = "avx512")
        );
        return true;
    }
    false
}

/// Run `metas` under forced scalar, then under every available vector
/// path, and assert each vector run is bitwise identical to scalar.
fn assert_paths_bitwise(metas: &[VariantMeta]) {
    let _g = FORCE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let be = CpuInterpreter::with_threads(1);
    for m in metas {
        let input = input_for(m, 0xC0FFEE ^ m.n as u64 ^ (m.batch as u64) << 32);
        simd::force(Some(SimdPath::Scalar)).unwrap();
        let (y_scalar, _) = be.execute(m, input.clone()).unwrap();
        for path in simd::available_vector_paths() {
            simd::force(Some(path)).unwrap();
            let (y_vec, _) = be.execute(m, input.clone()).unwrap();
            assert_bit_identical(&y_vec, &y_scalar, &format!("{} under {path}", m.key));
        }
    }
    simd::force(None).unwrap();
}

#[test]
fn all_radices_tiers_dirs_batches_are_bitwise() {
    if skip_no_vector("all_radices_tiers_dirs_batches_are_bitwise") {
        return;
    }
    // n = 32/64/128/256 end the schedule with radix 2/4/8/16, and every
    // pipeline opens with a radix-16 n2=1 stage (the cross-group sweep).
    // Batches 1 and 3 leave width-1 remainder cells on every vector
    // width (e.g. n=32 has 2 or 6 first-stage groups); batch 32 fills
    // full panels.
    let mut metas = Vec::new();
    for n in [32usize, 64, 128, 256] {
        for algo in ["tc", "tc_split", "tc_ec"] {
            for inverse in [false, true] {
                for batch in [1usize, 3, 32] {
                    metas.push(meta("fft1d", algo, n, batch, inverse));
                }
            }
        }
    }
    assert_paths_bitwise(&metas);
}

#[test]
fn tc_two_pass_past_fuse_limit_is_bitwise() {
    if skip_no_vector("tc_two_pass_past_fuse_limit_is_bitwise") {
        return;
    }
    // n = 131072 schedules [16,16,16,16,2]; the n2=4096 radix-16 stage
    // and the n2=65536 radix-2 stage price past FUSE_LIMIT, so one
    // pipeline exercises fused AND two-pass tc kernels back to back.
    let metas: Vec<_> = [false, true]
        .into_iter()
        .map(|inv| meta("fft1d", "tc", 131_072, 1, inv))
        .collect();
    assert_paths_bitwise(&metas);
}

#[test]
fn packed_lane_and_real_paths_are_bitwise() {
    if skip_no_vector("packed_lane_and_real_paths_are_bitwise") {
        return;
    }
    // rfft2d's column pass strides over lane = n/2 + 1 = 9 packed bins
    // (an odd lane count: full panels plus width-1 tails on every
    // vector width); fft2d's column pass runs lane = 16; rfft1d wraps
    // the half-size pipeline in the half-spectrum pass.
    let mut metas = Vec::new();
    for algo in ["tc", "tc_split", "tc_ec"] {
        for inverse in [false, true] {
            metas.push(meta("rfft2d", algo, 16, 3, inverse));
            metas.push(meta("fft2d", algo, 16, 3, inverse));
            metas.push(meta("rfft1d", algo, 64, 3, inverse));
        }
    }
    assert_paths_bitwise(&metas);
}

#[test]
fn forcing_an_unavailable_path_errors_and_keeps_selection() {
    let missing: Vec<_> = [SimdPath::Avx2, SimdPath::Avx512, SimdPath::Neon]
        .into_iter()
        .filter(|&p| !simd::available(p))
        .collect();
    if missing.is_empty() {
        eprintln!("note: every vector path is available here; nothing to refuse");
        return;
    }
    let _g = FORCE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    simd::force(Some(SimdPath::Scalar)).unwrap();
    for p in missing {
        assert!(simd::force(Some(p)).is_err(), "{p} must not be forcible");
        assert_eq!(simd::active(), SimdPath::Scalar, "failed force changed the path");
    }
    simd::force(None).unwrap();
}
